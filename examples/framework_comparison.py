"""Reproduce the paper's Figs. 2-3: weak-scaling of Caffe-MPI / CNTK /
MXNet / TensorFlow policies on both clusters, all three CNNs — plus the
beyond-paper bucketed policy — in a single call to the scenario-sweep
engine (:mod:`repro.core.sweep`).

    PYTHONPATH=src python examples/framework_comparison.py
"""
from repro.core.scenarios import ScenarioGrid
from repro.core.sweep import sweep

POLICIES = ("caffe-mpi", "cntk", "mxnet", "tensorflow", "bucketed-25mb")
WORKLOADS = ("alexnet", "googlenet", "resnet50")
CLUSTERS = ("k80-pcie-10gbe", "v100-nvlink-ib")


def table(result, cluster, workload, gpu_counts):
    print(f"\n--- {workload} on {cluster} "
          f"(samples/s; speedup vs 1 GPU) ---")
    header = f"{'framework':14s}" + "".join(f"{f'x{n}':>16s}"
                                            for n in gpu_counts)
    print(header)
    for pol in POLICIES:
        cells = []
        for n in gpu_counts:
            [r] = result.filter(workload=workload, cluster=cluster,
                                policy=pol, n_workers=n)
            cells.append(f"{r['samples_per_sec']:8.0f} ({r['speedup']:4.1f})")
        print(f"{pol:14s}" + "".join(f"{c:>16s}" for c in cells))


def main():
    # One sweep covers both figures: every (workload, cluster, policy,
    # size) cell below is one row of the tidy table.
    grid = ScenarioGrid(workloads=WORKLOADS, clusters=CLUSTERS,
                        worker_counts=(1, 2, 4, 8, 16), policies=POLICIES)
    result = sweep(grid)
    print(f"swept {len(result)} scenarios in {result.elapsed_s:.2f}s "
          f"({result.n_analytical} analytical, {result.n_timeline} "
          f"bucket-timeline, {result.n_simulated} event-driven)")

    print("\nFig. 2 reproduction: single node, 1-4 GPUs")
    for cluster in CLUSTERS:
        for wl in WORKLOADS:
            table(result, cluster, wl, (1, 2, 4))

    print("\nFig. 3 reproduction: 1-4 nodes x 4 GPUs")
    for cluster in CLUSTERS:
        for wl in WORKLOADS:
            table(result, cluster, wl, (4, 8, 16))

    print("\nPaper findings to look for:")
    print(" * K80 cluster scales near-linearly (comm hides behind bwd)")
    print(" * V100 cluster collapses on ResNet (comm-bound; t_c > t_b)")
    print(" * CNTK (no WFBP) always trails the overlapped frameworks")
    print(" * bucketed-25mb (beyond paper) recovers latency-bound losses")


if __name__ == "__main__":
    main()
