"""Reproduce the paper's Figs. 2-3: weak-scaling of Caffe-MPI / CNTK /
MXNet / TensorFlow policies on both clusters, all three CNNs — via the
DAG simulator — and the beyond-paper bucketed policy.

    PYTHONPATH=src python examples/framework_comparison.py
"""
from repro.core.hardware import K80_CLUSTER, V100_CLUSTER
from repro.core.policies import BUCKETED_25MB, FRAMEWORK_POLICIES
from repro.core.predictor import predict_cnn

POLICIES = dict(FRAMEWORK_POLICIES, **{"bucketed*": BUCKETED_25MB})


def table(cluster, workload, gpu_counts):
    print(f"\n--- {workload} on {cluster.name} "
          f"(samples/s; speedup vs 1 GPU) ---")
    header = f"{'framework':14s}" + "".join(f"{f'x{n}':>16s}"
                                            for n in gpu_counts)
    print(header)
    for fw, pol in POLICIES.items():
        cells = []
        for n in gpu_counts:
            nodes = max(1, n // 4)
            c = cluster.with_workers(n_nodes=nodes) if n > 4 else \
                cluster.with_workers(n_nodes=1)
            p = predict_cnn(workload, c, n, pol)
            cells.append(f"{p.samples_per_sec:8.0f} ({p.speedup:4.1f})")
        print(f"{fw:14s}" + "".join(f"{c:>16s}" for c in cells))


def main():
    print("Fig. 2 reproduction: single node, 1-4 GPUs")
    for cluster in (K80_CLUSTER, V100_CLUSTER):
        for wl in ("alexnet", "googlenet", "resnet50"):
            table(cluster, wl, (1, 2, 4))

    print("\nFig. 3 reproduction: 1-4 nodes x 4 GPUs")
    for cluster in (K80_CLUSTER, V100_CLUSTER):
        for wl in ("alexnet", "googlenet", "resnet50"):
            table(cluster, wl, (4, 8, 16))

    print("\nPaper findings to look for:")
    print(" * K80 cluster scales near-linearly (comm hides behind bwd)")
    print(" * V100 cluster collapses on ResNet (comm-bound; t_c > t_b)")
    print(" * CNTK (no WFBP) always trails the overlapped frameworks")
    print(" * bucketed* (beyond paper) recovers latency-bound losses")


if __name__ == "__main__":
    main()
