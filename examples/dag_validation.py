"""§V-D of the paper, against *this machine*: predict the iteration
time of a real multi-device data-parallel training run from its own
measured layer costs via the DAG model, then compare with the measured
wall-clock — the exact Fig. 4 methodology (paper reports 4.6-9.4%
error on Caffe-MPI; we run the same loop on forced host devices).

Spawns itself with XLA_FLAGS=--xla_force_host_platform_device_count=8
so plain `python examples/dag_validation.py` works from a normal
single-device environment.
"""
import json
import os
import subprocess
import sys

N_DEV = 8


def child():
    import time

    import jax
    import jax.numpy as jnp

    from repro.comm.ddp import make_ddp_train_step
    from repro.configs import get_config
    from repro.core.analytical import eq5_wfbp
    from repro.core.dag import IterationCosts, build_ssgd_dag
    from repro.core.policies import CAFFE_MPI, CNTK
    from repro.core.simulator import simulate
    from repro.launch.mesh import make_dp_mesh
    from repro.models import transformer as T
    from repro.optim.sgd import sgd
    from repro.traces.generate import TimedLayer, generate_trace

    cfg = get_config("qwen1.5-4b").reduced(num_layers=4, d_model=128,
                                           num_heads=4, d_ff=256,
                                           vocab_size=1024)
    mesh = make_dp_mesh(N_DEV)
    key = jax.random.PRNGKey(0)
    params = T.init_lm(cfg, key)
    opt = sgd(lr=1e-2, momentum=0.9)
    B, S = 32, 64

    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}

    # --- 1. measure per-layer costs on ONE device (the paper measures
    # per-layer cuDNN times from Caffe) --------------------------------
    local_B = B // N_DEV
    x_tok = batch["tokens"][:local_B]
    emb_layer = TimedLayer("embed",
                           lambda p, t: p[t], params["embedding"])
    unit_layers = []
    p_units = params["units"]

    def block_apply(i):
        def apply(p, x):
            from repro.models import blocks as BL
            positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
            y, _ = BL.apply_block(cfg, cfg.layer_pattern[0], p, x, positions)
            return y
        return apply

    for u in range(cfg.num_units):
        unit_p = jax.tree_util.tree_map(lambda a: a[u], p_units)
        unit_layers.append(TimedLayer(f"layer{u}", block_apply(u),
                                      unit_p["b0"]))

    head_layer = TimedLayer(
        "head", lambda p, x: jnp.einsum("bsd,dv->bsv", x, p),
        params["lm_head"])
    labels_loc = batch["labels"][:local_B]

    def xent(p, logits):
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
        picked = jnp.take_along_axis(logp, labels_loc[..., None], -1)
        return -jnp.mean(picked) + 0.0 * jnp.sum(p)

    loss_layer = TimedLayer("loss", xent, jnp.zeros((1,)))

    trace = generate_trace([emb_layer] + unit_layers + [head_layer,
                                                        loss_layer],
                           x_tok, cfg.name, n_iterations=2, repeats=3)
    mean = trace.mean_iteration()

    # measure the optimizer update itself
    st0 = opt.init(params)
    g0 = jax.tree_util.tree_map(jnp.ones_like, params)
    upd = jax.jit(lambda g, s, p: opt.update(g, s, p))
    jax.block_until_ready(upd(g0, st0, params))
    t0 = time.perf_counter()
    for _ in range(5):
        jax.block_until_ready(upd(g0, st0, params))
    t_u_measured = (time.perf_counter() - t0) / 5

    # comm cost per layer: measure one psum of that many bytes
    from jax.sharding import PartitionSpec as P

    from repro.comm.ddp import shard_map_compat

    def time_psum(nbytes):
        n = max(int(nbytes) // 4, 1)
        arr = jnp.ones((N_DEV, n), jnp.float32)
        f = jax.jit(shard_map_compat(lambda x: jax.lax.pmean(x, "data"),
                                     mesh, in_specs=P("data"),
                                     out_specs=P("data")))
        jax.block_until_ready(f(arr))
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(f(arr))
        return (time.perf_counter() - t0) / 5

    costs = IterationCosts(
        t_f=[r.forward_us * 1e-6 for r in mean],
        t_b=[r.backward_us * 1e-6 for r in mean],
        t_c=[time_psum(r.size_bytes) if r.size_bytes else 0.0 for r in mean],
        t_io=0.0, t_h2d=0.0, t_u=t_u_measured)

    # --- 2. DAG prediction -------------------------------------------
    # The N forced host devices share ONE physical core, so the DAG
    # must model worker compute on a shared channel (oversubscription);
    # the ideal-parallel prediction is reported alongside.
    pred = {}
    for pol in (CAFFE_MPI, CNTK):
        g = build_ssgd_dag(costs, N_DEV, pol, n_iterations=5,
                           shared_compute=True)
        pred[pol.name] = simulate(g).steady_iteration_time()
        g_ideal = build_ssgd_dag(costs, N_DEV, pol, n_iterations=5)
        pred[pol.name + "_ideal_parallel"] = \
            simulate(g_ideal).steady_iteration_time()
    pred["eq5"] = eq5_wfbp(costs)

    # --- 3. measured wall-clock of the real DDP step ------------------
    measured = {}
    for polname in ("wfbp", "at_end"):
        p0 = jax.tree_util.tree_map(lambda x: x.copy(), params)
        st = opt.init(p0)
        step = make_ddp_train_step(cfg, opt, mesh, sync_policy=polname)
        p0, st, m = step(p0, st, batch)          # compile
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        iters = 10
        for _ in range(iters):
            p0, st, m = step(p0, st, batch)
        jax.block_until_ready(m["loss"])
        measured[polname] = (time.perf_counter() - t0) / iters

    err = abs(pred["caffe-mpi"] - measured["wfbp"]) / measured["wfbp"] * 100
    out = {
        "predicted_wfbp_s": pred["caffe-mpi"],
        "predicted_cntk_s": pred["cntk"],
        "predicted_wfbp_ideal_parallel_s": pred["caffe-mpi_ideal_parallel"],
        "eq5_ideal_s": pred["eq5"],
        "measured_wfbp_s": measured["wfbp"],
        "measured_at_end_s": measured["at_end"],
        "prediction_error_pct": err,
        "paper_reported_error_pct": "4.6-9.4 (Caffe-MPI, Fig. 4)",
        "note": "N host devices share one physical core, so the DAG "
                "models worker compute on a shared channel",
    }
    print("RESULT " + json.dumps(out, indent=2))


def main():
    if os.environ.get("_DAG_VALIDATION_CHILD") == "1":
        child()
        return
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={N_DEV}",
               _DAG_VALIDATION_CHILD="1")
    env.setdefault("PYTHONPATH", "src")
    r = subprocess.run([sys.executable, __file__], env=env)
    sys.exit(r.returncode)


if __name__ == "__main__":
    main()
