"""Simulation study on the paper's published trace (§VI): load the
bundled Table VI AlexNet/K80 iteration, replay it through the DAG
model under every policy, and quantify how much communication each
overlap strategy hides — the kind of study the paper released the
trace dataset to enable.

    PYTHONPATH=src python examples/trace_analysis.py
"""
from repro.core import analytical as A
from repro.core.dag import build_ssgd_dag
from repro.core.policies import ALL_POLICIES
from repro.core.simulator import simulate
from repro.traces.bundled import ALEXNET_K80, TOTAL_GRAD_BYTES


def main():
    costs = ALEXNET_K80.to_iteration_costs()
    print(f"trace: {ALEXNET_K80.network} on {ALEXNET_K80.cluster} "
          f"({costs.num_layers} layers, "
          f"{TOTAL_GRAD_BYTES / 1e6:.0f} MB gradients)")
    print(f"  t_io={costs.t_io:.2f}s  fwd={sum(costs.t_f):.2f}s  "
          f"bwd={sum(costs.t_b):.2f}s  comm={sum(costs.t_c):.2f}s")
    tc_no = A.non_overlapped_comm(costs.t_b, costs.t_c)
    print(f"  Eq.5 non-overlappable comm t_c^no = {tc_no:.3f}s "
          f"({tc_no / sum(costs.t_c) * 100:.0f}% of total comm)\n")

    # effective bandwidth/latency implied by the trace itself (layer
    # comm times in Caffe traces include queueing, so bucket fusion is
    # re-derived from bytes at the trace's own effective bandwidth)
    total_bytes = sum(b for b in costs.grad_bytes if b)
    bw_eff = total_bytes / sum(costs.t_c)
    alpha = min(t for t, b in zip(costs.t_c, costs.grad_bytes) if b)

    def comm_scale(nbytes, _naive):
        return nbytes / bw_eff + alpha

    serial = A.eq2_naive_ssgd(costs)
    print(f"{'policy':45s}{'iter (s)':>10s}{'vs naive':>10s}"
          f"{'comm hidden':>12s}")
    for name, pol in ALL_POLICIES.items():
        g = build_ssgd_dag(costs, 2, pol, n_iterations=6,
                           comm_scale=comm_scale)
        t = simulate(g).steady_iteration_time()
        hidden = serial - t
        print(f"{pol.describe():45s}{t:10.3f}{serial / t:10.2f}x"
              f"{hidden:11.3f}s")

    print("\nper-layer comm profile (top 5 by size):")
    recs = sorted(ALEXNET_K80.mean_iteration(), key=lambda r: -r.size_bytes)
    for r in recs[:5]:
        print(f"  {r.name:6s} {r.size_bytes / 1e6:7.1f} MB  "
              f"comm {r.comm_us / 1e3:7.1f} ms")
    print("\nfc6+fc7 carry ~90% of bytes — exactly the layer-wise "
          "imbalance behind the paper's 9.6% bandwidth-utilization "
          "finding; bucketing fuses the small tail.")


if __name__ == "__main__":
    main()
