"""Simulation study on the paper's published trace (§VI): load the
bundled Table VI AlexNet/K80 iteration, replay it through the DAG
model under every policy, and quantify how much communication each
overlap strategy hides — the kind of study the paper released the
trace dataset to enable.  Then close the loop the other way: measure a
*live* jax model into the same trace format and run it through the
same predictor, side by side with the paper's trace.

    PYTHONPATH=src python examples/trace_analysis.py
"""
import tempfile
from pathlib import Path

from repro.core import analytical as A
from repro.core.dag import build_ssgd_dag
from repro.core.hardware import CLUSTERS
from repro.core.policies import ALL_POLICIES, CAFFE_MPI
from repro.core.predictor import predict_workload
from repro.core.simulator import simulate
from repro.traces.bundled import ALEXNET_K80, TOTAL_GRAD_BYTES


def main():
    costs = ALEXNET_K80.to_iteration_costs()
    print(f"trace: {ALEXNET_K80.network} on {ALEXNET_K80.cluster} "
          f"({costs.num_layers} layers, "
          f"{TOTAL_GRAD_BYTES / 1e6:.0f} MB gradients)")
    print(f"  t_io={costs.t_io:.2f}s  fwd={sum(costs.t_f):.2f}s  "
          f"bwd={sum(costs.t_b):.2f}s  comm={sum(costs.t_c):.2f}s")
    tc_no = A.non_overlapped_comm(costs.t_b, costs.t_c)
    print(f"  Eq.5 non-overlappable comm t_c^no = {tc_no:.3f}s "
          f"({tc_no / sum(costs.t_c) * 100:.0f}% of total comm)\n")

    # effective bandwidth/latency implied by the trace itself (layer
    # comm times in Caffe traces include queueing, so bucket fusion is
    # re-derived from bytes at the trace's own effective bandwidth)
    total_bytes = sum(b for b in costs.grad_bytes if b)
    bw_eff = total_bytes / sum(costs.t_c)
    alpha = min(t for t, b in zip(costs.t_c, costs.grad_bytes) if b)

    def comm_scale(nbytes, _naive):
        return nbytes / bw_eff + alpha

    serial = A.eq2_naive_ssgd(costs)
    print(f"{'policy':45s}{'iter (s)':>10s}{'vs naive':>10s}"
          f"{'comm hidden':>12s}")
    for name, pol in ALL_POLICIES.items():
        g = build_ssgd_dag(costs, 2, pol, n_iterations=6,
                           comm_scale=comm_scale)
        t = simulate(g).steady_iteration_time()
        hidden = serial - t
        print(f"{pol.describe():45s}{t:10.3f}{serial / t:10.2f}x"
              f"{hidden:11.3f}s")

    print("\nper-layer comm profile (top 5 by size):")
    recs = sorted(ALEXNET_K80.mean_iteration(), key=lambda r: -r.size_bytes)
    for r in recs[:5]:
        print(f"  {r.name:6s} {r.size_bytes / 1e6:7.1f} MB  "
              f"comm {r.comm_us / 1e3:7.1f} ms")
    print("\nfc6+fc7 carry ~90% of bytes — exactly the layer-wise "
          "imbalance behind the paper's 9.6% bandwidth-utilization "
          "finding; bucketing fuses the small tail.")

    measured_jax_workload()


def measured_jax_workload():
    """The measurement loop, in miniature: instrument a live jax train
    step into the paper's trace format (``repro.measure``), then route
    the measured ``jax:`` workload through ``predict_workload`` next to
    the bundled Table VI trace — two measured networks, one model."""
    from repro.configs import get_config
    from repro.measure import measure_model
    from repro.traces.format import write_trace

    print("\nmeasuring a live jax train step (tiny qwen variant, one "
          "host device)...")
    cfg = get_config("qwen1.5-4b").reduced(num_layers=2, d_model=64,
                                           num_heads=4, d_ff=128,
                                           vocab_size=256)
    run = measure_model(cfg, n_devices=1, batch_per_gpu=2, seq_len=16,
                        policies=("at_end",), repeats=2, step_iters=2)
    with tempfile.TemporaryDirectory() as td:
        path = Path(td) / "qwen-tiny.trace"
        write_trace(run.trace, path)

        cluster = CLUSTERS["v100-nvlink-ib"]
        print(f"\n{'measured workload':26s}{'layers':>7s}"
              f"{'iter (s) @8xV100':>17s}{'speedup':>8s}")
        for wl in (f"jax:{path}", "trace:alexnet-k80"):
            p = predict_workload(wl, cluster, 8, CAFFE_MPI)
            label = "jax:qwen-tiny (live)" if wl.startswith("jax:") \
                else wl
            layers = run.trace.num_layers if wl.startswith("jax:") \
                else ALEXNET_K80.num_layers
            print(f"{label:26s}{layers:7d}{p.iteration_time:17.4f}"
                  f"{p.speedup:8.2f}")
    print("the measured jax trace sweeps through the same predictor, "
          "clusters and collectives as the paper's published trace — "
          "comm is re-derived from its gradient bytes.")


if __name__ == "__main__":
    main()
