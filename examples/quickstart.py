"""Quickstart: the three layers of the framework in ~60 lines.

1. ANALYZE  — build the paper's S-SGD DAG for a workload + cluster and
              predict scaling under each framework policy.
2. TRAIN    — run real S-SGD steps on this machine with the WFBP
              gradient-sync policy and a prefetching input pipeline.
3. TRACE    — emit a paper-format layer-wise trace of the run.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.hardware import V100_CLUSTER
from repro.core.policies import CAFFE_MPI, CNTK
from repro.core.predictor import predict_cnn
from repro.data.pipeline import PrefetchLoader, SyntheticLMDataset
from repro.models import transformer as T
from repro.optim.sgd import sgd
from repro.traces.generate import TimedLayer, generate_trace

# ----------------------------------------------------------------- 1.
print("=== 1. DAG model: ResNet-50 on the V100/InfiniBand cluster ===")
for pol in (CAFFE_MPI, CNTK):
    p = predict_cnn("resnet50", V100_CLUSTER, 16, pol)
    print(f"  {pol.describe():60s} iter={p.iteration_time * 1e3:7.1f} ms "
          f"speedup={p.speedup:5.2f}/16")

# ----------------------------------------------------------------- 2.
print("=== 2. real S-SGD training (reduced gemma3, CPU) ===")
cfg = get_config("gemma3-1b").reduced(num_layers=2)
key = jax.random.PRNGKey(0)
params = T.init_lm(cfg, key)
opt = sgd(lr=3e-3, momentum=0.9)
state = opt.init(params)
loader = PrefetchLoader(SyntheticLMDataset(cfg.vocab_size, 64, 8), depth=2)


@jax.jit
def step(params, state, tokens, labels):
    (loss, _), grads = jax.value_and_grad(
        lambda p: T.loss_fn(cfg, p, tokens, labels), has_aux=True)(params)
    params, state = opt.update(grads, state, params)
    return params, state, loss


for i, batch in zip(range(10), loader):
    params, state, loss = step(params, state,
                               jnp.asarray(batch["tokens"]),
                               jnp.asarray(batch["labels"]))
    if i % 3 == 0:
        print(f"  step {i} loss {float(loss):.4f}")
loader.close()
print(f"  pipeline means: t_io={loader.mean_t_io() * 1e3:.2f} ms "
      f"t_h2d={loader.mean_t_h2d() * 1e3:.2f} ms")

# ----------------------------------------------------------------- 3.
print("=== 3. layer-wise trace (paper Table-VI format) of a 2-layer MLP ===")
k1, k2 = jax.random.split(key)
layers = [
    TimedLayer("fc1", lambda p, x: jnp.tanh(x @ p),
               jax.random.normal(k1, (128, 256)) * 0.05),
    TimedLayer("fc2", lambda p, x: x @ p,
               jax.random.normal(k2, (256, 64)) * 0.05),
]
trace = generate_trace(layers, jnp.ones((8, 128)), "mlp-demo",
                       n_iterations=1, repeats=2,
                       comm_time_fn=lambda b: V100_CLUSTER.allreduce_time(b, 16))
for rec in trace.mean_iteration():
    print(f"  {rec.layer_id} {rec.name:5s} fwd={rec.forward_us:8.1f}us "
          f"bwd={rec.backward_us:8.1f}us comm={rec.comm_us:6.1f}us "
          f"size={rec.size_bytes:9.0f}B")
print("done.")
