"""End-to-end driver (deliverable b): train a mid-size decoder LM for
a few hundred steps with the full stack — prefetching pipeline, WFBP
gradient sync across all local devices, SGD-momentum, periodic
checkpoints — and emit a run report plus a paper-format trace of the
layer costs.

Default model is a ~100M-parameter gemma3-family config; on this
1-core CPU container that is slow, so --preset small (~14M) is the
recorded configuration and --preset full is the real thing.

    PYTHONPATH=src python examples/train_e2e.py --preset small --steps 300
"""
import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import PrefetchLoader, SyntheticLMDataset
from repro.models import transformer as T
from repro.optim.sgd import sgd

PRESETS = {
    # ~100M params: 12 layers x d512 x ff2048, 32k vocab
    "full": dict(num_layers=12, d_model=512, num_heads=8, d_ff=2048,
                 vocab_size=32768, seq=256, batch=8),
    # ~14M params: fits a few hundred steps in CPU minutes
    "small": dict(num_layers=4, d_model=256, num_heads=4, d_ff=1024,
                  vocab_size=8192, seq=128, batch=8),
}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--preset", choices=PRESETS, default="small")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--out-dir", default="results/train_e2e")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args(argv)

    ps = PRESETS[args.preset]
    cfg = get_config("gemma3-1b").reduced(
        num_layers=ps["num_layers"], d_model=ps["d_model"],
        num_heads=ps["num_heads"], d_ff=ps["d_ff"],
        vocab_size=ps["vocab_size"])
    key = jax.random.PRNGKey(0)
    params = T.init_lm(cfg, key)
    n_params = T.param_count(params)
    print(f"model: {cfg.name} {n_params / 1e6:.1f}M params "
          f"pattern={cfg.layer_pattern} x{cfg.num_units}")

    opt = sgd(args.lr, momentum=0.9)
    state = opt.init(params)
    loader = PrefetchLoader(
        SyntheticLMDataset(cfg.vocab_size, ps["seq"], ps["batch"], seed=11),
        depth=2)

    @jax.jit
    def step(params, state, tokens, labels):
        (loss, m), grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, tokens, labels),
            has_aux=True)(params)
        params, state = opt.update(grads, state, params)
        return params, state, loss

    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    losses, times = [], []
    t_prev = time.perf_counter()
    for i, batch in zip(range(args.steps), loader):
        params, state, loss = step(params, state,
                                   jnp.asarray(batch["tokens"]),
                                   jnp.asarray(batch["labels"]))
        loss = float(loss)
        now = time.perf_counter()
        losses.append(loss)
        times.append(now - t_prev)
        t_prev = now
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {loss:.4f} "
                  f"({times[-1] * 1e3:.0f} ms/step)", flush=True)
        if args.ckpt_every and i and i % args.ckpt_every == 0:
            save_checkpoint(out_dir / f"ckpt_{i}.npz", params, state, step=i)
    loader.close()
    save_checkpoint(out_dir / "ckpt_final.npz", params, state,
                    step=args.steps)

    warm = times[3:]
    report = {
        "preset": args.preset, "params_m": n_params / 1e6,
        "steps": args.steps,
        "loss_first": losses[0], "loss_min": min(losses),
        "loss_last_mean10": float(np.mean(losses[-10:])),
        "mean_step_ms": float(np.mean(warm)) * 1e3,
        "tokens_per_s": ps["batch"] * ps["seq"] / float(np.mean(warm)),
        "t_io_ms": loader.mean_t_io() * 1e3,
        "t_h2d_ms": loader.mean_t_h2d() * 1e3,
    }
    (out_dir / "report.json").write_text(json.dumps(report, indent=2))
    print(json.dumps(report, indent=2))
    assert report["loss_last_mean10"] < report["loss_first"], \
        "training did not reduce loss"
    return report


if __name__ == "__main__":
    main()
