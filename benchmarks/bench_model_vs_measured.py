"""Model vs. measurement, on this repo's own stack (paper Fig. 4 / §V-D
closed-loop): measure real jax train steps under each gradient-sync
policy, predict the same iteration times from the harvested per-layer
trace via the DAG model, and report the error.

    PYTHONPATH=src python -m benchmarks.bench_model_vs_measured --smoke \\
        --json BENCH_calibration.json --assert-error-ceiling 200

Per architecture (two by default), the measurement subprocess
(:mod:`repro.measure.run`, forced host devices) produces:

* measured seconds/iteration for ``at_end`` / ``wfbp`` / ``bucketed``;
* a per-layer trace (scan-segmented fwd/bwd, measured collectives);
* an alpha-beta fit of the host's all-reduce and the HLO collective
  byte cross-check.

The parent then predicts each policy's iteration time with
:func:`repro.core.predictor.predict_sync_policy` over the measured
costs, records per-policy error, registers the traces as ``jax:``
workloads and sweeps them through the batched engine (closed-form
*and* bucket-timeline paths) — everything lands in
``BENCH_calibration.json``.  ``--assert-error-ceiling PCT`` turns the
maximum per-policy error into a CI gate (host-CPU wall clocks are
noisy; the ceiling guards against structural model breakage, not
single-digit accuracy).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks.common import row
from repro.comm.sync import DEFAULT_BUCKET_BYTES
from repro.core.predictor import predict_sync_policy
from repro.core.scenarios import ScenarioGrid
from repro.core.sweep import sweep
from repro.core.workloads import (clear_workload_cache, known_workloads,
                                  resolve_workload)
from repro.measure.calibrate import comm_scale_from_fit
from repro.measure.run import (MEASURABLE_ARCHS, Geometry, SMOKE_GEOMETRY,
                               default_out_dir, measure_in_subprocess)
from repro.traces.format import read_trace

DEFAULT_ARCHS = ("qwen1.5-4b", "gemma3-1b")


def predict_policies(doc: dict, trace_path: str) -> dict[str, float]:
    """Model predictions (seconds/iteration) for every measured policy,
    from the harvested trace + calibration fit alone."""
    trace = read_trace(trace_path)
    costs = trace.to_iteration_costs(t_u=doc["t_update_s"])
    fit = doc["allreduce_fit"]
    comm_scale = comm_scale_from_fit(fit["latency_s"],
                                     fit["bandwidth_bytes_per_s"])
    # the modeled bucketed policy uses the very threshold the step was
    # lowered with (one shared constant, repro.comm.sync)
    return {
        pol: predict_sync_policy(costs, doc["n_devices"], pol,
                                 comm_scale=comm_scale,
                                 bucket_bytes=DEFAULT_BUCKET_BYTES)
        for pol in doc["policy_times_s"]
    }


def sweep_measured_workloads(archs: list[str]) -> dict:
    """Sweep the freshly measured ``jax:`` workloads through the
    batched engine — closed-form policies ride the analytical path,
    bucketed/priority the bucket-timeline path — and return the row
    accounting (the acceptance check that lowered models are now
    first-class sweep citizens)."""
    grid = ScenarioGrid(
        workloads=tuple(f"jax:{a}" for a in archs),
        clusters=("k80-pcie-10gbe", "v100-nvlink-ib"),
        worker_counts=(2, 8, 32),
        policies=("cntk", "caffe-mpi", "bucketed-25mb", "priority"),
        collectives=("ring",),
    )
    res = sweep(grid)
    return {
        "n_scenarios": len(res),
        "n_analytical": res.n_analytical,
        "n_timeline": res.n_timeline,
        "n_simulated": res.n_simulated,
        "elapsed_s": res.elapsed_s,
    }


def run(archs=None, geometry: Geometry | None = None,
        out_dir: str | None = None, smoke: bool = True) -> dict:
    archs = list(archs or DEFAULT_ARCHS)
    geometry = geometry or SMOKE_GEOMETRY
    out_dir = out_dir or default_out_dir()
    doc: dict = {
        "smoke": smoke,
        "n_devices": geometry.n_devices,
        "measure_dir": out_dir,
        "policies": None,
        "archs": {},
    }
    t0 = time.time()
    max_err = 0.0
    for arch in archs:
        rec = measure_in_subprocess(arch, out_dir=out_dir,
                                    geometry=geometry)
        predicted = predict_policies(rec, rec["trace_path"])
        policies = sorted(predicted)
        doc["policies"] = policies
        entry = {
            "config": rec["config"],
            "measured_s": rec["policy_times_s"],
            "predicted_s": predicted,
            "error_pct": {},
            "t_update_s": rec["t_update_s"],
            "allreduce_fit": rec["allreduce_fit"],
            "bytes_crosscheck": rec["bytes_crosscheck"],
            "trace_path": rec["trace_path"],
        }
        for pol in policies:
            meas = rec["policy_times_s"][pol]
            pred = predicted[pol]
            err = abs(pred - meas) / meas * 100 if meas else float("inf")
            entry["error_pct"][pol] = err
            max_err = max(max_err, err)
            row(f"calibration/{arch}/{pol}", 0.0,
                f"measured_s={meas:.5f};predicted_s={pred:.5f};"
                f"err_pct={err:.1f}")
        for pol, c in rec["bytes_crosscheck"].items():
            row(f"calibration/{arch}/{pol}-bytes", 0.0,
                f"hlo={c['hlo_bytes']:.0f};expected={c['expected_bytes']:.0f};"
                f"rel_err={c['rel_err']:.2e}")
        doc["archs"][arch] = entry

    # the measured traces are now jax: workloads — sweep them
    os.environ["REPRO_MEASURE_DIR"] = out_dir
    clear_workload_cache()
    names = [w for w in known_workloads() if w.startswith("jax:")]
    for a in archs:
        if f"jax:{a}" not in names:
            raise RuntimeError(
                f"measured workload jax:{a} not enumerated by the "
                f"provider (measure dir {out_dir!r}, found {names})")
        resolve_workload(f"jax:{a}")
    doc["jax_workloads"] = names
    doc["sweep"] = sweep_measured_workloads(archs)
    row("calibration/jax-sweep", doc["sweep"]["elapsed_s"] * 1e6,
        f"scenarios={doc['sweep']['n_scenarios']};"
        f"analytical={doc['sweep']['n_analytical']};"
        f"timeline={doc['sweep']['n_timeline']};"
        f"simulated={doc['sweep']['n_simulated']}")
    doc["max_error_pct"] = max_err
    doc["elapsed_s"] = time.time() - t0
    return doc


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny geometry (CI-sized; a couple of minutes "
                         "on two host CPU devices)")
    ap.add_argument("--archs", default=",".join(DEFAULT_ARCHS),
                    help=f"comma-separated archs from {MEASURABLE_ARCHS}")
    ap.add_argument("--devices", type=int, default=None,
                    help="DP world size (forced host devices)")
    ap.add_argument("--out-dir", default=None,
                    help="measurement directory (default: "
                         "$REPRO_MEASURE_DIR or results/measure/)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full calibration document here")
    ap.add_argument("--assert-error-ceiling", type=float, default=None,
                    metavar="PCT",
                    help="exit 1 if any per-policy |model-measured| "
                         "error exceeds PCT percent")
    args = ap.parse_args(argv)

    archs = [a.strip() for a in args.archs.split(",") if a.strip()]
    for a in archs:
        if a not in MEASURABLE_ARCHS:
            ap.error(f"unknown/unmeasurable arch {a!r}; "
                     f"one of {MEASURABLE_ARCHS}")
    geometry = SMOKE_GEOMETRY if args.smoke else Geometry()
    if args.devices:
        import dataclasses

        geometry = dataclasses.replace(geometry, n_devices=args.devices)
    out_dir = args.out_dir or default_out_dir()

    doc = run(archs, geometry, out_dir, args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"wrote {args.json}")
    print(f"max per-policy error: {doc['max_error_pct']:.1f}%  "
          f"(archs={','.join(archs)}; policies={doc['policies']}; "
          f"{doc['elapsed_s']:.0f}s)")
    if args.assert_error_ceiling is not None \
            and doc["max_error_pct"] > args.assert_error_ceiling:
        print(f"ERROR: max error {doc['max_error_pct']:.1f}% exceeds "
              f"ceiling {args.assert_error_ceiling:g}%", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
