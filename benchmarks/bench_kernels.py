"""Kernel microbenchmarks on CPU: the memory-efficient production
paths (chunked attention, chunked xent) vs naive references, plus the
recurrent scan ops.  Wall-times are CPU-host numbers — the TPU story
is the roofline — but the *ratios* demonstrate the memory/flop
trade-offs hold end to end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_call
from repro.kernels import ref
from repro.kernels.chunked_attention import chunked_attention
from repro.models.loss import chunked_cross_entropy

KEY = jax.random.PRNGKey(0)


def run() -> dict:
    out = {}
    # attention: naive vs chunked at growing sequence length
    B, H, K, hd = 1, 4, 2, 64
    for S in (512, 1024, 2048):
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, K, hd))
        v = jax.random.normal(ks[2], (B, S, K, hd))
        naive = jax.jit(lambda q, k, v: ref.attention(q, k, v, causal=True))
        chunk = jax.jit(lambda q, k, v: chunked_attention(q, k, v, True,
                                                          None, 256, 256))
        jax.block_until_ready(naive(q, k, v))
        jax.block_until_ready(chunk(q, k, v))
        us_n = time_call(lambda: jax.block_until_ready(naive(q, k, v)))
        us_c = time_call(lambda: jax.block_until_ready(chunk(q, k, v)))
        row(f"kernels/attention-naive/S{S}", us_n, "")
        row(f"kernels/attention-chunked/S{S}", us_c,
            f"scores_mem_naive_MB={B * H * S * S * 4 / 1e6:.0f};"
            f"scores_mem_chunked_MB={B * H * 256 * 256 * 4 / 1e6:.1f}")
        out[f"attn_{S}"] = (us_n, us_c)

    # chunked xent vs dense at LLM vocab
    Bx, Sx, d, V = 2, 64, 128, 65536
    x = jax.random.normal(KEY, (Bx, Sx, d))
    head = jax.random.normal(KEY, (d, V)) * 0.02
    labels = jax.random.randint(KEY, (Bx, Sx), 0, V)

    def dense(x, head):
        logp = jax.nn.log_softmax((x @ head).astype(jnp.float32), -1)
        return -jnp.mean(jnp.take_along_axis(logp, labels[..., None],
                                             -1)[..., 0])

    jd = jax.jit(jax.grad(dense))
    jc = jax.jit(jax.grad(lambda x, h: chunked_cross_entropy(x, h, labels)))
    jax.block_until_ready(jd(x, head))
    jax.block_until_ready(jc(x, head))
    us_d = time_call(lambda: jax.block_until_ready(jd(x, head)))
    us_c = time_call(lambda: jax.block_until_ready(jc(x, head)))
    row("kernels/xent-dense-grad/V65536", us_d,
        f"logits_MB={Bx * Sx * V * 4 / 1e6:.0f}")
    row("kernels/xent-chunked-grad/V65536", us_c,
        f"live_MB={Bx * Sx * 8192 * 4 / 1e6:.0f}")
    out["xent"] = (us_d, us_c)

    # recurrent scans (jnp reference path used by models on CPU)
    Bw, Sw, Hw, hdw = 1, 256, 4, 64
    ks = jax.random.split(KEY, 5)
    r = jax.random.normal(ks[0], (Bw, Sw, Hw, hdw)) * 0.5
    kk = jax.random.normal(ks[1], (Bw, Sw, Hw, hdw)) * 0.5
    vv = jax.random.normal(ks[2], (Bw, Sw, Hw, hdw))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (Bw, Sw, Hw, hdw)) - 3.0))
    u = jax.random.normal(ks[4], (Hw, hdw)) * 0.3
    jw = jax.jit(lambda *a: ref.wkv6(*a)[0])
    jax.block_until_ready(jw(r, kk, vv, w, u))
    row("kernels/wkv6-ref/S256",
        time_call(lambda: jax.block_until_ready(jw(r, kk, vv, w, u))),
        f"tokens_per_call={Bw * Sw}")
    return out


if __name__ == "__main__":
    run()
