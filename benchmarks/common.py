"""Shared benchmark plumbing: ``name,us_per_call,derived`` CSV rows."""
from __future__ import annotations

import time
from typing import Callable


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.3f},{derived}"
    print(line, flush=True)
    return line


def time_call(fn: Callable, repeats: int = 5) -> float:
    """Median wall-time of fn() in microseconds."""
    fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
