"""Shared benchmark plumbing: ``name,us_per_call,derived`` CSV rows and
the persistent jax compilation cache every jax-touching benchmark
enables (jit compile time would otherwise dwarf the kernels being
measured on every fresh process — CI pays it once per cache key
instead)."""
from __future__ import annotations

import os
import time
from typing import Callable


def enable_jax_compilation_cache(cache_dir: str | None = None) -> str | None:
    """Point jax at a persistent on-disk compilation cache (created if
    missing) so repeated benchmark / CI processes reuse compiled
    kernels instead of re-tracing them.  Resolution order: explicit
    argument, ``JAX_COMPILATION_CACHE_DIR`` (the env var CI sets, which
    jax also reads natively), ``~/.cache/repro-jax``.  Returns the
    cache directory, or ``None`` when jax is unavailable — callers
    treat the cache as best-effort."""
    try:
        import jax
    except Exception:                                 # pragma: no cover
        return None
    cache_dir = (cache_dir
                 or os.environ.get("JAX_COMPILATION_CACHE_DIR")
                 or os.path.join(os.path.expanduser("~"), ".cache",
                                 "repro-jax"))
    try:
        os.makedirs(cache_dir, exist_ok=True)
        jax.config.update("jax_compilation_cache_dir", cache_dir)
        # benchmark kernels compile fast; cache them anyway
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    except Exception:                                 # pragma: no cover
        return None
    return cache_dir


def row(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.3f},{derived}"
    print(line, flush=True)
    return line


def time_call(fn: Callable, repeats: int = 5) -> float:
    """Median wall-time of fn() in microseconds."""
    fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
