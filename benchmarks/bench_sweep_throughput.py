"""Sweep-engine throughput: scenarios/second for the scenario-axis
**batched** kernel versus the per-scenario reference path, on the
540-scenario default grid, the 1620-scenario mixed-provider grid and
the 25 920-scenario frontier grid.

    PYTHONPATH=src python -m benchmarks.bench_sweep_throughput
    PYTHONPATH=src python -m benchmarks.bench_sweep_throughput --smoke

Prints the shared ``name,us_per_call,derived`` CSV rows and writes
``BENCH_sweep.json`` (override with ``--json``) so the perf trajectory
of the engine is tracked run over run: per grid, ``batched`` and
``per_scenario`` timings plus their ``speedup`` ratio (the ISSUE-3
acceptance gate is >= 25x on the default grid).  ``--smoke`` does one
timed repeat per grid and skips the slow per-scenario pass on the
frontier grid — the CI regression gate.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.common import row
from repro.core.scenarios import default_grid, frontier_grid, mixed_grid
from repro.core.sweep import sweep


def _time_sweep(grid, repeats: int, batched: bool) -> dict:
    n = len(grid)
    sweep(grid, batched=batched)         # warm tables + prepared structure
    elapsed = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = sweep(grid, batched=batched)
        elapsed.append(time.perf_counter() - t0)
    elapsed.sort()
    med = elapsed[len(elapsed) // 2]
    return {
        "n_scenarios": n,
        "elapsed_s": med,
        "scenarios_per_sec": n / med,
        "n_analytical": result.n_analytical,
        "n_simulated": result.n_simulated,
    }


def run(smoke: bool = False, json_path: str = "BENCH_sweep.json") -> dict:
    repeats = 1 if smoke else 5
    grids = {"default_grid": default_grid(), "mixed_grid": mixed_grid(),
             "frontier_grid": frontier_grid()}
    report: dict = {"smoke": smoke, "repeats": repeats}
    for name, grid in grids.items():
        r: dict = {"n_scenarios": len(grid)}
        r["batched"] = _time_sweep(grid, repeats, batched=True)
        row(f"sweep_{name}_batched", r["batched"]["elapsed_s"] * 1e6,
            f"{r['batched']['scenarios_per_sec']:.0f} scenarios/s "
            f"({len(grid)} scenarios)")
        # The per-scenario reference pass on the frontier grid costs
        # seconds; skip it in CI smoke mode (the default-grid ratio is
        # the acceptance gate).
        if not (smoke and name == "frontier_grid"):
            r["per_scenario"] = _time_sweep(grid, repeats, batched=False)
            r["speedup"] = (r["per_scenario"]["elapsed_s"]
                            / r["batched"]["elapsed_s"])
            row(f"sweep_{name}_per_scenario",
                r["per_scenario"]["elapsed_s"] * 1e6,
                f"{r['per_scenario']['scenarios_per_sec']:.0f} scenarios/s "
                f"(batched is {r['speedup']:.1f}x faster)")
        report[name] = r
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {json_path}", flush=True)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single timed repeat per grid, no frontier "
                         "per-scenario pass (CI mode)")
    ap.add_argument("--json", default="BENCH_sweep.json", metavar="PATH",
                    help="output JSON path ('' to skip)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(smoke=args.smoke, json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
