"""Sweep-engine throughput: scenarios/second on the analytical fast
path, for the 540-scenario default grid and the 1620-scenario
mixed-provider grid (cnn: + trace: + llm:).

    PYTHONPATH=src python -m benchmarks.bench_sweep_throughput
    PYTHONPATH=src python -m benchmarks.bench_sweep_throughput --smoke

Prints the shared ``name,us_per_call,derived`` CSV rows and writes
``BENCH_sweep.json`` (override with ``--json``) so the perf trajectory
of the engine is tracked run over run.  ``--smoke`` does one timed
repeat per grid — the CI regression gate.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.common import row
from repro.core.scenarios import default_grid, mixed_grid
from repro.core.sweep import sweep


def _throughput(grid, repeats: int) -> dict:
    n = len(grid)
    sweep(grid)                          # warm the workload-table cache
    elapsed = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = sweep(grid)
        elapsed.append(time.perf_counter() - t0)
    elapsed.sort()
    med = elapsed[len(elapsed) // 2]
    return {
        "n_scenarios": n,
        "elapsed_s": med,
        "scenarios_per_sec": n / med,
        "n_analytical": result.n_analytical,
        "n_simulated": result.n_simulated,
    }


def run(smoke: bool = False, json_path: str = "BENCH_sweep.json") -> dict:
    repeats = 1 if smoke else 5
    grids = {"default_grid": default_grid(), "mixed_grid": mixed_grid()}
    report: dict = {"smoke": smoke, "repeats": repeats}
    for name, grid in grids.items():
        r = _throughput(grid, repeats)
        report[name] = r
        row(f"sweep_{name}", r["elapsed_s"] * 1e6,
            f"{r['scenarios_per_sec']:.0f} scenarios/s "
            f"({r['n_scenarios']} scenarios)")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {json_path}", flush=True)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single timed repeat per grid (CI mode)")
    ap.add_argument("--json", default="BENCH_sweep.json", metavar="PATH",
                    help="output JSON path ('' to skip)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(smoke=args.smoke, json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
