"""Sweep-engine throughput: scenarios/second for the scenario-axis
**batched** kernel versus the per-scenario reference paths, on the
540-scenario default grid, the 1620-scenario mixed-provider grid, the
51 840-scenario frontier grid, and a >= 1000-scenario bucketed/priority
grid whose per-scenario reference is the event-driven simulator.

    PYTHONPATH=src python -m benchmarks.bench_sweep_throughput
    PYTHONPATH=src python -m benchmarks.bench_sweep_throughput --smoke

Prints the shared ``name,us_per_call,derived`` CSV rows and writes
``BENCH_sweep.json`` (override with ``--json``) so the perf trajectory
of the engine is tracked run over run: per grid, ``batched`` and
``per_scenario`` timings plus their ``speedup`` ratio (the ISSUE-3
acceptance gate is >= 25x on the default grid; the ISSUE-4 gate is
>= 20x on the bucketed/priority grid, where the slow side actually
builds and list-schedules a DAG per scenario, so ``n_simulated``
finally records a non-zero simulated-path trajectory).  The frontier
grid only times the batched side — its slow side would list-schedule
~26k DAGs, the exact gap the timeline path closes.

Each grid also records the **jax backend** (ISSUE 6): end-to-end
``sweep(backend="jax")`` throughput, kernel-only throughput for both
backends (warmed, jit compilation excluded), their speedup ratio, and
the max relative numeric disagreement — ``--assert-jax-floor`` gates
CI on kernel speedup >= X on the frontier grid and agreement <= 1e-6
everywhere.

The columnar-pipeline metrics (ISSUE 7): per grid, the
``e2e_over_kernel`` gap ratio (how much of a full ``sweep()`` is not
the kernel — tidy-table assembly used to cost more than the kernel
itself; the columnar result path holds it near 1) and ``jobs2``
process-pool throughput (recorded, not gated: one CI core has nothing
to fan out over).  ``--assert-e2e-floor R`` gates the frontier grid's
end-to-end throughput at >= R scenarios/s on both backends.

The heterogeneity metrics (ISSUE 8): a dedicated het/straggler grid
runs the (S,W,L) slowest-worker kernels plus the straggler Monte
Carlo tail pass end to end on both backends.  ``--assert-het-floor R``
gates CI on het-grid batched throughput >= R scenarios/s (numpy, MC
included) and backend agreement <= 1e-6 — the trajectory lands in
``BENCH_sweep.json`` under ``het_straggler_grid``.

The failure-model metrics (ISSUE 9): a faults + backup-workers grid
crosses K-of-N partial-sync thresholds with ``fail:`` crash specs on
top of compute skew, so the K-th-order-statistic kernels and the
fault Monte Carlo run end to end on both backends.
``--assert-faults-floor R`` gates CI on that grid's batched
throughput >= R scenarios/s (numpy, crash draws included) and
backend agreement <= 1e-6 — the trajectory lands in
``BENCH_sweep.json`` under ``failure_grid``.

``--smoke`` does one timed repeat per grid and shrinks the
bucketed/priority, het/straggler and failure grids — the CI
regression gate (pair with ``--assert-timeline-floor`` /
``--assert-jax-floor`` / ``--assert-e2e-floor`` /
``--assert-het-floor`` / ``--assert-faults-floor``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from benchmarks.common import enable_jax_compilation_cache, row
from repro.core.batched import grid_evaluator
from repro.core.batched_jax import jax_grid_evaluator
from repro.core.hardware import COLLECTIVE_ALGORITHMS
from repro.core.scenarios import (ScenarioGrid, default_grid, frontier_grid,
                                  mixed_grid)
from repro.core.sweep import sweep


def bucketed_priority_grid(smoke: bool = False) -> ScenarioGrid:
    """The schedule-dependent-policy grid: every paper CNN on both
    paper clusters under the bucket-size axis + priority scheduling.
    Full mode is 1080 scenarios (the ISSUE-4 acceptance floor is
    >= 1000); smoke mode shrinks the worker/collective/interconnect
    axes so the per-scenario simulator pass stays CI-sized."""
    kw = dict(workloads=("alexnet", "googlenet", "resnet50"),
              clusters=("k80-pcie-10gbe", "v100-nvlink-ib"),
              policies=("bucketed-1mb", "bucketed-4mb", "bucketed-25mb",
                        "bucketed-100mb", "priority"))
    if smoke:
        return ScenarioGrid(worker_counts=(2, 4),
                            collectives=("ring", "tree"), **kw)
    return ScenarioGrid(worker_counts=(2, 4, 8),
                        collectives=COLLECTIVE_ALGORITHMS,
                        interconnects=(None, "10gbe", "ib-200g",
                                       "ib-100g-fused"), **kw)


def het_straggler_grid(smoke: bool = False) -> ScenarioGrid:
    """The (S,W,L) heterogeneity grid: paper CNNs on both paper
    clusters with compute-skew and link-skew profiles, half the rows
    under a 100-draw lognormal straggler Monte Carlo.  This is the
    path ``--assert-het-floor`` gates: slowest-worker kernels + tail
    statistics end to end."""
    kw = dict(workloads=("alexnet", "googlenet", "resnet50"),
              clusters=("k80-pcie-10gbe", "v100-nvlink-ib"),
              policies=("tensorflow", "bucketed-4mb", "priority"),
              het_profiles=("het:1x0.5+3x1.0", "het:2x1.0@bw0.5"),
              stragglers=(None, "lognormal:0.2x100"))
    if smoke:
        return ScenarioGrid(worker_counts=(4,), collectives=("ring",), **kw)
    return ScenarioGrid(worker_counts=(4, 16),
                        collectives=("ring", "hierarchical"), **kw)


def failure_grid(smoke: bool = False) -> ScenarioGrid:
    """The K-of-N + fault-injection grid: paper CNNs on both paper
    clusters with compute skew, crossed with backup-worker sync
    thresholds (full sync, N-2 and N/2 backups) and crash specs under
    a 100-draw fault Monte Carlo.  This is the path
    ``--assert-faults-floor`` gates: K-th-order-statistic kernels +
    crash-penalty tail statistics end to end."""
    kw = dict(workloads=("alexnet", "googlenet", "resnet50"),
              clusters=("k80-pcie-10gbe", "v100-nvlink-ib"),
              policies=("tensorflow", "bucketed-4mb", "priority"),
              het_profiles=(None, "het:1x0.5+3x1.0"),
              sync_ks=(None, 2, 6),
              faults=(None, "fail:0.01@restart2.5x100"))
    if smoke:
        return ScenarioGrid(worker_counts=(8,), collectives=("ring",), **kw)
    return ScenarioGrid(worker_counts=(8, 16),
                        collectives=("ring", "hierarchical"), **kw)


def _time_sweep(grid, repeats: int, batched: bool,
                backend: str = "numpy", jobs: int | None = None) -> dict:
    n = len(grid)
    # Warm the memoized workload tables + prepared grid structure via
    # the batched path regardless of which side is being timed: the
    # per-scenario paths share the same table memo, and replaying the
    # full simulator sweep just to warm it would double the dominant
    # cost of the bucketed/priority slow side.  (On the jax backend
    # the warm-up run also pays the one-off jit compilation; under
    # jobs>1 it also pays the one-off pool spawn + per-worker
    # evaluator build, so the timed repeats see the steady state.)
    sweep(grid, batched=True, backend=backend, jobs=jobs)
    elapsed = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = sweep(grid, batched=batched, backend=backend, jobs=jobs)
        elapsed.append(time.perf_counter() - t0)
    elapsed.sort()
    med = elapsed[len(elapsed) // 2]
    return {
        "n_scenarios": n,
        "elapsed_s": med,
        "scenarios_per_sec": n / med,
        "n_analytical": result.n_analytical,
        "n_timeline": result.n_timeline,
        "n_simulated": result.n_simulated,
    }


def _time_kernels(grid, repeats: int) -> dict:
    """Kernel-only timings for both backends (tier-1 table + tier-2
    policy select, no tidy-row materialization) plus their numeric
    agreement — the backend-parity surface the ``--assert-jax-floor``
    CI gate checks.  The jax side is warmed first, so jit compilation
    is excluded (steady-state throughput, the number that matters for
    repeated what-if evaluation)."""
    n = len(grid)
    ev = grid_evaluator(grid)
    jev = jax_grid_evaluator(grid)

    def np_kernel():
        return ev.run().columns_slice(0, n)

    def jax_kernel():
        return jev.columns()

    out: dict = {"n_scenarios": n}
    for key, fn in (("numpy_kernel", np_kernel), ("jax_kernel", jax_kernel)):
        cols = fn()                               # warm (jit compile on jax)
        elapsed = []
        for _ in range(repeats):
            t0 = time.perf_counter()
            cols = fn()
            elapsed.append(time.perf_counter() - t0)
        elapsed.sort()
        med = elapsed[len(elapsed) // 2]
        out[key] = {"elapsed_s": med, "scenarios_per_sec": n / med}
        out[key]["iteration_time_s"] = cols["iteration_time_s"]
    a = out["numpy_kernel"].pop("iteration_time_s")
    b = out["jax_kernel"].pop("iteration_time_s")
    out["agreement_max_rel"] = float(np.abs(b - a).max()
                                     / np.abs(a).max()) if n else 0.0
    out["jax_vs_numpy_kernel_speedup"] = (
        out["numpy_kernel"]["elapsed_s"] / out["jax_kernel"]["elapsed_s"])
    return out


def run(smoke: bool = False, json_path: str = "BENCH_sweep.json") -> dict:
    enable_jax_compilation_cache()
    repeats = 1 if smoke else 5
    grids = {"default_grid": default_grid(), "mixed_grid": mixed_grid(),
             "frontier_grid": frontier_grid(),
             "bucketed_priority_grid": bucketed_priority_grid(smoke),
             "het_straggler_grid": het_straggler_grid(smoke),
             "failure_grid": failure_grid(smoke)}
    report: dict = {"smoke": smoke, "repeats": repeats}
    for name, grid in grids.items():
        r: dict = {"n_scenarios": len(grid)}
        r["batched"] = _time_sweep(grid, repeats, batched=True)
        row(f"sweep_{name}_batched", r["batched"]["elapsed_s"] * 1e6,
            f"{r['batched']['scenarios_per_sec']:.0f} scenarios/s "
            f"({len(grid)} scenarios)")
        r["jax"] = _time_sweep(grid, repeats, batched=True, backend="jax")
        row(f"sweep_{name}_jax", r["jax"]["elapsed_s"] * 1e6,
            f"{r['jax']['scenarios_per_sec']:.0f} scenarios/s end to end")
        kern = _time_kernels(grid, repeats)
        r["numpy_kernel"] = kern["numpy_kernel"]
        r["jax_kernel"] = kern["jax_kernel"]
        r["jax_vs_numpy_kernel_speedup"] = kern["jax_vs_numpy_kernel_speedup"]
        r["agreement_max_rel"] = kern["agreement_max_rel"]
        # end-to-end / kernel-only gap: how much of a full sweep() is
        # NOT the kernel (tidy-table assembly, counts, result object).
        # The columnar pipeline exists to drive this toward 1.
        r["e2e_over_kernel"] = {
            "numpy": (r["batched"]["elapsed_s"]
                      / kern["numpy_kernel"]["elapsed_s"]),
            "jax": (r["jax"]["elapsed_s"]
                    / kern["jax_kernel"]["elapsed_s"]),
        }
        row(f"sweep_{name}_numpy_kernel",
            kern["numpy_kernel"]["elapsed_s"] * 1e6,
            f"{kern['numpy_kernel']['scenarios_per_sec']:.0f} scenarios/s "
            f"kernel only (e2e gap "
            f"{r['e2e_over_kernel']['numpy']:.2f}x)")
        row(f"sweep_{name}_jax_kernel",
            kern["jax_kernel"]["elapsed_s"] * 1e6,
            f"{kern['jax_kernel']['scenarios_per_sec']:.0f} scenarios/s "
            f"kernel only ({kern['jax_vs_numpy_kernel_speedup']:.1f}x numpy, "
            f"max rel diff {kern['agreement_max_rel']:.1e}, e2e gap "
            f"{r['e2e_over_kernel']['jax']:.2f}x)")
        # sharded execution: same grid through the process pool.  On a
        # single-core runner this records the overhead floor rather
        # than a speedup; the scaling story needs cores to fan out
        # over, which is why it is recorded, not gated.
        r["jobs2"] = _time_sweep(grid, repeats, batched=True, jobs=2)
        row(f"sweep_{name}_jobs2", r["jobs2"]["elapsed_s"] * 1e6,
            f"{r['jobs2']['scenarios_per_sec']:.0f} scenarios/s "
            f"(2 worker processes)")
        # The per-scenario reference pass on the frontier grid is
        # skipped outright: half its 51 840 scenarios are
        # schedule-dependent, so the slow side would list-schedule
        # ~26k DAGs (tens of minutes) — the unbenchmarkable gap this
        # engine exists to close.  The bucketed/priority grid below is
        # the dedicated simulated-path trajectory; its slow side is
        # timed once (plenty of precision for a >= 20x gate).
        # ... and the het/straggler and failure grids' slow sides would
        # re-evaluate every Monte Carlo draw per scenario in Python;
        # their gates are throughput + agreement, not a speedup ratio.
        if name not in ("frontier_grid", "het_straggler_grid",
                        "failure_grid"):
            slow_repeats = 1 if name == "bucketed_priority_grid" else repeats
            r["per_scenario"] = _time_sweep(grid, slow_repeats, batched=False)
            r["speedup"] = (r["per_scenario"]["elapsed_s"]
                            / r["batched"]["elapsed_s"])
            row(f"sweep_{name}_per_scenario",
                r["per_scenario"]["elapsed_s"] * 1e6,
                f"{r['per_scenario']['scenarios_per_sec']:.0f} scenarios/s "
                f"(batched is {r['speedup']:.1f}x faster)")
        report[name] = r
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {json_path}", flush=True)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single timed repeat per grid, no frontier "
                         "per-scenario pass, shrunken bucketed/priority "
                         "grid (CI mode)")
    ap.add_argument("--json", default="BENCH_sweep.json", metavar="PATH",
                    help="output JSON path ('' to skip)")
    ap.add_argument("--assert-timeline-floor", type=float, default=None,
                    metavar="X",
                    help="exit non-zero unless the bucketed/priority "
                         "grid's batched-vs-simulator speedup is >= X "
                         "(the CI regression gate for the timeline path)")
    ap.add_argument("--assert-jax-floor", type=float, default=None,
                    metavar="X",
                    help="exit non-zero unless the frontier grid's "
                         "jax-vs-numpy kernel speedup is >= X AND the "
                         "backends agree to <= 1e-6 max relative "
                         "difference on every grid (the jax-backend CI "
                         "gate; 1 on the single-core CI runner — XLA "
                         "only pulls ahead of the BLAS-backed NumPy "
                         "kernel with cores/devices to fan out over)")
    ap.add_argument("--assert-e2e-floor", type=float, default=None,
                    metavar="R",
                    help="exit non-zero unless the frontier grid's "
                         "end-to-end batched sweep() throughput is >= R "
                         "scenarios/s on BOTH backends (the columnar-"
                         "pipeline CI gate: tidy-table assembly may not "
                         "reopen the e2e/kernel gap)")
    ap.add_argument("--assert-het-floor", type=float, default=None,
                    metavar="R",
                    help="exit non-zero unless the het/straggler grid's "
                         "end-to-end batched sweep() throughput (numpy, "
                         "Monte Carlo tails included) is >= R scenarios/s "
                         "AND the backends agree to <= 1e-6 on that grid "
                         "(the heterogeneity-engine CI gate)")
    ap.add_argument("--assert-faults-floor", type=float, default=None,
                    metavar="R",
                    help="exit non-zero unless the K-of-N/fault grid's "
                         "end-to-end batched sweep() throughput (numpy, "
                         "crash Monte Carlo included) is >= R scenarios/s "
                         "AND the backends agree to <= 1e-6 on that grid "
                         "(the failure-model CI gate)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    report = run(smoke=args.smoke, json_path=args.json)
    if args.assert_timeline_floor is not None:
        got = report["bucketed_priority_grid"].get("speedup", 0.0)
        if got < args.assert_timeline_floor:
            print(f"error: bucketed/priority batched speedup {got:.1f}x "
                  f"below the {args.assert_timeline_floor:g}x floor",
                  file=sys.stderr)
            return 1
        print(f"# timeline speedup gate: {got:.1f}x >= "
              f"{args.assert_timeline_floor:g}x")
    if args.assert_jax_floor is not None:
        worst = max((report[g]["agreement_max_rel"] for g in report
                     if isinstance(report[g], dict)
                     and "agreement_max_rel" in report[g]), default=0.0)
        if worst > 1e-6:
            print(f"error: jax/numpy kernel disagreement {worst:.2e} "
                  f"exceeds the 1e-6 gate", file=sys.stderr)
            return 1
        got = report["frontier_grid"]["jax_vs_numpy_kernel_speedup"]
        if got < args.assert_jax_floor:
            print(f"error: frontier-grid jax kernel speedup {got:.2f}x "
                  f"below the {args.assert_jax_floor:g}x floor",
                  file=sys.stderr)
            return 1
        print(f"# jax backend gate: {got:.2f}x >= "
              f"{args.assert_jax_floor:g}x, max rel diff {worst:.1e}")
    if args.assert_e2e_floor is not None:
        fr = report["frontier_grid"]
        for backend, key in (("numpy", "batched"), ("jax", "jax")):
            got = fr[key]["scenarios_per_sec"]
            if got < args.assert_e2e_floor:
                print(f"error: frontier-grid {backend} end-to-end "
                      f"throughput {got:,.0f}/s below the "
                      f"{args.assert_e2e_floor:,.0f}/s floor",
                      file=sys.stderr)
                return 1
        print(f"# e2e throughput gate: numpy "
              f"{fr['batched']['scenarios_per_sec']:,.0f}/s, jax "
              f"{fr['jax']['scenarios_per_sec']:,.0f}/s >= "
              f"{args.assert_e2e_floor:,.0f}/s")
    if args.assert_het_floor is not None:
        hg = report["het_straggler_grid"]
        got = hg["batched"]["scenarios_per_sec"]
        if got < args.assert_het_floor:
            print(f"error: het/straggler-grid batched throughput "
                  f"{got:,.0f}/s below the "
                  f"{args.assert_het_floor:,.0f}/s floor", file=sys.stderr)
            return 1
        if hg["agreement_max_rel"] > 1e-6:
            print(f"error: het-grid jax/numpy disagreement "
                  f"{hg['agreement_max_rel']:.2e} exceeds the 1e-6 gate",
                  file=sys.stderr)
            return 1
        print(f"# het/straggler gate: {got:,.0f}/s >= "
              f"{args.assert_het_floor:,.0f}/s, max rel diff "
              f"{hg['agreement_max_rel']:.1e}")
    if args.assert_faults_floor is not None:
        fg = report["failure_grid"]
        got = fg["batched"]["scenarios_per_sec"]
        if got < args.assert_faults_floor:
            print(f"error: failure-grid batched throughput "
                  f"{got:,.0f}/s below the "
                  f"{args.assert_faults_floor:,.0f}/s floor",
                  file=sys.stderr)
            return 1
        if fg["agreement_max_rel"] > 1e-6:
            print(f"error: failure-grid jax/numpy disagreement "
                  f"{fg['agreement_max_rel']:.2e} exceeds the 1e-6 gate",
                  file=sys.stderr)
            return 1
        print(f"# failure-model gate: {got:,.0f}/s >= "
              f"{args.assert_faults_floor:,.0f}/s, max rel diff "
              f"{fg['agreement_max_rel']:.1e}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
