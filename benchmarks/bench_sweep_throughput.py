"""Sweep-engine throughput: scenarios/second for the scenario-axis
**batched** kernel versus the per-scenario reference paths, on the
540-scenario default grid, the 1620-scenario mixed-provider grid, the
51 840-scenario frontier grid, and a >= 1000-scenario bucketed/priority
grid whose per-scenario reference is the event-driven simulator.

    PYTHONPATH=src python -m benchmarks.bench_sweep_throughput
    PYTHONPATH=src python -m benchmarks.bench_sweep_throughput --smoke

Prints the shared ``name,us_per_call,derived`` CSV rows and writes
``BENCH_sweep.json`` (override with ``--json``) so the perf trajectory
of the engine is tracked run over run: per grid, ``batched`` and
``per_scenario`` timings plus their ``speedup`` ratio (the ISSUE-3
acceptance gate is >= 25x on the default grid; the ISSUE-4 gate is
>= 20x on the bucketed/priority grid, where the slow side actually
builds and list-schedules a DAG per scenario, so ``n_simulated``
finally records a non-zero simulated-path trajectory).  The frontier
grid only times the batched side — its slow side would list-schedule
~26k DAGs, the exact gap the timeline path closes.  ``--smoke`` does
one timed repeat per grid and shrinks the bucketed/priority grid —
the CI regression gate (pair with ``--assert-timeline-floor``).
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.common import row
from repro.core.hardware import COLLECTIVE_ALGORITHMS
from repro.core.scenarios import (ScenarioGrid, default_grid, frontier_grid,
                                  mixed_grid)
from repro.core.sweep import sweep


def bucketed_priority_grid(smoke: bool = False) -> ScenarioGrid:
    """The schedule-dependent-policy grid: every paper CNN on both
    paper clusters under the bucket-size axis + priority scheduling.
    Full mode is 1080 scenarios (the ISSUE-4 acceptance floor is
    >= 1000); smoke mode shrinks the worker/collective/interconnect
    axes so the per-scenario simulator pass stays CI-sized."""
    kw = dict(workloads=("alexnet", "googlenet", "resnet50"),
              clusters=("k80-pcie-10gbe", "v100-nvlink-ib"),
              policies=("bucketed-1mb", "bucketed-4mb", "bucketed-25mb",
                        "bucketed-100mb", "priority"))
    if smoke:
        return ScenarioGrid(worker_counts=(2, 4),
                            collectives=("ring", "tree"), **kw)
    return ScenarioGrid(worker_counts=(2, 4, 8),
                        collectives=COLLECTIVE_ALGORITHMS,
                        interconnects=(None, "10gbe", "ib-200g",
                                       "ib-100g-fused"), **kw)


def _time_sweep(grid, repeats: int, batched: bool) -> dict:
    n = len(grid)
    # Warm the memoized workload tables + prepared grid structure via
    # the batched path regardless of which side is being timed: the
    # per-scenario paths share the same table memo, and replaying the
    # full simulator sweep just to warm it would double the dominant
    # cost of the bucketed/priority slow side.
    sweep(grid, batched=True)
    elapsed = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = sweep(grid, batched=batched)
        elapsed.append(time.perf_counter() - t0)
    elapsed.sort()
    med = elapsed[len(elapsed) // 2]
    return {
        "n_scenarios": n,
        "elapsed_s": med,
        "scenarios_per_sec": n / med,
        "n_analytical": result.n_analytical,
        "n_timeline": result.n_timeline,
        "n_simulated": result.n_simulated,
    }


def run(smoke: bool = False, json_path: str = "BENCH_sweep.json") -> dict:
    repeats = 1 if smoke else 5
    grids = {"default_grid": default_grid(), "mixed_grid": mixed_grid(),
             "frontier_grid": frontier_grid(),
             "bucketed_priority_grid": bucketed_priority_grid(smoke)}
    report: dict = {"smoke": smoke, "repeats": repeats}
    for name, grid in grids.items():
        r: dict = {"n_scenarios": len(grid)}
        r["batched"] = _time_sweep(grid, repeats, batched=True)
        row(f"sweep_{name}_batched", r["batched"]["elapsed_s"] * 1e6,
            f"{r['batched']['scenarios_per_sec']:.0f} scenarios/s "
            f"({len(grid)} scenarios)")
        # The per-scenario reference pass on the frontier grid is
        # skipped outright: half its 51 840 scenarios are
        # schedule-dependent, so the slow side would list-schedule
        # ~26k DAGs (tens of minutes) — the unbenchmarkable gap this
        # engine exists to close.  The bucketed/priority grid below is
        # the dedicated simulated-path trajectory; its slow side is
        # timed once (plenty of precision for a >= 20x gate).
        if name != "frontier_grid":
            slow_repeats = 1 if name == "bucketed_priority_grid" else repeats
            r["per_scenario"] = _time_sweep(grid, slow_repeats, batched=False)
            r["speedup"] = (r["per_scenario"]["elapsed_s"]
                            / r["batched"]["elapsed_s"])
            row(f"sweep_{name}_per_scenario",
                r["per_scenario"]["elapsed_s"] * 1e6,
                f"{r['per_scenario']['scenarios_per_sec']:.0f} scenarios/s "
                f"(batched is {r['speedup']:.1f}x faster)")
        report[name] = r
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {json_path}", flush=True)
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single timed repeat per grid, no frontier "
                         "per-scenario pass, shrunken bucketed/priority "
                         "grid (CI mode)")
    ap.add_argument("--json", default="BENCH_sweep.json", metavar="PATH",
                    help="output JSON path ('' to skip)")
    ap.add_argument("--assert-timeline-floor", type=float, default=None,
                    metavar="X",
                    help="exit non-zero unless the bucketed/priority "
                         "grid's batched-vs-simulator speedup is >= X "
                         "(the CI regression gate for the timeline path)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    report = run(smoke=args.smoke, json_path=args.json)
    if args.assert_timeline_floor is not None:
        got = report["bucketed_priority_grid"].get("speedup", 0.0)
        if got < args.assert_timeline_floor:
            print(f"error: bucketed/priority batched speedup {got:.1f}x "
                  f"below the {args.assert_timeline_floor:g}x floor",
                  file=sys.stderr)
            return 1
        print(f"# timeline speedup gate: {got:.1f}x >= "
              f"{args.assert_timeline_floor:g}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
