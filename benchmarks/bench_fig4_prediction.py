"""Paper Fig. 4 / §V-D: DAG-model prediction accuracy.

The paper predicts Caffe-MPI iteration times from measured layer-wise
traces and reports 9.4% / 4.7% / 4.6% average error on AlexNet /
GoogleNet / ResNet-50.  We validate the same pipeline two ways:

1. bundled-trace path: Table VI (AlexNet, K80) -> DAG -> predicted
   iteration time vs the trace's own serial sum (Eq. 1 ground truth);
2. closed-form path: the DAG simulator vs Eqs. (2)/(3)/(5) across all
   workloads and clusters — the simulator *is* the model, so error
   here measures scheduling slack only.

The real-measurement counterpart (wall-clock CPU multi-device runs vs
DAG prediction) lives in ``examples/dag_validation.py``.
"""
from __future__ import annotations

from benchmarks.common import row, time_call
from repro.core import analytical as A
from repro.core.dag import build_ssgd_dag
from repro.core.hardware import K80_CLUSTER, V100_CLUSTER
from repro.core.policies import CAFFE_MPI, CNTK, NAIVE, Policy
from repro.core.predictor import predict, predict_cnn
from repro.core.simulator import simulate
from repro.traces.bundled import ALEXNET_K80

EQ3 = Policy("eq3", overlap_io=True, h2d_early=True)


def run() -> dict:
    out = {}

    # 1) bundled Table VI trace
    costs = ALEXNET_K80.to_iteration_costs()
    serial = A.eq1_sgd_iteration(costs) + sum(costs.t_c)
    res = {}
    us = time_call(lambda: res.__setitem__(
        "p", predict(costs, 2, CAFFE_MPI, batch_per_gpu=1024)), repeats=2)
    p = res["p"]
    hidden = serial - p.iteration_time
    row("fig4/tableVI-alexnet-k80/wfbp-predicted-iter", us,
        f"iter_s={p.iteration_time:.3f};serial_s={serial:.3f};"
        f"hidden_s={hidden:.3f}")
    out["tableVI_iter"] = p.iteration_time

    # 2) simulator-vs-closed-form across workloads (prediction error)
    for cluster in (K80_CLUSTER, V100_CLUSTER):
        for wl in ("alexnet", "googlenet", "resnet50"):
            for pol, eq in ((NAIVE, A.eq2_naive_ssgd),
                            (EQ3, A.eq3_io_overlap),
                            (CAFFE_MPI, A.eq5_wfbp)):
                pred = predict_cnn(wl, cluster, 16, pol)
                from repro.core.costmodel import (CNN_WORKLOADS,
                                                  make_iteration_costs)
                builder, batch, bps = CNN_WORKLOADS[wl]
                c = make_iteration_costs(builder(), cluster, batch, 16,
                                         bytes_per_sample=bps)
                ana = eq(c)
                err = abs(pred.iteration_time - ana) / ana * 100
                row(f"fig4/{cluster.name}/{wl}/{pol.name}-error", 0.0,
                    f"sim_s={pred.iteration_time:.4f};eq_s={ana:.4f};"
                    f"err_pct={err:.2f}")
                out[(cluster.name, wl, pol.name)] = err
    return out


if __name__ == "__main__":
    run()
