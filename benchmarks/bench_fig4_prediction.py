"""Paper Fig. 4 / §V-D: DAG-model prediction accuracy.

The paper predicts Caffe-MPI iteration times from measured layer-wise
traces and reports 9.4% / 4.7% / 4.6% average error on AlexNet /
GoogleNet / ResNet-50.  We validate the same pipeline three ways:

1. bundled-trace path: Table VI (AlexNet, K80) -> DAG -> predicted
   iteration time vs the trace's own serial sum (Eq. 1 ground truth);
2. sweep-engine agreement: the analytical fast path of
   :mod:`repro.core.sweep` vs the event-driven simulator across all
   workloads, clusters and exactly-solvable policies — the closed
   forms *are* the model, so error here measures scheduling slack;
3. sweep throughput: wall time to evaluate the 540-scenario default
   grid (the ISSUE-1 acceptance gate is >= 500 scenarios in < 30 s).

The real-measurement counterpart (wall-clock CPU multi-device runs vs
DAG prediction) lives in ``examples/dag_validation.py``.
"""
from __future__ import annotations

from benchmarks.common import row, time_call
from repro.core import analytical as A
from repro.core.policies import CAFFE_MPI
from repro.core.predictor import predict
from repro.core.scenarios import ScenarioGrid, default_grid
from repro.core.sweep import evaluate_scenario, sweep
from repro.traces.bundled import ALEXNET_K80


def run() -> dict:
    out = {}

    # 1) bundled Table VI trace
    costs = ALEXNET_K80.to_iteration_costs()
    serial = A.eq1_sgd_iteration(costs) + sum(costs.t_c)
    res = {}
    us = time_call(lambda: res.__setitem__(
        "p", predict(costs, 2, CAFFE_MPI, batch_per_gpu=1024)), repeats=2)
    p = res["p"]
    hidden = serial - p.iteration_time
    row("fig4/tableVI-alexnet-k80/wfbp-predicted-iter", us,
        f"iter_s={p.iteration_time:.3f};serial_s={serial:.3f};"
        f"hidden_s={hidden:.3f}")
    out["tableVI_iter"] = p.iteration_time

    # 2) analytical fast path vs event-driven simulator, via the sweep
    # engine (prediction error of the closed forms)
    grid = ScenarioGrid(worker_counts=(16,),
                        policies=("naive", "cntk", "mxnet", "caffe-mpi"))
    for s in grid.expand():
        fast = evaluate_scenario(s, method="analytical")
        slow = evaluate_scenario(s, method="simulator")
        ana, sim = fast["iteration_time_s"], slow["iteration_time_s"]
        err = abs(sim - ana) / ana * 100
        row(f"fig4/{s.cluster}/{s.workload}/{s.policy}-error", 0.0,
            f"sim_s={sim:.4f};eq_s={ana:.4f};err_pct={err:.2f}")
        out[(s.cluster, s.workload, s.policy)] = err

    # 3) sweep-engine throughput on the 540-scenario default grid
    result = {}
    us = time_call(lambda: result.__setitem__("r", sweep(default_grid())),
                   repeats=3)
    r = result["r"]
    row("fig4/sweep-default-grid", us,
        f"scenarios={len(r)};scenarios_per_s={len(r) / (us * 1e-6):.0f};"
        f"analytical={r.n_analytical};simulated={r.n_simulated}")
    out["sweep_us"] = us
    return out


if __name__ == "__main__":
    run()
