"""Paper Table VI / §VI: the layer-wise trace dataset.

Round-trips the bundled AlexNet/K80 iteration through the trace format,
derives the aggregate quantities the paper reports (total gradient
bytes ~= 244 MB = 61M f32 params; forward/backward/comm totals), and
generates a fresh trace from a real instrumented CPU model in the same
format.
"""
from __future__ import annotations

import tempfile
from pathlib import Path

import jax

from benchmarks.common import row, time_call
from repro.core.hardware import K80_CLUSTER
from repro.models.cnn import alexnet_timed_layers
from repro.traces.bundled import ALEXNET_K80, TOTAL_GRAD_BYTES
from repro.traces.format import read_trace, write_trace
from repro.traces.generate import generate_trace


def run() -> dict:
    out = {}
    costs = ALEXNET_K80.to_iteration_costs()
    us = time_call(lambda: ALEXNET_K80.to_iteration_costs(), repeats=3)
    row("table6/bundled/totals", us,
        f"grad_MB={TOTAL_GRAD_BYTES / 1e6:.1f};t_io_s={costs.t_io:.2f};"
        f"fwd_s={sum(costs.t_f):.2f};bwd_s={sum(costs.t_b):.2f};"
        f"comm_s={sum(costs.t_c):.2f}")
    out["grad_bytes"] = TOTAL_GRAD_BYTES

    with tempfile.TemporaryDirectory() as d:
        p = Path(d) / "alexnet_k80.trace"
        us = time_call(lambda: write_trace(ALEXNET_K80, p), repeats=3)
        t2 = read_trace(p)
        ok = t2.iterations[0] == ALEXNET_K80.iterations[0]
        row("table6/roundtrip", us, f"identical={ok}")
        out["roundtrip_ok"] = ok

    # fresh trace from an instrumented real model (reduced AlexNet)
    layers, x0 = alexnet_timed_layers(jax.random.PRNGKey(0), input_hw=64)
    import jax.numpy as jnp
    x0 = jnp.broadcast_to(x0, (2,) + x0.shape[1:])
    res = {}
    us = time_call(lambda: res.__setitem__("t", generate_trace(
        layers, x0, "alexnet-mini", n_iterations=1, repeats=1,
        comm_time_fn=lambda b: K80_CLUSTER.allreduce_time(b, 16))), repeats=1)
    tr = res["t"]
    mean = tr.mean_iteration()
    row("table6/generated-alexnet-mini", us,
        f"layers={len(mean)};"
        f"fwd_us={sum(r.forward_us for r in mean):.0f};"
        f"grad_MB={sum(r.size_bytes for r in mean) / 1e6:.1f}")
    out["generated_layers"] = len(mean)
    return out


if __name__ == "__main__":
    run()
