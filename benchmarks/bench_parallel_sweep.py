"""Sharded sweep execution: serial vs ``jobs=N`` process-pool
throughput on the frontier grid, plus the exactness check that makes
sharding safe to enable by default.

    PYTHONPATH=src python -m benchmarks.bench_parallel_sweep
    PYTHONPATH=src python -m benchmarks.bench_parallel_sweep --smoke

Prints the shared ``name,us_per_call,derived`` CSV rows and writes
``BENCH_parallel.json``: per job count, steady-state ``sweep(jobs=N)``
throughput (pool spawn and per-worker evaluator build are paid in the
warm-up run) and the scaling ratio against serial.  Every parallel run
is also compared against the serial result **bit for bit** — the
chunk-sharded kernel is pure elementwise arithmetic per scenario
point, so span boundaries cannot change any value, and this benchmark
fails loudly if that ever stops being true.

On a single-core runner (the CI box) the recorded "scaling" is the
pool's overhead floor, not a speedup — which is exactly why the
numbers are recorded per machine in the JSON rather than gated.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

from benchmarks.common import row
from repro.core.resulttable import COLUMNS
from repro.core.scenarios import default_grid, frontier_grid
from repro.core.sweep import sweep


def _tables_equal(a: dict, b: dict) -> bool:
    return all(np.array_equal(a[k], b[k]) for k in COLUMNS)


def _time_jobs(grid, jobs: int | None, repeats: int) -> dict:
    n = len(grid)
    sweep(grid, jobs=jobs)                 # warm pool + worker evaluators
    elapsed = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        result = sweep(grid, jobs=jobs)
        elapsed.append(time.perf_counter() - t0)
    elapsed.sort()
    med = elapsed[len(elapsed) // 2]
    return {"n_scenarios": n, "elapsed_s": med,
            "scenarios_per_sec": n / med, "columns": result.columns}


def run(smoke: bool = False, json_path: str = "BENCH_parallel.json") -> dict:
    repeats = 1 if smoke else 5
    grid = default_grid() if smoke else frontier_grid()
    cores = os.cpu_count() or 1
    job_counts = sorted({2, cores} - {1})
    report: dict = {"smoke": smoke, "repeats": repeats, "cores": cores,
                    "n_scenarios": len(grid)}
    serial = _time_jobs(grid, None, repeats)
    serial_columns = serial.pop("columns")
    report["serial"] = serial
    row("parallel_sweep_serial", serial["elapsed_s"] * 1e6,
        f"{serial['scenarios_per_sec']:.0f} scenarios/s "
        f"({len(grid)} scenarios)")
    for jobs in job_counts:
        r = _time_jobs(grid, jobs, repeats)
        if not _tables_equal(serial_columns, r.pop("columns")):
            raise AssertionError(
                f"jobs={jobs} result differs from serial — sharding "
                f"changed the output")
        r["scaling_vs_serial"] = serial["elapsed_s"] / r["elapsed_s"]
        r["exact_match"] = True
        report[f"jobs{jobs}"] = r
        row(f"parallel_sweep_jobs{jobs}", r["elapsed_s"] * 1e6,
            f"{r['scenarios_per_sec']:.0f} scenarios/s "
            f"({r['scaling_vs_serial']:.2f}x serial, bit-identical)")
    report["cold_start"] = _cold_start(grid)
    cs = report["cold_start"]
    row("parallel_sweep_cold_first", cs["cold_first_sweep_s"] * 1e6,
        f"first sweep(jobs=2) on a cold pool")
    row("parallel_sweep_warmed_first", cs["warmed_first_sweep_s"] * 1e6,
        f"after warm_pool ({cs['first_sweep_speedup']:.2f}x cold; "
        f"warm_pool itself {cs['warm_pool_s'] * 1e3:.0f} ms)")
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {json_path}", flush=True)
    return report


def _cold_start(grid) -> dict:
    """Cold-span overhead: the first ``sweep(jobs=2)`` pays pool spawn,
    worker interpreter start and (pre-initializer) lazy imports +
    workload-table builds inside every worker.  The worker initializer
    now pre-imports the kernel modules and pre-resolves the built-in
    tables, and :func:`repro.core.parallel.warm_pool` forces all
    workers through it up front — so a warmed pool's first sweep is
    pure span execution."""
    from repro.core import parallel

    parallel._shutdown_pools()
    t0 = time.perf_counter()
    sweep(grid, jobs=2)
    cold = time.perf_counter() - t0

    parallel._shutdown_pools()
    t0 = time.perf_counter()
    parallel.warm_pool("process", jobs=2)
    warm_cost = time.perf_counter() - t0
    t0 = time.perf_counter()
    sweep(grid, jobs=2)
    warmed = time.perf_counter() - t0
    return {"cold_first_sweep_s": cold,
            "warm_pool_s": warm_cost,
            "warmed_first_sweep_s": warmed,
            "first_sweep_speedup": cold / warmed if warmed else 0.0}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="single repeat on the 540-scenario default "
                         "grid (CI mode)")
    ap.add_argument("--json", default="BENCH_parallel.json", metavar="PATH",
                    help="output JSON path ('' to skip)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(smoke=args.smoke, json_path=args.json)
    return 0


if __name__ == "__main__":
    sys.exit(main())
