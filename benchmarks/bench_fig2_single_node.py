"""Paper Fig. 2: single-node scaling (1/2/4 GPUs) of the four
framework policies on AlexNet / GoogleNet / ResNet-50, for both the
K80+PCIe and V100+NVLink servers — predicted by the DAG simulator.

Derived column: samples/s and weak-scaling speedup vs 1 GPU.
"""
from __future__ import annotations

from benchmarks.common import row, time_call
from repro.core.hardware import K80_CLUSTER, V100_CLUSTER
from repro.core.policies import FRAMEWORK_POLICIES
from repro.core.predictor import predict_cnn

WORKLOADS = ("alexnet", "googlenet", "resnet50")
GPUS = (1, 2, 4)


def run() -> dict:
    out = {}
    for cluster in (K80_CLUSTER, V100_CLUSTER):
        # single node: restrict to intra-node communication
        node = cluster.with_workers(n_nodes=1)
        for wl in WORKLOADS:
            for fw, pol in FRAMEWORK_POLICIES.items():
                sps = {}
                for n in GPUS:
                    us = time_call(lambda: sps.__setitem__(
                        n, predict_cnn(wl, node, n, pol)), repeats=1)
                    p = sps[n]
                    row(f"fig2/{cluster.name}/{wl}/{fw}/x{n}",
                        us, f"samples_s={p.samples_per_sec:.1f};"
                            f"speedup={p.speedup:.2f}")
                out[(cluster.name, wl, fw)] = {
                    n: sps[n].samples_per_sec for n in GPUS}
    return out


if __name__ == "__main__":
    run()
