"""Paper Fig. 3: multi-machine scaling (4/8/16 GPUs over 1/2/4 nodes,
4 GPUs each) on the 10GbE K80 cluster and the 100Gb-IB V100 cluster.

Reproduces the paper's headline finding: near-linear scaling on the
slow cluster, communication-bound collapse on the fast one.
"""
from __future__ import annotations

from benchmarks.common import row, time_call
from repro.core.hardware import K80_CLUSTER, V100_CLUSTER
from repro.core.policies import BUCKETED_25MB, FRAMEWORK_POLICIES
from repro.core.predictor import predict_cnn

WORKLOADS = ("alexnet", "googlenet", "resnet50")
NODES = (1, 2, 4)


def run() -> dict:
    out = {}
    policies = dict(FRAMEWORK_POLICIES)
    policies["bucketed-25mb(beyond-paper)"] = BUCKETED_25MB
    for cluster in (K80_CLUSTER, V100_CLUSTER):
        for wl in WORKLOADS:
            for fw, pol in policies.items():
                base = None
                for nodes in NODES:
                    n_gpus = nodes * 4
                    c = cluster.with_workers(n_nodes=nodes)
                    res = {}
                    us = time_call(lambda: res.__setitem__(
                        "p", predict_cnn(wl, c, n_gpus, pol)), repeats=1)
                    p = res["p"]
                    if base is None:
                        base = p.samples_per_sec
                    row(f"fig3/{cluster.name}/{wl}/{fw}/x{n_gpus}",
                        us,
                        f"samples_s={p.samples_per_sec:.1f};"
                        f"speedup_vs_4gpu={p.samples_per_sec / base:.2f};"
                        f"comm_util={p.comm_utilization:.2f}")
                    out[(cluster.name, wl, fw, n_gpus)] = p
    return out


if __name__ == "__main__":
    run()
