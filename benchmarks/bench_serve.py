"""Sweep service throughput: warm-cache queries vs cold one-shot CLI,
single vs multi-client qps, and coalesced vs uncoalesced serving.

    PYTHONPATH=src python -m benchmarks.bench_serve
    PYTHONPATH=src python -m benchmarks.bench_serve --smoke \
        --assert-serve-floor 5

Prints the shared ``name,us_per_call,derived`` CSV rows and writes
``BENCH_serve.json``:

* ``cold_vs_warm`` — first-query latency on a cold server (workload
  tables + grid-structure memos built on demand) vs the warm median
  for the same query: the value of process-lifetime caches.
* ``clients1`` / ``clients8`` — sequential and 8-thread closed-loop
  qps with p50/p95 latency over the same warm query.
* ``coalescing`` — the 8-client load against a micro-batching server
  (4 ms window) vs a ``window=0`` server, with each server's measured
  coalesce factor.
* ``warm_vs_cli`` — the acceptance gate: median warm query latency vs
  a cold one-shot ``python -m repro.launch.sweep`` subprocess running
  the same frontier slice.  ``--assert-serve-floor R`` fails the run
  unless the server is at least ``R``x faster; CI pins ``R = 5``.

All measurements run the server in-process on a loopback port; the CLI
comparison spawns a real subprocess so it pays genuine import +
table-build + kernel-warm-up cost, exactly like a user running the CLI
once.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile
import threading
import time
import urllib.request

import numpy as np

from benchmarks.common import row

#: The repeated what-if query of the benchmark: one frontier slice
#: (2880 scenarios — every policy/collective/interconnect/failure
#: combination for resnet50 at 8 workers).
QUERY = {"grid": "frontier", "workloads": ["resnet50"], "workers": [8]}


def _post(port: int, doc: dict) -> tuple[list[dict], float]:
    """One /query round trip: parsed NDJSON lines + wall latency."""
    req = urllib.request.Request(f"http://127.0.0.1:{port}/query",
                                 data=json.dumps(doc).encode(),
                                 method="POST")
    t0 = time.perf_counter()
    with urllib.request.urlopen(req) as resp:
        lines = [json.loads(line) for line in resp]
    return lines, time.perf_counter() - t0


def _stats(port: int) -> dict:
    with urllib.request.urlopen(f"http://127.0.0.1:{port}/stats") as r:
        return json.loads(r.read())


def _start_server(window_s: float):
    from repro.launch.serve_sweep import make_server

    srv = make_server(port=0, window_s=window_s)
    threading.Thread(target=srv.serve_forever, daemon=True).start()
    return srv, srv.server_address[1]


def _stop_server(srv) -> None:
    srv.shutdown()
    srv.server_close()
    srv.service.close()


def _pcts(latencies: list[float]) -> dict:
    a = np.sort(np.asarray(latencies))
    return {"p50_ms": float(np.quantile(a, 0.50)) * 1e3,
            "p95_ms": float(np.quantile(a, 0.95)) * 1e3}


def _closed_loop(port: int, clients: int, per_client: int) -> dict:
    """``clients`` threads, each issuing ``per_client`` back-to-back
    queries; aggregate qps over the wall window + latency percentiles."""
    lats: list[list[float]] = [[] for _ in range(clients)]

    def drive(i: int) -> None:
        for _ in range(per_client):
            _, dt = _post(port, QUERY)
            lats[i].append(dt)

    threads = [threading.Thread(target=drive, args=(i,))
               for i in range(clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    wall = time.perf_counter() - t0
    flat = [dt for ls in lats for dt in ls]
    return {"clients": clients, "queries": len(flat), "wall_s": wall,
            "qps": len(flat) / wall, **_pcts(flat)}


def _time_cli_once() -> float:
    """One cold ``python -m repro.launch.sweep`` subprocess running the
    benchmark query (imports + tables + kernel warm-up + sweep)."""
    with tempfile.NamedTemporaryFile(suffix=".json") as tmp:
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__))), "src")
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        cmd = [sys.executable, "-m", "repro.launch.sweep",
               "--grid", "frontier", "--workloads", "resnet50",
               "--workers", "8", "--json", tmp.name]
        t0 = time.perf_counter()
        subprocess.run(cmd, check=True, env=env,
                       stdout=subprocess.DEVNULL)
        return time.perf_counter() - t0


def run(smoke: bool = False, json_path: str = "BENCH_serve.json",
        assert_floor: float = 0.0) -> dict:
    warm_reps = 10 if smoke else 50
    per_client = 5 if smoke else 25
    report: dict = {"smoke": smoke, "query": QUERY}

    # -- cold vs warm first query (this server is the process's first:
    # nothing has resolved a workload or built an evaluator yet) ------
    srv, port = _start_server(window_s=0.004)
    lines, cold_s = _post(port, QUERY)
    probe = lines[-1]["qos"]["cache"]
    warm_lat = [_post(port, QUERY)[1] for _ in range(warm_reps)]
    warm_s = float(np.median(warm_lat))
    report["cold_vs_warm"] = {
        "cold_first_query_s": cold_s, "warm_median_s": warm_s,
        "speedup": cold_s / warm_s if warm_s else 0.0,
        "cold_cache_probe": probe,
        "n_scenarios": lines[0]["n_scenarios"]}
    row("serve_cold_first_query", cold_s * 1e6,
        f"{lines[0]['n_scenarios']} scenarios, caches cold")
    row("serve_warm_query", warm_s * 1e6,
        f"warm median ({report['cold_vs_warm']['speedup']:.1f}x cold)")

    # -- closed-loop qps ----------------------------------------------
    report["clients1"] = _closed_loop(port, 1, per_client * 8)
    report["clients8"] = _closed_loop(port, 8, per_client)
    for key in ("clients1", "clients8"):
        c = report[key]
        row(f"serve_{key}", 1e6 / c["qps"],
            f"{c['qps']:.1f} qps, p50 {c['p50_ms']:.1f} ms, "
            f"p95 {c['p95_ms']:.1f} ms")
    coalesced = _closed_loop(port, 8, per_client)
    coalesced["coalesce_factor"] = _stats(port)["coalesce_factor"]
    _stop_server(srv)

    # -- coalesced vs uncoalesced -------------------------------------
    srv0, port0 = _start_server(window_s=0.0)
    _post(port0, QUERY)                              # warm it
    uncoalesced = _closed_loop(port0, 8, per_client)
    uncoalesced["coalesce_factor"] = _stats(port0)["coalesce_factor"]
    _stop_server(srv0)
    report["coalescing"] = {
        "coalesced": coalesced, "uncoalesced": uncoalesced,
        "qps_ratio": coalesced["qps"] / uncoalesced["qps"]}
    row("serve_coalesced_8c", 1e6 / coalesced["qps"],
        f"{coalesced['qps']:.1f} qps at coalesce factor "
        f"{coalesced['coalesce_factor']:.2f}")
    row("serve_uncoalesced_8c", 1e6 / uncoalesced["qps"],
        f"{uncoalesced['qps']:.1f} qps at window 0")

    # -- warm server vs cold one-shot CLI (the acceptance gate) -------
    cli_s = _time_cli_once()
    speedup = cli_s / warm_s if warm_s else 0.0
    report["warm_vs_cli"] = {"cli_one_shot_s": cli_s,
                             "warm_query_s": warm_s,
                             "speedup": speedup,
                             "floor": assert_floor}
    row("serve_vs_cli_one_shot", cli_s * 1e6,
        f"cold CLI; warm server query is {speedup:.1f}x faster")

    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {json_path}", flush=True)
    if assert_floor and speedup < assert_floor:
        raise AssertionError(
            f"warm query speedup {speedup:.2f}x is below the "
            f"--assert-serve-floor {assert_floor}x")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced repeat counts (CI mode)")
    ap.add_argument("--json", default="BENCH_serve.json", metavar="PATH",
                    help="output JSON path ('' to skip)")
    ap.add_argument("--assert-serve-floor", type=float, default=0.0,
                    metavar="R",
                    help="fail unless warm queries beat the one-shot "
                         "CLI by at least Rx")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    run(smoke=args.smoke, json_path=args.json,
        assert_floor=args.assert_serve_floor)
    return 0


if __name__ == "__main__":
    sys.exit(main())
