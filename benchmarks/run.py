"""Benchmark driver: one module per paper table/figure plus the
roofline and kernel microbenchmarks.  Prints ``name,us_per_call,
derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig3,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

SUITES = ("fig2", "fig3", "fig4", "table6", "kernels", "roofline", "sweep",
          "parallel", "serve", "calibration")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--only", help="comma-separated subset of "
                                   + ",".join(SUITES))
    args = ap.parse_args(argv)
    wanted = set((args.only or ",".join(SUITES)).split(","))

    print("name,us_per_call,derived")
    failures = 0
    for name in SUITES:
        if name not in wanted:
            continue
        try:
            if name == "fig2":
                from benchmarks.bench_fig2_single_node import run
            elif name == "fig3":
                from benchmarks.bench_fig3_multi_node import run
            elif name == "fig4":
                from benchmarks.bench_fig4_prediction import run
            elif name == "table6":
                from benchmarks.bench_table6_trace import run
            elif name == "kernels":
                from benchmarks.bench_kernels import run
            elif name == "roofline":
                from benchmarks.bench_roofline import run
            elif name == "sweep":
                from benchmarks.bench_sweep_throughput import run
            elif name == "parallel":
                from benchmarks.bench_parallel_sweep import run
            elif name == "serve":
                from benchmarks.bench_serve import run
            elif name == "calibration":
                from benchmarks.bench_model_vs_measured import run
            run()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},0,FAILED", flush=True)
            traceback.print_exc()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
