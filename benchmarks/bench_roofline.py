"""Roofline table (deliverable g): three terms per (arch x shape x
mesh) from the dry-run artifacts in results/dryrun/.

  compute    = analytic_FLOPs / (chips x 197 TFLOP/s)
  memory     = analytic_HBM_bytes / (chips x 819 GB/s)
  collective = HLO_collective_bytes / (chips x 50 GB/s ICI)

collective bytes come from the optimized HLO (while-loop trip counts
parsed and applied); FLOPs/HBM use the analytic per-arch model since
XLA's cost_analysis visits scan bodies once (recorded alongside).
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import row
from repro.core.hardware import (V5E_HBM_BW, V5E_ICI_BW_PER_LINK,
                                 V5E_PEAK_FLOPS_BF16)

_ROOT = Path(__file__).resolve().parents[1] / "results"
RESULTS = _ROOT / "dryrun"
# labelled sweeps: paper-faithful baseline sharding vs the §Perf-
# optimized per-shape modes (EXPERIMENTS.md)
SWEEPS = (("baseline", _ROOT / "dryrun_baseline"),
          ("optimized", _ROOT / "dryrun_opt"))


def roofline_terms(rec: dict) -> dict:
    chips = rec["n_devices"]
    ana = rec["analytic"]
    coll = rec.get("collectives", {})
    compute_s = ana["flops"] / (chips * V5E_PEAK_FLOPS_BF16)
    memory_s = ana["hbm_bytes"] / (chips * V5E_HBM_BW)
    # collective bytes in the HLO are already per-device module bytes
    collective_s = coll.get("total_bytes", 0.0) / V5E_ICI_BW_PER_LINK
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=lambda k: terms[k])
    hlo_flops = (rec.get("cost_analysis") or {}).get("flops") or 0.0
    return {
        **terms,
        "dominant": dominant.replace("_s", ""),
        "bound_s": max(terms.values()),
        "model_flops": ana["model_flops"],
        "useful_flops_ratio": (ana["model_flops"] / ana["flops"]
                               if ana["flops"] else 0.0),
        "mfu_at_bound": (ana["model_flops"]
                         / (chips * V5E_PEAK_FLOPS_BF16)
                         / max(max(terms.values()), 1e-12)),
        "hlo_flops_per_device_loopbody_once": hlo_flops,
        "temp_bytes_per_device": (rec.get("memory") or {}).get("temp_bytes"),
    }


def load_records(results_dir: Path) -> list[dict]:
    recs = []
    for p in sorted(results_dir.glob("*.json")):
        rec = json.loads(p.read_text())
        if rec.get("status") == "ok":
            recs.append(rec)
    return recs


def run() -> list[dict]:
    out = []
    sweeps = [s for s in SWEEPS if s[1].is_dir()] or [("dryrun", RESULTS)]
    for label, results_dir in sweeps:
        for rec in load_records(results_dir):
            t = roofline_terms(rec)
            name = (f"roofline-{label}/{rec['arch']}/{rec['shape']}"
                    f"/{rec['mesh']}")
            row(name, rec.get("compile_s", 0.0) * 1e6,
                f"compute_ms={t['compute_s'] * 1e3:.3f};"
                f"memory_ms={t['memory_s'] * 1e3:.3f};"
                f"collective_ms={t['collective_s'] * 1e3:.3f};"
                f"dominant={t['dominant']};"
                f"mfu_bound={t['mfu_at_bound']:.3f};"
                f"useful_ratio={t['useful_flops_ratio']:.2f}")
            out.append({**rec, "sweep": label, "roofline": t})
    if not out:
        row("roofline/no-dryrun-artifacts", 0.0,
            "run `python -m repro.launch.dryrun --all` first")
    return out


if __name__ == "__main__":
    run()
