"""Checkpointing: flat-key npz save/restore for parameter/optimizer
pytrees, with step metadata."""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any

import jax
import numpy as np

SEP = "/"


def _flatten(tree: Any) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                       for k in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(path: str | Path, params: Any, opt_state: Any = None,
                    step: int = 0, extra: dict | None = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    arrays = {f"params{SEP}{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        arrays.update({f"opt{SEP}{k}": v
                       for k, v in _flatten(opt_state).items()})
    np.savez(path, __meta__=json.dumps({"step": step, **(extra or {})}),
             **arrays)


def restore_checkpoint(path: str | Path, params_like: Any,
                       opt_state_like: Any = None):
    """Restore into the structure of ``params_like`` (shape/dtype-true
    templates, e.g. freshly initialized params)."""
    with np.load(path, allow_pickle=False) as z:
        meta = json.loads(str(z["__meta__"]))

        def fill(template: Any, prefix: str) -> Any:
            leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
            out = []
            for path_, leaf in leaves:
                key = SEP.join(str(getattr(k, "key", getattr(k, "idx", k)))
                               for k in path_)
                arr = z[f"{prefix}{SEP}{key}"]
                if arr.shape != leaf.shape:
                    raise ValueError(f"shape mismatch for {key}: "
                                     f"{arr.shape} vs {leaf.shape}")
                out.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
            return jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(template), out)

        params = fill(params_like, "params")
        opt_state = (fill(opt_state_like, "opt")
                     if opt_state_like is not None else None)
    return params, opt_state, meta
