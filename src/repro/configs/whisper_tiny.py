"""whisper-tiny [audio]: enc-dec, conv frontend stubbed to frame
embeddings.  4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
[arXiv:2212.04356]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    arch_type="audio",
    num_layers=4,                 # decoder layers
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    qkv_bias=True,
    mlp_gated=False,              # GELU MLP
    norm="layernorm",
    layer_pattern="C",            # every decoder layer cross-attends
    encoder_layers=4,
    encoder_seq=1500,             # 30 s of audio at 50 frames/s
    source="arXiv:2212.04356",
).validate()
