"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=151936, 60 routed experts top-4 + 4 shared experts.
[hf:Qwen/Qwen1.5-MoE-A2.7B]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    arch_type="moe",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    layer_pattern="G",
    num_experts=60,
    experts_per_token=4,
    moe_d_ff=1408,
    shared_expert_d_ff=4 * 1408,    # 4 shared experts, fused
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
).validate()
