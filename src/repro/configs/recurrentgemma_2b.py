"""recurrentgemma-2b [hybrid] Griffin: 26L d_model=2560 10H (kv=1)
d_ff=7680, RG-LRU + local attention in a 2:1 pattern.
[arXiv:2402.19427]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    arch_type="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256000,
    layer_pattern="RRL",            # 2 recurrent : 1 local-attention
    sliding_window=2048,
    rnn_width=2560,
    conv1d_width=4,
    tie_embeddings=True,
    source="arXiv:2402.19427",
).validate()
