"""llama-3.2-vision-90b [vlm]: 100L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256; cross-attention image layers every 5th layer.
The ViT vision encoder + projector are STUBBED: ``input_specs``
provides projected patch embeddings (B, n_img_tokens, d_model).
[hf:meta-llama/Llama-3.2-11B-Vision]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    arch_type="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    layer_pattern="GGGGC",          # every 5th layer cross-attends (20 of 100)
    num_image_tokens=1601,          # 1 tile of 560x560 at patch 14 + cls
    source="hf:meta-llama/Llama-3.2-11B-Vision",
).validate()
