"""gemma3-1b [dense]: 26L d_model=1152 4H (GQA kv=1) d_ff=6912
vocab=262144, 5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    arch_type="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262144,
    layer_pattern="LLLLLG",        # 5 local : 1 global
    sliding_window=512,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    source="hf:google/gemma-3-1b-pt",
).validate()
