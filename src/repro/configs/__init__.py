"""Architecture config registry: ``--arch <id>`` resolution."""
from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, InputShape  # noqa: F401
from repro.models.common import ModelConfig

_MODULES = {
    "whisper-tiny": "repro.configs.whisper_tiny",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a27b",
    "qwen1.5-4b": "repro.configs.qwen15_4b",
    "gemma3-1b": "repro.configs.gemma3_1b",
    "grok-1-314b": "repro.configs.grok1_314b",
    "rwkv6-1.6b": "repro.configs.rwkv6_16b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "llama-3.2-vision-90b": "repro.configs.llama32_vision_90b",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; one of {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


# Archs that legitimately run the 524k-decode shape (sub-quadratic or
# windowed); everything else skips long_500k (see DESIGN.md §5).
LONG_CONTEXT_ARCHS = ("gemma3-1b", "rwkv6-1.6b", "recurrentgemma-2b")


def shape_applies(arch: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def dryrun_matrix() -> list[tuple[str, str]]:
    """All (arch, shape) pairs exercised by the multi-pod dry-run."""
    return [(a, s) for a in ARCH_IDS for s in SHAPES
            if shape_applies(a, s)]
