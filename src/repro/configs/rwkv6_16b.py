"""rwkv6-1.6b [ssm] "Finch": 24L d_model=2048 (attention-free)
d_ff=7168 vocab=65536, data-dependent decay.  [arXiv:2404.05892]"""
from repro.models.common import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-1.6b",
    arch_type="ssm",
    num_layers=24,
    d_model=2048,
    num_heads=32,                  # 2048 / 64-dim wkv heads
    d_ff=7168,
    vocab_size=65536,
    layer_pattern="W",
    norm="layernorm",
    source="arXiv:2404.05892",
).validate()
