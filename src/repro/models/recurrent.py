"""Recurrent blocks: RWKV6 (Finch) time/channel mix and the
RecurrentGemma RG-LRU block.  Sequence scans run through
:mod:`repro.kernels.ops` (Pallas on TPU, jnp reference elsewhere).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops as kops
from repro.models.common import ModelConfig, Params, dense_init, split_keys


# ----------------------------------------------------------------------
# RWKV6 — time mix (wkv with data-dependent decay) + channel mix.
# Heads of size 64, as in the released models.
# ----------------------------------------------------------------------
RWKV_HEAD_DIM = 64


def rwkv_heads(cfg: ModelConfig) -> int:
    assert cfg.d_model % RWKV_HEAD_DIM == 0
    return cfg.d_model // RWKV_HEAD_DIM


def init_rwkv_time_mix(cfg: ModelConfig, key) -> Params:
    d = cfg.d_model
    H = rwkv_heads(cfg)
    ks = split_keys(key, 8)
    return {
        # token-shift interpolation weights (one per projection)
        "mu": jnp.full((5, d), 0.5, cfg.dtype),        # r,k,v,w,g
        "wr": dense_init(ks[0], (d, d), cfg.dtype),
        "wk": dense_init(ks[1], (d, d), cfg.dtype),
        "wv": dense_init(ks[2], (d, d), cfg.dtype),
        "ww": dense_init(ks[3], (d, d), cfg.dtype),    # data-dependent decay
        "wg": dense_init(ks[4], (d, d), cfg.dtype),
        "w_bias": jnp.full((d,), -6.0, jnp.float32),   # decay bias (slow)
        "u": dense_init(ks[5], (H, RWKV_HEAD_DIM), jnp.float32),  # bonus
        "wo": dense_init(ks[6], (d, d), cfg.dtype),
        "ln_scale": jnp.ones((d,), jnp.float32),       # group-norm on heads
    }


def rwkv_time_mix(cfg: ModelConfig, p: Params, x: jax.Array,
                  state: Params | None = None,
                  prev_x: jax.Array | None = None,
                  ) -> tuple[jax.Array, Params]:
    """x: (B,S,d).  ``state`` = {"S": (B,H,hd,hd), "x_prev": (B,d)} for
    chunked/decode operation; None = fresh sequence."""
    B, S, d = x.shape
    H = rwkv_heads(cfg)
    hd = RWKV_HEAD_DIM
    xp = state["x_prev"][:, None, :] if state is not None else \
        jnp.zeros((B, 1, d), x.dtype)
    x_shift = jnp.concatenate([xp, x[:, :-1]], axis=1)    # token shift

    def lerp(i):
        return x + (x_shift - x) * p["mu"][i]

    r = jnp.einsum("bsd,de->bse", lerp(0), p["wr"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", lerp(1), p["wk"]).reshape(B, S, H, hd)
    v = jnp.einsum("bsd,de->bse", lerp(2), p["wv"]).reshape(B, S, H, hd)
    w_raw = jnp.einsum("bsd,de->bse", lerp(3), p["ww"]).astype(jnp.float32)
    g = jnp.einsum("bsd,de->bse", lerp(4), p["wg"])
    # decay in (0,1), data-dependent (the Finch contribution)
    w = jnp.exp(-jnp.exp(w_raw + p["w_bias"])).reshape(B, S, H, hd)

    S0 = state["S"] if state is not None else None
    out, S_new = kops.wkv6(r, k, v, w.astype(r.dtype), p["u"], state=S0)
    out = out.reshape(B, S, d)
    # simple per-head group norm
    of = out.astype(jnp.float32).reshape(B, S, H, hd)
    of = of * jax.lax.rsqrt(jnp.mean(of * of, axis=-1, keepdims=True) + 1e-6)
    out = (of.reshape(B, S, d) * p["ln_scale"]).astype(x.dtype)
    out = out * jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", out, p["wo"])
    new_state = {"S": S_new, "x_prev": x[:, -1, :]}
    return out, new_state


def init_rwkv_channel_mix(cfg: ModelConfig, key) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    return {
        "mu": jnp.full((2, d), 0.5, cfg.dtype),
        "wk": dense_init(ks[0], (d, ff), cfg.dtype),
        "wv": dense_init(ks[1], (ff, d), cfg.dtype, in_axis_size=ff),
        "wr": dense_init(ks[2], (d, d), cfg.dtype),
    }


def rwkv_channel_mix(cfg: ModelConfig, p: Params, x: jax.Array,
                     x_prev: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    B, S, d = x.shape
    xp = x_prev[:, None, :] if x_prev is not None else jnp.zeros((B, 1, d), x.dtype)
    x_shift = jnp.concatenate([xp, x[:, :-1]], axis=1)
    xk = x + (x_shift - x) * p["mu"][0]
    xr = x + (x_shift - x) * p["mu"][1]
    kk = jnp.einsum("bsd,df->bsf", xk, p["wk"])
    kk = jnp.square(jax.nn.relu(kk.astype(jnp.float32))).astype(x.dtype)
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["wr"]).astype(jnp.float32))
    out = r.astype(x.dtype) * jnp.einsum("bsf,fd->bsd", kk, p["wv"])
    return out, x[:, -1, :]


# ----------------------------------------------------------------------
# RG-LRU block (RecurrentGemma): proj-in (x2), conv1d, RG-LRU, gated out.
# ----------------------------------------------------------------------
def init_rglru_block(cfg: ModelConfig, key) -> Params:
    d, W = cfg.d_model, cfg.rnn_size
    ks = split_keys(key, 6)
    return {
        "w_in_x": dense_init(ks[0], (d, W), cfg.dtype),
        "w_in_gate": dense_init(ks[1], (d, W), cfg.dtype),
        "conv_w": dense_init(ks[2], (cfg.conv1d_width, W), cfg.dtype,
                             in_axis_size=cfg.conv1d_width),
        "conv_b": jnp.zeros((W,), cfg.dtype),
        "w_rgate": dense_init(ks[3], (W, W), cfg.dtype, in_axis_size=W),
        "w_igate": dense_init(ks[4], (W, W), cfg.dtype, in_axis_size=W),
        "lam": jnp.linspace(0.1, 2.0, W, dtype=jnp.float32),   # Lambda
        "w_out": dense_init(ks[5], (W, d), cfg.dtype, in_axis_size=W),
    }


def _causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                   x_prev: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv; x: (B,S,W); w: (kw,W); carries the last
    kw-1 inputs as state for decode."""
    kw = w.shape[0]
    B, S, W = x.shape
    pad = x_prev if x_prev is not None else jnp.zeros((B, kw - 1, W), x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(kw):
        out = out + xp[:, i:i + S, :].astype(jnp.float32) * w[i].astype(jnp.float32)
    new_prev = xp[:, -(kw - 1):, :] if kw > 1 else jnp.zeros((B, 0, W), x.dtype)
    return (out + b.astype(jnp.float32)).astype(x.dtype), new_prev


def rglru_block(cfg: ModelConfig, p: Params, x: jax.Array,
                state: Params | None = None) -> tuple[jax.Array, Params]:
    """The Griffin recurrent block. state = {"h": (B,W), "conv": (B,kw-1,W)}."""
    gate = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, p["w_in_gate"])
                       .astype(jnp.float32)).astype(x.dtype)
    u = jnp.einsum("bsd,dw->bsw", x, p["w_in_x"])
    u, conv_state = _causal_conv1d(u, p["conv_w"], p["conv_b"],
                                   state["conv"] if state else None)
    r_gate = jnp.einsum("bsw,wv->bsv", u, p["w_rgate"]).astype(jnp.float32)
    i_gate = jnp.einsum("bsw,wv->bsv", u, p["w_igate"]).astype(jnp.float32)
    h0 = state["h"] if state else None
    y, h = kops.rglru(u, r_gate.astype(u.dtype), i_gate.astype(u.dtype),
                      p["lam"], h0=h0)
    out = jnp.einsum("bsw,wd->bsd", y * gate, p["w_out"])
    return out, {"h": h, "conv": conv_state}
