"""Shared model vocabulary: config dataclass, norms, RoPE, init helpers.

One :class:`ModelConfig` describes every assigned architecture.  Layer
heterogeneity (gemma3's 5 local : 1 global, recurrentgemma's 2
recurrent : 1 local-attention, llama-vision's cross-attention every
5th layer) is expressed as a repeating ``layer_pattern`` string; the
transformer scans over *pattern units* so the HLO stays small and the
parameter count stays exact.

Block kind characters:
  ``G`` global self-attention      ``L`` local (sliding-window) self-attention
  ``R`` RG-LRU recurrent block     ``W`` RWKV6 time-mix + channel-mix block
  ``C`` cross-attention block (self-attn + cross-attn + mlp)
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

Params = Any      # nested dict pytree of jnp arrays


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                     # dense | moe | ssm | hybrid | encdec | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    vocab_size: int
    num_kv_heads: int | None = None
    head_dim: int | None = None
    qkv_bias: bool = False
    mlp_gated: bool = True             # SwiGLU; False = GELU MLP (whisper)
    norm: str = "rmsnorm"              # rmsnorm | layernorm
    layer_pattern: str = "G"
    sliding_window: int | None = None  # tokens, for 'L' blocks
    rope_theta: float = 10_000.0
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                  # per-expert hidden dim
    shared_expert_d_ff: int = 0        # fused shared-experts hidden dim
    capacity_factor: float = 1.25
    moe_group_size: int = 512
    # --- recurrent (R/W blocks) ---
    rnn_width: int = 0                 # RG-LRU recurrence width (0 = d_model)
    conv1d_width: int = 4
    # --- encoder-decoder / VLM ---
    encoder_layers: int = 0
    encoder_seq: int = 0               # e.g. whisper 1500 mel frames
    encoder_d_model: int = 0
    num_image_tokens: int = 0          # VLM stub patch-embedding count
    # --- numerics ---
    dtype: Any = jnp.bfloat16
    logit_dtype: Any = jnp.float32
    tie_embeddings: bool = False
    source: str = ""                   # citation (arXiv / model card)

    # ------------------------------------------------------------------
    @property
    def kv_heads(self) -> int:
        return self.num_kv_heads or self.num_heads

    @property
    def head_size(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def rnn_size(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def pattern_unit(self) -> str:
        return self.layer_pattern

    @property
    def num_units(self) -> int:
        return self.num_layers // len(self.layer_pattern)

    @property
    def remainder_pattern(self) -> str:
        """Layers that do not fill a whole pattern unit (prefix order)."""
        return self.layer_pattern[: self.num_layers % len(self.layer_pattern)]

    @property
    def is_subquadratic(self) -> bool:
        """True when no block attends globally over the full sequence,
        or attention-free blocks dominate memory (SSM/hybrid), making
        the 500k-decode shape feasible."""
        return self.arch_type in ("ssm", "hybrid") or "G" not in self.layer_pattern \
            or self.arch_type == "dense" and self.sliding_window is not None

    def validate(self) -> "ModelConfig":
        if self.num_layers < len(self.remainder_pattern):
            raise ValueError("num_layers smaller than pattern remainder")
        if self.num_heads % self.kv_heads:
            raise ValueError(f"{self.name}: num_heads {self.num_heads} not a "
                             f"multiple of kv heads {self.kv_heads}")
        if self.num_experts and not self.experts_per_token:
            raise ValueError("MoE needs experts_per_token")
        for ch in self.layer_pattern:
            if ch not in "GLRWC":
                raise ValueError(f"unknown block kind {ch!r}")
        return self

    def reduced(self, num_layers: int = 2, d_model: int = 256,
                num_heads: int = 4, d_ff: int = 512, vocab_size: int = 512,
                num_experts: int | None = None, **over) -> "ModelConfig":
        """Smoke-test variant of the same family (spec: 2 layers,
        d_model<=512, <=4 experts)."""
        kv = max(1, min(self.kv_heads, num_heads))
        ne = min(self.num_experts, 4) if num_experts is None else num_experts
        changes: dict[str, Any] = dict(
            name=self.name + "-reduced",
            num_layers=num_layers, d_model=d_model, num_heads=num_heads,
            num_kv_heads=kv if self.num_kv_heads else None,
            head_dim=d_model // num_heads if self.head_dim else None,
            d_ff=d_ff, vocab_size=vocab_size,
            num_experts=ne,
            experts_per_token=min(self.experts_per_token, max(ne, 1)) if ne else 0,
            moe_d_ff=min(self.moe_d_ff, d_ff) if ne else 0,
            shared_expert_d_ff=min(self.shared_expert_d_ff, d_ff),
            rnn_width=min(self.rnn_size, d_model) if self.rnn_width else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 64) if self.encoder_seq else 0,
            encoder_d_model=min(self.encoder_d_model, d_model) if self.encoder_d_model else 0,
            num_image_tokens=min(self.num_image_tokens, 16),
            moe_group_size=64,
            dtype=jnp.float32, logit_dtype=jnp.float32,
            # keep one block of each distinct kind so reduced variants
            # still exercise the family's heterogeneity (e.g. "GGGGC"
            # -> "GC", "LLLLLG" -> "LG", "RRL" -> "RL")
            layer_pattern="".join(dict.fromkeys(self.layer_pattern))[:num_layers]
            if len(self.layer_pattern) > num_layers else self.layer_pattern,
        )
        changes.update(over)
        return dataclasses.replace(self, **changes).validate()


# ----------------------------------------------------------------------
# Numerics
# ----------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale + bias).astype(x.dtype)


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def init_norm(cfg: ModelConfig, dtype=jnp.float32) -> Params:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((cfg.d_model,), dtype)}
    return {"scale": jnp.ones((cfg.d_model,), dtype),
            "bias": jnp.zeros((cfg.d_model,), dtype)}


# ----------------------------------------------------------------------
# RoPE
# ----------------------------------------------------------------------
def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    freqs = rope_frequencies(x.shape[-1], theta)                 # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                                # (..., S, 1, hd/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# Init helpers
# ----------------------------------------------------------------------
def dense_init(key, shape, dtype, in_axis_size: int | None = None):
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
