"""Block registry: one (init, train-apply, decode-apply, cache-init)
set per block kind.

Kinds (``ModelConfig.layer_pattern`` characters):
  G global attention + MLP        L sliding-window attention + MLP
  R RG-LRU recurrent + MLP        W RWKV6 time-mix + channel-mix
  C self-attn + cross-attn + MLP (whisper decoder / llama-vision)

Every block is pre-norm residual.  MLP is MoE when the config has
experts, else (gated) dense.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import recurrent as rec
from repro.models.common import (ModelConfig, Params, apply_norm, dense_init,
                                 init_norm, split_keys)
from repro.models.sharding import constrain


# ----------------------------------------------------------------------
# Dense MLP
# ----------------------------------------------------------------------
def init_mlp(cfg: ModelConfig, key) -> Params:
    d, ff = cfg.d_model, cfg.d_ff
    ks = split_keys(key, 3)
    p = {"wi": dense_init(ks[0], (d, ff), cfg.dtype),
         "wo": dense_init(ks[1], (ff, d), cfg.dtype, in_axis_size=ff)}
    if cfg.mlp_gated:
        p["wg"] = dense_init(ks[2], (d, ff), cfg.dtype)
    return p


def mlp_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    if cfg.mlp_gated:
        g = jnp.einsum("bsd,df->bsf", x, p["wg"])
        h = h * jax.nn.silu(g.astype(jnp.float32)).astype(h.dtype)
    else:
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(h.dtype)
    h = constrain(h, "batch", None, "tensor")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


def _ffn_init(cfg: ModelConfig, key) -> Params:
    if cfg.num_experts:
        return {"moe": moe_mod.init_moe(cfg, key)}
    return {"mlp": init_mlp(cfg, key)}


def _ffn_apply(cfg: ModelConfig, p: Params, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    if "moe" in p:
        return moe_mod.moe_mlp(cfg, p["moe"], x)
    return mlp_apply(cfg, p["mlp"], x), jnp.zeros((), jnp.float32)


# ----------------------------------------------------------------------
# Block init
# ----------------------------------------------------------------------
def init_block(cfg: ModelConfig, kind: str, key) -> Params:
    ks = split_keys(key, 4)
    if kind in ("G", "L"):
        return {"norm1": init_norm(cfg), "attn": attn.init_attention(cfg, ks[0]),
                "norm2": init_norm(cfg), **_ffn_init(cfg, ks[1])}
    if kind == "C":
        return {"norm1": init_norm(cfg), "attn": attn.init_attention(cfg, ks[0]),
                "norm_x": init_norm(cfg),
                "xattn": attn.init_attention(cfg, ks[2]),
                "norm2": init_norm(cfg), **_ffn_init(cfg, ks[1])}
    if kind == "R":
        return {"norm1": init_norm(cfg), "rglru": rec.init_rglru_block(cfg, ks[0]),
                "norm2": init_norm(cfg), **_ffn_init(cfg, ks[1])}
    if kind == "W":
        return {"norm1": init_norm(cfg),
                "time_mix": rec.init_rwkv_time_mix(cfg, ks[0]),
                "norm2": init_norm(cfg),
                "channel_mix": rec.init_rwkv_channel_mix(cfg, ks[1])}
    raise ValueError(f"unknown block kind {kind!r}")


# ----------------------------------------------------------------------
# Train / prefill (no cache)
# ----------------------------------------------------------------------
def apply_block(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                positions: jax.Array, encoder_out: jax.Array | None = None,
                ) -> tuple[jax.Array, jax.Array]:
    """Returns (x, moe_aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    if kind in ("G", "L", "C"):
        h = apply_norm(cfg, p["norm1"], x)
        window = cfg.sliding_window if kind == "L" else None
        x = x + attn.attention_fwd(cfg, p["attn"], h, positions,
                                   causal=True, window=window)
        if kind == "C":
            h = apply_norm(cfg, p["norm_x"], x)
            x = x + attn.attention_fwd(cfg, p["xattn"], h, positions,
                                       kv_src=encoder_out, use_rope=False)
        h = apply_norm(cfg, p["norm2"], x)
        y, aux = _ffn_apply(cfg, p, h)
        x = x + y
    elif kind == "R":
        h = apply_norm(cfg, p["norm1"], x)
        y, _ = rec.rglru_block(cfg, p["rglru"], h)
        x = x + y
        h = apply_norm(cfg, p["norm2"], x)
        y, aux = _ffn_apply(cfg, p, h)
        x = x + y
    elif kind == "W":
        h = apply_norm(cfg, p["norm1"], x)
        y, _ = rec.rwkv_time_mix(cfg, p["time_mix"], h)
        x = x + y
        h = apply_norm(cfg, p["norm2"], x)
        y, _ = rec.rwkv_channel_mix(cfg, p["channel_mix"], h)
        x = x + y
    else:
        raise ValueError(kind)
    x = constrain(x, "batch", None, None)
    return x, aux


# ----------------------------------------------------------------------
# Decode (serve_step): one token + cache
# ----------------------------------------------------------------------
def init_block_cache(cfg: ModelConfig, kind: str, batch: int, seq_len: int,
                     ) -> Params:
    if kind == "G":
        return attn.init_kv_cache(cfg, batch, seq_len)
    if kind == "L":
        return attn.init_kv_cache(cfg, batch, seq_len, window=cfg.sliding_window)
    if kind == "C":
        return attn.init_kv_cache(cfg, batch, seq_len)   # self-attn cache only
    if kind == "R":
        W, kw = cfg.rnn_size, cfg.conv1d_width
        return {"h": jnp.zeros((batch, W), jnp.float32),
                "conv": jnp.zeros((batch, kw - 1, W), cfg.dtype)}
    if kind == "W":
        H, hd = rec.rwkv_heads(cfg), rec.RWKV_HEAD_DIM
        d = cfg.d_model
        return {"S": jnp.zeros((batch, H, hd, hd), jnp.float32),
                "x_prev_tm": jnp.zeros((batch, d), cfg.dtype),
                "x_prev_cm": jnp.zeros((batch, d), cfg.dtype)}
    raise ValueError(kind)


def decode_block(cfg: ModelConfig, kind: str, p: Params, x: jax.Array,
                 cache: Params, pos: jax.Array,
                 encoder_out: jax.Array | None = None,
                 seq_axis: str | None = None,
                 ) -> tuple[jax.Array, Params]:
    """x: (B, 1, d) -> (x, new_cache)."""
    if kind in ("G", "L", "C"):
        h = apply_norm(cfg, p["norm1"], x)
        window = cfg.sliding_window if kind == "L" else None
        y, new_cache = attn.decode_attention(
            cfg, p["attn"], h, cache, pos, window=window,
            seq_axis=seq_axis if kind == "G" else None)
        x = x + y
        if kind == "C":
            h = apply_norm(cfg, p["norm_x"], x)
            posb = jnp.broadcast_to(pos[None, None], (x.shape[0], 1))
            x = x + attn.attention_fwd(cfg, p["xattn"], h, posb,
                                       kv_src=encoder_out, use_rope=False)
        h = apply_norm(cfg, p["norm2"], x)
        y, _ = _ffn_apply(cfg, p, h)
        x = x + y
    elif kind == "R":
        h = apply_norm(cfg, p["norm1"], x)
        y, new_cache = rec.rglru_block(cfg, p["rglru"], h, state=cache)
        x = x + y
        h = apply_norm(cfg, p["norm2"], x)
        y, _ = _ffn_apply(cfg, p, h)
        x = x + y
    elif kind == "W":
        h = apply_norm(cfg, p["norm1"], x)
        y, tm_state = rec.rwkv_time_mix(
            cfg, p["time_mix"], h,
            state={"S": cache["S"], "x_prev": cache["x_prev_tm"]})
        x = x + y
        h = apply_norm(cfg, p["norm2"], x)
        y, x_prev_cm = rec.rwkv_channel_mix(cfg, p["channel_mix"], h,
                                            x_prev=cache["x_prev_cm"])
        x = x + y
        new_cache = {"S": tm_state["S"], "x_prev_tm": tm_state["x_prev"],
                     "x_prev_cm": x_prev_cm}
    else:
        raise ValueError(kind)
    return x, new_cache
