"""Mixture-of-Experts MLP with grouped einsum dispatch (Mesh-TF /
MaxText style — SPMD-friendly, expert-parallel over the ``model`` mesh
axis).

Tokens are processed in groups; each group computes a top-k router,
builds a (group, expert, capacity) dispatch/combine pair, and the
expert FFNs run as a single batched einsum over the expert dimension.
Dropped tokens (over capacity) fall through the residual connection,
the standard capacity-factor behaviour.  Shared experts (qwen2-moe)
are a plain dense MLP fused to ``shared_expert_d_ff``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig, Params, dense_init, split_keys


def init_moe(cfg: ModelConfig, key) -> Params:
    d, E, ff = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = split_keys(key, 7)
    p: Params = {
        "router": dense_init(ks[0], (d, E), jnp.float32, in_axis_size=d),
        "wi": dense_init(ks[1], (E, d, ff), cfg.dtype, in_axis_size=d),
        "wg": dense_init(ks[2], (E, d, ff), cfg.dtype, in_axis_size=d),
        "wo": dense_init(ks[3], (E, ff, d), cfg.dtype, in_axis_size=ff),
    }
    if cfg.shared_expert_d_ff:
        sf = cfg.shared_expert_d_ff
        p["shared"] = {
            "wi": dense_init(ks[4], (d, sf), cfg.dtype, in_axis_size=d),
            "wg": dense_init(ks[5], (d, sf), cfg.dtype, in_axis_size=d),
            "wo": dense_init(ks[6], (sf, d), cfg.dtype, in_axis_size=sf),
        }
    return p


def _capacity(cfg: ModelConfig, group: int) -> int:
    c = int(group * cfg.experts_per_token * cfg.capacity_factor
            / max(cfg.num_experts, 1))
    return max(c, 1)


def moe_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    aux_loss is the standard load-balancing loss (mean over groups of
    E * sum_e fraction_e * router_prob_e), returned for the trainer.
    """
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    tokens = x.reshape(-1, d)
    T = tokens.shape[0]
    g = min(cfg.moe_group_size, T)
    # pad to a multiple of the group size
    pad = (-T) % g
    if pad:
        tokens = jnp.concatenate([tokens, jnp.zeros((pad, d), tokens.dtype)])
    G = tokens.shape[0] // g
    xg = tokens.reshape(G, g, d)
    C = _capacity(cfg, g)

    logits = jnp.einsum("Ggd,dE->GgE", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                       # (G,g,E)
    gate_vals, top_e = jax.lax.top_k(probs, k)                    # (G,g,k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert's capacity
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)            # (G,g,k,E)
    flat = onehot.reshape(G, g * k, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat                    # (G,g*k,E)
    pos = jnp.sum(pos_in_e * flat, axis=-1).reshape(G, g, k)      # (G,g,k)
    keep = pos < C

    # dispatch/combine tensors (G, g, E, C)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, C), C, dtype=xg.dtype)
    disp = jnp.einsum("GgkE,Ggkc->GgEc",
                      onehot.astype(xg.dtype) * keep[..., None], pos_oh)
    comb = jnp.einsum("Ggk,GgkE,Ggkc->GgEc",
                      gate_vals.astype(xg.dtype),
                      onehot.astype(xg.dtype) * keep[..., None], pos_oh)

    expert_in = jnp.einsum("GgEc,Ggd->EGcd", disp, xg)            # (E,G,C,d)
    h = jnp.einsum("EGcd,Edf->EGcf", expert_in, p["wi"])
    gates = jnp.einsum("EGcd,Edf->EGcf", expert_in, p["wg"])
    h = h * jax.nn.silu(gates.astype(jnp.float32)).astype(h.dtype)
    expert_out = jnp.einsum("EGcf,Efd->EGcd", h, p["wo"])
    out = jnp.einsum("GgEc,EGcd->Ggd", comb, expert_out)

    out = out.reshape(-1, d)[:T].reshape(B, S, d)

    # load-balance auxiliary loss (Switch-style)
    frac = jnp.mean(jnp.sum(onehot[..., 0, :] if k == 1 else
                            jnp.max(onehot, axis=2), axis=1) / g, axis=0)
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(frac * mean_prob)

    if "shared" in p:
        sp = p["shared"]
        hs = jnp.einsum("bsd,df->bsf", x, sp["wi"])
        gs = jnp.einsum("bsd,df->bsf", x, sp["wg"])
        hs = hs * jax.nn.silu(gs.astype(jnp.float32)).astype(hs.dtype)
        out = out + jnp.einsum("bsf,fd->bsd", hs, sp["wo"])
    return out, aux
