"""The paper's CNN workloads (Table IV) as real JAX models.

AlexNet and ResNet are built as *lists of named layers* so the trace
generator (:mod:`repro.traces.generate`) can time each layer's forward
and backward separately — reproducing exactly the layer-wise
methodology behind the paper's Table VI traces, but on this machine.

These run at reduced resolution/batch on CPU for trace generation; the
analytic FLOPs tables in :mod:`repro.core.costmodel` carry the
full-size ImageNet numbers.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, split_keys
from repro.traces.generate import TimedLayer


def _conv_apply(stride: int, padding: str = "SAME"):
    def apply(p, x):
        y = jax.lax.conv_general_dilated(
            x, p["w"], window_strides=(stride, stride), padding=padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        return jax.nn.relu(y + p["b"])
    return apply


def _conv_init(key, kh, cin, cout, dtype=jnp.float32):
    return {"w": dense_init(key, (kh, kh, cin, cout), dtype,
                            in_axis_size=kh * kh * cin),
            "b": jnp.zeros((cout,), dtype)}


def _maxpool(window: int, stride: int):
    def apply(_p, x):
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, window, window, 1),
            (1, stride, stride, 1), "VALID")
    return apply


def _fc_apply(relu: bool = True):
    def apply(p, x):
        x = x.reshape(x.shape[0], -1)
        y = x @ p["w"] + p["b"]
        return jax.nn.relu(y) if relu else y
    return apply


def _fc_init(key, nin, nout, dtype=jnp.float32):
    return {"w": dense_init(key, (nin, nout), dtype), "b": jnp.zeros((nout,), dtype)}


# ----------------------------------------------------------------------
# AlexNet (LRN excluded, per the paper).  ``scale`` shrinks the spatial
# resolution for CPU trace generation (scale=1 -> 224x224 ImageNet).
# ----------------------------------------------------------------------
def alexnet_timed_layers(key, input_hw: int = 224, scale: int = 1,
                         num_classes: int = 1000) -> tuple[list[TimedLayer], jax.Array]:
    hw = input_hw // scale
    ks = split_keys(key, 8)
    layers = [
        TimedLayer("conv1", _conv_apply(4, "VALID"), _conv_init(ks[0], 11, 3, 96)),
        TimedLayer("pool1", _maxpool(3, 2), {}),
        TimedLayer("conv2", _conv_apply(1), _conv_init(ks[1], 5, 96, 256)),
        TimedLayer("pool2", _maxpool(3, 2), {}),
        TimedLayer("conv3", _conv_apply(1), _conv_init(ks[2], 3, 256, 384)),
        TimedLayer("conv4", _conv_apply(1), _conv_init(ks[3], 3, 384, 384)),
        TimedLayer("conv5", _conv_apply(1), _conv_init(ks[4], 3, 384, 256)),
        TimedLayer("pool5", _maxpool(3, 2), {}),
    ]
    # infer the flattened size by tracing shapes
    x = jnp.zeros((1, hw, hw, 3), jnp.float32)
    for l in layers:
        x = jax.eval_shape(l.apply, l.params, x)
        x = jnp.zeros(x.shape, x.dtype)
    flat = int(jnp.prod(jnp.array(x.shape[1:])))
    layers += [
        TimedLayer("fc6", _fc_apply(), _fc_init(ks[5], flat, 4096)),
        TimedLayer("fc7", _fc_apply(), _fc_init(ks[6], 4096, 4096)),
        TimedLayer("fc8", _fc_apply(relu=False), _fc_init(ks[7], 4096, num_classes)),
    ]
    return layers, jnp.zeros((1, hw, hw, 3), jnp.float32)


# ----------------------------------------------------------------------
# ResNet (bottleneck): each residual block is one timed "layer", the
# granularity of the paper's ResNet-50 traces.  depth_per_stage=(3,4,6,3)
# is ResNet-50; smaller settings give CPU-sized variants.
# ----------------------------------------------------------------------
def _bottleneck_init(key, cin, mid, cout, stride):
    ks = split_keys(key, 4)
    p = {"c1": _conv_init(ks[0], 1, cin, mid),
         "c2": _conv_init(ks[1], 3, mid, mid),
         "c3": _conv_init(ks[2], 1, mid, cout)}
    if stride != 1 or cin != cout:
        p["proj"] = _conv_init(ks[3], 1, cin, cout)
    return p


def _bottleneck_apply(stride: int):
    def apply(p, x):
        y = _conv_apply(1)(p["c1"], x)
        y = _conv_apply(stride)(p["c2"], y)
        y = jax.lax.conv_general_dilated(
            y, p["c3"]["w"], (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["c3"]["b"]
        if "proj" in p:
            x = jax.lax.conv_general_dilated(
                x, p["proj"]["w"], (stride, stride), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["proj"]["b"]
        return jax.nn.relu(x + y)
    return apply


def resnet_timed_layers(key, input_hw: int = 224,
                        depth_per_stage: Sequence[int] = (3, 4, 6, 3),
                        width: int = 64, num_classes: int = 1000,
                        ) -> tuple[list[TimedLayer], jax.Array]:
    ks = split_keys(key, sum(depth_per_stage) + 2)
    ki = iter(ks)
    layers = [TimedLayer("conv1", _conv_apply(2), _conv_init(next(ki), 7, 3, width)),
              TimedLayer("pool1", _maxpool(3, 2), {})]
    cin = width
    for stage, blocks in enumerate(depth_per_stage):
        mid = width * (2 ** stage)
        cout = mid * 4
        for b in range(blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            layers.append(TimedLayer(
                f"res{stage + 2}{chr(ord('a') + b)}",
                _bottleneck_apply(stride),
                _bottleneck_init(next(ki), cin, mid, cout, stride)))
            cin = cout

    def pool_fc_apply(p, x):
        x = jnp.mean(x, axis=(1, 2))
        return x @ p["w"] + p["b"]

    layers.append(TimedLayer("fc", pool_fc_apply, _fc_init(next(ki), cin, num_classes)))
    return layers, jnp.zeros((1, input_hw, input_hw, 3), jnp.float32)
