"""Vocab-chunked softmax cross-entropy with custom VJP.

At 262k vocab (gemma3) the (B, S, V) f32 logits of a 1M-token batch
are several GB *per device*; materializing them forward and backward
dominates training memory.  This computes the loss by scanning vocab
chunks (running logsumexp + label-logit gather) and recomputes chunk
logits in the backward pass — O(B*S*chunk) live memory.

``loss, dx, dhead = f(x, head, labels)``; x: (B, S, d) final hidden
states, head: (d, V).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

DEFAULT_CHUNK = 8192


def _num_chunks(V: int, chunk: int) -> int:
    if V % chunk:
        # fall back to the largest divisor <= chunk
        for c in range(chunk, 0, -1):
            if V % c == 0:
                return V // c
    return V // chunk


def _lse_scan(x, head, labels, nc):
    """Running (max, sumexp, label_logit) over vocab chunks."""
    B, S, d = x.shape
    V = head.shape[1]
    c = V // nc
    headc = jnp.moveaxis(head.reshape(d, nc, c), 1, 0)     # (nc, d, c)

    def body(carry, args):
        m, l, lab = carry
        hc, ic = args
        logits = jnp.einsum("bsd,dc->bsc", x, hc).astype(jnp.float32)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        l = l * jnp.exp(m - m_new) + jnp.sum(jnp.exp(logits - m_new[..., None]),
                                             axis=-1)
        loc = labels - ic * c
        inside = (loc >= 0) & (loc < c)
        picked = jnp.take_along_axis(logits, jnp.clip(loc, 0, c - 1)[..., None],
                                     axis=-1)[..., 0]
        lab = jnp.where(inside, picked, lab)
        return (m_new, l, lab), None

    init = (jnp.full((B, S), -1e30, jnp.float32),
            jnp.zeros((B, S), jnp.float32),
            jnp.zeros((B, S), jnp.float32))
    (m, l, lab), _ = jax.lax.scan(body, init, (headc, jnp.arange(nc)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return lse, lab


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def chunked_cross_entropy(x, head, labels, chunk: int = DEFAULT_CHUNK):
    """Mean token NLL. x: (B,S,d); head: (d,V); labels: (B,S) int32."""
    loss, _ = _ce_fwd(x, head, labels, chunk)
    return loss


def _ce_fwd(x, head, labels, chunk):
    V = head.shape[1]
    nc = _num_chunks(V, min(chunk, V))
    lse, lab = _lse_scan(x, head, labels, nc)
    loss = jnp.mean(lse - lab)
    return loss, (x, head, labels, lse)


def _ce_bwd(chunk, res, dloss):
    x, head, labels, lse = res
    B, S, d = x.shape
    V = head.shape[1]
    nc = _num_chunks(V, min(chunk, V))
    c = V // nc
    headc = jnp.moveaxis(head.reshape(d, nc, c), 1, 0)
    scale = dloss / (B * S)

    def body(dx, args):
        hc, ic = args
        logits = jnp.einsum("bsd,dc->bsc", x, hc).astype(jnp.float32)
        p = jnp.exp(logits - lse[..., None])
        loc = labels - ic * c
        inside = (loc >= 0) & (loc < c)
        onehot = (jnp.arange(c)[None, None, :] == loc[..., None]) \
            & inside[..., None]
        dlogits = (p - onehot.astype(jnp.float32)) * scale
        dx = dx + jnp.einsum("bsc,dc->bsd", dlogits,
                             hc.astype(jnp.float32))
        dh = jnp.einsum("bsd,bsc->dc", x.astype(jnp.float32), dlogits)
        return dx, dh

    dx0 = jnp.zeros((B, S, d), jnp.float32)
    dx, dhc = jax.lax.scan(body, dx0, (headc, jnp.arange(nc)))
    dhead = jnp.moveaxis(dhc, 0, 1).reshape(d, V)
    return dx.astype(x.dtype), dhead.astype(head.dtype), None


chunked_cross_entropy.defvjp(_ce_fwd, _ce_bwd)
