"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Mesh axes: ``("data", "model")`` single pod, ``("pod", "data", "model")``
multi-pod.  Logical roles:

* ``batch``  -> every data-parallel axis (``pod`` + ``data``)
* ``fsdp``   -> ``data`` (parameter sharding; disabled in ``pure_dp``
  mode, where the paper's explicit gradient-sync policies apply)
* ``tensor`` -> ``model`` (heads / mlp / vocab)
* ``expert`` -> ``model`` (expert parallelism for MoE)

Parameter specs are derived from leaf names + ranks, so every model
family shares one rule table.  ``constrain`` is a no-op outside a mesh
context (CPU unit tests).
"""
from __future__ import annotations

import contextvars
from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


@dataclass(frozen=True)
class ShardingConfig:
    mesh_axes: tuple[str, ...]               # axes of the active mesh
    mode: str = "fsdp"                       # "fsdp" | "pure_dp"

    def _axis(self, logical: str):
        if logical == "batch":
            if self.mode == "zero3":
                # batch over the whole mesh: 256-way pure DP
                return tuple(self.mesh_axes)
            return tuple(a for a in self.mesh_axes if a in ("pod", "data")) or None
        if logical == "fsdp":
            if self.mode == "pure_dp":
                return None
            if self.mode in ("fsdp2d", "zero3"):
                # no tensor parallelism: both mesh axes shard parameters
                return tuple(a for a in self.mesh_axes
                             if a in ("data", "model")) or None
            return "data" if "data" in self.mesh_axes else None
        if logical in ("tensor", "expert"):
            if self.mode in ("fsdp2d", "zero3"):
                return None
            return "model" if "model" in self.mesh_axes else None
        if logical == "seq":  # sequence sharding (long-context decode)
            return "data" if "data" in self.mesh_axes else None
        if logical is None:
            return None
        raise KeyError(logical)

    def spec(self, *logical) -> P:
        return P(*(self._axis(l) for l in logical))

    @property
    def dp_axes(self) -> tuple[str, ...]:
        """Axes over which gradients must be explicitly averaged (pure
        data-parallel replication axes)."""
        if self.mode == "pure_dp":
            return tuple(a for a in self.mesh_axes if a in ("pod", "data"))
        # fsdp: the data axis reduce-scatters automatically through the
        # parameter sharding; only the pod axis is pure replication.
        return tuple(a for a in self.mesh_axes if a == "pod")


_ACTIVE: contextvars.ContextVar[ShardingConfig | None] = \
    contextvars.ContextVar("sharding_config", default=None)


def set_sharding(cfg: ShardingConfig | None):
    return _ACTIVE.set(cfg)


def active_sharding() -> ShardingConfig | None:
    return _ACTIVE.get()


def constrain(x: jax.Array, *logical) -> jax.Array:
    """with_sharding_constraint under the active rules; identity when
    no rules are active (single-device tests)."""
    sc = _ACTIVE.get()
    if sc is None:
        return x
    spec = resolve_spec(x.shape, [[l] if l else [] for l in logical], sc)
    return jax.lax.with_sharding_constraint(x, spec)


# ----------------------------------------------------------------------
# Divisibility-aware spec resolution.
#
# Assigned architectures have kv_heads in {1, 6, 8, ...} that do not
# divide the 16-way model axis; candidate lists let a leaf fall back
# (e.g. shard head_dim when kv_heads cannot take the tensor axis), and
# any dim whose size is not divisible stays replicated instead of
# failing to lower.
# ----------------------------------------------------------------------
_MESH_SIZES: contextvars.ContextVar[dict[str, int] | None] = \
    contextvars.ContextVar("mesh_sizes", default=None)


def set_mesh_sizes(sizes: dict[str, int] | None):
    return _MESH_SIZES.set(sizes)


def _mesh_sizes() -> dict[str, int]:
    sizes = _MESH_SIZES.get()
    if sizes is not None:
        return sizes
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and mesh.shape:
            return dict(mesh.shape)
    except Exception:
        pass
    return {}


def resolve_spec(shape, dim_candidates, sc: ShardingConfig) -> P:
    """Greedy spec assignment: per dim, the first candidate logical
    axis whose mesh axes (a) exist, (b) divide the dim size, and
    (c) are not already used by another dim of this leaf."""
    sizes = _mesh_sizes()
    used: set[str] = set()
    out = []
    for dim, candidates in zip(shape, dim_candidates):
        chosen = None
        for logical in candidates:
            axes = sc._axis(logical)
            if axes is None:
                continue
            was_tuple = isinstance(axes, tuple)
            axes_t = axes if was_tuple else (axes,)
            # progressively drop trailing axes until divisible & unused
            while axes_t:
                prod = 1
                ok = True
                for a in axes_t:
                    if a in used or a not in sizes:
                        ok = False
                        break
                    prod *= sizes[a]
                if ok and dim % prod == 0:
                    break
                axes_t = axes_t[:-1]
            if axes_t:
                # keep tuple-ness: a multi-axis logical role stays a
                # tuple entry even when dropped to one axis (older jax
                # PartitionSpecs do not equate 'x' with ('x',))
                chosen = axes_t if was_tuple else axes_t[0]
                used.update(axes_t)
                break
        out.append(chosen)
    return P(*out)


# ----------------------------------------------------------------------
# Parameter PartitionSpecs by leaf name + rank.  Each dim lists
# *candidates* in preference order (e.g. GQA kv projections prefer the
# tensor axis on kv_heads but fall back to head_dim).
# ----------------------------------------------------------------------
def _leaf_candidates(name: str, ndim: int) -> tuple:
    N = ()                                            # replicated dim
    # Attention projections: shard q-heads when they divide the axis,
    # otherwise REPLICATE the head dims (never shard head_dim: any
    # contraction over a sharded hd turns every attention block matmul
    # into a cross-device reduction — measured 100x collective blowup,
    # see EXPERIMENTS.md §Perf iteration 1).
    if ndim == 3 and name == "wq":                   # (d, H, hd)
        return (["fsdp"], ["tensor"], N)
    if ndim == 3 and name in ("wk", "wv"):           # (d, K, hd)
        return (["fsdp"], ["tensor"], N)
    if ndim == 3 and name in ("wi", "wg"):           # MoE experts (E, d, ff)
        # expert-parallel when E divides the axis; otherwise experts
        # are tensor-parallel over their hidden dim
        return (["expert"], ["fsdp"], ["tensor"])
    if ndim == 3 and name == "wo":                   # attn (H,hd,d) / MoE (E,ff,d)
        # never shard dim1 (attention head_dim: a sharded contraction;
        # for MoE the unsharded row side still lowers to the same
        # single output all-reduce as an explicit Megatron pair)
        return (["tensor"], N, ["fsdp"])
    if ndim == 2 and name == "embedding":            # (V, d)
        return (["tensor"], ["fsdp"])
    if ndim == 2 and name == "router":               # (d, E)
        return (["fsdp"], N)
    if ndim == 2 and name in ("wi", "wg", "wk", "wr", "ww", "wq",
                              "w_in_x", "w_in_gate", "w_rgate", "w_igate",
                              "lm_head"):            # (d_in, d_out) column-parallel
        return (["fsdp"], ["tensor"])
    if ndim == 2 and name in ("wv", "wo", "w_out"):  # (d_out, d) row-parallel
        return (["tensor"], ["fsdp"])
    if ndim == 2 and name == "conv_w":               # (kw, W)
        return (N, ["tensor"])
    if ndim == 2 and name == "u":                    # rwkv bonus (H, hd)
        return (["tensor"], N)
    if ndim == 1 and name in ("lam", "conv_b"):      # width-aligned vectors
        return (["tensor"],)
    return tuple(() for _ in range(ndim))            # norms, biases, mu


def param_specs(params, sc: ShardingConfig, stacked_prefixes=("units",)):
    """PartitionSpec pytree for a parameter pytree.  Leaves under any
    path component in ``stacked_prefixes`` carry a leading scan (unit)
    dimension which stays unsharded."""

    def spec_for(path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        stacked = any(n in stacked_prefixes for n in names[:-1])
        ndim = leaf.ndim - (1 if stacked else 0)
        cands = _leaf_candidates(name, ndim)
        if stacked:
            cands = ((),) + cands
        return resolve_spec(leaf.shape, cands, sc)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def named_shardings(params, sc: ShardingConfig, mesh: Mesh,
                    stacked_prefixes=("units",)):
    specs = param_specs(params, sc, stacked_prefixes)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs)


# ----------------------------------------------------------------------
# KV-cache / recurrent-state PartitionSpecs (serve_step).
# ----------------------------------------------------------------------
def _cache_candidates(name: str, ndim: int) -> tuple:
    N = ()
    if name in ("k", "v") and ndim == 4:     # (B, S, K, hd)
        # batch over the data axes; the cache *sequence* dim takes the
        # model axis (or the data axis when batch=1, the 500k shape):
        # decode attention over a seq-sharded cache costs only a
        # (B, H)-scale partial-softmax psum per layer, vs hd-sharded
        # caches turning the score contraction into a collective
        # (EXPERIMENTS.md §Perf iteration 6).
        return (["batch"], ["seq", "tensor"], ["tensor"], ["tensor"])
    if name == "S" and ndim == 4:            # rwkv state (B, H, hd, hd)
        return (["batch"], ["tensor"], N, N)
    if name == "h" and ndim == 2:            # rg-lru state (B, W)
        return (["batch"], ["tensor"])
    if name == "conv" and ndim == 3:         # (B, kw-1, W)
        return (["batch"], N, ["tensor"])
    if name.startswith("x_prev") and ndim == 2:
        return (["batch"], N)
    return tuple(N for _ in range(ndim))


def cache_specs(cache, sc: ShardingConfig, stacked_prefixes=("units",)):
    def spec_for(path, leaf) -> P:
        names = [getattr(k, "key", getattr(k, "name", str(k))) for k in path]
        name = names[-1]
        stacked = any(n in stacked_prefixes for n in names[:-1])
        ndim = leaf.ndim - (1 if stacked else 0)
        cands = _cache_candidates(name, ndim)
        if stacked:
            cands = ((),) + cands
        return resolve_spec(leaf.shape, cands, sc)

    return jax.tree_util.tree_map_with_path(spec_for, cache)
