"""Decoder-only LM over arbitrary ``layer_pattern`` block sequences.

Layers are executed as ``num_units`` repetitions of the pattern unit
via ``lax.scan`` over stacked parameters (small HLO, fast multi-pod
compiles) plus an unstacked remainder — so gemma3's 5:1 local:global,
recurrentgemma's 2:1 recurrent:attention and llama-vision's 4:1
self:cross patterns all lower through the same code path.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models import blocks as B
from repro.models.common import ModelConfig, Params, apply_norm, dense_init, \
    init_norm, split_keys
from repro.models.sharding import constrain


# ----------------------------------------------------------------------
# Init
# ----------------------------------------------------------------------
def init_unit(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, len(cfg.layer_pattern))
    return {f"b{i}": B.init_block(cfg, kind, ks[i])
            for i, kind in enumerate(cfg.layer_pattern)}


def init_lm(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, 4 + len(cfg.remainder_pattern))
    params: Params = {
        "embedding": dense_init(ks[0], (cfg.vocab_size, cfg.d_model),
                                cfg.dtype, in_axis_size=cfg.d_model),
        "final_norm": init_norm(cfg),
    }
    if cfg.num_units > 0:
        unit_keys = jnp.stack(split_keys(ks[1], cfg.num_units))
        params["units"] = jax.vmap(lambda k: init_unit(cfg, k))(unit_keys)
    for i, kind in enumerate(cfg.remainder_pattern):
        params[f"rem{i}"] = B.init_block(cfg, kind, ks[4 + i])
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab_size),
                                       cfg.dtype)
    return params


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ----------------------------------------------------------------------
# Forward (train / prefill)
# ----------------------------------------------------------------------
def forward(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
            encoder_out: jax.Array | None = None,
            positions: jax.Array | None = None,
            remat: bool = False,
            param_hook=None) -> tuple[jax.Array, jax.Array]:
    """tokens: (B, S) int32 -> (logits (B, S, V) in logit_dtype, moe_aux).

    ``param_hook`` (see :func:`repro.comm.sync.wfbp_param_hook`) is
    applied to each scanned unit's parameters *inside* the scan body —
    its backward rule then runs per layer inside the backward loop,
    which is how WFBP's layer-wise gradient all-reduce is realized in
    HLO — and to the unscanned leaves at their use sites.
    """
    x, head, aux = _final_hidden(cfg, params, tokens,
                                 encoder_out=encoder_out,
                                 positions=positions, remat=remat,
                                 param_hook=param_hook)
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(cfg.logit_dtype)
    logits = constrain(logits, "batch", None, "tensor")
    return logits, aux


def _final_hidden(cfg: ModelConfig, params: Params, tokens: jax.Array, *,
                  encoder_out=None, positions=None, remat=False,
                  param_hook=None):
    ph = param_hook or (lambda p: p)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)
    emb = ph(params["embedding"])
    x = emb[tokens]
    x = constrain(x, "batch", None, None)
    aux0 = jnp.zeros((), jnp.float32)

    def unit_body(carry, unit_params):
        x, aux = carry
        unit_params = ph(unit_params)
        for i, kind in enumerate(cfg.layer_pattern):
            x, a = B.apply_block(cfg, kind, unit_params[f"b{i}"], x,
                                 positions, encoder_out)
            aux = aux + a
        return (x, aux), None

    if remat:
        unit_body = jax.checkpoint(unit_body)

    if cfg.num_units > 0:
        (x, aux), _ = jax.lax.scan(unit_body, (x, aux0), params["units"])
    else:
        aux = aux0
    for i, kind in enumerate(cfg.remainder_pattern):
        x, a = B.apply_block(cfg, kind, ph(params[f"rem{i}"]), x, positions,
                             encoder_out)
        aux = aux + a

    x = apply_norm(cfg, ph(params["final_norm"]), x)
    head = emb.T if cfg.tie_embeddings else ph(params["lm_head"])
    return x, head, aux


# Vocab sizes at or above this use the chunked-xent path (the assigned
# archs have 51k-262k vocabularies; materializing (B,S,V) f32 logits
# fwd+bwd would dominate HBM).
CHUNKED_XENT_MIN_VOCAB = 16_384


def loss_fn(cfg: ModelConfig, params: Params, tokens: jax.Array,
            labels: jax.Array, *, encoder_out: jax.Array | None = None,
            aux_weight: float = 0.01, remat: bool = False,
            param_hook=None) -> tuple[jax.Array, dict]:
    x, head, aux = _final_hidden(cfg, params, tokens,
                                 encoder_out=encoder_out, remat=remat,
                                 param_hook=param_hook)
    if cfg.vocab_size >= CHUNKED_XENT_MIN_VOCAB:
        from repro.models.loss import chunked_cross_entropy
        loss = chunked_cross_entropy(x, head, labels)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, head).astype(cfg.logit_dtype)
        logp = jax.nn.log_softmax(logits, axis=-1)
        loss = -jnp.mean(
            jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0])
    total = loss + aux_weight * aux
    return total, {"loss": loss, "moe_aux": aux}


# ----------------------------------------------------------------------
# Decode (serve_step)
# ----------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Params:
    cache: Params = {}
    if cfg.num_units > 0:
        def one_unit(_):
            return {f"b{i}": B.init_block_cache(cfg, kind, batch, seq_len)
                    for i, kind in enumerate(cfg.layer_pattern)}
        cache["units"] = jax.vmap(one_unit)(jnp.arange(cfg.num_units))
    for i, kind in enumerate(cfg.remainder_pattern):
        cache[f"rem{i}"] = B.init_block_cache(cfg, kind, batch, seq_len)
    return cache


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                token: jax.Array, pos: jax.Array, *,
                encoder_out: jax.Array | None = None,
                seq_axis: str | None = None) -> tuple[jax.Array, Params]:
    """One-token decode.  token: (B,) int32; pos: scalar int32.
    Returns (logits (B, V), new_cache)."""
    x = params["embedding"][token][:, None, :]        # (B, 1, d)

    def unit_body(x, xs):
        unit_params, unit_cache = xs
        new_cache = {}
        for i, kind in enumerate(cfg.layer_pattern):
            x, nc = B.decode_block(cfg, kind, unit_params[f"b{i}"], x,
                                   unit_cache[f"b{i}"], pos,
                                   encoder_out=encoder_out,
                                   seq_axis=seq_axis)
            new_cache[f"b{i}"] = nc
        return x, new_cache

    new_cache: Params = {}
    if cfg.num_units > 0:
        x, new_cache["units"] = jax.lax.scan(
            unit_body, x, (params["units"], cache["units"]))
    for i, kind in enumerate(cfg.remainder_pattern):
        x, nc = B.decode_block(cfg, kind, params[f"rem{i}"], x,
                               cache[f"rem{i}"], pos,
                               encoder_out=encoder_out, seq_axis=seq_axis)
        new_cache[f"rem{i}"] = nc

    x = apply_norm(cfg, params["final_norm"], x)
    head = params["embedding"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head).astype(cfg.logit_dtype)
    return logits[:, 0, :], new_cache


def prefill_via_decode(cfg: ModelConfig, params: Params, tokens: jax.Array,
                       seq_len: int, *, encoder_out=None) -> tuple[jax.Array, Params]:
    """Sequential prefill for the serving example (small models): feed
    tokens one at a time through ``decode_step``."""
    cache = init_cache(cfg, tokens.shape[0], seq_len)

    def step(carry, t):
        cache, pos = carry
        logits, cache = decode_step(cfg, params, cache, t, pos,
                                    encoder_out=encoder_out)
        return (cache, pos + 1), logits

    (cache, _), logits = jax.lax.scan(step, (cache, jnp.int32(0)), tokens.T)
    return jnp.moveaxis(logits, 0, 1), cache
