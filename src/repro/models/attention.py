"""GQA attention: projections, RoPE, masks, KV caches, and a
shard_map'd distributed flash-decode for sequence-sharded caches.

The inner attention math lives in :mod:`repro.kernels.ops` so the
Pallas flash kernel and the pure-jnp reference are interchangeable.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kops
from repro.models.common import (ModelConfig, Params, apply_rope, dense_init,
                                 split_keys)
from repro.models.sharding import constrain


# ----------------------------------------------------------------------
# Params
# ----------------------------------------------------------------------
def init_attention(cfg: ModelConfig, key, cross: bool = False,
                   kv_d_model: int | None = None) -> Params:
    d, H, K, hd = cfg.d_model, cfg.num_heads, cfg.kv_heads, cfg.head_size
    kv_d = kv_d_model or d
    ks = split_keys(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], (d, H, hd), cfg.dtype, in_axis_size=d),
        "wk": dense_init(ks[1], (kv_d, K, hd), cfg.dtype, in_axis_size=kv_d),
        "wv": dense_init(ks[2], (kv_d, K, hd), cfg.dtype, in_axis_size=kv_d),
        "wo": dense_init(ks[3], (H, hd, d), cfg.dtype, in_axis_size=H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), cfg.dtype)
        p["bk"] = jnp.zeros((K, hd), cfg.dtype)
        p["bv"] = jnp.zeros((K, hd), cfg.dtype)
    return p


def _project_qkv(cfg: ModelConfig, p: Params, x: jax.Array,
                 kv_src: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_src, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return q, k, v


# ----------------------------------------------------------------------
# Full-sequence forward (training / prefill)
# ----------------------------------------------------------------------
def attention_fwd(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                       # (B, S, d)
    positions: jax.Array,               # (B, S)
    *,
    causal: bool = True,
    window: int | None = None,          # sliding window for 'L' blocks
    kv_src: jax.Array | None = None,    # cross-attention source (B, S_kv, d_kv)
    kv_positions: jax.Array | None = None,
    use_rope: bool = True,
    impl: str = "auto",
) -> jax.Array:
    cross = kv_src is not None
    src = kv_src if cross else x
    q, k, v = _project_qkv(cfg, p, x, src)
    if use_rope and not cross:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions if kv_positions is None else kv_positions,
                       cfg.rope_theta)
    kpos = kv_positions if kv_positions is not None else positions
    out = kops.attention(q, k, v,
                         q_positions=positions, kv_positions=kpos,
                         causal=causal and not cross, window=window,
                         impl=impl)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ----------------------------------------------------------------------
# KV-cache decode (serve_step): one token against a seq_len cache
# ----------------------------------------------------------------------
def init_kv_cache(cfg: ModelConfig, batch: int, seq_len: int,
                  window: int | None = None) -> Params:
    S = min(seq_len, window) if window else seq_len
    K, hd = cfg.kv_heads, cfg.head_size
    return {"k": jnp.zeros((batch, S, K, hd), cfg.dtype),
            "v": jnp.zeros((batch, S, K, hd), cfg.dtype)}


def decode_attention(
    cfg: ModelConfig,
    p: Params,
    x: jax.Array,                  # (B, 1, d)
    cache: Params,                 # {"k","v"}: (B, S_cache, K, hd)
    pos: jax.Array,                # scalar int32: index of the new token
    *,
    window: int | None = None,
    use_rope: bool = True,
    seq_axis: str | None = None,
) -> tuple[jax.Array, Params]:
    """One decode step.  The cache may be a ring buffer (``window``) or
    the full sequence; when ``seq_axis`` is given the cache's sequence
    dimension is sharded over that mesh axis and attention combines
    per-shard flash partials with collectives (distributed
    flash-decode — used by the 500k-token shape)."""
    q, k_new, v_new = _project_qkv(cfg, p, x, x)
    if use_rope:
        posb = jnp.broadcast_to(pos[None, None], (x.shape[0], 1))
        q = apply_rope(q, posb, cfg.rope_theta)
        k_new = apply_rope(k_new, posb, cfg.rope_theta)

    cache_len = cache["k"].shape[1]
    # Windowed 'L' blocks keep a ring buffer of the last ``window``
    # tokens; full-attention blocks index the absolute position.
    slot = pos % cache_len if window else pos

    # Decode activations are tiny (one token); force them replicated
    # over the model axis so the *sequence-sharded* cache layout wins —
    # otherwise XLA head-shards q and all-gathers the full f32 cache
    # per layer (measured 103 GB/step on internlm2; EXPERIMENTS.md
    # §Perf iteration 6).
    q = constrain(q, "batch", None, None, None)
    k_new = constrain(k_new, "batch", None, None, None)
    v_new = constrain(v_new, "batch", None, None, None)

    if seq_axis is None:
        # One-hot write instead of dynamic_update_slice: a DUS at a
        # *dynamic* index along the sequence dim forces XLA to
        # all-gather a sequence-sharded cache (measured 103 GB/step);
        # the where() is elementwise and stays local on every shard.
        kv_idx = jnp.arange(cache_len)
        onehot = (kv_idx == slot)[None, :, None, None]
        k = jnp.where(onehot, k_new.astype(cache["k"].dtype), cache["k"])
        v = jnp.where(onehot, v_new.astype(cache["v"].dtype), cache["v"])
        if window:
            valid = (kv_idx <= slot) | (pos >= cache_len)
        else:
            valid = kv_idx <= pos
        out = kops.decode_attention(q, k, v, valid)
        new_cache = {"k": k, "v": v}
    else:
        out, new_cache = _decode_attention_seq_sharded(
            q, k_new, v_new, cache, pos, seq_axis)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache


def _decode_attention_seq_sharded(q, k_new, v_new, cache, pos, seq_axis):
    """Body is called inside shard_map: cache holds a contiguous slice of
    the sequence; combine flash partials with pmax/psum over seq_axis."""
    S_loc = cache["k"].shape[1]
    shard = jax.lax.axis_index(seq_axis)
    offset = shard * S_loc
    # write the new kv into the owning shard's slot
    slot = pos - offset
    in_shard = (slot >= 0) & (slot < S_loc)
    slot_c = jnp.clip(slot, 0, S_loc - 1)
    k_upd = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot_c, axis=1)
    v_upd = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot_c, axis=1)
    k = jnp.where(in_shard, k_upd, cache["k"])
    v = jnp.where(in_shard, v_upd, cache["v"])
    valid = (jnp.arange(S_loc) + offset) <= pos
    # local flash partials
    o, m, l = kops.decode_attention_partials(q, k, v, valid)
    m_glob = jax.lax.pmax(m, seq_axis)
    scale = jnp.exp(m - m_glob)
    o = jax.lax.psum(o * scale[..., None], seq_axis)
    l = jax.lax.psum(l * scale, seq_axis)
    out = o / jnp.maximum(l, 1e-30)[..., None]
    return out.astype(q.dtype), {"k": k, "v": v}
