"""Encoder-decoder (whisper-style): bidirectional encoder over stub
frame embeddings + cross-attending decoder.

The audio frontend (mel-spectrogram + conv downsampling) is a STUB per
the assignment: ``input_specs`` provides precomputed frame embeddings
(B, encoder_seq, d_model); this module implements the transformer
backbone that consumes them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import blocks as B
from repro.models import transformer as T
from repro.models.common import (ModelConfig, Params, apply_norm, dense_init,
                                 init_norm, split_keys)


def init_encoder(cfg: ModelConfig, key) -> Params:
    ks = split_keys(key, cfg.encoder_layers + 1)
    layers = []
    for i in range(cfg.encoder_layers):
        k1, k2 = jax.random.split(ks[i])
        layers.append({"norm1": init_norm(cfg),
                       "attn": attn.init_attention(cfg, k1),
                       "norm2": init_norm(cfg),
                       "mlp": B.init_mlp(cfg, k2)})
    return {"layers": layers, "final_norm": init_norm(cfg)}


def encode(cfg: ModelConfig, params: Params, frames: jax.Array) -> jax.Array:
    """frames: (B, S_enc, d) stub embeddings -> encoder states."""
    x = frames
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    for lp in params["layers"]:
        h = apply_norm(cfg, lp["norm1"], x)
        x = x + attn.attention_fwd(cfg, lp["attn"], h, positions, causal=False)
        h = apply_norm(cfg, lp["norm2"], x)
        x = x + B.mlp_apply(cfg, lp["mlp"], h)
    return apply_norm(cfg, params["final_norm"], x)


def init_encdec(cfg: ModelConfig, key) -> Params:
    k_enc, k_dec = jax.random.split(key)
    return {"encoder": init_encoder(cfg, k_enc),
            "decoder": T.init_lm(cfg, k_dec)}


def forward(cfg: ModelConfig, params: Params, frames: jax.Array,
            tokens: jax.Array, *, remat: bool = False):
    """Full enc-dec forward: (frames, decoder tokens) -> logits."""
    enc = encode(cfg, params["encoder"], frames)
    return T.forward(cfg, params["decoder"], tokens, encoder_out=enc,
                     remat=remat)


def loss_fn(cfg: ModelConfig, params: Params, frames: jax.Array,
            tokens: jax.Array, labels: jax.Array, *, remat: bool = False):
    enc = encode(cfg, params["encoder"], frames)
    return T.loss_fn(cfg, params["decoder"], tokens, labels,
                     encoder_out=enc, remat=remat)


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                encoder_states: jax.Array, token: jax.Array, pos: jax.Array):
    """Serve step: encoder states are computed once at request admission
    and threaded through decode."""
    return T.decode_step(cfg, params["decoder"], cache, token, pos,
                         encoder_out=encoder_states)
