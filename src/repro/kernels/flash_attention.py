"""Pallas TPU flash attention (causal / sliding-window, GQA).

Blockwise softmax attention with explicit BlockSpec VMEM tiling:
grid = (batch, q_heads, q_blocks, kv_blocks), the kv dimension
innermost/sequential, with running max / sum / accumulator scratch in
VMEM (the standard online-softmax flash schedule).  GQA is handled in
the k/v index maps (``h -> h // group``), so KV blocks are fetched
once per group position without materializing expanded heads in HBM.

Causal + window block skipping: fully-masked kv blocks are skipped at
grid level (``@pl.when``), which for sliding-window layers (gemma3 'L'
blocks) makes the kernel O(S * window) instead of O(S^2) — the TPU
adaptation of the sub-quadratic requirement for the long-context
shapes.

Validated against :func:`repro.kernels.ref.attention` in interpret
mode (CPU) over shape/dtype sweeps; ``ops.attention`` routes here on
TPU backends.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  block_q: int, block_k: int, num_kv_blocks: int,
                  causal: bool, window: int | None, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = ik * block_k
    # Block-level skip: entirely above the causal diagonal, or entirely
    # left of the sliding window.
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window is not None:
        run &= (k_start + block_k - 1) >= (q_start - window + 1)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                  # (bq,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1)
        acc_scr[...] = (acc_scr[...] * corr[:, None]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ()))))
        m_scr[...] = m_new

    @pl.when(ik == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, S, H, hd); k, v: (B, S, K, hd) with H % K == 0.
    Self-attention (q and kv positions aligned).  Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    K = k.shape[2]
    if H % K:
        raise ValueError(f"H={H} not a multiple of K={K}")
    G = H // K
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    if S % block_q or S % block_k:
        raise ValueError(f"S={S} must divide block sizes {block_q}/{block_k}")
    nq, nk = S // block_q, S // block_k
    scale = 1.0 / math.sqrt(hd)

    qT = jnp.moveaxis(q, 2, 1)      # (B, H, S, hd)
    kT = jnp.moveaxis(k, 2, 1)      # (B, K, S, hd)
    vT = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        causal=causal, window=window, scale=scale)

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qT, kT, vT)
    return jnp.moveaxis(out, 1, 2)
