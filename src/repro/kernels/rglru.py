"""Pallas TPU kernel for the RG-LRU gated linear recurrence
(RecurrentGemma / Griffin):

    log a_t = -c * softplus(Lambda) * sigmoid(r_t)
    h_t     = a_t h_{t-1} + sqrt(1 - a_t^2) * (sigmoid(i_t) * x_t)

The recurrence is elementwise over the width dimension (VPU work), so
the TPU schedule tiles (time_block, width_block) into VMEM, runs the
time recurrence as an in-register ``fori_loop`` over rows, and carries
``h`` across sequential time blocks in scratch.  Width blocks ride a
parallel grid dimension (lane-aligned, 128 multiple).

Oracle: :func:`repro.kernels.ref.rglru`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ref import RGLRU_C

DEFAULT_BLOCK_T = 128
DEFAULT_BLOCK_W = 128


def _rglru_kernel(x_ref, r_ref, i_ref, lam_ref, h0_ref, o_ref, hout_ref,
                  h_scr, *, block_t: int, num_t_blocks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_scr[...] = h0_ref[...].astype(jnp.float32)

    x = x_ref[0].astype(jnp.float32)             # (T, W)
    r = r_ref[0].astype(jnp.float32)
    i = i_ref[0].astype(jnp.float32)
    lam = lam_ref[...].astype(jnp.float32)       # (1, W)

    log_a_base = -RGLRU_C * jax.nn.softplus(lam)     # (1, W)
    log_a = log_a_base * jax.nn.sigmoid(r)           # (T, W)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    gated = jax.nn.sigmoid(i) * x * mult             # (T, W)

    def body(t, carry):
        h, out = carry
        h = a[t] * h + gated[t]
        out = out.at[t].set(h)
        return h, out

    h, out = jax.lax.fori_loop(
        0, block_t, body, (h_scr[0], jnp.zeros_like(x)))
    o_ref[0] = out.astype(o_ref.dtype)
    h_scr[0] = h

    @pl.when(it == num_t_blocks - 1)
    def _final():
        hout_ref[0] = h.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "block_w", "interpret"))
def rglru(x, r_gate, i_gate, lam, h0=None, *,
          block_t: int = DEFAULT_BLOCK_T, block_w: int = DEFAULT_BLOCK_W,
          interpret: bool = False):
    """x, r_gate, i_gate: (B, S, W); lam: (W,); h0: (B, W) f32.
    Returns (out (B,S,W), h_final (B,W))."""
    B, S, W = x.shape
    block_t = min(block_t, S)
    block_w = min(block_w, W)
    if S % block_t or W % block_w:
        raise ValueError(f"S={S}/W={W} not multiples of blocks "
                         f"{block_t}/{block_w}")
    nt, nw = S // block_t, W // block_w
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    lam2 = lam.reshape(1, W)

    kernel = functools.partial(_rglru_kernel, block_t=block_t,
                               num_t_blocks=nt)
    out, hout = pl.pallas_call(
        kernel,
        grid=(B, nw, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda b, iw, it: (b, it, iw)),
            pl.BlockSpec((1, block_t, block_w), lambda b, iw, it: (b, it, iw)),
            pl.BlockSpec((1, block_t, block_w), lambda b, iw, it: (b, it, iw)),
            pl.BlockSpec((1, block_w), lambda b, iw, it: (0, iw)),
            pl.BlockSpec((1, block_w), lambda b, iw, it: (b, iw)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_t, block_w), lambda b, iw, it: (b, it, iw)),
            pl.BlockSpec((1, block_w), lambda b, iw, it: (b, iw)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, W), x.dtype),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((1, block_w), jnp.float32)],
        interpret=interpret,
    )(x, r_gate, i_gate, lam2, h0)
    return out, hout
