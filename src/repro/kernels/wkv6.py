"""Pallas TPU kernel for the RWKV6 (Finch) wkv scan with
data-dependent decay.

Recurrence (per head, state S in R^{hd x hd}):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)

A naive port would loop token-by-token — hostile to the MXU.  The TPU
adaptation reformulates each time *block* in log-decay space so the
intra-block part becomes two matmuls (the chunked linear-attention
trick):

    L_t   = sum_{j<=t} log w_j              (per channel, within block)
    r'_t  = r_t * exp(L_{t-1}),   k'_i = k_i * exp(-L_i)
    intra = tril_strict(r' k'^T) V  + diag-bonus (u term)
    cross = r' @ S_prev
    S_new = exp(L_last) * S_prev + (k * exp(L_last - L))^T V

Grid = (batch, heads, time_blocks) with the time dimension sequential
and the running state in VMEM scratch; the carried initial state makes
the same kernel serve chunked prefill and decode.  Block size is kept
small (64) so exp(-L) stays in fp32 range — strongly-decayed channels
underflow to zero exactly as they vanish mathematically.

Oracle: :func:`repro.kernels.ref.wkv6`.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_T = 64


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref,
                 o_ref, sout_ref, state_scr, *,
                 block_t: int, num_t_blocks: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)          # (T, hd)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    w = w_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)             # (hd,)
    S = state_scr[...]                           # (hd, hd)

    logw = jnp.log(jnp.maximum(w, 1e-38))
    L = jnp.cumsum(logw, axis=0)                 # (T, hd), L_t = sum_{j<=t}
    L_prev = L - logw                            # L_{t-1}
    r_scaled = r * jnp.exp(L_prev)
    k_scaled = k * jnp.exp(-L)

    # intra-block strict-lower attention + diagonal u-bonus
    scores = jax.lax.dot_general(r_scaled, k_scaled,
                                 (((1,), (1,)), ((), ())))      # (T, T)
    ti = jax.lax.broadcasted_iota(jnp.int32, (block_t, block_t), 0)
    tj = jax.lax.broadcasted_iota(jnp.int32, (block_t, block_t), 1)
    scores = jnp.where(tj < ti, scores, 0.0)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)                 # (T,)
    o = (jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())))
         + diag[:, None] * v
         + jax.lax.dot_general(r * jnp.exp(L_prev), S,
                               (((1,), (0,)), ((), ()))))
    o_ref[0, 0] = o.astype(o_ref.dtype)

    # state to next block
    decay_all = jnp.exp(L[-1])                                  # (hd,)
    k_tail = k * jnp.exp(L[-1][None, :] - L)                    # (T, hd)
    S_new = (decay_all[:, None] * S
             + jax.lax.dot_general(k_tail, v, (((0,), (0,)), ((), ()))))
    state_scr[...] = S_new

    @pl.when(it == num_t_blocks - 1)
    def _final():
        sout_ref[0, 0] = S_new.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def wkv6(r, k, v, w, u, state=None, *, block_t: int = DEFAULT_BLOCK_T,
         interpret: bool = False):
    """r,k,v,w: (B, S, H, hd); u: (H, hd); state: (B, H, hd, hd) f32.
    Returns (out (B,S,H,hd), final_state (B,H,hd,hd))."""
    B, S, H, hd = r.shape
    block_t = min(block_t, S)
    if S % block_t:
        raise ValueError(f"S={S} not a multiple of block_t={block_t}")
    nt = S // block_t
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)

    def tr(x):
        return jnp.moveaxis(x, 2, 1)             # (B, H, S, hd)

    kernel = functools.partial(_wkv6_kernel, block_t=block_t, num_t_blocks=nt)
    out, sout = pl.pallas_call(
        kernel,
        grid=(B, H, nt),
        in_specs=[
            pl.BlockSpec((1, 1, block_t, hd), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, block_t, hd), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, block_t, hd), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, block_t, hd), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, hd), lambda b, h, it: (h, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, it: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_t, hd), lambda b, h, it: (b, h, it, 0)),
            pl.BlockSpec((1, 1, hd, hd), lambda b, h, it: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), r.dtype),
            jax.ShapeDtypeStruct((B, H, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(tr(r), tr(k), tr(v), tr(w), u, state)
    return jnp.moveaxis(out, 1, 2), sout
