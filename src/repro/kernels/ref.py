"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernel tests assert against
(``interpret=True`` sweeps) and the default implementation on CPU.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# ----------------------------------------------------------------------
# Attention (training / prefill): GQA, causal and/or sliding window.
# q: (B, Sq, H, hd)  k, v: (B, Skv, K, hd) with H % K == 0.
# ----------------------------------------------------------------------
def repeat_kv(k, n: int):
    """(B, S, K, hd) -> (B, S, K*n, hd).  GQA via kv repetition: the
    sharded q-head dimension stays intact (no (K, G) reshape, which
    would redistribute a head-sharded tensor across devices)."""
    if n == 1:
        return k
    B, S, K, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (B, S, K, n, hd)) \
        .reshape(B, S, K * n, hd)


def attention(q, k, v, *, q_positions=None, kv_positions=None,
              causal=True, window=None):
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    k = repeat_kv(k, H // K)
    v = repeat_kv(v, H // K)
    if q_positions is None:
        q_positions = jnp.broadcast_to(jnp.arange(Sq), (B, Sq))
    if kv_positions is None:
        kv_positions = jnp.broadcast_to(jnp.arange(k.shape[1]), (B, k.shape[1]))
    scores = jnp.einsum("bqhd,bshd->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    qp = q_positions[:, None, :, None]
    kp = kv_positions[:, None, None, :]
    mask = jnp.ones_like(scores, dtype=bool)
    if causal:
        mask &= kp <= qp
    if window is not None:
        mask &= kp > qp - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqs,bshd->bqhd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ----------------------------------------------------------------------
# Decode attention: one query token vs a cache, with validity mask.
# ----------------------------------------------------------------------
def decode_attention(q, k, v, valid):
    """q: (B,1,H,hd); k,v: (B,S,K,hd); valid: (S,) bool."""
    o, m, l = decode_attention_partials(q, k, v, valid)
    return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)


def decode_attention_partials(q, k, v, valid):
    """Unnormalized flash partials (o, m, l) for cross-shard combining.

    Grouped formulation (no kv broadcast): in decode q is tiny and
    kept replicated, so reshaping its head dim is free, while
    broadcasting the seq-sharded cache to H heads would force XLA to
    all-gather it (EXPERIMENTS.md §Perf iteration 6)."""
    B, _, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qh = q.reshape(B, K, G, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qh.astype(jnp.float32),
                        k.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    m = jnp.max(scores, axis=-1)                      # (B,K,G)
    p = jnp.exp(scores - m[..., None])
    p = jnp.where(valid[None, None, None, :], p, 0.0)
    l = jnp.sum(p, axis=-1)                           # (B,K,G)
    o = jnp.einsum("bkgs,bskh->bkgh", p, v.astype(jnp.float32))
    return (o.reshape(B, 1, H, hd), m.reshape(B, 1, H),
            l.reshape(B, 1, H))


# ----------------------------------------------------------------------
# RWKV6 "wkv" linear-attention scan with data-dependent decay (Finch).
#   S_t = diag(w_t) S_{t-1} + k_t v_t^T
#   o_t = r_t (S_{t-1} + diag(u) k_t v_t^T)
# r,k,w: (B, S, Hd, hd); v: (B, S, H, hd); u: (H, hd); per-head state
# S: (B, H, hd, hd).
# ----------------------------------------------------------------------
def wkv6(r, k, v, w, u, state=None):
    B, S, H, hd = r.shape
    if state is None:
        state = jnp.zeros((B, H, hd, hd), jnp.float32)

    def step(S_prev, inp):
        rt, kt, vt, wt = inp                       # (B,H,hd) each
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,hd,hd)
        o = jnp.einsum("bhk,bhkv->bhv", rt, S_prev + u[None, :, :, None] * kv)
        S_new = wt[..., :, None] * S_prev + kv
        return S_new, o

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0) for t in (r, k, v, w))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), state


# ----------------------------------------------------------------------
# RG-LRU (RecurrentGemma / Griffin):
#   a_t = exp(-c * softplus(Lambda) * sigmoid(r_t))
#   h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
# x, r_gate, i_gate: (B, S, W); lam: (W,); h: (B, W).
# ----------------------------------------------------------------------
RGLRU_C = 8.0


def rglru(x, r_gate, i_gate, lam, h0=None):
    B, S, W = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    log_a_base = -RGLRU_C * jax.nn.softplus(lam.astype(jnp.float32))

    def step(h, inp):
        xt, rt, it = inp
        log_a = log_a_base * jax.nn.sigmoid(rt)
        a = jnp.exp(log_a)
        gated = jax.nn.sigmoid(it) * xt
        mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
        h_new = a * h + mult * gated
        return h_new, h_new

    xs = tuple(jnp.moveaxis(t.astype(jnp.float32), 1, 0)
               for t in (x, r_gate, i_gate))
    h, out = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(out, 0, 1).astype(x.dtype), h
