"""Memory-efficient chunked attention (FlashAttention-2 schedule in
pure jnp) with a custom VJP.

The naive reference materializes (B, H, S, S) scores — at 32k tokens
that is tens of GB per device and dominates the dry-run's temp memory.
This implementation scans kv blocks with online-softmax state in the
forward pass and *recomputes* block scores in the backward pass
(saving only ``out`` and the logsumexp), so both passes hold
O(block_q x block_k) scratch per (batch, head).  XLA maps the block
matmuls straight onto the MXU; the Pallas kernel in
``flash_attention.py`` remains the hand-tiled serving fast path and
shares its oracle with this module.

Supports GQA, causal masks and sliding windows.  Shapes follow the
model layout: q (B, S, H, hd), k/v (B, S, K, hd).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30
DEFAULT_BLOCK = 512


def _mask(qpos, kpos, causal, window):
    qp = qpos[..., :, None]
    kp = kpos[..., None, :]
    m = jnp.ones(jnp.broadcast_shapes(qp.shape, kp.shape), jnp.bool_)
    if causal:
        m &= kp <= qp
    if window is not None:
        m &= kp > qp - window
    return m


def _fwd_inner(q, k, v, q0, k0, causal, window, scale):
    """One (q-block, all kv-blocks) pass.  q: (B,K,G,bq,hd);
    k, v: (nk, B,K,bk,hd).  Returns (out, lse)."""
    B, K, G, bq, hd = q.shape
    nk, _, _, bk, _ = k.shape
    qpos = q0 + jnp.arange(bq)

    def body(carry, kv):
        m_run, l_run, acc = carry
        kb, vb, ik = kv
        kpos = k0 + ik * bk + jnp.arange(bk)
        s = jnp.einsum("bkgqh,bksh->bkgqs", q, kb.astype(jnp.float32)) * scale
        msk = _mask(qpos[None, None, None], kpos[None, None, None],
                    causal, window)
        s = jnp.where(msk, s, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(msk, p, 0.0)
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bksh->bkgqh", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc), None

    init = (jnp.full((B, K, G, bq), NEG_INF, jnp.float32),
            jnp.zeros((B, K, G, bq), jnp.float32),
            jnp.zeros((B, K, G, bq, hd), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(body, init, (k, v, jnp.arange(nk)))
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    return out, lse


def _split_blocks(x, block):
    """(B, S, K, hd) -> (n, B, K, block, hd)"""
    B, S, K, hd = x.shape
    n = S // block
    return jnp.moveaxis(x.reshape(B, n, block, K, hd), (1, 3), (0, 2))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def chunked_attention(q, k, v, causal=True, window=None,
                      block_q: int = DEFAULT_BLOCK,
                      block_k: int = DEFAULT_BLOCK):
    out, _ = _chunked_fwd(q, k, v, causal, window, block_q, block_k)
    return out


def _chunked_fwd(q, k, v, causal, window, block_q, block_k):
    from repro.kernels.ref import repeat_kv
    B, S, H, hd = q.shape
    # GQA via kv repetition: keeps the (possibly sharded) q-head dim
    # intact instead of reshaping it to (K, G), which would force a
    # cross-device redistribution whenever K doesn't divide the mesh
    # axis (EXPERIMENTS.md §Perf iteration 1).
    k = repeat_kv(k, H // k.shape[2])
    v = repeat_kv(v, H // v.shape[2])
    K = H
    G = 1
    bq = min(block_q, S)
    bk = min(block_k, S)
    if S % bq or S % bk:
        raise ValueError(f"S={S} must be a multiple of blocks {bq}/{bk}")
    nq = S // bq
    scale = 1.0 / math.sqrt(hd)
    qb = jnp.moveaxis(q.reshape(B, nq, bq, K, G, hd), (1, 3, 4), (0, 2, 3)) \
        .astype(jnp.float32)                       # (nq, B, K, G, bq, hd)
    kb = _split_blocks(k, bk)
    vb = _split_blocks(v, bk)

    def per_q(args):
        qi, iq = args
        return _fwd_inner(qi, kb, vb, iq * bq, 0, causal, window, scale)

    out_b, lse_b = jax.lax.map(per_q, (qb, jnp.arange(nq)))
    # (nq, B, K, G, bq, hd) -> (B, S, H, hd)
    out = jnp.moveaxis(out_b, (0, 2, 3), (1, 3, 4)).reshape(B, S, H, hd)
    lse = jnp.moveaxis(lse_b, (0, 2, 3), (1, 3, 4)).reshape(B, S, H)
    return out.astype(q.dtype), lse


def _vjp_fwd(q, k, v, causal, window, block_q, block_k):
    out, lse = _chunked_fwd(q, k, v, causal, window, block_q, block_k)
    return out, (q, k, v, out, lse)


def _vjp_bwd(causal, window, block_q, block_k, res, dout):
    from repro.kernels.ref import repeat_kv
    q, k, v, out, lse = res
    B, S, H, hd = q.shape
    K_orig = k.shape[2]
    G_orig = H // K_orig
    k = repeat_kv(k, G_orig)
    v = repeat_kv(v, G_orig)
    K = H
    G = 1
    bq = min(block_q, S)
    bk = min(block_k, S)
    nq, nk = S // bq, S // bk
    scale = 1.0 / math.sqrt(hd)

    def shape_q(x, last):
        return jnp.moveaxis(x.reshape(B, nq, bq, K, G, *last),
                            (1, 3, 4), (0, 2, 3)).astype(jnp.float32)

    qb = shape_q(q, (hd,))                          # (nq,B,K,G,bq,hd)
    dob = shape_q(dout, (hd,))
    outb = shape_q(out, (hd,))
    lseb = shape_q(lse, ())                         # (nq,B,K,G,bq)
    kb = _split_blocks(k, bk).astype(jnp.float32)   # (nk,B,K,bk,hd)
    vb = _split_blocks(v, bk).astype(jnp.float32)
    delta = jnp.sum(dob * outb, axis=-1)            # (nq,B,K,G,bq)

    def scores(qi, kj, iq, ik):
        s = jnp.einsum("bkgqh,bksh->bkgqs", qi, kj) * scale
        qpos = iq * bq + jnp.arange(bq)
        kpos = ik * bk + jnp.arange(bk)
        msk = _mask(qpos[None, None, None], kpos[None, None, None],
                    causal, window)
        return jnp.where(msk, s, NEG_INF), msk

    # dq: per q block, scan kv blocks
    def dq_one(args):
        qi, doi, lsei, di, iq = args

        def body(dq, kv):
            kj, vj, ik = kv
            s, msk = scores(qi, kj, iq, ik)
            p = jnp.where(msk, jnp.exp(s - lsei[..., None]), 0.0)
            dp = jnp.einsum("bkgqh,bksh->bkgqs", doi, vj)
            ds = p * (dp - di[..., None]) * scale
            return dq + jnp.einsum("bkgqs,bksh->bkgqh", ds, kj), None

        dq0 = jnp.zeros_like(qi)
        dq, _ = jax.lax.scan(body, dq0, (kb, vb, jnp.arange(nk)))
        return dq

    dqb = jax.lax.map(dq_one, (qb, dob, lseb, delta, jnp.arange(nq)))

    # dk, dv: per kv block, scan q blocks
    def dkv_one(args):
        kj, vj, ik = args

        def body(carry, qs):
            dk, dv = carry
            qi, doi, lsei, di, iq = qs
            s, msk = scores(qi, kj, iq, ik)
            p = jnp.where(msk, jnp.exp(s - lsei[..., None]), 0.0)
            dv = dv + jnp.einsum("bkgqs,bkgqh->bksh", p, doi)
            dp = jnp.einsum("bkgqh,bksh->bkgqs", doi, vj)
            ds = p * (dp - di[..., None]) * scale
            dk = dk + jnp.einsum("bkgqs,bkgqh->bksh", ds, qi)
            return (dk, dv), None

        init = (jnp.zeros_like(kj), jnp.zeros_like(vj))
        (dk, dv), _ = jax.lax.scan(
            body, init, (qb, dob, lseb, delta, jnp.arange(nq)))
        return dk, dv

    dkb, dvb = jax.lax.map(dkv_one, (kb, vb, jnp.arange(nk)))

    dq = jnp.moveaxis(dqb, (0, 2, 3), (1, 3, 4)).reshape(B, S, H, hd)

    def unsplit(x):
        full = jnp.moveaxis(x, (0, 2), (1, 3)).reshape(B, S, H, hd)
        if G_orig == 1:
            return full
        # reduce repeated-kv gradients back onto the true kv heads
        return full.reshape(B, S, K_orig, G_orig, hd).sum(axis=3)

    return (dq.astype(q.dtype), unsplit(dkb).astype(k.dtype),
            unsplit(dvb).astype(v.dtype))


chunked_attention.defvjp(_vjp_fwd, _vjp_bwd)
