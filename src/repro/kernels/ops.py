"""Jit-ready kernel entry points with implementation dispatch.

``impl``:
  * ``"ref"``     — pure-jnp oracle (:mod:`repro.kernels.ref`)
  * ``"pallas"``  — Pallas TPU kernel (``interpret=True`` off-TPU)
  * ``"auto"``    — pallas on TPU backends, ref elsewhere (CPU dry-runs
    lower the jnp path; the TPU deployment takes the kernel path)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except RuntimeError:
        return False


def _resolve(impl: str) -> str:
    if impl == "auto":
        return "pallas" if _on_tpu() else "ref"
    return impl


# Self-attention sequences at or above this length route to the
# chunked (flash-schedule) implementation: the naive path materializes
# (B, H, S, S) scores, which at 32k+ dominates device memory.
CHUNKED_ATTENTION_MIN_SEQ = 2048


def attention(q, k, v, *, q_positions=None, kv_positions=None, causal=True,
              window=None, impl: str = "auto"):
    S = q.shape[1]
    aligned_self = q.shape[1] == k.shape[1] and causal
    if _resolve(impl) == "pallas":
        from repro.kernels import flash_attention as fa
        # The Pallas kernel covers self-attention with equal q/kv lengths
        # and row-aligned positions; fall back otherwise.
        if (aligned_self and S % fa.DEFAULT_BLOCK_Q == 0
                and q.shape[-1] % 128 == 0):
            return fa.flash_attention(q, k, v, causal=causal, window=window,
                                      interpret=not _on_tpu())
    if (impl in ("auto", "chunked") and aligned_self
            and S >= CHUNKED_ATTENTION_MIN_SEQ):
        from repro.kernels import chunked_attention as ca
        block = 512 if S % 512 == 0 else next(
            b for b in (256, 128, 64, 1) if S % b == 0)
        return ca.chunked_attention(q, k, v, causal, window, block, block)
    return ref.attention(q, k, v, q_positions=q_positions,
                         kv_positions=kv_positions, causal=causal,
                         window=window)


def decode_attention(q, k, v, valid, impl: str = "auto"):
    return ref.decode_attention(q, k, v, valid)


def decode_attention_partials(q, k, v, valid, impl: str = "auto"):
    return ref.decode_attention_partials(q, k, v, valid)


def wkv6(r, k, v, w, u, state=None, impl: str = "auto"):
    if _resolve(impl) == "pallas":
        from repro.kernels import wkv6 as wk
        if r.shape[1] % wk.DEFAULT_BLOCK_T == 0:
            return wk.wkv6(r, k, v, w, u, state=state,
                           interpret=not _on_tpu())
    return ref.wkv6(r, k, v, w, u, state=state)


def rglru(x, r_gate, i_gate, lam, h0=None, impl: str = "auto"):
    if _resolve(impl) == "pallas":
        from repro.kernels import rglru as rg
        if x.shape[1] % rg.DEFAULT_BLOCK_T == 0 and x.shape[2] % 128 == 0:
            return rg.rglru(x, r_gate, i_gate, lam, h0=h0,
                            interpret=not _on_tpu())
    return ref.rglru(x, r_gate, i_gate, lam, h0=h0)
