"""HLO text analysis: collective-bytes extraction with while-loop
trip-count scaling.

``compiled.cost_analysis()`` visits a ``while`` body once, so any
collective (or flop) inside the layer scan is under-counted by the
trip count.  We therefore parse the optimized HLO:

* find every computation that is referenced as a ``while`` body,
* sum the result bytes of every collective op per computation,
* scale loop-body computations by the known scan trip count
  (``num_units`` for the layer scan of this framework's models).

This gives the ``collective_bytes`` term of the roofline.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    # fp8 families (HLO spells them f8e...): all one byte.  Without
    # these, fp8 collectives/buffers silently drop out of
    # ``collective_bytes`` — the parser skips unknown dtypes.
    "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2": 1, "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?\s*->", re.M)
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w\.\-]+)")


def _shape_bytes(text: str) -> float:
    """Sum bytes of every dtype[dims] occurrence in ``text``."""
    total = 0.0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: dict[str, float] = field(default_factory=lambda: defaultdict(float))
    count_by_op: dict[str, int] = field(default_factory=lambda: defaultdict(int))

    @property
    def total_bytes(self) -> float:
        return sum(self.bytes_by_op.values())

    @property
    def total_count(self) -> int:
        return sum(self.count_by_op.values())

    def to_dict(self) -> dict:
        return {"total_bytes": self.total_bytes,
                "total_count": self.total_count,
                "bytes_by_op": dict(self.bytes_by_op),
                "count_by_op": dict(self.count_by_op)}


def split_computations(hlo: str) -> dict[str, str]:
    """Split HLO module text into named computation bodies."""
    comps: dict[str, str] = {}
    cur_name, cur_lines = None, []
    for line in hlo.splitlines():
        if line and not line[0].isspace() and ("->" in line or
                                               line.startswith("ENTRY")):
            if cur_name:
                comps[cur_name] = "\n".join(cur_lines)
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)", line.strip())
            cur_name = "ENTRY" if line.startswith("ENTRY") else \
                (m.group(1) if m else None)
            cur_lines = [line]
        elif cur_name:
            cur_lines.append(line)
    if cur_name:
        comps[cur_name] = "\n".join(cur_lines)
    return comps


def while_bodies(hlo: str) -> set[str]:
    return set(_BODY_RE.findall(hlo))


# The while operand may carry its full tuple type — optimized HLO
# prints ``while((s32[], f32[...]{...}) %tuple.69), condition=...`` —
# so the operand match must be non-greedy up to ", condition=", not
# "anything but a paren".  The trailing group captures the rest of the
# line (metadata / backend_config) for the trip-count annotation.
_WHILE_RE = re.compile(r"while\(.*?\),\s*condition=%?([\w\.\-]+),\s*"
                       r"body=%?([\w\.\-]+)(.*)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_KNOWN_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')


def _trip_count(cond_body: str, default: int) -> int:
    """Scan-generated while conditions compare the induction variable
    against a constant trip count; take the largest **positive** s32
    constant (countdown loops compare against 0, which is never a trip
    count)."""
    consts = [int(c) for c in _CONST_RE.findall(cond_body)]
    consts = [c for c in consts if c > 0]
    return max(consts) if consts else default


def computation_multipliers(hlo: str, default_trip: int = 1) -> dict[str, int]:
    """Execution-count multiplier per computation: while bodies run
    trip-count times (nested loops multiply); fusions/calls inherit
    their caller's multiplier."""
    comps = split_computations(hlo)
    mult: dict[str, int] = {name: 1 for name in comps}

    # iterate to fixpoint (call graphs are shallow)
    for _ in range(6):
        changed = False
        for name, body in comps.items():
            m = mult.get(name, 1)
            # whiles inside this computation; XLA's own
            # ``known_trip_count`` annotation is authoritative when
            # present, the condition's comparison constant otherwise
            for cond, wbody, rest in _WHILE_RE.findall(body):
                known = _KNOWN_TRIP_RE.search(rest)
                trip = int(known.group(1)) if known \
                    else _trip_count(comps.get(cond, ""), default_trip)
                new = m * max(trip, 1)
                if wbody in mult and new > mult[wbody]:
                    mult[wbody] = new
                    changed = True
                if cond in mult and m > mult[cond]:
                    mult[cond] = m
                    changed = True
            # plain calls / fusions inherit the caller's multiplier
            # (while bodies already carry m*trip >= m, so max() keeps it)
            for callee in _CALLS_RE.findall(body):
                if callee in mult and m > mult[callee]:
                    mult[callee] = m
                    changed = True
        if not changed:
            break
    return mult


def _comp_collectives(body: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in body.splitlines():
        for op in COLLECTIVE_OPS:
            # match "op(" and "op-start(" but not "-done(" (the -done
            # half of an async pair carries the same bytes; count once)
            if f" {op}(" in line or f" {op}-start(" in line:
                # result type sits between '=' and the op name:
                #   %name = bf16[16,1152]{1,0} all-gather(...)
                rhs = line.split("=", 1)[1] if "=" in line else line
                result_ty = rhs.split(op)[0]
                stats.bytes_by_op[op] += _shape_bytes(result_ty)
                stats.count_by_op[op] += 1
                break
    return stats


def collective_stats(hlo: str, loop_trip_count: int = 1) -> CollectiveStats:
    """Aggregate collective bytes over the module, scaling each
    computation by its execution count (parsed while trip counts;
    ``loop_trip_count`` is the fallback for conditions whose constant
    cannot be recovered)."""
    comps = split_computations(hlo)
    mults = computation_multipliers(hlo, default_trip=loop_trip_count)

    total = CollectiveStats()
    for name, body in comps.items():
        st = _comp_collectives(body)
        mult = mults.get(name, 1)
        for op, b in st.bytes_by_op.items():
            total.bytes_by_op[op] += b * mult
            total.count_by_op[op] += st.count_by_op[op] * mult
    return total
