"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state.  The dry-run entry point sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any
jax import; tests and benches see the real single device.
"""
from __future__ import annotations

import jax

try:  # AxisType landed after jax 0.4.x; older jax implies Auto axes.
    from jax.sharding import AxisType

    def _axis_kwargs(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

    def _axis_kwargs(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: one pod = 16x16 chips; two pods add a leading DCN axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_cpu_mesh(n_data: int = 1, n_model: int = 1):
    """Small host mesh for tests / CPU validation runs."""
    axes = ("data", "model")
    return jax.make_mesh((n_data, n_model), axes, **_axis_kwargs(2))


def make_dp_mesh(n: int):
    return jax.make_mesh((n,), ("data",), **_axis_kwargs(1))


def activate_mesh(mesh):
    """Context manager making ``mesh`` the ambient mesh across jax
    versions: ``jax.sharding.set_mesh`` where it exists, else the
    legacy global-mesh context (``with mesh:``) of jax 0.4.x."""
    if hasattr(jax.sharding, "set_mesh"):
        return jax.sharding.set_mesh(mesh)
    return mesh
