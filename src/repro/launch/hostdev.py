"""Forced-host-platform device helpers (the dry-run trick, shared).

jax locks the device count at first backend init, so anything that
wants N CPU "devices" (the multi-pod dry-run, the measurement harness,
comm tests) must put ``--xla_force_host_platform_device_count=N`` into
``XLA_FLAGS`` *before the first jax import* — usually in a fresh
subprocess.  This module is the one place that flag is spelled:

* :func:`host_device_flags` — an ``XLA_FLAGS`` value with the flag
  **appended** to whatever the caller already set (never clobbering
  user flags; an existing count flag is replaced, so the helper is
  idempotent);
* :func:`force_host_device_count` — apply it to ``os.environ`` (call
  before importing jax);
* :func:`child_env` — an environment dict for spawning a measurement /
  dry-run subprocess.

Deliberately jax-free: importing this module must never initialize the
backend the flag is trying to configure.
"""
from __future__ import annotations

import os
import re
from typing import MutableMapping

HOST_DEVICE_FLAG = "--xla_force_host_platform_device_count"

_FLAG_RE = re.compile(re.escape(HOST_DEVICE_FLAG) + r"=\d+")


def host_device_flags(n_devices: int, existing: str | None = None) -> str:
    """``XLA_FLAGS`` value forcing ``n_devices`` host devices.

    ``existing`` (the current ``XLA_FLAGS``, possibly ``None``/empty)
    is preserved verbatim apart from any previous host-device-count
    flag, which is replaced — repeated calls don't accumulate flags
    and user-set flags (e.g. ``--xla_cpu_enable_fast_math``) survive.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices must be >= 1, got {n_devices}")
    flag = f"{HOST_DEVICE_FLAG}={n_devices}"
    if not existing:
        return flag
    kept = re.sub(r"\s+", " ", _FLAG_RE.sub("", existing)).strip()
    return f"{kept} {flag}" if kept else flag


def force_host_device_count(n_devices: int,
                            env: MutableMapping[str, str] | None = None) -> str:
    """Set ``XLA_FLAGS`` in ``env`` (default ``os.environ``) to force
    ``n_devices`` host devices, appending to any existing flags.  Must
    run before the first jax import; returns the value set."""
    env = os.environ if env is None else env
    value = host_device_flags(n_devices, env.get("XLA_FLAGS"))
    env["XLA_FLAGS"] = value
    return value


def child_env(n_devices: int,
              base: MutableMapping[str, str] | None = None) -> dict[str, str]:
    """A copy of ``base`` (default ``os.environ``) with ``XLA_FLAGS``
    forcing ``n_devices`` host devices — for ``subprocess.run(env=...)``
    when the current process already initialized jax."""
    env = dict(os.environ if base is None else base)
    env["XLA_FLAGS"] = host_device_flags(n_devices, env.get("XLA_FLAGS"))
    return env
