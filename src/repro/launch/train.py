"""Training launcher.

Runs real steps on the available devices (CPU in this container; the
reduced configs make that practical) with the full substrate engaged:
prefetching data pipeline, gradient-sync policy, optimizer,
checkpointing — and can emit a paper-format layer trace of the run.

    PYTHONPATH=src python -m repro.launch.train --arch gemma3-1b \
        --reduced --steps 20 --policy wfbp --data-parallel 1
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import save_checkpoint
from repro.comm.ddp import make_ddp_train_step
from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import PrefetchLoader, SyntheticLMDataset
from repro.launch.mesh import make_dp_mesh
from repro.launch.steps import init_params
from repro.models import transformer as T
from repro.optim.sgd import adamw, sgd


def build_argparser():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--full", dest="reduced", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", choices=("sgd", "adamw"), default="sgd")
    ap.add_argument("--policy", default="wfbp",
                    choices=("at_end", "wfbp", "bucketed", "single"))
    ap.add_argument("--data-parallel", type=int, default=0,
                    help="DP world size (0 = all local devices)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="pipeline depth; 0 = blocking I/O (naive S-SGD)")
    ap.add_argument("--io-delay", type=float, default=0.0,
                    help="injected per-batch fetch latency (seconds)")
    ap.add_argument("--checkpoint")
    ap.add_argument("--summary-json")
    ap.add_argument("--log-every", type=int, default=5)
    return ap


def run(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced(num_layers=2)
    if cfg.arch_type in ("audio", "vlm"):
        # the LM backbone trains standalone in this launcher
        import dataclasses
        cfg = dataclasses.replace(cfg, layer_pattern="G", arch_type="dense")

    n_dp = args.data_parallel or jax.local_device_count()
    opt = sgd(args.lr, momentum=0.9) if args.optimizer == "sgd" \
        else adamw(args.lr)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    opt_state = opt.init(params)

    dataset = SyntheticLMDataset(cfg.vocab_size, args.seq,
                                 args.batch, seed=1,
                                 simulate_io_seconds=args.io_delay)
    loader = PrefetchLoader(dataset, depth=args.prefetch)

    if args.policy == "single" or n_dp == 1:
        def step_fn(p, s, batch):
            def loss(p):
                return T.loss_fn(cfg, p, jnp.asarray(batch["tokens"]),
                                 jnp.asarray(batch["labels"]))
            (total, m), grads = jax.value_and_grad(loss, has_aux=True)(p)
            p2, s2 = opt.update(grads, s, p)
            return p2, s2, {"loss": m["loss"], "total_loss": total,
                            "grad_norm": jnp.zeros(())}
        step = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        mesh = make_dp_mesh(n_dp)
        step = make_ddp_train_step(cfg, opt, mesh, sync_policy=args.policy)

    losses, step_times = [], []
    t_prev = time.perf_counter()
    for i, batch in zip(range(args.steps), loader):
        params, opt_state, metrics = step(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        now = time.perf_counter()
        step_times.append(now - t_prev)
        t_prev = now
        losses.append(float(metrics["loss"]))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss {losses[-1]:.4f} "
                  f"({step_times[-1] * 1e3:.1f} ms)", flush=True)
    loader.close()

    if args.checkpoint:
        save_checkpoint(args.checkpoint, params, opt_state, step=args.steps)
        print(f"checkpoint -> {args.checkpoint}")

    warm = step_times[2:] or step_times
    summary = {
        "arch": cfg.name, "steps": args.steps, "world": n_dp,
        "policy": args.policy,
        "loss_first": losses[0], "loss_last": losses[-1],
        "mean_step_s": float(np.mean(warm)),
        "t_io_mean": loader.mean_t_io(), "t_h2d_mean": loader.mean_t_h2d(),
        "samples_per_s": args.batch * n_dp / float(np.mean(warm)),
    }
    if args.summary_json:
        Path(args.summary_json).write_text(json.dumps(summary, indent=2))
    print(json.dumps(summary, indent=2))
    return summary


def main(argv=None):
    run(build_argparser().parse_args(argv))


if __name__ == "__main__":
    main()
