"""Persistent what-if **sweep** server: NDJSON over HTTP, stdlib only.

Namespace note — two servers live under ``repro.launch``:

* :mod:`repro.launch.serve` serves **model inference** (prefill +
  decode over the transformer models): its unit of work is a token.
* :mod:`repro.launch.serve_sweep` (this module) serves **scenario
  sweeps** (what-if queries against the S-SGD DAG model, backed by
  :class:`repro.core.service.SweepService`): its unit of work is a
  scenario grid.  Repeated queries hit process-lifetime caches
  (workload tables, grid-structure memos, jit-compiled jax kernels)
  and concurrent same-signature queries coalesce into shared kernel
  calls, so a warm query costs milliseconds where a cold one-shot
  ``python -m repro.launch.sweep`` pays imports + table building +
  jit every time.

Protocol (newline-delimited JSON):

* ``POST /query`` — body is one JSON object in the
  :func:`repro.core.service.parse_query` vocabulary (``grid`` plus
  axis overrides plus ``backend``/``seed``), e.g.::

      {"grid": "frontier", "workloads": ["resnet50"], "workers": [8]}

  The response streams NDJSON lines: a ``header`` line (column order,
  scenario count, backend), result chunks, and a ``trailer`` line
  carrying the :data:`repro.core.sweep.RESULT_META_KEYS` metadata plus
  a ``qos`` dict (queue wait, latency, coalesce count, cache probes).
  Result chunks default to **columnar** ``cols`` lines (``{"type":
  "cols", "lo": ..., "cols": {column: [values...]}}`` — roughly half
  the bytes and a fraction of the serialize/parse cost of row dicts);
  a query carrying ``"format": "rows"`` streams tidy per-row dicts
  instead (``{"type": "rows", "rows": [...]}``).  Either way floats
  survive the JSON round trip exactly (``repr`` shortest round-trip),
  so a client rebuilding the table — see :func:`table_from_wire` —
  gets bit-identical columns.
* ``GET /stats`` — one JSON object: the
  :meth:`repro.core.service.ServiceStats.snapshot` QoS document
  (latency percentiles, queue depth, coalesce factor, cache hit
  rates, sustained scenarios/s).
* ``GET /healthz`` — ``{"ok": true}``.

Malformed queries get a structured single-line error document
(HTTP 400, ``{"type": "error", "code": ..., "error": ...}``) — the
same rejections the sweep CLI exits 2 on, never a traceback.  A client
disconnecting mid-stream only ends its own response; the server keeps
serving.
"""
from __future__ import annotations

import argparse
import json
import sys
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

import numpy as np

from repro.core.resulttable import (COLUMNS, _dtype_of, concat_tables,
                                    rows_from_table, slice_table,
                                    table_from_rows)
from repro.core.service import QueryError, SweepService
from repro.core.sweep import RESULT_META_KEYS

#: Rows per result NDJSON line — large enough to amortize JSON
#: overhead, small enough that clients can stream progressively.
ROWS_PER_LINE = 4096

#: Wire formats a query's ``format`` key may select.
FORMATS = ("columns", "rows")


def _json_line(doc: dict) -> bytes:
    return (json.dumps(doc) + "\n").encode()


def table_from_wire(lines: list[dict]) -> dict[str, np.ndarray]:
    """Rebuild the columnar result table from a parsed NDJSON response
    (either wire format) — bit-identical to the server-side table."""
    cols = [l for l in lines if l.get("type") == "cols"]
    if cols:
        return concat_tables([
            {k: np.array(c["cols"][k], dtype=_dtype_of(k))
             for k in COLUMNS} for c in cols])
    return table_from_rows([r for l in lines if l.get("type") == "rows"
                            for r in l["rows"]])


class SweepRequestHandler(BaseHTTPRequestHandler):
    """One HTTP request against the server's shared
    :class:`SweepService`."""

    # HTTP/1.0: the response body ends when the connection closes, so
    # streaming needs no Content-Length / chunked framing.
    protocol_version = "HTTP/1.0"
    server_version = "repro-sweepd/1.0"

    def log_message(self, fmt, *args):  # quiet by default
        if self.server.verbose:  # type: ignore[attr-defined]
            super().log_message(fmt, *args)

    @property
    def service(self) -> SweepService:
        return self.server.service  # type: ignore[attr-defined]

    def _send_json(self, status: int, doc: dict) -> None:
        body = _json_line(doc)
        self.send_response(status)
        self.send_header("Content-Type", "application/x-ndjson")
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_error_doc(self, status: int, code: str, message: str) -> None:
        self._send_json(status, {"type": "error", "code": code,
                                 "error": message})

    def do_GET(self) -> None:
        try:
            if self.path == "/stats":
                self._send_json(200, self.service.stats_snapshot())
            elif self.path == "/healthz":
                self._send_json(200, {"ok": True})
            else:
                self._send_error_doc(404, "not-found",
                                     f"no such endpoint {self.path!r}; "
                                     f"POST /query, GET /stats, "
                                     f"GET /healthz")
        except (BrokenPipeError, ConnectionResetError):
            pass

    def do_POST(self) -> None:
        try:
            if self.path != "/query":
                self._send_error_doc(404, "not-found",
                                     f"no such endpoint {self.path!r}; "
                                     f"POST /query")
                return
            try:
                length = int(self.headers.get("Content-Length", 0))
                doc = json.loads(self.rfile.read(length))
            except (ValueError, TypeError) as e:
                self._send_error_doc(400, "bad-json",
                                     f"request body is not valid JSON: {e}")
                return
            fmt = doc.pop("format", "columns") \
                if isinstance(doc, dict) else "columns"
            if fmt not in FORMATS:
                self._send_error_doc(400, "bad-query",
                                     f"unknown format {fmt!r}; "
                                     f"one of {FORMATS}")
                return
            try:
                ticket = self.service.submit(doc)
                result = ticket.wait(timeout=300.0)
            except QueryError as e:
                self._send_error_doc(400, e.code, str(e))
                return
            except (TimeoutError, RuntimeError) as e:
                self._send_error_doc(503, "unavailable", str(e))
                return
            self._stream_result(result, fmt)
        except (BrokenPipeError, ConnectionResetError):
            # the client went away mid-stream; its query was already
            # evaluated (and counted) — just stop writing to it.
            pass

    def _stream_result(self, result, fmt: str = "columns") -> None:
        table, meta = result.table, result.meta
        n = meta["n_scenarios"]
        self.send_response(200)
        self.send_header("Content-Type", "application/x-ndjson")
        self.end_headers()
        self.wfile.write(_json_line({"type": "header",
                                     "columns": list(COLUMNS),
                                     "format": fmt,
                                     "n_scenarios": n,
                                     "backend": meta["backend"]}))
        for lo in range(0, n, ROWS_PER_LINE):
            sub = slice_table(table, lo, min(lo + ROWS_PER_LINE, n))
            if fmt == "rows":
                doc = {"type": "rows", "rows": rows_from_table(sub)}
            else:
                doc = {"type": "cols", "lo": lo,
                       "cols": {k: sub[k].tolist() for k in COLUMNS}}
            self.wfile.write(_json_line(doc))
            self.wfile.flush()
        trailer = {"type": "trailer",
                   **{k: meta[k] for k in RESULT_META_KEYS},
                   "qos": meta["qos"]}
        self.wfile.write(_json_line(trailer))


class SweepServer(ThreadingHTTPServer):
    """ThreadingHTTPServer carrying the shared service; daemon threads
    so a hung client never blocks shutdown."""

    daemon_threads = True

    def __init__(self, address, service: SweepService, *,
                 verbose: bool = False):
        super().__init__(address, SweepRequestHandler)
        self.service = service
        self.verbose = verbose


def make_server(host: str = "127.0.0.1", port: int = 0,
                service: SweepService | None = None,
                window_s: float = 0.005, max_coalesce: int = 32,
                verbose: bool = False) -> SweepServer:
    """A bound (not yet serving) server — ``port=0`` picks a free port
    (``server.server_address[1]``); the tests and benchmarks drive
    this directly with ``serve_forever`` on a thread."""
    if service is None:
        service = SweepService(window_s=window_s,
                               max_coalesce=max_coalesce)
    return SweepServer((host, port), service, verbose=verbose)


def _warm(service: SweepService) -> None:
    """Pre-resolve the built-in workload tables and the default grid's
    evaluator so the first real query starts warm."""
    from repro.core.workloads import known_workloads, resolve_workload

    for name in known_workloads():
        resolve_workload(name)
    service.query({"grid": "default"}, timeout=120.0)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.launch.serve_sweep",
        description="Persistent what-if sweep server (NDJSON over HTTP).")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=8642)
    p.add_argument("--window-ms", type=float, default=5.0,
                   help="micro-batch coalescing window (0 disables)")
    p.add_argument("--max-coalesce", type=int, default=32,
                   help="max queries fused into one kernel call")
    p.add_argument("--no-warm", action="store_true",
                   help="skip startup cache warming")
    p.add_argument("--verbose", action="store_true",
                   help="log each request")
    args = p.parse_args(argv)

    service = SweepService(window_s=args.window_ms / 1e3,
                           max_coalesce=args.max_coalesce)
    if not args.no_warm:
        print("warming caches (workload tables + default grid) ...",
              file=sys.stderr)
        _warm(service)
    server = make_server(args.host, args.port, service=service,
                         verbose=args.verbose)
    host, port = server.server_address[:2]
    print(f"serving sweeps on http://{host}:{port}  "
          f"(POST /query, GET /stats; Ctrl-C to stop)", file=sys.stderr)
    try:
        server.serve_forever()
    except KeyboardInterrupt:
        pass
    finally:
        server.server_close()
        service.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
