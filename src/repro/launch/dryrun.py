"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
combination on placeholder devices and record memory / cost /
collective analyses.

MUST set the host-device flag before any jax import (jax locks the
device count on first init); the shared helper appends to any
user-provided ``XLA_FLAGS`` instead of clobbering them.
"""
from repro.launch.hostdev import force_host_device_count

force_host_device_count(512)

# ruff: noqa: E402
import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, dryrun_matrix, get_config
from repro.core import archcost
from repro.launch import hlo as hlo_mod
from repro.launch import steps as steps_mod
from repro.launch.mesh import activate_mesh, make_production_mesh
from repro.models import sharding as shd
from repro.optim.sgd import sgd

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _batch_shardings(cfg, shape, specs, sc, mesh):
    out = {}
    for name, s in specs.items():
        if name == "cache":
            out[name] = jax.tree_util.tree_map(
                lambda sp: NamedSharding(mesh, sp),
                shd.cache_specs(s, sc))
        elif name == "pos":
            out[name] = NamedSharding(mesh, P())
        else:
            nd = len(s.shape)
            spec = shd.resolve_spec(s.shape, [["batch"]] + [()] * (nd - 1), sc)
            out[name] = NamedSharding(mesh, spec)
    return out


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool,
               mode: str = "fsdp", remat: bool = True,
               save_hlo: str | None = None,
               donate: bool = True, accum_steps: int = 1) -> dict:
    t_start = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    sc = shd.ShardingConfig(mesh_axes=mesh.axis_names, mode=mode)
    shd.set_sharding(sc)
    shd.set_mesh_sizes(dict(zip(mesh.axis_names, mesh.devices.shape)))

    pshape = steps_mod.params_shape(cfg)
    pspecs = shd.named_shardings(pshape, sc, mesh)
    specs = steps_mod.input_specs(cfg, shape)
    in_batch_shardings = _batch_shardings(cfg, shape, specs, sc, mesh)

    record: dict = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": mode, "remat": remat, "accum_steps": accum_steps,
        "n_devices": mesh.devices.size,
        "status": "ok",
    }

    try:
        if shape.kind == "train":
            opt = sgd(lr=1e-2, momentum=0.9)
            oshape = jax.eval_shape(opt.init, pshape)
            ospecs = shd.named_shardings(oshape, sc, mesh)
            step = steps_mod.make_train_step(cfg, opt, remat=remat,
                                             accum_steps=accum_steps)
            jitted = jax.jit(step,
                             in_shardings=(pspecs, ospecs, in_batch_shardings),
                             out_shardings=(pspecs, ospecs, None),
                             donate_argnums=(0, 1) if donate else ())
            args = (pshape, oshape, specs)
        elif shape.kind == "prefill":
            step = steps_mod.make_prefill_step(cfg)
            jitted = jax.jit(step, in_shardings=(pspecs, in_batch_shardings))
            args = (pshape, specs)
        else:
            step = steps_mod.make_serve_step(cfg)
            cache_shardings = in_batch_shardings["cache"]
            jitted = jax.jit(
                step,
                in_shardings=(pspecs, in_batch_shardings),
                out_shardings=(None, cache_shardings),
                donate_argnums=(1,) if donate else ())
            args = (pshape, specs)

        with activate_mesh(mesh):
            t0 = time.time()
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()

        mem = compiled.memory_analysis()
        record["lower_s"] = round(t1 - t0, 2)
        record["compile_s"] = round(t2 - t1, 2)
        record["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", None),
        }
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        record["cost_analysis"] = {
            "flops": ca.get("flops"),
            "bytes_accessed": ca.get("bytes accessed"),
            "while_body_counted_once": True,
        }
        txt = compiled.as_text()
        stats = hlo_mod.collective_stats(txt, loop_trip_count=max(cfg.num_units, 1))
        record["collectives"] = stats.to_dict()
        record["hlo_bytes"] = len(txt)
        if save_hlo:
            Path(save_hlo).write_text(txt)

        cost = archcost.step_cost(cfg, shape)
        record["analytic"] = {
            "flops": cost.flops, "hbm_bytes": cost.hbm_bytes,
            "model_flops": cost.model_flops,
            "n_params": cost.n_params,
            "n_active_params": cost.n_active_params,
            "param_bytes": cost.param_bytes,
        }
    except Exception as e:  # noqa: BLE001 — record the failure, don't crash the sweep
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()
    record["total_s"] = round(time.time() - t_start, 2)
    return record


def result_path(arch: str, shape: str, multi_pod: bool, out_dir: Path) -> Path:
    mesh = "2x16x16" if multi_pod else "16x16"
    return out_dir / f"{arch}__{shape}__{mesh}.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run the full matrix in subprocesses")
    ap.add_argument("--missing-only", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--accum-steps", type=int, default=1)
    ap.add_argument("--mode", default="fsdp",
                    choices=("fsdp", "fsdp2d", "zero3", "pure_dp"))
    ap.add_argument("--save-hlo")
    ap.add_argument("--out-dir", default=str(RESULTS_DIR))
    args = ap.parse_args(argv)
    out_dir = Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        combos = [(a, s, mp) for (a, s) in dryrun_matrix()
                  for mp in (False, True)]
        failures = 0
        for i, (a, s, mp) in enumerate(combos):
            path = result_path(a, s, mp, out_dir)
            if args.missing_only and path.exists():
                ok = json.loads(path.read_text()).get("status") == "ok"
                if ok:
                    continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--out-dir", str(out_dir),
                   "--mode", args.mode]
            if mp:
                cmd.append("--multi-pod")
            print(f"[{i + 1}/{len(combos)}] {a} x {s} x "
                  f"{'2x16x16' if mp else '16x16'}", flush=True)
            r = subprocess.run(cmd, capture_output=True, text=True,
                               timeout=3600)
            if r.returncode != 0:
                failures += 1
                print(r.stdout[-2000:], r.stderr[-2000:], flush=True)
        print(f"done; {failures} subprocess failures")
        return 1 if failures else 0

    if not (args.arch and args.shape):
        ap.error("--arch and --shape required (or --all)")
    rec = dryrun_one(args.arch, args.shape, multi_pod=args.multi_pod,
                     mode=args.mode, remat=not args.no_remat,
                     save_hlo=args.save_hlo, accum_steps=args.accum_steps)
    path = result_path(args.arch, args.shape, args.multi_pod, out_dir)
    path.write_text(json.dumps(rec, indent=2))
    print(json.dumps({k: v for k, v in rec.items() if k != "traceback"},
                     indent=2))
    return 0 if rec["status"] == "ok" else 1


if __name__ == "__main__":
    sys.exit(main())
