"""Step builders: train_step / prefill_step / serve_step per
architecture family, plus ShapeDtypeStruct input specs for each
assigned input shape — the pieces the multi-pod dry-run lowers.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import InputShape
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.models.common import ModelConfig
from repro.optim.sgd import Optimizer, global_norm


def init_params(cfg: ModelConfig, key):
    if cfg.arch_type == "audio":
        return ED.init_encdec(cfg, key)
    return T.init_lm(cfg, key)


def params_shape(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.random.PRNGKey(0))


# ----------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins; no device allocation)
# ----------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: InputShape) -> dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind == "train":
        specs = {"tokens": sds((B, S), jnp.int32),
                 "labels": sds((B, S), jnp.int32)}
        if cfg.arch_type == "audio":
            specs["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        if cfg.arch_type == "vlm":
            specs["images"] = sds((B, cfg.num_image_tokens, cfg.d_model),
                                  cfg.dtype)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((B, S), jnp.int32)}
        if cfg.arch_type == "audio":
            specs["frames"] = sds((B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
        if cfg.arch_type == "vlm":
            specs["images"] = sds((B, cfg.num_image_tokens, cfg.d_model),
                                  cfg.dtype)
        return specs
    if shape.kind == "decode":
        cache = jax.eval_shape(
            functools.partial(T.init_cache, cfg, B, S))
        specs = {"token": sds((B,), jnp.int32),
                 "pos": sds((), jnp.int32),
                 "cache": cache}
        if cfg.arch_type == "audio":
            specs["encoder_states"] = sds((B, cfg.encoder_seq, cfg.d_model),
                                          cfg.dtype)
        if cfg.arch_type == "vlm":
            specs["images"] = sds((B, cfg.num_image_tokens, cfg.d_model),
                                  cfg.dtype)
        return specs
    raise ValueError(shape.kind)


def _encoder_input(cfg: ModelConfig, batch: dict):
    if cfg.arch_type == "vlm":
        return batch["images"]
    return None


# ----------------------------------------------------------------------
# Steps
# ----------------------------------------------------------------------
def make_train_step(cfg: ModelConfig, optimizer: Optimizer, *,
                    remat: bool = True, accum_steps: int = 1):
    """SPMD train step: loss -> grads -> optimizer update.  Collective
    placement is XLA's (the production runtime); the paper's explicit
    policies live in ``repro.comm.ddp``.

    ``accum_steps > 1`` splits the per-step batch into microbatches
    scanned with f32 gradient accumulation — live activation memory
    divides by ``accum_steps`` while the gradient-sync volume is
    unchanged (EXPERIMENTS.md §Perf iteration 3).
    """

    def loss_of(p, batch):
        if cfg.arch_type == "audio":
            return ED.loss_fn(cfg, p, batch["frames"], batch["tokens"],
                              batch["labels"], remat=remat)
        return T.loss_fn(cfg, p, batch["tokens"], batch["labels"],
                         encoder_out=_encoder_input(cfg, batch),
                         remat=remat)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            (total, metrics), grads = jax.value_and_grad(
                lambda p: loss_of(p, batch), has_aux=True)(params)
        else:
            micro = jax.tree_util.tree_map(
                lambda x: x.reshape(accum_steps, x.shape[0] // accum_steps,
                                    *x.shape[1:]), batch)

            def mb_body(carry, mbatch):
                gsum, tot_sum, loss_sum, aux_sum = carry
                (tot, m), g = jax.value_and_grad(
                    lambda p: loss_of(p, mbatch), has_aux=True)(params)
                gsum = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (gsum, tot_sum + tot, loss_sum + m["loss"],
                        aux_sum + m["moe_aux"]), None

            gzero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            zero = jnp.zeros((), jnp.float32)
            (gsum, tot, loss, aux), _ = jax.lax.scan(
                mb_body, (gzero, zero, zero, zero), micro)
            inv = 1.0 / accum_steps
            grads = jax.tree_util.tree_map(lambda g: g * inv, gsum)
            total, metrics = tot * inv, {"loss": loss * inv,
                                         "moe_aux": aux * inv}

        new_params, new_opt = optimizer.update(grads, opt_state, params)
        out = {"total_loss": total, "loss": metrics["loss"],
               "moe_aux": metrics["moe_aux"], "grad_norm": global_norm(grads)}
        return new_params, new_opt, out

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        if cfg.arch_type == "audio":
            logits, _ = ED.forward(cfg, params, batch["frames"],
                                   batch["tokens"])
        else:
            logits, _ = T.forward(cfg, params, batch["tokens"],
                                  encoder_out=_encoder_input(cfg, batch))
        return logits

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, seq_axis: str | None = None):
    """One-token decode against a seq_len cache."""

    def serve_step(params, batch):
        cache, token, pos = batch["cache"], batch["token"], batch["pos"]
        if cfg.arch_type == "audio":
            logits, new_cache = ED.decode_step(cfg, params, cache,
                                               batch["encoder_states"],
                                               token, pos)
        else:
            logits, new_cache = T.decode_step(
                cfg, params, cache, token, pos,
                encoder_out=_encoder_input(cfg, batch), seq_axis=seq_axis)
        return logits, new_cache

    return serve_step
