"""Roofline report generator: results/dryrun/*.json -> markdown tables
for EXPERIMENTS.md (§Dry-run + §Roofline).

    PYTHONPATH=src python -m repro.launch.roofline [--write]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.core.hardware import (V5E_HBM_BW, V5E_ICI_BW_PER_LINK,
                                 V5E_PEAK_FLOPS_BF16)

RESULTS = Path(__file__).resolve().parents[3] / "results" / "dryrun"
OUT = Path(__file__).resolve().parents[3] / "results" / "roofline.md"

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")


def terms(rec: dict) -> dict:
    chips = rec["n_devices"]
    ana = rec["analytic"]
    coll = (rec.get("collectives") or {}).get("total_bytes", 0.0)
    t = {
        "compute": ana["flops"] / (chips * V5E_PEAK_FLOPS_BF16),
        "memory": ana["hbm_bytes"] / (chips * V5E_HBM_BW),
        "collective": coll / V5E_ICI_BW_PER_LINK,
    }
    dom = max(t, key=lambda k: t[k])
    bound = t[dom]
    mfu = (ana["model_flops"] / (chips * V5E_PEAK_FLOPS_BF16)
           / max(bound, 1e-12))
    return {**t, "dominant": dom, "bound": bound, "mfu": mfu,
            "useful": (ana["model_flops"] / ana["flops"]
                       if ana["flops"] else 0.0)}


def load(results_dir: Path = RESULTS) -> list[dict]:
    recs = []
    for p in sorted(results_dir.glob("*.json")):
        r = json.loads(p.read_text())
        if r.get("status") == "ok":
            r["_terms"] = terms(r)
            recs.append(r)
    return recs


def fmt_ms(s: float) -> str:
    return f"{s * 1e3:9.2f}"


def dryrun_table(recs: list[dict]) -> str:
    lines = ["| arch | shape | mesh | compile s | temp GB/dev | arg GB/dev "
             "| HLO collective GB | #coll ops |",
             "|---|---|---|---:|---:|---:|---:|---:|"]
    for r in recs:
        mem = r.get("memory") or {}
        c = r.get("collectives") or {}
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r.get('compile_s', 0):.0f} "
            f"| {(mem.get('temp_bytes') or 0) / 1e9:.2f} "
            f"| {(mem.get('argument_bytes') or 0) / 1e9:.2f} "
            f"| {(c.get('total_bytes') or 0) / 1e9:.2f} "
            f"| {c.get('total_count', 0)} |")
    return "\n".join(lines)


def roofline_table(recs: list[dict], mesh: str = "16x16") -> str:
    lines = ["| arch | shape | compute ms | memory ms | collective ms "
             "| dominant | MFU@bound | useful FLOPs |",
             "|---|---|---:|---:|---:|---|---:|---:|"]
    for r in recs:
        if r["mesh"] != mesh:
            continue
        t = r["_terms"]
        lines.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_ms(t['compute'])} | {fmt_ms(t['memory'])} "
            f"| {fmt_ms(t['collective'])} | **{t['dominant']}** "
            f"| {t['mfu']:.3f} | {t['useful']:.2f} |")
    return "\n".join(lines)


def pick_hillclimb(recs: list[dict]) -> list[dict]:
    """worst roofline fraction / most collective-bound / most
    paper-representative (see EXPERIMENTS.md)."""
    pod = [r for r in recs if r["mesh"] == "16x16"]
    worst_mfu = min((r for r in pod if r["shape"] == "train_4k"),
                    key=lambda r: r["_terms"]["mfu"], default=None)
    most_coll = max(pod, key=lambda r: r["_terms"]["collective"],
                    default=None)
    return [worst_mfu, most_coll]


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results-dir", default=str(RESULTS))
    ap.add_argument("--write", action="store_true")
    ap.add_argument("--out", default=str(OUT))
    args = ap.parse_args(argv)
    recs = load(Path(args.results_dir))
    doc = ["# Dry-run artifacts", "", dryrun_table(recs), "",
           "# Roofline (single pod, 16x16 = 256 chips)", "",
           roofline_table(recs, "16x16"), "",
           "# Roofline (multi-pod, 2x16x16 = 512 chips)", "",
           roofline_table(recs, "2x16x16"), ""]
    text = "\n".join(doc)
    print(text)
    if args.write:
        out = Path(args.out)
        out.write_text(text)
        print(f"\nwrote {out}")


if __name__ == "__main__":
    main()
