"""Scenario-sweep CLI: evaluate a grid of S-SGD what-if scenarios and
emit a tidy results table.

    PYTHONPATH=src python -m repro.launch.sweep
    PYTHONPATH=src python -m repro.launch.sweep --grid mixed
    PYTHONPATH=src python -m repro.launch.sweep --grid frontier \\
        --stream --csv /tmp/frontier.csv
    PYTHONPATH=src python -m repro.launch.sweep \\
        --workloads cnn:resnet50,trace:alexnet-k80,llm:gemma3-1b \\
        --clusters v100-nvlink-ib \\
        --workers 4,8,16,32 --policies caffe-mpi,bucketed-25mb \\
        --collectives ring,tree,hierarchical --csv /tmp/sweep.csv
    PYTHONPATH=src python -m repro.launch.sweep \\
        --het none,het:1x0.5+3x1.0 --stragglers none,lognormal:0.2x1000 \\
        --seed 7 --sort t_p99_s
    PYTHONPATH=src python -m repro.launch.sweep \\
        --workers 8 --sync-k none,6 \\
        --faults none,fail:0.01@restart2.5x1000 --sort t_p99_s

Workloads resolve through the pluggable registry
(``repro.core.workloads``): bare paper CNN names or ``cnn:<name>``,
``trace:<bundled-name-or-file-path>``, ``llm:<arch>``, and measured
``jax:<name-or-path>`` workloads harvested from the repo's own
executed train steps (``python -m repro.measure --arch <id>``) — see
``--list-workloads``.  Axis values are comma-separated;
``--interconnects`` accepts preset names from
``repro.core.hardware.INTERCONNECT_PRESETS``, scaled what-ifs
(``ib-100g@bw2@lat0.25``) and ``default`` (keep the cluster's own
links).  The default grid is 540 scenarios on the batched analytical
fast path (milliseconds end to end); ``--grid mixed`` spans all three
providers (1620 scenarios); ``--grid frontier`` is the 51 840-scenario
bandwidth x latency x bucket-size x priority design-space study
(schedule-dependent policies ride the batched bucket-timeline path, so
the whole grid evaluates in tens of milliseconds) — pair it with
``--stream``
to write CSV/JSON incrementally instead of buffering every row.
"""
from __future__ import annotations

import argparse
import sys

from repro.core.hardware import COLLECTIVE_ALGORITHMS, INTERCONNECT_PRESETS
from repro.core.scenarios import grid_from_spec
from repro.core.sweep import COLUMNS, DEFAULT_CHUNK, stream, sweep
from repro.core.workloads import known_workloads


def _csv_list(text: str) -> list[str]:
    return [t.strip() for t in text.split(",") if t.strip()]


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.launch.sweep",
        description="Batched what-if sweep over the S-SGD DAG model.")
    p.add_argument("--grid", choices=("default", "mixed", "frontier"),
                   default="default",
                   help="base grid: 'default' (paper CNNs, 540 scenarios), "
                        "'mixed' (cnn:/trace:/llm: providers, 1620) or "
                        "'frontier' (bandwidth x latency x bucket-size x "
                        "priority what-ifs, 51840); other axis flags "
                        "override any of them")
    p.add_argument("--workloads", type=_csv_list, default=None,
                   help="comma-separated workload names: bare CNNs "
                        "(alexnet,googlenet,resnet50), cnn:<name>, "
                        "trace:<bundled-or-path>, llm:<arch>, "
                        "jax:<measured-name-or-path> "
                        "(see --list-workloads; measure with "
                        "`python -m repro.measure`)")
    p.add_argument("--list-workloads", action="store_true",
                   help="print every registered workload name and exit")
    p.add_argument("--clusters", type=_csv_list, default=None,
                   help="comma-separated cluster names")
    p.add_argument("--workers", type=_csv_list, default=None,
                   help="comma-separated worker counts, e.g. 1,4,16,64")
    p.add_argument("--policies", type=_csv_list, default=None,
                   help="comma-separated policy names (see repro.core.policies)")
    p.add_argument("--collectives", type=_csv_list, default=None,
                   help=f"comma-separated algorithms {COLLECTIVE_ALGORITHMS}")
    p.add_argument("--interconnects", type=_csv_list, default=None,
                   help="comma-separated presets "
                        f"({', '.join(sorted(INTERCONNECT_PRESETS))}) "
                        "and/or 'default'")
    p.add_argument("--het", type=_csv_list, default=None,
                   help="comma-separated heterogeneity profiles: 'none' "
                        "and/or 'het:<slots>' specs, e.g. "
                        "het:1x0.5+3x1.0 (one half-speed worker per 4), "
                        "het:2x1.0@bw0.5 (half link bandwidth); see "
                        "repro.core.het")
    p.add_argument("--stragglers", type=_csv_list, default=None,
                   help="comma-separated straggler models: 'none' and/or "
                        "'<dist>:<scale>[x<draws>]' with dist lognormal|exp, "
                        "e.g. lognormal:0.2x1000 — Monte Carlo tails land "
                        "in t_mean_s/t_p95_s/t_p99_s")
    p.add_argument("--sync-k", type=_csv_list, default=None,
                   help="comma-separated K-of-N partial-sync thresholds: "
                        "'none'/'0' (full sync) and/or positive K — each "
                        "iteration waits for the first K of N gradients "
                        "(K is clamped to the worker count; backup "
                        "workers = N - K)")
    p.add_argument("--faults", type=_csv_list, default=None,
                   help="comma-separated fault models: 'none' and/or "
                        "'fail:<p>[@restart<T>][x<draws>]' — each worker "
                        "crashes with probability p per iteration and "
                        "pays a T-second checkpoint restore (default "
                        "restart 5s), e.g. fail:0.01@restart2.5x1000; "
                        "Monte Carlo tails land in t_mean_s/t_p95_s/"
                        "t_p99_s")
    p.add_argument("--seed", type=int, default=0,
                   help="seed for the straggler Monte Carlo draws "
                        "(default 0; draws are keyed by (spec, workers, "
                        "seed), so results are reproducible across "
                        "backends, --jobs and chunking)")
    p.add_argument("--batch-per-gpu", type=int, default=None,
                   help="override the workload's per-GPU batch size")
    p.add_argument("--force-simulator", action="store_true",
                   help="run every scenario through the event-driven "
                        "simulator (slow; for validation)")
    p.add_argument("--per-scenario", action="store_true",
                   help="pin closed-form scenarios to the per-scenario "
                        "reference path instead of the batched kernel "
                        "(slow; the agreement oracle)")
    p.add_argument("--backend", choices=("numpy", "jax"), default="numpy",
                   help="batched kernel backend: 'numpy' (default, the "
                        "oracle) or 'jax' (jit+vmap kernels, sharded over "
                        "available devices; incompatible with "
                        "--per-scenario and --force-simulator)")
    p.add_argument("--jobs", type=int, default=None, metavar="N",
                   help="shard a grid sweep across N worker processes "
                        "(-1 = one per core; output is bit-identical to "
                        "serial, in the same order); with --backend jax "
                        "shards over the device mesh instead")
    p.add_argument("--chunk", type=int, default=None, metavar="N",
                   help="scenarios per evaluation chunk (default "
                        f"{DEFAULT_CHUNK}): the streaming buffer unit, "
                        "and the minimum shard size under --jobs")
    p.add_argument("--stream", action="store_true",
                   help="stream rows straight to --csv/--json without "
                        "buffering the table (huge grids); skips the "
                        "printed table")
    p.add_argument("--sort", default="samples_per_sec",
                   help="result column to sort by (descending)")
    p.add_argument("--top", type=int, default=20,
                   help="print only the best N rows (0 = all)")
    p.add_argument("--csv", default=None, metavar="PATH",
                   help="also write the full table as CSV")
    p.add_argument("--json", default=None, metavar="PATH",
                   help="also write the full table (plus sweep metadata) "
                        "as JSON")
    return p


def grid_from_args(args: argparse.Namespace):
    """The chosen base grid with any CLI-provided axes substituted in.

    Delegates to :func:`repro.core.scenarios.grid_from_spec` — the
    CLI's flags and the sweep service's JSON query documents
    (:mod:`repro.core.service`) share one axis vocabulary and one
    parser, so a spec this CLI exits 2 on is exactly one the server
    rejects with a structured error, and vice versa."""
    spec: dict = {"grid": args.grid}
    for key, val in (("workloads", args.workloads),
                     ("clusters", args.clusters),
                     ("workers", args.workers),
                     ("policies", args.policies),
                     ("collectives", args.collectives),
                     ("interconnects", args.interconnects),
                     ("het", args.het),
                     ("stragglers", args.stragglers),
                     ("sync_k", args.sync_k),
                     ("faults", args.faults)):
        if val:
            spec[key] = val
    if args.batch_per_gpu is not None:
        spec["batch_per_gpu"] = args.batch_per_gpu
    return grid_from_spec(spec)


def main(argv: list[str] | None = None) -> int:
    parser = build_parser()
    args = parser.parse_args(argv)
    if args.list_workloads:
        for name in known_workloads():
            print(name)
        return 0
    try:
        grid = grid_from_args(args)
        grid.validate_axes()           # validate axis values up front
    except (ValueError, KeyError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if args.sort and args.sort not in COLUMNS:
        print(f"error: unknown --sort column {args.sort!r}; "
              f"one of {', '.join(COLUMNS)}", file=sys.stderr)
        return 2
    if args.stream and not (args.csv or args.json):
        print("error: --stream requires --csv and/or --json",
              file=sys.stderr)
        return 2
    if args.backend == "jax" and args.per_scenario:
        print("error: --backend jax is the batched kernel; --per-scenario "
              "pins the per-scenario NumPy reference paths (drop one)",
              file=sys.stderr)
        return 2
    if args.backend == "jax" and args.force_simulator:
        print("error: --backend jax has no event-driven simulator; "
              "--force-simulator needs --backend numpy",
              file=sys.stderr)
        return 2
    print(f"sweep: {len(grid)} scenarios "
          f"({len(grid.workloads)} workloads x {len(grid.clusters)} clusters "
          f"x {len(grid.worker_counts)} sizes x {len(grid.policies)} policies "
          f"x {len(grid.collectives)} collectives "
          f"x {len(grid.interconnects)} interconnects "
          f"x {len(grid.het_profiles)} het x {len(grid.stragglers)} "
          f"stragglers x {len(grid.sync_ks)} sync-k "
          f"x {len(grid.faults)} faults)")
    if args.stream:
        summary = stream(grid, csv_path=args.csv, json_path=args.json,
                         force_simulator=args.force_simulator,
                         batched=not args.per_scenario,
                         backend=args.backend, jobs=args.jobs,
                         chunk=args.chunk or DEFAULT_CHUNK,
                         seed=args.seed)
        dests = ", ".join(p for p in (args.csv, args.json) if p)
        print(f"streamed {summary['n_scenarios']} rows to {dests} "
              f"in {summary['elapsed_s']:.2f}s "
              f"({summary['scenarios_per_sec']:,.0f}/s; "
              f"{summary['n_analytical']} analytical, "
              f"{summary['n_timeline']} timeline, "
              f"{summary['n_simulated']} simulated)")
        return 0
    result = sweep(grid, force_simulator=args.force_simulator,
                   batched=not args.per_scenario, backend=args.backend,
                   jobs=args.jobs, chunk=args.chunk, seed=args.seed)
    print(f"evaluated in {result.elapsed_s:.2f}s "
          f"({result.scenarios_per_sec:,.0f}/s; "
          f"{result.n_analytical} analytical, "
          f"{result.n_timeline} timeline, "
          f"{result.n_simulated} simulated)")

    rows = result.sorted_by(args.sort) if args.sort else result.rows
    limit = args.top if args.top and args.top > 0 else None
    print()
    print(result.format_table(rows, limit=limit))
    if limit is not None and len(rows) > limit:
        print(f"... {len(rows) - limit} more rows "
              f"(use --top 0 for all, --csv for the full table)")
    if args.csv:
        result.to_csv(args.csv)
        print(f"\nwrote {len(result)} rows to {args.csv}")
    if args.json:
        result.to_json(args.json)
        print(f"\nwrote {len(result)} rows to {args.json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
