"""Serving launcher: batched prefill + decode on the local devices.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b \
        --reduced --batch 4 --prompt-len 32 --gen 32

Namespace note — this module serves **model inference** (token
generation over the transformer models).  The persistent **scenario
sweep** server — what-if queries against the S-SGD DAG model, with
hot caches and query coalescing — is its sibling
:mod:`repro.launch.serve_sweep`.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.launch.steps import init_params
from repro.models import transformer as T


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCH_IDS, default="rwkv6-1.6b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced(num_layers=2)
    if cfg.arch_type in ("audio", "vlm"):
        import dataclasses
        cfg = dataclasses.replace(cfg, layer_pattern="G", arch_type="dense")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(key, (args.batch, args.prompt_len),
                                 0, cfg.vocab_size)

    t0 = time.perf_counter()
    logits, cache = T.prefill_via_decode(cfg, params, prompts, max_len)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(
        lambda p, c, tok, pos: T.decode_step(cfg, p, c, tok, pos))
    token = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
    out_tokens = [token]
    pos = jnp.int32(args.prompt_len)
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        lg, cache = decode(params, cache, token, pos + i)
        token = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        out_tokens.append(token)
    jax.block_until_ready(token)
    t_decode = time.perf_counter() - t0

    gen = jnp.stack(out_tokens, axis=1)
    summary = {
        "arch": cfg.name, "batch": args.batch,
        "prompt_len": args.prompt_len, "generated": int(gen.shape[1]),
        "prefill_s": t_prefill,
        "decode_tok_per_s": args.batch * (args.gen - 1) / max(t_decode, 1e-9),
        "sample_tokens": np.asarray(gen[0, :8]).tolist(),
    }
    print(json.dumps(summary, indent=2))
    return summary


if __name__ == "__main__":
    main()
