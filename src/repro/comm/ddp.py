"""Explicit data-parallel S-SGD train step (the paper's Algorithm 1).

This is the *paper-reproduction* runtime: parameters replicated on the
``data`` axis (pure DP), batch sharded, gradients synchronized by an
explicit, policy-selected collective schedule — so the lowered HLO
shows exactly the framework differences of §IV-C (one fused all-reduce
at the end for CNTK vs. per-layer all-reduces inside the backward loop
for WFBP vs. fused buckets).

The production runtime (``repro.launch.train``) instead uses SPMD
sharding (FSDP/TP) where XLA places the collectives.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh
from jax.sharding import PartitionSpec as P

from repro.comm import sync as S
from repro.models import transformer as T
from repro.models.common import ModelConfig
from repro.optim.sgd import Optimizer, global_norm


def shard_map_compat(f, mesh, in_specs, out_specs):
    """jax.shard_map across jax versions (top-level API + check_vma on
    newer jax; jax.experimental.shard_map + check_rep on 0.4.x)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


def make_ddp_train_step(cfg: ModelConfig, optimizer: Optimizer, mesh: Mesh,
                        sync_policy: str = "wfbp", dp_axis: str = "data",
                        bucket_bytes: float = S.DEFAULT_BUCKET_BYTES,
                        remat: bool = False):
    """Returns ``step(params, opt_state, batch) -> (params, opt_state,
    metrics)`` as a shard_map'd jitted function.

    ``sync_policy``: one of ``repro.comm.sync.SYNC_POLICIES``.
    """
    dp_axes = (dp_axis,)
    world = mesh.shape[dp_axis]

    def local_step(params, opt_state, batch):
        hook = (S.wfbp_param_hook(dp_axes, float(world))
                if sync_policy == "wfbp" else None)

        def loss(p):
            return T.loss_fn(cfg, p, batch["tokens"], batch["labels"],
                             remat=remat, param_hook=hook)

        (total, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
        grads = S.sync_gradients(grads, sync_policy, dp_axes, bucket_bytes)
        # the loss itself is also averaged for reporting
        total = jax.lax.pmean(total, dp_axes)
        new_params, new_opt = optimizer.update(grads, opt_state, params)
        out_metrics = {"loss": jax.lax.pmean(metrics["loss"], dp_axes),
                       "total_loss": total,
                       "grad_norm": global_norm(grads)}
        return new_params, new_opt, out_metrics

    batch_specs = {"tokens": P(dp_axis), "labels": P(dp_axis)}
    step = shard_map_compat(local_step, mesh,
                            in_specs=(P(), P(), batch_specs),
                            out_specs=(P(), P(), P()))
    return jax.jit(step, donate_argnums=(0, 1))


def lower_ddp_step(cfg: ModelConfig, optimizer: Optimizer, mesh: Mesh,
                   sync_policy: str, batch_size: int, seq_len: int,
                   dp_axis: str = "data"):
    """Lower (no execute) for HLO inspection of collective placement."""
    import numpy as np

    params = jax.eval_shape(lambda k: T.init_lm(cfg, k),
                            jax.random.PRNGKey(0))
    opt_state = jax.eval_shape(optimizer.init, params)
    batch = {"tokens": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32),
             "labels": jax.ShapeDtypeStruct((batch_size, seq_len), jnp.int32)}
    step = make_ddp_train_step(cfg, optimizer, mesh, sync_policy,
                               dp_axis=dp_axis)
    return step.lower(params, opt_state, batch)
