"""Gradient synchronization policies inside *real* JAX training steps.

This is where the paper's §IV-C framework taxonomy becomes executable
HLO rather than a simulation:

* ``at_end`` (CNTK): one fused ``pmean`` over the whole gradient pytree
  after the full backward pass — a single blocking collective phase.
* ``wfbp`` (Caffe-MPI / MXNet / TensorFlow): a ``custom_vjp`` identity
  is applied to each scanned layer's parameters, whose backward rule
  issues the data-parallel ``psum`` *inside the backward scan body* —
  so the lowered HLO carries one all-reduce per layer inside the
  backward ``while`` loop, exactly the wait-free back-propagation
  pattern, and the XLA latency-hiding scheduler can overlap it with
  the remaining backward compute.
* ``bucketed`` (beyond-paper, §VII future work): gradients are fused
  into size-targeted flat buckets before a per-bucket collective —
  fewer, larger messages (the fix for the 9.6% InfiniBand utilization
  the paper measured).

All three produce bitwise-identical gradients (property-tested); they
differ only in collective placement/fusion.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

SYNC_POLICIES = ("none", "at_end", "wfbp", "bucketed")

#: Default gradient-bucket fusion threshold in bytes (DDP's 25 MB) —
#: the one spelling shared by the executable step, the measurement
#: harness and the model-vs-measured benchmark, so the modeled
#: ``bucketed`` policy can never drift from the lowered one.
DEFAULT_BUCKET_BYTES = 25e6


# ----------------------------------------------------------------------
# WFBP: psum-in-backward via custom_vjp
# ----------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def psum_in_backward(x: jax.Array, axis_names: tuple[str, ...],
                     scale: float) -> jax.Array:
    """Identity on the forward pass; the cotangent is ``psum``-ed over
    ``axis_names`` and divided by ``scale`` on the backward pass.

    This is the executable form of the paper's WFBP (§IV-C): tagging a
    layer's parameters with this op places that layer's gradient
    all-reduce *inside* the backward pass, i.e. the DAG edge
    ``bwd_l -> comm_l`` of Fig. 1.  ``scale`` is the data-parallel
    world size (dimensionless), turning the psum into a mean.
    """
    return x


def _fwd(x, axis_names, scale):
    return x, None


def _bwd(axis_names, scale, _res, g):
    if axis_names:
        g = jax.lax.psum(g, axis_names)
    return (g / scale,)


psum_in_backward.defvjp(_fwd, _bwd)


def wfbp_param_hook(axis_names: Sequence[str], scale: float):
    """Returns a hook for ``transformer.forward(unit_param_hook=...)``:
    tags every parameter leaf of the scanned layer so its gradient is
    all-reduced the moment that layer's backward completes.  ``scale``
    is the data-parallel world size (psum -> mean)."""
    axes = tuple(axis_names)
    if not axes:
        return None

    def hook(unit_params):
        return jax.tree_util.tree_map(
            lambda p: psum_in_backward(p, axes, scale), unit_params)

    return hook


# ----------------------------------------------------------------------
# at_end: one pmean over the full pytree
# ----------------------------------------------------------------------
def pmean_at_end(grads: Any, axis_names: Sequence[str]) -> Any:
    """Mean-reduce the whole gradient pytree in one blocking collective
    phase after backward completes — the CNTK schedule of §IV-C, whose
    iteration time the DAG model's Eq. (3) (late-H2D variant)
    predicts.  No-op when ``axis_names`` is empty (single device)."""
    axes = tuple(axis_names)
    if not axes:
        return grads
    return jax.lax.pmean(grads, axes)


# ----------------------------------------------------------------------
# bucketed: flatten -> fixed-size buckets -> one collective per bucket
# ----------------------------------------------------------------------
def bucketed_pmean(grads: Any, axis_names: Sequence[str],
                   bucket_bytes: float = DEFAULT_BUCKET_BYTES) -> Any:
    """Fuse gradient leaves into flat f32 buckets of >= ``bucket_bytes``
    **bytes** each, mean-reduce one collective per bucket, and scatter
    back — DDP/Horovod-style fusion, the §VII fix for the 9.6%
    InfiniBand utilization the paper measured with layer-wise messages
    (simulated counterpart: ``Policy.bucket_bytes`` +
    ``repro.core.dag._bucketize``)."""
    axes = tuple(axis_names)
    if not axes:
        return grads
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    buckets: list[list[int]] = [[]]
    size = 0.0
    for i, leaf in enumerate(leaves):
        buckets[-1].append(i)
        size += leaf.size * leaf.dtype.itemsize
        if size >= bucket_bytes:
            buckets.append([])
            size = 0.0
    if not buckets[-1]:
        buckets.pop()
    out: list[Any] = [None] * len(leaves)
    for members in buckets:
        flat = jnp.concatenate([leaves[i].reshape(-1).astype(jnp.float32)
                                for i in members])
        flat = jax.lax.pmean(flat, axes)
        off = 0
        for i in members:
            n = leaves[i].size
            out[i] = flat[off:off + n].reshape(leaves[i].shape).astype(leaves[i].dtype)
            off += n
    return jax.tree_util.tree_unflatten(treedef, out)


def sync_gradients(grads: Any, policy: str, axis_names: Sequence[str],
                   bucket_bytes: float = DEFAULT_BUCKET_BYTES) -> Any:
    """Post-backward gradient sync dispatch; ``policy`` is one of
    :data:`SYNC_POLICIES` and ``bucket_bytes`` is the fusion threshold
    in **bytes** (only used by ``bucketed``).  ``wfbp`` grads are
    already reduced inside the backward pass — mean-normalized by the
    caller — so they pass through untouched here."""
    if policy in ("none", "wfbp"):
        return grads
    if policy == "at_end":
        return pmean_at_end(grads, axis_names)
    if policy == "bucketed":
        return bucketed_pmean(grads, axis_names, bucket_bytes)
    raise ValueError(f"unknown sync policy {policy!r}")


def axis_size(axis_names: Sequence[str]) -> jax.Array | int:
    """Product of the named mesh axis sizes (the data-parallel world
    size ``N_g`` of the paper's equations); 1 when no axes given."""
    n = 1
    for a in axis_names:
        n *= jax.lax.axis_size(a)
    return n
