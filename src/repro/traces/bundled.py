"""Bundled traces from the paper.

``ALEXNET_K80`` is Table VI of the paper **verbatim**: one iteration of
AlexNet on two K80 GPUs (times in microseconds, sizes in bytes).  The
paper's full downloadable trace archive is not reachable offline; this
table is the published sample from it and is enough to drive every
simulation path (the trace *generator* in :mod:`repro.traces.generate`
produces more files in the identical format from instrumented runs).
"""
from __future__ import annotations

from repro.traces.format import Trace, make_trace

# Table VI — AlexNet, one iteration, K80 GPU (id, name, fwd, bwd, comm, size)
_ALEXNET_K80_ROWS = [
    (0, "data", 1.20e6, 0, 0, 0),
    (1, "conv1", 3.27e6, 288202, 123.424, 139776),
    (2, "relu1", 17234.5, 27650.9, 0, 0),
    (3, "pool1", 32175.7, 60732.6, 0, 0),
    (4, "conv2", 3.14e6, 1.03216e6, 292.032, 1229824),
    (5, "relu2", 11507.5, 18422.5, 0, 0),
    (6, "pool2", 19831.2, 32459, 0, 0),
    (7, "conv3", 3.886e6, 791825, 288214, 3540480),
    (8, "relu3", 4770.3, 10996.3, 0, 0),
    (9, "conv4", 1.87e6, 510405, 1.03218e6, 2655744),
    (10, "relu4", 4760.26, 7872.45, 0, 0),
    (11, "conv5", 1.13e6, 306129, 275772, 1770496),
    (12, "relu5", 3201.22, 4939.42, 0, 0),
    (13, "pool5", 5812, 18666.2, 0, 0),
    (14, "fc6", 44689.7, 73935, 311170, 151011328),
    (15, "relu6", 295.168, 1092.83, 0, 0),
    (16, "drop6", 359.744, 131247, 0, 0),
    (17, "fc7", 19787.8, 34423.8, 610376, 67125248),
    (18, "relu7", 295.04, 451.904, 0, 0),
    (19, "drop7", 358.048, 317.312, 0, 0),
    (20, "fc8", 8033.12, 9922.72, 130964, 16388000),
    (21, "loss", 1723.49, 293.024, 0, 0),
]

# Table IV's AlexNet config: 1024 samples per GPU per iteration.
ALEXNET_K80: Trace = make_trace("alexnet", "k80-pcie-10gbe", _ALEXNET_K80_ROWS,
                                batch_per_gpu=1024)

#: Bundled traces the ``trace:`` workload provider resolves by name.
BUNDLED_TRACES: dict[str, Trace] = {"alexnet-k80": ALEXNET_K80}

TOTAL_GRAD_BYTES = sum(r[5] for r in _ALEXNET_K80_ROWS)   # ~244 MB = 61M f32
