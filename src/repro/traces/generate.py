"""Trace generator: instrument a real (JAX, CPU) model into the paper's
layer-wise trace format.

The paper measured Caffe-MPI's per-layer forward/backward/comm times;
here we time each layer's jitted forward and VJP on the actual device
and record gradient sizes from the parameter pytree, emitting a
:class:`~repro.traces.format.Trace` that the DAG predictor consumes —
so the full paper pipeline (measure -> trace -> DAG -> predict) runs
end to end inside this repo.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.traces.format import LayerRecord, Trace


@dataclass(frozen=True)
class TimedLayer:
    """A named layer: ``apply(params, x) -> y`` plus its parameters."""

    name: str
    apply: Callable[[Any, Any], Any]
    params: Any


def _param_bytes(params: Any) -> float:
    leaves = jax.tree_util.tree_leaves(params)
    return float(sum(l.size * l.dtype.itemsize for l in leaves))


def _block(x):
    return jax.block_until_ready(x)


def _time_call(fn, *args, repeats: int) -> float:
    """Median wall time of ``fn(*args)`` in microseconds (post-warmup)."""
    _block(fn(*args))
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        _block(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def generate_trace(
    layers: Sequence[TimedLayer],
    x0: Any,
    network: str,
    cluster: str = "cpu-host",
    n_iterations: int = 3,
    repeats: int = 5,
    comm_time_fn: Callable[[float], float] | None = None,
) -> Trace:
    """Measure per-layer fwd/bwd wall time and emit a paper-format trace.

    ``comm_time_fn(grad_bytes) -> seconds`` fills the Comm. column (e.g.
    a :meth:`ClusterSpec.allreduce_time` closure); default 0 (single
    device, as Eq. (1)).
    """
    iters: list[tuple[LayerRecord, ...]] = []
    fwd_jits = [jax.jit(l.apply) for l in layers]

    # VJP per layer: d(sum(y))/d(params [, x]) — integer inputs (token
    # ids into an embedding) only differentiate w.r.t. params.
    def make_bwd(apply, x_is_int):
        argnums = (0,) if x_is_int else (0, 1)

        def loss(params, x):
            return jnp.sum(apply(params, x))

        return jax.jit(jax.grad(loss, argnums=argnums))

    bwd_jits: dict[int, object] = {}

    for _ in range(n_iterations):
        recs: list[LayerRecord] = []
        x = x0
        for lid, (layer, fj) in enumerate(zip(layers, fwd_jits)):
            if lid not in bwd_jits:
                is_int = jnp.issubdtype(jnp.asarray(x).dtype, jnp.integer)
                bwd_jits[lid] = make_bwd(layer.apply, bool(is_int))
            bj = bwd_jits[lid]
            f_us = _time_call(fj, layer.params, x, repeats=repeats)
            b_us = (_time_call(bj, layer.params, x, repeats=repeats)
                    if jax.tree_util.tree_leaves(layer.params) else 0.0)
            size = _param_bytes(layer.params)
            c_us = (comm_time_fn(size) * 1e6 if (comm_time_fn and size) else 0.0)
            recs.append(LayerRecord(lid, layer.name, f_us, b_us, c_us, size))
            x = _block(fj(layer.params, x))
        iters.append(tuple(recs))
    return Trace(network, cluster, tuple(iters))
