"""The paper's layer-wise trace dataset format (§VI).

Each trace file holds iterations of layer-wise records with six
columns::

    Id  Name  Forward  Backward  Comm.  Size

times in **microseconds**, gradient ``Size`` in **bytes** (0 for
non-learnable layers).  ``read_trace``/``write_trace`` round-trip this
format; ``to_iteration_costs`` converts a trace into the DAG builder's
:class:`~repro.core.dag.IterationCosts` (seconds), which is exactly how
the paper uses its traces for simulation studies.
"""
from __future__ import annotations

import io
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, Sequence

from repro.core.dag import IterationCosts

US = 1e-6


@dataclass(frozen=True)
class LayerRecord:
    layer_id: int
    name: str
    forward_us: float
    backward_us: float
    comm_us: float
    size_bytes: float


@dataclass(frozen=True)
class Trace:
    """One or more iterations of layer-wise records.

    ``batch_per_gpu`` is the per-device batch the trace was measured
    at (``# batch:`` header; 0 = unrecorded), used by the ``trace:``
    workload provider to scale times to other batch sizes.

    Every iteration must record the same layers: ragged iterations are
    rejected at construction (a truncated trace file would otherwise
    silently skew :meth:`mean_iteration` or crash on indexing).
    """

    network: str
    cluster: str
    iterations: tuple[tuple[LayerRecord, ...], ...]
    batch_per_gpu: int = 0
    #: Input bytes read+copied per sample (``# bytes-per-sample:``
    #: header; 0 = unrecorded — the workload provider then falls back
    #: to its own default).  The measurement harness records the real
    #: value (token-id bytes for LM steps) so t_io / t_h2d stay honest.
    bytes_per_sample: float = 0.0

    def __post_init__(self):
        if not self.iterations:
            raise ValueError("trace has no iterations")
        counts = {len(it) for it in self.iterations}
        if len(counts) > 1:
            raise ValueError(
                f"ragged trace: iterations record different layer counts "
                f"{sorted(counts)}; every iteration must have the same "
                f"layers")
        if 0 in counts:
            raise ValueError("trace iteration has no layer records")

    @property
    def num_layers(self) -> int:
        return len(self.iterations[0])

    def mean_iteration(self) -> tuple[LayerRecord, ...]:
        """Average each layer over iterations (the paper's suggestion
        for more accurate measurements)."""
        n = len(self.iterations)
        first = self.iterations[0]
        out = []
        for i, rec in enumerate(first):
            f = sum(it[i].forward_us for it in self.iterations) / n
            b = sum(it[i].backward_us for it in self.iterations) / n
            c = sum(it[i].comm_us for it in self.iterations) / n
            out.append(LayerRecord(rec.layer_id, rec.name, f, b, c,
                                   rec.size_bytes))
        return tuple(out)

    def mean_compute_records(self) -> tuple[tuple[LayerRecord, ...],
                                            float | None]:
        """``(compute_records, io_seconds)``: the mean iteration with
        the Caffe ``data`` layer split off as the input-pipeline time
        in **seconds** (``None`` when there is no data layer).

        Caffe traces put the input pipeline in a ``data`` layer whose
        forward time is the blocking fetch+decode (e.g. 1.2 s for
        AlexNet's 1024-batch in Table VI).  This is the one place that
        convention lives; :meth:`to_iteration_costs` and the ``trace:``
        workload provider both consume it.
        """
        recs = list(self.mean_iteration())
        io_time = None
        if recs and recs[0].name == "data":
            io_time = recs[0].forward_us * US
            recs = recs[1:]
        return tuple(recs), io_time

    def to_iteration_costs(self, t_io: float | None = None,
                           t_h2d: float = 0.0, t_u: float = 0.0,
                           data_layer_as_io: bool = True) -> IterationCosts:
        """Convert to seconds-based :class:`IterationCosts`.

        With ``data_layer_as_io`` the Caffe ``data`` layer becomes
        ``t_io`` rather than a compute layer (see
        :meth:`mean_compute_records`).
        """
        if data_layer_as_io:
            recs, io_measured = self.mean_compute_records()
            io_time = io_measured or 0.0
        else:
            recs, io_time = list(self.mean_iteration()), 0.0
        if t_io is not None:
            io_time = t_io
        return IterationCosts(
            t_f=[r.forward_us * US for r in recs],
            t_b=[r.backward_us * US for r in recs],
            t_c=[r.comm_us * US for r in recs],
            t_io=io_time,
            t_h2d=t_h2d,
            t_u=t_u,
            grad_bytes=[r.size_bytes for r in recs],
        )


def write_trace(trace: Trace, path: str | Path) -> None:
    # %.17g is the shortest format that round-trips every float64
    # exactly, so write_trace -> read_trace is the identity.
    with open(path, "w") as f:
        f.write(f"# network: {trace.network}\n# cluster: {trace.cluster}\n")
        if trace.batch_per_gpu:
            f.write(f"# batch: {trace.batch_per_gpu}\n")
        if trace.bytes_per_sample:
            f.write(f"# bytes-per-sample: {trace.bytes_per_sample:.17g}\n")
        f.write("# Id\tName\tForward\tBackward\tComm.\tSize\n")
        for k, it in enumerate(trace.iterations):
            f.write(f"# iteration {k}\n")
            for r in it:
                f.write(f"{r.layer_id}\t{r.name}\t{r.forward_us:.17g}\t"
                        f"{r.backward_us:.17g}\t{r.comm_us:.17g}\t"
                        f"{r.size_bytes:.17g}\n")


def read_trace(path: str | Path, network: str = "", cluster: str = "") -> Trace:
    iterations: list[list[LayerRecord]] = []
    cur: list[LayerRecord] = []
    meta = {"network": network, "cluster": cluster}
    batch = 0
    bytes_per_sample = 0.0
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            if line.startswith("#"):
                body = line.lstrip("# ").strip()
                if body.startswith("network:"):
                    meta["network"] = body.split(":", 1)[1].strip()
                elif body.startswith("cluster:"):
                    meta["cluster"] = body.split(":", 1)[1].strip()
                elif body.startswith("batch:"):
                    value = body.split(":", 1)[1].strip()
                    try:
                        batch = int(value)
                    except ValueError:
                        raise ValueError(
                            f"malformed trace file {path}: '# batch:' "
                            f"value {value!r} is not an integer") from None
                elif body.startswith("bytes-per-sample:"):
                    value = body.split(":", 1)[1].strip()
                    try:
                        bytes_per_sample = float(value)
                    except ValueError:
                        raise ValueError(
                            f"malformed trace file {path}: "
                            f"'# bytes-per-sample:' value {value!r} is not "
                            f"a number") from None
                elif body.startswith("iteration") and cur:
                    iterations.append(cur)
                    cur = []
                continue
            parts = line.split("\t") if "\t" in line else line.split()
            lid, name, fw, bw, cm, sz = parts[:6]
            rec = LayerRecord(int(lid), name, float(fw), float(bw),
                              float(cm), float(sz))
            if cur and rec.layer_id <= cur[-1].layer_id:
                iterations.append(cur)
                cur = []
            cur.append(rec)
    if cur:
        iterations.append(cur)
    if not iterations:
        raise ValueError(f"empty trace file: {path}")
    try:
        return Trace(meta["network"], meta["cluster"],
                     tuple(tuple(it) for it in iterations),
                     batch_per_gpu=batch, bytes_per_sample=bytes_per_sample)
    except ValueError as e:
        raise ValueError(f"malformed trace file {path}: {e}") from None


def make_trace(network: str, cluster: str, rows: Iterable[Sequence],
               n_copies: int = 1, batch_per_gpu: int = 0,
               bytes_per_sample: float = 0.0) -> Trace:
    """Build a Trace from ``(id, name, fwd_us, bwd_us, comm_us, size)`` rows."""
    recs = tuple(LayerRecord(int(r[0]), str(r[1]), float(r[2]), float(r[3]),
                             float(r[4]), float(r[5])) for r in rows)
    return Trace(network, cluster, tuple(recs for _ in range(n_copies)),
                 batch_per_gpu=batch_per_gpu,
                 bytes_per_sample=bytes_per_sample)
