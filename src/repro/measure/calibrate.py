"""Calibration: cross-check and fit the harvested measurements.

Three jobs, all pure accounting over what the harness measured:

* **payload accounting** — gradient all-reduce bytes straight from the
  parameter pytree's shapes (:func:`grad_payload_bytes`), the ground
  truth both the ``jax:`` workload table's ``grad_bytes`` and the HLO
  harvest must agree with;
* **bytes cross-check** — the lowered step's while-loop-scaled HLO
  collective bytes (:mod:`repro.launch.hlo`) against the payload
  accounting, per sync policy (:func:`crosscheck_collective_bytes`).
  Catches drift in any of :mod:`repro.comm.sync`,
  :mod:`repro.launch.hlo` and :mod:`repro.core.workloads`;
* **alpha-beta fit** — measured ``(payload bytes, seconds)``
  all-reduce samples → a latency/bandwidth collective model
  (:func:`fit_alpha_beta`), from which :func:`comm_scale_from_fit`
  builds the ``comm_scale`` the DAG builder uses to cost fused
  gradient buckets.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.common import ModelConfig

#: Cluster-name prefix recorded in measured traces (suffixed with the
#: device count, e.g. ``jax-host-cpu-x8``).
HOST_CLUSTER_NAME = "jax-host-cpu"

#: f32 scalar collectives the ddp step issues besides the gradient
#: sync: ``pmean(total_loss)`` + ``pmean(loss)``.
METRIC_COLLECTIVE_BYTES = 8.0


def grad_payload_bytes(cfg: ModelConfig) -> tuple[float, float]:
    """``(per_unit_bytes, rest_bytes)``: gradient all-reduce payload of
    one scanned unit and of the non-scanned leaves, in the parameter
    dtype — from the parameter pytree's shapes, no allocation."""
    pshape = jax.eval_shape(lambda k: T.init_lm(cfg, k),
                            jax.random.PRNGKey(0))
    unit_bytes = 0.0
    rest_bytes = 0.0
    for path, leaf in jax.tree_util.tree_leaves_with_path(pshape):
        nbytes = float(leaf.size) * jnp.dtype(leaf.dtype).itemsize
        if path and getattr(path[0], "key", None) == "units":
            unit_bytes += nbytes / max(cfg.num_units, 1)
        else:
            rest_bytes += nbytes
    return unit_bytes, rest_bytes


def expected_collective_bytes(cfg: ModelConfig, sync_policy: str) -> float:
    """Bytes one iteration of the lowered ddp step *should* move
    through collectives under ``sync_policy``:

    * ``at_end`` / ``wfbp`` — every parameter's gradient once, in its
      own dtype (one fused pmean vs. layer-wise psums — same total
      payload, different placement);
    * ``bucketed`` — the same gradients upcast to flat **f32** buckets
      (:func:`repro.comm.sync.bucketed_pmean` concatenates in f32), so
      bytes are counted per leaf at 4 bytes/element — parameter trees
      mix dtypes (bf16 weights, f32 norms), so rescaling a
      dtype-weighted total would miscount;

    plus the two scalar metric pmeans every policy issues.
    """
    if sync_policy not in ("at_end", "wfbp", "bucketed"):
        raise ValueError(f"unknown sync policy {sync_policy!r}")
    pshape = jax.eval_shape(lambda k: T.init_lm(cfg, k),
                            jax.random.PRNGKey(0))
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(pshape):
        itemsize = 4.0 if sync_policy == "bucketed" \
            else float(jnp.dtype(leaf.dtype).itemsize)
        total += float(leaf.size) * itemsize
    return total + METRIC_COLLECTIVE_BYTES


@dataclass(frozen=True)
class BytesCrossCheck:
    """One policy's HLO-harvested collective bytes vs. the payload
    accounting (relative error on the HLO side)."""

    policy: str
    hlo_bytes: float
    expected_bytes: float

    @property
    def rel_err(self) -> float:
        if self.expected_bytes == 0:
            return 0.0 if self.hlo_bytes == 0 else float("inf")
        return abs(self.hlo_bytes - self.expected_bytes) / self.expected_bytes


def crosscheck_collective_bytes(cfg: ModelConfig,
                                collective_stats: dict[str, dict],
                                ) -> dict[str, BytesCrossCheck]:
    """Cross-check each measured policy's HLO collective bytes (the
    ``collective_stats`` of a :class:`~repro.measure.harness.
    MeasuredRun`) against :func:`expected_collective_bytes`."""
    return {
        pol: BytesCrossCheck(
            policy=pol,
            hlo_bytes=float(stats["total_bytes"]),
            expected_bytes=expected_collective_bytes(cfg, pol))
        for pol, stats in collective_stats.items()
    }


def fit_alpha_beta(samples: Sequence[tuple[float, float]],
                   ) -> tuple[float, float]:
    """Least-squares alpha-beta fit ``t = alpha + nbytes / beta`` over
    measured ``(payload bytes, seconds)`` all-reduce samples.

    Returns ``(latency_s, bandwidth_bytes_per_s)``.  Repeated samples
    of the same payload collapse to their minimum first (wall-clock
    noise is additive, so the smallest observation is the cleanest —
    the harness's own timing convention).  Degenerate inputs degrade
    gracefully: a single distinct payload pins latency to 0 and takes
    its bandwidth; no samples (single device — no collectives) return
    ``(0, inf)`` so the derived comm cost is exactly 0; a non-positive
    fitted slope (noise) also yields infinite bandwidth, and a negative
    intercept clamps to 0.
    """
    best: dict[float, float] = {}
    for b, t in samples:
        b, t = float(b), float(t)
        if b > 0 and t > 0:
            best[b] = min(t, best.get(b, t))
    if not best:
        return 0.0, float("inf")
    if len(best) == 1:
        (b, t), = best.items()
        return 0.0, b / t
    xs = np.array(sorted(best))
    ys = np.array([best[b] for b in xs])
    slope, icpt = np.polyfit(xs, ys, 1)
    bandwidth = 1.0 / slope if slope > 0 else float("inf")
    return max(float(icpt), 0.0), float(bandwidth)


def comm_scale_from_fit(latency_s: float, bandwidth_bytes_per_s: float,
                        ) -> Callable[[float, float], float]:
    """A ``comm_scale(total_bytes, naive_time) -> seconds`` closure for
    the DAG builder / simulator, from a measured alpha-beta fit — the
    measured counterpart of :func:`repro.core.costmodel.comm_scale_fn`.
    """

    def scale(total_bytes: float, _naive_time: float) -> float:
        if total_bytes <= 0:
            return 0.0
        return latency_s + total_bytes / bandwidth_bytes_per_s

    return scale
