"""Measurement runner / CLI: ``python -m repro.measure --arch <id>``.

jax locks the host device count at first backend init, so the
measurement always executes in a **child process** whose environment
carries ``--xla_force_host_platform_device_count`` (via the shared
:mod:`repro.launch.hostdev` helper, which appends to — never clobbers
— user ``XLA_FLAGS``).  Invoked without the child marker, :func:`main`
re-spawns itself with the right environment; with it, it measures
in-process and writes two artifacts into the measurement directory:

* ``<arch>.trace`` — the paper-format per-layer trace the ``jax:``
  workload provider serves (sweepable like any other workload);
* ``<arch>.json`` — the full harvest: per-policy measured step times,
  HLO collective bytes + cross-checks, the alpha-beta collective fit,
  segmentation and geometry metadata.

The measured model is a host-CPU-feasible ``reduced()`` variant of the
named architecture (geometry on the CLI); the trace records the real
geometry in its headers.  ``--smoke`` picks the tiny CI-sized preset.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import subprocess
import sys
from pathlib import Path

from repro.launch.hostdev import child_env

_CHILD_MARKER = "REPRO_MEASURE_CHILD"

#: Decoder-only archs the explicit-DP step can train as-is (the
#: encoder-decoder and vision archs need extra batch inputs the ddp
#: runtime doesn't carry).
MEASURABLE_ARCHS = (
    "gemma3-1b", "grok-1-314b", "internlm2-20b", "qwen1.5-32b",
    "qwen1.5-4b", "qwen2-moe-a2.7b", "recurrentgemma-2b", "rwkv6-1.6b",
)


@dataclasses.dataclass(frozen=True)
class Geometry:
    """Host-feasible model/measurement geometry."""

    num_layers: int = 8
    d_model: int = 256
    num_heads: int = 4
    d_ff: int = 512
    vocab_size: int = 1024
    seq_len: int = 64
    batch_per_gpu: int = 4
    n_devices: int = 2
    repeats: int = 5
    step_iters: int = 8


SMOKE_GEOMETRY = Geometry(num_layers=4, d_model=128, d_ff=256,
                          vocab_size=512, seq_len=32, batch_per_gpu=2,
                          repeats=3, step_iters=4)


def default_out_dir() -> str:
    from repro.core.workloads import JaxProvider

    return JaxProvider.measure_dir()


def run_measurement(arch: str, out_dir: str | Path,
                    geometry: Geometry,
                    policies: tuple[str, ...] | None = None) -> dict:
    """Measure ``arch`` in-process (device count must already be
    forced), write ``<arch>.trace`` + ``<arch>.json`` into ``out_dir``,
    and return the JSON document."""
    from repro.configs import get_config
    from repro.measure import calibrate
    from repro.measure.harness import MEASURED_SYNC_POLICIES, measure_model
    from repro.traces.format import write_trace

    g = geometry
    cfg = get_config(arch).reduced(
        num_layers=g.num_layers, d_model=g.d_model, num_heads=g.num_heads,
        d_ff=g.d_ff, vocab_size=g.vocab_size)
    run = measure_model(
        cfg, arch=arch, n_devices=g.n_devices,
        batch_per_gpu=g.batch_per_gpu, seq_len=g.seq_len,
        policies=policies or MEASURED_SYNC_POLICIES,
        repeats=g.repeats, step_iters=g.step_iters)

    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    trace_path = out_dir / f"{arch}.trace"
    write_trace(run.trace, trace_path)

    latency, bandwidth = calibrate.fit_alpha_beta(run.allreduce_samples)
    checks = calibrate.crosscheck_collective_bytes(cfg, run.collective_stats)
    doc = dict(run.summary())
    doc.update({
        "workload": f"jax:{arch}",
        "trace_path": str(trace_path),
        "allreduce_fit": {"latency_s": latency,
                          "bandwidth_bytes_per_s": bandwidth},
        "bytes_crosscheck": {
            pol: {"hlo_bytes": c.hlo_bytes,
                  "expected_bytes": c.expected_bytes,
                  "rel_err": c.rel_err}
            for pol, c in checks.items()},
    })
    (out_dir / f"{arch}.json").write_text(json.dumps(doc, indent=2))
    return doc


#: Geometry field -> CLI flag; everything not listed here is the field
#: name with underscores dashed (the one derivation shared by the
#: parser and the subprocess command builder).
_FLAG_OVERRIDES = {"n_devices": "--devices"}


def _geometry_flag(field_name: str) -> str:
    return _FLAG_OVERRIDES.get(field_name,
                               "--" + field_name.replace("_", "-"))


def _marked_child_env(n_devices: int) -> dict[str, str]:
    """Environment for the measurement child: forced host devices, the
    re-spawn marker, and this repo's ``src`` on PYTHONPATH so the child
    resolves ``repro`` regardless of cwd."""
    env = child_env(n_devices)
    env[_CHILD_MARKER] = "1"
    src = str(Path(__file__).resolve().parents[2])
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    return env


def _spawn_child(child_argv: list[str], n_devices: int, *,
                 capture: bool, timeout: float | None = None):
    """The one spawn contract for measurement children — CLI re-spawn
    and programmatic runs must never diverge."""
    return subprocess.run(
        [sys.executable, "-m", "repro.measure.run", *child_argv],
        env=_marked_child_env(n_devices),
        capture_output=capture, text=capture, timeout=timeout)


def measure_in_subprocess(arch: str, *, out_dir: str | Path,
                          geometry: Geometry = SMOKE_GEOMETRY,
                          policies: tuple[str, ...] | None = None,
                          timeout: float = 1800) -> dict:
    """Spawn the measurement child for ``arch`` and return its JSON
    document — the entry point for benchmarks/tests whose own process
    must keep the single-device view."""
    argv = ["--arch", arch, "--out-dir", str(out_dir)]
    for f in dataclasses.fields(Geometry):
        argv += [_geometry_flag(f.name), str(getattr(geometry, f.name))]
    if policies:
        argv += ["--policies", ",".join(policies)]
    r = _spawn_child(argv, geometry.n_devices, capture=True,
                     timeout=timeout)
    if r.returncode != 0:
        raise RuntimeError(
            f"measurement subprocess for {arch!r} failed "
            f"(rc={r.returncode}):\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
    return json.loads(
        (Path(out_dir) / f"{arch}.json").read_text())


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="repro.measure",
        description="Measure a real jax train step into a sweepable "
                    "jax: workload trace.")
    p.add_argument("--arch", required=True, choices=MEASURABLE_ARCHS)
    p.add_argument("--out-dir", default=None,
                   help="measurement directory (default: "
                        "$REPRO_MEASURE_DIR or results/measure/)")
    # geometry flags default to None so "explicitly passed" is
    # distinguishable from "follow the preset" (--smoke or full)
    full, smoke = Geometry(), SMOKE_GEOMETRY
    for f in dataclasses.fields(Geometry):
        p.add_argument(_geometry_flag(f.name), type=int, default=None,
                       dest=f.name,
                       help=f"default {getattr(full, f.name)} "
                            f"(--smoke: {getattr(smoke, f.name)})")
    p.add_argument("--policies", default=None,
                   help="comma-separated sync policies "
                        "(default: at_end,wfbp,bucketed)")
    p.add_argument("--smoke", action="store_true",
                   help="tiny CI-sized geometry preset (individual "
                        "geometry flags still win)")
    return p


def _geometry_from_args(args: argparse.Namespace) -> Geometry:
    base = SMOKE_GEOMETRY if args.smoke else Geometry()
    return dataclasses.replace(base, **{
        f.name: getattr(args, f.name) for f in dataclasses.fields(Geometry)
        if getattr(args, f.name) is not None})


def main(argv: list[str] | None = None) -> int:
    args = build_parser().parse_args(argv)
    geometry = _geometry_from_args(args)
    out_dir = args.out_dir or default_out_dir()
    policies = tuple(t.strip() for t in args.policies.split(",")
                     if t.strip()) if args.policies else None

    if os.environ.get(_CHILD_MARKER) != "1":
        # re-spawn with the forced-host-device environment
        child_argv = sys.argv[1:] if argv is None else list(argv)
        return _spawn_child(child_argv, geometry.n_devices,
                            capture=False).returncode

    doc = run_measurement(args.arch, out_dir, geometry, policies)
    brief = {k: doc[k] for k in
             ("workload", "trace_path", "n_devices", "policy_times_s",
              "t_update_s", "allreduce_fit", "bytes_crosscheck",
              "elapsed_s")}
    print(json.dumps(brief, indent=2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
