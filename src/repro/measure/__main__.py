"""``python -m repro.measure`` — delegate to :mod:`repro.measure.run`."""
import sys

from repro.measure.run import main

sys.exit(main())
