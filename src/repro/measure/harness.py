"""Per-layer timing harness over the repo's *real* jax train steps.

Runs the explicit data-parallel S-SGD step (:mod:`repro.comm.ddp`)
under each gradient-sync policy on forced host devices and harvests
everything the DAG model needs:

* **whole-step wall time per policy** — the "measured" side of the
  paper's Fig. 4 comparison;
* **per-layer forward/backward seconds**, segmented via the layer-scan
  structure: the transformer executes ``num_units`` trips of one scan
  body over stacked parameters, so timing the jitted loss (forward)
  and its gradient (forward+backward) at two scan depths and fitting a
  line gives the per-trip (per-layer) cost as the slope and the
  non-scanned remainder (embedding + head + loss) as the intercept —
  measuring the *actual compiled scan body*, not a re-implementation;
* **per-payload collective times** on the same device mesh (one
  ``psum`` per distinct gradient payload), which both fill the trace's
  Comm. column and feed the alpha-beta fit in
  :mod:`repro.measure.calibrate`;
* **optimizer-update time** (``t_u``) and **HLO collective bytes** per
  policy (via :mod:`repro.launch.hlo`, while-loop-scaled) for the
  bytes cross-check.

The result is emitted as a paper-format
:class:`~repro.traces.format.Trace` (§VI), so measured runs round-trip
through the exact machinery the published traces use, and the ``jax:``
workload provider serves them to the sweep engine.

Requires the host platform to expose enough devices — spawn through
:mod:`repro.measure.run` (or set
:func:`repro.launch.hostdev.force_host_device_count` before the first
jax import).
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.comm.ddp import make_ddp_train_step, shard_map_compat
from repro.comm.sync import DEFAULT_BUCKET_BYTES
from repro.launch import hlo as hlo_mod
from repro.launch.mesh import make_dp_mesh
from repro.measure.calibrate import HOST_CLUSTER_NAME, grad_payload_bytes
from repro.models import transformer as T
from repro.models.common import ModelConfig
from repro.optim.sgd import sgd
from repro.traces.format import LayerRecord, Trace

#: The executable gradient-sync policies the harness measures (the
#: "none" policy is the single-device baseline, not a sync schedule).
MEASURED_SYNC_POLICIES = ("at_end", "wfbp", "bucketed")


# ----------------------------------------------------------------------
# Timing primitives
# ----------------------------------------------------------------------
def _timeit(fn: Callable, repeats: int) -> float:
    """Minimum wall seconds of ``fn()`` after one warmup call; ``fn``
    must block on its own result (callers wrap with
    ``jax.block_until_ready``).  Minimum, not median: wall-clock noise
    on a shared host is strictly additive, so the smallest observation
    is the least-contaminated estimate — which matters for the
    segmentation slopes, where noise comparable to one scan trip would
    otherwise leak into the per-layer costs."""
    fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


# ----------------------------------------------------------------------
# Scan-structure segmentation (pure math, unit-tested)
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class SegmentTiming:
    """Per-layer costs segmented out of the scan: seconds per scanned
    unit (slope) and for the non-scanned remainder (intercept)."""

    unit_fwd_s: float
    unit_bwd_s: float
    rest_fwd_s: float
    rest_bwd_s: float


def segment_from_depths(units: Sequence[int], fwd_s: Sequence[float],
                        full_s: Sequence[float]) -> SegmentTiming:
    """Least-squares segmentation: ``fwd_s[i]`` (forward-only) and
    ``full_s[i]`` (forward+backward) are measured wall seconds at scan
    depth ``units[i]``.  The fitted slope is the per-unit cost, the
    intercept the non-scanned remainder; backward = full − forward.
    Negative values (timing noise on near-zero terms) clamp to 0.
    """
    if len(units) < 2:
        raise ValueError("need at least two scan depths to segment")
    u = np.asarray(units, dtype=np.float64)
    if len(set(units)) < 2:
        raise ValueError("scan depths must be distinct")
    f_slope, f_icpt = np.polyfit(u, np.asarray(fwd_s, dtype=np.float64), 1)
    t_slope, t_icpt = np.polyfit(u, np.asarray(full_s, dtype=np.float64), 1)
    unit_fwd = max(float(f_slope), 0.0)
    rest_fwd = max(float(f_icpt), 0.0)
    return SegmentTiming(
        unit_fwd_s=unit_fwd,
        unit_bwd_s=max(float(t_slope) - unit_fwd, 0.0),
        rest_fwd_s=rest_fwd,
        rest_bwd_s=max(float(t_icpt) - rest_fwd, 0.0),
    )


def _depth_variant(cfg: ModelConfig, n_units: int) -> ModelConfig:
    """Same family at a different scan depth: ``n_units`` pattern trips
    with the remainder-block count preserved, everything else equal."""
    rem = cfg.num_layers % len(cfg.layer_pattern)
    return dataclasses.replace(
        cfg, name=f"{cfg.name}-u{n_units}",
        num_layers=n_units * len(cfg.layer_pattern) + rem)


def _default_depths(cfg: ModelConfig) -> tuple[int, int]:
    u = cfg.num_units
    if u < 1:
        raise ValueError(
            f"{cfg.name}: segmentation needs at least one scanned unit "
            f"(num_layers {cfg.num_layers} < pattern "
            f"{cfg.layer_pattern!r})")
    # a 2x depth spread keeps the fitted slope well above wall-clock
    # noise even for tiny smoke models (one extra trip would not)
    return (u, 2 * u)


# ----------------------------------------------------------------------
# The measurement itself
# ----------------------------------------------------------------------
@dataclass
class MeasuredRun:
    """Everything one instrumented-execution run harvested."""

    arch: str
    config_name: str
    n_devices: int
    batch_per_gpu: int
    seq_len: int
    num_units: int
    depths: tuple[int, int]
    trace: Trace                          # per-layer fwd/bwd/comm + bytes
    segments: SegmentTiming
    policy_times: dict[str, float]        # measured wall s/iter per policy
    collective_stats: dict[str, dict]     # per policy: HLO-harvested bytes
    t_update_s: float
    allreduce_samples: list[tuple[float, float]]   # (payload bytes, seconds)
    unit_grad_bytes: float
    rest_grad_bytes: float
    elapsed_s: float

    @property
    def total_grad_bytes(self) -> float:
        return self.rest_grad_bytes + self.num_units * self.unit_grad_bytes

    def summary(self) -> dict:
        """JSON-serializable record (everything but the trace body)."""
        return {
            "arch": self.arch,
            "config": self.config_name,
            "n_devices": self.n_devices,
            "batch_per_gpu": self.batch_per_gpu,
            "seq_len": self.seq_len,
            "num_units": self.num_units,
            "depths": list(self.depths),
            "policy_times_s": self.policy_times,
            "collective_stats": self.collective_stats,
            "t_update_s": self.t_update_s,
            "allreduce_samples": [[b, t] for b, t in self.allreduce_samples],
            "unit_grad_bytes": self.unit_grad_bytes,
            "rest_grad_bytes": self.rest_grad_bytes,
            "total_grad_bytes": self.total_grad_bytes,
            "segments": dataclasses.asdict(self.segments),
            "elapsed_s": self.elapsed_s,
        }


def _time_segments(cfg: ModelConfig, depths: Sequence[int],
                   batch_per_gpu: int, seq_len: int,
                   repeats: int) -> SegmentTiming:
    """Jit the loss (forward) and its gradient (forward+backward) at
    each scan depth on one device, time them, and segment."""
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (batch_per_gpu, seq_len), 0,
                                cfg.vocab_size)
    labels = jax.random.randint(jax.random.PRNGKey(1),
                                (batch_per_gpu, seq_len), 0, cfg.vocab_size)
    fwd_s, full_s = [], []
    for u in depths:
        cfg_u = _depth_variant(cfg, u)
        params = T.init_lm(cfg_u, jax.random.PRNGKey(2))

        def loss(p, c=cfg_u):
            return T.loss_fn(c, p, tokens, labels)[0]

        fwd = jax.jit(loss)
        bwd = jax.jit(jax.value_and_grad(loss))
        fwd_s.append(_timeit(
            lambda: jax.block_until_ready(fwd(params)), repeats))
        full_s.append(_timeit(
            lambda: jax.block_until_ready(bwd(params)), repeats))
    return segment_from_depths(list(depths), fwd_s, full_s)


def _time_allreduce(mesh, nbytes: float, repeats: int) -> float:
    """Measured wall seconds of one data-parallel mean all-reduce of a
    ``nbytes``-per-rank f32 payload on ``mesh`` (0.0 on one device —
    no collective is issued, matching the model's ``n=1`` convention).
    """
    n_dev = mesh.devices.size
    if n_dev <= 1 or nbytes <= 0:
        return 0.0
    from jax.sharding import PartitionSpec as P

    n = max(int(nbytes) // 4, 1)
    arr = jnp.ones((n_dev, n), jnp.float32)
    fn = jax.jit(shard_map_compat(
        lambda x: jax.lax.pmean(x, "data"), mesh,
        in_specs=P("data"), out_specs=P("data")))
    return _timeit(lambda: jax.block_until_ready(fn(arr)), repeats)


def _time_policy_step(cfg: ModelConfig, mesh, policy: str,
                      batch: dict, step_iters: int,
                      bucket_bytes: float) -> tuple[float, dict]:
    """(measured seconds/iteration, HLO collective stats) for one
    executable sync policy: AOT-compile the ddp step once, read its
    optimized HLO for the bytes harvest, then run it ``step_iters``
    times back-to-back (outputs re-fed, one trailing block) — the
    steady-pipeline timing of the paper's measurements."""
    opt = sgd(lr=1e-2, momentum=0.9)
    step = make_ddp_train_step(cfg, opt, mesh, sync_policy=policy,
                               bucket_bytes=bucket_bytes)
    params = T.init_lm(cfg, jax.random.PRNGKey(3))
    opt_state = opt.init(params)
    compiled = step.lower(params, opt_state, batch).compile()
    stats = hlo_mod.collective_stats(
        compiled.as_text(), loop_trip_count=max(cfg.num_units, 1))

    p, st, m = compiled(params, opt_state, batch)      # warmup
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(step_iters):
        p, st, m = compiled(p, st, batch)
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / step_iters, stats.to_dict()


def measure_model(cfg: ModelConfig, *, arch: str = "",
                  n_devices: int = 2, batch_per_gpu: int = 2,
                  seq_len: int = 32,
                  policies: Sequence[str] = MEASURED_SYNC_POLICIES,
                  depths: tuple[int, int] | None = None,
                  repeats: int = 3, step_iters: int = 5,
                  bucket_bytes: float = DEFAULT_BUCKET_BYTES) -> MeasuredRun:
    """Instrument ``cfg``'s train step end to end on ``n_devices``
    forced host devices and return the full :class:`MeasuredRun`.

    ``batch_per_gpu`` is the per-device batch (the global batch is
    ``batch_per_gpu * n_devices``); segmentation and collective timing
    run at the per-device view, exactly how the paper measured
    per-layer costs on one GPU of the cluster.
    """
    t_start = time.perf_counter()
    avail = len(jax.devices())
    if avail < n_devices:
        raise RuntimeError(
            f"need {n_devices} devices but jax sees {avail}; spawn via "
            f"`python -m repro.measure` (or call "
            f"repro.launch.hostdev.force_host_device_count before the "
            f"first jax import)")
    mesh = make_dp_mesh(n_devices)
    depths = depths or _default_depths(cfg)

    B = batch_per_gpu * n_devices
    key = jax.random.PRNGKey(0)
    batch = {
        "tokens": jax.random.randint(key, (B, seq_len), 0, cfg.vocab_size),
        "labels": jax.random.randint(jax.random.PRNGKey(1), (B, seq_len),
                                     0, cfg.vocab_size),
    }

    # 1) whole-step wall time + HLO collective bytes, per policy
    policy_times: dict[str, float] = {}
    collective: dict[str, dict] = {}
    for pol in policies:
        policy_times[pol], collective[pol] = _time_policy_step(
            cfg, mesh, pol, batch, step_iters, bucket_bytes)

    # 2) per-layer segmentation via the scan structure (one device)
    segments = _time_segments(cfg, depths, batch_per_gpu, seq_len, repeats)

    # 3) gradient payloads + measured collectives per distinct payload
    unit_bytes, rest_bytes = grad_payload_bytes(cfg)
    total_bytes = rest_bytes + cfg.num_units * unit_bytes
    samples: list[tuple[float, float]] = []
    comm_of: dict[float, float] = {}
    for nbytes in sorted({unit_bytes, rest_bytes, total_bytes}):
        t = _time_allreduce(mesh, nbytes, repeats)
        comm_of[nbytes] = t
        if nbytes > 0 and t > 0:
            samples.append((nbytes, t))

    # 4) optimizer update (t_u)
    opt = sgd(lr=1e-2, momentum=0.9)
    params = T.init_lm(cfg, jax.random.PRNGKey(2))
    st = opt.init(params)
    g = jax.tree_util.tree_map(jnp.ones_like, params)
    upd = jax.jit(lambda g, s, p: opt.update(g, s, p))
    t_update = _timeit(lambda: jax.block_until_ready(upd(g, st, params)),
                       repeats)

    # 5) paper-format trace: the non-scanned remainder (embedding +
    # head + loss) as layer 0, one record per scanned unit.  Layer 0's
    # gradients genuinely release last in backward (the embedding), so
    # WFBP ordering is preserved; times in microseconds, per §VI.
    us = 1e6
    recs = [LayerRecord(0, "embed_head", segments.rest_fwd_s * us,
                        segments.rest_bwd_s * us,
                        comm_of.get(rest_bytes, 0.0) * us, rest_bytes)]
    for i in range(cfg.num_units):
        recs.append(LayerRecord(i + 1, f"unit{i}",
                                segments.unit_fwd_s * us,
                                segments.unit_bwd_s * us,
                                comm_of.get(unit_bytes, 0.0) * us,
                                unit_bytes))
    trace = Trace(
        network=cfg.name,
        cluster=f"{HOST_CLUSTER_NAME}-x{n_devices}",
        iterations=(tuple(recs),),
        batch_per_gpu=batch_per_gpu,
        # int32 tokens + labels per sample position
        bytes_per_sample=8.0 * seq_len,
    )

    return MeasuredRun(
        arch=arch or cfg.name,
        config_name=cfg.name,
        n_devices=n_devices,
        batch_per_gpu=batch_per_gpu,
        seq_len=seq_len,
        num_units=cfg.num_units,
        depths=tuple(depths),
        trace=trace,
        segments=segments,
        policy_times=policy_times,
        collective_stats=collective,
        t_update_s=t_update,
        allreduce_samples=samples,
        unit_grad_bytes=unit_bytes,
        rest_grad_bytes=rest_bytes,
        elapsed_s=time.perf_counter() - t_start,
    )
