"""Instrumented execution: measure this repo's *real* jax train steps
and feed them back into the DAG model (paper §V-D / §VI, closed).

The paper's validation loop is measure → trace → DAG → predict →
compare-with-measurement; its companion framework study shows the
per-layer costs must come from instrumented execution, not FLOP
counts.  This package is that loop for the repo's own executable
stack:

* :mod:`repro.measure.harness` — run :func:`repro.comm.ddp` train
  steps under each gradient-sync policy on forced host devices,
  segment per-layer forward/backward seconds out of the layer-scan
  structure, time collectives and the optimizer update, and emit a
  paper-format :class:`~repro.traces.format.Trace`;
* :mod:`repro.measure.calibrate` — cross-check harvested collective
  bytes against the HLO analysis (:mod:`repro.launch.hlo`) and the
  workload table's ``grad_bytes``, and fit an alpha-beta collective
  model to the measured all-reduces;
* :mod:`repro.measure.run` — the CLI / subprocess runner
  (``python -m repro.measure --arch <id>``): spawns itself with the
  forced-host-platform flag (shared helper
  :mod:`repro.launch.hostdev`), writes ``<arch>.trace`` +
  ``<arch>.json`` into the measurement directory, from which the
  ``jax:`` workload provider (:mod:`repro.core.workloads`) serves
  sweepable tables.

``benchmarks/bench_model_vs_measured.py`` closes the Fig.-4 circle:
model-predicted vs measured iteration time per sync policy, gated in
CI.
"""
from repro.measure.calibrate import (HOST_CLUSTER_NAME, BytesCrossCheck,
                                     comm_scale_from_fit,
                                     crosscheck_collective_bytes,
                                     expected_collective_bytes,
                                     fit_alpha_beta, grad_payload_bytes)
from repro.measure.harness import (MEASURED_SYNC_POLICIES, MeasuredRun,
                                   measure_model, segment_from_depths)

__all__ = [
    "MEASURED_SYNC_POLICIES", "MeasuredRun", "measure_model",
    "segment_from_depths", "grad_payload_bytes", "fit_alpha_beta",
    "comm_scale_from_fit", "expected_collective_bytes",
    "crosscheck_collective_bytes", "BytesCrossCheck", "HOST_CLUSTER_NAME",
]
