"""Optimizers: SGD(+momentum) — the paper's algorithm — and AdamW.

Minimal optax-style interface: ``opt.init(params) -> state`` and
``opt.update(grads, state, params) -> (new_params, new_state)``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any


@dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Params], tuple[Params, Any]]


def sgd(lr: float, momentum: float = 0.9, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mom": jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params):
        def upd(p, g, m):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            if momentum:
                m = momentum * m + g
                g = m
            p_new = p.astype(jnp.float32) - lr * g
            return p_new.astype(p.dtype), m

        if momentum:
            out = jax.tree_util.tree_map(upd, params, grads, state["mom"])
            new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                                is_leaf=lambda t: isinstance(t, tuple))
            new_mom = jax.tree_util.tree_map(lambda t: t[1], out,
                                             is_leaf=lambda t: isinstance(t, tuple))
            return new_params, {"mom": new_mom}
        new_params = jax.tree_util.tree_map(
            lambda p, g: upd(p, g, None)[0], params, grads)
        return new_params, state

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree_util.tree_map(zeros, params),
                "v": jax.tree_util.tree_map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            upd_ = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            p_new = p.astype(jnp.float32) - lr * (upd_ + weight_decay
                                                  * p.astype(jnp.float32))
            return p_new.astype(p.dtype), m, v

        trip = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
        is3 = lambda t: isinstance(t, tuple)
        new_params = jax.tree_util.tree_map(lambda t: t[0], trip, is_leaf=is3)
        m = jax.tree_util.tree_map(lambda t: t[1], trip, is_leaf=is3)
        v = jax.tree_util.tree_map(lambda t: t[2], trip, is_leaf=is3)
        return new_params, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))
