"""Host-side data pipeline with background prefetch.

Implements the paper's first optimization opportunity — *overlapping
I/O with computing* (§IV-C, tasks T36–T43 of Fig. 1): a producer
thread fetches + preprocesses the next mini-batches and stages them
onto the device(s) while the current step computes.  The loader
records per-batch ``t_io`` (fetch) and ``t_h2d`` (device_put) so real
runs can emit paper-format traces.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterator

import jax
import numpy as np


@dataclass
class SyntheticLMDataset:
    """Deterministic synthetic token stream (documents of random
    tokens with next-token labels)."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    simulate_io_seconds: float = 0.0    # inject disk latency (experiments)

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        while True:
            if self.simulate_io_seconds:
                time.sleep(self.simulate_io_seconds)
            tokens = rng.integers(0, self.vocab_size,
                                  (self.batch_size, self.seq_len + 1),
                                  dtype=np.int32)
            yield {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


@dataclass
class BatchTiming:
    t_io: float
    t_h2d: float


class PrefetchLoader:
    """Producer-consumer loader with ``depth`` staged batches.

    ``depth=0`` disables prefetching (the naive S-SGD of Eq. (2):
    fetch blocks the step).  ``device_put_fn`` lets the trainer stage
    batches with the right sharding.
    """

    def __init__(self, dataset, depth: int = 2,
                 device_put_fn: Callable[[Any], Any] | None = None):
        self.dataset = iter(dataset)
        self.depth = depth
        self.device_put = device_put_fn or jax.device_put
        self.timings: list[BatchTiming] = []
        self._q: queue.Queue = queue.Queue(maxsize=max(depth, 1))
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        if depth > 0:
            self._thread = threading.Thread(target=self._producer, daemon=True)
            self._thread.start()

    def _fetch_and_stage(self):
        t0 = time.perf_counter()
        batch = next(self.dataset)
        t1 = time.perf_counter()
        staged = self.device_put(batch)
        jax.tree_util.tree_map(
            lambda x: x.block_until_ready() if hasattr(x, "block_until_ready")
            else x, staged)
        t2 = time.perf_counter()
        self.timings.append(BatchTiming(t_io=t1 - t0, t_h2d=t2 - t1))
        return staged

    def _producer(self):
        while not self._stop.is_set():
            try:
                item = self._fetch_and_stage()
            except StopIteration:
                self._q.put(None)
                return
            self._q.put(item)

    def __iter__(self):
        return self

    def __next__(self):
        if self.depth == 0:
            return self._fetch_and_stage()
        item = self._q.get()
        if item is None:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass

    def mean_t_io(self) -> float:
        return float(np.mean([t.t_io for t in self.timings])) if self.timings else 0.0

    def mean_t_h2d(self) -> float:
        return float(np.mean([t.t_h2d for t in self.timings])) if self.timings else 0.0
