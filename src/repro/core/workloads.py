"""Pluggable workload registry: every per-layer cost source the DAG
model can consume, resolvable by name from a :class:`Scenario`.

The paper's point (§VI) is that the DAG model is agnostic to where the
per-layer costs come from — analytic layer tables (Table IV), measured
traces (Table VI), or any other profile.  This module makes that
pluggable: a *workload name* resolves through a scheme-prefixed
registry to a :class:`WorkloadTable`, the single construction path for
:class:`~repro.core.dag.IterationCosts` shared by the sweep engine's
analytical fast path and the event-driven simulator.

Naming scheme (``scheme:spec``):

* ``cnn:<name>`` — the paper's Table-IV layer tables from
  :mod:`repro.core.costmodel` (``alexnet``, ``googlenet``,
  ``resnet50``).  Bare names without a scheme resolve here for
  backward compatibility.
* ``trace:<name-or-path>`` — measured layer traces: the bundled
  Table VI (``trace:alexnet-k80``) or any on-disk file in the paper's
  trace format (``trace:path/to/file.trace``).  Compute times are the
  measured ones; comm is re-derived from the per-layer gradient bytes
  so traces sweep across worker counts / collectives / interconnects.
* ``llm:<arch>`` — per-block layer costs sliced out of
  :func:`repro.core.archcost.block_cost_table` for every config in
  :mod:`repro.configs` (``llm:gemma3-1b``, ``llm:qwen1.5-32b``, …),
  with bf16 gradient payloads and pattern-aware blocks, at the
  ``train_4k`` sequence length.
* ``jax:<name-or-path>`` — **measured** per-layer costs harvested from
  this repo's own executed jax train steps by the measurement harness
  (``python -m repro.measure``, :mod:`repro.measure`).  Resolves trace
  files from the measurement directory (``REPRO_MEASURE_DIR`` env var,
  default ``results/measure/``) by stem, or any explicit path.  Same
  measured-table semantics as ``trace:`` — compute times are the
  instrumented ones, comm is re-derived from per-layer gradient bytes
  — which is what closes the model↔measurement loop: a lowered,
  executed model sweeps across clusters/workers/collectives like any
  analytic table.

Tables are memoized at module scope (:func:`resolve_workload`), so
repeated ``sweep()`` / ``evaluate_scenario()`` calls never rebuild a
layer list.
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Protocol, runtime_checkable

import numpy as np

from repro.core.costmodel import CNN_WORKLOADS, total_params, update_time
from repro.core.dag import IterationCosts
from repro.core.hardware import ClusterSpec

#: Sequence length the ``llm:`` provider costs one "sample" at (one
#: sample = one sequence), matching ``repro.configs.shapes.TRAIN_4K``.
LLM_SEQ_LEN = 4096

#: Bytes of input read/copied per LLM sample: int32 token ids.
LLM_BYTES_PER_TOKEN = 4.0


@dataclass(frozen=True, eq=False)
class WorkloadTable:
    """Per-workload layer arrays — built once, memoized, and shared.

    Exactly one compute-time source is set:

    * **analytic** (``flops_fwd`` is not ``None``): per-sample forward
      flops per layer; times derive from the cluster's achieved rate,
      backward = ``bwd_fwd_ratio`` × forward.
    * **measured** (``t_f``/``t_b`` are not ``None``): per-layer
      seconds measured at ``batch_default`` samples; times scale
      linearly with the requested batch.

    ``grad_bytes`` is always the per-layer all-reduce payload in bytes
    (f32 for CNN tables, bf16 for LLM configs, verbatim for traces),
    which is what lets every source sweep across worker counts,
    collectives and interconnects.
    """

    name: str
    grad_bytes: np.ndarray            # (L,) all-reduce payload per layer
    batch_default: int                # samples/GPU when the scenario says None
    bytes_per_sample: float           # input bytes read + copied per sample
    param_bytes: float                # total parameter bytes (for t_u)
    flops_fwd: np.ndarray | None = None   # (L,) per-sample fwd flops (analytic)
    t_f: np.ndarray | None = None         # (L,) measured fwd seconds @ batch_default
    t_b: np.ndarray | None = None         # (L,) measured bwd seconds @ batch_default
    t_io_measured: float | None = None    # measured input-pipeline seconds
    bwd_fwd_ratio: float = 2.0
    batch_locked: bool = False        # True: measured batch unknown, no rescale

    @property
    def num_layers(self) -> int:
        return len(self.grad_bytes)

    @property
    def is_measured(self) -> bool:
        return self.t_f is not None

    def iteration_costs(self, cluster: ClusterSpec, batch_per_gpu: int,
                        n_workers: int, collective: str = "ring",
                        bwd_fwd_ratio: float | None = None,
                        bytes_per_sample: float | None = None,
                        decode_seconds_per_byte: float = 0.0) -> IterationCosts:
        """The paper's Table-I cost vocabulary (seconds) on a concrete
        cluster — the one construction path used by both the analytical
        fast path and the simulator fallback, so the two cannot drift.

        ``bytes_per_sample`` overrides the table's own;
        ``bwd_fwd_ratio`` and ``decode_seconds_per_byte`` work exactly
        as in :func:`repro.core.costmodel.make_iteration_costs` but
        apply to analytic tables only — a measured trace carries its
        own backward times and its input-pipeline time already
        includes the decode, so overriding either there is an error.

        All per-layer entries come back as NumPy float64 arrays; the
        closed forms in :mod:`repro.core.analytical` evaluate them
        directly and the DAG builder iterates them as scalars.
        """
        if self.is_measured:
            if self.batch_locked and batch_per_gpu != self.batch_default:
                raise ValueError(
                    f"workload {self.name!r} has no recorded batch size "
                    f"(no '# batch:' header in the trace), so its measured "
                    f"times cannot be rescaled to batch_per_gpu="
                    f"{batch_per_gpu}; leave batch_per_gpu unset")
            if bwd_fwd_ratio is not None:
                raise ValueError(
                    f"bwd_fwd_ratio does not apply to measured workload "
                    f"{self.name!r}: the trace carries its own backward "
                    f"times")
            if decode_seconds_per_byte:
                raise ValueError(
                    f"decode_seconds_per_byte does not apply to measured "
                    f"workload {self.name!r}: the trace's input-pipeline "
                    f"time already includes the decode")
            scale = batch_per_gpu / self.batch_default
            t_f = self.t_f * scale
            t_b = self.t_b * scale
        else:
            ratio = self.bwd_fwd_ratio if bwd_fwd_ratio is None \
                else bwd_fwd_ratio
            t_f = cluster.compute_time(self.flops_fwd * batch_per_gpu)
            t_b = ratio * t_f
        if n_workers > 1:
            t_c = np.where(
                self.grad_bytes > 0,
                cluster.allreduce_time(self.grad_bytes, n_workers, collective),
                0.0)
        else:
            t_c = np.zeros_like(t_f)
        bps = self.bytes_per_sample if bytes_per_sample is None \
            else bytes_per_sample
        nbytes_in = batch_per_gpu * bps
        if self.t_io_measured is not None:
            t_io = self.t_io_measured * batch_per_gpu / self.batch_default
        else:
            t_io = cluster.io_time(nbytes_in) \
                + decode_seconds_per_byte * nbytes_in
        return IterationCosts(
            t_f=t_f, t_b=t_b, t_c=t_c,
            t_io=t_io,
            t_h2d=cluster.h2d_time(nbytes_in),
            t_u=update_time(self.param_bytes, cluster),
            grad_bytes=self.grad_bytes)


@runtime_checkable
class WorkloadProvider(Protocol):
    """One workload family: resolves ``spec`` (the part after the
    scheme prefix) to a :class:`WorkloadTable`."""

    scheme: str

    def names(self) -> tuple[str, ...]:
        """Enumerable specs (for error messages and docs); providers
        accepting open-ended specs (file paths) list their fixed ones."""
        ...

    def build(self, spec: str) -> WorkloadTable:
        """Build the table, raising ``ValueError`` for unknown specs."""
        ...


# ----------------------------------------------------------------------
# cnn: — the paper's Table-IV analytic layer tables.
# ----------------------------------------------------------------------
class CNNProvider:
    scheme = "cnn"

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(CNN_WORKLOADS))

    def build(self, spec: str) -> WorkloadTable:
        try:
            builder, batch, bytes_per_sample = CNN_WORKLOADS[spec]
        except KeyError:
            raise ValueError(f"unknown cnn workload {spec!r}; "
                             f"one of {sorted(CNN_WORKLOADS)}") from None
        layers = builder()
        return WorkloadTable(
            name=f"cnn:{spec}",
            flops_fwd=np.array([l.flops_fwd for l in layers], dtype=np.float64),
            grad_bytes=np.array([l.grad_bytes for l in layers], dtype=np.float64),
            batch_default=batch,
            bytes_per_sample=bytes_per_sample,
            param_bytes=4.0 * total_params(layers))


# ----------------------------------------------------------------------
# trace: — measured layer traces (bundled Table VI or on-disk files).
# ----------------------------------------------------------------------
class TraceProvider:
    scheme = "trace"

    #: Default on-disk bytes/sample when the trace doesn't say (ImageNet
    #: JPEG, the paper's Table IV figure — only feeds t_h2d since traces
    #: carry their own measured input-pipeline time).
    bytes_per_sample = 110e3

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._bundled()))

    @staticmethod
    def _bundled():
        from repro.traces.bundled import BUNDLED_TRACES

        return BUNDLED_TRACES

    def build(self, spec: str) -> WorkloadTable:
        bundled = self._bundled()
        if spec in bundled:
            return self.table_from_trace(bundled[spec], f"trace:{spec}")
        if os.path.exists(spec):
            from repro.traces.format import read_trace

            return self.table_from_trace(read_trace(spec), f"trace:{spec}")
        raise ValueError(f"unknown trace {spec!r}: not a bundled trace "
                         f"({sorted(bundled)}) and no such file")

    def cache_key(self, spec: str) -> str:
        """File-backed specs memoize by absolute path + mtime, so a
        chdir, an overwrite, or a different file at the same relative
        path never silently serves a stale table."""
        if spec not in self._bundled() and os.path.exists(spec):
            path = os.path.abspath(spec)
            return f"{path}@{os.stat(path).st_mtime_ns}"
        return spec

    def table_from_trace(self, trace, name: str) -> WorkloadTable:
        """Measured table: mean-iteration layer times in seconds, the
        Caffe ``data`` layer mapped to ``t_io``
        (:meth:`repro.traces.format.Trace.mean_compute_records` owns
        that convention).  A trace without a ``# batch:`` header gets a
        locked nominal batch of 1: its measured times stay usable but
        cannot be rescaled to other batch sizes.  A trace with a
        ``# bytes-per-sample:`` header carries its own input-byte
        convention; otherwise the provider's default applies."""
        from repro.traces.format import US

        recs, t_io = trace.mean_compute_records()
        grad_bytes = np.array([r.size_bytes for r in recs], dtype=np.float64)
        return WorkloadTable(
            name=name,
            grad_bytes=grad_bytes,
            batch_default=trace.batch_per_gpu or 1,
            bytes_per_sample=trace.bytes_per_sample or self.bytes_per_sample,
            param_bytes=float(grad_bytes.sum()),
            t_f=np.array([r.forward_us * US for r in recs], dtype=np.float64),
            t_b=np.array([r.backward_us * US for r in recs], dtype=np.float64),
            t_io_measured=t_io,
            batch_locked=not trace.batch_per_gpu)


# ----------------------------------------------------------------------
# jax: — measured traces harvested from this repo's own executed train
# steps by the measurement harness (repro.measure).
# ----------------------------------------------------------------------
class JaxProvider(TraceProvider):
    """Measured ``jax:`` workloads — the model↔measurement bridge.

    The measurement harness (``python -m repro.measure --arch <id>``)
    runs real :mod:`repro.comm.ddp` train steps on forced host devices,
    segments per-layer forward/backward seconds out of the layer scan,
    and writes a paper-format trace into the measurement directory.
    This provider resolves ``jax:<stem>`` against that directory (or
    ``jax:<path>`` for any explicit trace file), producing a *measured*
    :class:`WorkloadTable` exactly like ``trace:`` does — so a lowered,
    executed model sweeps through the batched engine, the predictor and
    the simulator with no special casing anywhere downstream.
    """

    scheme = "jax"

    #: Fallback input bytes/sample when the trace lacks a
    #: ``# bytes-per-sample:`` header: int32 token ids + labels at the
    #: ``llm:`` provider's sequence length.  The harness always writes
    #: the header, so this only covers hand-made files.
    bytes_per_sample = 2 * LLM_BYTES_PER_TOKEN * LLM_SEQ_LEN

    @staticmethod
    def measure_dir() -> str:
        """Where measured traces live: ``$REPRO_MEASURE_DIR`` or the
        repo-level ``results/measure/``."""
        env = os.environ.get("REPRO_MEASURE_DIR")
        if env:
            return env
        root = os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))))
        return os.path.join(root, "results", "measure")

    def names(self) -> tuple[str, ...]:
        d = self.measure_dir()
        if not os.path.isdir(d):
            return ()
        return tuple(sorted(
            f[:-len(".trace")] for f in os.listdir(d)
            if f.endswith(".trace")))

    def _resolve_path(self, spec: str) -> str | None:
        if os.path.exists(spec):
            return spec
        cached = os.path.join(self.measure_dir(), spec + ".trace")
        if os.path.exists(cached):
            return cached
        return None

    def build(self, spec: str) -> WorkloadTable:
        path = self._resolve_path(spec)
        if path is None:
            raise ValueError(
                f"no measured trace for {spec!r}: not a file and nothing "
                f"at {os.path.join(self.measure_dir(), spec + '.trace')!r} "
                f"(measured: {list(self.names())}); run "
                f"`python -m repro.measure --arch <id>` to measure it")
        from repro.traces.format import read_trace

        return self.table_from_trace(read_trace(path), f"jax:{spec}")

    def cache_key(self, spec: str) -> str:
        """Memoize by resolved absolute path + mtime (same contract as
        ``trace:`` file specs): re-measuring an arch or pointing
        ``REPRO_MEASURE_DIR`` elsewhere never serves a stale table."""
        path = self._resolve_path(spec)
        if path is None:
            return spec
        path = os.path.abspath(path)
        return f"{path}@{os.stat(path).st_mtime_ns}"


# ----------------------------------------------------------------------
# llm: — per-block costs sliced from archcost for every assigned config.
# ----------------------------------------------------------------------
class LLMProvider:
    scheme = "llm"

    def names(self) -> tuple[str, ...]:
        from repro.configs import ARCH_IDS

        return tuple(sorted(ARCH_IDS))

    def build(self, spec: str) -> WorkloadTable:
        from repro.configs import get_config
        from repro.core.archcost import block_cost_table

        try:
            cfg = get_config(spec)
        except KeyError as e:
            raise ValueError(str(e)) from None
        blocks = block_cost_table(cfg, LLM_SEQ_LEN)
        # bf16 gradient payloads over *total* params (every expert's
        # gradient is all-reduced, not just the routed-active ones);
        # compute from *active* params, matching archcost.step_cost.
        return WorkloadTable(
            name=f"llm:{spec}",
            flops_fwd=np.array([b.flops_fwd for b in blocks], dtype=np.float64),
            grad_bytes=np.array([2.0 * b.params for b in blocks],
                                dtype=np.float64),
            batch_default=1,
            bytes_per_sample=LLM_BYTES_PER_TOKEN * LLM_SEQ_LEN,
            param_bytes=2.0 * sum(b.params for b in blocks))


# ----------------------------------------------------------------------
# Registry + module-scope memoization.
# ----------------------------------------------------------------------
WORKLOAD_PROVIDERS: dict[str, WorkloadProvider] = {}

_TABLES: dict[str, WorkloadTable] = {}


def register_provider(provider: WorkloadProvider) -> None:
    WORKLOAD_PROVIDERS[provider.scheme] = provider


register_provider(CNNProvider())
register_provider(TraceProvider())
register_provider(LLMProvider())
register_provider(JaxProvider())


def canonical_name(workload: str) -> str:
    """Scheme-qualified form: bare Table-IV names become ``cnn:<name>``
    (backward compatibility with the pre-registry sweep engine)."""
    if ":" in workload:
        return workload
    return f"cnn:{workload}"


def resolve_workload(workload: str) -> WorkloadTable:
    """Workload name -> memoized :class:`WorkloadTable`.

    Raises ``ValueError`` with the known names for anything
    unresolvable — this is also what :meth:`Scenario.validate` calls.
    """
    scheme, _, spec = canonical_name(workload).partition(":")
    provider = WORKLOAD_PROVIDERS.get(scheme)
    if provider is None:
        raise ValueError(
            f"unknown workload {workload!r}: no provider for scheme "
            f"{scheme!r}; known workloads: {describe_workloads()}")
    # providers may refine the memoization key (e.g. the trace provider
    # keys file-backed specs by absolute path + mtime)
    key_fn = getattr(provider, "cache_key", None)
    key = f"{scheme}:{key_fn(spec) if key_fn else spec}"
    table = _TABLES.get(key)
    if table is None:
        try:
            table = provider.build(spec)
        except ValueError as e:
            raise ValueError(f"unknown workload {workload!r}: {e}") from None
        _TABLES[key] = table
    return table


def validate_workload(workload: str) -> None:
    """Raise ``ValueError`` unless ``workload`` resolves (memoized, so
    eager grid validation stays cheap)."""
    resolve_workload(workload)


def workload_cached(workload: str) -> bool:
    """True when :func:`resolve_workload` would hit the table memo — a
    pure probe (nothing is built or cached; unresolvable names are
    simply "not cached").  The sweep service
    (:mod:`repro.core.service`) uses this for cache-hit accounting."""
    scheme, _, spec = canonical_name(workload).partition(":")
    provider = WORKLOAD_PROVIDERS.get(scheme)
    if provider is None:
        return False
    key_fn = getattr(provider, "cache_key", None)
    try:
        key = f"{scheme}:{key_fn(spec) if key_fn else spec}"
    except (ValueError, OSError):
        return False
    return key in _TABLES


def clear_workload_cache() -> None:
    """Drop memoized tables (tests; after registering a provider whose
    scheme shadows cached names)."""
    _TABLES.clear()


def known_workloads() -> list[str]:
    """Every enumerable workload name, scheme-qualified and sorted."""
    return sorted(f"{scheme}:{spec}"
                  for scheme, p in WORKLOAD_PROVIDERS.items()
                  for spec in p.names())


def describe_workloads() -> str:
    """One-line summary of the registry for error messages / --help."""
    suffixes = {
        "trace": " or a trace-file path",
        "jax": " or a measured-trace path (python -m repro.measure)",
    }
    parts = []
    for scheme in sorted(WORKLOAD_PROVIDERS):
        names = ", ".join(WORKLOAD_PROVIDERS[scheme].names())
        parts.append(f"{scheme}: [{names}]{suffixes.get(scheme, '')}")
    return "; ".join(parts)
