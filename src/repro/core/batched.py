"""Scenario-axis batched fast path: the whole sweep as matrices.

The per-scenario fast path (:func:`repro.core.sweep._fast_eval`) is
vectorized over the *layer* dimension only — every scenario still pays
a Python round-trip through ``resolve_workload -> iteration_costs ->
closed_form``, which caps the engine at roughly 10k scenarios/s.  This
module vectorizes the *scenario* axis too, in two tiers:

* **Kernel grid**: every per-layer cost (``t_f``/``t_b``/``t_c``), the
  pipeline terms and the WFBP residual depend only on ``(workload,
  cluster x interconnect, n_workers, collective, batch)`` — *not* on
  the overlap policy.  The unique points of that reduced product are
  evaluated as ``(K, L)`` matrices built in one shot from array-valued
  collective models (:mod:`repro.core.hardware`) over per-point
  ``(n_workers, bandwidth, latency)`` vectors, with the prefix-max
  formulation of the WFBP residual
  (:func:`repro.core.analytical.non_overlapped_comm_batch`) reducing
  them to ``(K,)`` terms — pure NumPy over both axes, no per-scenario
  Python.  Workloads of different depths share one zero-padded
  ``(…, L_max)`` table: a padded layer has ``t_f = t_b = t_c =
  grad_bytes = 0``, contributes nothing to any sum, and is masked out
  of the prefix-max.
* **Policy select**: Eqs. (2)/(3)/(5) and their late-H2D variants are
  ``max``/``+`` combinations of those ``(K,)`` terms; each scenario
  gathers its kernel point and selects its policy's equation — cheap
  ``(S,)`` vector ops, so adding policies to a grid costs almost
  nothing.

Schedule-dependent policies (bucket fusion, priority comm) ride the
same two tiers: the kernel additionally reduces padded ``(S, B)``
bucket matrices (structure from :mod:`repro.core.bucketsim`, fused
payloads costed through the same collective dispatch as the per-layer
``t_c``) to one timeline-residual column per distinct bucket size, and
the policy select substitutes that residual for the WFBP term — see
:func:`repro.core.analytical.has_timeline_form` for why this is exact.

Correctness contract: every closed-form row agrees with the
per-scenario reference implementation ``_fast_eval`` to <= 1e-9
relative, and every timeline row with the event-driven
``simulate_steady`` oracle to <= 1e-6 (property-tested on the default,
mixed and frontier grids).  This module is the throughput engine
:func:`repro.core.sweep.sweep` routes every batched-eligible scenario
through.

:func:`grid_evaluator` memoizes the prepared *structure* of a grid
(axis tables, code vectors, label lists) keyed by grid value and
resolved table identity — numeric results are recomputed on every
:meth:`GridEvaluator.run`, never cached.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core import analytical, bucketsim
from repro.core.hardware import (CLUSTERS, apply_interconnect_preset,
                                 hierarchical_allreduce_time,
                                 ring_allreduce_time, tree_allreduce_time)
from repro.core.policies import Policy, get_policy
from repro.core.scenarios import (Scenario, ScenarioGrid,
                                  normalize_interconnect)
from repro.core.workloads import WorkloadTable, resolve_workload

_COLLECTIVE_CODE = {"ring": 0, "tree": 1, "hierarchical": 2}

#: Kernel points evaluated per ``(K, L)`` matrix allocation — bounds
#: transient memory on huge grids without measurably hurting speed.
KERNEL_CHUNK = 8192


# ----------------------------------------------------------------------
# Axis tables: everything a code vector indexes into.
# ----------------------------------------------------------------------
@dataclass
class _WorkloadAxis:
    """Unique workloads of the batch, padded to a shared layer count.

    Analytic tables populate ``flops``; measured ones populate
    ``tf_meas``/``tb_meas`` (the other family's rows are zero, so the
    combined expression ``flops*batch/rate + tf_meas*scale`` is exact
    for both — adding literal 0.0 is FP-identity).
    """

    names: list[str]                  # row-label spelling, as given
    flops: np.ndarray                 # (W, Lmax) per-sample fwd flops
    tf_meas: np.ndarray               # (W, Lmax) measured fwd s @ batch_default
    tb_meas: np.ndarray               # (W, Lmax) measured bwd s @ batch_default
    grad_bytes: np.ndarray            # (W, Lmax) all-reduce payload
    bwd_ratio: np.ndarray             # (W,)
    batch_default: np.ndarray         # (W,) float64
    bytes_per_sample: np.ndarray      # (W,)
    param_bytes: np.ndarray           # (W,)
    t_io_meas: np.ndarray             # (W,) measured input-pipeline s (0 if analytic)
    has_meas_io: np.ndarray           # (W,) bool
    batch_locked: np.ndarray          # (W,) bool
    table_names: list[str]            # canonical names, for error messages
    any_measured: bool                # any table with measured t_f/t_b
    any_meas_io: bool                 # any table with measured t_io


def _workload_axis(names: Sequence[str]) -> _WorkloadAxis:
    """Resolve + pad the unique workloads of a batch."""
    tables: list[WorkloadTable] = [resolve_workload(n) for n in names]
    lmax = max((t.num_layers for t in tables), default=1)
    W = len(tables)
    flops = np.zeros((W, lmax))
    tf_meas = np.zeros((W, lmax))
    tb_meas = np.zeros((W, lmax))
    grad = np.zeros((W, lmax))
    for i, t in enumerate(tables):
        L = t.num_layers
        grad[i, :L] = t.grad_bytes
        if t.is_measured:
            tf_meas[i, :L] = t.t_f
            tb_meas[i, :L] = t.t_b
        else:
            flops[i, :L] = t.flops_fwd
    return _WorkloadAxis(
        names=list(names),
        flops=flops, tf_meas=tf_meas, tb_meas=tb_meas, grad_bytes=grad,
        bwd_ratio=np.array([t.bwd_fwd_ratio for t in tables]),
        batch_default=np.array([t.batch_default for t in tables],
                               dtype=np.float64),
        bytes_per_sample=np.array([t.bytes_per_sample for t in tables]),
        param_bytes=np.array([t.param_bytes for t in tables]),
        t_io_meas=np.array([t.t_io_measured or 0.0 for t in tables]),
        has_meas_io=np.array([t.t_io_measured is not None for t in tables],
                             dtype=bool),
        batch_locked=np.array([t.batch_locked for t in tables], dtype=bool),
        table_names=[t.name for t in tables],
        # distinct flags: a trace can carry measured t_f/t_b without a
        # 'data' layer (no measured t_io) — gating the compute-time
        # terms on measured *I/O* would silently zero its layers
        any_measured=any(t.is_measured for t in tables),
        any_meas_io=any(t.t_io_measured is not None for t in tables))


def _check_batch_locked(wax: _WorkloadAxis, widx: np.ndarray,
                        batch: np.ndarray) -> None:
    """Exactly the guard
    :meth:`~repro.core.workloads.WorkloadTable.iteration_costs` applies
    per scenario: a batch override on a trace without a recorded batch
    is an error (its measured times cannot be rescaled)."""
    bad = wax.batch_locked[widx] & (batch > 0) \
        & (batch != wax.batch_default[widx])
    if bool(bad.any()):
        i = int(np.argmax(bad))
        raise ValueError(
            f"workload {wax.table_names[int(widx[i])]!r} has no recorded "
            f"batch size (no '# batch:' header in the trace), so its "
            f"measured times cannot be rescaled to batch_per_gpu="
            f"{int(batch[i])}; leave batch_per_gpu unset")


@dataclass
class _ClusterAxis:
    """Unique ``(cluster, interconnect)`` pairs, resolved once.

    Node sizing (``with_workers``) never changes any of these
    parameters, so the pair — not the worker count — is the right
    resolution key.
    """

    intra_bw: np.ndarray
    intra_lat: np.ndarray
    inter_bw: np.ndarray
    inter_lat: np.ndarray
    gpn: np.ndarray                   # gpus_per_node, int64
    disk_lat: np.ndarray
    disk_bw: np.ndarray
    h2d_lat: np.ndarray
    h2d_bw: np.ndarray
    rate: np.ndarray                  # achieved flop/s
    hbm_bw: np.ndarray


def _cluster_axis(pairs: Sequence[tuple[str, str | None]]) -> _ClusterAxis:
    specs = [apply_interconnect_preset(CLUSTERS[c], ic) for c, ic in pairs]
    return _ClusterAxis(
        intra_bw=np.array([c.intra.effective_bandwidth for c in specs]),
        intra_lat=np.array([c.intra.latency for c in specs]),
        inter_bw=np.array([c.inter.effective_bandwidth for c in specs]),
        inter_lat=np.array([c.inter.latency for c in specs]),
        gpn=np.array([c.gpus_per_node for c in specs], dtype=np.int64),
        disk_lat=np.array([c.disk.latency for c in specs]),
        disk_bw=np.array([c.disk.effective_bandwidth for c in specs]),
        h2d_lat=np.array([c.h2d.latency for c in specs]),
        h2d_bw=np.array([c.h2d.effective_bandwidth for c in specs]),
        rate=np.array([c.device.peak_flops * c.device.compute_efficiency
                       for c in specs]),
        hbm_bw=np.array([c.device.hbm_bandwidth for c in specs]))


@dataclass
class _PolicyAxis:
    names: list[str]
    overlap_io: np.ndarray            # (P,) bool
    overlap_comm: np.ndarray
    h2d_early: np.ndarray
    has_fast: np.ndarray              # (P,) exact per-layer closed form
    has_tl: np.ndarray                # (P,) exact bucket-timeline form
    tl_spec: np.ndarray               # (P,) index into tl_specs, -1 = none
    #: Unique ``(bucket_bytes, overlap_comm)`` pairs the kernel must
    #: compute a timeline-residual column for.  Priority-only policies
    #: (no buckets) need no column: order-independence makes their
    #: residual the per-layer WFBP term ``tc_no`` already on hand.
    tl_specs: list[tuple[float, bool]]


def _policy_axis(names: Sequence[str]) -> _PolicyAxis:
    pols: list[Policy] = [get_policy(n) for n in names]
    specs: dict[tuple[float, bool], int] = {}
    tl_spec = np.full(len(pols), -1, dtype=np.int64)
    for i, p in enumerate(pols):
        if analytical.has_timeline_form(p) and p.bucket_bytes:
            key = (float(p.bucket_bytes), bool(p.overlap_comm))
            tl_spec[i] = specs.setdefault(key, len(specs))
    return _PolicyAxis(
        names=list(names),
        overlap_io=np.array([p.overlap_io for p in pols], dtype=bool),
        overlap_comm=np.array([p.overlap_comm for p in pols], dtype=bool),
        h2d_early=np.array([p.h2d_early for p in pols], dtype=bool),
        has_fast=np.array([analytical.has_closed_form(p) for p in pols],
                          dtype=bool),
        has_tl=np.array([analytical.has_timeline_form(p) for p in pols],
                        dtype=bool),
        tl_spec=tl_spec,
        tl_specs=list(specs))


# ----------------------------------------------------------------------
# Tier 1: the (K, L) kernel — policy-independent cost terms.
# ----------------------------------------------------------------------
def _kernel_cols(wax: _WorkloadAxis, cax: _ClusterAxis,
                 widx: np.ndarray, cidx: np.ndarray, coll: np.ndarray,
                 n: np.ndarray, batch: np.ndarray,
                 tl_specs: Sequence[tuple[float, bool]] = (),
                 chunk: int = KERNEL_CHUNK) -> dict[str, np.ndarray]:
    """Policy-independent terms for every kernel point, reduced over
    the layer axis: ``(K,)`` vectors of ``io_h2d``, ``t_h2d``, ``comp``
    (= sum t_f + sum t_b), ``sum_c``, ``tc_no``, ``t_u``, plus the
    resolved ``n_f``/``batch_f``.  The transient ``(K, L)`` matrices
    are built ``chunk`` points at a time so huge grids stay in bounded
    memory.

    ``tl_specs`` (from :attr:`_PolicyAxis.tl_specs`) adds one
    bucket-timeline residual column ``tl<i>`` per unique
    ``(bucket_bytes, overlap_comm)`` pair: bucket payloads from the
    shared :func:`repro.core.bucketsim.bucket_table` structure, costed
    through the *same* per-chunk collective dispatch as the per-layer
    ``t_c`` (so fused buckets amortize latency exactly as
    ``repro.core.costmodel.comm_scale_fn`` does), reduced by
    :func:`repro.core.bucketsim.timeline_residual`.
    """
    K = len(widx)
    # Bucket structure depends only on (workload axis, bucket size) —
    # built once per call, gathered per chunk.
    btables = [bucketsim.bucket_table(wax.grad_bytes, bb)
               for bb, _ in tl_specs]
    out = {name: np.empty(K) for name in
           ("io_h2d", "t_h2d", "comp", "sum_c", "tc_no", "t_u",
            "n_f", "batch_f")}
    for i in range(len(tl_specs)):
        out[f"tl{i}"] = np.empty(K)
    for lo in range(0, K, chunk):
        sl = slice(lo, lo + chunk)
        w, c = widx[sl], cidx[sl]
        nn, cl = n[sl], coll[sl]
        batch_f = np.where(batch[sl] > 0, batch[sl],
                           wax.batch_default[w]).astype(np.float64)
        n_f = nn.astype(np.float64)

        # compute costs: (k, L)
        tfa = wax.flops[w] * batch_f[:, None] / cax.rate[c][:, None]
        t_f = tfa
        t_b = wax.bwd_ratio[w][:, None] * tfa
        if wax.any_measured:          # adding literal 0.0 rows is exact,
            scale = (batch_f / wax.batch_default[w])[:, None]
            t_f = t_f + wax.tf_meas[w] * scale     # but skip it when the
            t_b = t_b + wax.tb_meas[w] * scale     # batch has no traces

        # comm costs: array-valued collective models, each algorithm
        # evaluated only on its own rows (the collective axis
        # partitions the points; computing all three models on the
        # full matrix would triple the dominant kernel cost).  The
        # dispatch is payload-agnostic, so the same closure costs the
        # per-layer gradients *and* the fused bucket payloads.
        grad = wax.grad_bytes[w]
        use_intra = nn <= cax.gpn[c]
        link_bw = np.where(use_intra, cax.intra_bw[c], cax.inter_bw[c])
        link_lat = np.where(use_intra, cax.intra_lat[c], cax.inter_lat[c])
        codes_present = np.unique(cl)

        def comm_rows(payload, sel, code: int) -> np.ndarray:
            g, ns = payload[sel], nn[sel][:, None]
            if code == 0:
                return ring_allreduce_time(g, n_f[sel][:, None],
                                           link_bw[sel][:, None],
                                           link_lat[sel][:, None])
            if code == 1:
                return tree_allreduce_time(g, ns, link_bw[sel][:, None],
                                           link_lat[sel][:, None])
            ci = c[sel]
            return hierarchical_allreduce_time(
                g, ns, cax.gpn[ci][:, None],
                cax.intra_bw[ci][:, None], cax.intra_lat[ci][:, None],
                cax.inter_bw[ci][:, None], cax.inter_lat[ci][:, None])

        def comm_matrix(payload: np.ndarray) -> np.ndarray:
            """(k, B) payload bytes -> (k, B) collective seconds, with
            zero-payload entries (padding, no-comm layers) zeroed."""
            if len(codes_present) == 1:
                t = comm_rows(payload, slice(None), int(codes_present[0]))
            else:
                t = np.empty_like(payload)
                for code in codes_present:
                    sel = np.nonzero(cl == code)[0]
                    t[sel] = comm_rows(payload, sel, int(code))
            return t * (payload > 0)

        t_c = comm_matrix(grad)

        # pipeline terms: (k,)
        nbytes_in = batch_f * wax.bytes_per_sample[w]
        t_io = cax.disk_lat[c] + nbytes_in / cax.disk_bw[c]
        if wax.any_meas_io:
            t_io = np.where(wax.has_meas_io[w],
                            wax.t_io_meas[w] * batch_f
                            / wax.batch_default[w],
                            t_io)
        t_h2d = cax.h2d_lat[c] + nbytes_in / cax.h2d_bw[c]

        out["io_h2d"][sl] = t_io + t_h2d
        out["t_h2d"][sl] = t_h2d
        out["comp"][sl] = t_f.sum(axis=1) + t_b.sum(axis=1)
        out["sum_c"][sl] = t_c.sum(axis=1)
        out["tc_no"][sl] = analytical.non_overlapped_comm_batch(t_b, t_c)
        out["t_u"][sl] = 3.0 * wax.param_bytes[w] / cax.hbm_bw[c]
        out["n_f"][sl] = n_f
        out["batch_f"][sl] = batch_f

        # bucket-timeline residuals: gather the (W, B) bucket structure
        # to this chunk's rows, cost the fused payloads through the
        # same collective dispatch, reduce over the bucket axis
        for i, (bt, (_, ov_comm)) in enumerate(zip(btables, tl_specs)):
            dur = comm_matrix(bt.nbytes[w])
            out[f"tl{i}"][sl] = bucketsim.timeline_residual(
                t_b, dur, bt.release_layer[w], bt.mask[w],
                overlap_comm=ov_comm)
    return out


# ----------------------------------------------------------------------
# Tier 2: per-scenario policy select — cheap (S,) vector ops.
# ----------------------------------------------------------------------
def _policy_select(pax: _PolicyAxis, polidx: np.ndarray,
                   kc: dict[str, np.ndarray],
                   kidx: np.ndarray | None) -> dict[str, np.ndarray]:
    """Gather each scenario's kernel point (``kidx=None`` means the
    identity map) and select its policy's steady-state form — Eqs. (2),
    (3), (5) and the late-H2D variants for closed-form policies, the
    bucket-timeline residual for schedule-dependent ones — plus the
    zero-comm weak-scaling baseline with the *same* policy (what
    ``_fast_eval`` / ``_sim_eval`` compute for the speedup column)."""
    def g(a: np.ndarray) -> np.ndarray:
        return a if kidx is None else a[kidx]

    io_h2d, t_h2d = g(kc["io_h2d"]), g(kc["t_h2d"])
    comp, sum_c = g(kc["comp"]), g(kc["sum_c"])
    tc_no, t_u = g(kc["tc_no"]), g(kc["t_u"])
    n_f, batch_f = g(kc["n_f"]), g(kc["batch_f"])

    ov_io = pax.overlap_io[polidx]
    ov_comm = pax.overlap_comm[polidx]
    early = pax.h2d_early[polidx]

    comm_term = np.where(ov_comm, tc_no, sum_c)     # WFBP residual or full
    # Schedule-dependent overrides.  Bucketed policies substitute their
    # bucket-timeline residual column; priority-only policies need no
    # override — the net channel is work-conserving, so reordering
    # never moves the last comm finish and the per-layer term already
    # selected (tc_no / sum_c) *is* their residual.
    spec_of = pax.tl_spec[polidx]
    for i in range(len(pax.tl_specs)):
        comm_term = np.where(spec_of == i, g(kc[f"tl{i}"]), comm_term)
    gpu_chain = comp + comm_term + t_u
    eq2 = io_h2d + gpu_chain                        # no I/O overlap
    eq_early = np.maximum(io_h2d, gpu_chain)        # Eq. (3)/(5)
    eq_late = np.maximum(io_h2d, t_h2d + gpu_chain)  # late-H2D variants
    t_iter = np.where(~ov_io, eq2, np.where(early, eq_early, eq_late))

    base_chain = comp + t_u                         # zero-comm baseline
    t1 = np.where(~ov_io, io_h2d + base_chain,
                  np.where(early, np.maximum(io_h2d, base_chain),
                           np.maximum(io_h2d, t_h2d + base_chain)))

    # method labels: the per-row evaluation-path column ("analytical"
    # for closed forms, "timeline" for the bucket-timeline form; rows
    # matching neither are discarded by the caller for the simulator)
    fast = pax.has_fast[polidx]
    method = np.where(fast, "analytical",
                      np.where(pax.has_tl[polidx], "timeline",
                               "simulated")).tolist()

    return {
        "batch": batch_f,
        "iteration_time_s": t_iter,
        "samples_per_sec": n_f * batch_f / t_iter,
        "speedup": n_f * t1 / t_iter,
        "t_comm_s": sum_c,
        "t_comp_s": comp,
        "method": method,
    }


def _make_rows(workload: list, cluster: list, n_workers: list, policy: list,
               collective: list, interconnect: list,
               cols: dict[str, np.ndarray]) -> list[dict]:
    """Tidy row dicts from label lists + numeric columns (``.tolist()``
    converts whole columns to Python scalars in C, which is what keeps
    row assembly off the throughput critical path)."""
    return [
        {
            "workload": wl, "cluster": cl, "n_workers": nw, "policy": pol,
            "collective": co, "interconnect": ic, "batch_per_gpu": b,
            "iteration_time_s": it, "samples_per_sec": sps, "speedup": sp,
            "t_comm_s": tcm, "t_comp_s": tcp, "method": meth,
        }
        for wl, cl, nw, pol, co, ic, b, it, sps, sp, tcm, tcp, meth in zip(
            workload, cluster, n_workers, policy, collective, interconnect,
            np.asarray(cols["batch"], dtype=np.int64).tolist(),
            cols["iteration_time_s"].tolist(),
            cols["samples_per_sec"].tolist(),
            cols["speedup"].tolist(),
            cols["t_comm_s"].tolist(),
            cols["t_comp_s"].tolist(),
            cols["method"])
    ]


# ----------------------------------------------------------------------
# Grid front end: codes straight from the axes, no Scenario objects.
# ----------------------------------------------------------------------
def _axis_codes(sizes: Sequence[int]) -> list[np.ndarray]:
    """Flat cross-product code vectors, rightmost axis fastest — the
    exact :meth:`ScenarioGrid.expand` order."""
    out = []
    for i, size in enumerate(sizes):
        after = int(np.prod(sizes[i + 1:], dtype=np.int64))
        before = int(np.prod(sizes[:i], dtype=np.int64))
        out.append(np.tile(np.repeat(np.arange(size), after), before))
    return out


class GridEvaluator:
    """A :class:`ScenarioGrid` prepared for batched evaluation.

    Builds the axis tables, the kernel-grid code vectors (policy axis
    dropped), the scenario -> kernel-point map and the row label lists
    directly from the grid's cross-product structure — no per-scenario
    Python objects at all.  Closed-form *and* bucket-timeline policies
    are both batched; scenarios whose policy has neither form come
    back as ``None`` rows and :meth:`scenario_at` materializes just
    those for the simulator fallback.

    The evaluator holds only *structure*; :meth:`run` computes the
    numbers.  Get instances through :func:`grid_evaluator`, which
    memoizes them by grid value + workload-table identity.
    """

    def __init__(self, grid: ScenarioGrid):
        grid.validate_axes()
        self.grid = grid
        nW, nC = len(grid.workloads), len(grid.clusters)
        nK, nP = len(grid.worker_counts), len(grid.policies)
        nA, nI = len(grid.collectives), len(grid.interconnects)
        self._sizes = (nW, nC, nK, nP, nA, nI)
        self.n_scenarios = nW * nC * nK * nP * nA * nI

        self._wax = _workload_axis(grid.workloads)
        pairs = [(c, ic) for c in grid.clusters for ic in grid.interconnects]
        self._cax = _cluster_axis(pairs)
        self._pax = _policy_axis(grid.policies)

        # Kernel grid: the scenario product with the policy axis
        # dropped — order (workloads, clusters, workers, collectives,
        # interconnects), rightmost fastest.  O(K) int vectors; every
        # per-*scenario* quantity is derived per chunk instead (see
        # _scenario_codes), so preparation stays O(axes + K) however
        # large the scenario product is.
        kw, kc, kk, ka, ki = _axis_codes((nW, nC, nK, nA, nI))
        self._kwidx = kw
        self._kcidx = kc * nI + ki              # (cluster, interconnect) pair
        self._kcoll = np.array(
            [_COLLECTIVE_CODE[c] for c in grid.collectives],
            dtype=np.int64)[ka]
        self._kn = np.array([int(k) for k in grid.worker_counts],
                            dtype=np.int64)[kk]
        self._kbatch = np.full(len(kw), grid.batch_per_gpu or 0,
                               dtype=np.int64)
        _check_batch_locked(self._wax, kw, self._kbatch)

        per_policy = self.n_scenarios // nP if nP else 0
        self.n_fast = per_policy * int(self._pax.has_fast.sum())
        self.n_timeline = per_policy * int(self._pax.has_tl.sum())
        self.all_batched = \
            self.n_fast + self.n_timeline == self.n_scenarios

        # Per-axis label values (tiny object arrays, fancy-indexed per
        # chunk by the derived codes).
        self._wl_values = np.array(list(grid.workloads), dtype=object)
        self._cl_values = np.array(list(grid.clusters), dtype=object)
        self._n_values = np.array([int(k) for k in grid.worker_counts],
                                  dtype=np.int64)
        self._pol_values = np.array(list(grid.policies), dtype=object)
        self._coll_values = np.array(list(grid.collectives), dtype=object)
        self._ic_values = np.array(
            [normalize_interconnect(ic) for ic in grid.interconnects],
            dtype=object)

    def __len__(self) -> int:
        return self.n_scenarios

    def _scenario_codes(self, lo: int, hi: int) -> dict[str, np.ndarray]:
        """Axis codes, the kernel-point map and the fast mask for flat
        scenario indices ``[lo, hi)``, derived arithmetically from the
        expand() order (rightmost axis fastest) — O(chunk) work and
        memory, nothing per-scenario is ever stored."""
        nW, nC, nK, nP, nA, nI = self._sizes
        r = np.arange(lo, hi, dtype=np.int64)
        ii = r % nI
        r //= nI
        ai = r % nA
        r //= nA
        pi = r % nP
        r //= nP
        ki = r % nK
        r //= nK
        ci = r % nC
        wi = r // nC
        kidx = (((wi * nC + ci) * nK + ki) * nA + ai) * nI + ii
        return {"wi": wi, "ci": ci, "ki": ki, "pi": pi, "ai": ai, "ii": ii,
                "kidx": kidx,
                "batched": self._pax.has_fast[pi] | self._pax.has_tl[pi]}

    def run(self) -> "GridRun":
        """Evaluate the kernel grid (fresh numbers every call) and
        return the per-run row materializer."""
        return GridRun(self, _kernel_cols(
            self._wax, self._cax, self._kwidx, self._kcidx,
            self._kcoll, self._kn, self._kbatch,
            tl_specs=self._pax.tl_specs))

    def scenario_at(self, i: int) -> Scenario:
        """Materialize flat index ``i`` (used for simulator-fallback
        entries only)."""
        g = self.grid
        sizes = (len(g.workloads), len(g.clusters), len(g.worker_counts),
                 len(g.policies), len(g.collectives), len(g.interconnects))
        codes = []
        for size in reversed(sizes):
            i, c = divmod(i, size)
            codes.append(c)
        wi, ci, ki, pi, ai, ii = reversed(codes)
        return Scenario(workload=g.workloads[wi], cluster=g.clusters[ci],
                        n_workers=int(g.worker_counts[ki]),
                        policy=g.policies[pi], collective=g.collectives[ai],
                        interconnect=g.interconnects[ii],
                        batch_per_gpu=g.batch_per_gpu)


class GridRun:
    """One evaluation of a grid: the ``(K,)`` kernel columns plus the
    shared structure, materializing tidy rows chunk by chunk."""

    def __init__(self, ev: GridEvaluator, kernel_cols: dict[str, np.ndarray]):
        self._ev = ev
        self._kc = kernel_cols

    def __len__(self) -> int:
        return self._ev.n_scenarios

    def columns_slice(self, lo: int, hi: int) -> dict[str, np.ndarray]:
        """Numeric result columns (plus ``method`` labels) for flat
        scenario indices ``[lo, hi)`` — the policy-selected values
        before tidy-row assembly.  The kernel-only surface the
        throughput benchmark times and the jax backend's differential
        gate compares against."""
        ev = self._ev
        codes = ev._scenario_codes(lo, hi)
        return _policy_select(ev._pax, codes["pi"], self._kc, codes["kidx"])

    def rows_slice(self, lo: int, hi: int) -> list[dict | None]:
        """Batched rows for flat scenario indices ``[lo, hi)`` in grid
        order; entries whose policy needs the simulator come back as
        ``None`` for the caller to fill."""
        ev = self._ev
        codes = ev._scenario_codes(lo, hi)
        cols = _policy_select(ev._pax, codes["pi"], self._kc, codes["kidx"])
        rows: list[dict | None] = _make_rows(
            ev._wl_values[codes["wi"]].tolist(),
            ev._cl_values[codes["ci"]].tolist(),
            ev._n_values[codes["ki"]].tolist(),
            ev._pol_values[codes["pi"]].tolist(),
            ev._coll_values[codes["ai"]].tolist(),
            ev._ic_values[codes["ii"]].tolist(), cols)
        if not ev.all_batched:
            for i in np.nonzero(~codes["batched"])[0].tolist():
                rows[i] = None                # selected a bogus equation
        return rows


#: Structure memo: prepared evaluators keyed by grid value + the
#: identity of the resolved workload tables (holding the tables alive
#: keeps the ids stable; a re-resolved table — e.g. an on-disk trace
#: whose mtime changed — misses the memo and rebuilds).
_EVALUATOR_MEMO: dict = {}
_MEMO_LIMIT = 64


def grid_evaluator(grid: ScenarioGrid) -> GridEvaluator:
    """Memoized :class:`GridEvaluator` for ``grid`` (falls back to a
    fresh instance when the grid isn't hashable, e.g. list-valued
    axes)."""
    try:
        tables = tuple(resolve_workload(w) for w in grid.workloads)
        key = (grid, tuple(id(t) for t in tables))
        hash(key)
    except TypeError:
        return GridEvaluator(grid)
    hit = _EVALUATOR_MEMO.get(key)
    if hit is not None:
        return hit[0]
    if len(_EVALUATOR_MEMO) >= _MEMO_LIMIT:
        _EVALUATOR_MEMO.clear()
    ev = GridEvaluator(grid)
    _EVALUATOR_MEMO[key] = (ev, tables)
    return ev


# ----------------------------------------------------------------------
# Scenario-list front end (arbitrary iterables, already validated).
# ----------------------------------------------------------------------
def scenario_axes(scenarios: Sequence[Scenario]):
    """One Python pass over a scenario list: resolve the unique
    workload/cluster-pair/policy axes and the per-scenario code
    vectors.  Returns ``(wax, cax, pax, widx, cidx, polidx, coll, n,
    batch)`` — the inputs of the two-tier kernel with the identity
    scenario -> kernel-point map.  Shared by :func:`eval_scenarios`
    and the jax backend's list front end
    (:func:`repro.core.batched_jax.eval_scenarios_jax`), raising
    ``ValueError`` if any scenario's policy has neither a closed nor a
    bucket-timeline form.
    """
    wl_key: dict[str, int] = {}
    pair_key: dict[tuple[str, str | None], int] = {}
    pol_key: dict[str, int] = {}
    widx = np.empty(len(scenarios), dtype=np.int64)
    cidx = np.empty(len(scenarios), dtype=np.int64)
    polidx = np.empty(len(scenarios), dtype=np.int64)
    coll = np.empty(len(scenarios), dtype=np.int64)
    n = np.empty(len(scenarios), dtype=np.int64)
    batch = np.empty(len(scenarios), dtype=np.int64)
    for i, s in enumerate(scenarios):
        wi = wl_key.get(s.workload)
        if wi is None:
            wi = wl_key[s.workload] = len(wl_key)
        widx[i] = wi
        pk = (s.cluster, s.interconnect)
        ci = pair_key.get(pk)
        if ci is None:
            ci = pair_key[pk] = len(pair_key)
        cidx[i] = ci
        pi = pol_key.get(s.policy)
        if pi is None:
            pi = pol_key[s.policy] = len(pol_key)
        polidx[i] = pi
        coll[i] = _COLLECTIVE_CODE[s.collective]
        n[i] = s.n_workers
        batch[i] = s.batch_per_gpu or 0
    wax = _workload_axis(list(wl_key))
    _check_batch_locked(wax, widx, batch)
    cax = _cluster_axis(list(pair_key))
    pax = _policy_axis(list(pol_key))
    batched_ok = pax.has_fast | pax.has_tl
    if not bool(batched_ok[polidx].all()):
        bad = [pax.names[int(p)]
               for p in np.unique(polidx[~batched_ok[polidx]])]
        raise ValueError(f"policies with neither a closed form nor a "
                         f"bucket-timeline form cannot take the batched "
                         f"path: {bad}")
    return wax, cax, pax, widx, cidx, polidx, coll, n, batch


def eval_scenarios(scenarios: Sequence[Scenario]) -> list[dict]:
    """Batched rows (input order) for a list of batched-path-eligible
    scenarios (closed-form or bucket-timeline policies); one Python
    pass to build code vectors, then the same two-tier kernel the grid
    front end uses (with the identity scenario -> kernel-point map).

    Raises ``ValueError`` if any scenario's policy has neither form —
    callers (:func:`repro.core.sweep.sweep`) partition first.
    """
    if not scenarios:
        return []
    wax, cax, pax, widx, cidx, polidx, coll, n, batch = \
        scenario_axes(scenarios)
    kc = _kernel_cols(wax, cax, widx, cidx, coll, n, batch,
                      tl_specs=pax.tl_specs)
    cols = _policy_select(pax, polidx, kc, kidx=None)
    return _make_rows(
        [s.workload for s in scenarios],
        [s.cluster for s in scenarios],
        [s.n_workers for s in scenarios],
        [s.policy for s in scenarios],
        [s.collective for s in scenarios],
        [normalize_interconnect(s.interconnect) for s in scenarios],
        cols)
