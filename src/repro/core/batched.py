"""Scenario-axis batched fast path: the whole sweep as matrices.

The per-scenario fast path (:func:`repro.core.sweep._fast_eval`) is
vectorized over the *layer* dimension only — every scenario still pays
a Python round-trip through ``resolve_workload -> iteration_costs ->
closed_form``, which caps the engine at roughly 10k scenarios/s.  This
module vectorizes the *scenario* axis too, in two tiers:

* **Kernel grid**: every per-layer cost (``t_f``/``t_b``/``t_c``), the
  pipeline terms and the WFBP residual depend only on ``(workload,
  cluster x interconnect, n_workers, collective, batch)`` — *not* on
  the overlap policy.  The unique points of that reduced product are
  evaluated as ``(K, L)`` matrices built in one shot from array-valued
  collective models (:mod:`repro.core.hardware`) over per-point
  ``(n_workers, bandwidth, latency)`` vectors, with the prefix-max
  formulation of the WFBP residual
  (:func:`repro.core.analytical.non_overlapped_comm_batch`) reducing
  them to ``(K,)`` terms — pure NumPy over both axes, no per-scenario
  Python.  Workloads of different depths share one zero-padded
  ``(…, L_max)`` table: a padded layer has ``t_f = t_b = t_c =
  grad_bytes = 0``, contributes nothing to any sum, and is masked out
  of the prefix-max.
* **Policy select**: Eqs. (2)/(3)/(5) and their late-H2D variants are
  ``max``/``+`` combinations of those ``(K,)`` terms; each scenario
  gathers its kernel point and selects its policy's equation — cheap
  ``(S,)`` vector ops, so adding policies to a grid costs almost
  nothing.

Schedule-dependent policies (bucket fusion, priority comm) ride the
same two tiers: the kernel additionally reduces padded ``(S, B)``
bucket matrices (structure from :mod:`repro.core.bucketsim`, fused
payloads costed through the same collective dispatch as the per-layer
``t_c``) to one timeline-residual column per distinct bucket size, and
the policy select substitutes that residual for the WFBP term — see
:func:`repro.core.analytical.has_timeline_form` for why this is exact.

Correctness contract: every closed-form row agrees with the
per-scenario reference implementation ``_fast_eval`` to <= 1e-9
relative, and every timeline row with the event-driven
``simulate_steady`` oracle to <= 1e-6 (property-tested on the default,
mixed and frontier grids).  This module is the throughput engine
:func:`repro.core.sweep.sweep` routes every batched-eligible scenario
through.

:func:`grid_evaluator` memoizes the prepared *structure* of a grid
(axis tables, code vectors, label lists) keyed by grid value and
resolved table identity — numeric results are recomputed on every
:meth:`GridEvaluator.run`, never cached.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from repro.core import analytical, bucketsim
from repro.core import het as het_mod
from repro.core.hardware import (CLUSTERS, apply_interconnect_preset,
                                 hierarchical_allreduce_coeffs,
                                 ring_allreduce_coeffs,
                                 tree_allreduce_coeffs)
from repro.core.policies import Policy, get_policy
from repro.core.resulttable import METHOD_LABELS, rows_from_table
from repro.core.scenarios import (Scenario, ScenarioGrid,
                                  normalize_interconnect,
                                  normalize_sync_k)
from repro.core.workloads import WorkloadTable, resolve_workload

_COLLECTIVE_CODE = {"ring": 0, "tree": 1, "hierarchical": 2}

#: Kernel points evaluated per ``(K, L)`` matrix allocation — bounds
#: transient memory on huge grids without measurably hurting speed.
KERNEL_CHUNK = 8192


# ----------------------------------------------------------------------
# Axis tables: everything a code vector indexes into.
# ----------------------------------------------------------------------
@dataclass
class _WorkloadAxis:
    """Unique workloads of the batch, padded to a shared layer count.

    Analytic tables populate ``flops``; measured ones populate
    ``tf_meas``/``tb_meas`` (the other family's rows are zero, so the
    combined expression ``flops*batch/rate + tf_meas*scale`` is exact
    for both — adding literal 0.0 is FP-identity).
    """

    names: list[str]                  # row-label spelling, as given
    flops: np.ndarray                 # (W, Lmax) per-sample fwd flops
    tf_meas: np.ndarray               # (W, Lmax) measured fwd s @ batch_default
    tb_meas: np.ndarray               # (W, Lmax) measured bwd s @ batch_default
    grad_bytes: np.ndarray            # (W, Lmax) all-reduce payload
    bwd_ratio: np.ndarray             # (W,)
    batch_default: np.ndarray         # (W,) float64
    bytes_per_sample: np.ndarray      # (W,)
    param_bytes: np.ndarray           # (W,)
    t_io_meas: np.ndarray             # (W,) measured input-pipeline s (0 if analytic)
    has_meas_io: np.ndarray           # (W,) bool
    batch_locked: np.ndarray          # (W,) bool
    table_names: list[str]            # canonical names, for error messages
    any_measured: bool                # any table with measured t_f/t_b
    any_meas_io: bool                 # any table with measured t_io


def _workload_axis(names: Sequence[str]) -> _WorkloadAxis:
    """Resolve + pad the unique workloads of a batch."""
    tables: list[WorkloadTable] = [resolve_workload(n) for n in names]
    lmax = max((t.num_layers for t in tables), default=1)
    W = len(tables)
    flops = np.zeros((W, lmax))
    tf_meas = np.zeros((W, lmax))
    tb_meas = np.zeros((W, lmax))
    grad = np.zeros((W, lmax))
    for i, t in enumerate(tables):
        L = t.num_layers
        grad[i, :L] = t.grad_bytes
        if t.is_measured:
            tf_meas[i, :L] = t.t_f
            tb_meas[i, :L] = t.t_b
        else:
            flops[i, :L] = t.flops_fwd
    return _WorkloadAxis(
        names=list(names),
        flops=flops, tf_meas=tf_meas, tb_meas=tb_meas, grad_bytes=grad,
        bwd_ratio=np.array([t.bwd_fwd_ratio for t in tables]),
        batch_default=np.array([t.batch_default for t in tables],
                               dtype=np.float64),
        bytes_per_sample=np.array([t.bytes_per_sample for t in tables]),
        param_bytes=np.array([t.param_bytes for t in tables]),
        t_io_meas=np.array([t.t_io_measured or 0.0 for t in tables]),
        has_meas_io=np.array([t.t_io_measured is not None for t in tables],
                             dtype=bool),
        batch_locked=np.array([t.batch_locked for t in tables], dtype=bool),
        table_names=[t.name for t in tables],
        # distinct flags: a trace can carry measured t_f/t_b without a
        # 'data' layer (no measured t_io) — gating the compute-time
        # terms on measured *I/O* would silently zero its layers
        any_measured=any(t.is_measured for t in tables),
        any_meas_io=any(t.t_io_measured is not None for t in tables))


def _check_batch_locked(wax: _WorkloadAxis, widx: np.ndarray,
                        batch: np.ndarray) -> None:
    """Exactly the guard
    :meth:`~repro.core.workloads.WorkloadTable.iteration_costs` applies
    per scenario: a batch override on a trace without a recorded batch
    is an error (its measured times cannot be rescaled)."""
    bad = wax.batch_locked[widx] & (batch > 0) \
        & (batch != wax.batch_default[widx])
    if bool(bad.any()):
        i = int(np.argmax(bad))
        raise ValueError(
            f"workload {wax.table_names[int(widx[i])]!r} has no recorded "
            f"batch size (no '# batch:' header in the trace), so its "
            f"measured times cannot be rescaled to batch_per_gpu="
            f"{int(batch[i])}; leave batch_per_gpu unset")


@dataclass
class _ClusterAxis:
    """Unique ``(cluster, interconnect)`` pairs, resolved once.

    Node sizing (``with_workers``) never changes any of these
    parameters, so the pair — not the worker count — is the right
    resolution key.
    """

    intra_bw: np.ndarray
    intra_lat: np.ndarray
    inter_bw: np.ndarray
    inter_lat: np.ndarray
    gpn: np.ndarray                   # gpus_per_node, int64
    disk_lat: np.ndarray
    disk_bw: np.ndarray
    h2d_lat: np.ndarray
    h2d_bw: np.ndarray
    rate: np.ndarray                  # achieved flop/s
    hbm_bw: np.ndarray


def _cluster_axis(pairs: Sequence[tuple[str, str | None]]) -> _ClusterAxis:
    specs = [apply_interconnect_preset(CLUSTERS[c], ic) for c, ic in pairs]
    return _ClusterAxis(
        intra_bw=np.array([c.intra.effective_bandwidth for c in specs]),
        intra_lat=np.array([c.intra.latency for c in specs]),
        inter_bw=np.array([c.inter.effective_bandwidth for c in specs]),
        inter_lat=np.array([c.inter.latency for c in specs]),
        gpn=np.array([c.gpus_per_node for c in specs], dtype=np.int64),
        disk_lat=np.array([c.disk.latency for c in specs]),
        disk_bw=np.array([c.disk.effective_bandwidth for c in specs]),
        h2d_lat=np.array([c.h2d.latency for c in specs]),
        h2d_bw=np.array([c.h2d.effective_bandwidth for c in specs]),
        rate=np.array([c.device.peak_flops * c.device.compute_efficiency
                       for c in specs]),
        hbm_bw=np.array([c.device.hbm_bandwidth for c in specs]))


@dataclass
class _PolicyAxis:
    names: list[str]
    overlap_io: np.ndarray            # (P,) bool
    overlap_comm: np.ndarray
    h2d_early: np.ndarray
    has_fast: np.ndarray              # (P,) exact per-layer closed form
    has_tl: np.ndarray                # (P,) exact bucket-timeline form
    tier: np.ndarray                  # (P,) METHOD_LABELS index
    tl_spec: np.ndarray               # (P,) index into tl_specs, -1 = none
    #: Unique ``(bucket_bytes, overlap_comm)`` pairs the kernel must
    #: compute a timeline-residual column for.  Priority-only policies
    #: (no buckets) need no column: order-independence makes their
    #: residual the per-layer WFBP term ``tc_no`` already on hand.
    tl_specs: list[tuple[float, bool]]


def _policy_axis(names: Sequence[str]) -> _PolicyAxis:
    pols: list[Policy] = [get_policy(n) for n in names]
    specs: dict[tuple[float, bool], int] = {}
    tl_spec = np.full(len(pols), -1, dtype=np.int64)
    for i, p in enumerate(pols):
        if analytical.has_timeline_form(p) and p.bucket_bytes:
            key = (float(p.bucket_bytes), bool(p.overlap_comm))
            tl_spec[i] = specs.setdefault(key, len(specs))
    has_fast = np.array([analytical.has_closed_form(p) for p in pols],
                        dtype=bool)
    has_tl = np.array([analytical.has_timeline_form(p) for p in pols],
                      dtype=bool)
    return _PolicyAxis(
        names=list(names),
        overlap_io=np.array([p.overlap_io for p in pols], dtype=bool),
        overlap_comm=np.array([p.overlap_comm for p in pols], dtype=bool),
        h2d_early=np.array([p.h2d_early for p in pols], dtype=bool),
        has_fast=has_fast,
        has_tl=has_tl,
        tier=np.where(has_fast, 0, np.where(has_tl, 1, 2)).astype(np.int64),
        tl_spec=tl_spec,
        tl_specs=list(specs))


# ----------------------------------------------------------------------
# Tier 1: the affine kernel — policy-independent cost terms.
# ----------------------------------------------------------------------
def _collective_coeffs(cax: _ClusterAxis, cidx: np.ndarray,
                       coll: np.ndarray, n: np.ndarray,
                       bwmul: np.ndarray | None = None,
                       latmul: np.ndarray | None = None,
                       ) -> tuple[np.ndarray, np.ndarray]:
    """Per-point affine collective coefficients ``(per_byte,
    per_message)``: every collective model is affine in the payload for
    fixed ``(n, links)`` (see :mod:`repro.core.hardware`), and each
    algorithm's coefficients are evaluated only on its own points (the
    collective axis partitions the kernel grid).

    ``bwmul``/``latmul`` are per-point slowest-worker link multipliers
    (per-worker vectors already reduced by
    :func:`repro.core.analytical.worker_bottleneck`): a heterogeneous
    collective is gated by its slowest link, so both the intra- and
    inter-node parameters are derated before the algorithm dispatch
    (hierarchical scales both levels).  ``None`` (or all-ones — FP
    multiply by 1.0 is exact) leaves the homogeneous path bit-identical.
    """
    n_f = n.astype(np.float64)
    intra_bw, intra_lat = cax.intra_bw[cidx], cax.intra_lat[cidx]
    inter_bw, inter_lat = cax.inter_bw[cidx], cax.inter_lat[cidx]
    if bwmul is not None:
        intra_bw = intra_bw * bwmul
        inter_bw = inter_bw * bwmul
    if latmul is not None:
        intra_lat = intra_lat * latmul
        inter_lat = inter_lat * latmul
    use_intra = n <= cax.gpn[cidx]
    link_bw = np.where(use_intra, intra_bw, inter_bw)
    link_lat = np.where(use_intra, intra_lat, inter_lat)
    codes_present = np.unique(coll)
    if len(codes_present) == 1:
        sels: list = [slice(None)]
    else:
        sels = [np.nonzero(coll == code)[0] for code in codes_present]
    per_byte = np.empty(len(cidx))
    per_message = np.empty(len(cidx))
    for code, sel in zip(codes_present, sels):
        if code == 0:
            a, b = ring_allreduce_coeffs(n_f[sel], link_bw[sel],
                                         link_lat[sel])
        elif code == 1:
            a, b = tree_allreduce_coeffs(n[sel], link_bw[sel],
                                         link_lat[sel])
        else:
            a, b = hierarchical_allreduce_coeffs(
                n[sel], cax.gpn[cidx[sel]], intra_bw[sel], intra_lat[sel],
                inter_bw[sel], inter_lat[sel])
        per_byte[sel], per_message[sel] = a, b
    return per_byte, per_message


def _compute_row_map(wax: _WorkloadAxis, cax: _ClusterAxis,
                     widx: np.ndarray, cidx: np.ndarray,
                     batch: np.ndarray,
                     tmul: np.ndarray | None = None):
    """``(uw, uc, ubatch, ut, uk)``: the unique *compute rows* of a
    point set and the point -> row map.  ``t_f``/``t_b`` (and
    everything derived from them: prefix/suffix sums, ``comp``) depend
    only on ``(workload, device rate, batch)`` — on a product grid that
    is a tiny set (workloads x devices, not x interconnects x workers x
    collectives), so the layer-axis matrices are built on ``U`` rows
    and gathered per point instead of being recomputed ``K`` times.

    ``tmul`` (per-point slowest-worker compute multipliers) joins the
    unique key — it must, because it scales the *measured* time tables
    too, which bypass the device rate — and comes back as the
    per-unique-row ``ut`` column (``None`` when not given).  A constant
    ``tmul`` contributes one key level and leaves the row set (and the
    homogeneous path) unchanged."""
    urate, rinv = np.unique(cax.rate[cidx], return_inverse=True)
    ubv, binv = np.unique(batch, return_inverse=True)
    key = (widx * len(ubv) + binv) * len(urate) + rinv
    if tmul is not None:
        utm, tinv = np.unique(tmul, return_inverse=True)
        key = key * len(utm) + tinv
    _, rep, uk = np.unique(key, return_index=True, return_inverse=True)
    ut = None if tmul is None else tmul[rep]
    return widx[rep], cidx[rep], batch[rep], ut, uk


def _kernel_cols(wax: _WorkloadAxis, cax: _ClusterAxis,
                 widx: np.ndarray, cidx: np.ndarray, coll: np.ndarray,
                 n: np.ndarray, batch: np.ndarray,
                 tl_specs: Sequence[tuple[float, bool]] = (),
                 chunk: int = KERNEL_CHUNK,
                 tmul: np.ndarray | None = None,
                 bwmul: np.ndarray | None = None,
                 latmul: np.ndarray | None = None) -> dict[str, np.ndarray]:
    """Policy-independent terms for every kernel point, reduced over
    the layer axis: ``(K,)`` vectors of ``io_h2d``, ``t_h2d``, ``comp``
    (= sum t_f + sum t_b), ``sum_c``, ``tc_no``, ``t_u``, plus the
    resolved ``n_f``/``batch_f``.

    The evaluation is **cumsum-free over the point axis**: per-point
    collective costs are affine in the payload (``per_byte * M +
    per_message``, :func:`_collective_coeffs`), so every per-layer
    prefix sum collapses to the workload-level cumulative tables
    ``cumgrad``/``cumcount`` scaled by two per-point scalars, and
    ``sum_c`` to ``per_byte * sum(grad) + per_message * n_comm``.  The
    backward-time tables themselves are built once per unique
    ``(workload, rate, batch)`` *compute row* (:func:`_compute_row_map`
    — a handful of rows even on frontier-sized grids) and gathered per
    point.  The surviving ``(k, L)`` work is a fused multiply-add +
    masked max for the WFBP residual, built ``chunk`` points at a time
    so huge grids stay in bounded memory.

    ``tl_specs`` (from :attr:`_PolicyAxis.tl_specs`) adds one
    bucket-timeline residual column ``tl<i>`` per unique
    ``(bucket_bytes, overlap_comm)`` pair, through the same affine
    collapse: bucket structure from the shared
    :func:`repro.core.bucketsim.bucket_table` boundaries, duration
    suffix sums from :func:`repro.core.bucketsim.suffix_tables` (so
    fused buckets amortize latency exactly as
    ``repro.core.costmodel.comm_scale_fn`` does), release times
    gathered from the per-row backward suffix — the exact
    :func:`repro.core.bucketsim.timeline_residual` makespan, never
    materializing a per-point duration matrix.

    ``tmul``/``bwmul``/``latmul`` (all ``(K,)`` or ``None``) are the
    slowest-worker bottleneck multipliers of the heterogeneity engine —
    per-worker vectors already reduced by
    :func:`repro.core.analytical.worker_bottleneck` (and, on the Monte
    Carlo straggler path, already folded with each draw's jitter):
    ``tmul`` scales every compute-time term (analytic *and* measured —
    it joins the unique-row key via :func:`_compute_row_map`), while
    ``bwmul``/``latmul`` derate the collective links
    (:func:`_collective_coeffs`).  ``t_io``/``t_h2d`` stay homogeneous
    (their channels are per-worker and identical) and ``t_u`` is
    HBM-bandwidth-bound, not compute-rate-bound, so neither is scaled.
    All-ones multipliers are bit-identity (IEEE ``x * 1.0 == x``).
    """
    K = len(widx)
    # Per-workload layer tables: inclusive payload/count prefix sums
    # (forward order) for the affine WFBP residual, plus the bucket
    # structure + suffix tables per timeline spec — all O(W x L), built
    # once per call, gathered per chunk.
    grad = wax.grad_bytes
    comm_mask = (grad > 0).astype(np.float64)
    cumgrad = np.cumsum(grad, axis=1)
    cumcount = np.cumsum(comm_mask, axis=1)
    gradsum, ncomm = cumgrad[:, -1], cumcount[:, -1]
    btables = []
    for bb, _ in tl_specs:
        bt = bucketsim.bucket_table(wax.grad_bytes, bb)
        btables.append((bt,) + bucketsim.suffix_tables(bt))
    out = {name: np.empty(K) for name in
           ("io_h2d", "t_h2d", "comp", "sum_c", "tc_no", "t_u",
            "n_f", "batch_f")}
    for i in range(len(tl_specs)):
        out[f"tl{i}"] = np.empty(K)
    for lo in range(0, K, chunk):
        sl = slice(lo, lo + chunk)
        w, c = widx[sl], cidx[sl]
        nn, cl = n[sl], coll[sl]
        batch_f = np.where(batch[sl] > 0, batch[sl],
                           wax.batch_default[w]).astype(np.float64)
        n_f = nn.astype(np.float64)

        # compute costs: (U, L) on the unique compute rows only
        uw, uc, ub, ut, uk = _compute_row_map(
            wax, cax, w, c, batch[sl],
            None if tmul is None else tmul[sl])
        ubatch_f = np.where(ub > 0, ub,
                            wax.batch_default[uw]).astype(np.float64)
        tfa = wax.flops[uw] * ubatch_f[:, None] / cax.rate[uc][:, None]
        t_f = tfa
        t_b = wax.bwd_ratio[uw][:, None] * tfa
        if wax.any_measured:          # adding literal 0.0 rows is exact,
            scale = (ubatch_f / wax.batch_default[uw])[:, None]
            t_f = t_f + wax.tf_meas[uw] * scale    # but skip it when the
            t_b = t_b + wax.tb_meas[uw] * scale    # batch has no traces
        if ut is not None:            # slowest-worker compute multiplier
            t_f = t_f * ut[:, None]
            t_b = t_b * ut[:, None]
        prefix_b = np.cumsum(t_b, axis=1)
        total_b_u = prefix_b[:, -1]
        suffix_b_u = (total_b_u[:, None] - prefix_b) + t_b   # inclusive
        comp_u = t_f.sum(axis=1) + t_b.sum(axis=1)
        total_b = total_b_u[uk]

        # per-point affine collective coefficients
        per_byte, per_message = _collective_coeffs(
            cax, c, cl, nn,
            None if bwmul is None else bwmul[sl],
            None if latmul is None else latmul[sl])

        # pipeline terms: (k,)
        nbytes_in = batch_f * wax.bytes_per_sample[w]
        t_io = cax.disk_lat[c] + nbytes_in / cax.disk_bw[c]
        if wax.any_meas_io:
            t_io = np.where(wax.has_meas_io[w],
                            wax.t_io_meas[w] * batch_f
                            / wax.batch_default[w],
                            t_io)
        t_h2d = cax.h2d_lat[c] + nbytes_in / cax.h2d_bw[c]

        out["io_h2d"][sl] = t_io + t_h2d
        out["t_h2d"][sl] = t_h2d
        out["comp"][sl] = comp_u[uk]
        out["sum_c"][sl] = per_byte * gradsum[w] + per_message * ncomm[w]
        # WFBP residual (non_overlapped_comm_batch, affine form): the
        # comm prefix sum at layer l is per_byte*cumgrad[l] +
        # per_message*cumcount[l]; candidates masked to comm layers
        # (t_c > 0 <=> grad > 0 when n > 1; when n <= 1 both
        # coefficients are 0, every candidate is <= total_b and the
        # clamp yields the same exact 0.0)
        cand = suffix_b_u[uk]
        cand += per_byte[:, None] * cumgrad[w]
        cand += per_message[:, None] * cumcount[w]
        cand *= comm_mask[w]
        out["tc_no"][sl] = np.maximum(
            cand.max(axis=1, initial=0.0) - total_b, 0.0)
        out["t_u"][sl] = 3.0 * wax.param_bytes[w] / cax.hbm_bw[c]
        out["n_f"][sl] = n_f
        out["batch_f"][sl] = batch_f

        # bucket-timeline residuals: the timeline_residual makespan
        # with the duration suffix sum in affine form — release times
        # from the unique-row backward suffix, one fused multiply-add +
        # masked max over the (k, B) bucket axis per spec
        for i, ((bt, sufnb, sufcnt), (_, ov_comm)) in \
                enumerate(zip(btables, tl_specs)):
            if ov_comm:
                release_u = np.take_along_axis(
                    suffix_b_u, bt.release_layer[uw], axis=1)
            else:
                release_u = np.broadcast_to(
                    total_b_u[:, None], (len(uw), bt.n_buckets))
            cand = release_u[uk]
            cand += per_byte[:, None] * sufnb[w]
            cand += per_message[:, None] * sufcnt[w]
            cand *= bt.mask[w]
            out[f"tl{i}"][sl] = np.maximum(
                cand.max(axis=1, initial=0.0) - total_b, 0.0)
    return out


# ----------------------------------------------------------------------
# Tier 2: per-scenario policy select — cheap (S,) vector ops.
# ----------------------------------------------------------------------
def _policy_select(pax: _PolicyAxis, polidx: np.ndarray,
                   kc: dict[str, np.ndarray],
                   kidx: np.ndarray | None,
                   chain_extra: np.ndarray | None = None
                   ) -> dict[str, np.ndarray]:
    """Gather each scenario's kernel point (``kidx=None`` means the
    identity map) and select its policy's steady-state form — Eqs. (2),
    (3), (5) and the late-H2D variants for closed-form policies, the
    bucket-timeline residual for schedule-dependent ones — plus the
    zero-comm weak-scaling baseline with the *same* policy (what
    ``_fast_eval`` / ``_sim_eval`` compute for the speedup column).

    ``chain_extra`` is an additive extension of the GPU/update chain
    (the fault model's serialized checkpoint restores, which gate the
    update broadcast).  It sits *inside* the pipeline max, so an
    I/O-bound pipeline absorbs part of the penalty — exactly what the
    event-driven DAG produces.  The zero-comm baseline ``t1`` is
    unaffected (it is the hypothetical fault-free single-GPU time)."""
    def g(a: np.ndarray) -> np.ndarray:
        return a if kidx is None else a[kidx]

    io_h2d, t_h2d = g(kc["io_h2d"]), g(kc["t_h2d"])
    comp, sum_c = g(kc["comp"]), g(kc["sum_c"])
    tc_no, t_u = g(kc["tc_no"]), g(kc["t_u"])
    n_f, batch_f = g(kc["n_f"]), g(kc["batch_f"])

    ov_io = pax.overlap_io[polidx]
    ov_comm = pax.overlap_comm[polidx]
    early = pax.h2d_early[polidx]

    comm_term = np.where(ov_comm, tc_no, sum_c)     # WFBP residual or full
    # Schedule-dependent overrides.  Bucketed policies substitute their
    # bucket-timeline residual column; priority-only policies need no
    # override — the net channel is work-conserving, so reordering
    # never moves the last comm finish and the per-layer term already
    # selected (tc_no / sum_c) *is* their residual.
    spec_of = pax.tl_spec[polidx]
    for i in range(len(pax.tl_specs)):
        comm_term = np.where(spec_of == i, g(kc[f"tl{i}"]), comm_term)
    gpu_chain = comp + comm_term + t_u
    if chain_extra is not None:
        gpu_chain = gpu_chain + chain_extra
    eq2 = io_h2d + gpu_chain                        # no I/O overlap
    eq_early = np.maximum(io_h2d, gpu_chain)        # Eq. (3)/(5)
    eq_late = np.maximum(io_h2d, t_h2d + gpu_chain)  # late-H2D variants
    t_iter = np.where(~ov_io, eq2, np.where(early, eq_early, eq_late))

    base_chain = comp + t_u                         # zero-comm baseline
    t1 = np.where(~ov_io, io_h2d + base_chain,
                  np.where(early, np.maximum(io_h2d, base_chain),
                           np.maximum(io_h2d, t_h2d + base_chain)))

    # method tier code: index into resulttable.METHOD_LABELS (0 =
    # closed form, 1 = bucket timeline, 2 = simulator-only — the
    # caller discards tier-2 rows for the simulator fallback).  Kept
    # as an int column so the select stays label-free; the table
    # assembly gathers the labels.
    return {
        "batch": batch_f,
        "iteration_time_s": t_iter,
        "samples_per_sec": n_f * batch_f / t_iter,
        "speedup": n_f * t1 / t_iter,
        "t_comm_s": sum_c,
        "t_comp_s": comp,
        "method_code": pax.tier[polidx],
    }


def select_to_columns(cols: dict[str, np.ndarray],
                      labels: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Assemble a tidy columnar table (:data:`repro.core.resulttable.COLUMNS`
    order) from a :func:`_policy_select` output plus per-scenario label
    columns (object arrays, already gathered).  Shared by both batched
    backends — the NumPy grid/list front ends here and
    :class:`repro.core.batched_jax.JaxGridRun`.

    The tail columns ``t_mean_s``/``t_p95_s``/``t_p99_s`` come from the
    straggler Monte Carlo pass when present; deterministic rows (no
    straggler spec, or zero jitter) default to ``iteration_time_s`` —
    the distribution is a point mass there.
    """
    t_iter = np.asarray(cols["iteration_time_s"])
    return {
        "workload": labels["workload"],
        "cluster": labels["cluster"],
        "n_workers": labels["n_workers"],
        "policy": labels["policy"],
        "collective": labels["collective"],
        "interconnect": labels["interconnect"],
        "het": labels["het"],
        "straggler": labels["straggler"],
        "sync_k": labels["sync_k"],
        "faults": labels["faults"],
        "batch_per_gpu": np.asarray(cols["batch"]).astype(np.int64),
        "iteration_time_s": t_iter,
        "samples_per_sec": np.asarray(cols["samples_per_sec"]),
        "speedup": np.asarray(cols["speedup"]),
        "t_comm_s": np.asarray(cols["t_comm_s"]),
        "t_comp_s": np.asarray(cols["t_comp_s"]),
        "t_mean_s": np.asarray(cols.get("t_mean_s", t_iter)),
        "t_p95_s": np.asarray(cols.get("t_p95_s", t_iter)),
        "t_p99_s": np.asarray(cols.get("t_p99_s", t_iter)),
        "method": METHOD_LABELS[np.asarray(cols["method_code"])],
    }


# ----------------------------------------------------------------------
# Failure-model Monte Carlo: per-draw kernel evaluation, reduced to
# tails (straggler jitter, K-of-N sync, fault injection).
# ----------------------------------------------------------------------
def _apply_mc_tails(wax: _WorkloadAxis, cax: _ClusterAxis, pax: _PolicyAxis,
                    widx: np.ndarray, cidx: np.ndarray, coll: np.ndarray,
                    n: np.ndarray, batch: np.ndarray, polidx: np.ndarray,
                    hks: np.ndarray, wtab: dict[str, np.ndarray],
                    bwmul: np.ndarray | None, latmul: np.ndarray | None,
                    st_specs: Sequence, stidx: np.ndarray,
                    cols: dict[str, np.ndarray], seed: int,
                    active: np.ndarray | None = None,
                    synck: np.ndarray | None = None,
                    ft_specs: Sequence = (None,),
                    fidx: np.ndarray | None = None) -> None:
    """Attach ``t_mean_s``/``t_p95_s``/``t_p99_s`` to a
    :func:`_policy_select` output in place.

    Every input array is per-*row*: ``widx``/``cidx``/``coll``/``n``/
    ``batch`` locate the row's kernel point, ``polidx`` its policy,
    ``hks`` its padded worker-table row in ``wtab``
    (:func:`repro.core.het.worker_table_rows`), ``stidx`` its spec in
    ``st_specs`` (parsed :class:`repro.core.het.StragglerSpec` or
    ``None``), ``bwmul``/``latmul`` its deterministic slowest-link
    multipliers, ``synck`` its normalized sync threshold (``0`` = full
    sync) and ``fidx`` its spec in ``ft_specs`` (parsed
    :class:`repro.core.het.FaultSpec` or ``None``).  Deterministic rows
    (no stochastic spec) keep the point-mass default — tails equal to
    ``iteration_time_s``, bit-exact.

    Stochastic rows take a Monte Carlo pass: per draw ``d`` the
    bottleneck theorem applies with multiplier ``kth_w(J[d, w] /
    speed_w)`` — the K-th order statistic of the jitter folded with the
    het profile's per-worker rates (``K = n`` under full sync recovers
    the max; the slow worker and the unlucky worker need not coincide,
    and under K-of-N each draw elects its *own* K-th worker) — so each
    draw is one deterministic kernel evaluation at that ``tmul``.  A
    fault spec contributes a per-draw penalty ``restart * crashes[d]``
    (crash counts from
    :meth:`~repro.core.het.FaultSpec.crash_matrix`) injected into the
    GPU/update chain via ``_policy_select(chain_extra=...)``: restores
    serialize on the shared checkpoint store and gate the update
    broadcast, so they extend the chain *inside* the pipeline max — an
    I/O-bound pipeline absorbs part of the penalty, exactly as the
    event-driven DAG does.  Rows sharing
    ``(kernel point, policy, worker table, sync_k)`` are deduplicated
    first, per-point draw multipliers are built once per unique
    ``(worker-table row, sync_k)`` pair (the ``(D, W)`` matrices come
    from :meth:`~repro.core.het.StragglerSpec.draw_matrix`, keyed by
    ``(spec, n, seed)`` so every backend and shard consumes the
    identical sample; the draw count is the straggler spec's when one
    is present, else the fault spec's), and the expanded ``point x
    draw`` set streams through the ordinary two-tier kernel in blocks
    of roughly :data:`KERNEL_CHUNK` rows.  The per-draw iteration times
    reduce to mean/p95/p99 with ``np.quantile`` on the host — shared by
    the jax backend, which guarantees the draw-for-draw <= 1e-6
    agreement.

    ``active=False`` rows (simulator-fallback policies) are skipped:
    their whole row, tails included, is overwritten by the per-draw
    oracle path in :mod:`repro.core.sweep`.
    """
    t_iter = np.asarray(cols["iteration_time_s"])
    cols["t_mean_s"] = t_iter.copy()
    cols["t_p95_s"] = t_iter.copy()
    cols["t_p99_s"] = t_iter.copy()
    if synck is None:
        synck = np.zeros(len(t_iter), dtype=np.int64)
    if fidx is None:
        fidx = np.zeros(len(t_iter), dtype=np.int64)
    for si, st in enumerate(st_specs):
        st_live = st is not None and not st.is_deterministic
        for fi, ft in enumerate(ft_specs):
            ft_live = ft is not None and not ft.is_deterministic
            if not (st_live or ft_live):
                continue
            sel = (stidx == si) & (fidx == fi)
            if active is not None:
                sel = sel & active
            rows = np.nonzero(sel)[0]
            if not len(rows):
                continue
            # one MC evaluation per unique (kernel point, policy,
            # worker table, sync_k) tuple — rows sharing all four see
            # identical draws
            key = np.stack([widx[rows], cidx[rows], coll[rows], n[rows],
                            batch[rows], polidx[rows], hks[rows],
                            synck[rows]], axis=1)
            _, rep, uinv = np.unique(key, axis=0, return_index=True,
                                     return_inverse=True)
            urows = rows[rep]
            U = len(urows)
            D = st.draws if st_live else ft.draws
            tmuls = np.empty((U, D))
            pens = np.zeros((U, D)) if ft_live else None
            hkpairs = np.stack([hks[urows], synck[urows]], axis=1)
            for h, k in np.unique(hkpairs, axis=0):
                pts = np.nonzero((hkpairs[:, 0] == h)
                                 & (hkpairs[:, 1] == k))[0]
                nw = int(wtab["n"][h])
                J = (st.draw_matrix(nw, seed) if st_live
                     else np.ones((D, nw)))
                times = J * wtab["inv_speed"][h, :nw]      # (D, nw)
                keff = nw if k == 0 else min(max(int(k), 1), nw)
                if keff >= nw:
                    tmuls[pts] = times.max(axis=1)
                else:
                    tmuls[pts] = np.partition(
                        times, keff - 1, axis=1)[:, keff - 1]
                if ft_live:
                    crashes = ft.crash_matrix(
                        nw, seed, draws=D).sum(axis=1)     # (D,)
                    pens[pts] = ft.restart * crashes
            mean_u = np.empty(U)
            p95_u = np.empty(U)
            p99_u = np.empty(U)
            blk = max(1, KERNEL_CHUNK // D)
            for lo in range(0, U, blk):
                pt = urows[lo:lo + blk]
                m = len(pt)
                rp = np.repeat(pt, D)
                kc = _kernel_cols(
                    wax, cax, widx[rp], cidx[rp], coll[rp], n[rp],
                    batch[rp], tl_specs=pax.tl_specs,
                    tmul=tmuls[lo:lo + m].ravel(),
                    bwmul=None if bwmul is None else bwmul[rp],
                    latmul=None if latmul is None else latmul[rp])
                ti = _policy_select(
                    pax, polidx[rp], kc, kidx=None,
                    chain_extra=None if pens is None
                    else pens[lo:lo + m].ravel())[
                    "iteration_time_s"].reshape(m, D)
                mean_u[lo:lo + m] = ti.mean(axis=1)
                p95_u[lo:lo + m] = np.quantile(ti, 0.95, axis=1)
                p99_u[lo:lo + m] = np.quantile(ti, 0.99, axis=1)
            cols["t_mean_s"][rows] = mean_u[uinv]
            cols["t_p95_s"][rows] = p95_u[uinv]
            cols["t_p99_s"][rows] = p99_u[uinv]


# ----------------------------------------------------------------------
# Grid front end: codes straight from the axes, no Scenario objects.
# ----------------------------------------------------------------------
def _axis_codes(sizes: Sequence[int]) -> list[np.ndarray]:
    """Flat cross-product code vectors, rightmost axis fastest — the
    exact :meth:`ScenarioGrid.expand` order."""
    out = []
    for i, size in enumerate(sizes):
        after = int(np.prod(sizes[i + 1:], dtype=np.int64))
        before = int(np.prod(sizes[:i], dtype=np.int64))
        out.append(np.tile(np.repeat(np.arange(size), after), before))
    return out


class GridEvaluator:
    """A :class:`ScenarioGrid` prepared for batched evaluation.

    Builds the axis tables, the kernel-grid code vectors (policy axis
    dropped), the scenario -> kernel-point map and the row label lists
    directly from the grid's cross-product structure — no per-scenario
    Python objects at all.  Closed-form *and* bucket-timeline policies
    are both batched; scenarios whose policy has neither form come
    back as ``None`` rows and :meth:`scenario_at` materializes just
    those for the simulator fallback.

    The evaluator holds only *structure*; :meth:`run` computes the
    numbers.  Get instances through :func:`grid_evaluator`, which
    memoizes them by grid value + workload-table identity.
    """

    def __init__(self, grid: ScenarioGrid):
        grid.validate_axes()
        self.grid = grid
        nW, nC = len(grid.workloads), len(grid.clusters)
        nK, nP = len(grid.worker_counts), len(grid.policies)
        nA, nI = len(grid.collectives), len(grid.interconnects)
        nH, nT = len(grid.het_profiles), len(grid.stragglers)
        nQ, nF = len(grid.sync_ks), len(grid.faults)
        self._sizes = (nW, nC, nK, nP, nA, nI, nH, nT, nQ, nF)
        self.n_scenarios = (nW * nC * nK * nP * nA * nI * nH * nT
                            * nQ * nF)

        self._wax = _workload_axis(grid.workloads)
        pairs = [(c, ic) for c in grid.clusters for ic in grid.interconnects]
        self._cax = _cluster_axis(pairs)
        self._pax = _policy_axis(grid.policies)

        # Kernel grid: the scenario product with the policy, straggler
        # and fault axes dropped — order (workloads, clusters, workers,
        # collectives, interconnects, het_profiles, sync_ks), rightmost
        # fastest.  The straggler and fault axes never change a
        # deterministic kernel point (jitter and crash penalties only
        # enter the Monte Carlo pass); the het axis does, through the
        # bottleneck multipliers, and the sync_k axis does too — it
        # picks *which* order statistic of the per-worker rates gates
        # the iteration.  O(K) int vectors; every per-*scenario*
        # quantity is derived per chunk instead (see _scenario_codes),
        # so preparation stays O(axes + K) however large the scenario
        # product is.
        kw, kc, kk, ka, ki, kh, kq = _axis_codes(
            (nW, nC, nK, nA, nI, nH, nQ))
        self._kwidx = kw
        self._kcidx = kc * nI + ki              # (cluster, interconnect) pair
        self._kcoll = np.array(
            [_COLLECTIVE_CODE[c] for c in grid.collectives],
            dtype=np.int64)[ka]
        self._kn = np.array([int(k) for k in grid.worker_counts],
                            dtype=np.int64)[kk]
        self._kbatch = np.full(len(kw), grid.batch_per_gpu or 0,
                               dtype=np.int64)
        self._khk = kh * nK + kk                # (het profile, n) pair row
        sk_values = np.array(
            [normalize_sync_k(k) for k in grid.sync_ks], dtype=np.int64)
        self._ksynck = sk_values[kq]            # 0 = full sync
        _check_batch_locked(self._wax, kw, self._kbatch)

        # Heterogeneity: one padded per-worker table row per (profile,
        # n_workers) pair, reduced once to the bottleneck multipliers
        # and gathered per kernel point.  All-homogeneous grids keep
        # the multipliers as None so the kernel's fast path stays
        # literally untouched (not merely bit-identical) — exact even
        # under K-of-N sync, where every order statistic of an all-ones
        # rate vector is 1.0; a partial-sync threshold only changes the
        # *deterministic* kernel point when workers actually differ.
        profiles = [het_mod.parse_het_profile(h) for h in grid.het_profiles]
        self._wtab = het_mod.worker_table_rows(
            [(prof, int(n)) for prof in profiles
             for n in grid.worker_counts])
        self._any_het = any(p is not None for p in profiles)
        self._any_synck = bool((sk_values != 0).any())
        if self._any_het:
            tm, bm, lm = analytical.worker_bottleneck(
                self._wtab["inv_speed"], self._wtab["bw_mult"],
                self._wtab["lat_mult"])
            self._kbwmul = bm[self._khk]
            self._klatmul = lm[self._khk]
            if self._any_synck:
                nrow = self._wtab["n"][self._khk]
                self._ktmul = analytical.kth_order_statistic(
                    self._wtab["inv_speed"][self._khk], nrow,
                    analytical.effective_sync_k(self._ksynck, nrow))
            else:
                self._ktmul = tm[self._khk]
        else:
            self._ktmul = self._kbwmul = self._klatmul = None
        self._st_specs = [het_mod.parse_straggler(s)
                          for s in grid.stragglers]
        self._ft_specs = [het_mod.parse_fault(f) for f in grid.faults]
        self._any_mc = (
            any(s is not None and not s.is_deterministic
                for s in self._st_specs)
            or any(f is not None and not f.is_deterministic
                   for f in self._ft_specs))

        per_policy = self.n_scenarios // nP if nP else 0
        self.n_fast = per_policy * int(self._pax.has_fast.sum())
        self.n_timeline = per_policy * int(self._pax.has_tl.sum())
        self.all_batched = \
            self.n_fast + self.n_timeline == self.n_scenarios

        # Per-axis label values (tiny object arrays, fancy-indexed per
        # chunk by the derived codes).
        self._wl_values = np.array(list(grid.workloads), dtype=object)
        self._cl_values = np.array(list(grid.clusters), dtype=object)
        self._n_values = np.array([int(k) for k in grid.worker_counts],
                                  dtype=np.int64)
        self._pol_values = np.array(list(grid.policies), dtype=object)
        self._coll_values = np.array(list(grid.collectives), dtype=object)
        self._ic_values = np.array(
            [normalize_interconnect(ic) for ic in grid.interconnects],
            dtype=object)
        self._ht_values = np.array(
            [het_mod.normalize_het(h) for h in grid.het_profiles],
            dtype=object)
        self._st_values = np.array(
            [het_mod.normalize_straggler(s) for s in grid.stragglers],
            dtype=object)
        self._sk_values = sk_values
        self._fl_values = np.array(
            [het_mod.normalize_fault(f) for f in grid.faults],
            dtype=object)

    def __len__(self) -> int:
        return self.n_scenarios

    def _scenario_codes(self, lo: int, hi: int) -> dict[str, np.ndarray]:
        """Axis codes, the kernel-point map and the fast mask for flat
        scenario indices ``[lo, hi)``, derived arithmetically from the
        expand() order (rightmost axis fastest) — O(chunk) work and
        memory, nothing per-scenario is ever stored."""
        nW, nC, nK, nP, nA, nI, nH, nT, nQ, nF = self._sizes
        r = np.arange(lo, hi, dtype=np.int64)
        fli = r % nF
        r //= nF
        ski = r % nQ
        r //= nQ
        sti = r % nT
        r //= nT
        hp = r % nH
        r //= nH
        ii = r % nI
        r //= nI
        ai = r % nA
        r //= nA
        pi = r % nP
        r //= nP
        ki = r % nK
        r //= nK
        ci = r % nC
        wi = r // nC
        kidx = ((((((wi * nC + ci) * nK + ki) * nA + ai) * nI + ii) * nH
                 + hp) * nQ + ski)
        return {"wi": wi, "ci": ci, "ki": ki, "pi": pi, "ai": ai, "ii": ii,
                "hi": hp, "sti": sti, "ski": ski, "fli": fli, "kidx": kidx,
                "batched": self._pax.has_fast[pi] | self._pax.has_tl[pi]}

    def _label_columns(self, codes: dict[str, np.ndarray]) -> dict:
        return {
            "workload": self._wl_values[codes["wi"]],
            "cluster": self._cl_values[codes["ci"]],
            "n_workers": self._n_values[codes["ki"]],
            "policy": self._pol_values[codes["pi"]],
            "collective": self._coll_values[codes["ai"]],
            "interconnect": self._ic_values[codes["ii"]],
            "het": self._ht_values[codes["hi"]],
            "straggler": self._st_values[codes["sti"]],
            "sync_k": self._sk_values[codes["ski"]],
            "faults": self._fl_values[codes["fli"]],
        }

    def _apply_tails(self, codes: dict[str, np.ndarray],
                     cols: dict[str, np.ndarray], seed: int) -> None:
        """Attach the tail columns for the rows of ``codes`` in place:
        the point-mass default everywhere, overwritten by the straggler
        Monte Carlo pass (:func:`_apply_mc_tails`) on stochastic rows.
        Simulator-fallback rows are excluded — their tails come from
        the per-draw oracle in :mod:`repro.core.sweep`."""
        if not self._any_mc:
            t_iter = np.asarray(cols["iteration_time_s"])
            cols["t_mean_s"] = t_iter
            cols["t_p95_s"] = t_iter
            cols["t_p99_s"] = t_iter
            return
        k = codes["kidx"]
        _apply_mc_tails(
            self._wax, self._cax, self._pax,
            self._kwidx[k], self._kcidx[k], self._kcoll[k], self._kn[k],
            self._kbatch[k], codes["pi"], self._khk[k], self._wtab,
            None if self._kbwmul is None else self._kbwmul[k],
            None if self._klatmul is None else self._klatmul[k],
            self._st_specs, codes["sti"], cols, seed,
            active=codes["batched"], synck=self._ksynck[k],
            ft_specs=self._ft_specs, fidx=codes["fli"])

    def run(self, seed: int = 0) -> "GridRun":
        """Evaluate the kernel grid (fresh numbers every call) and
        return the per-run table materializer.  ``seed`` keys the
        straggler Monte Carlo draws (ignored on deterministic grids)."""
        return GridRun(self, _kernel_cols(
            self._wax, self._cax, self._kwidx, self._kcidx,
            self._kcoll, self._kn, self._kbatch,
            tl_specs=self._pax.tl_specs,
            tmul=self._ktmul, bwmul=self._kbwmul, latmul=self._klatmul),
            seed=seed)

    def run_span(self, lo: int, hi: int, seed: int = 0):
        """Evaluate just the flat scenario indices ``[lo, hi)`` —
        kernel restricted to the unique kernel points the span touches,
        so a worker evaluating one shard never pays for the whole grid.
        Returns ``(table, batched)``: the columnar result table and the
        per-row batched mask (``False`` rows carry tier-2 placeholder
        numbers the caller must overwrite with the simulator — see
        :mod:`repro.core.parallel`)."""
        codes = self._scenario_codes(lo, hi)
        uk, inv = np.unique(codes["kidx"], return_inverse=True)
        kc = _kernel_cols(
            self._wax, self._cax, self._kwidx[uk], self._kcidx[uk],
            self._kcoll[uk], self._kn[uk], self._kbatch[uk],
            tl_specs=self._pax.tl_specs,
            tmul=None if self._ktmul is None else self._ktmul[uk],
            bwmul=None if self._kbwmul is None else self._kbwmul[uk],
            latmul=None if self._klatmul is None else self._klatmul[uk])
        cols = _policy_select(self._pax, codes["pi"], kc, inv)
        self._apply_tails(codes, cols, seed)
        return (select_to_columns(cols, self._label_columns(codes)),
                codes["batched"])

    def scenario_at(self, i: int) -> Scenario:
        """Materialize flat index ``i`` (used for simulator-fallback
        entries only)."""
        return self.grid.scenario_at(i)


class GridRun:
    """One evaluation of a grid: the ``(K,)`` kernel columns plus the
    shared structure, materializing columnar result tables chunk by
    chunk (:meth:`table_slice` is the hot path; :meth:`rows_slice` is
    the per-row compat view)."""

    def __init__(self, ev: GridEvaluator, kernel_cols: dict[str, np.ndarray],
                 seed: int = 0):
        self._ev = ev
        self._kc = kernel_cols
        self._seed = seed

    def __len__(self) -> int:
        return self._ev.n_scenarios

    def columns_slice(self, lo: int, hi: int) -> dict[str, np.ndarray]:
        """Numeric result columns (plus ``method`` labels as a Python
        list) for flat scenario indices ``[lo, hi)`` — the
        policy-selected values before tidy-table assembly.  The
        kernel-only surface the throughput benchmark times and the jax
        backend's differential gate compares against."""
        ev = self._ev
        codes = ev._scenario_codes(lo, hi)
        cols = _policy_select(ev._pax, codes["pi"], self._kc, codes["kidx"])
        ev._apply_tails(codes, cols, self._seed)
        cols["method"] = METHOD_LABELS[cols.pop("method_code")].tolist()
        return cols

    def table_slice(self, lo: int, hi: int):
        """Columnar result table for flat scenario indices ``[lo, hi)``
        in grid order — label columns gathered from the per-axis value
        arrays, numeric columns straight from the policy select.
        Returns ``(table, batched)`` where ``batched`` is the per-row
        mask; ``False`` rows carry tier-2 placeholder numbers (their
        policy needs the simulator) that the caller overwrites via
        :func:`repro.core.resulttable.fill_rows`."""
        ev = self._ev
        codes = ev._scenario_codes(lo, hi)
        cols = _policy_select(ev._pax, codes["pi"], self._kc, codes["kidx"])
        ev._apply_tails(codes, cols, self._seed)
        return (select_to_columns(cols, ev._label_columns(codes)),
                codes["batched"])

    def rows_slice(self, lo: int, hi: int) -> list[dict | None]:
        """Batched rows for flat scenario indices ``[lo, hi)`` in grid
        order; entries whose policy needs the simulator come back as
        ``None`` for the caller to fill."""
        table, batched = self.table_slice(lo, hi)
        rows: list[dict | None] = rows_from_table(table)
        if not self._ev.all_batched:
            for i in np.nonzero(~batched)[0].tolist():
                rows[i] = None                # selected a bogus equation
        return rows


#: Structure memo: prepared evaluators keyed by grid value + the
#: identity of the resolved workload tables (holding the tables alive
#: keeps the ids stable; a re-resolved table — e.g. an on-disk trace
#: whose mtime changed — misses the memo and rebuilds).
_EVALUATOR_MEMO: dict = {}
_MEMO_LIMIT = 64


def grid_evaluator(grid: ScenarioGrid) -> GridEvaluator:
    """Memoized :class:`GridEvaluator` for ``grid`` (falls back to a
    fresh instance when the grid isn't hashable, e.g. list-valued
    axes)."""
    try:
        tables = tuple(resolve_workload(w) for w in grid.workloads)
        key = (grid, tuple(id(t) for t in tables))
        hash(key)
    except TypeError:
        return GridEvaluator(grid)
    hit = _EVALUATOR_MEMO.get(key)
    if hit is not None:
        return hit[0]
    if len(_EVALUATOR_MEMO) >= _MEMO_LIMIT:
        _EVALUATOR_MEMO.clear()
    ev = GridEvaluator(grid)
    _EVALUATOR_MEMO[key] = (ev, tables)
    return ev


def evaluator_cached(grid: ScenarioGrid) -> bool:
    """True when :func:`grid_evaluator` would hit the structure memo —
    a pure probe (nothing is built, no entry is added), which is how
    the sweep service (:mod:`repro.core.service`) accounts cache
    hit/miss rates without perturbing the cache it is measuring."""
    try:
        tables = tuple(resolve_workload(w) for w in grid.workloads)
        key = (grid, tuple(id(t) for t in tables))
        hash(key)
    except (TypeError, ValueError):
        return False
    return key in _EVALUATOR_MEMO


# ----------------------------------------------------------------------
# Scenario-list front end (arbitrary iterables, already validated).
# ----------------------------------------------------------------------
def scenario_axes(scenarios: Sequence[Scenario]):
    """One Python pass over a scenario list: resolve the unique
    workload/cluster-pair/policy axes and the per-scenario code
    vectors.  Returns ``(wax, cax, pax, widx, cidx, polidx, coll, n,
    batch)`` — the inputs of the two-tier kernel with the identity
    scenario -> kernel-point map.  Shared by :func:`eval_scenarios`
    and the jax backend's list front end
    (:func:`repro.core.batched_jax.eval_scenarios_jax`), raising
    ``ValueError`` if any scenario's policy has neither a closed nor a
    bucket-timeline form.
    """
    wl_key: dict[str, int] = {}
    pair_key: dict[tuple[str, str | None], int] = {}
    pol_key: dict[str, int] = {}
    widx = np.empty(len(scenarios), dtype=np.int64)
    cidx = np.empty(len(scenarios), dtype=np.int64)
    polidx = np.empty(len(scenarios), dtype=np.int64)
    coll = np.empty(len(scenarios), dtype=np.int64)
    n = np.empty(len(scenarios), dtype=np.int64)
    batch = np.empty(len(scenarios), dtype=np.int64)
    for i, s in enumerate(scenarios):
        wi = wl_key.get(s.workload)
        if wi is None:
            wi = wl_key[s.workload] = len(wl_key)
        widx[i] = wi
        pk = (s.cluster, s.interconnect)
        ci = pair_key.get(pk)
        if ci is None:
            ci = pair_key[pk] = len(pair_key)
        cidx[i] = ci
        pi = pol_key.get(s.policy)
        if pi is None:
            pi = pol_key[s.policy] = len(pol_key)
        polidx[i] = pi
        coll[i] = _COLLECTIVE_CODE[s.collective]
        n[i] = s.n_workers
        batch[i] = s.batch_per_gpu or 0
    wax = _workload_axis(list(wl_key))
    _check_batch_locked(wax, widx, batch)
    cax = _cluster_axis(list(pair_key))
    pax = _policy_axis(list(pol_key))
    batched_ok = pax.has_fast | pax.has_tl
    if not bool(batched_ok[polidx].all()):
        bad = [pax.names[int(p)]
               for p in np.unique(polidx[~batched_ok[polidx]])]
        raise ValueError(f"policies with neither a closed form nor a "
                         f"bucket-timeline form cannot take the batched "
                         f"path: {bad}")
    return wax, cax, pax, widx, cidx, polidx, coll, n, batch


def scenario_het_axes(scenarios: Sequence[Scenario]):
    """One Python pass over a scenario list: the heterogeneity /
    failure-model structure the kernel and the Monte Carlo pass need.
    Returns ``(hks, wtab, tmul, bwmul, latmul, st_specs, stidx, synck,
    ft_specs, fidx)`` — per-scenario rows into a padded worker table
    over the unique ``(het, n_workers)`` pairs, the reduced bottleneck
    multiplier vectors (``None`` when every scenario is homogeneous,
    keeping the kernel's fast path untouched; the compute multiplier is
    the ``sync_k``-th order statistic when a partial-sync threshold is
    present), the unique parsed straggler specs with the per-scenario
    index, the normalized per-scenario sync thresholds (``0`` = full
    sync) and the unique parsed fault specs with the per-scenario
    index.  Shared with the jax list front end so both backends agree
    on structure."""
    pair_key: dict[tuple[str, int], int] = {}
    st_key: dict[str, int] = {}
    fl_key: dict[str, int] = {}
    hks = np.empty(len(scenarios), dtype=np.int64)
    stidx = np.empty(len(scenarios), dtype=np.int64)
    fidx = np.empty(len(scenarios), dtype=np.int64)
    synck = np.empty(len(scenarios), dtype=np.int64)
    any_het = False
    for i, s in enumerate(scenarios):
        hspec = het_mod.normalize_het(s.het)
        pk = (hspec, int(s.n_workers))
        j = pair_key.get(pk)
        if j is None:
            j = pair_key[pk] = len(pair_key)
        hks[i] = j
        if hspec != "none":
            any_het = True
        sk = het_mod.normalize_straggler(s.straggler)
        si = st_key.get(sk)
        if si is None:
            si = st_key[sk] = len(st_key)
        stidx[i] = si
        fl = het_mod.normalize_fault(s.faults)
        fi = fl_key.get(fl)
        if fi is None:
            fi = fl_key[fl] = len(fl_key)
        fidx[i] = fi
        synck[i] = normalize_sync_k(s.sync_k)
    wtab = het_mod.worker_table_rows(
        [(het_mod.parse_het_profile(h), n) for h, n in pair_key])
    if any_het:
        tm, bm, lm = analytical.worker_bottleneck(
            wtab["inv_speed"], wtab["bw_mult"], wtab["lat_mult"])
        bwmul, latmul = bm[hks], lm[hks]
        if bool((synck != 0).any()):
            nrow = wtab["n"][hks]
            tmul = analytical.kth_order_statistic(
                wtab["inv_speed"][hks], nrow,
                analytical.effective_sync_k(synck, nrow))
        else:
            tmul = tm[hks]
    else:
        tmul = bwmul = latmul = None
    st_specs = [het_mod.parse_straggler(s) for s in st_key]
    ft_specs = [het_mod.parse_fault(f) for f in fl_key]
    return (hks, wtab, tmul, bwmul, latmul, st_specs, stidx,
            synck, ft_specs, fidx)


def scenario_labels(scenarios: Sequence[Scenario]) -> dict[str, np.ndarray]:
    """Per-scenario label columns (object arrays) for a scenario list —
    the list front end's counterpart of the grid's per-axis value
    arrays.  Shared with :func:`repro.core.batched_jax.eval_scenarios_jax`."""
    return {
        "workload": np.array([s.workload for s in scenarios], dtype=object),
        "cluster": np.array([s.cluster for s in scenarios], dtype=object),
        "n_workers": np.array([s.n_workers for s in scenarios],
                              dtype=np.int64),
        "policy": np.array([s.policy for s in scenarios], dtype=object),
        "collective": np.array([s.collective for s in scenarios],
                               dtype=object),
        "interconnect": np.array(
            [normalize_interconnect(s.interconnect) for s in scenarios],
            dtype=object),
        "het": np.array([het_mod.normalize_het(s.het) for s in scenarios],
                        dtype=object),
        "straggler": np.array(
            [het_mod.normalize_straggler(s.straggler) for s in scenarios],
            dtype=object),
        "sync_k": np.array(
            [normalize_sync_k(s.sync_k) for s in scenarios],
            dtype=np.int64),
        "faults": np.array(
            [het_mod.normalize_fault(s.faults) for s in scenarios],
            dtype=object),
    }


def eval_scenarios_table(scenarios: Sequence[Scenario],
                         seed: int = 0) -> dict[str, np.ndarray]:
    """Columnar result table (input order) for a list of
    batched-path-eligible scenarios (closed-form or bucket-timeline
    policies); one Python pass to build code vectors, then the same
    two-tier kernel the grid front end uses (with the identity
    scenario -> kernel-point map).  ``seed`` keys the straggler Monte
    Carlo draws for stochastic scenarios.

    Raises ``ValueError`` if any scenario's policy has neither form —
    callers (:func:`repro.core.sweep.sweep`) partition first.
    """
    wax, cax, pax, widx, cidx, polidx, coll, n, batch = \
        scenario_axes(scenarios)
    (hks, wtab, tmul, bwmul, latmul, st_specs, stidx,
     synck, ft_specs, fidx) = scenario_het_axes(scenarios)
    kc = _kernel_cols(wax, cax, widx, cidx, coll, n, batch,
                      tl_specs=pax.tl_specs,
                      tmul=tmul, bwmul=bwmul, latmul=latmul)
    cols = _policy_select(pax, polidx, kc, kidx=None)
    _apply_mc_tails(wax, cax, pax, widx, cidx, coll, n, batch, polidx,
                    hks, wtab, bwmul, latmul, st_specs, stidx,
                    cols, seed, synck=synck, ft_specs=ft_specs, fidx=fidx)
    return select_to_columns(cols, scenario_labels(scenarios))


def eval_scenarios(scenarios: Sequence[Scenario],
                   seed: int = 0) -> list[dict]:
    """Batched rows (input order) for a scenario list — the per-row
    view of :func:`eval_scenarios_table`."""
    if not scenarios:
        return []
    return rows_from_table(eval_scenarios_table(scenarios, seed=seed))
