"""Per-layer cost model: FLOPs / parameter bytes for every workload.

Two families:

* The paper's own CNN workloads (Table IV: AlexNet, GoogleNet,
  ResNet-50) — layer tables generated from the published architectures,
  used to populate DAG communication/computation nodes when no measured
  trace is available.
* The assigned transformer architectures — per-block FLOPs/params from
  the configs, used by the predictor to extend the paper's model to the
  TPU production mesh.

FLOPs here are *per training sample* multiply-accumulate*2 for the
forward pass; backward is modeled as ``2x`` forward (two GEMMs per
GEMM: dgrad + wgrad), the standard approximation the paper's traces
corroborate.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

from repro.core.dag import IterationCosts
from repro.core.hardware import ClusterSpec


@dataclass(frozen=True)
class LayerSpec:
    """One DAG layer's static costs: ``flops_fwd`` in **flop/sample**
    (forward pass), ``params`` as a raw count (0 = no gradient sync
    node for this layer in Fig. 1)."""

    name: str
    flops_fwd: float          # per-sample forward flops
    params: int               # learnable parameter count (0 = no gradient sync)

    @property
    def grad_bytes(self) -> float:
        """Gradient all-reduce payload in **bytes** (f32, as in the paper)."""
        return 4.0 * self.params


def conv(name: str, h: int, w: int, cout: int, k: int, cin: int,
         groups: int = 1) -> LayerSpec:
    """Conv layer: ``h x w`` output, ``k x k`` kernel — flops are
    multiply-accumulate*2 per sample, params include the bias."""
    cin_g = cin // groups
    flops = 2.0 * h * w * cout * k * k * cin_g
    params = cout * (k * k * cin_g) + cout
    return LayerSpec(name, flops, params)


def fc(name: str, nin: int, nout: int) -> LayerSpec:
    """Fully-connected layer: ``2 * nin * nout`` flop/sample."""
    return LayerSpec(name, 2.0 * nin * nout, nin * nout + nout)


def act(name: str, elems: int) -> LayerSpec:
    """Activation / pooling / norm: ~1 flop per element, no params —
    never produces a communication node."""
    return LayerSpec(name, float(elems), 0)


# ----------------------------------------------------------------------
# AlexNet (Krizhevsky 2012, LRN excluded per the paper's Table IV note).
# ----------------------------------------------------------------------
def alexnet_layers() -> list[LayerSpec]:
    return [
        conv("conv1", 55, 55, 96, 11, 3),
        act("relu1+pool1", 55 * 55 * 96 + 27 * 27 * 96),
        conv("conv2", 27, 27, 256, 5, 96, groups=2),
        act("relu2+pool2", 27 * 27 * 256 + 13 * 13 * 256),
        conv("conv3", 13, 13, 384, 3, 256),
        act("relu3", 13 * 13 * 384),
        conv("conv4", 13, 13, 384, 3, 384, groups=2),
        act("relu4", 13 * 13 * 384),
        conv("conv5", 13, 13, 256, 3, 384, groups=2),
        act("relu5+pool5", 13 * 13 * 256 + 6 * 6 * 256),
        fc("fc6", 9216, 4096),
        act("relu6+drop6", 4096 * 2),
        fc("fc7", 4096, 4096),
        act("relu7+drop7", 4096 * 2),
        fc("fc8", 4096, 1000),
    ]


# ----------------------------------------------------------------------
# ResNet-50 (He et al. 2015).
# ----------------------------------------------------------------------
def resnet50_layers() -> list[LayerSpec]:
    layers: list[LayerSpec] = [conv("conv1", 112, 112, 64, 7, 3)]
    cfg = [  # (blocks, in_ch, mid_ch, out_ch, spatial)
        (3, 64, 64, 256, 56),
        (4, 256, 128, 512, 28),
        (6, 512, 256, 1024, 14),
        (3, 1024, 512, 2048, 7),
    ]
    for stage, (blocks, cin, mid, cout, hw) in enumerate(cfg, start=2):
        for b in range(blocks):
            cin_b = cin if b == 0 else cout
            pre = f"res{stage}{chr(ord('a') + b)}"
            layers.append(conv(f"{pre}_1x1a", hw, hw, mid, 1, cin_b))
            layers.append(conv(f"{pre}_3x3", hw, hw, mid, 3, mid))
            layers.append(conv(f"{pre}_1x1b", hw, hw, cout, 1, mid))
            if b == 0:
                layers.append(conv(f"{pre}_proj", hw, hw, cout, 1, cin_b))
            layers.append(act(f"{pre}_bn_relu", 3 * hw * hw * cout))
    layers.append(fc("fc1000", 2048, 1000))
    return layers


# ----------------------------------------------------------------------
# GoogleNet / Inception-v1 (Szegedy et al. 2015).
# Note: actual parameter count is ~7M; the paper's Table IV quotes
# "~53 millions", which does not match the published architecture — we
# use the real architecture (documented deviation, DESIGN.md §9).
# ----------------------------------------------------------------------
_INCEPTION = [  # name, hw, cin, 1x1, 3x3red, 3x3, 5x5red, 5x5, pool_proj
    ("3a", 28, 192, 64, 96, 128, 16, 32, 32),
    ("3b", 28, 256, 128, 128, 192, 32, 96, 64),
    ("4a", 14, 480, 192, 96, 208, 16, 48, 64),
    ("4b", 14, 512, 160, 112, 224, 24, 64, 64),
    ("4c", 14, 512, 128, 128, 256, 24, 64, 64),
    ("4d", 14, 512, 112, 144, 288, 32, 64, 64),
    ("4e", 14, 528, 256, 160, 320, 32, 128, 128),
    ("5a", 7, 832, 256, 160, 320, 32, 128, 128),
    ("5b", 7, 832, 384, 192, 384, 48, 128, 128),
]


def googlenet_layers() -> list[LayerSpec]:
    layers = [
        conv("conv1", 112, 112, 64, 7, 3),
        conv("conv2_red", 56, 56, 64, 1, 64),
        conv("conv2", 56, 56, 192, 3, 64),
    ]
    for name, hw, cin, c1, c3r, c3, c5r, c5, cp in _INCEPTION:
        flops = params = 0.0
        for spec in (conv("x", hw, hw, c1, 1, cin),
                     conv("x", hw, hw, c3r, 1, cin),
                     conv("x", hw, hw, c3, 3, c3r),
                     conv("x", hw, hw, c5r, 1, cin),
                     conv("x", hw, hw, c5, 5, c5r),
                     conv("x", hw, hw, cp, 1, cin)):
            flops += spec.flops_fwd
            params += spec.params
        layers.append(LayerSpec(f"inception_{name}", flops, int(params)))
    layers.append(fc("fc1000", 1024, 1000))
    return layers


CNN_WORKLOADS = {
    # name -> (layer list builder, per-GPU batch from Table IV, bytes/sample on disk)
    "alexnet": (alexnet_layers, 1024, 110e3),
    "googlenet": (googlenet_layers, 64, 110e3),
    "resnet50": (resnet50_layers, 32, 110e3),
}


def total_params(layers: Sequence[LayerSpec]) -> int:
    """Total learnable parameter count (multiply by 4 for f32 bytes)."""
    return sum(l.params for l in layers)


def total_flops(layers: Sequence[LayerSpec]) -> float:
    """Total forward flop/sample across the layer table."""
    return sum(l.flops_fwd for l in layers)


# ----------------------------------------------------------------------
# LayerSpec list -> IterationCosts on a concrete cluster.
# ----------------------------------------------------------------------
def make_iteration_costs(
    layers: Sequence[LayerSpec] | str,
    cluster: ClusterSpec,
    batch_per_gpu: int,
    n_workers: int,
    bytes_per_sample: float | None = None,
    bwd_fwd_ratio: float | None = None,
    decode_seconds_per_byte: float = 0.0,
    collective: str = "ring",
) -> IterationCosts:
    """Build the paper's Table-I cost vocabulary (all entries in
    **seconds**) from a layer table.

    ``layers`` may also be a workload *name* (``"resnet50"``,
    ``"cnn:alexnet"``, ``"trace:alexnet-k80"``, ``"llm:gemma3-1b"`` —
    anything :func:`repro.core.workloads.resolve_workload` accepts), in
    which case the memoized registry table supplies the per-layer
    costs; ``bytes_per_sample`` ``None`` then means the workload's own
    value (and 110e3, the Table-IV ImageNet figure, for a layer table).

    From a layer table:

    * ``t_f``/``t_b`` per layer from per-sample forward FLOPs at the
      device's achieved flop/s (backward = ``bwd_fwd_ratio`` x forward);
    * ``t_c`` per layer from the cluster's all-reduce model for
      ``collective`` (one of
      :data:`repro.core.hardware.COLLECTIVE_ALGORITHMS`);
    * ``t_io``/``t_h2d`` from ``batch_per_gpu * bytes_per_sample`` bytes
      over the disk and PCIe links (Eq. 1's input pipeline terms);
    * ``t_u`` as one read-modify-write sweep over all parameter bytes at
      HBM bandwidth.

    ``decode_seconds_per_byte`` models host-side JPEG decode in
    **seconds per input byte** — achieved host decode rate, inverted
    (the paper attributes CNTK/TF's poor AlexNet scaling to CPU-side
    decoding of 4096 images/iter); it inflates ``t_io``.
    """
    if isinstance(layers, str):
        from repro.core.workloads import resolve_workload  # circular-safe

        return resolve_workload(layers).iteration_costs(
            cluster, batch_per_gpu, n_workers, collective,
            bwd_fwd_ratio=bwd_fwd_ratio,
            bytes_per_sample=bytes_per_sample,
            decode_seconds_per_byte=decode_seconds_per_byte)
    if bytes_per_sample is None:
        bytes_per_sample = 110e3
    if bwd_fwd_ratio is None:
        bwd_fwd_ratio = 2.0
    t_f = [cluster.compute_time(l.flops_fwd * batch_per_gpu) for l in layers]
    t_b = [bwd_fwd_ratio * tf for tf in t_f]
    t_c = [cluster.allreduce_time(l.grad_bytes, n_workers, collective)
           if l.params else 0.0 for l in layers]
    grad_bytes = [l.grad_bytes for l in layers]
    nbytes_in = batch_per_gpu * bytes_per_sample
    t_io = cluster.io_time(nbytes_in) + decode_seconds_per_byte * nbytes_in
    t_h2d = cluster.h2d_time(nbytes_in)
    t_u = update_time(4.0 * total_params(layers), cluster)
    return IterationCosts(t_f=t_f, t_b=t_b, t_c=t_c, t_io=t_io, t_h2d=t_h2d,
                          t_u=t_u, grad_bytes=grad_bytes)


def update_time(param_bytes: float, cluster: ClusterSpec) -> float:
    """``t_u`` in seconds: the SGD update as one read-modify-write
    sweep over ``param_bytes`` bytes of parameters at HBM bandwidth
    (3x traffic: read param, read grad, write param)."""
    return 3.0 * param_bytes / cluster.device.hbm_bandwidth


def comm_scale_fn(cluster: ClusterSpec, n_workers: int,
                  collective: str = "ring"):
    """Bucket-fusion collective model for the DAG builder: maps a fused
    bucket's total gradient bytes to one collective's duration in
    seconds under the chosen algorithm (ring / tree / hierarchical)."""

    def scale(total_bytes: float, _naive_time: float) -> float:
        return cluster.allreduce_time(total_bytes, n_workers, collective)

    return scale
