"""Persistent sweep service: hot caches, query micro-batching, QoS.

The engine behind the what-if server (:mod:`repro.launch.serve_sweep`).
A one-shot CLI sweep pays full preparation on every invocation —
workload-table resolution, grid-structure memos, jax jit compilation —
before the kernel's actual millions-of-scenarios-per-second shows up.
:class:`SweepService` amortizes all of it across a process lifetime:

* **Request queue → micro-batching coalescer.**  Queries land on a
  queue; a dispatcher thread collects everything that arrives within a
  short batch window (``window_s``) and groups it by **kernel
  signature** ``(backend, seed, padded layer depth)`` — see
  :attr:`Query.signature` for why the padding depth is part of the
  key.  Heterogeneous queries — different grids,
  het/straggler/sync-k/fault axes — share a signature as long as
  their policies are batched-eligible, because the scenario-list
  kernels are row-wise over ``(S, L)`` matrices.
* **One fused kernel call per group.**  A group's queries have their
  scenario lists concatenated and evaluated by **one**
  :func:`repro.core.batched.eval_scenarios_table` /
  :func:`repro.core.batched_jax.eval_scenarios_table_jax` call; the
  resulting columnar table is de-multiplexed back per query by offset
  (:func:`repro.core.resulttable.slice_table` — views, not copies).
  The per-point arithmetic is elementwise and the Monte Carlo draws
  are keyed by ``(spec, n_workers, seed)`` alone, so a coalesced
  query's columns are **bit-identical** to a direct :func:`sweep` of
  its grid on both backends (``np.array_equal`` per column, pinned by
  ``tests/test_service.py``).  A group of one routes through the
  memoized grid front end instead — same results, and the structure
  memos (:func:`repro.core.batched.grid_evaluator` /
  ``batched_jax._JAX_MEMO``) stay hot for repeated queries.
* **Process-lifetime caches.**  Workload tables
  (``repro.core.workloads._TABLES``), grid-structure memos and
  compiled jax executables live as long as the service; the service
  additionally memoizes grid expansions (the coalescer's Python-side
  cost).  Cache hit/miss rates are *probed* per query
  (:func:`repro.core.batched.evaluator_cached`,
  :func:`repro.core.workloads.workload_cached`) without perturbing the
  caches being measured.
* **QoS telemetry** (:class:`ServiceStats`): per-query latency and
  queue-wait percentiles, queue depth, coalesce factor (queries per
  kernel call), cache hit rates, sustained scenarios/s over kernel-busy
  time, error counts — served by the launcher's ``/stats`` endpoint
  and echoed per query in the streamed trailer's ``qos`` entry.

Degenerate queries never take the service down and never divide by
zero: :func:`parse_query` rejects malformed specs, unknown axis values
and zero-scenario grids with a structured :class:`QueryError` (a
stable ``code`` plus the same human-readable message the CLI prints
before exiting 2), and evaluation failures resolve only the tickets of
the failing group.

The trailer of every query carries the
:data:`repro.core.sweep.RESULT_META_KEYS` metadata —
``scenarios_per_sec`` guarded against zero elapsed — plus the ``qos``
dict, mirroring :meth:`repro.core.sweep.SweepResult.to_json` key for
key (parity pinned by the tests).
"""
from __future__ import annotations

import threading
import time
from collections import deque
from dataclasses import dataclass, field
from queue import Empty, Queue

import numpy as np

from repro.core import analytical
from repro.core.batched import eval_scenarios_table, evaluator_cached
from repro.core.policies import get_policy
from repro.core.resulttable import method_counts, slice_table, table_len
from repro.core.scenarios import (BASE_GRIDS, GRID_SPEC_KEYS, Scenario,
                                  ScenarioGrid, grid_from_spec)
from repro.core.sweep import BACKENDS, RESULT_META_KEYS, sweep
from repro.core.workloads import resolve_workload, workload_cached


class QueryError(ValueError):
    """Structured rejection of a query — the server-side counterpart
    of the CLI's exit-2 path.  ``code`` is a stable machine-readable
    slug (``bad-query`` / ``empty-grid`` / ``evaluation-failed``);
    ``str(exc)`` the human-readable message."""

    def __init__(self, message: str, code: str = "bad-query"):
        super().__init__(message)
        self.code = code


#: Keys a query document may carry: the grid-spec vocabulary plus the
#: evaluation knobs.
QUERY_KEYS = ("grid",) + GRID_SPEC_KEYS + ("backend", "seed")


@dataclass(frozen=True)
class Query:
    """One parsed, validated what-if query: a grid plus the kernel
    signature ``(backend, seed)`` it must be evaluated under.
    ``coalescable`` is False only when the grid contains a policy with
    neither batched form (such queries are served solo through the
    NumPy simulator fallback, never fused with others)."""

    grid: ScenarioGrid
    backend: str = "numpy"
    seed: int = 0
    coalescable: bool = True

    @property
    def signature(self) -> tuple:
        """The kernel-compatibility key.  Besides backend and seed it
        carries the grid's **padded layer depth**: the kernels zero-pad
        every workload's layer tables to the batch's deepest workload
        and reduce with ``.sum(axis=1)``, whose pairwise-summation tree
        depends on the padded length — so bit-identity with a direct
        per-grid sweep requires that coalescing never change a query's
        padding.  Grouping by equal depth guarantees the union's
        ``L_max`` equals each member's own."""
        lmax = max(resolve_workload(w).num_layers
                   for w in self.grid.workloads)
        return (self.backend, self.seed, lmax)


def parse_query(doc: dict) -> Query:
    """A :class:`Query` from a wire document, or :class:`QueryError`.

    The document is the :func:`repro.core.scenarios.grid_from_spec`
    vocabulary (``grid`` / axis keys) plus ``backend`` and ``seed`` —
    every grid the sweep CLI accepts is expressible, and every spec the
    CLI exits 2 on is rejected here with the same message."""
    if not isinstance(doc, dict):
        raise QueryError(f"query must be a JSON object, "
                         f"got {type(doc).__name__}")
    unknown = set(doc) - set(QUERY_KEYS)
    if unknown:
        raise QueryError(f"unknown query keys {sorted(unknown)}; "
                         f"known keys: {', '.join(QUERY_KEYS)}")
    backend = doc.get("backend", "numpy")
    if backend not in BACKENDS:
        raise QueryError(f"unknown backend {backend!r}; "
                         f"one of {BACKENDS}")
    seed = doc.get("seed", 0)
    if isinstance(seed, bool) or not isinstance(seed, int):
        raise QueryError(f"seed must be an integer, got {seed!r}")
    try:
        grid = grid_from_spec({k: v for k, v in doc.items()
                               if k not in ("backend", "seed")})
    except (ValueError, KeyError) as e:
        raise QueryError(str(e)) from None
    if len(grid) == 0:
        raise QueryError("zero-scenario grid: every axis needs at least "
                         "one value", code="empty-grid")
    coalescable = True
    for name in grid.policies:
        pol = get_policy(name)       # validated by grid_from_spec
        if not (analytical.has_closed_form(pol)
                or analytical.has_timeline_form(pol)):
            if backend == "jax":
                raise QueryError(
                    f"backend='jax' cannot evaluate simulator-only "
                    f"policy {name!r}; use backend='numpy'")
            coalescable = False
    return Query(grid=grid, backend=backend, seed=seed,
                 coalescable=coalescable)


@dataclass
class QueryResult:
    """One finished query: the columnar result table (the same column
    arrays a direct :func:`repro.core.sweep.sweep` would produce) and
    the trailer metadata (:data:`RESULT_META_KEYS` plus ``qos``)."""

    table: dict
    meta: dict


class QueryTicket:
    """A submitted query's handle: :meth:`wait` blocks until the
    dispatcher resolves it with a result or an error."""

    def __init__(self, query: Query):
        self.query = query
        self.t_submit = time.perf_counter()
        self.t_dispatch = self.t_submit
        self.cache_probe: dict = {}
        self._done = threading.Event()
        self._result: QueryResult | None = None
        self._error: Exception | None = None

    def _resolve(self, result: QueryResult | None = None,
                 error: Exception | None = None) -> None:
        self._result = result
        self._error = error
        self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def wait(self, timeout: float | None = None) -> QueryResult:
        if not self._done.wait(timeout):
            raise TimeoutError(f"query not served within {timeout}s")
        if self._error is not None:
            raise self._error
        assert self._result is not None
        return self._result


class ServiceStats:
    """Thread-safe QoS counters; :meth:`snapshot` returns a JSON-ready
    dict (the ``/stats`` document).  Latency/queue-wait percentiles
    are over a sliding window of the most recent queries."""

    def __init__(self, window: int = 2048):
        self._lock = threading.Lock()
        self._t0 = time.perf_counter()
        self.n_queries = 0
        self.n_errors = 0
        self.n_scenarios = 0
        self.kernel_calls = 0
        self.kernel_queries = 0
        self.kernel_busy_s = 0.0
        self._latencies: deque = deque(maxlen=window)
        self._queue_waits: deque = deque(maxlen=window)
        self.cache = {name: {"hits": 0, "misses": 0}
                      for name in ("grid_structure", "workload_tables")}

    def record_cache(self, name: str, hit: bool) -> None:
        with self._lock:
            self.cache[name]["hits" if hit else "misses"] += 1

    def record_kernel(self, n_queries: int, n_scenarios: int,
                      busy_s: float) -> None:
        with self._lock:
            self.kernel_calls += 1
            self.kernel_queries += n_queries
            self.kernel_busy_s += busy_s
            self.n_scenarios += n_scenarios

    def record_query(self, latency_s: float, queue_wait_s: float) -> None:
        with self._lock:
            self.n_queries += 1
            self._latencies.append(latency_s)
            self._queue_waits.append(queue_wait_s)

    def record_error(self) -> None:
        with self._lock:
            self.n_errors += 1

    @staticmethod
    def _pcts_ms(values) -> dict:
        if not values:
            return {"count": 0, "p50_ms": 0.0, "p95_ms": 0.0,
                    "p99_ms": 0.0, "max_ms": 0.0}
        a = np.sort(np.asarray(values, dtype=np.float64)) * 1e3
        return {"count": int(len(a)),
                "p50_ms": float(np.quantile(a, 0.50)),
                "p95_ms": float(np.quantile(a, 0.95)),
                "p99_ms": float(np.quantile(a, 0.99)),
                "max_ms": float(a[-1])}

    def snapshot(self, queue_depth: int = 0) -> dict:
        with self._lock:
            lat = list(self._latencies)
            waits = list(self._queue_waits)
            cache = {
                name: {**c, "hit_rate": (c["hits"] / total
                                         if (total := c["hits"]
                                             + c["misses"]) else 0.0)}
                for name, c in self.cache.items()}
            return {
                "uptime_s": time.perf_counter() - self._t0,
                "n_queries": self.n_queries,
                "n_errors": self.n_errors,
                "n_scenarios_served": self.n_scenarios,
                "kernel_calls": self.kernel_calls,
                "coalesce_factor": (self.kernel_queries / self.kernel_calls
                                    if self.kernel_calls else 0.0),
                "sustained_scenarios_per_sec": (
                    self.n_scenarios / self.kernel_busy_s
                    if self.kernel_busy_s else 0.0),
                "queue_depth": queue_depth,
                "latency": self._pcts_ms(lat),
                "queue_wait": self._pcts_ms(waits),
                "cache": cache,
            }


class _Close:
    """Queue sentinel that wakes the dispatcher for shutdown."""


class SweepService:
    """The persistent what-if engine: submit queries from any thread,
    get bit-identical-to-:func:`sweep` columnar results back, with
    concurrent same-signature queries fused into shared kernel calls.

    ``window_s`` is the micro-batch window: after the first query of a
    batch arrives, the dispatcher keeps collecting for up to
    ``window_s`` seconds (or ``max_coalesce`` queries) before
    evaluating — the classic throughput/latency dial.  ``window_s=0``
    disables coalescing except for queries already waiting in the
    queue.

    Use as a context manager, or call :meth:`close` — in-flight
    queries are served, queued-but-unserved ones resolve with a
    ``service closed`` error.
    """

    def __init__(self, *, window_s: float = 0.005, max_coalesce: int = 32,
                 stats_window: int = 2048):
        self.window_s = float(window_s)
        self.max_coalesce = int(max_coalesce)
        self.stats = ServiceStats(window=stats_window)
        self._queue: Queue = Queue()
        self._expand_memo: dict[ScenarioGrid, list[Scenario]] = {}
        self._expand_limit = 32
        self._closed = False
        self._thread = threading.Thread(target=self._loop,
                                        name="sweep-service", daemon=True)
        self._thread.start()

    # -- client side ---------------------------------------------------
    def submit(self, query: Query | dict) -> QueryTicket:
        """Enqueue a query (a :class:`Query` or a wire document, parsed
        via :func:`parse_query`) and return its ticket immediately."""
        if isinstance(query, dict):
            # probe the workload-table cache BEFORE parsing: grid
            # validation resolves (and therefore caches) the tables, so
            # only a pre-parse probe can see a cold cache.
            workloads = self._workloads_of_doc(query)
            tables_hit = bool(workloads) and all(workload_cached(w)
                                                 for w in workloads)
            query = parse_query(query)
        elif isinstance(query, Query):
            tables_hit = all(workload_cached(w)
                             for w in query.grid.workloads)
        else:
            raise QueryError(f"query must be a Query or a mapping, "
                             f"got {type(query).__name__}")
        if len(query.grid) == 0:
            raise QueryError("zero-scenario grid: every axis needs at "
                             "least one value", code="empty-grid")
        if self._closed:
            raise RuntimeError("service is closed")
        ticket = QueryTicket(query)
        self.stats.record_cache("workload_tables", tables_hit)
        ticket.cache_probe["workload_tables"] = ("hit" if tables_hit
                                                 else "miss")
        self._queue.put(ticket)
        return ticket

    @staticmethod
    def _workloads_of_doc(doc: dict) -> tuple:
        """Best-effort workload names of a not-yet-parsed query doc
        (explicit ``workloads`` key, else the base grid's); used only
        for the pre-parse cache probe, so a wrong guess on a doc that
        parsing will reject anyway is harmless."""
        wl = doc.get("workloads") if isinstance(doc, dict) else None
        if wl is None:
            base = BASE_GRIDS.get(doc.get("grid", "default")) \
                if isinstance(doc, dict) else None
            return base().workloads if base else ()
        if isinstance(wl, str):
            return tuple(p.strip() for p in wl.split(",") if p.strip())
        if isinstance(wl, (list, tuple)):
            return tuple(str(w) for w in wl)
        return ()

    def query(self, query: Query | dict,
              timeout: float | None = None) -> QueryResult:
        """Blocking convenience: ``submit(query).wait(timeout)``."""
        return self.submit(query).wait(timeout)

    def queue_depth(self) -> int:
        return self._queue.qsize()

    def stats_snapshot(self) -> dict:
        return self.stats.snapshot(queue_depth=self._queue.qsize())

    def close(self, timeout: float = 30.0) -> None:
        if not self._closed:
            self._closed = True
            self._queue.put(_Close())
        self._thread.join(timeout)

    def __enter__(self) -> "SweepService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- dispatcher side -----------------------------------------------
    def _loop(self) -> None:
        closing = False
        while not closing:
            try:
                first = self._queue.get(timeout=0.5)
            except Empty:
                continue
            if isinstance(first, _Close):
                break
            batch = [first]
            deadline = time.perf_counter() + self.window_s
            while len(batch) < self.max_coalesce:
                remaining = deadline - time.perf_counter()
                try:
                    item = self._queue.get(
                        timeout=remaining if remaining > 0 else 0,
                        block=remaining > 0)
                except Empty:
                    break
                if isinstance(item, _Close):
                    closing = True
                    break
                batch.append(item)
            self._serve_batch(batch)
        # resolve anything still queued after close
        while True:
            try:
                item = self._queue.get_nowait()
            except Empty:
                return
            if not isinstance(item, _Close):
                item._resolve(error=RuntimeError("service is closed"))

    def _serve_batch(self, batch: list[QueryTicket]) -> None:
        now = time.perf_counter()
        groups: dict[tuple, list[QueryTicket]] = {}
        solo: list[QueryTicket] = []
        for t in batch:
            t.t_dispatch = now
            t.cache_probe["grid_structure"] = self._probe_structure(
                t.query)
            if t.query.coalescable:
                groups.setdefault(t.query.signature, []).append(t)
            else:
                solo.append(t)
        for tickets in groups.values():
            q = tickets[0].query
            self._eval_group(tickets, q.backend, q.seed)
        for t in solo:
            self._eval_group([t], t.query.backend, t.query.seed)

    def _probe_structure(self, q: Query) -> str:
        """Hit/miss probe of the grid-structure memo of the query's
        backend, *before* evaluation touches it (the probe never
        builds or inserts anything).  The memo is exercised directly
        by singleton queries and by anyone re-sweeping the grid;
        coalesced groups rebuild scenario-list axes but share the
        memoized workload tables (probed at submit, pre-parse)."""
        if q.backend == "jax":
            from repro.core.batched_jax import jax_evaluator_cached
            structure = jax_evaluator_cached(q.grid)
        else:
            structure = evaluator_cached(q.grid)
        self.stats.record_cache("grid_structure", structure)
        return "hit" if structure else "miss"

    def _expand(self, grid: ScenarioGrid) -> list[Scenario]:
        """Memoized ``grid.expand()`` — the coalescer's Python-side
        cost for repeated grids (the axes were validated at parse)."""
        try:
            hit = self._expand_memo.get(grid)
        except TypeError:
            return grid.expand()
        if hit is None:
            if len(self._expand_memo) >= self._expand_limit:
                self._expand_memo.clear()
            hit = self._expand_memo[grid] = grid.expand()
        return hit

    def _eval_group(self, tickets: list[QueryTicket], backend: str,
                    seed: int) -> None:
        """Evaluate one same-signature group with a single kernel call
        and de-multiplex the table back per ticket.  A singleton group
        routes through the memoized grid front end (:func:`sweep`) —
        identical columns, hot structure memos; a larger group
        concatenates the expanded scenario lists through the
        scenario-list kernel, which yields the same columns bit for
        bit (pinned by the tests)."""
        t0 = time.perf_counter()
        try:
            if len(tickets) == 1:
                res = sweep(tickets[0].query.grid, backend=backend,
                            seed=seed)
                table, elapsed = res.columns, res.elapsed_s
                spans = [(0, len(res))]
            else:
                lists = [self._expand(t.query.grid) for t in tickets]
                spans, lo = [], 0
                for part in lists:
                    spans.append((lo, lo + len(part)))
                    lo += len(part)
                scenarios = [s for part in lists for s in part]
                if backend == "jax":
                    from repro.core.batched_jax import \
                        eval_scenarios_table_jax
                    table = eval_scenarios_table_jax(scenarios, seed=seed)
                else:
                    table = eval_scenarios_table(scenarios, seed=seed)
                elapsed = time.perf_counter() - t0
        except Exception as exc:
            err = QueryError(f"evaluation failed: {exc}",
                             code="evaluation-failed")
            for t in tickets:
                self.stats.record_error()
                t._resolve(error=err)
            return
        self.stats.record_kernel(len(tickets), table_len(table), elapsed)
        t_done = time.perf_counter()
        for t, (lo, hi) in zip(tickets, spans):
            sub = slice_table(table, lo, hi)
            n = table_len(sub)
            n_fast, n_tl, n_sim = method_counts(sub)
            wait = t.t_dispatch - t.t_submit
            latency = t_done - t.t_submit
            meta = {
                "n_scenarios": n,
                "elapsed_s": elapsed,
                "scenarios_per_sec": n / elapsed if elapsed else 0.0,
                "n_analytical": n_fast,
                "n_timeline": n_tl,
                "n_simulated": n_sim,
                "backend": backend,
                "qos": {
                    "queue_wait_s": wait,
                    "latency_s": latency,
                    "coalesced_queries": len(tickets),
                    "cache": t.cache_probe,
                },
            }
            assert set(meta) == set(RESULT_META_KEYS) | {"qos"}
            self.stats.record_query(latency, wait)
            t._resolve(QueryResult(table=sub, meta=meta))
