"""Bucket-timeline steady state: the schedule-dependent policies, closed.

The sweep engine's two "inexact" policies — gradient-bucket fusion
(``bucketed-*``) and priority comm scheduling (``priority``) — used to
be simulator-only: their comm schedule depends on the schedule itself,
so no *per-layer* closed form exists.  But their **steady state** does
have an exact closed form, because the collective network is a single
work-conserving channel:

* Iterations cannot overlap on the net channel (iteration *k*'s update
  precedes iteration *k+1*'s forward, which precedes its backward,
  which releases its comm), so each iteration's comm schedule starts on
  an idle channel.
* On a single non-idling channel the **finish time of the last task is
  order-independent**: the backlog ``arrived(t) - completed(t)`` evolves
  identically for every work-conserving order, and the channel is busy
  exactly while the backlog is positive.  FIFO bucket chains and
  ByteScheduler-style priority reordering therefore release the model
  update at the same instant (priority still changes *which* tensor
  lands first — that matters for cross-iteration schedules the DAG
  model does not express — but not the steady iteration time).

So with buckets ``j = 0..B-1`` in issue order (backward layer order),
release times ``r_j`` (the backward finish of the bucket's earliest
layer under WFBP, or the full backward time without comm overlap) and
durations ``d_j`` (one collective over the bucket's summed payload),
the channel finishes at

    makespan = max_j ( r_j + sum_{j' >= j} d_j' )

and the residual the GPU chain cannot hide is
``max(makespan - sum(t_b), 0)`` — exactly the prefix/suffix-sum shape
of :func:`repro.core.analytical.non_overlapped_comm_batch`, with
buckets in place of layers.  ``tests/test_bucketsim.py`` pins this
against :func:`repro.core.simulator.simulate_steady` to <= 1e-6
relative on every built-in grid (and much tighter on synthetic costs);
``force_simulator=True`` keeps the event-driven path available as the
agreement oracle.

This module holds the pure kernel: bucket structure tables (padded
``(W, B)`` per workload axis, mirroring :func:`repro.core.dag._bucketize`
boundaries exactly) and the vectorized ``(S, B)`` residual.  The
wiring — collective-model durations, policy select, grid routing —
lives in :mod:`repro.core.batched`.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


def bucket_partition(comm_mask, payload,
                     bucket_bytes: float | None) -> list[list[int]]:
    """**The** bucket-boundary rule, shared by the DAG builder
    (:func:`repro.core.dag._bucketize`) and the batched timeline kernel
    so the two paths can never disagree on where buckets fall.

    Returns member-layer lists (each in backward order) in issue
    order: layers are visited backward (layer L first), layers with a
    falsy ``comm_mask`` entry are skipped (they produce no comm task),
    and a bucket flushes once its accumulated ``payload`` reaches
    ``bucket_bytes`` — the trailing partial bucket flushes at the end.
    ``bucket_bytes=None`` degenerates to one bucket per comm layer
    (the per-layer pattern the ``priority`` policy schedules);
    ``payload=None`` (byte sizes unknown) never flushes early, i.e.
    one bucket spanning every comm layer.
    """
    buckets: list[list[int]] = []
    cur: list[int] = []
    cur_bytes = 0.0
    for layer in range(len(comm_mask) - 1, -1, -1):
        if not comm_mask[layer]:
            continue
        cur.append(layer)
        if payload is not None:
            cur_bytes += payload[layer]
        if bucket_bytes is None or \
                (payload is not None and cur_bytes >= bucket_bytes):
            buckets.append(cur)
            cur, cur_bytes = [], 0.0
    if cur:
        buckets.append(cur)
    return buckets


def bucket_layers(grad_bytes, bucket_bytes: float | None) -> list[tuple[float, int]]:
    """``[(payload_bytes, release_layer)]`` in issue order for one
    workload's per-layer gradient payloads.

    A layer carries a comm task iff its payload is positive — the same
    predicate :meth:`~repro.core.workloads.WorkloadTable.iteration_costs`
    uses to zero ``t_c``, so this matches the DAG builder's ``t_c > 0``
    membership on every table the batched path evaluates; the
    boundaries themselves come from the shared
    :func:`bucket_partition`.  ``release_layer`` is the forward index
    of the bucket's *earliest* (= last-flushed) member: under WFBP the
    bucket is released when that layer's backward finishes.
    """
    grad_bytes = np.asarray(grad_bytes, dtype=np.float64)
    return [(float(sum(grad_bytes[m] for m in members)), members[-1])
            for members in bucket_partition(grad_bytes > 0, grad_bytes,
                                            bucket_bytes)]


@dataclass(frozen=True)
class BucketTable:
    """Padded bucket structure for a workload axis at one bucket size.

    ``(W, B_max)`` arrays, one row per workload; padding buckets have
    ``nbytes = 0``, ``release_layer = 0`` and ``mask = False`` — they
    contribute no duration and are excluded from the makespan max, so
    workloads with different bucket counts share one table (the same
    zero-padding contract as the batched layer tables).
    """

    nbytes: np.ndarray            # (W, B) summed gradient payload
    release_layer: np.ndarray     # (W, B) int64 forward index, 0 on padding
    mask: np.ndarray              # (W, B) bool, False on padding

    @property
    def n_buckets(self) -> int:
        return self.nbytes.shape[1]


def bucket_table(grad_bytes: np.ndarray, bucket_bytes: float | None) -> BucketTable:
    """Bucket structure for a padded ``(W, L)`` gradient-payload matrix
    (the batched evaluator's workload axis) at one bucket size."""
    rows = [bucket_layers(g, bucket_bytes) for g in np.atleast_2d(grad_bytes)]
    bmax = max((len(r) for r in rows), default=0) or 1
    W = len(rows)
    nbytes = np.zeros((W, bmax))
    release = np.zeros((W, bmax), dtype=np.int64)
    mask = np.zeros((W, bmax), dtype=bool)
    for i, r in enumerate(rows):
        for j, (b, lmin) in enumerate(r):
            nbytes[i, j] = b
            release[i, j] = lmin
            mask[i, j] = True
    return BucketTable(nbytes=nbytes, release_layer=release, mask=mask)


def suffix_tables(bt: BucketTable) -> tuple[np.ndarray, np.ndarray]:
    """``(suffix_nbytes, suffix_count)``: inclusive suffix sums over
    issue order of bucket payload bytes and live-bucket counts, both
    ``(W, B)`` float64.

    With an affine collective model ``d_j = per_byte * nbytes_j +
    per_message`` (zero on padding), the duration suffix sum inside
    :func:`timeline_residual` collapses to ``per_byte * suffix_nbytes +
    per_message * suffix_count`` — no per-point ``(S, B)`` duration
    matrix, no cumsum.  Shared by both batched backends
    (:mod:`repro.core.batched`, :mod:`repro.core.batched_jax`)."""
    sufnb = np.flip(np.cumsum(np.flip(bt.nbytes, -1), -1), -1)
    sufcnt = np.flip(np.cumsum(np.flip(
        bt.mask.astype(np.float64), -1), -1), -1)
    return sufnb, sufcnt


def timeline_residual(t_b: np.ndarray, durations: np.ndarray,
                      release_layer: np.ndarray, mask: np.ndarray,
                      overlap_comm: bool = True) -> np.ndarray:
    """The communication residual of the bucket timeline, vectorized
    over ``(scenario, bucket)`` matrices.

    ``t_b`` is ``(..., L)`` backward times in forward layer order (zero
    padding allowed); ``durations`` / ``release_layer`` / ``mask`` are
    ``(..., B)`` bucket matrices in issue order, layer/bucket axes
    last — ``(S, L)``/``(S, B)`` on the batched NumPy path, single
    ``(L,)``/``(B,)`` rows under the vmap of
    :mod:`repro.core.batched_jax` (dtype-polymorphic over NumPy and
    ``jax.numpy``).  With ``overlap_comm`` a bucket is released at the
    inclusive backward suffix sum of its ``release_layer`` (WFBP);
    without it every bucket releases when the whole backward pass
    finishes (comm-at-end).  Returns the ``(...,)`` residual
    ``max(makespan - sum(t_b), 0)`` that joins the GPU chain in place
    of the per-layer WFBP term ``t_c^no``.

    Degenerate shapes fall out of the formula: one giant bucket whose
    release layer is the first comm layer reproduces comm-at-end; one
    bucket per layer reproduces
    :func:`repro.core.analytical.non_overlapped_comm_batch` exactly
    (property-tested).
    """
    from repro.core.xputil import array_namespace

    xp = array_namespace(t_b, durations, release_layer)
    t_b = xp.asarray(t_b, dtype=xp.float64)
    durations = xp.asarray(durations, dtype=xp.float64) * mask
    prefix_b = xp.cumsum(t_b, axis=-1)
    total_b = prefix_b[..., -1]
    if overlap_comm:
        suffix_b = (total_b[..., None] - prefix_b) + t_b  # inclusive suffix
        release = xp.take_along_axis(suffix_b, release_layer, axis=-1)
    else:
        release = xp.broadcast_to(total_b[..., None], durations.shape)
    # duration suffix sum over issue order: bucket j waits for nothing
    # issued after it, but everything issued at-or-after j must run
    # before the channel drains past j's contribution
    sufdur = xp.flip(xp.cumsum(xp.flip(durations, axis=-1), axis=-1), axis=-1)
    cand = (release + sufdur) * mask      # mask-multiply: padding -> 0
    makespan = cand.max(axis=-1, initial=0.0)
    return xp.maximum(makespan - total_b, 0.0)
