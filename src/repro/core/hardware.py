"""Hardware models for the DAG performance model.

Calibrated to the paper's Table II clusters (K80+PCIe+10GbE,
V100+NVLink+100Gb InfiniBand) plus the TPU v5e production target
this framework deploys on.

Units, everywhere in this module: bandwidths are **bytes/second**,
latencies **seconds**, payloads **bytes**, compute rates **flop/s**,
and every function returning a time returns **seconds**.  The comm
cost functions accept NumPy arrays for ``nbytes`` and broadcast
elementwise — this is what the sweep engine's vectorized fast path
relies on (:mod:`repro.core.sweep`).
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

import numpy as np

from repro.core.xputil import array_namespace, is_jax_array

GB = 1e9
MB = 1e6
US = 1e-6

#: All-reduce algorithms understood by :meth:`ClusterSpec.allreduce_time`.
#: ``ring`` is the paper's NCCL baseline; ``tree`` models NCCL's
#: double-binary-tree; ``hierarchical`` is intra-node + inter-node
#: two-level reduction (§VII of the paper calls for exactly this kind
#: of topology-aware collective study).
COLLECTIVE_ALGORITHMS = ("ring", "tree", "hierarchical")


@dataclass(frozen=True)
class Interconnect:
    """A communication channel with an alpha-beta cost model.

    ``transfer_time(n)`` = alpha + n / (B * efficiency), i.e. the
    classic latency/bandwidth model the paper uses for every link
    (PCIe, NVLink, 10GbE, InfiniBand).
    """

    name: str
    bandwidth: float          # bytes / s (peak, per direction)
    latency: float            # seconds per message (alpha term)
    efficiency: float = 1.0   # achieved fraction of peak for collectives

    @property
    def effective_bandwidth(self) -> float:
        """Achieved bytes/s for collectives: ``bandwidth * efficiency``."""
        return self.bandwidth * self.efficiency

    def transfer_time(self, nbytes: float) -> float:
        """Point-to-point transfer time (seconds) for ``nbytes`` bytes."""
        return self.latency + nbytes / self.effective_bandwidth

    def scaled(self, bandwidth_factor: float = 1.0,
               latency_factor: float = 1.0) -> "Interconnect":
        """A what-if copy with scaled bandwidth and/or latency (used by
        the sweep engine's interconnect axis and the monotonicity
        property tests)."""
        return dataclasses.replace(
            self,
            name=f"{self.name}x{bandwidth_factor:g}",
            bandwidth=self.bandwidth * bandwidth_factor,
            latency=self.latency * latency_factor,
        )


# ----------------------------------------------------------------------
# Collective algorithm primitives (alpha-beta closed forms).
#
# Each returns seconds for all-reducing ``nbytes`` bytes per rank over
# ``n`` ranks on a link with ``bandwidth`` effective bytes/s and
# ``latency`` seconds/message.  Every argument may be a NumPy array and
# broadcasts elementwise — the scenario-axis batched fast path
# (:mod:`repro.core.batched`) passes per-scenario ``(n, bandwidth,
# latency)`` column vectors against per-layer ``nbytes`` row vectors to
# get ``(scenario x layer)`` cost matrices in one shot.
# ----------------------------------------------------------------------
def slowest_link(bandwidth, latency, axis: int = -1):
    """Reduce per-worker link vectors to the link that gates the
    collective: a synchronous all-reduce completes when its slowest
    participant finishes, so heterogeneous links collapse to
    ``(min bandwidth, max latency)`` over the worker axis.

    Degenerates bit-exactly to the scalar model when the vectors are
    constant (min/max never round), which is how the heterogeneous
    engine keeps homogeneous scenarios bit-identical.  Dtype-polymorphic
    over NumPy and ``jax.numpy`` like the collective models below.
    """
    xp = array_namespace(bandwidth, latency)
    return xp.min(bandwidth, axis=axis), xp.max(latency, axis=axis)


def ring_allreduce_time(nbytes, n, bandwidth, latency, worker_axis=None):
    """Ring all-reduce: ``2 (n-1)/n * M/B + 2 (n-1) alpha`` seconds.

    ``worker_axis`` marks ``bandwidth``/``latency`` as carrying a
    per-worker axis: the time is then gated by the slowest link
    (:func:`slowest_link` reduces that axis first).

    Bandwidth-optimal (each rank sends ``2 (n-1)/n`` of the payload)
    but latency grows linearly in ``n`` — the regime behind the 9.6%
    InfiniBand utilization the paper measured for layer-wise messages.

    Dtype-polymorphic: jax inputs (arrays *or* tracers, e.g. under the
    vmap of :mod:`repro.core.batched_jax`) take the array path on
    ``jax.numpy``; the Python-scalar branch is reserved for genuine
    host scalars because ``if n <= 1`` cannot be traced.
    """
    if worker_axis is not None:
        bandwidth, latency = slowest_link(bandwidth, latency, worker_axis)
    if np.ndim(n) == 0 and not is_jax_array(n):
        if n <= 1:
            return nbytes * 0.0
        return 2.0 * (n - 1) / n * nbytes / bandwidth + 2.0 * (n - 1) * latency
    # Array path: zeroing the n <= 1 entries by mask *multiplication*
    # (0.0 * finite == 0.0 exactly) — np.where materializes both
    # branches and costs ~10x an elementwise multiply at sweep sizes.
    xp = array_namespace(nbytes, n, bandwidth, latency)
    n = xp.asarray(n, dtype=xp.float64)
    safe_n = xp.where(n > 1, n, 2.0)         # small: broadcast shape of n
    t = 2.0 * (safe_n - 1) / safe_n * nbytes / bandwidth \
        + 2.0 * (safe_n - 1) * latency
    return t * (n > 1)


def _ceil_log2(n, xp=np):
    """Exact ``ceil(log2 n)`` for integer arrays ``n >= 1`` (frexp-based
    so powers of two never round up a notch)."""
    m, e = xp.frexp(xp.asarray(n, dtype=xp.float64))
    return xp.where(m == 0.5, e - 1, e).astype(xp.float64)


def tree_allreduce_time(nbytes, n, bandwidth, latency, worker_axis=None):
    """Double-binary-tree all-reduce: ``2 M/B + 2 ceil(log2 n) alpha``.

    NCCL >= 2.4's tree pair pipelines reduce+broadcast so the bandwidth
    term is a flat ``2 M/B`` (slightly worse than ring's
    ``2 (n-1)/n M/B``) while latency grows only logarithmically —
    strictly better than ring for small messages on large clusters.
    ``worker_axis`` marks per-worker link vectors (see
    :func:`slowest_link`).
    """
    if worker_axis is not None:
        bandwidth, latency = slowest_link(bandwidth, latency, worker_axis)
    if np.ndim(n) == 0 and not is_jax_array(n):
        if n <= 1:
            return nbytes * 0.0
        depth = math.ceil(math.log2(n))
        return 2.0 * nbytes / bandwidth + 2.0 * depth * latency
    xp = array_namespace(nbytes, n, bandwidth, latency)
    n = xp.asarray(n)
    depth = _ceil_log2(xp.where(n > 1, n, 2), xp)    # small: shape of n
    t = 2.0 * nbytes / bandwidth + 2.0 * depth * latency
    return t * (n > 1)


def hierarchical_allreduce_time(nbytes, n, gpus_per_node,
                                intra_bandwidth, intra_latency,
                                inter_bandwidth, inter_latency,
                                worker_axis=None):
    """Two-level all-reduce: ``g``-wide intra-node reduce-scatter,
    inter-node ring all-reduce of the ``nbytes/g`` shard, intra-node
    all-gather.  Degenerates to a flat intra ring on one node and to a
    flat inter ring with one device per node.

    Array-valued like the flat primitives: ``n`` / ``gpus_per_node`` /
    link parameters broadcast against ``nbytes``, which is how the
    batched fast path costs every scenario of a grid at once — and
    dtype-polymorphic, so the jit/vmap kernels trace the same code.
    ``worker_axis`` marks all four link parameters as per-worker
    vectors, each reduced to its slowest entry (:func:`slowest_link`).
    """
    if worker_axis is not None:
        intra_bandwidth, intra_latency = slowest_link(
            intra_bandwidth, intra_latency, worker_axis)
        inter_bandwidth, inter_latency = slowest_link(
            inter_bandwidth, inter_latency, worker_axis)
    xp = array_namespace(nbytes, n, gpus_per_node,
                         intra_bandwidth, inter_bandwidth)
    scalar = xp is np and np.ndim(n) == 0 and np.ndim(gpus_per_node) == 0
    n = xp.asarray(n, dtype=xp.int64)
    gpn = xp.asarray(gpus_per_node, dtype=xp.int64)
    g = xp.minimum(n, gpn)
    safe_g = xp.maximum(g, 1)
    nodes = (n + safe_g - 1) // safe_g          # exact ceil(n / g)
    gf = safe_g.astype(xp.float64)
    intra = 2.0 * ((gf - 1) / gf * nbytes / intra_bandwidth
                   + (gf - 1) * intra_latency)
    # ring_allreduce_time already mask-zeroes its nodes <= 1 entries
    t = intra * (g > 1) + ring_allreduce_time(
        nbytes / gf, nodes.astype(xp.float64),
        inter_bandwidth, inter_latency)
    if scalar and np.ndim(t) == 0:
        return float(t)
    return t


# ----------------------------------------------------------------------
# Affine collective coefficients.
#
# Every collective model above is *affine in the payload* for fixed
# ``(n, links)``: ``time(M) = per_byte * M + per_message`` whenever
# ``M > 0`` (callers mask zero payloads, exactly as the batched comm
# matrices do).  Factoring the coefficients out lets the batched
# kernels cost a whole layer/bucket table against one kernel point with
# a single multiply-add — prefix/suffix sums of ``time`` collapse to
# ``per_byte * (payload sums) + per_message * (payload counts)``, which
# is the cumsum-free formulation :mod:`repro.core.batched` evaluates.
# Each function folds the ``n <= 1`` zeroing in (both coefficients are
# exactly 0.0 there) and is dtype-polymorphic like the time models.
# ----------------------------------------------------------------------
def ring_allreduce_coeffs(n, bandwidth, latency):
    """``(per_byte, per_message)`` of :func:`ring_allreduce_time`:
    ``2 (n-1)/n / B`` and ``2 (n-1) alpha``, zeroed where ``n <= 1``."""
    xp = array_namespace(n, bandwidth, latency)
    n = xp.asarray(n, dtype=xp.float64)
    live = n > 1
    safe_n = xp.where(live, n, 2.0)
    per_byte = 2.0 * (safe_n - 1) / safe_n / bandwidth * live
    per_message = 2.0 * (safe_n - 1) * latency * live
    return per_byte, per_message


def tree_allreduce_coeffs(n, bandwidth, latency):
    """``(per_byte, per_message)`` of :func:`tree_allreduce_time`:
    ``2 / B`` and ``2 ceil(log2 n) alpha``, zeroed where ``n <= 1``."""
    xp = array_namespace(n, bandwidth, latency)
    n = xp.asarray(n)
    live = n > 1
    depth = _ceil_log2(xp.where(live, n, 2), xp)
    per_byte = 2.0 / bandwidth * live
    per_message = 2.0 * depth * latency * live
    return per_byte, per_message


def hierarchical_allreduce_coeffs(n, gpus_per_node,
                                  intra_bandwidth, intra_latency,
                                  inter_bandwidth, inter_latency):
    """``(per_byte, per_message)`` of
    :func:`hierarchical_allreduce_time`: the intra-node term (live when
    ``g > 1``) plus the inter-node ring over the ``1/g`` shard (live
    when ``nodes > 1``), each contributing its own affine piece."""
    xp = array_namespace(n, gpus_per_node, intra_bandwidth,
                         inter_bandwidth)
    n = xp.asarray(n, dtype=xp.int64)
    gpn = xp.asarray(gpus_per_node, dtype=xp.int64)
    g = xp.minimum(n, gpn)
    safe_g = xp.maximum(g, 1)
    nodes = (n + safe_g - 1) // safe_g          # exact ceil(n / g)
    gf = safe_g.astype(xp.float64)
    intra_live = g > 1
    per_byte = 2.0 * (gf - 1) / gf / intra_bandwidth * intra_live
    per_message = 2.0 * (gf - 1) * intra_latency * intra_live
    ring_byte, ring_message = ring_allreduce_coeffs(
        nodes.astype(xp.float64), inter_bandwidth, inter_latency)
    return per_byte + ring_byte / gf, per_message + ring_message


@dataclass(frozen=True)
class DeviceSpec:
    name: str
    peak_flops: float         # flop/s (the paper quotes peak incl. TensorCores)
    hbm_bandwidth: float      # bytes / s
    memory_bytes: float
    compute_efficiency: float = 0.5   # achieved fraction of peak in DNN layers


@dataclass(frozen=True)
class ClusterSpec:
    """A training cluster: N nodes x n_g devices, intra + inter connects.

    Mirrors Table II of the paper. ``allreduce_time`` implements the
    ring all-reduce alpha-beta model used to populate the DAG's
    communication nodes when no measured trace is available.
    """

    name: str
    device: DeviceSpec
    n_nodes: int
    gpus_per_node: int
    intra: Interconnect       # PCIe / NVLink / ICI
    inter: Interconnect      # 10GbE / InfiniBand / DCN
    disk: Interconnect        # storage read channel (t_io)
    h2d: Interconnect         # host-to-device copy channel (t_h2d)

    @property
    def total_devices(self) -> int:
        return self.n_nodes * self.gpus_per_node

    def with_workers(self, n_nodes: int, gpus_per_node: int | None = None) -> "ClusterSpec":
        g = self.gpus_per_node if gpus_per_node is None else gpus_per_node
        return dataclasses.replace(self, n_nodes=n_nodes, gpus_per_node=g)

    # ------------------------------------------------------------------
    # Collective models
    # ------------------------------------------------------------------
    def _bottleneck(self, n_workers: int) -> Interconnect:
        """The link a flat ring spanning ``n_workers`` devices is limited by."""
        if n_workers <= self.gpus_per_node:
            return self.intra
        return self.inter

    def with_interconnect(self, intra: Interconnect | None = None,
                          inter: Interconnect | None = None) -> "ClusterSpec":
        """A copy with the intra- and/or inter-node link replaced —
        the sweep engine's interconnect axis (PCIe vs NVLink vs 10GbE
        vs InfiniBand, the paper's four communication techniques)."""
        return dataclasses.replace(
            self,
            intra=intra if intra is not None else self.intra,
            inter=inter if inter is not None else self.inter,
        )

    def allreduce_time(self, nbytes, n_workers: int | None = None,
                       algorithm: str = "ring"):
        """All-reduce of ``nbytes`` bytes per rank over ``n_workers``
        devices; returns **seconds**.

        ``algorithm`` selects the cost model (see
        :data:`COLLECTIVE_ALGORITHMS`):

        * ``ring`` — Eq.-style ``2 (n-1)/n M/B + 2 (n-1) alpha`` on the
          bottleneck link (the paper's NCCL baseline, and this method's
          historical behavior).
        * ``tree`` — double binary tree, ``2 M/B + 2 ceil(log2 n) alpha``
          on the bottleneck link.
        * ``hierarchical`` — intra-node reduce-scatter + all-gather on
          the intra link around an inter-node ring all-reduce of the
          ``1/g`` shard on the inter link (NCCL "CollNet"/2D style).

        ``nbytes`` may be a scalar or a NumPy array (vectorized over
        the layer dimension by the sweep fast path).
        """
        if algorithm not in COLLECTIVE_ALGORITHMS:
            raise ValueError(
                f"unknown collective algorithm {algorithm!r}; "
                f"one of {COLLECTIVE_ALGORITHMS}")
        n = self.total_devices if n_workers is None else n_workers
        if n <= 1:
            return nbytes * 0.0
        if algorithm == "hierarchical":
            return self._hierarchical_allreduce_time(nbytes, n)
        link = self._bottleneck(n)
        if algorithm == "ring":
            return ring_allreduce_time(nbytes, n, link.effective_bandwidth,
                                       link.latency)
        return tree_allreduce_time(nbytes, n, link.effective_bandwidth,
                                   link.latency)

    def _hierarchical_allreduce_time(self, nbytes, n: int):
        """Delegates to :func:`hierarchical_allreduce_time` — one
        implementation shared with the batched fast path so the scalar
        and scenario-axis vectorized costs cannot drift."""
        return hierarchical_allreduce_time(
            nbytes, n, self.gpus_per_node,
            self.intra.effective_bandwidth, self.intra.latency,
            self.inter.effective_bandwidth, self.inter.latency)

    def reduce_scatter_time(self, nbytes: float, n_workers: int | None = None) -> float:
        """Ring reduce-scatter of ``nbytes`` bytes per rank, in seconds:
        ``(n-1)/n * M/B + (n-1) alpha`` on the bottleneck link."""
        n = self.total_devices if n_workers is None else n_workers
        if n <= 1:
            return 0.0
        link = self._bottleneck(n)
        return (n - 1) / n * nbytes / link.effective_bandwidth \
            + (n - 1) * link.latency

    def allgather_time(self, nbytes: float, n_workers: int | None = None) -> float:
        """Ring all-gather — same alpha-beta cost as reduce-scatter."""
        return self.reduce_scatter_time(nbytes, n_workers)

    def alltoall_time(self, nbytes: float, n_workers: int | None = None) -> float:
        """All-to-all of ``nbytes`` bytes held per device (MoE dispatch),
        in seconds."""
        n = self.total_devices if n_workers is None else n_workers
        if n <= 1:
            return 0.0
        link = self._bottleneck(n)
        return (n - 1) / n * nbytes / link.effective_bandwidth \
            + (n - 1) * link.latency

    # ------------------------------------------------------------------
    # Elementary task models (the paper's Table I vocabulary)
    # ------------------------------------------------------------------
    def compute_time(self, flops: float) -> float:
        """Seconds to execute ``flops`` floating-point operations at the
        device's achieved rate (``peak_flops * compute_efficiency``) —
        feeds the DAG's ``t_f`` / ``t_b`` nodes."""
        return flops / (self.device.peak_flops * self.device.compute_efficiency)

    def io_time(self, nbytes: float) -> float:
        """Seconds to read ``nbytes`` bytes from storage (``t_io``)."""
        return self.disk.transfer_time(nbytes)

    def h2d_time(self, nbytes: float) -> float:
        """Seconds to copy ``nbytes`` bytes host->device (``t_h2d``)."""
        return self.h2d.transfer_time(nbytes)


# ----------------------------------------------------------------------
# Paper Table II clusters.
#
# Collective efficiencies are calibrated against the paper's measured
# numbers (Section V-C2): training ResNet-50 on the V100 cluster the
# per-iteration gradient communication is ~79.7 ms for ~24M f32
# parameters over 16 GPUs — the paper reports NCCL2 achieving only
# ~9.6% of the 100Gb/s InfiniBand bandwidth due to layer-wise small
# messages.  The K80 cluster's 10GbE reaches a much larger fraction of
# its (far lower) peak.
# ----------------------------------------------------------------------
# Compute efficiencies calibrated against the paper's measured ResNet-50
# per-iteration times (§V-C2): K80 backward 0.243 s, V100 backward
# 0.0625 s at batch 32 (ResNet-50 fwd ~7.7 GFLOP/sample, bwd ~2x fwd).
K80_DEVICE = DeviceSpec(
    name="Tesla K80",
    peak_flops=4.37e12,
    hbm_bandwidth=240 * GB,
    memory_bytes=12 * GB,
    compute_efficiency=0.47,
)

V100_DEVICE = DeviceSpec(
    name="Tesla V100",
    peak_flops=125e12,        # with Tensor Cores, as quoted in the paper
    hbm_bandwidth=900 * GB,
    memory_bytes=16 * GB,
    # Calibrated: 0.0625 s for ResNet-50 backward at batch 32 implies
    # ~7.9 TFLOP/s achieved — 6.3% of the quoted 125 TFLOP TensorCore
    # peak (fp32 training largely bypasses TensorCores; the paper's own
    # point is that quoted peak vastly outruns end-to-end compute).
    compute_efficiency=0.063,
)

K80_CLUSTER = ClusterSpec(
    name="k80-pcie-10gbe",
    device=K80_DEVICE,
    n_nodes=4,
    gpus_per_node=4,
    intra=Interconnect("pcie3", 15 * GB, 10 * US, efficiency=0.7),
    inter=Interconnect("10gbe", 1.25 * GB, 50 * US, efficiency=0.7),
    disk=Interconnect("nfs", 1.1 * GB, 1e-4),
    h2d=Interconnect("pcie3-h2d", 15 * GB, 10 * US, efficiency=0.8),
)

V100_CLUSTER = ClusterSpec(
    name="v100-nvlink-ib",
    device=V100_DEVICE,
    n_nodes=4,
    gpus_per_node=4,
    intra=Interconnect("nvlink", 95 * GB, 5 * US, efficiency=0.6),
    # 100Gbps IB = 12.5 GB/s peak.  Efficiency calibrated so the ring
    # all-reduce of ResNet-50's 102 MB of f32 gradients over 16 GPUs
    # costs the measured 79.7 ms (the paper reports NCCL2 reaching only
    # ~9.6% of raw link bandwidth when counting the layer-wise message
    # pattern; 0.19 is the matching end-to-end collective efficiency).
    inter=Interconnect("ib-100g", 12.5 * GB, 10 * US, efficiency=0.19),
    disk=Interconnect("ssd", 367.3 * MB, 1e-4),
    h2d=Interconnect("pcie3-h2d", 15 * GB, 10 * US, efficiency=0.8),
)

# ----------------------------------------------------------------------
# Production target: TPU v5e pod(s).  One pod = 16x16 chips on a 2D ICI
# torus; pods connect over DCN.  Constants per the assignment:
#   197 TFLOP/s bf16 / chip, 819 GB/s HBM, ~50 GB/s/link ICI.
# DCN per-chip bandwidth is an assumption (documented in DESIGN.md).
# ----------------------------------------------------------------------
TPU_V5E = DeviceSpec(
    name="TPU v5e",
    peak_flops=197e12,
    hbm_bandwidth=819 * GB,
    memory_bytes=16 * GB,
    compute_efficiency=0.55,
)

TPU_V5E_POD = ClusterSpec(
    name="tpu-v5e-pod",
    device=TPU_V5E,
    n_nodes=1,
    gpus_per_node=256,
    intra=Interconnect("ici", 50 * GB, 1 * US, efficiency=0.8),
    inter=Interconnect("dcn", 6.25 * GB, 10 * US, efficiency=0.8),
    disk=Interconnect("gcs", 2 * GB, 1e-3),
    h2d=Interconnect("pcie-host", 32 * GB, 10 * US),
)

TPU_V5E_MULTIPOD = dataclasses.replace(TPU_V5E_POD, name="tpu-v5e-2pod", n_nodes=2)

CLUSTERS = {c.name: c for c in (K80_CLUSTER, V100_CLUSTER, TPU_V5E_POD, TPU_V5E_MULTIPOD)}

# ----------------------------------------------------------------------
# Interconnect presets — the sweep engine's interconnect axis.
#
# Each preset names a link and the slot it replaces on a ClusterSpec
# ("intra" or "inter"); the paper's four communication techniques
# (PCIe, NVLink, 10GbE, InfiniBand) plus faster what-if variants.
# ----------------------------------------------------------------------
INTERCONNECT_PRESETS: dict[str, tuple[str, Interconnect]] = {
    "pcie": ("intra", Interconnect("pcie3", 15 * GB, 10 * US, efficiency=0.7)),
    "nvlink": ("intra", Interconnect("nvlink", 95 * GB, 5 * US, efficiency=0.6)),
    "10gbe": ("inter", Interconnect("10gbe", 1.25 * GB, 50 * US, efficiency=0.7)),
    "ib-100g": ("inter", Interconnect("ib-100g", 12.5 * GB, 10 * US, efficiency=0.19)),
    # What-if links beyond the paper's testbeds: IB with DDP-style bucket
    # fusion reaches far higher collective efficiency, and 200G doubles
    # the rate.  Useful sweep points for the §VII optimization study.
    "ib-100g-fused": ("inter", Interconnect("ib-100g-fused", 12.5 * GB, 10 * US,
                                            efficiency=0.7)),
    "ib-200g": ("inter", Interconnect("ib-200g", 25 * GB, 10 * US, efficiency=0.7)),
}


def resolve_interconnect_preset(preset: str) -> tuple[str, Interconnect]:
    """``(slot, link)`` for a preset name, including the *scaled-preset
    grammar* ``<base>@bw<F>@lat<F>``: a base preset with its bandwidth
    and/or latency multiplied by ``F`` (either modifier may be omitted,
    order-free).  ``"ib-100g@bw2@lat0.25"`` is 2x the bandwidth at a
    quarter of the latency of ``ib-100g`` — the frontier grid sweeps
    these what-ifs without registering hundreds of named presets.

    Raises ``KeyError`` for unknown bases and ``ValueError`` for
    malformed modifiers.
    """
    base, _, mods = preset.partition("@")
    try:
        slot, link = INTERCONNECT_PRESETS[base]
    except KeyError:
        raise KeyError(f"unknown interconnect preset {base!r}; "
                       f"one of {sorted(INTERCONNECT_PRESETS)} or 'default'")
    if not mods:
        return slot, link
    bw_factor = lat_factor = 1.0
    for mod in mods.split("@"):
        if mod.startswith("bw"):
            bw_factor = float(mod[2:])
        elif mod.startswith("lat"):
            lat_factor = float(mod[3:])
        else:
            raise ValueError(
                f"malformed interconnect modifier {mod!r} in {preset!r}; "
                f"expected bw<factor> or lat<factor>")
        if bw_factor <= 0 or lat_factor < 0:
            raise ValueError(f"interconnect factors must be positive "
                             f"(latency may be 0), got {preset!r}")
    return slot, dataclasses.replace(
        link, name=preset, bandwidth=link.bandwidth * bw_factor,
        latency=link.latency * lat_factor)


def apply_interconnect_preset(cluster: ClusterSpec, preset: str | None) -> ClusterSpec:
    """Return ``cluster`` with the named preset's link substituted in.

    ``None`` (or ``"default"``) leaves the cluster untouched; scaled
    presets (``<base>@bw<F>@lat<F>``) resolve through
    :func:`resolve_interconnect_preset`.
    """
    if preset is None or preset == "default":
        return cluster
    slot, link = resolve_interconnect_preset(preset)
    return cluster.with_interconnect(**{slot: link})

# Roofline constants for the v5e target (used by launch/roofline.py).
V5E_PEAK_FLOPS_BF16 = 197e12
V5E_HBM_BW = 819 * GB
V5E_ICI_BW_PER_LINK = 50 * GB
