"""Hardware models for the DAG performance model.

Calibrated to the paper's Table II clusters (K80+PCIe+10GbE,
V100+NVLink+100Gb InfiniBand) plus the TPU v5e production target
this framework deploys on.

All bandwidths are bytes/second, latencies seconds, compute flop/s.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass

GB = 1e9
MB = 1e6
US = 1e-6


@dataclass(frozen=True)
class Interconnect:
    """A communication channel with an alpha-beta cost model."""

    name: str
    bandwidth: float          # bytes / s (peak, per direction)
    latency: float            # seconds per message (alpha term)
    efficiency: float = 1.0   # achieved fraction of peak for collectives

    def transfer_time(self, nbytes: float) -> float:
        """Point-to-point transfer time for ``nbytes``."""
        return self.latency + nbytes / (self.bandwidth * self.efficiency)


@dataclass(frozen=True)
class DeviceSpec:
    name: str
    peak_flops: float         # flop/s (the paper quotes peak incl. TensorCores)
    hbm_bandwidth: float      # bytes / s
    memory_bytes: float
    compute_efficiency: float = 0.5   # achieved fraction of peak in DNN layers


@dataclass(frozen=True)
class ClusterSpec:
    """A training cluster: N nodes x n_g devices, intra + inter connects.

    Mirrors Table II of the paper. ``allreduce_time`` implements the
    ring all-reduce alpha-beta model used to populate the DAG's
    communication nodes when no measured trace is available.
    """

    name: str
    device: DeviceSpec
    n_nodes: int
    gpus_per_node: int
    intra: Interconnect       # PCIe / NVLink / ICI
    inter: Interconnect      # 10GbE / InfiniBand / DCN
    disk: Interconnect        # storage read channel (t_io)
    h2d: Interconnect         # host-to-device copy channel (t_h2d)

    @property
    def total_devices(self) -> int:
        return self.n_nodes * self.gpus_per_node

    def with_workers(self, n_nodes: int, gpus_per_node: int | None = None) -> "ClusterSpec":
        g = self.gpus_per_node if gpus_per_node is None else gpus_per_node
        return dataclasses.replace(self, n_nodes=n_nodes, gpus_per_node=g)

    # ------------------------------------------------------------------
    # Collective models
    # ------------------------------------------------------------------
    def _bottleneck(self, n_workers: int) -> Interconnect:
        """The link a ring spanning ``n_workers`` devices is limited by."""
        if n_workers <= self.gpus_per_node:
            return self.intra
        return self.inter

    def allreduce_time(self, nbytes: float, n_workers: int | None = None) -> float:
        """Ring all-reduce of ``nbytes`` over ``n_workers`` devices.

        t = 2 (n-1)/n * nbytes / B_eff + 2 (n-1) alpha
        """
        n = self.total_devices if n_workers is None else n_workers
        if n <= 1:
            return 0.0
        link = self._bottleneck(n)
        bw = link.bandwidth * link.efficiency
        return 2.0 * (n - 1) / n * nbytes / bw + 2.0 * (n - 1) * link.latency

    def reduce_scatter_time(self, nbytes: float, n_workers: int | None = None) -> float:
        n = self.total_devices if n_workers is None else n_workers
        if n <= 1:
            return 0.0
        link = self._bottleneck(n)
        bw = link.bandwidth * link.efficiency
        return (n - 1) / n * nbytes / bw + (n - 1) * link.latency

    def allgather_time(self, nbytes: float, n_workers: int | None = None) -> float:
        return self.reduce_scatter_time(nbytes, n_workers)

    def alltoall_time(self, nbytes: float, n_workers: int | None = None) -> float:
        """All-to-all of ``nbytes`` held per device (MoE dispatch)."""
        n = self.total_devices if n_workers is None else n_workers
        if n <= 1:
            return 0.0
        link = self._bottleneck(n)
        bw = link.bandwidth * link.efficiency
        return (n - 1) / n * nbytes / bw + (n - 1) * link.latency

    # ------------------------------------------------------------------
    # Elementary task models
    # ------------------------------------------------------------------
    def compute_time(self, flops: float) -> float:
        return flops / (self.device.peak_flops * self.device.compute_efficiency)

    def io_time(self, nbytes: float) -> float:
        return self.disk.transfer_time(nbytes)

    def h2d_time(self, nbytes: float) -> float:
        return self.h2d.transfer_time(nbytes)


# ----------------------------------------------------------------------
# Paper Table II clusters.
#
# Collective efficiencies are calibrated against the paper's measured
# numbers (Section V-C2): training ResNet-50 on the V100 cluster the
# per-iteration gradient communication is ~79.7 ms for ~24M f32
# parameters over 16 GPUs — the paper reports NCCL2 achieving only
# ~9.6% of the 100Gb/s InfiniBand bandwidth due to layer-wise small
# messages.  The K80 cluster's 10GbE reaches a much larger fraction of
# its (far lower) peak.
# ----------------------------------------------------------------------
# Compute efficiencies calibrated against the paper's measured ResNet-50
# per-iteration times (§V-C2): K80 backward 0.243 s, V100 backward
# 0.0625 s at batch 32 (ResNet-50 fwd ~7.7 GFLOP/sample, bwd ~2x fwd).
K80_DEVICE = DeviceSpec(
    name="Tesla K80",
    peak_flops=4.37e12,
    hbm_bandwidth=240 * GB,
    memory_bytes=12 * GB,
    compute_efficiency=0.47,
)

V100_DEVICE = DeviceSpec(
    name="Tesla V100",
    peak_flops=125e12,        # with Tensor Cores, as quoted in the paper
    hbm_bandwidth=900 * GB,
    memory_bytes=16 * GB,
    # Calibrated: 0.0625 s for ResNet-50 backward at batch 32 implies
    # ~7.9 TFLOP/s achieved — 6.3% of the quoted 125 TFLOP TensorCore
    # peak (fp32 training largely bypasses TensorCores; the paper's own
    # point is that quoted peak vastly outruns end-to-end compute).
    compute_efficiency=0.063,
)

K80_CLUSTER = ClusterSpec(
    name="k80-pcie-10gbe",
    device=K80_DEVICE,
    n_nodes=4,
    gpus_per_node=4,
    intra=Interconnect("pcie3", 15 * GB, 10 * US, efficiency=0.7),
    inter=Interconnect("10gbe", 1.25 * GB, 50 * US, efficiency=0.7),
    disk=Interconnect("nfs", 1.1 * GB, 1e-4),
    h2d=Interconnect("pcie3-h2d", 15 * GB, 10 * US, efficiency=0.8),
)

V100_CLUSTER = ClusterSpec(
    name="v100-nvlink-ib",
    device=V100_DEVICE,
    n_nodes=4,
    gpus_per_node=4,
    intra=Interconnect("nvlink", 95 * GB, 5 * US, efficiency=0.6),
    # 100Gbps IB = 12.5 GB/s peak.  Efficiency calibrated so the ring
    # all-reduce of ResNet-50's 102 MB of f32 gradients over 16 GPUs
    # costs the measured 79.7 ms (the paper reports NCCL2 reaching only
    # ~9.6% of raw link bandwidth when counting the layer-wise message
    # pattern; 0.19 is the matching end-to-end collective efficiency).
    inter=Interconnect("ib-100g", 12.5 * GB, 10 * US, efficiency=0.19),
    disk=Interconnect("ssd", 367.3 * MB, 1e-4),
    h2d=Interconnect("pcie3-h2d", 15 * GB, 10 * US, efficiency=0.8),
)

# ----------------------------------------------------------------------
# Production target: TPU v5e pod(s).  One pod = 16x16 chips on a 2D ICI
# torus; pods connect over DCN.  Constants per the assignment:
#   197 TFLOP/s bf16 / chip, 819 GB/s HBM, ~50 GB/s/link ICI.
# DCN per-chip bandwidth is an assumption (documented in DESIGN.md).
# ----------------------------------------------------------------------
TPU_V5E = DeviceSpec(
    name="TPU v5e",
    peak_flops=197e12,
    hbm_bandwidth=819 * GB,
    memory_bytes=16 * GB,
    compute_efficiency=0.55,
)

TPU_V5E_POD = ClusterSpec(
    name="tpu-v5e-pod",
    device=TPU_V5E,
    n_nodes=1,
    gpus_per_node=256,
    intra=Interconnect("ici", 50 * GB, 1 * US, efficiency=0.8),
    inter=Interconnect("dcn", 6.25 * GB, 10 * US, efficiency=0.8),
    disk=Interconnect("gcs", 2 * GB, 1e-3),
    h2d=Interconnect("pcie-host", 32 * GB, 10 * US),
)

TPU_V5E_MULTIPOD = dataclasses.replace(TPU_V5E_POD, name="tpu-v5e-2pod", n_nodes=2)

CLUSTERS = {c.name: c for c in (K80_CLUSTER, V100_CLUSTER, TPU_V5E_POD, TPU_V5E_MULTIPOD)}

# Roofline constants for the v5e target (used by launch/roofline.py).
V5E_PEAK_FLOPS_BF16 = 197e12
V5E_HBM_BW = 819 * GB
V5E_ICI_BW_PER_LINK = 50 * GB
