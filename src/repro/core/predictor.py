"""Predictor: turn costs (analytic model, measured trace, or compiled
cost-analysis) into iteration-time / speedup predictions via the DAG.

This is the bridge the paper demonstrates in §V-D (Fig. 4): feed the
measured layer-wise times into the DAG, list-schedule it, and compare
against measurement.  :func:`predict_sync_policy` is the
measurement-loop entry: it maps this repo's *executable* gradient-sync
policies (:data:`repro.comm.sync.SYNC_POLICIES`) onto the DAG policies
whose schedule models them, so
``benchmarks/bench_model_vs_measured.py`` can score the model against
the repo's own instrumented runs.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

from repro.core import analytical
from repro.core.costmodel import comm_scale_fn
from repro.core.dag import NET_CHANNEL, IterationCosts
from repro.core.hardware import ClusterSpec
from repro.core.policies import BUCKETED_25MB, CAFFE_MPI, Policy
from repro.core.simulator import simulate_policy, simulate_steady
from repro.core.workloads import resolve_workload


@dataclass(frozen=True)
class Prediction:
    policy: str
    n_workers: int
    iteration_time: float          # steady-state, from the DAG simulator
    analytical_time: float | None  # closed-form counterpart, when defined
    samples_per_sec: float
    speedup: float                 # vs 1 worker, weak scaling (Eq. 6 form)
    comm_utilization: float        # busy fraction of the collective channel


def predict(
    costs: IterationCosts,
    n_workers: int,
    policy: Policy,
    batch_per_gpu: int = 1,
    costs_1gpu: IterationCosts | None = None,
    cluster: ClusterSpec | None = None,
    warm_iterations: int = 4,
    collective: str = "ring",
) -> Prediction:
    """Steady-state iteration time for ``costs`` under ``policy``."""
    comm_scale = comm_scale_fn(cluster, n_workers, collective) \
        if cluster else None
    r = simulate_policy(costs, n_workers, policy,
                        n_iterations=warm_iterations, comm_scale=comm_scale)
    t_iter = r.steady_iteration_time()

    base = costs_1gpu or costs
    c1 = IterationCosts(t_f=base.t_f, t_b=base.t_b, t_c=[0.0] * base.num_layers,
                        t_io=base.t_io, t_h2d=base.t_h2d, t_u=base.t_u)
    t1 = simulate_steady(c1, 1, policy, n_iterations=warm_iterations)
    speedup = n_workers * t1 / t_iter if t_iter > 0 else float(n_workers)

    # None for bucketed/priority policies: their steady state has no
    # exact closed form, only the simulator result above.
    ana = analytical.closed_form(costs, policy)
    return Prediction(
        policy=policy.name,
        n_workers=n_workers,
        iteration_time=t_iter,
        analytical_time=ana,
        samples_per_sec=n_workers * batch_per_gpu / t_iter if t_iter else 0.0,
        speedup=speedup,
        comm_utilization=r.utilization(NET_CHANNEL),
    )


def predict_workload(
    workload: str,
    cluster: ClusterSpec,
    n_workers: int,
    policy: Policy,
    collective: str = "ring",
    batch_per_gpu: int | None = None,
    **cost_kw,
) -> Prediction:
    """End-to-end: registry workload name -> prediction on a cluster.

    ``workload`` is anything the registry resolves — a paper CNN
    (``"resnet50"``), a measured trace (``"trace:alexnet-k80"``) or an
    LLM config (``"llm:gemma3-1b"``).  ``collective`` picks the
    all-reduce cost model (ring / tree / hierarchical, see
    :data:`repro.core.hardware.COLLECTIVE_ALGORITHMS`); ``cost_kw``
    (``bwd_fwd_ratio``, ``bytes_per_sample``,
    ``decode_seconds_per_byte``) forwards to
    :meth:`~repro.core.workloads.WorkloadTable.iteration_costs`.
    """
    tab = resolve_workload(workload)
    batch = batch_per_gpu or tab.batch_default
    costs = tab.iteration_costs(cluster, batch, n_workers, collective,
                                **cost_kw)
    costs_1 = tab.iteration_costs(cluster, batch, 1, collective, **cost_kw)
    return predict(costs, n_workers, policy, batch_per_gpu=batch,
                   costs_1gpu=costs_1, cluster=cluster, collective=collective)


#: Pre-registry name, kept for callers of the CNN-only era.
predict_cnn = predict_workload


#: Executable gradient-sync policy (``repro.comm.sync``) -> the DAG
#: policy whose schedule models it.  ``at_end`` is one fused collective
#: after backward: a single infinite bucket releases exactly when the
#: whole backward pass has (its earliest layer's gradient ready) —
#: fused comm-at-end, the degenerate bucket case the timeline tests
#: pin.  ``wfbp`` is layer-wise comm inside backward (Caffe-MPI's
#: schedule); ``bucketed`` is the DDP-default 25 MB fusion.
SYNC_POLICY_MODELS: dict[str, Policy] = {
    "at_end": Policy("at-end-fused", overlap_io=True, h2d_early=True,
                     overlap_comm=True, bucket_bytes=float("inf")),
    "wfbp": CAFFE_MPI,
    "bucketed": BUCKETED_25MB,
}


def predict_sync_policy(
    costs: IterationCosts,
    n_workers: int,
    sync_policy: str,
    comm_scale=None,
    bucket_bytes: float | None = None,
    warm_iterations: int = 8,
) -> float:
    """Model-predicted steady iteration time (seconds) for an
    *executable* sync policy — ``at_end`` / ``wfbp`` / ``bucketed`` —
    over measured (or analytic) ``costs``.

    ``comm_scale(total_bytes, naive_time) -> seconds`` prices fused
    buckets (measured alpha-beta fit via
    :func:`repro.measure.calibrate.comm_scale_from_fit`, or a
    cluster-model closure via
    :func:`repro.core.costmodel.comm_scale_fn`); without it, a fused
    bucket costs the sum of its layers' ``t_c``.  ``bucket_bytes``
    overrides the modeled fusion threshold for ``bucketed`` (to match
    the threshold the step was actually lowered with).
    """
    try:
        policy = SYNC_POLICY_MODELS[sync_policy]
    except KeyError:
        raise ValueError(
            f"unknown sync policy {sync_policy!r}; one of "
            f"{sorted(SYNC_POLICY_MODELS)}") from None
    if bucket_bytes is not None and sync_policy == "bucketed":
        policy = replace(policy, bucket_bytes=bucket_bytes)
    return simulate_steady(costs, n_workers, policy,
                           n_iterations=warm_iterations,
                           comm_scale=comm_scale)


def scaling_curve(workload: str, cluster: ClusterSpec, policy: Policy,
                  worker_counts=(1, 2, 4, 8, 16), **cost_kw) -> list[Prediction]:
    return [predict_workload(workload, cluster, n, policy, **cost_kw)
            for n in worker_counts]
