"""Sharded sweep execution: grid chunks across a worker pool.

The batched kernel already evaluates tens of thousands of scenarios per
core-millisecond, so the parallel layer's job is **not** to make one
chunk faster — it is to let a grid sweep use more than one core without
changing a single output bit.  The design that makes that trivial:

* A :class:`~repro.core.scenarios.ScenarioGrid` is a tiny frozen
  value object, and every per-scenario quantity is *derived* from the
  flat index (rightmost axis fastest).  A unit of work is therefore
  just ``(grid, lo, hi)`` — no arrays cross the process boundary on
  the way in, and the grid pickles in microseconds.
* :meth:`repro.core.batched.GridEvaluator.run_span` restricts the
  kernel to the unique kernel points a span touches, so a worker
  evaluating 1/Nth of the grid does ~1/Nth of the kernel work — the
  memoized evaluator (grid structure, workload tables, bucket tables)
  is built once per worker process and shared by all its spans.
* Workers return columnar tables (:mod:`repro.core.resulttable`):
  one pickled NumPy array per column, not N dicts.
* Chunking is deterministic and results are yielded **in submission
  order**, so ``jobs=N`` output is bit-identical to serial — the
  kernel is pure elementwise arithmetic per scenario point, and
  chunk boundaries cannot change any value
  (``tests/test_parallel.py`` pins exact equality).

``pool="process"`` (default) uses a spawn-context
``ProcessPoolExecutor`` — fork is unsafe with threaded BLAS and any
jax runtime in the parent.  ``pool="thread"`` runs the spans on
threads instead: zero startup cost and useful concurrency because the
kernel spends its time inside NumPy (GIL released), but processes are
the honest default for CPU-bound sharding.  Pools are cached per
``(kind, jobs)`` and shut down at interpreter exit.

The jax backend does **not** use this module: sharding there happens
on the device mesh inside the jit kernel
(:mod:`repro.core.batched_jax`), where a host pool would only fight
XLA for the same devices.
"""
from __future__ import annotations

import atexit
import os
import sys
from concurrent.futures import Executor, ProcessPoolExecutor, \
    ThreadPoolExecutor
from typing import Iterator

import numpy as np

from repro.core.scenarios import ScenarioGrid

#: ``sys.path`` entry the workers need to import :mod:`repro` — spawned
#: interpreters inherit neither ``PYTHONPATH`` mutations made after
#: startup nor the parent's ``sys.path``.
_SRC_PATH = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

POOL_KINDS = ("process", "thread")


def resolve_jobs(jobs: int | None) -> int:
    """Worker count for a ``jobs`` argument: ``None``/``0``/``1`` mean
    serial, a negative value means one worker per available core."""
    if not jobs or jobs == 1:
        return 1
    if jobs < 0:
        return os.cpu_count() or 1
    return int(jobs)


def span_plan(n: int, jobs: int, chunk: int) -> list[tuple[int, int]]:
    """Contiguous ``(lo, hi)`` spans covering ``[0, n)``: at least
    ``chunk`` scenarios each (a span below kernel-chunk size wastes the
    fixed per-call cost), at most ``4 * jobs`` spans total (enough
    slack to even out simulator-fallback stragglers without drowning
    in per-span overhead)."""
    if n == 0:
        return []
    span = max(chunk, -(-n // (jobs * 4)))
    return [(lo, min(lo + span, n)) for lo in range(0, n, span)]


def _init_worker(src_path: str) -> None:
    if src_path not in sys.path:
        sys.path.insert(0, src_path)


def _eval_span(grid: ScenarioGrid, lo: int, hi: int,
               warm_iterations: int, seed: int = 0) -> dict:
    """One unit of work: evaluate flat scenario indices ``[lo, hi)``
    and return the finished columnar table.  Runs in the worker; the
    evaluator memo (:func:`repro.core.batched.grid_evaluator`) makes
    every span after a worker's first reuse the prepared structure.
    ``seed`` keys the straggler Monte Carlo draws — the draws are keyed
    by ``(spec, n_workers, seed)`` alone, so sharding cannot change a
    single sample."""
    from repro.core.batched import grid_evaluator

    ev = grid_evaluator(grid)
    table, batched = ev.run_span(lo, hi, seed=seed)
    if not bool(batched.all()):
        # simulator-fallback rows are filled where they are computed,
        # so the parent never re-derives which rows a span left bogus
        from repro.core.resulttable import fill_rows
        from repro.core.sweep import _sim_eval

        idx = np.nonzero(~batched)[0]
        fill_rows(table, idx,
                  [_sim_eval(ev.scenario_at(lo + int(i)), warm_iterations,
                             seed=seed)
                   for i in idx])
    return table


_POOLS: dict[tuple[str, int], Executor] = {}


def _get_pool(kind: str, jobs: int) -> Executor:
    if kind not in POOL_KINDS:
        raise ValueError(f"unknown pool {kind!r}; one of {POOL_KINDS}")
    key = (kind, jobs)
    pool = _POOLS.get(key)
    if pool is None:
        if kind == "process":
            import multiprocessing as mp

            pool = ProcessPoolExecutor(
                max_workers=jobs, mp_context=mp.get_context("spawn"),
                initializer=_init_worker, initargs=(_SRC_PATH,))
        else:
            pool = ThreadPoolExecutor(max_workers=jobs)
        _POOLS[key] = pool
    return pool


@atexit.register
def _shutdown_pools() -> None:
    for pool in _POOLS.values():
        pool.shutdown(wait=False, cancel_futures=True)
    _POOLS.clear()


def parallel_tables(grid: ScenarioGrid, *, jobs: int,
                    chunk: int, warm_iterations: int = 6,
                    pool: str | Executor = "process",
                    seed: int = 0) -> Iterator[dict]:
    """Evaluate ``grid`` sharded across ``jobs`` workers, yielding
    finished columnar tables **in grid order** (submission order; all
    spans are in flight at once, results are consumed as each earliest
    outstanding span completes).  ``pool`` is ``"process"`` /
    ``"thread"`` or any ``concurrent.futures.Executor`` to reuse;
    ``seed`` keys the straggler Monte Carlo draws identically in every
    worker."""
    jobs = resolve_jobs(jobs)
    n = len(grid)
    spans = span_plan(n, jobs, chunk)
    if not spans:
        return
    if jobs == 1:
        for lo, hi in spans:
            yield _eval_span(grid, lo, hi, warm_iterations, seed)
        return
    ex = pool if isinstance(pool, Executor) else _get_pool(pool, jobs)
    futures = [ex.submit(_eval_span, grid, lo, hi, warm_iterations, seed)
               for lo, hi in spans]
    for fut in futures:
        yield fut.result()
