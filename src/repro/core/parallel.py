"""Sharded sweep execution: grid chunks across a worker pool.

The batched kernel already evaluates tens of thousands of scenarios per
core-millisecond, so the parallel layer's job is **not** to make one
chunk faster — it is to let a grid sweep use more than one core without
changing a single output bit.  The design that makes that trivial:

* A :class:`~repro.core.scenarios.ScenarioGrid` is a tiny frozen
  value object, and every per-scenario quantity is *derived* from the
  flat index (rightmost axis fastest).  A unit of work is therefore
  just ``(grid, lo, hi)`` — no arrays cross the process boundary on
  the way in, and the grid pickles in microseconds.
* :meth:`repro.core.batched.GridEvaluator.run_span` restricts the
  kernel to the unique kernel points a span touches, so a worker
  evaluating 1/Nth of the grid does ~1/Nth of the kernel work — the
  memoized evaluator (grid structure, workload tables, bucket tables)
  is built once per worker process and shared by all its spans.
* Workers return columnar tables (:mod:`repro.core.resulttable`):
  one pickled NumPy array per column, not N dicts.
* Chunking is deterministic and results are yielded **in submission
  order**, so ``jobs=N`` output is bit-identical to serial — the
  kernel is pure elementwise arithmetic per scenario point, and
  chunk boundaries cannot change any value
  (``tests/test_parallel.py`` pins exact equality).

``pool="process"`` (default) uses a spawn-context
``ProcessPoolExecutor`` — fork is unsafe with threaded BLAS and any
jax runtime in the parent.  ``pool="thread"`` runs the spans on
threads instead: zero startup cost and useful concurrency because the
kernel spends its time inside NumPy (GIL released), but processes are
the honest default for CPU-bound sharding.  Pools are cached per
``(kind, jobs)`` and shut down at interpreter exit; a cached pool that
broke (a worker OOM-killed or segfaulted) is evicted and rebuilt on
the next request instead of poisoning every later sweep.

Execution is **crash-tolerant**: a span whose worker process dies
(``BrokenProcessPool``) is retried on a freshly built pool with
exponential backoff, and a span that keeps killing workers — a poison
span — is isolated and rescued in the parent process (whole-span
first, then scenario by scenario, finally raising an error that names
the offending flat-index range).  Because every span is a pure
function of ``(grid, lo, hi, seed)``, re-running it cannot change a
bit: a sweep that loses a worker finishes with output bit-identical
to the serial evaluation (``tests/test_parallel.py`` kills a live
worker mid-sweep and pins exact equality).

The jax backend does **not** use this module: sharding there happens
on the device mesh inside the jit kernel
(:mod:`repro.core.batched_jax`), where a host pool would only fight
XLA for the same devices.
"""
from __future__ import annotations

import atexit
import os
import sys
import time
from concurrent.futures import BrokenExecutor, Executor, \
    ProcessPoolExecutor, ThreadPoolExecutor
from typing import Iterator

import numpy as np

from repro.core.scenarios import ScenarioGrid

#: ``sys.path`` entry the workers need to import :mod:`repro` — spawned
#: interpreters inherit neither ``PYTHONPATH`` mutations made after
#: startup nor the parent's ``sys.path``.
_SRC_PATH = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

POOL_KINDS = ("process", "thread")


def resolve_jobs(jobs: int | None) -> int:
    """Worker count for a ``jobs`` argument: ``None``/``0``/``1`` mean
    serial, a negative value means one worker per available core."""
    if not jobs or jobs == 1:
        return 1
    if jobs < 0:
        return os.cpu_count() or 1
    return int(jobs)


def span_plan(n: int, jobs: int, chunk: int) -> list[tuple[int, int]]:
    """Contiguous ``(lo, hi)`` spans covering ``[0, n)``: at least
    ``chunk`` scenarios each (a span below kernel-chunk size wastes the
    fixed per-call cost), at most ``4 * jobs`` spans total (enough
    slack to even out simulator-fallback stragglers without drowning
    in per-span overhead)."""
    if n == 0:
        return []
    span = max(chunk, -(-n // (jobs * 4)))
    return [(lo, min(lo + span, n)) for lo in range(0, n, span)]


#: Workload schemes whose tables every spawned worker pre-resolves in
#: its initializer: the built-in Table-IV CNN tables — the workloads of
#: the default and frontier grids, cheap to build and file-free.
#: (``trace:``/``llm:``/``jax:`` tables keep resolving lazily on first
#: use; preloading them would mean file I/O and archcost slicing for
#: sweeps that may never touch them.)
PRELOAD_SCHEMES = ("cnn",)


def _init_worker(src_path: str, preload: bool = True) -> None:
    """Spawned-worker initializer: make ``repro`` importable, then
    pre-pay the preparation a cold span would otherwise pay inside its
    first evaluation — import the evaluation stack (sweep engine,
    batched kernels, workload registry) and resolve the built-in
    workload tables (:data:`PRELOAD_SCHEMES`).  Preloading is
    opportunistic: any failure leaves the worker lazy, exactly as
    before."""
    if src_path not in sys.path:
        sys.path.insert(0, src_path)
    if not preload:
        return
    try:
        from repro.core import batched, sweep  # noqa: F401
        from repro.core.workloads import WORKLOAD_PROVIDERS, resolve_workload

        for scheme in PRELOAD_SCHEMES:
            provider = WORKLOAD_PROVIDERS.get(scheme)
            for name in provider.names() if provider else ():
                resolve_workload(f"{scheme}:{name}")
    except Exception:               # pragma: no cover - best effort
        pass


def _eval_span(grid: ScenarioGrid, lo: int, hi: int,
               warm_iterations: int, seed: int = 0) -> dict:
    """One unit of work: evaluate flat scenario indices ``[lo, hi)``
    and return the finished columnar table.  Runs in the worker; the
    evaluator memo (:func:`repro.core.batched.grid_evaluator`) makes
    every span after a worker's first reuse the prepared structure.
    ``seed`` keys the straggler Monte Carlo draws — the draws are keyed
    by ``(spec, n_workers, seed)`` alone, so sharding cannot change a
    single sample."""
    from repro.core.batched import grid_evaluator

    ev = grid_evaluator(grid)
    table, batched = ev.run_span(lo, hi, seed=seed)
    if not bool(batched.all()):
        # simulator-fallback rows are filled where they are computed,
        # so the parent never re-derives which rows a span left bogus
        from repro.core.resulttable import fill_rows
        from repro.core.sweep import _sim_eval

        idx = np.nonzero(~batched)[0]
        fill_rows(table, idx,
                  [_sim_eval(ev.scenario_at(lo + int(i)), warm_iterations,
                             seed=seed)
                   for i in idx])
    return table


_POOLS: dict[tuple[str, int], Executor] = {}

#: Pool rebuilds :func:`parallel_tables` pays for worker-process deaths
#: before treating the failing span as poison and rescuing it in the
#: parent.
MAX_POOL_REBUILDS = 3

#: First-retry backoff after a worker death; doubles per rebuild.
RETRY_BACKOFF_S = 0.05


def _evict_pool(ex: Executor) -> None:
    """Drop ``ex`` from the cache (if present) and shut it down — a
    broken executor rejects every future submit, so keeping it cached
    would poison all later sweeps."""
    for key, pool in list(_POOLS.items()):
        if pool is ex:
            del _POOLS[key]
    ex.shutdown(wait=False, cancel_futures=True)


def _get_pool(kind: str, jobs: int) -> Executor:
    if kind not in POOL_KINDS:
        raise ValueError(f"unknown pool {kind!r}; one of {POOL_KINDS}")
    key = (kind, jobs)
    pool = _POOLS.get(key)
    if pool is not None and getattr(pool, "_broken", False):
        # a worker died since the last sweep (OOM killer, segfault):
        # the executor is permanently broken — rebuild instead of
        # handing the corpse to every future caller
        _evict_pool(pool)
        pool = None
    if pool is None:
        if kind == "process":
            import multiprocessing as mp

            pool = ProcessPoolExecutor(
                max_workers=jobs, mp_context=mp.get_context("spawn"),
                initializer=_init_worker, initargs=(_SRC_PATH,))
        else:
            pool = ThreadPoolExecutor(max_workers=jobs)
        _POOLS[key] = pool
    return pool


def warm_pool(kind: str = "process", jobs: int = 2) -> None:
    """Build (or fetch) the cached pool for ``(kind, jobs)`` and block
    until every worker has spawned and run its initializer — the
    pre-import/pre-resolve of :func:`_init_worker` included — so the
    *first* ``sweep(jobs=N)`` pays no per-worker preparation inside its
    spans.  The sweep server calls this at startup; benchmarks call it
    to separate cold-start cost from steady-state throughput.

    One short parked task per worker forces the executor's lazy spawn
    to reach all ``jobs`` processes (tasks that return instantly would
    all land on the first worker)."""
    jobs = resolve_jobs(jobs)
    if jobs <= 1:
        return
    ex = _get_pool(kind, jobs)
    for f in [ex.submit(time.sleep, 0.05) for _ in range(jobs)]:
        f.result()


@atexit.register
def _shutdown_pools() -> None:
    for pool in _POOLS.values():
        pool.shutdown(wait=False, cancel_futures=True)
    _POOLS.clear()


def _rescue_span(grid: ScenarioGrid, lo: int, hi: int,
                 warm_iterations: int, seed: int) -> dict:
    """In-parent rescue for a poison span: evaluate ``[lo, hi)`` whole;
    if that raises, fall back scenario by scenario so a single bad
    point is named by its flat index instead of taking the span's other
    scenarios down with it."""
    from repro.core.resulttable import concat_tables

    try:
        return _eval_span(grid, lo, hi, warm_iterations, seed)
    except Exception:
        tables = []
        for i in range(lo, hi):
            try:
                tables.append(
                    _eval_span(grid, i, i + 1, warm_iterations, seed))
            except Exception as exc:
                raise RuntimeError(
                    f"scenario at flat index {i} of poison span "
                    f"[{lo}, {hi}) failed even in-process: {exc}") from exc
        return concat_tables(tables)


def parallel_tables(grid: ScenarioGrid, *, jobs: int,
                    chunk: int, warm_iterations: int = 6,
                    pool: str | Executor = "process",
                    seed: int = 0) -> Iterator[dict]:
    """Evaluate ``grid`` sharded across ``jobs`` workers, yielding
    finished columnar tables **in grid order** (submission order; all
    spans are in flight at once, results are consumed as each earliest
    outstanding span completes).  ``pool`` is ``"process"`` /
    ``"thread"`` or any ``concurrent.futures.Executor`` to reuse;
    ``seed`` keys the straggler Monte Carlo draws identically in every
    worker.

    A dying worker process (``BrokenProcessPool``) does not kill the
    sweep: the broken pool is evicted from the cache, a fresh one is
    built after an exponential backoff, and every not-yet-yielded span
    is resubmitted — spans are pure functions of ``(grid, lo, hi,
    seed)``, so the retried output is bit-identical.  After
    :data:`MAX_POOL_REBUILDS` (or a span that breaks two pools in a
    row — a poison span) the failing span is rescued in the parent via
    :func:`_rescue_span`, naming the offending flat-index range if it
    cannot be salvaged at all.  A caller-supplied executor is never
    rebuilt: the ``BrokenExecutor`` propagates, because replacing a
    pool this function does not own would be a lie."""
    jobs = resolve_jobs(jobs)
    n = len(grid)
    spans = span_plan(n, jobs, chunk)
    if not spans:
        return
    if jobs == 1:
        for lo, hi in spans:
            yield _eval_span(grid, lo, hi, warm_iterations, seed)
        return
    external = isinstance(pool, Executor)
    ex = pool if external else _get_pool(pool, jobs)

    def submit_from(start: int) -> None:
        futures[start:] = [
            ex.submit(_eval_span, grid, lo, hi, warm_iterations, seed)
            for lo, hi in spans[start:]]

    futures: list = [None] * len(spans)
    submit_from(0)
    rebuilds = 0
    breaks: dict[int, int] = {}        # span index -> pools it broke
    i = 0
    while i < len(spans):
        lo, hi = spans[i]
        try:
            table = futures[i].result()
        except BrokenExecutor:
            if external:
                raise
            breaks[i] = breaks.get(i, 0) + 1
            _evict_pool(ex)
            if rebuilds >= MAX_POOL_REBUILDS or breaks[i] > 1:
                # poison span (or the machine keeps killing workers):
                # rescue this span in the parent, then let the rest of
                # the sweep continue on a fresh pool
                table = _rescue_span(grid, lo, hi, warm_iterations, seed)
                ex = _get_pool(pool, jobs)
                submit_from(i + 1)
            else:
                rebuilds += 1
                time.sleep(RETRY_BACKOFF_S * 2 ** (rebuilds - 1))
                ex = _get_pool(pool, jobs)
                submit_from(i)
                continue
        yield table
        i += 1
