"""Closed-form iteration-time model — Eqs. (1)–(6) of the paper.

These are the analytical counterparts of the DAG simulator; the
property tests assert they coincide with :func:`repro.core.simulator.simulate`
on the matching topologies.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

from repro.core.dag import IterationCosts


def eq1_sgd_iteration(costs: IterationCosts) -> float:
    """Single-GPU mini-batch SGD: t_io + t_h2d + sum t_f + sum t_b + t_u."""
    return costs.t_io + costs.t_h2d + sum(costs.t_f) + sum(costs.t_b) + costs.t_u


def eq2_naive_ssgd(costs: IterationCosts) -> float:
    """Naive S-SGD: fully sequential io, h2d, fwd, bwd, comm, update."""
    return (costs.t_io + costs.t_h2d + sum(costs.t_f) + sum(costs.t_b)
            + sum(costs.t_c) + costs.t_u)


def eq3_io_overlap(costs: IterationCosts) -> float:
    """Overlapping I/O with computing: max(t_io + t_h2d, t_f + t_b + t_c).

    The paper's Eq. (3) omits ``t_u``; in steady state the update
    belongs to the GPU pipeline stage, so it joins the compute branch
    of the max (this is what the DAG simulator produces exactly).
    """
    return max(costs.t_io + costs.t_h2d,
               sum(costs.t_f) + sum(costs.t_b) + sum(costs.t_c) + costs.t_u)


def non_overlapped_comm(t_b: Sequence[float], t_c: Sequence[float]) -> float:
    """``t_c^no`` — the residual communication that WFBP cannot hide.

    Greedy WFBP schedule (paper §IV-C): the all-reduce of layer ``l``
    may start once the backward of layer ``l`` has finished, and the
    collective channel serializes.  Backward runs layer L..1.  The
    returned value satisfies Eq. (5):

        t_iter = max(t_io + t_h2d, t_f + t_b + t_c^no)
    """
    L = len(t_b)
    if L != len(t_c):
        raise ValueError("length mismatch")
    bwd_finish = 0.0
    comm_finish = 0.0
    for l in range(L - 1, -1, -1):      # layer L first
        bwd_finish += t_b[l]
        if t_c[l] > 0:
            comm_finish = max(comm_finish, bwd_finish) + t_c[l]
    total_b = sum(t_b)
    return max(comm_finish - total_b, 0.0)


def non_overlapped_comm_batch(t_b: np.ndarray, t_c: np.ndarray) -> np.ndarray:
    """Vectorized ``t_c^no`` over ``(scenario, layer)`` matrices — the
    prefix-max formulation of :func:`non_overlapped_comm`.

    Unrolling the greedy WFBP recurrence
    ``comm_finish = max(comm_finish, bwd_finish_l) + t_c_l`` (layers
    visited L..1, zero-comm layers skipped) gives the closed form

        comm_finish = max over layers l with t_c_l > 0 of
                      (bwd_finish_l + sum of t_c over layers <= l)

    i.e. a backward-time suffix sum plus a comm prefix sum, reduced
    with one max — three cumulative-sum/max passes over the matrix, no
    per-scenario Python.  Zero-padded layers (``t_b = t_c = 0``) drop
    out of both sums and are masked from the max, which is what lets
    the batched evaluator share one padded matrix across workloads of
    different depths.

    ``t_b`` / ``t_c`` are ``(..., L)`` in forward layer order (index 0
    = layer 1), matching :class:`~repro.core.dag.IterationCosts`, with
    the layer axis last — ``(S, L)`` matrices on the batched NumPy
    path, single ``(L,)`` rows under the vmap of
    :mod:`repro.core.batched_jax` (the function is dtype-polymorphic
    over NumPy and ``jax.numpy``).  Returns the ``(...,)`` residual,
    elementwise identical (<= 1e-9 relative, property-tested) to the
    scalar loop.
    """
    from repro.core.xputil import array_namespace

    xp = array_namespace(t_b, t_c)
    t_b = xp.asarray(t_b, dtype=xp.float64)
    t_c = xp.asarray(t_c, dtype=xp.float64)
    if t_b.shape != t_c.shape:
        raise ValueError("length mismatch")
    # All passes run on the forward-order contiguous matrices:
    # bwd_finish at layer l is the *suffix* sum of t_b (backward has
    # reached l), the comm issued by then is the *prefix* sum of t_c
    # (layers >= l were all enqueued first), and mask-multiplication
    # (not np.where) zeroes the no-comm candidates.
    prefix_b = xp.cumsum(t_b, axis=-1)
    total_b = prefix_b[..., -1]
    suffix_b = (total_b[..., None] - prefix_b) + t_b     # inclusive suffix
    prefix_c = xp.cumsum(t_c, axis=-1)
    cand = (suffix_b + prefix_c) * (t_c > 0)
    comm_finish = cand.max(axis=-1, initial=0.0)
    return xp.maximum(comm_finish - total_b, 0.0)


def worker_bottleneck(inv_speed, bw_mult, lat_mult, axis: int = -1):
    """Slowest-worker reduction over the per-worker axis: the
    synchronous steady state is gated by the slowest participant, so a
    heterogeneous scenario collapses to the homogeneous closed forms
    evaluated at ``tmul = max_w inv_speed``, ``bwmul = min_w bw_mult``,
    ``latmul = max_w lat_mult``.

    Exact, not an approximation: per-worker multipliers are constant
    across layers, so the same worker attains the per-layer max at
    every layer and the per-worker DAG reproduces the reduced closed
    form (property-tested against the event-driven simulator ≤1e-6).

    Accepts the zero/``+inf``-padded ``(..., Wmax)`` worker tables of
    :func:`repro.core.het.worker_table_rows` — the pads are neutral for
    these reductions — and is dtype-polymorphic over NumPy and
    ``jax.numpy`` (the batched kernels of both backends reduce the same
    padded tables).  A constant vector reduces to its value bit-exactly
    (max/min never round), which is what keeps all-ones profiles
    bit-identical to the scalar path.
    """
    from repro.core.xputil import array_namespace

    xp = array_namespace(inv_speed, bw_mult, lat_mult)
    return (xp.max(inv_speed, axis=axis),
            xp.min(bw_mult, axis=axis),
            xp.max(lat_mult, axis=axis))


def effective_sync_k(sync_k, n_workers):
    """The K actually waited for: ``sync_k`` clamped to ``[1, n]``,
    with the full-sync sentinels (``None`` / ``0``) mapping to ``n``.
    Clamping (rather than rejecting ``K > n``) keeps grid-axis
    validation separable from the worker-count axis — the same design
    rule as the het profiles' proportional slot stretching.  Accepts
    scalars or arrays (vectorized over rows)."""
    from repro.core.xputil import array_namespace

    if sync_k is None:
        return n_workers
    xp = array_namespace(sync_k, n_workers)
    k = xp.asarray(sync_k)
    n = xp.asarray(n_workers)
    return xp.where(k <= 0, n, xp.clip(k, 1, n))


def kth_order_statistic(values, n, k):
    """The ``k``-th smallest of the ``n`` live entries in each
    zero-padded ``(..., Wmax)`` row of ``values`` (live entries are
    strictly positive, pads are ``0`` — the
    :func:`repro.core.het.worker_table_rows` convention).

    ``k = n`` returns exactly the row max (the slowest-worker
    reduction, bit-identical — a sort never rounds); ``k = 1`` the live
    min.  Sorting descending puts the pads *last*, so the ``k``-th
    smallest live value sits at index ``n - k`` regardless of padding.
    Dtype-polymorphic: the jax branch sorts with ``jax.lax.top_k``
    (k = Wmax, i.e. a full descending sort, jit/vmap-compatible with a
    static width), the NumPy branch with ``np.sort``.  ``n`` and ``k``
    broadcast over the leading axes; ``k`` must already be clamped to
    ``[1, n]`` (:func:`effective_sync_k`)."""
    from repro.core.xputil import array_namespace

    xp = array_namespace(values, n, k)
    values = xp.asarray(values, dtype=xp.float64)
    wmax = values.shape[-1]
    n = xp.asarray(n)
    k = xp.asarray(k)
    if xp.__name__.startswith("jax"):
        import jax

        desc, _ = jax.lax.top_k(values, wmax)
    else:
        desc = -xp.sort(-values, axis=-1)
    idx = xp.clip(n - k, 0, wmax - 1).astype(xp.int64)
    idx = xp.broadcast_to(idx, values.shape[:-1])
    return xp.take_along_axis(desc, idx[..., None], axis=-1)[..., 0]


def worker_bottleneck_k(inv_speed, bw_mult, lat_mult, n, sync_k, axis: int = -1):
    """K-of-N generalization of :func:`worker_bottleneck`: the
    synchronous update fires once the ``K``-th fastest gradient is in,
    so the compute multiplier is the ``K``-th *order statistic* of the
    per-worker ``inv_speed`` (not the max), while the link multipliers
    stay the full min/max — all ``N`` workers keep their place in the
    collective and receive the broadcast update; the threshold only
    stops the barrier from waiting for gradients beyond the ``K``-th.

    Exactness argument unchanged from :func:`worker_bottleneck`:
    per-worker multipliers are constant across layers, so the worker
    ranked ``K``-th is ranked ``K``-th at every layer, and the K-of-N
    DAG steady state equals the homogeneous closed form at
    ``tmul = kth_smallest_w(inv_speed)`` (property-tested ≤1e-6 against
    the event-driven simulator).  ``sync_k`` may be a scalar or a
    per-row array; full-sync sentinels (``None``/``0``) and ``K >= n``
    reproduce :func:`worker_bottleneck` bit-identically."""
    from repro.core.xputil import array_namespace

    if axis != -1:
        raise ValueError("worker_bottleneck_k reduces the last axis only")
    xp = array_namespace(inv_speed, bw_mult, lat_mult)
    keff = effective_sync_k(sync_k, n)
    return (kth_order_statistic(inv_speed, n, keff),
            xp.min(bw_mult, axis=-1),
            xp.max(lat_mult, axis=-1))


def eq5_wfbp(costs: IterationCosts) -> float:
    """WFBP: max(t_io + t_h2d, t_f + t_b + t_c^no + t_u)."""
    tc_no = non_overlapped_comm(costs.t_b, costs.t_c)
    return max(costs.t_io + costs.t_h2d,
               sum(costs.t_f) + sum(costs.t_b) + tc_no + costs.t_u)


def eq3_late_h2d(costs: IterationCosts) -> float:
    """CNTK pipeline: I/O overlapped but the H2D copy waits for the
    previous model update (no spare device buffer), so ``t_h2d`` joins
    the GPU-side chain:

        t_iter = max(t_io + t_h2d, t_h2d + t_f + t_b + t_c + t_u)

    This is the late-H2D variant of Eq. (3); the DAG simulator
    reproduces it exactly (property-tested).
    """
    return max(costs.t_io + costs.t_h2d,
               costs.t_h2d + sum(costs.t_f) + sum(costs.t_b)
               + sum(costs.t_c) + costs.t_u)


def eq5_late_h2d(costs: IterationCosts) -> float:
    """MXNet/TensorFlow pipeline: WFBP comm overlap, but late H2D —
    the late-H2D variant of Eq. (5):

        t_iter = max(t_io + t_h2d, t_h2d + t_f + t_b + t_c^no + t_u)
    """
    tc_no = non_overlapped_comm(costs.t_b, costs.t_c)
    return max(costs.t_io + costs.t_h2d,
               costs.t_h2d + sum(costs.t_f) + sum(costs.t_b) + tc_no + costs.t_u)


def eq6_speedup(costs_1gpu: IterationCosts, costs_n: IterationCosts,
                n_gpus: int) -> float:
    """Weak-scaling speedup of N_g GPUs over one GPU (Eq. 6).

    ``costs_1gpu`` carries the single-GPU I/O time ``t_io_1`` and zero
    comm; ``costs_n`` carries the per-layer comm of the N_g-GPU run and
    the (possibly larger) I/O time ``t_io_Ng``.
    """
    t1 = max(costs_1gpu.t_io + costs_1gpu.t_h2d,
             sum(costs_1gpu.t_f) + sum(costs_1gpu.t_b))
    tc_no = non_overlapped_comm(costs_n.t_b, costs_n.t_c)
    tn = max(costs_n.t_io + costs_n.t_h2d,
             sum(costs_n.t_f) + sum(costs_n.t_b) + tc_no)
    return n_gpus * t1 / tn if tn > 0 else float(n_gpus)


def has_closed_form(policy) -> bool:
    """True when ``policy``'s steady state has an exact *per-layer*
    closed form — Eqs. (2)/(3)/(5) or a late-H2D variant.

    Bucket fusion and priority comm fall outside these equations:
    bucket boundaries and net-channel reordering depend on the schedule
    itself.  Their steady state *is* still exactly expressible — as the
    bucket-timeline form (:func:`has_timeline_form`,
    :mod:`repro.core.bucketsim`) — just not by the per-layer equations
    this predicate guards.  The single shared predicate for
    :func:`closed_form` and the sweep engine's fast-path routing.
    """
    if policy.bucket_bytes or policy.priority_comm:
        return False
    if not policy.overlap_io and (policy.overlap_comm or policy.h2d_early):
        return False           # combination not studied; simulate it
    return True


def has_timeline_form(policy) -> bool:
    """True when ``policy``'s steady state is exactly expressible by
    the **bucket-timeline** form (:mod:`repro.core.bucketsim`): a
    schedule-dependent comm policy (bucket fusion and/or priority
    scheduling) whose pipeline flags are among the studied
    combinations.

    The net channel is a single work-conserving resource, so its
    iteration makespan is order-independent — bucketed-FIFO and
    priority schedules share one closed residual (property-tested
    against the event-driven simulator, which remains the agreement
    oracle and the path ``force_simulator=True`` pins).  Policies that
    are neither closed-form nor timeline-form (unstudied pipeline
    combinations) still fall back to the simulator.
    """
    if not (policy.bucket_bytes or policy.priority_comm):
        return False           # per-layer exact policy: closed form
    if not policy.overlap_io and (policy.overlap_comm or policy.h2d_early):
        return False           # combination not studied; simulate it
    return True


def closed_form(costs: IterationCosts, policy) -> float | None:
    """Exact closed-form steady-state iteration time for ``policy``
    (a :class:`repro.core.policies.Policy`), or ``None`` when no exact
    closed form exists and the event-driven simulator must be used.

    Exactness (verified by the property tests in
    ``tests/test_dag_model.py`` and ``tests/test_sweep.py``):

    * no I/O overlap, no comm overlap  -> Eq. (2)
    * I/O overlap, early H2D           -> Eq. (3) / Eq. (5) with WFBP
    * I/O overlap, late H2D            -> the late-H2D variants above
    * bucket fusion or priority comm   -> inexact (``None``), see
      :func:`has_closed_form`.
    """
    if costs.num_layers == 0 or not has_closed_form(policy):
        return None
    if not policy.overlap_io:
        return eq2_naive_ssgd(costs)
    if policy.overlap_comm:
        return eq5_wfbp(costs) if policy.h2d_early else eq5_late_h2d(costs)
    return eq3_io_overlap(costs) if policy.h2d_early else eq3_late_h2d(costs)


def iteration_time(costs: IterationCosts, policy_name: str) -> float:
    """Dispatch the closed form matching a named policy.

    Raises ``ValueError`` for policies without an exact closed form
    (bucketed / priority) — use the DAG simulator for those.
    """
    from repro.core.policies import get_policy

    p = get_policy(policy_name)
    t = closed_form(costs, p)
    if t is None:
        raise ValueError(
            f"policy {policy_name!r} has no exact closed form; "
            "use repro.core.simulator")
    return t
