"""The paper's DAG model of S-SGD and everything that evaluates it.

Module map (see ``docs/architecture.md`` for the paper mapping):

* :mod:`repro.core.dag` — Fig. 1's task graph + ``IterationCosts``
  (Table I vocabulary).
* :mod:`repro.core.simulator` — event-driven list scheduler; turns a
  DAG into an iteration-time prediction under channel contention.
* :mod:`repro.core.analytical` — Eqs. (1)-(6) closed forms, plus the
  late-H2D variants and the ``closed_form`` policy dispatch.
* :mod:`repro.core.policies` — §IV-C framework taxonomy (overlap
  booleans) + beyond-paper bucketed/priority policies.
* :mod:`repro.core.hardware` — Table II clusters, alpha-beta links,
  ring/tree/hierarchical all-reduce cost models, interconnect presets.
* :mod:`repro.core.costmodel` — Table IV layer tables (AlexNet,
  GoogleNet, ResNet-50) -> ``IterationCosts`` on a cluster.
* :mod:`repro.core.predictor` — single-scenario prediction bridge
  (§V-D / Fig. 4).
* :mod:`repro.core.scenarios` / :mod:`repro.core.sweep` — declarative
  scenario grids and the batched sweep engine (vectorized closed-form
  fast path, batched bucket-timeline path for schedule-dependent
  policies, simulator fallback).
* :mod:`repro.core.bucketsim` — the bucket-timeline steady state:
  padded ``(scenario x bucket)`` structure tables and the vectorized
  residual that makes bucketed/priority policies batchable.
* :mod:`repro.core.archcost` — compiled-HLO cost analysis for the
  production transformer workloads.
"""
