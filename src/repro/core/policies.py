"""Overlap policies — the paper's §IV-C framework taxonomy, reified.

The paper distinguishes the four studied frameworks by exactly three
boolean pipeline choices plus the comm schedule:

=============  ===========  ============  =========
framework      overlap_io   h2d_early     overlap_comm (WFBP)
=============  ===========  ============  =========
Caffe-MPI      yes          yes           yes
MXNet          yes          no            yes
TensorFlow     yes          no            yes
CNTK           yes          no            no
naive S-SGD    no           no            no
=============  ===========  ============  =========

Beyond-paper policies: the ``bucketed-{1,4,25,100}mb`` family fuses
layer-wise gradients into size-targeted buckets (DDP/Horovod style —
the fix for the 9.6% network utilization the paper measured on
InfiniBand; the size axis sweeps latency amortization against overlap
lost to coarser release granularity), and ``PRIORITY`` frees the
comm-channel FIFO so smaller/earlier-needed tensors may overtake
(ByteScheduler style).
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class Policy:
    name: str
    overlap_io: bool = False      # prefetch next batch during compute
    h2d_early: bool = False       # copy to device buffer before update finishes
    overlap_comm: bool = False    # WFBP: layer-wise all-reduce inside backward
    serialize_comm: bool = True   # collective channel is FIFO (single NCCL stream)
    bucket_bytes: float | None = None   # fuse gradients into >= this many bytes
    priority_comm: bool = False   # allow priority scheduling on the net channel

    def describe(self) -> str:
        bits = []
        bits.append("io-overlap" if self.overlap_io else "blocking-io")
        bits.append("early-h2d" if self.h2d_early else "late-h2d")
        bits.append("wfbp" if self.overlap_comm else "comm-at-end")
        if self.bucket_bytes:
            bits.append(f"bucket={self.bucket_bytes / 1e6:.0f}MB")
        if self.priority_comm:
            bits.append("priority")
        return f"{self.name}({', '.join(bits)})"


NAIVE = Policy("naive")
CNTK = Policy("cntk", overlap_io=True)
MXNET = Policy("mxnet", overlap_io=True, overlap_comm=True)
TENSORFLOW = Policy("tensorflow", overlap_io=True, overlap_comm=True)
CAFFE_MPI = Policy("caffe-mpi", overlap_io=True, h2d_early=True, overlap_comm=True)

# Beyond-paper optimizations (§VII future work).  The bucket-size
# family sweeps the fusion axis the paper's conclusion asks about:
# 1 MB (latency still dominates), 4 MB, 25 MB (DDP's default) and
# 100 MB (one-ish bucket for the paper CNNs ≈ comm-at-end with a fused
# collective).
def _bucketed(mb: float) -> Policy:
    return Policy(f"bucketed-{mb:g}mb", overlap_io=True, h2d_early=True,
                  overlap_comm=True, bucket_bytes=mb * 1e6)


BUCKETED_1MB = _bucketed(1)
BUCKETED_4MB = _bucketed(4)
BUCKETED_25MB = _bucketed(25)
BUCKETED_100MB = _bucketed(100)
BUCKETED_POLICIES = {p.name: p for p in
                     (BUCKETED_1MB, BUCKETED_4MB, BUCKETED_25MB,
                      BUCKETED_100MB)}
# No serialize_comm chain edges: the net channel still executes one
# collective at a time (channel constraint), but the *order* is the
# priority queue's to choose — otherwise issue-order FIFO edges would
# pin the schedule and the priorities could never reorder anything.
PRIORITY = Policy("priority", overlap_io=True, h2d_early=True,
                  overlap_comm=True, serialize_comm=False,
                  priority_comm=True)

FRAMEWORK_POLICIES = {
    "caffe-mpi": CAFFE_MPI,
    "cntk": CNTK,
    "mxnet": MXNET,
    "tensorflow": TENSORFLOW,
}

ALL_POLICIES = dict(FRAMEWORK_POLICIES, naive=NAIVE,
                    **BUCKETED_POLICIES, priority=PRIORITY)


def get_policy(name: str) -> Policy:
    try:
        return ALL_POLICIES[name]
    except KeyError:
        raise KeyError(f"unknown policy {name!r}; one of {sorted(ALL_POLICIES)}")
