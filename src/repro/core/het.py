"""Per-worker heterogeneity: profiles, padded worker tables, stragglers.

The paper's DAG model (§III) assumes all ``N`` workers are identical;
this module is the vocabulary that relaxes that.  A **heterogeneity
profile** assigns every worker a compute-speed multiplier and per-link
bandwidth/latency multipliers via a compact grammar on the scenario
axes (mirroring the scaled-interconnect grammar
``ib-100g@bw2@lat0.25``):

    het:<count>x<speed>[@bw<F>][@lat<F>][+<count>x<speed>...]

e.g. ``het:8x0.5+8x1.0`` — eight half-speed workers plus eight
full-speed ones; ``het:4x1@bw0.5`` — four workers whose links run at
half bandwidth.  Profiles are *ratio patterns*: a profile with ``C``
slots stretches to any ``n_workers`` by the proportional slot rule
``slot(i) = floor(i * C / n)``, which keeps grid-axis validation
separable from the worker-count axis.

A **straggler spec** adds stochastic per-worker compute jitter on top:

    <dist>:<scale>[x<draws>]        dist in {lognormal, exp}

``lognormal:0.2x1000`` multiplies every worker's compute time by
``exp(0.2 * Z)`` (``Z`` standard normal) in each of 1000 Monte Carlo
draws; ``exp:0.5`` uses ``1 + Exponential(0.5)`` multipliers (jitter
can only slow a worker down).  Draws are generated once in host NumPy
from a counter-based key — ``(spec, n_workers, seed)`` — so every
backend, process shard and chunk boundary sees the identical sample.

The synchronous steady state is gated by the *slowest* participant:
with per-worker multipliers constant across layers, the same worker
attains the per-layer max everywhere, so the heterogeneous iteration
time equals the homogeneous closed form evaluated at the bottleneck
multipliers ``tmul = max_w(jitter_w / speed_w)``,
``bwmul = min_w(bw_w)``, ``latmul = max_w(lat_w)`` (the reduction
:func:`repro.core.analytical.worker_bottleneck` — validated ≤1e-6
against the per-worker event-driven simulator).  The padded
``(profile, W)`` tables here use *neutral* pads for those reductions:
``inv_speed = 0`` and ``lat_mult = 0`` (max-reduce), ``bw_mult = +inf``
(min-reduce) — padding with 1.0 would corrupt the max/min.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Sequence

import numpy as np

HET_PREFIX = "het:"
FAULT_PREFIX = "fail:"
STRAGGLER_DISTRIBUTIONS = ("lognormal", "exp")
DEFAULT_DRAWS = 1000
MAX_DRAWS = 1_000_000
#: Default checkpoint-restore penalty (seconds) when ``@restart<T>`` is
#: omitted from a fault spec: the wall-clock of re-reading a ~10 GB npz
#: checkpoint (:mod:`repro.checkpoint.ckpt` save/restore pair) from a
#: ~2 GB/s shared store and re-staging it — see :func:`restart_penalty_s`.
DEFAULT_RESTART_S = 5.0


def restart_penalty_s(ckpt_bytes: float, store_bw: float = 2e9) -> float:
    """Checkpoint-restore penalty for a checkpoint of ``ckpt_bytes``
    read from shared storage at ``store_bw`` bytes/s — the
    :mod:`repro.checkpoint.ckpt`-shaped cost a crashed worker pays
    before rejoining (npz read is bandwidth-bound; the h2d restage is
    folded into the same stream).  Use this to derive the
    ``@restart<T>`` value of a fault spec from a real model size."""
    if not ckpt_bytes >= 0:
        raise ValueError("ckpt_bytes must be >= 0")
    if not store_bw > 0:
        raise ValueError("store_bw must be > 0")
    return float(ckpt_bytes) / float(store_bw)


def normalize_het(spec: str | None) -> str:
    """The one spelling of "homogeneous workers" used everywhere:
    ``None`` and ``"none"`` both mean it (mirroring
    :func:`repro.core.scenarios.normalize_interconnect`)."""
    return "none" if spec is None or spec == "none" else spec


def normalize_straggler(spec: str | None) -> str:
    """``None`` and ``"none"`` both mean "no jitter"."""
    return "none" if spec is None or spec == "none" else spec


@dataclass(frozen=True)
class HetSlot:
    """One homogeneous group inside a profile: ``count`` workers at
    compute-speed multiplier ``speed`` whose links run at
    ``bw_mult`` x bandwidth and ``lat_mult`` x latency."""

    count: int
    speed: float
    bw_mult: float = 1.0
    lat_mult: float = 1.0


@dataclass(frozen=True)
class HetProfile:
    """A parsed heterogeneity profile — an ordered tuple of slots."""

    slots: tuple[HetSlot, ...]

    @property
    def n_slots(self) -> int:
        return sum(s.count for s in self.slots)

    def slot_vectors(self) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-slot ``(inv_speed, bw_mult, lat_mult)`` vectors of
        length :attr:`n_slots` (slot counts expanded)."""
        inv = np.concatenate(
            [np.full(s.count, 1.0 / s.speed) for s in self.slots])
        bw = np.concatenate(
            [np.full(s.count, s.bw_mult) for s in self.slots])
        lat = np.concatenate(
            [np.full(s.count, s.lat_mult) for s in self.slots])
        return inv, bw, lat


def _parse_slot(part: str, spec: str) -> HetSlot:
    head, sep, mods = part.partition("@")
    if sep and not mods:
        raise ValueError(
            f"malformed het slot {part!r} in {spec!r}: dangling '@'")
    count_s, sep, speed_s = head.partition("x")
    if not sep:
        raise ValueError(
            f"malformed het slot {part!r} in {spec!r}: expected "
            f"<count>x<speed>[@bw<F>][@lat<F>]")
    try:
        count = int(count_s)
        speed = float(speed_s)
    except ValueError:
        raise ValueError(
            f"malformed het slot {part!r} in {spec!r}: count must be an "
            f"int and speed a float") from None
    if count < 1:
        raise ValueError(f"het slot count must be >= 1 in {spec!r}")
    if not speed > 0:
        raise ValueError(f"het slot speed must be > 0 in {spec!r}")
    bw_mult = lat_mult = 1.0
    if mods:
        for mod in mods.split("@"):
            if mod.startswith("bw"):
                key, val_s = "bw", mod[2:]
            elif mod.startswith("lat"):
                key, val_s = "lat", mod[3:]
            else:
                raise ValueError(
                    f"malformed het modifier {mod!r} in {spec!r}: "
                    f"expected bw<F> or lat<F>")
            try:
                val = float(val_s)
            except ValueError:
                raise ValueError(
                    f"malformed het modifier {mod!r} in {spec!r}") from None
            if not val > 0:
                raise ValueError(
                    f"het modifier {mod!r} in {spec!r} must be > 0")
            if key == "bw":
                bw_mult = val
            else:
                lat_mult = val
    return HetSlot(count=count, speed=speed,
                   bw_mult=bw_mult, lat_mult=lat_mult)


def parse_het_profile(spec: str | None) -> HetProfile | None:
    """Parse a heterogeneity spec; ``None``/``"none"`` -> ``None``
    (homogeneous).  Raises ``ValueError`` with the grammar on any
    malformed spec."""
    if spec is None or spec == "none":
        return None
    if not isinstance(spec, str) or not spec.startswith(HET_PREFIX):
        raise ValueError(
            f"unknown het profile {spec!r}: expected 'none' or "
            f"'het:<count>x<speed>[@bw<F>][@lat<F>][+...]'")
    body = spec[len(HET_PREFIX):]
    if not body:
        raise ValueError(f"empty het profile {spec!r}")
    return HetProfile(tuple(_parse_slot(p, spec) for p in body.split("+")))


def validate_het(spec: str | None) -> None:
    """Raise ``ValueError`` unless ``spec`` parses (axis validation)."""
    parse_het_profile(spec)


def worker_vectors(profile: HetProfile | None,
                   n_workers: int) -> tuple[np.ndarray, np.ndarray,
                                            np.ndarray]:
    """Per-worker ``(inv_speed, bw_mult, lat_mult)`` vectors of length
    ``n_workers``: the profile's slot pattern stretched proportionally
    (worker ``i`` takes slot ``floor(i * n_slots / n_workers)``), so
    ``het:1x0.5+1x1.0`` means "the first half of the cluster is slow"
    at any size.  ``profile=None`` -> all-ones (homogeneous)."""
    n = int(n_workers)
    if profile is None:
        ones = np.ones(n)
        return ones, ones.copy(), ones.copy()
    inv, bw, lat = profile.slot_vectors()
    idx = (np.arange(n) * profile.n_slots) // n
    return inv[idx], bw[idx], lat[idx]


def worker_table_rows(pairs: Sequence[tuple[HetProfile | None, int]],
                      ) -> dict[str, np.ndarray]:
    """Padded per-worker tables for a list of ``(profile, n_workers)``
    pairs: ``(len(pairs), Wmax)`` float64 arrays ``inv_speed`` /
    ``bw_mult`` / ``lat_mult`` plus the integer ``n`` column.  Pads are
    *neutral* for :func:`repro.core.analytical.worker_bottleneck`
    (``0`` for the max-reduced columns, ``+inf`` for the min-reduced
    bandwidth column), so reducing a padded row equals reducing the
    live prefix."""
    ns = np.array([int(n) for _, n in pairs], dtype=np.int64)
    wmax = int(ns.max()) if len(ns) else 1
    rows = len(pairs)
    inv = np.zeros((rows, wmax))
    bw = np.full((rows, wmax), np.inf)
    lat = np.zeros((rows, wmax))
    for j, (prof, n) in enumerate(pairs):
        iv, bv, lv = worker_vectors(prof, n)
        inv[j, :n], bw[j, :n], lat[j, :n] = iv, bv, lv
    return {"inv_speed": inv, "bw_mult": bw, "lat_mult": lat, "n": ns}


@dataclass(frozen=True)
class StragglerSpec:
    """A parsed straggler distribution: per-worker compute-jitter
    multipliers sampled per Monte Carlo draw."""

    dist: str          # "lognormal" | "exp"
    scale: float       # sigma (lognormal) / mean excess (exp), >= 0
    draws: int         # Monte Carlo draws

    @property
    def is_deterministic(self) -> bool:
        """``scale == 0`` short-circuits to the deterministic makespan
        (every multiplier is exactly 1.0; skipping the draws keeps the
        tail columns bit-identical to ``iteration_time_s`` instead of
        within one ulp of it)."""
        return self.scale == 0.0

    def key(self, n_workers: int) -> str:
        return f"{self.dist}:{self.scale:g}x{self.draws}|w{int(n_workers)}"

    def draw_matrix(self, n_workers: int, seed: int = 0) -> np.ndarray:
        """The ``(draws, n_workers)`` jitter-multiplier matrix.  Keyed
        by ``(spec, n_workers, seed)`` only — independent of chunk
        boundaries, process sharding and backend, so every evaluation
        path consumes the identical sample (draw-for-draw)."""
        rng = np.random.default_rng(
            [int(seed) & 0x7FFFFFFFFFFFFFFF,
             zlib.crc32(self.key(n_workers).encode())])
        shape = (self.draws, int(n_workers))
        if self.dist == "lognormal":
            return np.exp(self.scale * rng.standard_normal(shape))
        return 1.0 + rng.exponential(self.scale, shape)


def parse_straggler(spec: str | None) -> StragglerSpec | None:
    """Parse a straggler spec ``<dist>:<scale>[x<draws>]``;
    ``None``/``"none"`` -> ``None`` (no jitter)."""
    if spec is None or spec == "none":
        return None
    if not isinstance(spec, str):
        raise ValueError(f"unknown straggler spec {spec!r}")
    dist, sep, rest = spec.partition(":")
    if not sep or dist not in STRAGGLER_DISTRIBUTIONS:
        raise ValueError(
            f"unknown straggler spec {spec!r}: expected "
            f"'<dist>:<scale>[x<draws>]' with dist in "
            f"{STRAGGLER_DISTRIBUTIONS}")
    scale_s, sep, draws_s = rest.partition("x")
    try:
        scale = float(scale_s)
        draws = int(draws_s) if sep else DEFAULT_DRAWS
    except ValueError:
        raise ValueError(
            f"malformed straggler spec {spec!r}: scale must be a float "
            f"and draws an int") from None
    if scale < 0:
        raise ValueError(f"straggler scale must be >= 0 in {spec!r}")
    if not 1 <= draws <= MAX_DRAWS:
        raise ValueError(
            f"straggler draws must be in [1, {MAX_DRAWS}] in {spec!r}")
    return StragglerSpec(dist=dist, scale=scale, draws=draws)


def validate_straggler(spec: str | None) -> None:
    """Raise ``ValueError`` unless ``spec`` parses (axis validation)."""
    parse_straggler(spec)


def normalize_fault(spec: str | None) -> str:
    """``None`` and ``"none"`` both mean "no faults"."""
    return "none" if spec is None or spec == "none" else spec


@dataclass(frozen=True)
class FaultSpec:
    """A parsed fault-injection spec: per-iteration, per-worker crash
    probability plus the checkpoint-restore penalty a crash costs.

    The model is additive on the update chain: restores read the
    shared checkpoint store (:func:`restart_penalty_s`), which
    serializes them, and the synchronous update cannot broadcast until
    every crashed worker has rejoined — so an iteration with ``c``
    crashes (out of ``n`` per-worker Bernoulli(``p``) trials) extends
    the GPU/update chain by exactly ``c * restart``, independent of
    the ``sync_k`` threshold (even backup workers beyond the K-th
    gradient must re-join from the checkpoint before the next
    iteration).  The penalty rides *inside* the pipeline max, so an
    I/O-bound pipeline absorbs part of it.  The event-driven oracle
    reproduces this with explicit crash/restore tasks (see
    :class:`repro.core.dag.SSGDDagBuilder`)."""

    p: float           # per-iteration per-worker crash probability
    restart: float     # checkpoint-restore penalty in seconds, >= 0
    draws: int         # Monte Carlo draws (when no straggler spec rules)

    @property
    def is_deterministic(self) -> bool:
        """``p == 0`` or ``restart == 0`` means no draw can ever add a
        penalty — skip the Monte Carlo pass and keep the tail columns
        bit-identical to ``iteration_time_s``."""
        return self.p == 0.0 or self.restart == 0.0

    def key(self, n_workers: int, draws: int | None = None) -> str:
        d = self.draws if draws is None else int(draws)
        return (f"fail:{self.p:g}@restart{self.restart:g}x{d}"
                f"|w{int(n_workers)}")

    def crash_matrix(self, n_workers: int, seed: int = 0,
                     draws: int | None = None) -> np.ndarray:
        """The ``(draws, n_workers)`` boolean crash matrix — entry
        ``[d, w]`` is True when worker ``w`` crashes in draw ``d``.
        Keyed by ``(spec, effective draws, n_workers, seed)`` only, like
        :meth:`StragglerSpec.draw_matrix`, so every backend, shard and
        chunk consumes the identical sample.  ``draws`` overrides the
        spec's own count when a straggler spec sets the Monte Carlo
        draw count for the combined pass."""
        d = self.draws if draws is None else int(draws)
        rng = np.random.default_rng(
            [int(seed) & 0x7FFFFFFFFFFFFFFF,
             zlib.crc32(self.key(n_workers, d).encode())])
        return rng.random((d, int(n_workers))) < self.p


def parse_fault(spec: str | None) -> FaultSpec | None:
    """Parse a fault spec ``fail:<p>[@restart<T>][x<draws>]``;
    ``None``/``"none"`` -> ``None`` (no faults).  ``p`` is the
    per-iteration per-worker crash probability, ``T`` the
    checkpoint-restore penalty in seconds (default
    :data:`DEFAULT_RESTART_S`), ``draws`` the Monte Carlo draw count
    (default :data:`DEFAULT_DRAWS`)."""
    if spec is None or spec == "none":
        return None
    if not isinstance(spec, str) or not spec.startswith(FAULT_PREFIX):
        raise ValueError(
            f"unknown fault spec {spec!r}: expected 'none' or "
            f"'fail:<p>[@restart<T>][x<draws>]'")
    body = spec[len(FAULT_PREFIX):]
    head, sep, mod = body.partition("@")
    restart = DEFAULT_RESTART_S
    draws_s = None
    if sep:
        if not mod.startswith("restart"):
            raise ValueError(
                f"malformed fault modifier {mod!r} in {spec!r}: "
                f"expected restart<T>")
        restart_s, xsep, tail = mod[len("restart"):].partition("x")
        if xsep:
            draws_s = tail
        try:
            restart = float(restart_s)
        except ValueError:
            raise ValueError(
                f"malformed fault modifier in {spec!r}: restart must "
                f"be a float") from None
    else:
        head, xsep, tail = head.partition("x")
        if xsep:
            draws_s = tail
    try:
        p = float(head)
        draws = int(draws_s) if draws_s is not None else DEFAULT_DRAWS
    except ValueError:
        raise ValueError(
            f"malformed fault spec {spec!r}: p must be a float and "
            f"draws an int") from None
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"fault probability must be in [0, 1] in {spec!r}")
    if restart < 0:
        raise ValueError(f"fault restart penalty must be >= 0 in {spec!r}")
    if not 1 <= draws <= MAX_DRAWS:
        raise ValueError(
            f"fault draws must be in [1, {MAX_DRAWS}] in {spec!r}")
    return FaultSpec(p=p, restart=restart, draws=draws)


def validate_fault(spec: str | None) -> None:
    """Raise ``ValueError`` unless ``spec`` parses (axis validation)."""
    parse_fault(spec)
