"""Declarative scenario grids for the S-SGD sweep engine.

A :class:`Scenario` is one fully-specified what-if question the paper's
DAG model can answer: *this* workload on *this* cluster with *this*
many workers, *this* interconnect, *this* overlap policy and *this*
all-reduce algorithm.  A :class:`ScenarioGrid` is the cross product of
axis values — the shape of study behind the paper's Figs. 2-4 (four
frameworks x two clusters x three CNNs x 1..16 GPUs) and of every
follow-up study §VII calls for.

:mod:`repro.core.sweep` evaluates grids; this module only describes
and validates them, so grids stay cheap to build, hash and diff.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from dataclasses import dataclass
from typing import Iterator, Sequence

from repro.core import het as het_mod
from repro.core.hardware import (CLUSTERS, COLLECTIVE_ALGORITHMS,
                                 INTERCONNECT_PRESETS, ClusterSpec,
                                 apply_interconnect_preset,
                                 resolve_interconnect_preset)
from repro.core.policies import ALL_POLICIES, Policy, get_policy
from repro.core.workloads import validate_workload


def normalize_interconnect(interconnect: str | None) -> str:
    """The one spelling of "cluster default links" used everywhere:
    ``None`` and ``"default"`` both mean it, and rows/labels/filters all
    go through this normalizer so they can never disagree."""
    return "default" if interconnect is None else interconnect


def normalize_sync_k(sync_k: int | None) -> int:
    """The one spelling of "full synchronization" used everywhere:
    ``None``/``0``/``"none"`` all mean it and normalize to ``0``; a
    positive K means "sync with the first K of N gradients" (backup
    workers).  The effective threshold is clamped to the scenario's
    worker count at evaluation time
    (:func:`repro.core.analytical.effective_sync_k`), which keeps
    grid-axis validation separable from the worker-count axis."""
    if sync_k is None or sync_k == "none" or sync_k == 0:
        return 0
    return int(sync_k)


def validate_sync_k(sync_k: int | None) -> None:
    """Raise ``ValueError`` unless ``sync_k`` is a full-sync sentinel
    (``None``/``0``/``"none"``) or a positive int."""
    if sync_k is None or sync_k == "none":
        return
    try:
        k = int(sync_k)
    except (TypeError, ValueError):
        raise ValueError(
            f"sync_k must be 'none' or a positive int, got {sync_k!r}"
        ) from None
    if k < 0:
        raise ValueError(f"sync_k must be >= 0 (0 = full sync), got {k}")


def validate_interconnect(interconnect: str | None) -> None:
    """Raise ``ValueError`` unless ``interconnect`` is ``None``,
    ``"default"``, a preset name, or a scaled preset
    (``<base>@bw<F>@lat<F>``)."""
    if interconnect is None or interconnect == "default":
        return
    try:
        resolve_interconnect_preset(interconnect)
    except (KeyError, ValueError) as e:
        raise ValueError(
            f"unknown interconnect preset {interconnect!r}: {e}; one of "
            f"{sorted(INTERCONNECT_PRESETS)} (optionally with @bw<F>/"
            f"@lat<F> modifiers) or None") from None


@dataclass(frozen=True)
class Scenario:
    """One point of the sweep: a fully-resolved what-if question.

    ``workload`` is any name the workload registry resolves
    (:func:`repro.core.workloads.resolve_workload`): a bare Table-IV
    CNN name, ``cnn:<name>``, ``trace:<bundled-or-path>`` or
    ``llm:<arch>``.  ``interconnect`` is ``None`` (cluster default) or
    a preset name from
    :data:`repro.core.hardware.INTERCONNECT_PRESETS`; ``batch_per_gpu``
    ``None`` means the workload's default (Table IV for CNNs, the
    measured batch for traces, one sequence for LLM configs).
    """

    workload: str
    cluster: str
    n_workers: int
    policy: str
    collective: str = "ring"
    interconnect: str | None = None
    het: str | None = None
    straggler: str | None = None
    sync_k: int | None = None
    faults: str | None = None
    batch_per_gpu: int | None = None

    def label(self) -> str:
        ic = normalize_interconnect(self.interconnect)
        label = (f"{self.workload}/{self.cluster}/w{self.n_workers}"
                 f"/{self.policy}/{self.collective}/{ic}")
        if self.het is not None and self.het != "none":
            label += f"/{self.het}"
        if self.straggler is not None and self.straggler != "none":
            label += f"/{self.straggler}"
        if normalize_sync_k(self.sync_k):
            label += f"/k{normalize_sync_k(self.sync_k)}"
        if self.faults is not None and self.faults != "none":
            label += f"/{self.faults}"
        return label

    def validate(self) -> None:
        validate_workload(self.workload)     # any registered provider
        if self.cluster not in CLUSTERS:
            raise ValueError(f"unknown cluster {self.cluster!r}; "
                             f"one of {sorted(CLUSTERS)}")
        if self.n_workers < 1:
            raise ValueError(f"n_workers must be >= 1, got {self.n_workers}")
        if self.policy not in ALL_POLICIES:
            raise ValueError(f"unknown policy {self.policy!r}; "
                             f"one of {sorted(ALL_POLICIES)}")
        if self.collective not in COLLECTIVE_ALGORITHMS:
            raise ValueError(f"unknown collective {self.collective!r}; "
                             f"one of {COLLECTIVE_ALGORITHMS}")
        validate_interconnect(self.interconnect)
        try:
            het_mod.validate_het(self.het)
            het_mod.validate_straggler(self.straggler)
            het_mod.validate_fault(self.faults)
            validate_sync_k(self.sync_k)
        except ValueError as e:
            raise ValueError(str(e)) from None
        if self.batch_per_gpu is not None and self.batch_per_gpu < 1:
            raise ValueError(f"batch_per_gpu must be >= 1, "
                             f"got {self.batch_per_gpu}")


def apply_het_links(cluster: ClusterSpec, bw_mult: float,
                    lat_mult: float) -> ClusterSpec:
    """A copy of ``cluster`` with both links scaled by the slowest-
    worker link multipliers of a heterogeneity profile (the per-worker
    vectors reduce to ``min bw`` / ``max lat`` first — see
    :func:`repro.core.analytical.worker_bottleneck`).  Identity
    multipliers return the cluster untouched, keeping homogeneous
    scenarios bit-identical."""
    if bw_mult == 1.0 and lat_mult == 1.0:
        return cluster
    return dataclasses.replace(
        cluster,
        intra=cluster.intra.scaled(bw_mult, lat_mult),
        inter=cluster.inter.scaled(bw_mult, lat_mult))


def resolve_cluster(scenario: Scenario) -> ClusterSpec:
    """Concrete :class:`ClusterSpec` for a scenario: the named base
    cluster resized to hold ``n_workers`` devices (whole nodes of
    ``gpus_per_node``, like the paper's 1/2/4-node testbeds) with the
    interconnect preset applied, and — when the scenario carries a
    heterogeneity profile — the links derated to the slowest worker's
    multipliers."""
    base = CLUSTERS[scenario.cluster]
    n_nodes = max(1, math.ceil(scenario.n_workers / base.gpus_per_node))
    cluster = base.with_workers(n_nodes=n_nodes)
    cluster = apply_interconnect_preset(cluster, scenario.interconnect)
    profile = het_mod.parse_het_profile(scenario.het)
    if profile is not None:
        _, bw, lat = het_mod.worker_vectors(profile, scenario.n_workers)
        cluster = apply_het_links(cluster, float(bw.min()), float(lat.max()))
    return cluster


def resolve_policy(scenario: Scenario) -> Policy:
    return get_policy(scenario.policy)


@dataclass(frozen=True)
class ScenarioGrid:
    """Cross product of sweep axes; ``expand()`` yields the scenarios.

    Every axis value is validated eagerly at expansion so a typo'd
    policy name fails before the first evaluation, not after thousands.
    """

    workloads: Sequence[str] = ("alexnet", "googlenet", "resnet50")
    clusters: Sequence[str] = ("k80-pcie-10gbe", "v100-nvlink-ib")
    worker_counts: Sequence[int] = (1, 2, 4, 8, 16)
    policies: Sequence[str] = ("naive", "cntk", "mxnet", "tensorflow",
                               "caffe-mpi")
    collectives: Sequence[str] = ("ring",)
    interconnects: Sequence[str | None] = (None,)
    het_profiles: Sequence[str | None] = (None,)
    stragglers: Sequence[str | None] = (None,)
    sync_ks: Sequence[int | None] = (None,)
    faults: Sequence[str | None] = (None,)
    batch_per_gpu: int | None = None

    def __len__(self) -> int:
        return (len(self.workloads) * len(self.clusters)
                * len(self.worker_counts) * len(self.policies)
                * len(self.collectives) * len(self.interconnects)
                * len(self.het_profiles) * len(self.stragglers)
                * len(self.sync_ks) * len(self.faults))

    def __iter__(self) -> Iterator[Scenario]:
        return iter(self.expand())

    def validate_axes(self) -> None:
        """Validate every axis *value* once.  Scenario validity is
        axis-separable (no cross-field constraints), so this is
        equivalent to validating all ``len(self)`` scenarios — which is
        exactly why ``expand()`` can skip per-scenario validation."""
        if self.batch_per_gpu is not None and self.batch_per_gpu < 1:
            raise ValueError(f"batch_per_gpu must be >= 1, "
                             f"got {self.batch_per_gpu}")
        for wl in self.workloads:
            validate_workload(wl)
        for cl in self.clusters:
            if cl not in CLUSTERS:
                raise ValueError(f"unknown cluster {cl!r}; "
                                 f"one of {sorted(CLUSTERS)}")
        for n in self.worker_counts:
            if int(n) < 1:
                raise ValueError(f"n_workers must be >= 1, got {n}")
        for pol in self.policies:
            if pol not in ALL_POLICIES:
                raise ValueError(f"unknown policy {pol!r}; "
                                 f"one of {sorted(ALL_POLICIES)}")
        for coll in self.collectives:
            if coll not in COLLECTIVE_ALGORITHMS:
                raise ValueError(f"unknown collective {coll!r}; "
                                 f"one of {COLLECTIVE_ALGORITHMS}")
        for ic in self.interconnects:
            validate_interconnect(ic)
        for h in self.het_profiles:
            het_mod.validate_het(h)
        for st in self.stragglers:
            het_mod.validate_straggler(st)
        for k in self.sync_ks:
            validate_sync_k(k)
        for f in self.faults:
            het_mod.validate_fault(f)

    def expand(self) -> list[Scenario]:
        self.validate_axes()
        return [Scenario(workload=wl, cluster=cl, n_workers=int(n),
                         policy=pol, collective=coll, interconnect=ic,
                         het=h, straggler=st, sync_k=sk, faults=fl,
                         batch_per_gpu=self.batch_per_gpu)
                for wl, cl, n, pol, coll, ic, h, st, sk, fl
                in itertools.product(
                    self.workloads, self.clusters, self.worker_counts,
                    self.policies, self.collectives, self.interconnects,
                    self.het_profiles, self.stragglers, self.sync_ks,
                    self.faults)]

    def scenario_at(self, i: int) -> Scenario:
        """Materialize the scenario at flat ``expand()`` index ``i``
        (rightmost axis fastest) without expanding the grid — how the
        batched/parallel paths recover the few simulator-fallback
        scenarios of an otherwise fully batched grid."""
        codes = []
        for axis in (self.faults, self.sync_ks, self.stragglers,
                     self.het_profiles, self.interconnects,
                     self.collectives, self.policies, self.worker_counts,
                     self.clusters, self.workloads):
            i, c = divmod(i, len(axis))
            codes.append(c)
        fi, qi, sti, hi, ii, ai, pi, ki, ci, wi = codes
        return Scenario(workload=self.workloads[wi],
                        cluster=self.clusters[ci],
                        n_workers=int(self.worker_counts[ki]),
                        policy=self.policies[pi],
                        collective=self.collectives[ai],
                        interconnect=self.interconnects[ii],
                        het=self.het_profiles[hi],
                        straggler=self.stragglers[sti],
                        sync_k=self.sync_ks[qi],
                        faults=self.faults[fi],
                        batch_per_gpu=self.batch_per_gpu)

def default_grid() -> ScenarioGrid:
    """The out-of-the-box study: every paper workload and cluster, six
    cluster sizes, the five exactly-solvable policies, and all three
    collective algorithms — 540 scenarios, all on the analytical fast
    path."""
    return ScenarioGrid(
        worker_counts=(1, 2, 4, 8, 16, 32),
        collectives=COLLECTIVE_ALGORITHMS,
    )


def mixed_grid() -> ScenarioGrid:
    """A cross-provider study on the same closed-form fast path: one
    Table-IV CNN, the bundled Table-VI measured trace, and three
    modern LLM configs (dense / MoE / recurrent), over both paper
    clusters and the TPU pod, six sizes, five exact policies and all
    three collectives — 1620 scenarios."""
    return ScenarioGrid(
        workloads=("cnn:resnet50", "trace:alexnet-k80",
                   "llm:gemma3-1b", "llm:qwen2-moe-a2.7b",
                   "llm:recurrentgemma-2b", "llm:qwen1.5-32b"),
        clusters=("k80-pcie-10gbe", "v100-nvlink-ib", "tpu-v5e-pod"),
        worker_counts=(1, 2, 4, 8, 16, 32),
        collectives=COLLECTIVE_ALGORITHMS,
    )


#: Frontier-grid what-if axes: inter-node link bases (``ib-100g-fused``
#: is the DDP-style bucket-fusion what-if — the collective efficiency a
#: fused gradient stream achieves, on the exact fast path) crossed with
#: bandwidth and latency scale factors via the scaled-preset grammar.
FRONTIER_LINK_BASES = ("10gbe", "ib-100g", "ib-100g-fused", "ib-200g")
FRONTIER_BW_FACTORS = (0.5, 1, 2, 4)
FRONTIER_LAT_FACTORS = (0.25, 1, 4)

#: Frontier policy axis: the five per-layer-exact policies plus the
#: schedule-dependent ones the bucket-timeline kernel made sweepable —
#: the bucket-size axis (1/4/25/100 MB) and priority scheduling.
FRONTIER_POLICIES = ("naive", "cntk", "mxnet", "tensorflow", "caffe-mpi",
                     "bucketed-1mb", "bucketed-4mb", "bucketed-25mb",
                     "bucketed-100mb", "priority")


#: Named base grids a declarative spec (or the CLI's ``--grid`` flag)
#: starts from — populated after the factory definitions below.
BASE_GRIDS: dict = {}

#: Axis keys :func:`grid_from_spec` understands besides ``"grid"`` —
#: the wire vocabulary shared by the sweep CLI's flags and the sweep
#: service's query documents.
GRID_SPEC_KEYS = ("workloads", "clusters", "workers", "policies",
                  "collectives", "interconnects", "het", "stragglers",
                  "sync_k", "faults", "batch_per_gpu")


def _spec_values(value, key: str) -> list:
    """Axis values from a spec entry: a JSON list or a comma-separated
    string (the CLI's flag format), never empty — an empty axis would
    make a zero-scenario grid, which no caller ever means."""
    if isinstance(value, str):
        vals = [t.strip() for t in value.split(",") if t.strip()]
    elif isinstance(value, (list, tuple)):
        vals = list(value)
    else:
        raise ValueError(
            f"{key} must be a list or a comma-separated string, "
            f"got {value!r}")
    if not vals:
        raise ValueError(f"{key} must have at least one value "
                         f"(an empty axis makes a zero-scenario grid)")
    return vals


def _spec_synck(k):
    validate_sync_k(k)
    return None if k in (None, "none", 0, "0") else int(k)


def grid_from_spec(spec: dict) -> ScenarioGrid:
    """A validated :class:`ScenarioGrid` from a declarative spec dict —
    the **one** parser behind both front doors: the sweep CLI's axis
    flags (:func:`repro.launch.sweep.grid_from_args`) and the sweep
    service's JSON query documents
    (:func:`repro.core.service.parse_query`), so a spec the CLI exits 2
    on is exactly one the server rejects with a structured error.

    Keys: ``"grid"`` names a base grid (:data:`BASE_GRIDS`, default
    ``"default"``); each :data:`GRID_SPEC_KEYS` entry overrides one
    axis (values: JSON lists or comma-separated strings).  ``"none"``
    spells the null value on the nullable axes (het / stragglers /
    sync_k / faults), ``"default"`` the cluster-default interconnect.
    Unknown keys and invalid axis values raise ``ValueError`` naming
    the alternatives; the returned grid has passed ``validate_axes()``.
    """
    if not isinstance(spec, dict):
        raise ValueError(f"grid spec must be a mapping (JSON object), "
                         f"got {type(spec).__name__}")
    unknown = set(spec) - set(GRID_SPEC_KEYS) - {"grid"}
    if unknown:
        raise ValueError(
            f"unknown grid-spec keys {sorted(unknown)}; known keys: "
            f"grid, {', '.join(GRID_SPEC_KEYS)}")
    name = spec.get("grid", "default")
    base_fn = BASE_GRIDS.get(name) if isinstance(name, str) else None
    if base_fn is None:
        raise ValueError(f"unknown base grid {name!r}; "
                         f"one of {sorted(BASE_GRIDS)}")
    axes: dict = {}
    for key, axis, conv in (
            ("workloads", "workloads", str),
            ("clusters", "clusters", str),
            ("workers", "worker_counts", int),
            ("policies", "policies", str),
            ("collectives", "collectives", str),
            ("interconnects", "interconnects",
             lambda i: None if i in (None, "default") else str(i)),
            ("het", "het_profiles",
             lambda h: None if h in (None, "none") else str(h)),
            ("stragglers", "stragglers",
             lambda s: None if s in (None, "none") else str(s)),
            ("sync_k", "sync_ks", _spec_synck),
            ("faults", "faults",
             lambda f: None if f in (None, "none") else str(f))):
        if spec.get(key) is None:
            continue
        try:
            axes[axis] = tuple(conv(v) for v in _spec_values(spec[key], key))
        except ValueError as e:
            raise ValueError(f"bad {key} value: {e}") from None
    if spec.get("batch_per_gpu") is not None:
        try:
            axes["batch_per_gpu"] = int(spec["batch_per_gpu"])
        except (TypeError, ValueError):
            raise ValueError(f"batch_per_gpu must be an integer, "
                             f"got {spec['batch_per_gpu']!r}") from None
    grid = dataclasses.replace(base_fn(), **axes)
    grid.validate_axes()
    return grid


def frontier_grid() -> ScenarioGrid:
    """The §VII design-space study at interactive scale: every paper CNN
    on both paper clusters, six cluster sizes, all three collectives,
    ten policies — the five exact ones **plus** the bucket-size axis
    (1/4/25/100 MB gradient fusion) and priority comm, both on the
    batched bucket-timeline path — and a ``bandwidth x latency x
    bucket-fusion`` interconnect frontier (four inter-node link bases,
    each at {0.5,1,2,4}x bandwidth and {0.25,1,4}x latency via the
    scaled-preset grammar) — 51 840 scenarios, every one batched.
    This is exactly the what-if study the paper's future-work section
    asks for (which bucket size rescues InfiniBand utilization, and at
    what link speed does fusion stop mattering?); the batched evaluator
    answers it in tens of milliseconds."""
    interconnects = tuple(
        f"{base}@bw{bw:g}@lat{lat:g}"
        for base in FRONTIER_LINK_BASES
        for bw in FRONTIER_BW_FACTORS
        for lat in FRONTIER_LAT_FACTORS)
    return ScenarioGrid(
        worker_counts=(2, 4, 8, 16, 32, 64),
        policies=FRONTIER_POLICIES,
        collectives=COLLECTIVE_ALGORITHMS,
        interconnects=interconnects,
    )


BASE_GRIDS.update(default=default_grid, mixed=mixed_grid,
                  frontier=frontier_grid)
