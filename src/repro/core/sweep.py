"""Batched scenario-sweep engine for the S-SGD DAG model.

Evaluates a :class:`repro.core.scenarios.ScenarioGrid` — thousands of
``(workload x cluster x workers x interconnect x policy x collective)``
combinations — in one call, two ways:

* **Analytical fast path** (the default for every policy whose closed
  form is exact — see :func:`repro.core.analytical.has_closed_form`):
  the per-layer cost model is evaluated as NumPy arrays over the layer
  dimension (workload tables resolved through the pluggable registry
  of :mod:`repro.core.workloads` — ``cnn:``/``trace:``/``llm:`` — and
  memoized at module scope, shared across every scenario and every
  call) and fed straight into the shared closed forms of
  :mod:`repro.core.analytical`; each scenario costs microseconds.
* **Event-driven fallback** for policies whose steady state depends on
  the schedule itself (gradient-bucket fusion, priority comm): the
  Fig.-1 DAG is built and list-scheduled via
  :func:`repro.core.simulator.simulate_steady`.

The property tests assert the two paths agree to <= 1e-6 relative on
every policy with an exact closed form.
"""
from __future__ import annotations

import csv
import json
import time
from dataclasses import dataclass, replace
from typing import Iterable, Sequence

import numpy as np

from repro.core import analytical
from repro.core.costmodel import comm_scale_fn
from repro.core.policies import Policy
from repro.core.scenarios import (Scenario, ScenarioGrid, resolve_cluster,
                                  resolve_policy)
from repro.core.simulator import simulate_steady
from repro.core.workloads import WorkloadTable, resolve_workload

#: Column order of the tidy results table.
COLUMNS = ("workload", "cluster", "n_workers", "policy", "collective",
           "interconnect", "batch_per_gpu", "iteration_time_s",
           "samples_per_sec", "speedup", "t_comm_s", "t_comp_s",
           "method")


def has_fast_path(policy: Policy) -> bool:
    """True when the policy's steady state has an exact closed form
    (delegates to the single source of truth,
    :func:`repro.core.analytical.has_closed_form`)."""
    return analytical.has_closed_form(policy)


def _scenario_costs(s: Scenario, tab: WorkloadTable):
    """(costs, cluster, policy, batch) for one scenario, through the
    single construction path every workload provider shares
    (:meth:`repro.core.workloads.WorkloadTable.iteration_costs`)."""
    cluster = resolve_cluster(s)
    policy = resolve_policy(s)
    batch = s.batch_per_gpu or tab.batch_default
    costs = tab.iteration_costs(cluster, batch, s.n_workers, s.collective)
    return costs, cluster, policy, batch


def _fast_eval(s: Scenario) -> dict:
    """Analytical fast path: one scenario, NumPy arrays over the layer
    dimension fed straight into the shared closed forms (the scalar
    equations in :mod:`repro.core.analytical` are pure arithmetic over
    sequences, so array-valued ``IterationCosts`` evaluate directly —
    no parallel formula implementation to keep in lockstep)."""
    costs, _, policy, batch = _scenario_costs(s, resolve_workload(s.workload))
    t_iter = float(analytical.closed_form(costs, policy))
    t1 = float(analytical.closed_form(
        costs.with_comm(np.zeros_like(costs.t_f)), policy))
    return _row(s, batch, t_iter, t1, float(np.sum(costs.t_c)),
                float(np.sum(costs.t_f) + np.sum(costs.t_b)), "analytical")


def _sim_eval(s: Scenario, warm_iterations: int = 6) -> dict:
    """Event-driven fallback: build the Fig.-1 DAG and list-schedule."""
    tab = resolve_workload(s.workload)
    costs, cluster, policy, batch = _scenario_costs(s, tab)
    comm_scale = comm_scale_fn(cluster, s.n_workers, s.collective) \
        if policy.bucket_bytes else None
    t_iter = simulate_steady(costs, s.n_workers, policy,
                             n_iterations=warm_iterations,
                             comm_scale=comm_scale)
    # weak-scaling baseline: same pipeline, one worker, no comm
    base_policy = replace(policy, bucket_bytes=None, priority_comm=False)
    c1 = costs.with_comm([0.0] * costs.num_layers)
    t1 = analytical.closed_form(c1, base_policy)
    if t1 is None:                                    # pragma: no cover
        t1 = simulate_steady(c1, 1, base_policy, n_iterations=warm_iterations)
    return _row(s, batch, t_iter, t1, float(np.sum(costs.t_c)),
                float(np.sum(costs.t_f) + np.sum(costs.t_b)), "simulated")


def _row(s: Scenario, batch: int, t_iter: float, t1: float, t_comm: float,
         t_comp: float, method: str) -> dict:
    return {
        "workload": s.workload,
        "cluster": s.cluster,
        "n_workers": s.n_workers,
        "policy": s.policy,
        "collective": s.collective,
        "interconnect": s.interconnect or "default",
        "batch_per_gpu": batch,
        "iteration_time_s": t_iter,
        "samples_per_sec": s.n_workers * batch / t_iter if t_iter else 0.0,
        "speedup": s.n_workers * t1 / t_iter if t_iter else float(s.n_workers),
        "t_comm_s": t_comm,
        "t_comp_s": t_comp,
        "method": method,
    }


@dataclass
class SweepResult:
    """Tidy results table: one dict per scenario, :data:`COLUMNS` keys."""

    rows: list[dict]
    elapsed_s: float
    n_analytical: int
    n_simulated: int

    def __len__(self) -> int:
        return len(self.rows)

    def sorted_by(self, column: str, reverse: bool = True) -> list[dict]:
        return sorted(self.rows, key=lambda r: r[column], reverse=reverse)

    def filter(self, **eq) -> list[dict]:
        """Rows matching all ``column=value`` pairs."""
        return [r for r in self.rows
                if all(r[k] == v for k, v in eq.items())]

    def to_csv(self, path) -> None:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=COLUMNS)
            w.writeheader()
            w.writerows(self.rows)

    def to_json(self, path=None, indent: int | None = 2) -> str:
        """The full result as a JSON document (and optionally write it
        to ``path``): sweep metadata plus the tidy rows."""
        doc = {
            "columns": list(COLUMNS),
            "n_scenarios": len(self.rows),
            "elapsed_s": self.elapsed_s,
            "n_analytical": self.n_analytical,
            "n_simulated": self.n_simulated,
            "rows": self.rows,
        }
        text = json.dumps(doc, indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_dataframe(self):
        """Results as a pandas DataFrame (pandas is optional)."""
        import pandas as pd

        return pd.DataFrame(self.rows, columns=COLUMNS)

    def format_table(self, rows: Sequence[dict] | None = None,
                     limit: int | None = None) -> str:
        rows = self.rows if rows is None else list(rows)
        if limit is not None:
            rows = rows[:limit]
        # wide enough for provider-prefixed names (llm:qwen2-moe-a2.7b)
        header = (f"{'workload':22s} {'cluster':16s} {'wk':>3s} "
                  f"{'policy':13s} {'coll':12s} {'interconn':12s} "
                  f"{'iter_ms':>9s} {'samp/s':>10s} {'speedup':>7s} {'m':>2s}")
        lines = [header, "-" * len(header)]
        for r in rows:
            lines.append(
                f"{r['workload']:22s} {r['cluster']:16s} "
                f"{r['n_workers']:3d} {r['policy']:13s} "
                f"{r['collective']:12s} {r['interconnect']:12s} "
                f"{r['iteration_time_s'] * 1e3:9.2f} "
                f"{r['samples_per_sec']:10.0f} {r['speedup']:7.2f} "
                f"{r['method'][:1]:>2s}")
        return "\n".join(lines)


def sweep(grid: ScenarioGrid | Iterable[Scenario], *,
          force_simulator: bool = False,
          warm_iterations: int = 6) -> SweepResult:
    """Evaluate every scenario of ``grid`` and return the tidy table.

    ``force_simulator=True`` routes *all* scenarios through the
    event-driven simulator — used by the agreement tests and for
    studying schedules the closed forms cannot express.
    """
    scenarios = grid.expand() if isinstance(grid, ScenarioGrid) \
        else list(grid)
    t0 = time.perf_counter()
    rows: list[dict] = []
    n_fast = n_slow = 0
    for s in scenarios:
        s.validate()
        if not force_simulator and has_fast_path(resolve_policy(s)):
            rows.append(_fast_eval(s))     # tables memoized in the registry
            n_fast += 1
        else:
            rows.append(_sim_eval(s, warm_iterations))
            n_slow += 1
    return SweepResult(rows=rows, elapsed_s=time.perf_counter() - t0,
                       n_analytical=n_fast, n_simulated=n_slow)


def evaluate_scenario(s: Scenario, method: str = "auto",
                      warm_iterations: int = 6) -> dict:
    """Evaluate one scenario; ``method`` is ``auto`` (fast path when
    exact), ``analytical`` (raise if inexact) or ``simulator``."""
    s.validate()
    policy = resolve_policy(s)
    if method == "simulator":
        return _sim_eval(s, warm_iterations)
    if method == "analytical":
        if not has_fast_path(policy):
            raise ValueError(f"policy {s.policy!r} has no exact closed form")
        return _fast_eval(s)
    if method != "auto":
        raise ValueError(f"unknown method {method!r}")
    if has_fast_path(policy):
        return _fast_eval(s)
    return _sim_eval(s, warm_iterations)
