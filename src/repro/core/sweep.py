"""Batched scenario-sweep engine for the S-SGD DAG model.

Evaluates a :class:`repro.core.scenarios.ScenarioGrid` — thousands of
``(workload x cluster x workers x interconnect x policy x collective)``
combinations — in one call, two ways:

* **Batched analytical fast path** (the default for every policy
  whose closed form is exact — see
  :func:`repro.core.analytical.has_closed_form`): the scenario-axis
  batched kernel of :mod:`repro.core.batched` evaluates the whole
  grid as ``(scenario x layer)`` matrices (workload tables resolved
  through the pluggable registry of :mod:`repro.core.workloads` —
  ``cnn:``/``trace:``/``llm:`` — and memoized at module scope);
  hundreds of thousands of scenarios per second.  The per-scenario
  :func:`_fast_eval` stays as the reference implementation — the two
  agree to <= 1e-9 relative (property-tested), and ``batched=False``
  pins a sweep to it.
* **Batched bucket-timeline path** for the schedule-dependent policies
  (gradient-bucket fusion, priority comm): their steady state is
  exactly the bucket-timeline form (:mod:`repro.core.bucketsim`), so
  the same kernel evaluates them as padded ``(scenario x bucket)``
  matrices — no Python DAG objects, no list scheduler.  Rows carry
  ``method="timeline"``.
* **Event-driven fallback** for policies with neither form, and for
  ``force_simulator=True`` (the agreement oracle): the Fig.-1 DAG is
  built and list-scheduled via
  :func:`repro.core.simulator.simulate_steady`.

``backend="jax"`` swaps the batched engine for the jit/vmap-compiled
kernels of :mod:`repro.core.batched_jax` (same two tiers through XLA,
float64, <= 1e-6 agreement with the NumPy oracle, property-tested).
NumPy stays the default and the reference: the jax backend never
falls back silently — combinations that would need the per-scenario
reference paths (``batched=False``), the event-driven simulator
(``force_simulator=True``) or a grid with simulator-only policies
raise ``ValueError`` instead.

The property tests assert the analytical and simulator paths agree to
<= 1e-6 relative on every policy with an exact closed form, and the
timeline path to <= 1e-6 against the simulator on the bucketed and
priority policies.  For grids too big to buffer, :func:`iter_rows` /
:func:`stream_csv` / :func:`stream_json` evaluate lazily chunk by
chunk.
"""
from __future__ import annotations

import csv
import json
import time
from dataclasses import dataclass, replace
from typing import Iterable, Iterator, Sequence

import numpy as np

from repro.core import analytical
from repro.core.batched import eval_scenarios, grid_evaluator
from repro.core.costmodel import comm_scale_fn
from repro.core.policies import Policy
from repro.core.scenarios import (Scenario, ScenarioGrid,
                                  normalize_interconnect, resolve_cluster,
                                  resolve_policy)
from repro.core.simulator import simulate_steady
from repro.core.workloads import WorkloadTable, resolve_workload

#: Column order of the tidy results table.
COLUMNS = ("workload", "cluster", "n_workers", "policy", "collective",
           "interconnect", "batch_per_gpu", "iteration_time_s",
           "samples_per_sec", "speedup", "t_comm_s", "t_comp_s",
           "method")


def has_fast_path(policy: Policy) -> bool:
    """True when the policy's steady state has an exact closed form
    (delegates to the single source of truth,
    :func:`repro.core.analytical.has_closed_form`)."""
    return analytical.has_closed_form(policy)


def has_batched_path(policy: Policy) -> bool:
    """True when the policy can be evaluated by the batched kernel at
    all: an exact per-layer closed form (``method="analytical"``) or
    the bucket-timeline form (``method="timeline"``).  Everything else
    — and every scenario under ``force_simulator=True`` — goes through
    the event-driven simulator."""
    return analytical.has_closed_form(policy) \
        or analytical.has_timeline_form(policy)


def _scenario_costs(s: Scenario, tab: WorkloadTable):
    """(costs, cluster, policy, batch) for one scenario, through the
    single construction path every workload provider shares
    (:meth:`repro.core.workloads.WorkloadTable.iteration_costs`)."""
    cluster = resolve_cluster(s)
    policy = resolve_policy(s)
    batch = s.batch_per_gpu or tab.batch_default
    costs = tab.iteration_costs(cluster, batch, s.n_workers, s.collective)
    return costs, cluster, policy, batch


def _fast_eval(s: Scenario) -> dict:
    """Per-scenario analytical path: NumPy arrays over the layer
    dimension fed straight into the shared closed forms (the scalar
    equations in :mod:`repro.core.analytical` are pure arithmetic over
    sequences, so array-valued ``IterationCosts`` evaluate directly —
    no parallel formula implementation to keep in lockstep).

    This is the **reference implementation and agreement oracle** for
    the scenario-axis batched kernel (:mod:`repro.core.batched`), which
    is what :func:`sweep` actually routes closed-form scenarios
    through; the property tests pin the two to <= 1e-9 relative."""
    costs, _, policy, batch = _scenario_costs(s, resolve_workload(s.workload))
    t_iter = float(analytical.closed_form(costs, policy))
    t1 = float(analytical.closed_form(
        costs.with_comm(np.zeros_like(costs.t_f)), policy))
    return _row(s, batch, t_iter, t1, float(np.sum(costs.t_c)),
                float(np.sum(costs.t_f) + np.sum(costs.t_b)), "analytical")


def _sim_eval(s: Scenario, warm_iterations: int = 6) -> dict:
    """Event-driven fallback: build the Fig.-1 DAG and list-schedule."""
    tab = resolve_workload(s.workload)
    costs, cluster, policy, batch = _scenario_costs(s, tab)
    comm_scale = comm_scale_fn(cluster, s.n_workers, s.collective) \
        if policy.bucket_bytes else None
    t_iter = simulate_steady(costs, s.n_workers, policy,
                             n_iterations=warm_iterations,
                             comm_scale=comm_scale)
    # weak-scaling baseline: same pipeline, one worker, no comm
    base_policy = replace(policy, bucket_bytes=None, priority_comm=False)
    c1 = costs.with_comm([0.0] * costs.num_layers)
    t1 = analytical.closed_form(c1, base_policy)
    if t1 is None:                                    # pragma: no cover
        t1 = simulate_steady(c1, 1, base_policy, n_iterations=warm_iterations)
    return _row(s, batch, t_iter, t1, float(np.sum(costs.t_c)),
                float(np.sum(costs.t_f) + np.sum(costs.t_b)), "simulated")


def _row(s: Scenario, batch: int, t_iter: float, t1: float, t_comm: float,
         t_comp: float, method: str) -> dict:
    return {
        "workload": s.workload,
        "cluster": s.cluster,
        "n_workers": s.n_workers,
        "policy": s.policy,
        "collective": s.collective,
        "interconnect": normalize_interconnect(s.interconnect),
        "batch_per_gpu": batch,
        "iteration_time_s": t_iter,
        "samples_per_sec": s.n_workers * batch / t_iter if t_iter else 0.0,
        "speedup": s.n_workers * t1 / t_iter if t_iter else float(s.n_workers),
        "t_comm_s": t_comm,
        "t_comp_s": t_comp,
        "method": method,
    }


@dataclass
class SweepResult:
    """Tidy results table: one dict per scenario, :data:`COLUMNS` keys.

    ``n_analytical`` counts closed-form batched rows, ``n_timeline``
    bucket-timeline batched rows, ``n_simulated`` event-driven
    fallback rows — the three evaluation paths of :func:`sweep`.
    ``backend`` records which batched engine produced the rows
    (``"numpy"`` or ``"jax"``).
    """

    rows: list[dict]
    elapsed_s: float
    n_analytical: int
    n_simulated: int
    n_timeline: int = 0
    backend: str = "numpy"

    def __len__(self) -> int:
        return len(self.rows)

    def sorted_by(self, column: str, reverse: bool = True) -> list[dict]:
        return sorted(self.rows, key=lambda r: r[column], reverse=reverse)

    def filter(self, **eq) -> list[dict]:
        """Rows matching all ``column=value`` pairs.

        ``interconnect`` accepts both spellings of "cluster default":
        ``None`` and ``"default"`` (rows always store the normalized
        form, via the same normalizer as ``Scenario.label()``).
        """
        if "interconnect" in eq:
            eq["interconnect"] = normalize_interconnect(eq["interconnect"])
        return [r for r in self.rows
                if all(r[k] == v for k, v in eq.items())]

    def to_csv(self, path) -> None:
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=COLUMNS)
            w.writeheader()
            w.writerows(self.rows)

    def to_json(self, path=None, indent: int | None = 2) -> str:
        """The full result as a JSON document (and optionally write it
        to ``path``): sweep metadata plus the tidy rows."""
        doc = {
            "columns": list(COLUMNS),
            "n_scenarios": len(self.rows),
            "elapsed_s": self.elapsed_s,
            "n_analytical": self.n_analytical,
            "n_timeline": self.n_timeline,
            "n_simulated": self.n_simulated,
            "backend": self.backend,
            "rows": self.rows,
        }
        text = json.dumps(doc, indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_dataframe(self):
        """Results as a pandas DataFrame (pandas is optional)."""
        import pandas as pd

        return pd.DataFrame(self.rows, columns=COLUMNS)

    def format_table(self, rows: Sequence[dict] | None = None,
                     limit: int | None = None) -> str:
        rows = self.rows if rows is None else list(rows)
        if limit is not None:
            rows = rows[:limit]
        # wide enough for provider-prefixed names (llm:qwen2-moe-a2.7b)
        header = (f"{'workload':22s} {'cluster':16s} {'wk':>3s} "
                  f"{'policy':13s} {'coll':12s} {'interconn':12s} "
                  f"{'iter_ms':>9s} {'samp/s':>10s} {'speedup':>7s} {'m':>2s}")
        lines = [header, "-" * len(header)]
        for r in rows:
            lines.append(
                f"{r['workload']:22s} {r['cluster']:16s} "
                f"{r['n_workers']:3d} {r['policy']:13s} "
                f"{r['collective']:12s} {r['interconnect']:12s} "
                f"{r['iteration_time_s'] * 1e3:9.2f} "
                f"{r['samples_per_sec']:10.0f} {r['speedup']:7.2f} "
                f"{r['method'][:1]:>2s}")
        return "\n".join(lines)


#: Scenarios evaluated per batched kernel call — bounds transient
#: ``(S, L)`` matrix memory on huge (frontier-sized) grids without
#: measurably hurting throughput.
DEFAULT_CHUNK = 8192

#: Evaluation backends :func:`sweep` / :func:`iter_rows` / :func:`stream`
#: accept: the NumPy engine (default, and the agreement oracle) and the
#: jit/vmap-compiled jax kernels.
BACKENDS = ("numpy", "jax")


def _check_backend(backend: str, *, batched: bool,
                   force_simulator: bool) -> None:
    """Reject invalid ``backend`` combinations loudly — the jax
    backend has no per-scenario reference path and no event-driven
    fallback, and silently falling back to NumPy would defeat the
    point of selecting it explicitly."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if backend == "jax" and not batched:
        raise ValueError(
            "backend='jax' IS the batched kernel; batched=False pins the "
            "per-scenario NumPy reference paths, which have no jax "
            "counterpart. Drop batched=False or use backend='numpy'.")
    if backend == "jax" and force_simulator:
        raise ValueError(
            "force_simulator=True routes every scenario through the "
            "event-driven NumPy simulator — there is no jax simulator to "
            "force. Drop force_simulator or use backend='numpy'.")


def _jax_grid_chunks(grid: ScenarioGrid, chunk: int) -> Iterator[list[dict]]:
    """Grid rows through the jax backend, chunk by chunk.  Grids with
    simulator-only policies raise (in ``JaxGridEvaluator``) before any
    evaluation happens."""
    from repro.core.batched_jax import jax_grid_evaluator

    run = jax_grid_evaluator(grid).run()
    for lo in range(0, len(run), chunk):
        yield run.rows_slice(lo, min(lo + chunk, len(run)))


def _grid_chunks(grid: ScenarioGrid, warm_iterations: int,
                 chunk: int) -> Iterator[list[dict]]:
    """Evaluate a grid through the batched kernel chunk by chunk,
    filling simulator-fallback entries in place — the one copy of the
    interleave logic shared by :func:`sweep` and :func:`iter_rows`."""
    ev = grid_evaluator(grid)
    run = ev.run()
    for lo in range(0, len(run), chunk):
        part = run.rows_slice(lo, min(lo + chunk, len(run)))
        if not ev.all_batched:
            for i, r in enumerate(part):
                if r is None:
                    part[i] = _sim_eval(ev.scenario_at(lo + i),
                                        warm_iterations)
        yield part


def iter_rows(grid: ScenarioGrid | Iterable[Scenario], *,
              force_simulator: bool = False,
              warm_iterations: int = 6,
              batched: bool = True,
              backend: str = "numpy",
              chunk: int = DEFAULT_CHUNK) -> Iterator[dict]:
    """Yield tidy result rows in scenario order, lazily.

    The streaming core behind :func:`sweep` and :func:`stream`:
    closed-form and bucket-timeline scenarios are evaluated by the
    scenario-axis batched kernel ``chunk`` at a time, simulator
    fallbacks are interleaved in place, and no more than one chunk of
    rows is ever buffered — which is what lets frontier-sized grids
    (tens of thousands of scenarios) stream straight to disk.

    ``batched=False`` forces the per-scenario reference paths —
    :func:`_fast_eval` for closed forms, the event-driven simulator
    for schedule-dependent policies — the agreement oracles and the
    slow side of the throughput benchmark.

    ``backend="jax"`` evaluates through the jit/vmap kernels
    (:mod:`repro.core.batched_jax`); incompatible with
    ``batched=False`` / ``force_simulator=True`` and with
    simulator-only policies (raises ``ValueError``, never a silent
    fallback).
    """
    _check_backend(backend, batched=batched, force_simulator=force_simulator)
    if backend == "jax":
        if isinstance(grid, ScenarioGrid):
            for part in _jax_grid_chunks(grid, chunk):
                yield from part
        else:
            from repro.core.batched_jax import eval_scenarios_jax

            scenarios = list(grid)
            for s in scenarios:
                s.validate()
            for lo in range(0, len(scenarios), chunk):
                yield from eval_scenarios_jax(scenarios[lo:lo + chunk])
        return
    if isinstance(grid, ScenarioGrid):
        if batched and not force_simulator:
            for part in _grid_chunks(grid, warm_iterations, chunk):
                yield from part
            return
        scenarios = grid.expand()          # validates the axes
    else:
        scenarios = list(grid)
        for s in scenarios:
            s.validate()
    # per-policy evaluation tier: 2 = closed form, 1 = bucket-timeline
    # form (batched kernel only), 0 = simulator-only
    tier_of: dict[str, int] = {}
    for lo in range(0, len(scenarios), chunk):
        part = scenarios[lo:lo + chunk]
        fast: list[int] = []
        for i, s in enumerate(part):
            tier = tier_of.get(s.policy)
            if tier is None:
                pol = resolve_policy(s)
                tier = tier_of[s.policy] = 2 if has_fast_path(pol) \
                    else (1 if has_batched_path(pol) else 0)
            if force_simulator:
                continue
            # batched=False pins the per-scenario reference paths:
            # _fast_eval for closed forms, the simulator for the rest
            if tier >= (1 if batched else 2):
                fast.append(i)
        if batched and fast:
            fast_rows = iter(eval_scenarios([part[i] for i in fast]))
        else:
            fast_rows = iter([_fast_eval(part[i]) for i in fast])
        fast_set = set(fast)
        for i, s in enumerate(part):
            yield next(fast_rows) if i in fast_set \
                else _sim_eval(s, warm_iterations)


def sweep(grid: ScenarioGrid | Iterable[Scenario], *,
          force_simulator: bool = False,
          warm_iterations: int = 6,
          batched: bool = True,
          backend: str = "numpy") -> SweepResult:
    """Evaluate every scenario of ``grid`` and return the tidy table.

    Closed-form and bucket-timeline scenarios go through the
    scenario-axis batched kernel (:mod:`repro.core.batched`); the rest
    through the event-driven simulator.  ``batched=False`` pins every
    scenario to its per-scenario reference path instead — ``_fast_eval``
    for closed forms (same rows to <= 1e-9 relative, property-tested),
    the simulator for bucketed/priority policies (<= 1e-6).
    ``force_simulator=True`` routes *all* scenarios through the
    event-driven simulator — the agreement oracle, and the way to study
    schedules neither batched form can express.

    ``backend="jax"`` routes batched evaluation through the jit/vmap
    kernels (:mod:`repro.core.batched_jax`) instead of the NumPy
    engine; rows agree with the NumPy oracle to <= 1e-6
    (property-tested).  The jax backend has no reference or simulator
    path, so ``batched=False`` / ``force_simulator=True`` / grids with
    simulator-only policies raise ``ValueError`` rather than silently
    falling back.
    """
    _check_backend(backend, batched=batched, force_simulator=force_simulator)
    t0 = time.perf_counter()
    rows: list[dict] = []
    if backend == "jax" and isinstance(grid, ScenarioGrid):
        ev = grid_evaluator(grid)          # raises in _jax_grid_chunks if
        for part in _jax_grid_chunks(grid, DEFAULT_CHUNK):  # not all batched
            rows.extend(part)
        return SweepResult(rows=rows, elapsed_s=time.perf_counter() - t0,
                           n_analytical=ev.n_fast,
                           n_timeline=ev.n_timeline,
                           n_simulated=0, backend=backend)
    if backend == "numpy" and isinstance(grid, ScenarioGrid) \
            and batched and not force_simulator:
        ev = grid_evaluator(grid)
        for part in _grid_chunks(grid, warm_iterations, DEFAULT_CHUNK):
            rows.extend(part)
        return SweepResult(rows=rows, elapsed_s=time.perf_counter() - t0,
                           n_analytical=ev.n_fast,
                           n_timeline=ev.n_timeline,
                           n_simulated=len(ev) - ev.n_fast - ev.n_timeline)
    n_fast = n_tl = n_slow = 0
    for r in iter_rows(grid, force_simulator=force_simulator,
                       warm_iterations=warm_iterations, batched=batched,
                       backend=backend):
        rows.append(r)
        if r["method"] == "analytical":
            n_fast += 1
        elif r["method"] == "timeline":
            n_tl += 1
        else:
            n_slow += 1
    return SweepResult(rows=rows, elapsed_s=time.perf_counter() - t0,
                       n_analytical=n_fast, n_timeline=n_tl,
                       n_simulated=n_slow, backend=backend)


def stream(grid: ScenarioGrid | Iterable[Scenario], *,
           csv_path=None, json_path=None,
           force_simulator: bool = False, warm_iterations: int = 6,
           batched: bool = True, backend: str = "numpy",
           chunk: int = DEFAULT_CHUNK) -> dict:
    """Evaluate ``grid`` **once** and write the tidy table to
    ``csv_path`` and/or ``json_path`` incrementally — one chunk of
    rows in memory at a time, both formats fed from the same pass.
    Returns summary metadata (``n_scenarios`` / ``elapsed_s`` /
    ``n_analytical`` / ``n_simulated``).

    The JSON document has the :meth:`SweepResult.to_json` shape (same
    keys; ``rows`` first so the array can stream, counts in the
    trailer).
    """
    if csv_path is None and json_path is None:
        raise ValueError("stream() needs csv_path and/or json_path")
    _check_backend(backend, batched=batched, force_simulator=force_simulator)
    t0 = time.perf_counter()
    n_fast = n_tl = n_slow = 0
    csv_file = json_file = None
    try:
        if csv_path is not None:
            csv_file = open(csv_path, "w", newline="")
            writer = csv.DictWriter(csv_file, fieldnames=COLUMNS)
            writer.writeheader()
        if json_path is not None:
            json_file = open(json_path, "w")
            json_file.write('{\n  "columns": %s,\n  "rows": ['
                            % json.dumps(list(COLUMNS)))
        first = True
        for r in iter_rows(grid, force_simulator=force_simulator,
                           warm_iterations=warm_iterations,
                           batched=batched, backend=backend, chunk=chunk):
            if csv_file is not None:
                writer.writerow(r)
            if json_file is not None:
                json_file.write(("\n    " if first else ",\n    ")
                                + json.dumps(r))
            first = False
            if r["method"] == "analytical":
                n_fast += 1
            elif r["method"] == "timeline":
                n_tl += 1
            else:
                n_slow += 1
        elapsed = time.perf_counter() - t0
        if json_file is not None:
            json_file.write(
                '\n  ],\n  "n_scenarios": %d,\n  "elapsed_s": %s,\n'
                '  "n_analytical": %d,\n  "n_timeline": %d,\n'
                '  "n_simulated": %d,\n  "backend": %s\n}\n'
                % (n_fast + n_tl + n_slow, json.dumps(elapsed),
                   n_fast, n_tl, n_slow, json.dumps(backend)))
    finally:
        for f in (csv_file, json_file):
            if f is not None:
                f.close()
    return {"n_scenarios": n_fast + n_tl + n_slow, "elapsed_s": elapsed,
            "n_analytical": n_fast, "n_timeline": n_tl,
            "n_simulated": n_slow, "backend": backend}


def stream_csv(grid: ScenarioGrid | Iterable[Scenario], path,
               **kw) -> dict:
    """:func:`stream` to a single CSV file."""
    return stream(grid, csv_path=path, **kw)


def stream_json(grid: ScenarioGrid | Iterable[Scenario], path,
                **kw) -> dict:
    """:func:`stream` to a single JSON document."""
    return stream(grid, json_path=path, **kw)


def evaluate_scenario(s: Scenario, method: str = "auto",
                      warm_iterations: int = 6) -> dict:
    """Evaluate one scenario; ``method`` is ``auto`` (closed form when
    exact, else the batched bucket-timeline kernel, else the
    simulator), ``analytical`` (raise unless the per-layer closed form
    applies) or ``simulator``."""
    s.validate()
    policy = resolve_policy(s)
    if method == "simulator":
        return _sim_eval(s, warm_iterations)
    if method == "analytical":
        if not has_fast_path(policy):
            raise ValueError(f"policy {s.policy!r} has no exact closed form")
        return _fast_eval(s)
    if method != "auto":
        raise ValueError(f"unknown method {method!r}")
    if has_fast_path(policy):
        return _fast_eval(s)
    if has_batched_path(policy):
        return eval_scenarios([s])[0]
    return _sim_eval(s, warm_iterations)
