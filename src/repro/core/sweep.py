"""Batched scenario-sweep engine for the S-SGD DAG model.

Evaluates a :class:`repro.core.scenarios.ScenarioGrid` — thousands of
``(workload x cluster x workers x interconnect x policy x collective
x het x straggler x sync_k x faults)`` combinations — in one call,
two ways:

* **Batched analytical fast path** (the default for every policy
  whose closed form is exact — see
  :func:`repro.core.analytical.has_closed_form`): the scenario-axis
  batched kernel of :mod:`repro.core.batched` evaluates the whole
  grid as ``(scenario x layer)`` matrices (workload tables resolved
  through the pluggable registry of :mod:`repro.core.workloads` —
  ``cnn:``/``trace:``/``llm:`` — and memoized at module scope);
  millions of scenarios per second.  The per-scenario
  :func:`_fast_eval` stays as the reference implementation — the two
  agree to <= 1e-9 relative (property-tested), and ``batched=False``
  pins a sweep to it.
* **Batched bucket-timeline path** for the schedule-dependent policies
  (gradient-bucket fusion, priority comm): their steady state is
  exactly the bucket-timeline form (:mod:`repro.core.bucketsim`), so
  the same kernel evaluates them as padded ``(scenario x bucket)``
  matrices — no Python DAG objects, no list scheduler.  Rows carry
  ``method="timeline"``.
* **Event-driven fallback** for policies with neither form, and for
  ``force_simulator=True`` (the agreement oracle): the Fig.-1 DAG is
  built and list-scheduled via
  :func:`repro.core.simulator.simulate_steady`.

Results are **columnar end-to-end**: the batched kernels emit tables
(one NumPy array per :data:`COLUMNS` key, schema in
:mod:`repro.core.resulttable`), :func:`iter_tables` streams them chunk
by chunk, and :class:`SweepResult` stores the column arrays — per-row
dicts are a lazy compat view (:attr:`SweepResult.rows`), never built
on the hot path.  ``jobs=N`` shards the chunks of a grid sweep across
a process (or thread) pool (:mod:`repro.core.parallel`), preserving
grid order exactly.

``backend="jax"`` swaps the batched engine for the fused jit kernel
of :mod:`repro.core.batched_jax` (same two tiers through XLA, float64,
<= 1e-6 agreement with the NumPy oracle, property-tested).  NumPy
stays the default and the reference: the jax backend never falls back
silently — combinations that would need the per-scenario reference
paths (``batched=False``), the event-driven simulator
(``force_simulator=True``) or a grid with simulator-only policies
raise ``ValueError`` instead.  Under ``jobs>1`` the jax backend
shards over its device mesh when more than one device is visible (a
host pool would fight XLA for the devices), and is a documented no-op
on one device.

The property tests assert the analytical and simulator paths agree to
<= 1e-6 relative on every policy with an exact closed form, and the
timeline path to <= 1e-6 against the simulator on the bucketed and
priority policies.  For grids too big to buffer, :func:`iter_rows` /
:func:`stream_csv` / :func:`stream_json` evaluate lazily chunk by
chunk.
"""
from __future__ import annotations

import csv
import json
import os
import time
from dataclasses import dataclass, field, replace
from typing import Iterable, Iterator, Sequence

import numpy as np

from repro.core import analytical
from repro.core import het as het_mod
from repro.core.batched import grid_evaluator
from repro.core.batched import eval_scenarios  # noqa: F401  (re-export)
from repro.core.costmodel import comm_scale_fn
from repro.core.policies import Policy
from repro.core.resulttable import (COLUMNS, concat_tables, method_counts,
                                    rows_from_table, table_from_rows,
                                    table_len)
from repro.core.scenarios import (Scenario, ScenarioGrid,
                                  normalize_interconnect, normalize_sync_k,
                                  resolve_cluster, resolve_policy)
from repro.core.simulator import simulate_steady
from repro.core.workloads import WorkloadTable, resolve_workload


def has_fast_path(policy: Policy) -> bool:
    """True when the policy's steady state has an exact closed form
    (delegates to the single source of truth,
    :func:`repro.core.analytical.has_closed_form`)."""
    return analytical.has_closed_form(policy)


def has_batched_path(policy: Policy) -> bool:
    """True when the policy can be evaluated by the batched kernel at
    all: an exact per-layer closed form (``method="analytical"``) or
    the bucket-timeline form (``method="timeline"``).  Everything else
    — and every scenario under ``force_simulator=True`` — goes through
    the event-driven simulator."""
    return analytical.has_closed_form(policy) \
        or analytical.has_timeline_form(policy)


def _scenario_costs(s: Scenario, tab: WorkloadTable):
    """(costs, cluster, policy, batch) for one scenario, through the
    single construction path every workload provider shares
    (:meth:`repro.core.workloads.WorkloadTable.iteration_costs`)."""
    cluster = resolve_cluster(s)
    policy = resolve_policy(s)
    batch = s.batch_per_gpu or tab.batch_default
    costs = tab.iteration_costs(cluster, batch, s.n_workers, s.collective)
    return costs, cluster, policy, batch


def _scale_compute(costs, tmul: float):
    """Slowest-worker theorem applied to :class:`IterationCosts`: the
    synchronous steady state with per-worker compute multipliers equals
    the homogeneous closed form with ``t_f``/``t_b`` scaled by the
    bottleneck multiplier (``t_io``/``t_h2d``/``t_c``/``t_u`` are not
    compute-rate-bound and stay put)."""
    return replace(costs, t_f=np.asarray(costs.t_f) * tmul,
                   t_b=np.asarray(costs.t_b) * tmul)


def _het_state(s: Scenario):
    """``(inv_speed | None, StragglerSpec | None)`` for one scenario —
    the per-worker compute-rate vector (``None`` when homogeneous, so
    the deterministic path stays bit-identical) and the parsed
    straggler spec."""
    profile = het_mod.parse_het_profile(s.het)
    inv = None
    if profile is not None:
        inv, _, _ = het_mod.worker_vectors(profile, s.n_workers)
    return inv, het_mod.parse_straggler(s.straggler)


def _kth_tmul(times: np.ndarray, sync_k: int) -> np.ndarray:
    """Per-draw bottleneck multiplier under K-of-N partial sync: the
    K-th smallest of each row of per-worker times (``sync_k = 0`` means
    full sync, the max).  ``times`` is ``(D, n)``; clamping keeps
    ``K >= n`` bit-identical to the historical max reduction."""
    n = times.shape[-1]
    keff = n if sync_k == 0 else min(max(int(sync_k), 1), n)
    if keff >= n:
        return times.max(axis=-1)
    return np.partition(times, keff - 1, axis=-1)[..., keff - 1]


def _fault_state(s: Scenario, seed: int, draws: int | None):
    """``(FaultSpec | None, crash_matrix | None)``: the parsed fault
    spec and, when stochastic, the seed-keyed ``(D, n)`` boolean crash
    matrix — each crashed worker costs a serialized ``restart``-second
    checkpoint restore gating the update broadcast (see
    :class:`repro.core.dag.SSGDDagBuilder`)."""
    ft = het_mod.parse_fault(s.faults)
    if ft is None or ft.is_deterministic:
        return ft, None
    return ft, ft.crash_matrix(s.n_workers, seed, draws=draws)


def _ref_tails(t_iters) -> tuple[float, float, float]:
    """``(mean, p95, p99)`` of per-draw iteration times — the same
    host-side reduction the batched Monte Carlo pass applies."""
    t = np.asarray(t_iters)
    return (float(t.mean()), float(np.quantile(t, 0.95)),
            float(np.quantile(t, 0.99)))


def _fast_eval(s: Scenario, seed: int = 0) -> dict:
    """Per-scenario analytical path: NumPy arrays over the layer
    dimension fed straight into the shared closed forms (the scalar
    equations in :mod:`repro.core.analytical` are pure arithmetic over
    sequences, so array-valued ``IterationCosts`` evaluate directly —
    no parallel formula implementation to keep in lockstep).

    Heterogeneous scenarios apply the slowest-worker reduction: links
    are derated in :func:`repro.core.scenarios.resolve_cluster`,
    compute by :func:`_scale_compute` at ``max_w(1/speed_w)``.
    Stochastic stragglers loop the closed form over the Monte Carlo
    draws for the tail columns (same draw matrices as the batched
    engines, keyed by ``seed``).

    This is the **reference implementation and agreement oracle** for
    the scenario-axis batched kernel (:mod:`repro.core.batched`), which
    is what :func:`sweep` actually routes closed-form scenarios
    through; the property tests pin the two to <= 1e-9 relative.

    The failure model folds in exactly as in the batched engine:
    ``sync_k`` swaps the bottleneck max for the K-th order statistic
    (:func:`_kth_tmul`), and a stochastic fault spec adds each draw's
    serialized restore penalty to ``t_u`` — the restores gate the
    update broadcast, so the penalty rides the GPU/update chain
    *inside* the pipeline max."""
    costs0, _, policy, batch = _scenario_costs(s, resolve_workload(s.workload))
    inv, st = _het_state(s)
    sk = normalize_sync_k(s.sync_k)
    costs = costs0 if inv is None else _scale_compute(
        costs0, float(_kth_tmul(inv[None, :], sk)[0]))
    t_iter = float(analytical.closed_form(costs, policy))
    t1 = float(analytical.closed_form(
        costs.with_comm(np.zeros_like(costs.t_f)), policy))
    tails = None
    st_live = st is not None and not st.is_deterministic
    ft, cm = _fault_state(s, seed,
                          st.draws if st_live else None)
    if st_live or cm is not None:
        D = st.draws if st_live else ft.draws
        J = st.draw_matrix(s.n_workers, seed) if st_live \
            else np.ones((D, s.n_workers))
        tmuls = _kth_tmul(J if inv is None else J * inv, sk)
        pens = np.zeros(D) if cm is None else ft.restart * cm.sum(axis=1)
        tails = _ref_tails([
            float(analytical.closed_form(
                replace(_scale_compute(costs0, m), t_u=costs0.t_u + p),
                policy))
            for m, p in zip(tmuls, pens)])
    return _row(s, batch, t_iter, t1, float(np.sum(costs.t_c)),
                float(np.sum(costs.t_f) + np.sum(costs.t_b)), "analytical",
                tails=tails)


def _sim_eval(s: Scenario, warm_iterations: int = 6, seed: int = 0) -> dict:
    """Event-driven fallback: build the Fig.-1 DAG and list-schedule.

    This is the per-worker oracle for the heterogeneity engine: the
    per-worker rate vector goes to the DAG builder *unreduced*
    (``worker_scale``), so agreement with the batched path validates
    the slowest-worker theorem rather than assuming it.  Stochastic
    stragglers re-simulate per draw with ``jitter * inv_speed``.  The
    failure model goes to the builder equally unreduced: ``sync_k``
    gates the DAG's aggregation edges on the K fastest workers, and
    each draw's crashed-worker set becomes serialized checkpoint
    restores — agreement with the batched closed form validates the
    K-th-order-statistic reduction and the additive restore chain."""
    tab = resolve_workload(s.workload)
    costs, cluster, policy, batch = _scenario_costs(s, tab)
    inv, st = _het_state(s)
    sk = normalize_sync_k(s.sync_k)
    comm_scale = comm_scale_fn(cluster, s.n_workers, s.collective) \
        if policy.bucket_bytes else None
    t_iter = simulate_steady(costs, s.n_workers, policy,
                             n_iterations=warm_iterations,
                             comm_scale=comm_scale,
                             worker_scale=inv,
                             sync_k=sk or None)
    # weak-scaling baseline: same pipeline, one worker, no comm — with
    # the same bottleneck compute rate, matching the batched speedup
    base_policy = replace(policy, bucket_bytes=None, priority_comm=False)
    c1 = costs.with_comm([0.0] * costs.num_layers)
    if inv is not None:
        c1 = _scale_compute(c1, float(_kth_tmul(inv[None, :], sk)[0]))
    t1 = analytical.closed_form(c1, base_policy)
    if t1 is None:                                    # pragma: no cover
        t1 = simulate_steady(c1, 1, base_policy, n_iterations=warm_iterations)
    tails = None
    st_live = st is not None and not st.is_deterministic
    ft, cm = _fault_state(s, seed, st.draws if st_live else None)
    if st_live or cm is not None:
        D = st.draws if st_live else ft.draws
        J = st.draw_matrix(s.n_workers, seed) if st_live \
            else np.ones((D, s.n_workers))
        mul = J if inv is None else J * inv
        crash_sets = [()] * D if cm is None else \
            [tuple(np.nonzero(c)[0].tolist()) for c in cm]
        tails = _ref_tails([
            simulate_steady(costs, s.n_workers, policy,
                            n_iterations=warm_iterations,
                            comm_scale=comm_scale,
                            worker_scale=m,
                            sync_k=sk or None,
                            crashed=crashed,
                            restart_s=0.0 if ft is None else ft.restart)
            for m, crashed in zip(mul, crash_sets)])
    return _row(s, batch, t_iter, t1, float(np.sum(costs.t_c)),
                float(np.sum(costs.t_f) + np.sum(costs.t_b)), "simulated",
                tails=tails)


def _row(s: Scenario, batch: int, t_iter: float, t1: float, t_comm: float,
         t_comp: float, method: str,
         tails: tuple[float, float, float] | None = None) -> dict:
    t_mean, t_p95, t_p99 = tails if tails is not None \
        else (t_iter, t_iter, t_iter)
    return {
        "workload": s.workload,
        "cluster": s.cluster,
        "n_workers": s.n_workers,
        "policy": s.policy,
        "collective": s.collective,
        "interconnect": normalize_interconnect(s.interconnect),
        "het": het_mod.normalize_het(s.het),
        "straggler": het_mod.normalize_straggler(s.straggler),
        "sync_k": normalize_sync_k(s.sync_k),
        "faults": het_mod.normalize_fault(s.faults),
        "batch_per_gpu": batch,
        "iteration_time_s": t_iter,
        "samples_per_sec": s.n_workers * batch / t_iter if t_iter else 0.0,
        "speedup": s.n_workers * t1 / t_iter if t_iter else float(s.n_workers),
        "t_comm_s": t_comm,
        "t_comp_s": t_comp,
        "t_mean_s": t_mean,
        "t_p95_s": t_p95,
        "t_p99_s": t_p99,
        "method": method,
    }


@dataclass
class SweepResult:
    """Tidy results table, stored **columnar**: ``columns`` maps each
    :data:`COLUMNS` key to one ``(n,)`` NumPy array (the schema of
    :mod:`repro.core.resulttable`).  :attr:`rows` is the lazy per-row
    compat view — a ``list[dict]`` built (and cached) on first access,
    so code that iterates rows keeps working while the hot path
    (:func:`sweep` -> CSV/JSON/DataFrame/filter/sort) never touches
    per-row Python objects.

    ``n_analytical`` counts closed-form batched rows, ``n_timeline``
    bucket-timeline batched rows, ``n_simulated`` event-driven
    fallback rows — the three evaluation paths of :func:`sweep`.
    ``backend`` records which batched engine produced the rows
    (``"numpy"`` or ``"jax"``).
    """

    columns: dict[str, np.ndarray]
    elapsed_s: float
    n_analytical: int
    n_simulated: int
    n_timeline: int = 0
    backend: str = "numpy"
    _rows: list | None = field(default=None, repr=False, compare=False)

    @property
    def rows(self) -> list[dict]:
        """Per-row dict view of :attr:`columns` (cached)."""
        if self._rows is None:
            self._rows = rows_from_table(self.columns)
        return self._rows

    def __len__(self) -> int:
        return table_len(self.columns)

    @property
    def scenarios_per_sec(self) -> float:
        return len(self) / self.elapsed_s if self.elapsed_s else 0.0

    def _col(self, column: str) -> np.ndarray:
        """The column array, or a ``KeyError`` naming the valid columns
        — a typo'd ``sorted_by("t_p95")`` should say what *is* there."""
        try:
            return self.columns[column]
        except KeyError:
            raise KeyError(
                f"unknown column {column!r}; one of "
                f"{', '.join(COLUMNS)}") from None

    def sorted_by(self, column: str, reverse: bool = True) -> list[dict]:
        """Rows ordered by ``column`` — a stable argsort over the
        column array (ties keep grid order, exactly like
        ``sorted(rows, ...)`` did on the per-row path)."""
        col = self._col(column)
        if reverse:
            # stable *descending*: stable-argsort the reversed column,
            # map indices back, reverse — equal keys keep ascending
            # original order, matching sorted(reverse=True)
            n = len(col)
            idx = (n - 1 - np.argsort(col[::-1], kind="stable"))[::-1]
        else:
            idx = np.argsort(col, kind="stable")
        return rows_from_table(self.columns, idx)

    def filter(self, **eq) -> list[dict]:
        """Rows matching all ``column=value`` pairs — one vectorized
        equality mask per pair, no per-row Python comparisons.

        ``interconnect`` accepts both spellings of "cluster default":
        ``None`` and ``"default"`` (rows always store the normalized
        form, via the same normalizer as ``Scenario.label()``); ``het``,
        ``straggler`` and ``faults`` likewise accept ``None`` for
        ``"none"``, and ``sync_k`` accepts ``None`` for ``0`` (full
        sync).  Unknown column names raise ``KeyError`` naming the
        valid ones.
        """
        if "interconnect" in eq:
            eq["interconnect"] = normalize_interconnect(eq["interconnect"])
        if "het" in eq:
            eq["het"] = het_mod.normalize_het(eq["het"])
        if "straggler" in eq:
            eq["straggler"] = het_mod.normalize_straggler(eq["straggler"])
        if "faults" in eq:
            eq["faults"] = het_mod.normalize_fault(eq["faults"])
        if "sync_k" in eq:
            eq["sync_k"] = normalize_sync_k(eq["sync_k"])
        mask = np.ones(len(self), dtype=bool)
        for k, v in eq.items():
            mask &= self._col(k) == v
        return rows_from_table(self.columns, np.nonzero(mask)[0])

    def to_csv(self, path) -> None:
        with open(path, "w", newline="") as f:
            w = csv.writer(f)
            w.writerow(COLUMNS)
            w.writerows(zip(*(self.columns[k].tolist() for k in COLUMNS)))

    def meta(self) -> dict:
        """Sweep metadata in :data:`RESULT_META_KEYS` order — the
        :meth:`to_json` document minus ``columns``/``rows``, and the
        base of the sweep service's per-query trailer."""
        return {
            "n_scenarios": len(self),
            "elapsed_s": self.elapsed_s,
            "scenarios_per_sec": self.scenarios_per_sec,
            "n_analytical": self.n_analytical,
            "n_timeline": self.n_timeline,
            "n_simulated": self.n_simulated,
            "backend": self.backend,
        }

    def to_json(self, path=None, indent: int | None = 2) -> str:
        """The full result as a JSON document (and optionally write it
        to ``path``): sweep metadata plus the tidy rows."""
        doc = {"columns": list(COLUMNS), **self.meta(), "rows": self.rows}
        text = json.dumps(doc, indent=indent)
        if path is not None:
            with open(path, "w") as f:
                f.write(text)
        return text

    def to_dataframe(self):
        """Results as a pandas DataFrame (pandas is optional) — built
        column-wise from the arrays, no row dicts."""
        import pandas as pd

        return pd.DataFrame({k: self.columns[k] for k in COLUMNS},
                            columns=list(COLUMNS))

    def format_table(self, rows: Sequence[dict] | None = None,
                     limit: int | None = None) -> str:
        if rows is None:
            # only materialize the rows actually printed
            n = len(self) if limit is None else min(limit, len(self))
            rows = rows_from_table(self.columns, np.arange(n))
        else:
            rows = list(rows)
            if limit is not None:
                rows = rows[:limit]
        # wide enough for provider-prefixed names (llm:qwen2-moe-a2.7b);
        # the heterogeneity/failure columns appear only when some row
        # uses them
        with_het = any(r["het"] != "none" or r["straggler"] != "none"
                       for r in rows)
        with_fail = any(r["sync_k"] != 0 or r["faults"] != "none"
                        for r in rows)
        header = (f"{'workload':22s} {'cluster':16s} {'wk':>3s} "
                  f"{'policy':13s} {'coll':12s} {'interconn':12s} "
                  f"{'iter_ms':>9s} {'samp/s':>10s} {'speedup':>7s} {'m':>2s}")
        if with_het:
            header += (f" {'het':18s} {'straggler':18s} "
                       f"{'p99_ms':>9s}")
        if with_fail:
            header += f" {'k':>3s} {'faults':26s}"
        lines = [header, "-" * len(header)]
        for r in rows:
            line = (
                f"{r['workload']:22s} {r['cluster']:16s} "
                f"{r['n_workers']:3d} {r['policy']:13s} "
                f"{r['collective']:12s} {r['interconnect']:12s} "
                f"{r['iteration_time_s'] * 1e3:9.2f} "
                f"{r['samples_per_sec']:10.0f} {r['speedup']:7.2f} "
                f"{r['method'][:1]:>2s}")
            if with_het:
                line += (f" {r['het'][:18]:18s} {r['straggler'][:18]:18s} "
                         f"{r['t_p99_s'] * 1e3:9.2f}")
            if with_fail:
                line += f" {r['sync_k']:3d} {r['faults'][:26]:26s}"
            lines.append(line)
        return "\n".join(lines)


#: Scenarios evaluated per batched kernel call — bounds transient
#: ``(S, L)`` matrix memory on huge (frontier-sized) grids without
#: measurably hurting throughput.
DEFAULT_CHUNK = 8192

#: Evaluation backends :func:`sweep` / :func:`iter_rows` / :func:`stream`
#: accept: the NumPy engine (default, and the agreement oracle) and the
#: fused jit jax kernel.
BACKENDS = ("numpy", "jax")

#: Metadata keys every result surface shares — the
#: :meth:`SweepResult.to_json` document minus ``columns``/``rows``,
#: the :func:`stream` JSON trailer and return value, and the sweep
#: service's per-query trailer (:mod:`repro.core.service`); the parity
#: is pinned by tests, so a key added here propagates everywhere or
#: fails loudly.
RESULT_META_KEYS = ("n_scenarios", "elapsed_s", "scenarios_per_sec",
                    "n_analytical", "n_timeline", "n_simulated", "backend")


def _check_backend(backend: str, *, batched: bool,
                   force_simulator: bool) -> None:
    """Reject invalid ``backend`` combinations loudly — the jax
    backend has no per-scenario reference path and no event-driven
    fallback, and silently falling back to NumPy would defeat the
    point of selecting it explicitly."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if backend == "jax" and not batched:
        raise ValueError(
            "backend='jax' IS the batched kernel; batched=False pins the "
            "per-scenario NumPy reference paths, which have no jax "
            "counterpart. Drop batched=False or use backend='numpy'.")
    if backend == "jax" and force_simulator:
        raise ValueError(
            "force_simulator=True routes every scenario through the "
            "event-driven NumPy simulator — there is no jax simulator to "
            "force. Drop force_simulator or use backend='numpy'.")


def _fill_simulated(table: dict, batched_mask: np.ndarray, ev, lo: int,
                    warm_iterations: int, seed: int = 0) -> None:
    """Overwrite the tier-2 placeholder rows of a chunk table with
    event-driven simulator results, in place."""
    from repro.core.resulttable import fill_rows

    idx = np.nonzero(~batched_mask)[0]
    if len(idx):
        fill_rows(table, idx,
                  [_sim_eval(ev.scenario_at(lo + int(i)), warm_iterations,
                             seed=seed)
                   for i in idx])


def _reference_rows(scenarios: Sequence[Scenario], *,
                    force_simulator: bool, warm_iterations: int,
                    batched: bool, chunk: int,
                    seed: int = 0) -> Iterator[list[dict]]:
    """The per-scenario reference paths, chunk by chunk:
    :func:`_fast_eval` for closed forms (or the batched list kernel
    when ``batched``), the event-driven simulator for the rest — the
    agreement oracles and the slow side of the throughput benchmark."""
    # per-policy evaluation tier: 2 = closed form, 1 = bucket-timeline
    # form (batched kernel only), 0 = simulator-only
    tier_of: dict[str, int] = {}
    for lo in range(0, len(scenarios), chunk):
        part = scenarios[lo:lo + chunk]
        fast: list[int] = []
        for i, s in enumerate(part):
            tier = tier_of.get(s.policy)
            if tier is None:
                pol = resolve_policy(s)
                tier = tier_of[s.policy] = 2 if has_fast_path(pol) \
                    else (1 if has_batched_path(pol) else 0)
            if force_simulator:
                continue
            # batched=False pins the per-scenario reference paths:
            # _fast_eval for closed forms, the simulator for the rest
            if tier >= (1 if batched else 2):
                fast.append(i)
        if batched and fast:
            fast_rows = iter(eval_scenarios([part[i] for i in fast],
                                            seed=seed))
        else:
            fast_rows = iter([_fast_eval(part[i], seed=seed) for i in fast])
        fast_set = set(fast)
        yield [next(fast_rows) if i in fast_set
               else _sim_eval(s, warm_iterations, seed=seed)
               for i, s in enumerate(part)]


def iter_tables(grid: ScenarioGrid | Iterable[Scenario], *,
                force_simulator: bool = False,
                warm_iterations: int = 6,
                batched: bool = True,
                backend: str = "numpy",
                chunk: int = DEFAULT_CHUNK,
                jobs: int | None = None,
                pool: str = "process",
                seed: int = 0) -> Iterator[dict]:
    """Yield columnar result tables in scenario order, lazily — the
    single evaluation core behind :func:`sweep`, :func:`iter_rows` and
    :func:`stream`.  Each yielded table maps every :data:`COLUMNS` key
    to one NumPy array of ``<= chunk`` rows (exactly ``chunk`` except
    the last), so no more than one chunk is ever buffered.

    Routing: a :class:`ScenarioGrid` on the default arguments goes
    straight through the batched grid kernel
    (:meth:`repro.core.batched.GridRun.table_slice`), with
    simulator-fallback rows overwritten in place; ``jobs > 1`` shards
    the grid's chunks across a worker pool
    (:func:`repro.core.parallel.parallel_tables` — order-preserving,
    bit-identical to serial); ``backend="jax"`` evaluates through the
    fused jit kernel (sharding over the device mesh when ``jobs > 1``
    and more than one device is visible).  Scenario lists and the
    reference paths (``batched=False`` / ``force_simulator=True``)
    produce per-row dicts and are wrapped into tables chunk by chunk.

    ``seed`` keys the straggler Monte Carlo draws (no effect on
    deterministic scenarios); every route threads it to the same keyed
    generator, so results are independent of backend, sharding and
    chunking.
    """
    _check_backend(backend, batched=batched, force_simulator=force_simulator)
    if backend == "jax":
        if isinstance(grid, ScenarioGrid):
            from repro.core.batched_jax import jax_grid_evaluator

            mesh = None
            if jobs is not None and jobs > 1:
                import jax as _jax
                if len(_jax.devices()) > 1:
                    from repro.launch.mesh import make_dp_mesh
                    mesh = make_dp_mesh(min(jobs, len(_jax.devices())))
            run = jax_grid_evaluator(grid, mesh=mesh).run(seed=seed)
            for lo in range(0, len(run), chunk):
                yield run.table_slice(lo, min(lo + chunk, len(run)))[0]
        else:
            from repro.core.batched_jax import eval_scenarios_jax

            scenarios = list(grid)
            for s in scenarios:
                s.validate()
            for lo in range(0, len(scenarios), chunk):
                yield table_from_rows(
                    eval_scenarios_jax(scenarios[lo:lo + chunk], seed=seed))
        return
    if isinstance(grid, ScenarioGrid) and batched and not force_simulator:
        if jobs is not None and jobs > 1:
            from repro.core.parallel import parallel_tables

            yield from parallel_tables(grid, jobs=jobs, chunk=chunk,
                                       warm_iterations=warm_iterations,
                                       pool=pool, seed=seed)
            return
        ev = grid_evaluator(grid)
        run = ev.run(seed=seed)
        for lo in range(0, len(run), chunk):
            table, mask = run.table_slice(lo, min(lo + chunk, len(run)))
            if not ev.all_batched:
                _fill_simulated(table, mask, ev, lo, warm_iterations,
                                seed=seed)
            yield table
        return
    if isinstance(grid, ScenarioGrid):
        scenarios = grid.expand()          # validates the axes
    else:
        scenarios = list(grid)
        for s in scenarios:
            s.validate()
    for part in _reference_rows(scenarios, force_simulator=force_simulator,
                                warm_iterations=warm_iterations,
                                batched=batched, chunk=chunk, seed=seed):
        yield table_from_rows(part)


def iter_rows(grid: ScenarioGrid | Iterable[Scenario], *,
              force_simulator: bool = False,
              warm_iterations: int = 6,
              batched: bool = True,
              backend: str = "numpy",
              chunk: int = DEFAULT_CHUNK,
              jobs: int | None = None,
              seed: int = 0) -> Iterator[dict]:
    """Yield tidy result rows in scenario order, lazily — the per-row
    view of :func:`iter_tables` (one chunk of rows is materialized at
    a time; for columnar access use :func:`iter_tables` directly)."""
    for table in iter_tables(grid, force_simulator=force_simulator,
                             warm_iterations=warm_iterations,
                             batched=batched, backend=backend,
                             chunk=chunk, jobs=jobs, seed=seed):
        yield from rows_from_table(table)


def sweep(grid: ScenarioGrid | Iterable[Scenario], *,
          force_simulator: bool = False,
          warm_iterations: int = 6,
          batched: bool = True,
          backend: str = "numpy",
          jobs: int | None = None,
          chunk: int | None = None,
          seed: int = 0) -> SweepResult:
    """Evaluate every scenario of ``grid`` and return the tidy table.

    Closed-form and bucket-timeline scenarios go through the
    scenario-axis batched kernel (:mod:`repro.core.batched`); the rest
    through the event-driven simulator.  ``batched=False`` pins every
    scenario to its per-scenario reference path instead — ``_fast_eval``
    for closed forms (same rows to <= 1e-9 relative, property-tested),
    the simulator for bucketed/priority policies (<= 1e-6).
    ``force_simulator=True`` routes *all* scenarios through the
    event-driven simulator — the agreement oracle, and the way to study
    schedules neither batched form can express.

    ``backend="jax"`` routes batched evaluation through the fused jit
    kernel (:mod:`repro.core.batched_jax`) instead of the NumPy
    engine; rows agree with the NumPy oracle to <= 1e-6
    (property-tested).  The jax backend has no reference or simulator
    path, so ``batched=False`` / ``force_simulator=True`` / grids with
    simulator-only policies raise ``ValueError`` rather than silently
    falling back.

    ``jobs=N`` (grid sweeps) shards chunks across ``N`` worker
    processes (:mod:`repro.core.parallel`) — output is bit-identical
    to serial, in the same order.  On the jax backend it shards over
    the device mesh instead (no-op on a single device).

    ``seed`` keys the straggler Monte Carlo draws; same grid + same
    seed reproduces the tail columns exactly on every backend.
    """
    _check_backend(backend, batched=batched, force_simulator=force_simulator)
    t0 = time.perf_counter()
    grid_batched = isinstance(grid, ScenarioGrid) and batched \
        and not force_simulator
    if chunk is None:
        if grid_batched and (jobs is None or jobs <= 1):
            # one whole-grid chunk: a single table, no concat
            chunk = max(len(grid), 1)
        else:
            chunk = DEFAULT_CHUNK
    columns = concat_tables(list(iter_tables(
        grid, force_simulator=force_simulator,
        warm_iterations=warm_iterations, batched=batched,
        backend=backend, chunk=chunk, jobs=jobs, seed=seed)))
    elapsed = time.perf_counter() - t0
    if grid_batched:
        # static counts from the grid structure — no label scan
        ev = grid_evaluator(grid)
        n_fast, n_tl = ev.n_fast, ev.n_timeline
        n_slow = 0 if backend == "jax" else len(ev) - n_fast - n_tl
    else:
        n_fast, n_tl, n_slow = method_counts(columns)
    return SweepResult(columns=columns, elapsed_s=elapsed,
                       n_analytical=n_fast, n_timeline=n_tl,
                       n_simulated=n_slow, backend=backend)


def stream(grid: ScenarioGrid | Iterable[Scenario], *,
           csv_path=None, json_path=None,
           force_simulator: bool = False, warm_iterations: int = 6,
           batched: bool = True, backend: str = "numpy",
           chunk: int = DEFAULT_CHUNK, jobs: int | None = None,
           seed: int = 0) -> dict:
    """Evaluate ``grid`` **once** and write the tidy table to
    ``csv_path`` and/or ``json_path`` incrementally — one chunk of
    rows in memory at a time, both formats fed from the same pass.
    Returns summary metadata (``n_scenarios`` / ``elapsed_s`` /
    ``scenarios_per_sec`` / ``n_analytical`` / ``n_simulated``).

    The JSON document has the :meth:`SweepResult.to_json` shape (same
    keys; ``rows`` first so the array can stream, counts and timing in
    the trailer).

    Writes are **atomic**: each output streams to ``<path>.tmp`` and is
    renamed over ``path`` only after the whole pass succeeds, so an
    exception mid-sweep (a bad scenario in a late chunk, a killed
    worker) can never leave a truncated CSV or an unterminated JSON
    document behind — the temp file is removed and any pre-existing
    ``path`` is untouched.
    """
    if csv_path is None and json_path is None:
        raise ValueError("stream() needs csv_path and/or json_path")
    _check_backend(backend, batched=batched, force_simulator=force_simulator)
    t0 = time.perf_counter()
    n_fast = n_tl = n_slow = 0
    csv_tmp = None if csv_path is None else str(csv_path) + ".tmp"
    json_tmp = None if json_path is None else str(json_path) + ".tmp"
    csv_file = json_file = None
    ok = False
    try:
        if csv_tmp is not None:
            csv_file = open(csv_tmp, "w", newline="")
            writer = csv.writer(csv_file)
            writer.writerow(COLUMNS)
        if json_tmp is not None:
            json_file = open(json_tmp, "w")
            json_file.write('{\n  "columns": %s,\n  "rows": ['
                            % json.dumps(list(COLUMNS)))
        first = True
        for table in iter_tables(grid, force_simulator=force_simulator,
                                 warm_iterations=warm_iterations,
                                 batched=batched, backend=backend,
                                 chunk=chunk, jobs=jobs, seed=seed):
            if csv_file is not None:
                writer.writerows(
                    zip(*(table[k].tolist() for k in COLUMNS)))
            if json_file is not None:
                for r in rows_from_table(table):
                    json_file.write(("\n    " if first else ",\n    ")
                                    + json.dumps(r))
                    first = False
            f, tl, _ = method_counts(table)
            n_fast += f
            n_tl += tl
            n_slow += table_len(table) - f - tl
        elapsed = time.perf_counter() - t0
        n = n_fast + n_tl + n_slow
        rate = n / elapsed if elapsed else 0.0
        meta = {"n_scenarios": n, "elapsed_s": elapsed,
                "scenarios_per_sec": rate, "n_analytical": n_fast,
                "n_timeline": n_tl, "n_simulated": n_slow,
                "backend": backend}
        if json_file is not None:
            # trailer keys == RESULT_META_KEYS == the to_json key set
            # minus columns/rows (parity pinned by the tests)
            json_file.write(
                "\n  ]," + ",".join(f'\n  "{k}": {json.dumps(meta[k])}'
                                    for k in RESULT_META_KEYS) + "\n}\n")
        ok = True
    finally:
        for f in (csv_file, json_file):
            if f is not None:
                f.close()
        if ok:
            if csv_tmp is not None:
                os.replace(csv_tmp, csv_path)
            if json_tmp is not None:
                os.replace(json_tmp, json_path)
        else:
            for tmp in (csv_tmp, json_tmp):
                if tmp is not None and os.path.exists(tmp):
                    os.unlink(tmp)
    return meta


def stream_csv(grid: ScenarioGrid | Iterable[Scenario], path,
               **kw) -> dict:
    """:func:`stream` to a single CSV file."""
    return stream(grid, csv_path=path, **kw)


def stream_json(grid: ScenarioGrid | Iterable[Scenario], path,
                **kw) -> dict:
    """:func:`stream` to a single JSON document."""
    return stream(grid, json_path=path, **kw)


def evaluate_scenario(s: Scenario, method: str = "auto",
                      warm_iterations: int = 6, seed: int = 0) -> dict:
    """Evaluate one scenario; ``method`` is ``auto`` (closed form when
    exact, else the batched bucket-timeline kernel, else the
    simulator), ``analytical`` (raise unless the per-layer closed form
    applies) or ``simulator``.  ``seed`` keys the straggler draws."""
    s.validate()
    policy = resolve_policy(s)
    if method == "simulator":
        return _sim_eval(s, warm_iterations, seed=seed)
    if method == "analytical":
        if not has_fast_path(policy):
            raise ValueError(f"policy {s.policy!r} has no exact closed form")
        return _fast_eval(s, seed=seed)
    if method != "auto":
        raise ValueError(f"unknown method {method!r}")
    if has_fast_path(policy):
        return _fast_eval(s, seed=seed)
    if has_batched_path(policy):
        return eval_scenarios([s], seed=seed)[0]
    return _sim_eval(s, warm_iterations, seed=seed)
