"""The paper's DAG model of S-SGD (Section IV).

A training job is a DAG ``G = (V_c U V_n, E)`` where ``V_c`` are
*computing* tasks (per-layer forward/backward, model update), ``V_n``
are *communication* tasks (disk I/O, host-to-device copy, per-layer
gradient aggregation), and a directed edge ``(x, y)`` means task ``y``
may only start after ``x`` finishes.

``build_ssgd_dag`` reproduces Fig. 1 of the paper for an arbitrary
number of layers, workers and iterations, parameterized by an overlap
:class:`~repro.core.policies.Policy` — which is exactly how the paper
distinguishes Caffe-MPI / CNTK / MXNet / TensorFlow.
"""
from __future__ import annotations

import dataclasses
import enum
from dataclasses import dataclass, field
from typing import Callable, Iterable, Sequence

from repro.core.policies import Policy


class TaskKind(enum.Enum):
    COMPUTE = "compute"
    COMM = "comm"


# Channel name templates.  The simulator serializes tasks that share a
# channel; distinct channels run in parallel (GPU stream vs. PCIe vs.
# disk vs. the collective network, as in the paper's two task classes).
def gpu_channel(worker: int) -> str:
    return f"gpu:{worker}"


def disk_channel(worker: int) -> str:
    return f"disk:{worker}"


def pcie_channel(worker: int) -> str:
    return f"pcie:{worker}"


NET_CHANNEL = "net"

#: Shared checkpoint-store channel: crash restores read the same npz
#: store (:mod:`repro.checkpoint.ckpt`), so they serialize — which is
#: what makes the per-iteration fault penalty additive in the crash
#: count (see :class:`repro.core.het.FaultSpec`).
CKPT_CHANNEL = "ckpt"


@dataclass
class Task:
    tid: int
    name: str
    kind: TaskKind
    duration: float
    channel: str
    iteration: int = 0
    layer: int | None = None          # 1-based, as in the paper
    worker: int | None = None
    priority: float = 0.0             # lower = scheduled first on channel ties
    nbytes: float = 0.0               # payload for comm tasks


@dataclass
class DAG:
    """Directed acyclic graph of :class:`Task` with precedence edges."""

    tasks: dict[int, Task] = field(default_factory=dict)
    preds: dict[int, set[int]] = field(default_factory=dict)
    succs: dict[int, set[int]] = field(default_factory=dict)
    _next_id: int = 0

    # -- construction ---------------------------------------------------
    def add_task(self, name: str, kind: TaskKind, duration: float, channel: str,
                 **kw) -> int:
        if duration < 0:
            raise ValueError(f"negative duration for task {name}: {duration}")
        tid = self._next_id
        self._next_id += 1
        self.tasks[tid] = Task(tid, name, kind, float(duration), channel, **kw)
        self.preds[tid] = set()
        self.succs[tid] = set()
        return tid

    def add_edge(self, src: int, dst: int) -> None:
        if src == dst:
            raise ValueError("self edge")
        self.preds[dst].add(src)
        self.succs[src].add(dst)

    def add_edges(self, srcs: Iterable[int], dst: int) -> None:
        for s in srcs:
            self.add_edge(s, dst)

    # -- queries ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.tasks)

    def sources(self) -> list[int]:
        return [t for t in self.tasks if not self.preds[t]]

    def sinks(self) -> list[int]:
        return [t for t in self.tasks if not self.succs[t]]

    def topo_order(self) -> list[int]:
        """Kahn topological order; raises if the graph has a cycle."""
        indeg = {t: len(p) for t, p in self.preds.items()}
        ready = sorted([t for t, d in indeg.items() if d == 0])
        order: list[int] = []
        import heapq

        heapq.heapify(ready)
        while ready:
            t = heapq.heappop(ready)
            order.append(t)
            for s in self.succs[t]:
                indeg[s] -= 1
                if indeg[s] == 0:
                    heapq.heappush(ready, s)
        if len(order) != len(self.tasks):
            raise ValueError("DAG contains a cycle")
        return order

    def critical_path(self) -> tuple[float, list[int]]:
        """Makespan with infinite resources (longest path)."""
        finish: dict[int, float] = {}
        best_pred: dict[int, int | None] = {}
        for t in self.topo_order():
            start = 0.0
            bp = None
            for p in self.preds[t]:
                if finish[p] > start:
                    start, bp = finish[p], p
            finish[t] = start + self.tasks[t].duration
            best_pred[t] = bp
        end = max(finish, key=lambda t: finish[t])
        path = [end]
        while best_pred[path[-1]] is not None:
            path.append(best_pred[path[-1]])  # type: ignore[arg-type]
        return finish[end], list(reversed(path))

    def total_work(self) -> float:
        return sum(t.duration for t in self.tasks.values())


@dataclass(frozen=True)
class IterationCosts:
    """Per-iteration task durations feeding the DAG builder.

    This is the paper's Table I vocabulary: ``t_io``, ``t_h2d``,
    layer-wise ``t_f^(l)``, ``t_b^(l)``, ``t_c^(l)`` and ``t_u``.
    Comm durations are for the *collective* across all participating
    workers (layer-wise all-reduce), as measured in the paper's traces.
    """

    t_f: Sequence[float]              # forward, layer 1..L
    t_b: Sequence[float]              # backward, layer 1..L (index 0 = layer 1)
    t_c: Sequence[float]              # gradient all-reduce, layer 1..L
    t_io: float = 0.0
    t_h2d: float = 0.0
    t_u: float = 0.0
    grad_bytes: Sequence[float] | None = None   # per layer, for bucketing

    @property
    def num_layers(self) -> int:
        return len(self.t_f)

    def with_comm(self, t_c: Sequence[float],
                  grad_bytes: Sequence[float] | None = None) -> "IterationCosts":
        """Copy with the per-layer comm durations replaced — used by the
        sweep engine to re-cost the same compute profile under a
        different collective algorithm / interconnect without rebuilding
        the layer tables."""
        return dataclasses.replace(
            self, t_c=list(t_c),
            grad_bytes=self.grad_bytes if grad_bytes is None else list(grad_bytes))

    def __post_init__(self):
        if not (len(self.t_f) == len(self.t_b) == len(self.t_c)):
            raise ValueError("t_f, t_b, t_c must have equal length")
        if self.grad_bytes is not None and len(self.grad_bytes) != len(self.t_f):
            raise ValueError("grad_bytes length mismatch")


def _bucketize(costs: IterationCosts, policy: Policy,
               comm_scale: Callable[[float, float], float] | None) -> list[tuple[str, list[int], float]]:
    """Group layers (in backward order L..1) into communication buckets.

    Returns ``[(name, member_layers, duration)]`` in issue order.  With
    ``policy.bucket_bytes`` unset every learnable layer is its own
    bucket (the paper's layer-wise NCCL pattern).  With bucketing the
    durations are re-derived via ``comm_scale(total_bytes, total_time)``
    when byte sizes are known, else summed.

    Boundaries come from the shared
    :func:`repro.core.bucketsim.bucket_partition` — the one boundary
    rule this builder and the batched timeline kernel both consume, so
    the event-driven oracle and the batched path can never drift.
    """
    from repro.core.bucketsim import bucket_partition  # circular-safe

    if not policy.bucket_bytes:
        return [(f"comm_l{m + 1}", [m], costs.t_c[m])
                for [m] in bucket_partition(
                    [c > 0 for c in costs.t_c], None, None)]

    buckets: list[tuple[str, list[int], float]] = []
    for members in bucket_partition([c > 0 for c in costs.t_c],
                                    costs.grad_bytes, policy.bucket_bytes):
        cur_time = sum(costs.t_c[m] for m in members)
        cur_bytes = sum(costs.grad_bytes[m] for m in members) \
            if costs.grad_bytes is not None else 0.0
        dur = comm_scale(cur_bytes, cur_time) \
            if (comm_scale and cur_bytes) else cur_time
        buckets.append((f"comm_bucket{len(buckets)}", members, dur))
    return buckets


class SSGDDagBuilder:
    """Incremental Fig.-1 DAG construction, one iteration at a time.

    Holds the cross-iteration state (the previous update and H2D
    tasks) so callers can interleave :meth:`add_iteration` with
    incremental simulation — this is what lets
    :func:`repro.core.simulator.simulate_steady` stop building as soon
    as the update-task deltas converge instead of always paying the
    full warm-up cap.  :func:`build_ssgd_dag` wraps it for the common
    build-everything-up-front case.
    """

    def __init__(self, costs: IterationCosts, n_workers: int, policy: Policy,
                 comm_scale: Callable[[float, float], float] | None = None,
                 shared_compute: bool = False,
                 worker_scale: Sequence[float] | None = None,
                 sync_k: int | None = None,
                 crashed: Sequence[int] = (),
                 restart_s: float = 0.0):
        if n_workers < 1:
            raise ValueError("n_workers >= 1")
        if restart_s < 0:
            raise ValueError("restart_s must be >= 0")
        if worker_scale is not None:
            worker_scale = [float(s) for s in worker_scale]
            if len(worker_scale) != n_workers:
                raise ValueError(
                    f"worker_scale must have one entry per worker "
                    f"({n_workers}), got {len(worker_scale)}")
            if any(s <= 0 for s in worker_scale):
                raise ValueError("worker_scale entries must be > 0")
        self.dag = DAG()
        self.costs = costs
        self.n_workers = n_workers
        self.policy = policy
        self.n_iterations = 0
        # Per-worker compute-time multipliers (heterogeneous GPUs /
        # straggler jitter): worker ``w``'s forward and backward tasks
        # run ``worker_scale[w]`` x slower.  I/O, H2D, comm and the
        # update are deliberately unscaled — they live on their own
        # channels (disk/PCIe/net) or are HBM-bound (t_u).
        self._worker_scale = worker_scale
        # ``shared_compute`` serializes all workers on one compute
        # channel — models host-device oversubscription (N logical
        # devices on one core), used by examples/dag_validation.py.
        self._gpu_of = (lambda w: "gpu:shared") if shared_compute \
            else gpu_channel
        # bucket boundaries depend only on (costs, policy, comm_scale)
        self._buckets = _bucketize(costs, policy, comm_scale) \
            if n_workers > 1 else []
        # K-of-N partial synchronization: the aggregation and the model
        # update gate on the K *fastest* workers only (smallest
        # compute multiplier, ties broken by worker index — exactly the
        # K-th order statistic the closed form takes).  ``None`` keeps
        # the full-sync edge set bit-identical to the historical path.
        keff = n_workers if not sync_k or int(sync_k) <= 0 \
            else min(int(sync_k), n_workers)
        if keff < n_workers:
            ws = worker_scale if worker_scale is not None \
                else [1.0] * n_workers
            order = sorted(range(n_workers), key=lambda w: (ws[w], w))
            self._sync_workers: list[int] | None = sorted(order[:keff])
        else:
            self._sync_workers = None
        # Crash/recover events: each worker in ``crashed`` loses its
        # state every iteration and re-reads the checkpoint
        # (``restart_s`` seconds on the shared CKPT_CHANNEL) before the
        # model update may broadcast.
        self._crashed = sorted({int(w) for w in crashed})
        if any(w < 0 or w >= n_workers for w in self._crashed):
            raise ValueError("crashed worker index out of range")
        self._restart_s = float(restart_s)
        self._prev_update: int | None = None
        self._prev_h2d: list[int] = []

    def add_iteration(self) -> int:
        """Append one iteration's tasks and edges; returns the
        iteration's ``update`` task id."""
        g, costs, policy = self.dag, self.costs, self.policy
        L = costs.num_layers
        it = self.n_iterations
        prev_update, prev_h2d = self._prev_update, self._prev_h2d

        # --- I/O + H2D (communication tasks T0-T7 in Fig. 1) -----------
        h2d_tasks = []
        for w in range(self.n_workers):
            io = g.add_task(f"io_w{w}", TaskKind.COMM, costs.t_io,
                            disk_channel(w), iteration=it, worker=w)
            # Overlapped I/O: next fetch only waits for the previous fetch
            # (disk channel); otherwise it waits for the previous update.
            if prev_update is not None and not policy.overlap_io:
                g.add_edge(prev_update, io)
            if prev_h2d:
                # Single staging buffer: the next fetch reuses the buffer
                # freed by the previous upload, so the prefetch stage has
                # period t_io + t_h2d — exactly the paper's Eq. (3)/(5)
                # term max(t_io + t_h2d, ...).
                g.add_edge(prev_h2d[w], io)
            h2d = g.add_task(f"h2d_w{w}", TaskKind.COMM, costs.t_h2d,
                             pcie_channel(w), iteration=it, worker=w)
            g.add_edge(io, h2d)
            # Early H2D (Caffe-MPI's GPU-side buffer) starts right after its
            # fetch; otherwise it must wait for the previous model update
            # (no spare device buffer to write into).
            if prev_update is not None and not policy.h2d_early:
                g.add_edge(prev_update, h2d)
            if prev_h2d:
                g.add_edge(prev_h2d[w], h2d)
            h2d_tasks.append(h2d)

        # --- forward, layer 1..L ---------------------------------------
        scale = self._worker_scale
        fwd: list[list[int]] = [[] for _ in range(L)]
        for w in range(self.n_workers):
            ws = 1.0 if scale is None else scale[w]
            prev = h2d_tasks[w]
            for l in range(L):
                t = g.add_task(f"fwd_l{l + 1}_w{w}", TaskKind.COMPUTE,
                               costs.t_f[l] * ws, self._gpu_of(w),
                               iteration=it,
                               layer=l + 1, worker=w, priority=float(l))
                g.add_edge(prev, t)
                if l == 0 and prev_update is not None:
                    g.add_edge(prev_update, t)
                fwd[l].append(t)
                prev = t

        # --- backward, layer L..1 --------------------------------------
        bwd: dict[int, list[int]] = {}
        for w in range(self.n_workers):
            ws = 1.0 if scale is None else scale[w]
            prev = fwd[L - 1][w]
            for l in range(L - 1, -1, -1):
                t = g.add_task(f"bwd_l{l + 1}_w{w}", TaskKind.COMPUTE,
                               costs.t_b[l] * ws, self._gpu_of(w),
                               iteration=it,
                               layer=l + 1, worker=w,
                               priority=float(2 * L - l))
                g.add_edge(prev, t)
                bwd.setdefault(l, []).append(t)
                prev = t
        last_bwd = [bwd[0][w] for w in range(self.n_workers)]  # layer 1 last
        # Partial sync: only the K participants' gradients gate the
        # aggregation and the update.  Non-participants keep training
        # (their tasks still occupy their own channels) but nothing
        # downstream waits for them.
        sync = self._sync_workers
        sync_last_bwd = last_bwd if sync is None \
            else [last_bwd[w] for w in sync]

        # --- gradient aggregation (comm tasks T32-T34) -----------------
        comm_tasks: list[int] = []
        prev_comm: int | None = None
        for bname, members, dur in self._buckets:
            # ByteScheduler semantics (policies.py): priority is the
            # bucket's earliest layer — layer-1/earlier-needed
            # tensors overtake on a priority-scheduled net channel
            # (lower value = scheduled first).  ``members`` is in
            # backward order, so the earliest layer is members[-1].
            c = g.add_task(bname, TaskKind.COMM, dur, NET_CHANNEL,
                           iteration=it, layer=members[0] + 1,
                           priority=float(members[-1]),
                           nbytes=sum(costs.grad_bytes[m] for m in members)
                           if costs.grad_bytes is not None else 0.0)
            if policy.overlap_comm:
                # WFBP: ready as soon as every participating worker
                # finished the backward of every member layer.
                for m in members:
                    g.add_edges(bwd[m] if sync is None
                                else [bwd[m][w] for w in sync], c)
            else:
                # CNTK: aggregation only after the entire backward pass.
                g.add_edges(sync_last_bwd, c)
            if prev_comm is not None and policy.serialize_comm:
                g.add_edge(prev_comm, c)
            prev_comm = c
            comm_tasks.append(c)

        # --- checkpoint restores (crash/recover events) ----------------
        # A crashed worker re-reads the checkpoint before the update may
        # broadcast.  Restores gate on the same predecessors the update
        # would (the sync point is where the crash is detected) and
        # chain on the shared checkpoint store, so an iteration with
        # ``c`` crashes finishes exactly ``c * restart_s`` later.
        restores: list[int] = []
        for w in self._crashed:
            r = g.add_task(f"restore_w{w}", TaskKind.COMM,
                           self._restart_s, CKPT_CHANNEL, iteration=it,
                           worker=w, priority=float(3 * L))
            g.add_edges(sync_last_bwd, r)
            g.add_edges(comm_tasks, r)
            if restores:
                g.add_edge(restores[-1], r)
            restores.append(r)

        # --- model update (T35) ----------------------------------------
        # The update runs on a *participant's* GPU stream: under K-of-N
        # a non-participant straggler keeps its own channel busy past
        # the sync point, and parking the update there would serialize
        # the whole pipeline behind a worker nobody waits for.
        upd = g.add_task("update", TaskKind.COMPUTE, costs.t_u,
                         self._gpu_of(0 if sync is None else sync[0]),
                         iteration=it, priority=float(3 * L + 1))
        g.add_edges(sync_last_bwd, upd)
        g.add_edges(comm_tasks, upd)
        g.add_edges(restores, upd)
        self._prev_update = upd
        self._prev_h2d = h2d_tasks
        self.n_iterations += 1
        return upd


def build_ssgd_dag(
    costs: IterationCosts,
    n_workers: int,
    policy: Policy,
    n_iterations: int = 1,
    comm_scale: Callable[[float, float], float] | None = None,
    shared_compute: bool = False,
    worker_scale: Sequence[float] | None = None,
    sync_k: int | None = None,
    crashed: Sequence[int] = (),
    restart_s: float = 0.0,
) -> DAG:
    """Build the S-SGD DAG of Fig. 1 for ``n_iterations`` iterations.

    Single-GPU training (``n_workers == 1``) degenerates to Eq. (1):
    the comm tasks get zero duration and the graph is a chain.

    ``comm_scale(total_bytes, naive_total_time)`` maps a fused bucket to
    its collective duration (used by the bucketing policy to model the
    latency amortization the paper calls for in §VII).
    ``worker_scale`` gives per-worker compute-time multipliers
    (heterogeneous GPUs / straggler jitter draws) — the per-worker DAG
    is the agreement oracle for the heterogeneous batched engine.
    ``sync_k`` enables K-of-N partial synchronization (``None``/``0`` =
    full sync); ``crashed`` workers pay a serialized ``restart_s``
    checkpoint restore before each iteration's update.
    """
    b = SSGDDagBuilder(costs, n_workers, policy, comm_scale=comm_scale,
                       shared_compute=shared_compute,
                       worker_scale=worker_scale, sync_k=sync_k,
                       crashed=crashed, restart_s=restart_s)
    for _ in range(n_iterations):
        b.add_iteration()
    return b.dag
