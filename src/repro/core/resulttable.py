"""Columnar result tables: the tidy-results schema as NumPy columns.

The sweep pipeline's result unit is a **table** — a dict mapping each
:data:`COLUMNS` key to one ``(n,)`` NumPy array (object arrays for the
label columns, ``int64``/``float64`` for the numeric ones).  Tables
flow straight out of the batched kernels
(:meth:`repro.core.batched.GridRun.table_slice`), through the parallel
execution layer (:mod:`repro.core.parallel`) and into
:class:`repro.core.sweep.SweepResult` without ever materializing a
``list[dict]`` on the hot path; per-row dicts are a *view* built on
demand by :func:`rows_from_table` (``.tolist()`` converts whole
columns to Python scalars in C, so even the compat view never loops
per value in Python).

This module is a leaf — :mod:`repro.core.batched`,
:mod:`repro.core.batched_jax` and :mod:`repro.core.sweep` all import
the schema from here, which is what lets the kernel emit result
columns directly without a circular import.
"""
from __future__ import annotations

from typing import Sequence

import numpy as np

#: Column order of the tidy results table (the single source of truth;
#: :mod:`repro.core.sweep` re-exports it).  ``het`` / ``straggler``
#: are the heterogeneity axes (label ``"none"`` when unused);
#: ``sync_k`` / ``faults`` the failure-model axes (``sync_k = 0`` means
#: full synchronization, a positive K means the iteration waits for the
#: first K of N gradients; ``faults`` is the ``fail:`` spec label,
#: ``"none"`` when unused); ``t_mean_s``/``t_p95_s``/``t_p99_s`` are
#: the Monte Carlo tail statistics of the iteration time — equal to
#: ``iteration_time_s`` on deterministic rows (a point mass has no
#: tails).
COLUMNS = ("workload", "cluster", "n_workers", "policy", "collective",
           "interconnect", "het", "straggler", "sync_k", "faults",
           "batch_per_gpu",
           "iteration_time_s", "samples_per_sec", "speedup",
           "t_comm_s", "t_comp_s", "t_mean_s", "t_p95_s", "t_p99_s",
           "method")

#: String-valued columns, stored as object arrays (shared-pointer
#: labels: fancy-indexing an object array copies references, never
#: string bytes).
LABEL_COLUMNS = ("workload", "cluster", "policy", "collective",
                 "interconnect", "het", "straggler", "faults", "method")

#: Integer-valued columns (int64).
INT_COLUMNS = ("n_workers", "sync_k", "batch_per_gpu")

#: Float-valued columns (float64).
FLOAT_COLUMNS = ("iteration_time_s", "samples_per_sec", "speedup",
                 "t_comm_s", "t_comp_s", "t_mean_s", "t_p95_s",
                 "t_p99_s")

#: Evaluation-path labels indexed by the policy tier code the batched
#: select computes (0 = closed form, 1 = bucket timeline, 2 =
#: event-driven simulator).
METHOD_LABELS = np.array(["analytical", "timeline", "simulated"],
                         dtype=object)


def _dtype_of(column: str):
    if column in LABEL_COLUMNS:
        return object
    if column in INT_COLUMNS:
        return np.int64
    return np.float64


def empty_table() -> dict[str, np.ndarray]:
    """A zero-row table with the canonical dtypes."""
    return {k: np.empty(0, dtype=_dtype_of(k)) for k in COLUMNS}


def table_from_rows(rows: Sequence[dict]) -> dict[str, np.ndarray]:
    """Columnar table from tidy row dicts (the per-scenario reference
    paths still produce rows; everything downstream speaks tables)."""
    if not rows:
        return empty_table()
    return {k: np.array([r[k] for r in rows], dtype=_dtype_of(k))
            for k in COLUMNS}


def concat_tables(tables: Sequence[dict]) -> dict[str, np.ndarray]:
    """Concatenate chunk tables in order into one table."""
    tables = [t for t in tables if len(next(iter(t.values())))]
    if not tables:
        return empty_table()
    if len(tables) == 1:
        return tables[0]
    return {k: np.concatenate([t[k] for t in tables]) for k in COLUMNS}


def table_len(table: dict) -> int:
    return len(table["workload"])


def slice_table(table: dict, lo: int, hi: int) -> dict[str, np.ndarray]:
    """Row slice ``[lo, hi)`` of a table, as column **views** (NumPy
    basic slicing — no bytes copied): how the sweep service
    de-multiplexes one coalesced kernel table back into per-query
    results."""
    return {k: table[k][lo:hi] for k in COLUMNS}


def rows_from_table(table: dict,
                    indices: np.ndarray | None = None) -> list[dict]:
    """Tidy row dicts from a table — the compat view.  ``indices``
    selects (and orders) a subset of rows; ``None`` takes the whole
    table in order."""
    def col(k):
        c = table[k] if indices is None else table[k][indices]
        return c.tolist()

    return [dict(zip(COLUMNS, values))
            for values in zip(*(col(k) for k in COLUMNS))]


def fill_rows(table: dict, indices: Sequence[int],
              rows: Sequence[dict]) -> None:
    """Overwrite ``table``'s rows at ``indices`` with ``rows`` in
    place (the simulator-fallback interleave)."""
    idx = np.asarray(list(indices), dtype=np.int64)
    for k in COLUMNS:
        table[k][idx] = np.array([r[k] for r in rows], dtype=_dtype_of(k))


def method_counts(table: dict) -> tuple[int, int, int]:
    """``(n_analytical, n_timeline, n_simulated)`` from the method
    column."""
    m = table["method"]
    n_fast = int(np.count_nonzero(m == "analytical"))
    n_tl = int(np.count_nonzero(m == "timeline"))
    return n_fast, n_tl, len(m) - n_fast - n_tl
