"""Analytic FLOPs / bytes model for the assigned transformer
architectures — the MODEL_FLOPS side of the roofline (exact for
matmuls; elementwise ignored).

Conventions: FLOPs are multiply-accumulate*2.  Backward = 2x forward.
Attention terms use 4*S*ctx*H*hd per layer forward (QK^T + PV);
sliding-window layers replace ctx with min(S, window); MoE counts only
routed-active + shared expert parameters (6*N_active*D).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.configs.shapes import InputShape
from repro.models.common import ModelConfig


@dataclass(frozen=True)
class StepCost:
    flops: float              # global flops for one step
    hbm_bytes: float          # global HBM traffic estimate
    model_flops: float        # 6*N*D (train) or 2*N*D (inference)
    param_bytes: float
    n_params: float
    n_active_params: float


def _block_params(cfg: ModelConfig, kind: str) -> tuple[float, float]:
    """(total, active) parameter count of one block of ``kind``."""
    d, hd = cfg.d_model, cfg.head_size
    H, K = cfg.num_heads, cfg.kv_heads
    attn = d * H * hd + 2 * d * K * hd + H * hd * d
    if cfg.num_experts:
        e = cfg.num_experts * 3 * d * cfg.moe_d_ff
        e_active = cfg.experts_per_token * 3 * d * cfg.moe_d_ff
        shared = 3 * d * cfg.shared_expert_d_ff if cfg.shared_expert_d_ff else 0
        router = d * cfg.num_experts
        ffn, ffn_active = e + shared + router, e_active + shared + router
    else:
        n_mats = 3 if cfg.mlp_gated else 2
        ffn = ffn_active = n_mats * d * cfg.d_ff
    if kind in ("G", "L"):
        return attn + ffn, attn + ffn_active
    if kind == "C":
        return 2 * attn + ffn, 2 * attn + ffn_active
    if kind == "R":
        W = cfg.rnn_size
        rec = 2 * d * W + 2 * W * W + W * d + cfg.conv1d_width * W
        return rec + ffn, rec + ffn_active
    if kind == "W":
        tm = 6 * d * d                  # r,k,v,w,g,o projections
        cm = d * cfg.d_ff * 2 + d * d
        return tm + cm, tm + cm
    raise ValueError(kind)


def _pattern_of(cfg: ModelConfig) -> str:
    return (cfg.layer_pattern * cfg.num_units) + cfg.remainder_pattern


def param_counts(cfg: ModelConfig) -> tuple[float, float]:
    total = active = cfg.vocab_size * cfg.d_model   # embedding
    if not cfg.tie_embeddings:
        total += cfg.d_model * cfg.vocab_size
        active += cfg.d_model * cfg.vocab_size
    for kind in _pattern_of(cfg):
        t, a = _block_params(cfg, kind)
        total, active = total + t, active + a
    if cfg.arch_type == "audio":
        d = cfg.d_model
        enc_block = 4 * d * d + 2 * d * cfg.d_ff
        total += cfg.encoder_layers * enc_block
        active += cfg.encoder_layers * enc_block
    return float(total), float(active)


def _attn_ctx(cfg: ModelConfig, kind: str, S: int) -> float:
    if kind == "L" and cfg.sliding_window:
        return float(min(S, cfg.sliding_window))
    if kind == "C":
        return float(cfg.encoder_seq or cfg.num_image_tokens or S)
    return float(S)


def _attention_flops_fwd(cfg: ModelConfig, S: int, B: int) -> float:
    """Score+value matmul flops for one full forward over (B, S)."""
    H, hd = cfg.num_heads, cfg.head_size
    total = 0.0
    for kind in _pattern_of(cfg):
        if kind == "G":
            # causal: average context S/2
            total += 2.0 * B * S * S * H * hd
        elif kind == "L":
            total += 4.0 * B * S * _attn_ctx(cfg, kind, S) * H * hd
        elif kind == "C":
            # self (causal) + cross over encoder tokens
            total += 2.0 * B * S * S * H * hd
            total += 4.0 * B * S * _attn_ctx(cfg, kind, S) * H * hd
        elif kind == "W":
            total += 4.0 * B * S * hd * cfg.d_model    # state updates per token
        elif kind == "R":
            total += 8.0 * B * S * cfg.rnn_size        # elementwise recurrence
    return total


@dataclass(frozen=True)
class BlockCost:
    """One DAG layer of an ``llm:`` workload: the embedding, one
    pattern block, one audio-encoder block, or the untied LM head."""

    name: str
    flops_fwd: float          # forward flops for ONE sequence of seq_len tokens
    params: float             # total learnable params (gradient payload)
    active_params: float      # per-token-active params (compute source)


def _block_attn_flops_fwd(cfg: ModelConfig, kind: str, S: int) -> float:
    """Score+value matmul forward flops of one block for one sequence —
    the per-block slice of :func:`_attention_flops_fwd` (B=1)."""
    H, hd = cfg.num_heads, cfg.head_size
    if kind == "G":
        return 2.0 * S * S * H * hd
    if kind == "L":
        return 4.0 * S * _attn_ctx(cfg, kind, S) * H * hd
    if kind == "C":
        return 2.0 * S * S * H * hd + 4.0 * S * _attn_ctx(cfg, kind, S) * H * hd
    if kind == "W":
        return 4.0 * S * hd * cfg.d_model
    if kind == "R":
        return 8.0 * S * cfg.rnn_size
    raise ValueError(kind)


def block_cost_table(cfg: ModelConfig, seq_len: int) -> list[BlockCost]:
    """Slice the architecture into per-block layer costs — the
    ``llm:`` workload provider's cost source.

    Follows :func:`param_counts` / :func:`step_cost` exactly: every
    parameter matrix contributes ``2 * active_params * seq_len`` forward
    matmul flops per sequence (embeddings included, per the 6ND
    convention) plus the block kind's attention term, so

    * ``sum(params)`` == ``param_counts(cfg)[0]``,
    * ``sum(active_params)`` == ``param_counts(cfg)[1]``,
    * ``3 * B * sum(flops_fwd)`` == ``step_cost(cfg, train).flops``
      when the shapes' ``seq_len`` match (train = 3x forward).
    """
    S = seq_len
    emb = float(cfg.vocab_size * cfg.d_model)
    table = [BlockCost("embed", 2.0 * emb * S, emb, emb)]
    for i, kind in enumerate(_pattern_of(cfg)):
        total, active = _block_params(cfg, kind)
        table.append(BlockCost(
            f"block{i}_{kind}",
            2.0 * active * S + _block_attn_flops_fwd(cfg, kind, S),
            float(total), float(active)))
    if cfg.arch_type == "audio":
        d = cfg.d_model
        enc = float(4 * d * d + 2 * d * cfg.d_ff)
        for j in range(cfg.encoder_layers):
            table.append(BlockCost(f"enc{j}", 2.0 * enc * S, enc, enc))
    if not cfg.tie_embeddings:
        table.append(BlockCost("lm_head", 2.0 * emb * S, emb, emb))
    return table


def step_cost(cfg: ModelConfig, shape: InputShape) -> StepCost:
    B, S = shape.global_batch, shape.seq_len
    n_total, n_active = param_counts(cfg)
    pbytes = 2.0 * n_total                              # bf16
    if shape.kind == "train":
        D = B * S
        matmul = 6.0 * n_active * D
        attn = 3.0 * _attention_flops_fwd(cfg, S, B)
        flops = matmul + attn
        model_flops = 6.0 * n_active * D
        # params read fwd+bwd (bf16) + grads written + SGD-momentum
        # update (f32 m read/write + param read/write)
        hbm = 2 * pbytes + pbytes + 12.0 * n_total \
            + 20.0 * D * cfg.d_model * len(_pattern_of(cfg))
    elif shape.kind == "prefill":
        D = B * S
        flops = 2.0 * n_active * D + _attention_flops_fwd(cfg, S, B)
        model_flops = 2.0 * n_active * D
        hbm = pbytes + 4.0 * D * cfg.d_model * len(_pattern_of(cfg))
    else:  # decode: one token per sequence, cache of length S
        D = B
        flops = 2.0 * n_active * D
        cache_bytes = 0.0
        for kind in _pattern_of(cfg):
            if kind in ("G", "C"):
                ctx = S
            elif kind == "L":
                ctx = min(S, cfg.sliding_window or S)
            else:
                ctx = 0
            if ctx:
                flops += 4.0 * B * ctx * cfg.num_heads * cfg.head_size
                cache_bytes += 2.0 * B * ctx * cfg.kv_heads * cfg.head_size * 2
            if kind == "W":
                hd = 64
                H = cfg.d_model // hd
                flops += 4.0 * B * H * hd * hd
                cache_bytes += 4.0 * B * H * hd * hd
            if kind == "R":
                flops += 8.0 * B * cfg.rnn_size
                cache_bytes += 4.0 * B * cfg.rnn_size
        model_flops = 2.0 * n_active * D
        hbm = pbytes + cache_bytes                     # read params + cache
    return StepCost(flops=flops, hbm_bytes=hbm, model_flops=model_flops,
                    param_bytes=pbytes, n_params=n_total,
                    n_active_params=n_active)
