"""NumPy / jax.numpy namespace dispatch for the shared cost kernels.

The dtype-polymorphic numerics — the collective models in
:mod:`repro.core.hardware`, the WFBP prefix-max residual in
:mod:`repro.core.analytical` and the bucket-timeline residual in
:mod:`repro.core.bucketsim` — are written once against whichever array
namespace their inputs live in: plain NumPy for the batched oracle
engine (:mod:`repro.core.batched`) and ``jax.numpy`` for the
jit/vmap-compiled kernels (:mod:`repro.core.batched_jax`), including
under tracing (``jax.Array`` covers both concrete device arrays and
the tracers ``vmap``/``grad``/``jit`` substitute).

jax is resolved lazily through ``sys.modules`` so importing the NumPy
engine never imports (or initializes) jax.
"""
from __future__ import annotations

import sys
from typing import Any

import numpy as np


def is_jax_array(x: Any) -> bool:
    """True when ``x`` is a jax array *or tracer* — without importing
    jax if nothing has imported it yet (then nothing can be one)."""
    jax = sys.modules.get("jax")
    return jax is not None and isinstance(x, jax.Array)


def array_namespace(*args: Any):
    """``jax.numpy`` if any argument is a jax array/tracer, else
    :mod:`numpy` — the single dispatch point of the polymorphic
    kernels."""
    for a in args:
        if is_jax_array(a):
            import jax.numpy as jnp
            return jnp
    return np
