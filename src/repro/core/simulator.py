"""Event-driven list scheduler for the S-SGD DAG.

Executes a :class:`repro.core.dag.DAG` under *resource constraints*:
each channel (GPU stream per worker, disk, PCIe, collective network)
runs one task at a time.  This is what turns the paper's Fig. 1
precedence graph into an iteration-time prediction — and it reproduces
Eqs. (2), (3) and (5) exactly when given the matching policy (verified
by property tests).
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable

from repro.core.dag import (DAG, NET_CHANNEL, IterationCosts, Task, TaskKind,
                            build_ssgd_dag)


@dataclass(frozen=True)
class ScheduledTask:
    task: Task
    start: float
    finish: float


@dataclass
class SimResult:
    makespan: float
    schedule: dict[int, ScheduledTask]
    channel_busy: dict[str, float]

    def utilization(self, channel: str) -> float:
        return self.channel_busy.get(channel, 0.0) / self.makespan if self.makespan else 0.0

    def tasks_on(self, channel: str) -> list[ScheduledTask]:
        return sorted((s for s in self.schedule.values() if s.task.channel == channel),
                      key=lambda s: s.start)

    def timeline(self) -> list[ScheduledTask]:
        return sorted(self.schedule.values(), key=lambda s: (s.start, s.task.channel))

    def iteration_times(self) -> list[float]:
        """Finish time of each iteration's update task (cumulative).

        Empty when the DAG has no ``update`` task (``n_iterations=0``
        or a custom graph) — callers that need at least one iteration
        (:meth:`steady_iteration_time`) raise a clear error instead of
        indexing into nothing.
        """
        ups = sorted((s for s in self.schedule.values() if s.task.name == "update"),
                     key=lambda s: s.task.iteration)
        return [s.finish for s in ups]

    def steady_iteration_time(self) -> float:
        """Per-iteration time once the pipeline is warm (last iter delta).

        Raises ``ValueError`` when the schedule contains no ``update``
        task — e.g. a DAG built with ``n_iterations=0`` or a custom
        graph without an update node.
        """
        it = self.iteration_times()
        if not it:
            raise ValueError(
                "schedule contains no 'update' task (was the DAG built "
                "with n_iterations=0, or without an update node?); "
                "steady-state iteration time is undefined")
        if len(it) == 1:
            return it[0]
        return it[-1] - it[-2]


def simulate(dag: DAG, priority_channels: frozenset[str] | None = None) -> SimResult:
    """List-schedule ``dag`` on constrained channels.

    Tasks become *ready* when all predecessors finished; each channel
    executes ready tasks one at a time.  Ready tasks on the same channel
    are ordered by (ready_time, priority, tid) — FIFO with the task's
    ``priority`` as a tie-break — unless the channel is in
    ``priority_channels`` in which case the channel takes, each time it
    frees up, the smallest-``priority`` task among those already ready
    (ByteScheduler-style preemption-free priority queueing).  Priority
    scheduling is *work-conserving*: the channel never idles waiting
    for a higher-priority task that has not been released yet.
    """
    priority_channels = priority_channels or frozenset()
    indeg = {t: len(p) for t, p in dag.preds.items()}
    ready_time = {t: 0.0 for t in dag.tasks}

    # Per-channel queues of ready tasks: a (ready, prio, tid) heap for
    # FIFO channels, a plain scanned list for priority channels (the
    # candidate depends on when the channel frees, so no static heap
    # order is correct — queues are short, the scan is cheap).
    queues: dict[str, list[tuple]] = {}
    channel_free: dict[str, float] = {}

    def push(tid: int, at: float):
        ch = dag.tasks[tid].channel
        prio = dag.tasks[tid].priority
        queues.setdefault(ch, [])
        channel_free.setdefault(ch, 0.0)
        if ch in priority_channels:
            queues[ch].append((prio, at, tid))
        else:
            heapq.heappush(queues[ch], ((at, prio, tid), tid))

    for t, d in indeg.items():
        if d == 0:
            push(t, 0.0)

    schedule: dict[int, ScheduledTask] = {}
    channel_busy: dict[str, float] = {}
    # Event loop: repeatedly pick the channel whose chosen task can
    # start earliest.
    n_done = 0
    n_total = len(dag.tasks)
    while n_done < n_total:
        best = None
        best_item = None
        for ch, q in queues.items():
            if not q:
                continue
            if ch in priority_channels:
                # earliest instant the channel can start anything...
                start = max(channel_free[ch], min(r for _, r, _ in q))
                # ...and the best priority among tasks ready by then
                item = min(it for it in q if it[1] <= start)
                cand = (start, item, ch, item[2])
            else:
                key, tid = q[0]
                start = max(channel_free[ch], ready_time[tid])
                item = None
                cand = (start, key, ch, tid)
            if best is None or cand < best:
                best, best_item = cand, item
        if best is None:
            raise RuntimeError("deadlock: no ready task but DAG not done (cycle?)")
        start, key, ch, tid = best
        if ch in priority_channels:
            queues[ch].remove(best_item)
        else:
            heapq.heappop(queues[ch])
        task = dag.tasks[tid]
        finish = start + task.duration
        schedule[tid] = ScheduledTask(task, start, finish)
        channel_free[ch] = finish
        channel_busy[ch] = channel_busy.get(ch, 0.0) + task.duration
        n_done += 1
        for s in dag.succs[tid]:
            indeg[s] -= 1
            ready_time[s] = max(ready_time[s], finish)
            if indeg[s] == 0:
                push(s, ready_time[s])

    makespan = max((s.finish for s in schedule.values()), default=0.0)
    return SimResult(makespan, schedule, channel_busy)


def simulate_policy(
    costs: IterationCosts,
    n_workers: int,
    policy,
    n_iterations: int = 6,
    comm_scale: Callable[[float, float], float] | None = None,
) -> SimResult:
    """Build the Fig.-1 S-SGD DAG for ``policy`` and list-schedule it.

    One-stop entry point shared by the predictor, the sweep engine's
    simulator fallback, and the property tests; honors
    ``policy.priority_comm`` by putting the collective channel in
    priority-scheduling mode.
    """
    g = build_ssgd_dag(costs, n_workers, policy, n_iterations=n_iterations,
                       comm_scale=comm_scale)
    prio = frozenset([NET_CHANNEL]) if getattr(policy, "priority_comm", False) \
        else None
    return simulate(g, priority_channels=prio)


def simulate_steady(
    costs: IterationCosts,
    n_workers: int,
    policy,
    n_iterations: int = 6,
    comm_scale: Callable[[float, float], float] | None = None,
) -> float:
    """:func:`simulate_policy`, reduced to the warm per-iteration time
    in seconds."""
    return simulate_policy(costs, n_workers, policy, n_iterations,
                           comm_scale).steady_iteration_time()
