"""Event-driven list scheduler for the S-SGD DAG.

Executes a :class:`repro.core.dag.DAG` under *resource constraints*:
each channel (GPU stream per worker, disk, PCIe, collective network)
runs one task at a time.  This is what turns the paper's Fig. 1
precedence graph into an iteration-time prediction — and it reproduces
Eqs. (2), (3) and (5) exactly when given the matching policy (verified
by property tests).

The scheduler is a **global event heap** over per-channel candidates:
each channel keeps its ready queue, and whenever the queue or the
channel's free time changes, its current best candidate (start time,
queue key) is pushed onto one shared heap with a per-channel version
stamp — stale entries are discarded on pop (lazy invalidation).  This
replaces the historical rescan of every channel per event (O(events x
channels)) with O(events x log) work, which matters once the oracle is
property-tested against the batched kernels on real grids.

:class:`Simulation` is incremental: tasks appended to the DAG after a
completed :meth:`~Simulation.run` are picked up by
:meth:`~Simulation.extend`, which is what lets
:func:`simulate_steady` grow the DAG one iteration at a time and stop
as soon as the steady state is reached instead of always paying the
full warm-up cap.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field
from typing import Callable

from repro.core.dag import (DAG, NET_CHANNEL, IterationCosts, SSGDDagBuilder,
                            Task, TaskKind)

#: Relative tolerance for steady-state detection: two consecutive
#: update-delta pairs must agree this tightly before the warm-up loop
#: stops early.  Steady pipelines are exactly periodic, so the deltas
#: typically repeat bit-for-bit — the tolerance only absorbs float
#: noise in the accumulated finish times.
STEADY_RTOL = 1e-9


@dataclass(frozen=True)
class ScheduledTask:
    task: Task
    start: float
    finish: float


@dataclass
class SimResult:
    makespan: float
    schedule: dict[int, ScheduledTask]
    channel_busy: dict[str, float]
    #: Iterations actually simulated when the schedule came from
    #: :func:`simulate_policy` / :func:`simulate_steady` — with
    #: ``auto_steady`` this is where the warm-up converged (<= the
    #: requested cap).  ``None`` for raw :func:`simulate` calls.
    n_iterations_used: int | None = None

    def utilization(self, channel: str) -> float:
        return self.channel_busy.get(channel, 0.0) / self.makespan if self.makespan else 0.0

    def tasks_on(self, channel: str) -> list[ScheduledTask]:
        return sorted((s for s in self.schedule.values() if s.task.channel == channel),
                      key=lambda s: s.start)

    def timeline(self) -> list[ScheduledTask]:
        return sorted(self.schedule.values(), key=lambda s: (s.start, s.task.channel))

    def iteration_times(self) -> list[float]:
        """Finish time of each iteration's update task (cumulative).

        Empty when the DAG has no ``update`` task (``n_iterations=0``
        or a custom graph) — callers that need at least one iteration
        (:meth:`steady_iteration_time`) raise a clear error instead of
        indexing into nothing.
        """
        ups = sorted((s for s in self.schedule.values() if s.task.name == "update"),
                     key=lambda s: s.task.iteration)
        return [s.finish for s in ups]

    def steady_iteration_time(self) -> float:
        """Per-iteration time once the pipeline is warm (last iter delta).

        Raises ``ValueError`` when the schedule contains no ``update``
        task — e.g. a DAG built with ``n_iterations=0`` or a custom
        graph without an update node.
        """
        it = self.iteration_times()
        if not it:
            raise ValueError(
                "schedule contains no 'update' task (was the DAG built "
                "with n_iterations=0, or without an update node?); "
                "steady-state iteration time is undefined")
        if len(it) == 1:
            return it[0]
        return it[-1] - it[-2]


class Simulation:
    """Incremental list scheduler over a (possibly growing) DAG.

    Tasks become *ready* when all predecessors finished; each channel
    executes ready tasks one at a time.  Ready tasks on the same channel
    are ordered by (ready_time, priority, tid) — FIFO with the task's
    ``priority`` as a tie-break — unless the channel is in
    ``priority_channels`` in which case the channel takes, each time it
    frees up, the smallest-``priority`` task among those already ready
    (ByteScheduler-style preemption-free priority queueing).  Priority
    scheduling is *work-conserving*: the channel never idles waiting
    for a higher-priority task that has not been released yet.

    After :meth:`run` completes, more tasks may be appended to the DAG
    (their predecessors must all be already-scheduled tasks or fellow
    new tasks — exactly what :class:`repro.core.dag.SSGDDagBuilder`
    produces); :meth:`extend` ingests them and :meth:`run` continues.
    Committed start/finish times never change, and the combined
    schedule is identical to simulating the full DAG in one shot: every
    channel's earlier-iteration tasks transitively precede its
    later-iteration ones, so nothing committed early could have been
    preempted by work that arrives later.
    """

    def __init__(self, dag: DAG,
                 priority_channels: frozenset[str] | None = None):
        self.dag = dag
        self.priority_channels = priority_channels or frozenset()
        self.schedule: dict[int, ScheduledTask] = {}
        self.channel_busy: dict[str, float] = {}
        self._queues: dict[str, list] = {}
        self._channel_free: dict[str, float] = {}
        self._version: dict[str, int] = {}
        self._heap: list = []
        self._indeg: dict[int, int] = {}
        self._ready_time: dict[int, float] = {}
        self._ingested = 0                  # tids are dense and ordered
        self._n_done = 0
        self.extend()

    # -- task intake ----------------------------------------------------
    def _push(self, tid: int, at: float) -> None:
        ch = self.dag.tasks[tid].channel
        prio = self.dag.tasks[tid].priority
        q = self._queues.setdefault(ch, [])
        self._channel_free.setdefault(ch, 0.0)
        self._version.setdefault(ch, 0)
        if ch in self.priority_channels:
            q.append((prio, at, tid))
        else:
            heapq.heappush(q, ((at, prio, tid), tid))

    def extend(self) -> int:
        """Ingest tasks appended to the DAG since the last call;
        returns how many were picked up."""
        new = range(self._ingested, self.dag._next_id)
        touched = set()
        for tid in new:
            preds = self.dag.preds[tid]
            ready = 0.0
            pending = 0
            for p in preds:
                done = self.schedule.get(p)
                if done is None:
                    pending += 1
                elif done.finish > ready:
                    ready = done.finish
            self._indeg[tid] = pending
            self._ready_time[tid] = ready
            if pending == 0:
                self._push(tid, ready)
                touched.add(self.dag.tasks[tid].channel)
        self._ingested = self.dag._next_id
        for ch in touched:
            self._push_candidate(ch)
        return len(new)

    # -- the event heap -------------------------------------------------
    def _push_candidate(self, ch: str) -> None:
        """(Re)announce ``ch``'s best next task on the global heap.

        The entry is stamped with the channel's version; any change to
        the channel's queue or free time bumps the version, so stale
        heap entries are recognized and skipped on pop.
        """
        q = self._queues.get(ch)
        self._version[ch] = self._version.get(ch, 0) + 1
        if not q:
            return
        if ch in self.priority_channels:
            # earliest instant the channel can start anything...
            start = max(self._channel_free[ch], min(r for _, r, _ in q))
            # ...and the best priority among tasks ready by then
            item = min(it for it in q if it[1] <= start)
            key, tid = item, item[2]
        else:
            key, tid = q[0]
            start = max(self._channel_free[ch], self._ready_time[tid])
            item = None
        heapq.heappush(self._heap,
                       (start, key, ch, self._version[ch], tid, item))

    def run(self) -> None:
        """Schedule every ingested task; safe to call repeatedly as the
        DAG grows (see :meth:`extend`)."""
        dag = self.dag
        while self._n_done < self._ingested:
            if not self._heap:
                raise RuntimeError(
                    "deadlock: no ready task but DAG not done (cycle?)")
            start, key, ch, ver, tid, item = heapq.heappop(self._heap)
            if ver != self._version[ch]:
                continue                     # stale candidate
            if ch in self.priority_channels:
                self._queues[ch].remove(item)
            else:
                heapq.heappop(self._queues[ch])
            task = dag.tasks[tid]
            finish = start + task.duration
            self.schedule[tid] = ScheduledTask(task, start, finish)
            self._channel_free[ch] = finish
            self.channel_busy[ch] = \
                self.channel_busy.get(ch, 0.0) + task.duration
            self._n_done += 1
            touched = {ch}
            for s in dag.succs[tid]:
                self._indeg[s] -= 1
                if finish > self._ready_time[s]:
                    self._ready_time[s] = finish
                if self._indeg[s] == 0:
                    self._push(s, self._ready_time[s])
                    touched.add(dag.tasks[s].channel)
            for c2 in touched:
                self._push_candidate(c2)

    def result(self) -> SimResult:
        makespan = max((s.finish for s in self.schedule.values()),
                       default=0.0)
        return SimResult(makespan, self.schedule, self.channel_busy)


def simulate(dag: DAG, priority_channels: frozenset[str] | None = None) -> SimResult:
    """List-schedule ``dag`` on constrained channels (one shot)."""
    sim = Simulation(dag, priority_channels=priority_channels)
    sim.run()
    return sim.result()


def _steady_converged(finishes: list[float], rtol: float) -> bool:
    """True once the last two update-interval deltas agree (pairwise,
    within ``rtol`` of their magnitude) — i.e. three consecutive
    iterations have taken the same time, the pipeline is periodic."""
    if len(finishes) < 4:
        return False
    d = [finishes[-1] - finishes[-2], finishes[-2] - finishes[-3],
         finishes[-3] - finishes[-4]]
    scale = max(abs(x) for x in d)
    if scale == 0.0:
        return True
    return (abs(d[0] - d[1]) <= rtol * scale
            and abs(d[1] - d[2]) <= rtol * scale)


def simulate_policy(
    costs: IterationCosts,
    n_workers: int,
    policy,
    n_iterations: int = 6,
    comm_scale: Callable[[float, float], float] | None = None,
    auto_steady: bool = False,
    rtol: float = STEADY_RTOL,
    worker_scale=None,
    sync_k: int | None = None,
    crashed: tuple = (),
    restart_s: float = 0.0,
) -> SimResult:
    """Build the Fig.-1 S-SGD DAG for ``policy`` and list-schedule it.

    One-stop entry point shared by the predictor, the sweep engine's
    simulator fallback, and the property tests; honors
    ``policy.priority_comm`` by putting the collective channel in
    priority-scheduling mode.

    With ``auto_steady=True`` the DAG is grown and simulated one
    iteration at a time and the warm-up stops as soon as the
    update-task deltas converge (``rtol``), capped at ``n_iterations``
    — :attr:`SimResult.n_iterations_used` records where it stopped.

    ``worker_scale`` (per-worker compute-time multipliers) makes this
    the per-worker oracle for the heterogeneous/straggler engine — see
    :class:`repro.core.dag.SSGDDagBuilder`.  ``sync_k`` / ``crashed`` /
    ``restart_s`` add the failure model: K-of-N partial sync and
    per-iteration checkpoint-restore crash events.
    """
    builder = SSGDDagBuilder(costs, n_workers, policy,
                             comm_scale=comm_scale,
                             worker_scale=worker_scale, sync_k=sync_k,
                             crashed=crashed, restart_s=restart_s)
    prio = frozenset([NET_CHANNEL]) if getattr(policy, "priority_comm", False) \
        else None
    sim = Simulation(builder.dag, priority_channels=prio)
    finishes: list[float] = []
    for _ in range(n_iterations):
        upd = builder.add_iteration()
        sim.extend()
        sim.run()
        finishes.append(sim.schedule[upd].finish)
        if auto_steady and _steady_converged(finishes, rtol):
            break
    res = sim.result()
    res.n_iterations_used = builder.n_iterations
    return res


def simulate_steady(
    costs: IterationCosts,
    n_workers: int,
    policy,
    n_iterations: int = 6,
    comm_scale: Callable[[float, float], float] | None = None,
    worker_scale=None,
    sync_k: int | None = None,
    crashed: tuple = (),
    restart_s: float = 0.0,
) -> float:
    """:func:`simulate_policy`, reduced to the warm per-iteration time
    in seconds.  Auto-detects the steady state: the warm-up stops as
    soon as consecutive update deltas converge, with ``n_iterations``
    as the cap (the historical fixed warm-up count)."""
    return simulate_policy(costs, n_workers, policy, n_iterations,
                           comm_scale, auto_steady=True,
                           worker_scale=worker_scale, sync_k=sync_k,
                           crashed=crashed, restart_s=restart_s) \
        .steady_iteration_time()
