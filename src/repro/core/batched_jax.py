"""JAX-native batched sweep kernels: one ``jit`` from codes to columns.

The NumPy engine (:mod:`repro.core.batched`) evaluates a grid in two
tiers — a policy-independent affine kernel reduced to ``(K,)`` cost
columns, then a cheap per-scenario policy select.  This module runs
the *same* two tiers through XLA as **one compiled function** over
whole code vectors (no ``vmap`` round trip, no per-point closures):

* tier 1 mirrors :func:`repro.core.batched._kernel_cols` — the affine
  collective coefficients (:mod:`repro.core.hardware` ``*_coeffs``),
  the unique-compute-row backward tables (structure precomputed on the
  host by :func:`repro.core.batched._compute_row_map`, gathered on
  device) and the fused multiply-add + masked-max residuals;
* tier 2 mirrors :func:`repro.core.batched._policy_select` — the same
  ``where``/``maximum`` equation select over ``(S,)`` vectors;
* the composition is one ``jit``-compiled function whose array inputs
  (axis tables, code vectors) are ordinary pytree arguments — same
  shapes, same compilation, fresh numbers every call — and whose
  output is exactly the numeric result columns, so ``backend="jax"``
  end-to-end cost is the kernel plus host label gathers.

There is no parallel formula implementation to keep in lockstep: the
affine coefficients come from the same dtype-polymorphic
:mod:`repro.core.hardware` functions the NumPy kernel calls, and the
per-workload prefix/suffix tables (``cumgrad``/``cumcount``, bucket
suffix sums via :func:`repro.core.bucketsim.suffix_tables`) are the
NumPy engine's own host-side arrays, shipped in as pytree inputs.
Numerics run in float64 under a scoped
``jax.experimental.enable_x64`` (never the global flag, which would
leak into the repo's other jax code), which is what makes the <= 1e-6
differential agreement against the NumPy oracle achievable; the
differential suite (``tests/test_batched_jax.py``) pins it on every
built-in grid.

Scenario-axis sharding: with more than one device (or an explicit
``mesh=``), the kernel and scenario code vectors are zero-padded to a
device-count multiple and placed with a ``NamedSharding`` over the
data axis of a :func:`repro.launch.mesh.make_dp_mesh` mesh — ``jit``
then partitions both tiers across devices, and the padding rows are
sliced off the gathered result.  The tiny unique-row tables stay
replicated.

Differentiability: the continuous inputs — link bandwidths/latencies
per ``(cluster, interconnect)`` pair and the bucket sizes — are
exposed as a params dict (:func:`default_params`), and
:func:`iteration_time_fn` returns a jit-compiled function of them
suitable for ``jax.grad``.  Iteration time is *piecewise constant* in
``bucket_bytes`` (the bucket size enters only through the partition
boundaries, which are discrete), so its exact gradient is 0 almost
everywhere — ``jax.grad`` returns exactly that 0, matching central
finite differences on the NumPy path whenever the perturbation stays
inside one partition cell.  :func:`numpy_iteration_times` is the
NumPy twin over the same params (bucket partitions *rebuilt* from the
perturbed sizes), which is what the finite-difference tests and the
CI agreement gate evaluate.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterable, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import analytical, batched, bucketsim
from repro.core.batched import grid_evaluator
from repro.core.hardware import (hierarchical_allreduce_coeffs,
                                 ring_allreduce_coeffs,
                                 tree_allreduce_coeffs)
from repro.core.resulttable import METHOD_LABELS, rows_from_table
from repro.core.scenarios import Scenario, ScenarioGrid

#: Continuous model inputs exposed to ``jax.grad`` — per
#: ``(cluster, interconnect)`` pair link parameters plus the bucket
#: sizes of the grid's timeline specs.
PARAM_KEYS = ("intra_bw", "intra_lat", "inter_bw", "inter_lat",
              "bucket_bytes")

#: Numeric columns shared with the NumPy engine's policy select.
_NUMERIC_COLS = ("batch", "iteration_time_s", "samples_per_sec",
                 "speedup", "t_comm_s", "t_comp_s")


# ----------------------------------------------------------------------
# Structure extraction: axis tables -> one flat dict of arrays (a jit
# pytree argument), prefix/suffix and bucket structure included.
# ----------------------------------------------------------------------
def _axes_tables(wax, cax, pax, wtab) -> tuple[dict, dict]:
    """``(tables, pflags)`` array dicts from the NumPy engine's axis
    dataclasses — the jit kernel's pytree inputs, including the
    per-workload prefix tables the affine formulation gathers
    (``cumgrad``/``cumcount`` and their totals) and the bucket suffix
    tables per timeline spec.  ``bucket_bytes`` rides along purely as
    a differentiation input: the partition structure (``bt<i>_*``) is
    discrete and prebuilt, which is exactly the piecewise-constant
    dependence documented in the module docstring.

    ``wtab`` is the padded per-worker table
    (:func:`repro.core.het.worker_table_rows`) over the unique
    ``(het profile, n_workers)`` pairs: the kernel reduces the gathered
    rows with :func:`repro.core.analytical.worker_bottleneck` *inside*
    the jit, so the het link derating shards and differentiates with
    everything else.  On all-homogeneous inputs every row is ones (the
    pads are neutral) and the reduction multiplies by exactly 1.0 —
    bit-identity, same contract as the NumPy kernel's ``None`` path."""
    grad = wax.grad_bytes
    comm_mask = (grad > 0).astype(np.float64)
    cumgrad = np.cumsum(grad, axis=1)
    cumcount = np.cumsum(comm_mask, axis=1)
    tables = {
        "flops": wax.flops, "tf_meas": wax.tf_meas, "tb_meas": wax.tb_meas,
        "bwd_ratio": wax.bwd_ratio,
        "batch_default": wax.batch_default,
        "bytes_per_sample": wax.bytes_per_sample,
        "param_bytes": wax.param_bytes, "t_io_meas": wax.t_io_meas,
        "has_meas_io": wax.has_meas_io,
        "comm_mask": comm_mask, "cumgrad": cumgrad, "cumcount": cumcount,
        "gradsum": cumgrad[:, -1], "ncomm": cumcount[:, -1],
        "intra_bw": cax.intra_bw, "intra_lat": cax.intra_lat,
        "inter_bw": cax.inter_bw, "inter_lat": cax.inter_lat,
        "gpn": cax.gpn, "disk_lat": cax.disk_lat, "disk_bw": cax.disk_bw,
        "h2d_lat": cax.h2d_lat, "h2d_bw": cax.h2d_bw,
        "rate": cax.rate, "hbm_bw": cax.hbm_bw,
        "bucket_bytes": np.array([bb for bb, _ in pax.tl_specs],
                                 dtype=np.float64),
        "w_inv": wtab["inv_speed"], "w_bw": wtab["bw_mult"],
        "w_lat": wtab["lat_mult"],
    }
    for i, (bb, _) in enumerate(pax.tl_specs):
        bt = bucketsim.bucket_table(wax.grad_bytes, bb)
        sufnb, sufcnt = bucketsim.suffix_tables(bt)
        tables[f"bt{i}_release"] = bt.release_layer
        tables[f"bt{i}_mask"] = bt.mask.astype(np.float64)
        tables[f"bt{i}_sufnb"] = sufnb
        tables[f"bt{i}_sufcnt"] = sufcnt
    pflags = {"overlap_io": pax.overlap_io,
              "overlap_comm": pax.overlap_comm,
              "h2d_early": pax.h2d_early,
              "tl_spec": pax.tl_spec}
    return tables, pflags


# ----------------------------------------------------------------------
# Tier 1: the affine kernel over whole code vectors.
# ----------------------------------------------------------------------
def _kernel_cols_jax(tbl: dict, kcodes: dict, ucodes: dict,
                     tl_overlaps: tuple, coll_codes: tuple) -> dict:
    """Policy-independent ``(K,)`` cost columns, traced on whole code
    vectors — the jax twin of :func:`repro.core.batched._kernel_cols`:
    affine collective coefficients, unique-compute-row backward tables
    gathered through the host-precomputed ``uk`` map, and the fused
    multiply-add + masked-max residuals.

    Heterogeneity enters exactly as in the NumPy kernel:
    ``ucodes["tmul"]`` (slowest-worker compute multiplier, folded into
    the unique-row key on the host) scales ``t_f``/``t_b``, and the
    per-point link multipliers — reduced in-jit from the padded worker
    table gathered at ``kcodes["hk"]`` — derate both link levels
    before the collective dispatch.  All-ones multipliers are
    bit-identity (IEEE ``x * 1.0 == x``)."""
    w, c = kcodes["w"], kcodes["c"]
    coll, n, batch, uk = kcodes["coll"], kcodes["n"], kcodes["batch"], \
        kcodes["uk"]
    hk = kcodes["hk"]
    uw, uc, ub, ut = ucodes["w"], ucodes["c"], ucodes["batch"], \
        ucodes["tmul"]
    batch_f = jnp.where(batch > 0, batch,
                        tbl["batch_default"][w]).astype(jnp.float64)
    n_f = n.astype(jnp.float64)

    # compute costs: (U, L) on the unique compute rows only
    ubatch_f = jnp.where(ub > 0, ub,
                         tbl["batch_default"][uw]).astype(jnp.float64)
    tfa = tbl["flops"][uw] * ubatch_f[:, None] / tbl["rate"][uc][:, None]
    scale = (ubatch_f / tbl["batch_default"][uw])[:, None]
    t_f = tfa + tbl["tf_meas"][uw] * scale         # measured rows: exact,
    t_b = tbl["bwd_ratio"][uw][:, None] * tfa \
        + tbl["tb_meas"][uw] * scale               # others +0.0
    t_f = t_f * ut[:, None]            # slowest-worker compute multiplier
    t_b = t_b * ut[:, None]
    prefix_b = jnp.cumsum(t_b, axis=1)
    total_b_u = prefix_b[:, -1]
    suffix_b_u = (total_b_u[:, None] - prefix_b) + t_b   # inclusive
    comp_u = t_f.sum(axis=1) + t_b.sum(axis=1)
    total_b = total_b_u[uk]

    # per-point affine collective coefficients (coll is traced; the
    # codes *present* are static, so only those models trace).  The
    # heterogeneous collective is gated by its slowest link, so both
    # link levels are derated before the algorithm dispatch.
    _, bwmul, latmul = analytical.worker_bottleneck(
        tbl["w_inv"][hk], tbl["w_bw"][hk], tbl["w_lat"][hk])
    intra_bw = tbl["intra_bw"][c] * bwmul
    intra_lat = tbl["intra_lat"][c] * latmul
    inter_bw = tbl["inter_bw"][c] * bwmul
    inter_lat = tbl["inter_lat"][c] * latmul
    use_intra = n <= tbl["gpn"][c]
    link_bw = jnp.where(use_intra, intra_bw, inter_bw)
    link_lat = jnp.where(use_intra, intra_lat, inter_lat)

    def _model(code: int):
        if code == 0:
            return ring_allreduce_coeffs(n_f, link_bw, link_lat)
        if code == 1:
            return tree_allreduce_coeffs(n, link_bw, link_lat)
        return hierarchical_allreduce_coeffs(
            n, tbl["gpn"][c], intra_bw, intra_lat, inter_bw, inter_lat)

    per_byte, per_message = _model(coll_codes[0])
    for code in coll_codes[1:]:
        a, b = _model(code)
        sel = coll == code
        per_byte = jnp.where(sel, a, per_byte)
        per_message = jnp.where(sel, b, per_message)

    # pipeline terms: (K,)
    nbytes_in = batch_f * tbl["bytes_per_sample"][w]
    t_io = tbl["disk_lat"][c] + nbytes_in / tbl["disk_bw"][c]
    t_io = jnp.where(tbl["has_meas_io"][w],
                     tbl["t_io_meas"][w] * batch_f / tbl["batch_default"][w],
                     t_io)
    t_h2d = tbl["h2d_lat"][c] + nbytes_in / tbl["h2d_bw"][c]

    # WFBP residual (affine form — see the NumPy kernel's derivation)
    cand = suffix_b_u[uk] \
        + per_byte[:, None] * tbl["cumgrad"][w] \
        + per_message[:, None] * tbl["cumcount"][w]
    cand = cand * tbl["comm_mask"][w]
    out = {
        "io_h2d": t_io + t_h2d,
        "t_h2d": t_h2d,
        "comp": comp_u[uk],
        "sum_c": per_byte * tbl["gradsum"][w] + per_message * tbl["ncomm"][w],
        "tc_no": jnp.maximum(cand.max(axis=1, initial=0.0) - total_b, 0.0),
        "t_u": 3.0 * tbl["param_bytes"][w] / tbl["hbm_bw"][c],
        "n_f": n_f,
        "batch_f": batch_f,
    }
    for i, ov_comm in enumerate(tl_overlaps):
        if ov_comm:
            release_u = jnp.take_along_axis(
                suffix_b_u, tbl[f"bt{i}_release"][uw], axis=1)
        else:
            release_u = jnp.broadcast_to(
                total_b_u[:, None],
                (len(uw), tbl[f"bt{i}_release"].shape[1]))
        cand = release_u[uk] \
            + per_byte[:, None] * tbl[f"bt{i}_sufnb"][w] \
            + per_message[:, None] * tbl[f"bt{i}_sufcnt"][w]
        cand = cand * tbl[f"bt{i}_mask"][w]
        out[f"tl{i}"] = jnp.maximum(
            cand.max(axis=1, initial=0.0) - total_b, 0.0)
    return out


# ----------------------------------------------------------------------
# Tier 2: the policy select over whole scenario vectors.
# ----------------------------------------------------------------------
def _select_jax(pflags: dict, tl_overlaps: tuple, kc: dict, pi, kidx):
    """The jax twin of :func:`repro.core.batched._policy_select` (same
    equations, same zero-comm weak-scaling baseline), over whole
    ``(S,)`` vectors; method labels are strings and stay on the host
    side."""
    def g(name):
        return kc[name][kidx]

    ov_io = pflags["overlap_io"][pi]
    ov_comm = pflags["overlap_comm"][pi]
    early = pflags["h2d_early"][pi]

    comm_term = jnp.where(ov_comm, g("tc_no"), g("sum_c"))
    spec_of = pflags["tl_spec"][pi]
    for i, _ in enumerate(tl_overlaps):
        comm_term = jnp.where(spec_of == i, g(f"tl{i}"), comm_term)
    gpu_chain = g("comp") + comm_term + g("t_u")
    io_h2d, t_h2d = g("io_h2d"), g("t_h2d")
    eq2 = io_h2d + gpu_chain
    eq_early = jnp.maximum(io_h2d, gpu_chain)
    eq_late = jnp.maximum(io_h2d, t_h2d + gpu_chain)
    t_iter = jnp.where(~ov_io, eq2, jnp.where(early, eq_early, eq_late))

    base_chain = g("comp") + g("t_u")
    t1 = jnp.where(~ov_io, io_h2d + base_chain,
                   jnp.where(early, jnp.maximum(io_h2d, base_chain),
                             jnp.maximum(io_h2d, t_h2d + base_chain)))
    n_f, batch_f = g("n_f"), g("batch_f")
    return {
        "batch": batch_f,
        "iteration_time_s": t_iter,
        "samples_per_sec": n_f * batch_f / t_iter,
        "speedup": n_f * t1 / t_iter,
        "t_comm_s": g("sum_c"),
        "t_comp_s": g("comp"),
    }


@functools.partial(jax.jit, static_argnames=("tl_overlaps", "coll_codes"))
def _columns_jax(tables: dict, pflags: dict, kcodes: dict, scodes: dict,
                 ucodes: dict, tl_overlaps: tuple,
                 coll_codes: tuple) -> dict:
    """The whole two-tier evaluation — codes in, result columns out —
    as one compiled function.  Compilation is keyed by array
    shapes/dtypes and the static ``tl_overlaps``/``coll_codes``
    tuples — re-running a grid (or any same-shaped grid) with fresh
    numbers reuses the executable."""
    kc = _kernel_cols_jax(tables, kcodes, ucodes, tl_overlaps, coll_codes)
    return _select_jax(pflags, tl_overlaps, kc, scodes["pi"],
                       scodes["kidx"])


# ----------------------------------------------------------------------
# Sharding: pad the batch axes to a device-count multiple and place
# the code vectors over the mesh's data axis.
# ----------------------------------------------------------------------
#: Benign fill for padding rows (index 0 is always valid; n=1 is the
#: zero-comm degenerate; batch=0 means "table default").
_PAD_FILL = {"n": 1}


def _shard_codes(codes: dict, mesh) -> dict:
    ndev = math.prod(mesh.devices.shape)
    axis = mesh.axis_names[0]
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(axis))
    size = len(next(iter(codes.values())))
    pad = (-size) % ndev
    out = {}
    for k, v in codes.items():
        if pad:
            fill = np.full(pad, _PAD_FILL.get(k, 0), dtype=v.dtype)
            v = np.concatenate([v, fill])
        out[k] = jax.device_put(v, sharding)
    return out


# ----------------------------------------------------------------------
# Grid front end.
# ----------------------------------------------------------------------
class JaxGridEvaluator:
    """A :class:`ScenarioGrid` prepared for the fused jit kernel.

    Reuses the NumPy engine's memoized structure (axis tables, code
    vectors, label arrays, unique-compute-row map) — only the numeric
    evaluation moves to XLA.  Raises ``ValueError`` for grids
    containing simulator-only policies: unlike the NumPy engine there
    is no event-driven fallback to interleave, and silently falling
    back would defeat the point of selecting the backend explicitly.

    ``mesh=None`` autoselects: a data-parallel mesh over all devices
    when more than one is visible, unsharded otherwise.  Pass a mesh
    (e.g. :func:`repro.launch.mesh.make_dp_mesh`) to force sharding —
    a single-device mesh exercises the sharded path end to end.
    """

    def __init__(self, grid: ScenarioGrid, *, mesh=None):
        ev = grid_evaluator(grid)
        if not ev.all_batched:
            bad = [name for name, f, t in zip(
                ev._pax.names, ev._pax.has_fast, ev._pax.has_tl)
                if not (bool(f) or bool(t))]
            raise ValueError(
                f"backend='jax' evaluates closed-form and bucket-timeline "
                f"policies only; {bad} need the event-driven simulator. "
                f"Use backend='numpy' for grids containing them.")
        self.ev = ev
        self._tables, self._pflags = _axes_tables(ev._wax, ev._cax,
                                                  ev._pax, ev._wtab)
        self._tl_overlaps = tuple(bool(ov) for _, ov in ev._pax.tl_specs)
        self._coll_codes = tuple(int(x) for x in np.unique(ev._kcoll)) or (0,)
        uw, uc, ub, ut, uk = batched._compute_row_map(
            ev._wax, ev._cax, ev._kwidx, ev._kcidx, ev._kbatch, ev._ktmul)
        kcodes = {"w": ev._kwidx, "c": ev._kcidx, "coll": ev._kcoll,
                  "n": ev._kn, "batch": ev._kbatch, "uk": uk,
                  "hk": ev._khk}
        self._ucodes = {"w": uw, "c": uc, "batch": ub,
                        "tmul": np.ones(len(uw)) if ut is None else ut}
        S = len(ev)
        if S:
            sc = ev._scenario_codes(0, S)
            scodes = {"pi": sc["pi"], "kidx": sc["kidx"]}
        else:
            scodes = {"pi": np.empty(0, dtype=np.int64),
                      "kidx": np.empty(0, dtype=np.int64)}
        if mesh is None and len(jax.devices()) > 1:
            from repro.launch.mesh import make_dp_mesh
            mesh = make_dp_mesh(len(jax.devices()))
        self.mesh = mesh
        if mesh is not None and S:
            with enable_x64():
                kcodes = _shard_codes(kcodes, mesh)
                scodes = _shard_codes(scodes, mesh)
        self._kcodes, self._scodes = kcodes, scodes

    def __len__(self) -> int:
        return len(self.ev)

    def columns(self, params: dict | None = None) -> dict[str, np.ndarray]:
        """All numeric result columns as host float64 ``(S,)`` arrays
        (blocks on the device computation).  ``params`` optionally
        overrides the :data:`PARAM_KEYS` entries."""
        S = len(self.ev)
        if S == 0:
            return {k: np.empty(0) for k in _NUMERIC_COLS}
        with enable_x64():
            out = self._traced_columns(params)
            return {k: np.asarray(v)[:S] for k, v in out.items()
                    if k in _NUMERIC_COLS}

    def _traced_columns(self, params: dict | None = None) -> dict:
        """The jit call itself — kept separate so the differentiable
        front end (:func:`iteration_time_fn`) can trace through it.
        Callers are responsible for the ``enable_x64`` scope."""
        tables = self._tables
        if params:
            unknown = set(params) - set(PARAM_KEYS)
            if unknown:
                raise ValueError(f"unknown param keys {sorted(unknown)}; "
                                 f"differentiable params are {PARAM_KEYS}")
            tables = {**tables, **params}
        return _columns_jax(tables, self._pflags, self._kcodes,
                            self._scodes, self._ucodes, self._tl_overlaps,
                            self._coll_codes)

    def run(self, params: dict | None = None, seed: int = 0) -> "JaxGridRun":
        """One evaluation: the jit kernel for the deterministic
        columns, then the straggler Monte Carlo tail pass.  The MC
        orchestration (dedup, keyed draws, slowest-worker fold,
        ``np.quantile`` reduction) is the host-side pass *shared* with
        the NumPy engine (:func:`repro.core.batched._apply_mc_tails`),
        which is what guarantees draw-for-draw agreement between the
        backends; deterministic grids skip it and the tail columns
        equal ``iteration_time_s`` bit-exactly."""
        cols = self.columns(params)
        ev = self.ev
        if ev._any_mc and len(ev):
            codes = ev._scenario_codes(0, len(ev))
            k = codes["kidx"]
            batched._apply_mc_tails(
                ev._wax, ev._cax, ev._pax, ev._kwidx[k], ev._kcidx[k],
                ev._kcoll[k], ev._kn[k], ev._kbatch[k], codes["pi"],
                ev._khk[k], ev._wtab,
                None if ev._kbwmul is None else ev._kbwmul[k],
                None if ev._klatmul is None else ev._klatmul[k],
                ev._st_specs, codes["sti"], cols, seed,
                synck=ev._ksynck[k], ft_specs=ev._ft_specs,
                fidx=codes["fli"])
        else:
            t_iter = cols["iteration_time_s"]
            cols["t_mean_s"] = t_iter
            cols["t_p95_s"] = t_iter
            cols["t_p99_s"] = t_iter
        return JaxGridRun(self, cols)

    def method_labels(self, pi: np.ndarray) -> list[str]:
        """Per-row evaluation-path labels (``all_batched`` holds, so
        only the two batched labels occur)."""
        return METHOD_LABELS[self.ev._pax.tier[pi]].tolist()


class JaxGridRun:
    """One evaluation of a grid on the jax backend: host-side numeric
    columns plus the shared structure, materializing columnar result
    tables chunk by chunk — the jax twin of
    :class:`repro.core.batched.GridRun` (no simulator rows:
    simulator-only grids are rejected up front)."""

    def __init__(self, jev: JaxGridEvaluator, cols: dict[str, np.ndarray]):
        self._jev = jev
        self._cols = cols

    def __len__(self) -> int:
        return len(self._jev)

    def columns_slice(self, lo: int, hi: int) -> dict[str, np.ndarray]:
        ev = self._jev.ev
        out = {k: v[lo:hi] for k, v in self._cols.items()}
        out["method"] = self._jev.method_labels(
            ev._scenario_codes(lo, hi)["pi"])
        return out

    def table_slice(self, lo: int, hi: int):
        """Columnar result table for flat scenario indices ``[lo, hi)``
        in grid order — the jax twin of
        :meth:`repro.core.batched.GridRun.table_slice` (the ``batched``
        mask is all-true by construction)."""
        ev = self._jev.ev
        codes = ev._scenario_codes(lo, hi)
        cols = {k: v[lo:hi] for k, v in self._cols.items()}
        cols["method_code"] = ev._pax.tier[codes["pi"]]
        return (batched.select_to_columns(cols, ev._label_columns(codes)),
                codes["batched"])

    def rows_slice(self, lo: int, hi: int) -> list[dict]:
        table, _ = self.table_slice(lo, hi)
        return rows_from_table(table)


#: Structure memo, mirroring :func:`repro.core.batched.grid_evaluator`
#: (separate because the jax evaluator also holds device-side codes).
_JAX_MEMO: dict = {}
_MEMO_LIMIT = 64


def jax_grid_evaluator(grid: ScenarioGrid, *, mesh=None) -> JaxGridEvaluator:
    """Memoized :class:`JaxGridEvaluator` (unsharded/auto mesh only —
    explicit meshes always build fresh)."""
    if mesh is not None:
        return JaxGridEvaluator(grid, mesh=mesh)
    try:
        from repro.core.workloads import resolve_workload
        tables = tuple(resolve_workload(w) for w in grid.workloads)
        key = (grid, tuple(id(t) for t in tables))
        hash(key)
    except TypeError:
        return JaxGridEvaluator(grid)
    hit = _JAX_MEMO.get(key)
    if hit is not None:
        return hit[0]
    if len(_JAX_MEMO) >= _MEMO_LIMIT:
        _JAX_MEMO.clear()
    jev = JaxGridEvaluator(grid)
    _JAX_MEMO[key] = (jev, tables)
    return jev


def jax_evaluator_cached(grid: ScenarioGrid) -> bool:
    """True when :func:`jax_grid_evaluator` would hit the structure
    memo — the jax twin of :func:`repro.core.batched.evaluator_cached`
    (a pure probe; the sweep service's cache-hit accounting)."""
    try:
        from repro.core.workloads import resolve_workload
        tables = tuple(resolve_workload(w) for w in grid.workloads)
        key = (grid, tuple(id(t) for t in tables))
        hash(key)
    except (TypeError, ValueError):
        return False
    return key in _JAX_MEMO


# ----------------------------------------------------------------------
# Scenario-list front end — jax twin of batched.eval_scenarios_table.
# ----------------------------------------------------------------------
def eval_scenarios_table_jax(
        scenarios: Sequence[Scenario] | Iterable[Scenario],
        seed: int = 0) -> dict[str, np.ndarray]:
    """Columnar result table (input order) for a list of
    batched-path-eligible scenarios, evaluated by the fused jit kernel
    with the identity scenario -> kernel-point map; het/straggler
    structure comes from the shared
    :func:`repro.core.batched.scenario_het_axes` pass and the straggler
    Monte Carlo tails from the shared host-side pass, exactly as on the
    grid path — which is what makes a *concatenation* of several
    queries' scenario lists bit-identical, column for column, to
    sweeping each query's grid directly (the sweep service's coalescer
    contract, pinned by ``tests/test_service.py``).  Raises
    ``ValueError`` (via :func:`repro.core.batched.scenario_axes`) if
    any scenario's policy has neither a closed nor a bucket-timeline
    form."""
    from repro.core.resulttable import empty_table

    scenarios = list(scenarios)
    if not scenarios:
        return empty_table()
    wax, cax, pax, widx, cidx, polidx, coll, n, batch = \
        batched.scenario_axes(scenarios)
    (hks, wtab, tmul, bwmul, latmul, st_specs, stidx,
     synck, ft_specs, fidx) = batched.scenario_het_axes(scenarios)
    tables, pflags = _axes_tables(wax, cax, pax, wtab)
    tl_overlaps = tuple(bool(ov) for _, ov in pax.tl_specs)
    S = len(scenarios)
    uw, uc, ub, ut, uk = batched._compute_row_map(wax, cax, widx, cidx,
                                                  batch, tmul)
    kcodes = {"w": widx, "c": cidx, "coll": coll, "n": n, "batch": batch,
              "uk": uk, "hk": hks}
    ucodes = {"w": uw, "c": uc, "batch": ub,
              "tmul": np.ones(len(uw)) if ut is None else ut}
    scodes = {"pi": polidx, "kidx": np.arange(S, dtype=np.int64)}
    coll_codes = tuple(int(x) for x in np.unique(coll)) or (0,)
    with enable_x64():
        out = _columns_jax(tables, pflags, kcodes, scodes, ucodes,
                           tl_overlaps, coll_codes)
        cols = {k: np.asarray(v) for k, v in out.items()
                if k in _NUMERIC_COLS}
    batched._apply_mc_tails(wax, cax, pax, widx, cidx, coll, n, batch,
                            polidx, hks, wtab, bwmul, latmul, st_specs,
                            stidx, cols, seed, synck=synck,
                            ft_specs=ft_specs, fidx=fidx)
    cols["method_code"] = pax.tier[polidx]
    return batched.select_to_columns(cols,
                                     batched.scenario_labels(scenarios))


def eval_scenarios_jax(scenarios: Sequence[Scenario] | Iterable[Scenario],
                       seed: int = 0) -> list[dict]:
    """Batched rows (input order) for a scenario list — the per-row
    view of :func:`eval_scenarios_table_jax`."""
    return rows_from_table(eval_scenarios_table_jax(scenarios, seed=seed))


# ----------------------------------------------------------------------
# Differentiable front end.
# ----------------------------------------------------------------------
def default_params(grid: ScenarioGrid) -> dict[str, np.ndarray]:
    """The grid's resolved continuous inputs (:data:`PARAM_KEYS`):
    per-pair link bandwidths/latencies and per-timeline-spec bucket
    sizes — the point :func:`iteration_time_fn` differentiates
    around."""
    jev = jax_grid_evaluator(grid)
    return {k: np.array(jev._tables[k], dtype=np.float64, copy=True)
            for k in PARAM_KEYS}


def iteration_time_fn(grid: ScenarioGrid):
    """``(f, params0)``: ``f(params) -> (S,)`` iteration times, jit
    compiled and differentiable w.r.t. every :data:`PARAM_KEYS` entry.
    Call (and differentiate) ``f`` inside a
    ``jax.experimental.enable_x64()`` scope, or use the
    :func:`grad_iteration_time` convenience wrapper.

    The gradient w.r.t. ``bucket_bytes`` is exactly 0: iteration time
    is piecewise constant in the bucket size (see the module
    docstring), and ``f`` holds the partition fixed at ``params0``'s
    structure.  :func:`numpy_iteration_times` *rebuilds* the partition
    per call, so central differences on it recover the same 0 inside a
    partition cell."""
    jev = jax_grid_evaluator(grid)
    S = len(jev)

    def f(params: dict):
        return jev._traced_columns(params)["iteration_time_s"][:S]

    return f, default_params(grid)


def grad_iteration_time(grid: ScenarioGrid,
                        params: dict | None = None) -> dict[str, np.ndarray]:
    """``d(sum of iteration times)/d(params)`` as host arrays — the
    end-to-end differentiability surface the gradient-correctness
    tests pin against NumPy central differences."""
    f, p0 = iteration_time_fn(grid)
    if params:
        p0 = {**p0, **params}
    with enable_x64():
        p = {k: jnp.asarray(v, dtype=jnp.float64) for k, v in p0.items()}
        g = jax.grad(lambda q: f(q).sum())(p)
        return {k: np.asarray(v) for k, v in g.items()}


def numpy_iteration_times(grid: ScenarioGrid,
                          params: dict | None = None) -> np.ndarray:
    """The NumPy oracle over the same params surface: link overrides
    swap into the cluster axis, bucket-size overrides *rebuild* the
    bucket partitions.  This is the finite-difference reference for
    :func:`grad_iteration_time` and the numeric side of the CI
    agreement gate."""
    ev = grid_evaluator(grid)
    cax = ev._cax
    tl_specs = list(ev._pax.tl_specs)
    if params:
        link = {k: np.asarray(params[k], dtype=np.float64)
                for k in ("intra_bw", "intra_lat", "inter_bw", "inter_lat")
                if k in params}
        if link:
            cax = dataclasses.replace(cax, **link)
        if "bucket_bytes" in params:
            bb = np.asarray(params["bucket_bytes"], dtype=np.float64)
            tl_specs = [(float(bb[i]), ov)
                        for i, (_, ov) in enumerate(tl_specs)]
    kc = batched._kernel_cols(ev._wax, cax, ev._kwidx, ev._kcidx,
                              ev._kcoll, ev._kn, ev._kbatch,
                              tl_specs=tl_specs, tmul=ev._ktmul,
                              bwmul=ev._kbwmul, latmul=ev._klatmul)
    codes = ev._scenario_codes(0, len(ev))
    return batched._policy_select(ev._pax, codes["pi"], kc,
                                  codes["kidx"])["iteration_time_s"]
