"""JAX-native batched sweep kernels: ``jit`` + ``vmap`` over scenarios.

The NumPy engine (:mod:`repro.core.batched`) evaluates a grid in two
tiers — a policy-independent ``(K, L)`` kernel grid reduced to ``(K,)``
cost columns, then a cheap per-scenario policy select.  This module
runs the *same* two tiers through XLA:

* the per-point kernel (compute costs, collective dispatch, WFBP
  prefix-max residual, bucket-timeline residuals) is written per
  kernel point and ``vmap``-batched over the kernel axis;
* the policy select is written per scenario and ``vmap``-batched over
  the scenario axis;
* the composition is one ``jit``-compiled function whose array inputs
  (axis tables, code vectors) are ordinary pytree arguments — same
  shapes, same compilation, fresh numbers every call.

There is no parallel formula implementation to keep in lockstep: the
collective models (:mod:`repro.core.hardware`), the WFBP residual
(:func:`repro.core.analytical.non_overlapped_comm_batch`) and the
bucket timeline (:func:`repro.core.bucketsim.timeline_residual`) are
dtype-polymorphic (:mod:`repro.core.xputil`) and trace here on
``jax.numpy`` rows exactly as they evaluate on NumPy matrices in the
oracle engine.  Numerics run in float64 under a scoped
``jax.experimental.enable_x64`` (never the global flag, which would
leak into the repo's other jax code), which is what makes the <= 1e-6
differential agreement against the NumPy oracle achievable; the
differential suite (``tests/test_batched_jax.py``) pins it on every
built-in grid.

Scenario-axis sharding: with more than one device (or an explicit
``mesh=``), the kernel and scenario code vectors are zero-padded to a
device-count multiple and placed with a ``NamedSharding`` over the
data axis of a :func:`repro.launch.mesh.make_dp_mesh` mesh — ``jit``
then partitions both tiers across devices, and the padding rows are
sliced off the gathered result.

Differentiability: the continuous inputs — link bandwidths/latencies
per ``(cluster, interconnect)`` pair and the bucket sizes — are
exposed as a params dict (:func:`default_params`), and
:func:`iteration_time_fn` returns a jit-compiled function of them
suitable for ``jax.grad``.  Iteration time is *piecewise constant* in
``bucket_bytes`` (the bucket size enters only through the partition
boundaries, which are discrete), so its exact gradient is 0 almost
everywhere — ``jax.grad`` returns exactly that 0, matching central
finite differences on the NumPy path whenever the perturbation stays
inside one partition cell.  :func:`numpy_iteration_times` is the
NumPy twin over the same params (bucket partitions *rebuilt* from the
perturbed sizes), which is what the finite-difference tests and the
CI agreement gate evaluate.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Iterable, Sequence

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import enable_x64

from repro.core import analytical, batched, bucketsim
from repro.core.batched import grid_evaluator
from repro.core.hardware import (hierarchical_allreduce_time,
                                 ring_allreduce_time, tree_allreduce_time)
from repro.core.scenarios import Scenario, ScenarioGrid, normalize_interconnect

#: Continuous model inputs exposed to ``jax.grad`` — per
#: ``(cluster, interconnect)`` pair link parameters plus the bucket
#: sizes of the grid's timeline specs.
PARAM_KEYS = ("intra_bw", "intra_lat", "inter_bw", "inter_lat",
              "bucket_bytes")

#: Numeric columns shared with the NumPy engine's policy select.
_NUMERIC_COLS = ("batch", "iteration_time_s", "samples_per_sec",
                 "speedup", "t_comm_s", "t_comp_s")


# ----------------------------------------------------------------------
# Structure extraction: axis tables -> one flat dict of arrays (a jit
# pytree argument), bucket structure included.
# ----------------------------------------------------------------------
def _axes_tables(wax, cax, pax) -> tuple[dict, dict]:
    """``(tables, pflags)`` array dicts from the NumPy engine's axis
    dataclasses — the jit kernel's pytree inputs.  ``bucket_bytes``
    rides along purely as a differentiation input: the partition
    structure (``bt<i>_*``) is discrete and prebuilt, which is exactly
    the piecewise-constant dependence documented in the module
    docstring."""
    tables = {
        "flops": wax.flops, "tf_meas": wax.tf_meas, "tb_meas": wax.tb_meas,
        "grad_bytes": wax.grad_bytes, "bwd_ratio": wax.bwd_ratio,
        "batch_default": wax.batch_default,
        "bytes_per_sample": wax.bytes_per_sample,
        "param_bytes": wax.param_bytes, "t_io_meas": wax.t_io_meas,
        "has_meas_io": wax.has_meas_io,
        "intra_bw": cax.intra_bw, "intra_lat": cax.intra_lat,
        "inter_bw": cax.inter_bw, "inter_lat": cax.inter_lat,
        "gpn": cax.gpn, "disk_lat": cax.disk_lat, "disk_bw": cax.disk_bw,
        "h2d_lat": cax.h2d_lat, "h2d_bw": cax.h2d_bw,
        "rate": cax.rate, "hbm_bw": cax.hbm_bw,
        "bucket_bytes": np.array([bb for bb, _ in pax.tl_specs],
                                 dtype=np.float64),
    }
    for i, (bb, _) in enumerate(pax.tl_specs):
        bt = bucketsim.bucket_table(wax.grad_bytes, bb)
        tables[f"bt{i}_nbytes"] = bt.nbytes
        tables[f"bt{i}_release"] = bt.release_layer
        tables[f"bt{i}_mask"] = bt.mask
    pflags = {"overlap_io": pax.overlap_io,
              "overlap_comm": pax.overlap_comm,
              "h2d_early": pax.h2d_early,
              "tl_spec": pax.tl_spec}
    return tables, pflags


# ----------------------------------------------------------------------
# Tier 1: one kernel point — vmapped over the kernel axis.
# ----------------------------------------------------------------------
def _point_kernel(tbl: dict, tl_overlaps: tuple, coll_codes: tuple,
                  w, c, coll, n, batch):
    """Policy-independent cost terms of one kernel point, traced on
    the dtype-polymorphic models — the jax twin of one row of
    :func:`repro.core.batched._kernel_cols`.  ``coll`` is traced, but
    the set of collective codes present in the grid (``coll_codes``)
    is static — only those models are evaluated and selected, the jax
    counterpart of the NumPy kernel's host-side partition by
    collective code (a single-collective grid pays for exactly one
    model)."""
    batch_f = jnp.where(batch > 0, batch,
                        tbl["batch_default"][w]).astype(jnp.float64)
    n_f = n.astype(jnp.float64)
    tfa = tbl["flops"][w] * batch_f / tbl["rate"][c]
    scale = batch_f / tbl["batch_default"][w]
    t_f = tfa + tbl["tf_meas"][w] * scale          # measured rows: exact,
    t_b = tbl["bwd_ratio"][w] * tfa + tbl["tb_meas"][w] * scale  # others +0.0
    use_intra = n <= tbl["gpn"][c]
    link_bw = jnp.where(use_intra, tbl["intra_bw"][c], tbl["inter_bw"][c])
    link_lat = jnp.where(use_intra, tbl["intra_lat"][c], tbl["inter_lat"][c])

    def _one_model(code: int, payload):
        if code == 0:
            return ring_allreduce_time(payload, n_f, link_bw, link_lat)
        if code == 1:
            return tree_allreduce_time(payload, n_f, link_bw, link_lat)
        return hierarchical_allreduce_time(
            payload, n, tbl["gpn"][c],
            tbl["intra_bw"][c], tbl["intra_lat"][c],
            tbl["inter_bw"][c], tbl["inter_lat"][c])

    def comm(payload):
        """(B,) payload bytes -> (B,) collective seconds; the same
        payload-agnostic dispatch as the NumPy kernel's comm_matrix."""
        t = _one_model(coll_codes[0], payload)
        for code in coll_codes[1:]:
            t = jnp.where(coll == code, _one_model(code, payload), t)
        return t * (payload > 0)

    t_c = comm(tbl["grad_bytes"][w])
    nbytes_in = batch_f * tbl["bytes_per_sample"][w]
    t_io = tbl["disk_lat"][c] + nbytes_in / tbl["disk_bw"][c]
    t_io = jnp.where(tbl["has_meas_io"][w], tbl["t_io_meas"][w] * scale, t_io)
    t_h2d = tbl["h2d_lat"][c] + nbytes_in / tbl["h2d_bw"][c]
    out = {
        "io_h2d": t_io + t_h2d,
        "t_h2d": t_h2d,
        "comp": t_f.sum() + t_b.sum(),
        "sum_c": t_c.sum(),
        "tc_no": analytical.non_overlapped_comm_batch(t_b, t_c),
        "t_u": 3.0 * tbl["param_bytes"][w] / tbl["hbm_bw"][c],
        "n_f": n_f,
        "batch_f": batch_f,
    }
    for i, ov_comm in enumerate(tl_overlaps):
        dur = comm(tbl[f"bt{i}_nbytes"][w])
        out[f"tl{i}"] = bucketsim.timeline_residual(
            t_b, dur, tbl[f"bt{i}_release"][w], tbl[f"bt{i}_mask"][w],
            overlap_comm=ov_comm)
    return out


# ----------------------------------------------------------------------
# Tier 2: one scenario's policy select — vmapped over the scenario axis.
# ----------------------------------------------------------------------
def _point_select(pflags: dict, tl_overlaps: tuple, kc: dict, pi, kidx):
    """The jax twin of one row of
    :func:`repro.core.batched._policy_select` (same equations, same
    zero-comm weak-scaling baseline); method labels are strings and
    stay on the host side."""
    def g(name):
        return kc[name][kidx]

    ov_io = pflags["overlap_io"][pi]
    ov_comm = pflags["overlap_comm"][pi]
    early = pflags["h2d_early"][pi]

    comm_term = jnp.where(ov_comm, g("tc_no"), g("sum_c"))
    spec_of = pflags["tl_spec"][pi]
    for i, _ in enumerate(tl_overlaps):
        comm_term = jnp.where(spec_of == i, g(f"tl{i}"), comm_term)
    gpu_chain = g("comp") + comm_term + g("t_u")
    io_h2d, t_h2d = g("io_h2d"), g("t_h2d")
    eq2 = io_h2d + gpu_chain
    eq_early = jnp.maximum(io_h2d, gpu_chain)
    eq_late = jnp.maximum(io_h2d, t_h2d + gpu_chain)
    t_iter = jnp.where(~ov_io, eq2, jnp.where(early, eq_early, eq_late))

    base_chain = g("comp") + g("t_u")
    t1 = jnp.where(~ov_io, io_h2d + base_chain,
                   jnp.where(early, jnp.maximum(io_h2d, base_chain),
                             jnp.maximum(io_h2d, t_h2d + base_chain)))
    n_f, batch_f = g("n_f"), g("batch_f")
    return {
        "batch": batch_f,
        "iteration_time_s": t_iter,
        "samples_per_sec": n_f * batch_f / t_iter,
        "speedup": n_f * t1 / t_iter,
        "t_comm_s": g("sum_c"),
        "t_comp_s": g("comp"),
    }


@functools.partial(jax.jit, static_argnames=("tl_overlaps", "coll_codes"))
def _columns_jax(tables: dict, pflags: dict, kcodes: dict, scodes: dict,
                 tl_overlaps: tuple, coll_codes: tuple) -> dict:
    """The whole two-tier evaluation as one compiled function.
    Compilation is keyed by array shapes/dtypes and the static
    ``tl_overlaps``/``coll_codes`` tuples — re-running a grid (or any
    same-shaped grid) with fresh numbers reuses the executable."""
    kc = jax.vmap(
        lambda w, c, coll, n, b:
            _point_kernel(tables, tl_overlaps, coll_codes, w, c, coll, n, b)
    )(kcodes["w"], kcodes["c"], kcodes["coll"], kcodes["n"], kcodes["batch"])
    return jax.vmap(
        lambda pi, kidx: _point_select(pflags, tl_overlaps, kc, pi, kidx)
    )(scodes["pi"], scodes["kidx"])


# ----------------------------------------------------------------------
# Sharding: pad the batch axes to a device-count multiple and place
# the code vectors over the mesh's data axis.
# ----------------------------------------------------------------------
#: Benign fill for padding rows (index 0 is always valid; n=1 is the
#: zero-comm degenerate; batch=0 means "table default").
_PAD_FILL = {"n": 1}


def _shard_codes(codes: dict, mesh) -> dict:
    ndev = math.prod(mesh.devices.shape)
    axis = mesh.axis_names[0]
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec(axis))
    size = len(next(iter(codes.values())))
    pad = (-size) % ndev
    out = {}
    for k, v in codes.items():
        if pad:
            fill = np.full(pad, _PAD_FILL.get(k, 0), dtype=v.dtype)
            v = np.concatenate([v, fill])
        out[k] = jax.device_put(v, sharding)
    return out


# ----------------------------------------------------------------------
# Grid front end.
# ----------------------------------------------------------------------
class JaxGridEvaluator:
    """A :class:`ScenarioGrid` prepared for the jit/vmap kernels.

    Reuses the NumPy engine's memoized structure (axis tables, code
    vectors, label arrays) — only the numeric evaluation moves to XLA.
    Raises ``ValueError`` for grids containing simulator-only policies:
    unlike the NumPy engine there is no event-driven fallback to
    interleave, and silently falling back would defeat the point of
    selecting the backend explicitly.

    ``mesh=None`` autoselects: a data-parallel mesh over all devices
    when more than one is visible, unsharded otherwise.  Pass a mesh
    (e.g. :func:`repro.launch.mesh.make_dp_mesh`) to force sharding —
    a single-device mesh exercises the sharded path end to end.
    """

    def __init__(self, grid: ScenarioGrid, *, mesh=None):
        ev = grid_evaluator(grid)
        if not ev.all_batched:
            bad = [name for name, f, t in zip(
                ev._pax.names, ev._pax.has_fast, ev._pax.has_tl)
                if not (bool(f) or bool(t))]
            raise ValueError(
                f"backend='jax' evaluates closed-form and bucket-timeline "
                f"policies only; {bad} need the event-driven simulator. "
                f"Use backend='numpy' for grids containing them.")
        self.ev = ev
        self._tables, self._pflags = _axes_tables(ev._wax, ev._cax, ev._pax)
        self._tl_overlaps = tuple(bool(ov) for _, ov in ev._pax.tl_specs)
        self._coll_codes = tuple(int(x) for x in np.unique(ev._kcoll)) or (0,)
        kcodes = {"w": ev._kwidx, "c": ev._kcidx, "coll": ev._kcoll,
                  "n": ev._kn, "batch": ev._kbatch}
        S = len(ev)
        if S:
            sc = ev._scenario_codes(0, S)
            scodes = {"pi": sc["pi"], "kidx": sc["kidx"]}
        else:
            scodes = {"pi": np.empty(0, dtype=np.int64),
                      "kidx": np.empty(0, dtype=np.int64)}
        if mesh is None and len(jax.devices()) > 1:
            from repro.launch.mesh import make_dp_mesh
            mesh = make_dp_mesh(len(jax.devices()))
        self.mesh = mesh
        if mesh is not None and S:
            with enable_x64():
                kcodes = _shard_codes(kcodes, mesh)
                scodes = _shard_codes(scodes, mesh)
        self._kcodes, self._scodes = kcodes, scodes

    def __len__(self) -> int:
        return len(self.ev)

    def columns(self, params: dict | None = None) -> dict[str, np.ndarray]:
        """All numeric result columns as host float64 ``(S,)`` arrays
        (blocks on the device computation).  ``params`` optionally
        overrides the :data:`PARAM_KEYS` entries."""
        S = len(self.ev)
        if S == 0:
            return {k: np.empty(0) for k in _NUMERIC_COLS}
        with enable_x64():
            out = self._traced_columns(params)
            return {k: np.asarray(v)[:S] for k, v in out.items()
                    if k in _NUMERIC_COLS}

    def _traced_columns(self, params: dict | None = None) -> dict:
        """The jit call itself — kept separate so the differentiable
        front end (:func:`iteration_time_fn`) can trace through it.
        Callers are responsible for the ``enable_x64`` scope."""
        tables = self._tables
        if params:
            unknown = set(params) - set(PARAM_KEYS)
            if unknown:
                raise ValueError(f"unknown param keys {sorted(unknown)}; "
                                 f"differentiable params are {PARAM_KEYS}")
            tables = {**tables, **params}
        return _columns_jax(tables, self._pflags, self._kcodes,
                            self._scodes, self._tl_overlaps,
                            self._coll_codes)

    def run(self, params: dict | None = None) -> "JaxGridRun":
        return JaxGridRun(self, self.columns(params))

    def method_labels(self, pi: np.ndarray) -> list[str]:
        """Per-row evaluation-path labels (``all_batched`` holds, so
        only the two batched labels occur)."""
        return np.where(self.ev._pax.has_fast[pi],
                        "analytical", "timeline").tolist()


class JaxGridRun:
    """One evaluation of a grid on the jax backend: host-side numeric
    columns plus the shared structure, materializing tidy rows chunk by
    chunk — the jax twin of :class:`repro.core.batched.GridRun` (no
    ``None`` entries: simulator-only grids are rejected up front)."""

    def __init__(self, jev: JaxGridEvaluator, cols: dict[str, np.ndarray]):
        self._jev = jev
        self._cols = cols

    def __len__(self) -> int:
        return len(self._jev)

    def columns_slice(self, lo: int, hi: int) -> dict[str, np.ndarray]:
        ev = self._jev.ev
        out = {k: v[lo:hi] for k, v in self._cols.items()}
        out["method"] = self._jev.method_labels(
            ev._scenario_codes(lo, hi)["pi"])
        return out

    def rows_slice(self, lo: int, hi: int) -> list[dict]:
        ev = self._jev.ev
        codes = ev._scenario_codes(lo, hi)
        cols = {k: v[lo:hi] for k, v in self._cols.items()}
        cols["method"] = self._jev.method_labels(codes["pi"])
        return batched._make_rows(
            ev._wl_values[codes["wi"]].tolist(),
            ev._cl_values[codes["ci"]].tolist(),
            ev._n_values[codes["ki"]].tolist(),
            ev._pol_values[codes["pi"]].tolist(),
            ev._coll_values[codes["ai"]].tolist(),
            ev._ic_values[codes["ii"]].tolist(), cols)


#: Structure memo, mirroring :func:`repro.core.batched.grid_evaluator`
#: (separate because the jax evaluator also holds device-side codes).
_JAX_MEMO: dict = {}
_MEMO_LIMIT = 64


def jax_grid_evaluator(grid: ScenarioGrid, *, mesh=None) -> JaxGridEvaluator:
    """Memoized :class:`JaxGridEvaluator` (unsharded/auto mesh only —
    explicit meshes always build fresh)."""
    if mesh is not None:
        return JaxGridEvaluator(grid, mesh=mesh)
    try:
        from repro.core.workloads import resolve_workload
        tables = tuple(resolve_workload(w) for w in grid.workloads)
        key = (grid, tuple(id(t) for t in tables))
        hash(key)
    except TypeError:
        return JaxGridEvaluator(grid)
    hit = _JAX_MEMO.get(key)
    if hit is not None:
        return hit[0]
    if len(_JAX_MEMO) >= _MEMO_LIMIT:
        _JAX_MEMO.clear()
    jev = JaxGridEvaluator(grid)
    _JAX_MEMO[key] = (jev, tables)
    return jev


# ----------------------------------------------------------------------
# Scenario-list front end — jax twin of batched.eval_scenarios.
# ----------------------------------------------------------------------
def eval_scenarios_jax(scenarios: Sequence[Scenario] | Iterable[Scenario]
                       ) -> list[dict]:
    """Batched rows (input order) for a list of batched-path-eligible
    scenarios, evaluated by the jit/vmap kernels with the identity
    scenario -> kernel-point map.  Raises ``ValueError`` (via
    :func:`repro.core.batched.scenario_axes`) if any scenario's policy
    has neither a closed nor a bucket-timeline form."""
    scenarios = list(scenarios)
    if not scenarios:
        return []
    wax, cax, pax, widx, cidx, polidx, coll, n, batch = \
        batched.scenario_axes(scenarios)
    tables, pflags = _axes_tables(wax, cax, pax)
    tl_overlaps = tuple(bool(ov) for _, ov in pax.tl_specs)
    S = len(scenarios)
    kcodes = {"w": widx, "c": cidx, "coll": coll, "n": n, "batch": batch}
    scodes = {"pi": polidx, "kidx": np.arange(S, dtype=np.int64)}
    coll_codes = tuple(int(x) for x in np.unique(coll)) or (0,)
    with enable_x64():
        out = _columns_jax(tables, pflags, kcodes, scodes, tl_overlaps,
                           coll_codes)
        cols = {k: np.asarray(v) for k, v in out.items()
                if k in _NUMERIC_COLS}
    cols["method"] = np.where(pax.has_fast[polidx],
                              "analytical", "timeline").tolist()
    return batched._make_rows(
        [s.workload for s in scenarios],
        [s.cluster for s in scenarios],
        [s.n_workers for s in scenarios],
        [s.policy for s in scenarios],
        [s.collective for s in scenarios],
        [normalize_interconnect(s.interconnect) for s in scenarios],
        cols)


# ----------------------------------------------------------------------
# Differentiable front end.
# ----------------------------------------------------------------------
def default_params(grid: ScenarioGrid) -> dict[str, np.ndarray]:
    """The grid's resolved continuous inputs (:data:`PARAM_KEYS`):
    per-pair link bandwidths/latencies and per-timeline-spec bucket
    sizes — the point :func:`iteration_time_fn` differentiates
    around."""
    jev = jax_grid_evaluator(grid)
    return {k: np.array(jev._tables[k], dtype=np.float64, copy=True)
            for k in PARAM_KEYS}


def iteration_time_fn(grid: ScenarioGrid):
    """``(f, params0)``: ``f(params) -> (S,)`` iteration times, jit
    compiled and differentiable w.r.t. every :data:`PARAM_KEYS` entry.
    Call (and differentiate) ``f`` inside a
    ``jax.experimental.enable_x64()`` scope, or use the
    :func:`grad_iteration_time` convenience wrapper.

    The gradient w.r.t. ``bucket_bytes`` is exactly 0: iteration time
    is piecewise constant in the bucket size (see the module
    docstring), and ``f`` holds the partition fixed at ``params0``'s
    structure.  :func:`numpy_iteration_times` *rebuilds* the partition
    per call, so central differences on it recover the same 0 inside a
    partition cell."""
    jev = jax_grid_evaluator(grid)
    S = len(jev)

    def f(params: dict):
        return jev._traced_columns(params)["iteration_time_s"][:S]

    return f, default_params(grid)


def grad_iteration_time(grid: ScenarioGrid,
                        params: dict | None = None) -> dict[str, np.ndarray]:
    """``d(sum of iteration times)/d(params)`` as host arrays — the
    end-to-end differentiability surface the gradient-correctness
    tests pin against NumPy central differences."""
    f, p0 = iteration_time_fn(grid)
    if params:
        p0 = {**p0, **params}
    with enable_x64():
        p = {k: jnp.asarray(v, dtype=jnp.float64) for k, v in p0.items()}
        g = jax.grad(lambda q: f(q).sum())(p)
        return {k: np.asarray(v) for k, v in g.items()}


def numpy_iteration_times(grid: ScenarioGrid,
                          params: dict | None = None) -> np.ndarray:
    """The NumPy oracle over the same params surface: link overrides
    swap into the cluster axis, bucket-size overrides *rebuild* the
    bucket partitions.  This is the finite-difference reference for
    :func:`grad_iteration_time` and the numeric side of the CI
    agreement gate."""
    ev = grid_evaluator(grid)
    cax = ev._cax
    tl_specs = list(ev._pax.tl_specs)
    if params:
        link = {k: np.asarray(params[k], dtype=np.float64)
                for k in ("intra_bw", "intra_lat", "inter_bw", "inter_lat")
                if k in params}
        if link:
            cax = dataclasses.replace(cax, **link)
        if "bucket_bytes" in params:
            bb = np.asarray(params["bucket_bytes"], dtype=np.float64)
            tl_specs = [(float(bb[i]), ov)
                        for i, (_, ov) in enumerate(tl_specs)]
    kc = batched._kernel_cols(ev._wax, cax, ev._kwidx, ev._kcidx,
                              ev._kcoll, ev._kn, ev._kbatch,
                              tl_specs=tl_specs)
    codes = ev._scenario_codes(0, len(ev))
    return batched._policy_select(ev._pax, codes["pi"], kc,
                                  codes["kidx"])["iteration_time_s"]
