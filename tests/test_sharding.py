"""Sharding rules: divisibility-aware spec resolution (pure logic, no
devices needed)."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.models import sharding as shd
from repro.models import transformer as T

SIZES_1POD = {"data": 16, "model": 16}
SIZES_2POD = {"pod": 2, "data": 16, "model": 16}


@pytest.fixture(autouse=True)
def _mesh_sizes():
    tok = shd.set_mesh_sizes(SIZES_1POD)
    yield
    shd.set_mesh_sizes(None)


def sc(mode="fsdp", axes=("data", "model")):
    return shd.ShardingConfig(mesh_axes=axes, mode=mode)


class TestResolveSpec:
    def test_divisible(self):
        spec = shd.resolve_spec((64, 32), [["fsdp"], ["tensor"]], sc())
        assert spec == P("data", "model")

    def test_indivisible_falls_back(self):
        spec = shd.resolve_spec((65, 32), [["fsdp"], ["tensor"]], sc())
        assert spec == P(None, "model")

    def test_candidate_fallback_kv_heads(self):
        # GQA kv projection (d, K=8, hd=128): tensor can't take K=8,
        # falls through to head_dim
        spec = shd.resolve_spec((6144, 8, 128),
                                [["fsdp"], ["tensor"], ["tensor"]], sc())
        assert spec == P("data", None, "model")

    def test_axis_used_once(self):
        spec = shd.resolve_spec((64, 64), [["tensor"], ["tensor"]], sc())
        assert spec == P("model", None)

    def test_batch_tuple_progressive_drop(self):
        shd.set_mesh_sizes(SIZES_2POD)
        c = sc(axes=("pod", "data", "model"))
        assert shd.resolve_spec((64,), [["batch"]], c) == P(("pod", "data"))
        # batch=2 only fits the pod axis
        assert shd.resolve_spec((2,), [["batch"]], c) == P(("pod",))
        # batch=1 cannot shard at all
        assert shd.resolve_spec((1,), [["batch"]], c) == P(None)

    def test_pure_dp_mode_disables_fsdp(self):
        spec = shd.resolve_spec((64, 32), [["fsdp"], ["tensor"]],
                                sc(mode="pure_dp"))
        assert spec == P(None, "model")


class TestParamSpecs:
    def test_dense_arch_specs(self):
        cfg = get_config("internlm2-20b")
        pshape = jax.eval_shape(lambda k: T.init_lm(cfg, k),
                                jax.random.PRNGKey(0))
        specs = shd.param_specs(pshape, sc())
        # embedding (92544, 6144) -> vocab on model, d on data
        assert specs["embedding"] == P("model", "data")
        unit = specs["units"]["b0"]
        # stacked wq (U, d, H, hd): leading unit dim unsharded
        assert unit["attn"]["wq"] == P(None, "data", "model", None)
        # kv heads = 8 < 16 and head_dim is NEVER sharded (a sharded
        # contraction; see EXPERIMENTS.md §Perf iteration 1) -> kv
        # projections replicate their head dims
        assert unit["attn"]["wk"] == P(None, "data", None, None)
        assert unit["mlp"]["wi"] == P(None, "data", "model")
        assert unit["mlp"]["wo"] == P(None, "model", "data")
        assert specs["final_norm"]["scale"] == P(None)

    def test_moe_expert_parallel(self):
        cfg = get_config("qwen2-moe-a2.7b")
        pshape = jax.eval_shape(lambda k: T.init_lm(cfg, k),
                                jax.random.PRNGKey(0))
        specs = shd.param_specs(pshape, sc())
        moe = specs["units"]["b0"]["moe"]
        # experts (E=60, d, ff): E % 16 != 0, so experts fall back to
        # tensor-parallel over their hidden dim (stacked leading None);
        # wo's middle (row) dim stays unsharded — the output all-reduce
        # is equivalent (EXPERIMENTS.md §Perf iteration 2)
        assert moe["wi"] == P(None, None, "data", "model")
        assert moe["wo"] == P(None, None, None, "data")
        assert moe["router"] == P(None, "data", None)

    def test_cache_specs_decode(self):
        cfg = get_config("internlm2-20b")
        cache = jax.eval_shape(lambda: T.init_cache(cfg, 128, 1024))
        specs = shd.cache_specs(cache, sc())
        kspec = specs["units"]["b0"]["k"]
        # (U, B=128, S, K=8, hd=128): batch on data, cache *sequence*
        # on model (EXPERIMENTS.md §Perf iteration 6)
        assert kspec == P(None, ("data",), "model", None, None)

    def test_cache_specs_long_context_seq_shard(self):
        cfg = get_config("gemma3-1b")
        cache = jax.eval_shape(lambda: T.init_cache(cfg, 1, 4096))
        specs = shd.cache_specs(cache, sc())
        # global layer cache: batch=1 unshardable -> seq takes data
        gspec = specs["units"]["b5"]["k"]   # pattern LLLLLG -> b5 is 'G'
        assert gspec[1] is None
        assert gspec[2] == "data"


class TestConstrainNoMesh:
    def test_noop_without_context(self):
        shd.set_sharding(None)
        x = jnp.ones((4, 4))
        assert shd.constrain(x, "batch", None) is x
