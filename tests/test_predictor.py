"""Predictor + cost model: reproduce the paper's §V findings."""
import pytest

from repro.core.costmodel import (CNN_WORKLOADS, alexnet_layers,
                                  googlenet_layers, make_iteration_costs,
                                  resnet50_layers, total_flops, total_params)
from repro.core.hardware import (K80_CLUSTER, TPU_V5E_POD, V100_CLUSTER)
from repro.core.policies import BUCKETED_25MB, CAFFE_MPI, CNTK, MXNET
from repro.core.predictor import predict_cnn, scaling_curve


class TestCostTables:
    def test_alexnet_params_match_paper(self):
        # Table IV: ~60 millions
        assert total_params(alexnet_layers()) == pytest.approx(61e6, rel=0.03)

    def test_resnet50_params(self):
        # ~25.5M (paper quotes ~24M)
        assert total_params(resnet50_layers()) == pytest.approx(25.5e6, rel=0.05)

    def test_googlenet_params(self):
        # actual inception-v1 (~7M; see DESIGN.md note on Table IV)
        assert total_params(googlenet_layers()) == pytest.approx(7.0e6, rel=0.1)

    def test_resnet_flops(self):
        # ~7.7 GFLOPs (multiply-acc*2) per 224x224 sample (fwd, incl.
        # elementwise)
        assert total_flops(resnet50_layers()) == pytest.approx(7.7e9, rel=0.1)


class TestPaperFindings:
    def test_k80_resnet_backward_calibration(self):
        """Paper §V-C2: ResNet-50 backward ~0.243 s on K80, ~0.0625 s
        on V100 (batch 32)."""
        layers = resnet50_layers()
        for cluster, want in ((K80_CLUSTER, 0.243), (V100_CLUSTER, 0.0625)):
            c = make_iteration_costs(layers, cluster, 32, 16)
            assert sum(c.t_b) == pytest.approx(want, rel=0.25)

    def test_v100_resnet_comm_calibration(self):
        """Gradient aggregation ~79.7 ms for ResNet-50 on 16 V100s
        over 100Gb IB."""
        c = make_iteration_costs(resnet50_layers(), V100_CLUSTER, 32, 16)
        assert sum(c.t_c) == pytest.approx(0.0797, rel=0.25)

    def test_k80_cluster_hides_communication(self):
        """On the slow cluster comm hides behind backward (near-linear
        scaling, paper Fig. 3a)."""
        p = predict_cnn("resnet50", K80_CLUSTER, 16, CAFFE_MPI)
        assert p.speedup > 11.0     # >70% efficiency at 16 GPUs

    def test_v100_cluster_is_comm_bound(self):
        """On the fast cluster ResNet becomes communication-bound and
        scaling efficiency drops well below the K80 cluster's (paper
        Fig. 3b shows ~10/16 for the best framework)."""
        p16 = predict_cnn("resnet50", V100_CLUSTER, 16, CAFFE_MPI)
        k16 = predict_cnn("resnet50", K80_CLUSTER, 16, CAFFE_MPI)
        assert p16.speedup < 12.0
        assert p16.speedup < k16.speedup
        assert p16.comm_utilization > 0.5

    def test_framework_ordering_on_both_clusters(self):
        for cluster in (K80_CLUSTER, V100_CLUSTER):
            t = {pol.name: predict_cnn("resnet50", cluster, 16, pol)
                 .iteration_time for pol in (CAFFE_MPI, MXNET, CNTK)}
            assert t["caffe-mpi"] <= t["mxnet"] + 1e-9
            assert t["mxnet"] <= t["cntk"] + 1e-9

    def test_weak_scaling_monotone_in_workers(self):
        curve = scaling_curve("googlenet", K80_CLUSTER, CAFFE_MPI,
                              worker_counts=(1, 2, 4, 8, 16))
        sps = [p.samples_per_sec for p in curve]
        assert all(b > a for a, b in zip(sps, sps[1:]))

    def test_bucketing_beats_layerwise_when_comm_bound(self):
        """Beyond-paper: fusing gradients recovers the latency the
        paper blames for 9.6% bandwidth utilization."""
        base = predict_cnn("resnet50", V100_CLUSTER, 16, CAFFE_MPI)
        fused = predict_cnn("resnet50", V100_CLUSTER, 16, BUCKETED_25MB)
        assert fused.iteration_time <= base.iteration_time * 1.02

    def test_tpu_pod_predictions_finite(self):
        p = predict_cnn("resnet50", TPU_V5E_POD, 256, CAFFE_MPI)
        assert p.iteration_time > 0 and p.speedup > 1
