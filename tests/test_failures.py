"""Failure-aware S-SGD: K-of-N partial sync and fault injection.

Pins the failure-model contracts:

* The ``fail:`` grammar round-trips, rejects malformed specs, and the
  seed-keyed crash matrices are deterministic and backend-independent.
* :func:`repro.core.analytical.kth_order_statistic` is the K-th
  smallest over the live (unpadded) workers — exact against ``np.sort``
  on random tables, in NumPy and **inside jit** via ``jax.lax.top_k``.
* ``sync_k = N`` (and 0/None/over-large K) is **bit-identical** to the
  historical full-sync path; iteration time is monotone non-increasing
  in K; ``K = 1`` waits only for the fastest worker.
* The K-of-N / fault closed forms agree with the event-driven DAG
  oracle to <= 1e-6 on the built-in grid and random grids, and the two
  batched backends agree draw-for-draw with faults enabled.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings

from strategies import fault_specs, scenario_grids, sync_ks, worker_rates
from repro.core import analytical
from repro.core import het
from repro.core.scenarios import (Scenario, ScenarioGrid, default_grid,
                                  normalize_sync_k, validate_sync_k)
from repro.core.sweep import evaluate_scenario, sweep


class TestFaultGrammar:
    def test_parse_full_spec(self):
        ft = het.parse_fault("fail:0.05@restart2.5x500")
        assert ft == het.FaultSpec(p=0.05, restart=2.5, draws=500)
        assert not ft.is_deterministic

    def test_parse_defaults(self):
        ft = het.parse_fault("fail:0.1")
        assert ft.restart == het.DEFAULT_RESTART_S
        assert ft.draws == het.DEFAULT_DRAWS
        assert het.parse_fault("fail:0.1x64").draws == 64

    def test_none_and_normalize(self):
        assert het.parse_fault(None) is None
        assert het.parse_fault("none") is None
        assert het.normalize_fault(None) == "none"
        assert het.normalize_fault("fail:0.1") == "fail:0.1"

    def test_deterministic_degenerates(self):
        assert het.parse_fault("fail:0").is_deterministic
        assert het.parse_fault("fail:0.5@restart0").is_deterministic

    @pytest.mark.parametrize("bad", [
        "fail:", "fail:x", "fail:1.5", "fail:-0.1", "fail:0.1@boom2",
        "fail:0.1@restart-1", "fail:0.1@restartx", "fail:0.1x0",
        "fail:0.1x999999999", "lognormal:0.2", "0.1"])
    def test_malformed_specs_rejected(self, bad):
        with pytest.raises(ValueError):
            het.parse_fault(bad)

    def test_crash_matrix_seeded_and_shaped(self):
        ft = het.parse_fault("fail:0.3@restart1x200")
        a = ft.crash_matrix(8, seed=7)
        assert a.shape == (200, 8) and a.dtype == bool
        assert np.array_equal(a, ft.crash_matrix(8, seed=7))
        assert not np.array_equal(a, ft.crash_matrix(8, seed=8))
        # draw-count override re-keys the stream (shard/backend safety)
        assert ft.crash_matrix(8, seed=7, draws=64).shape == (64, 8)

    def test_crash_rate_matches_p(self):
        ft = het.parse_fault("fail:0.25@restart1x4000")
        rate = ft.crash_matrix(16, seed=0).mean()
        assert rate == pytest.approx(0.25, abs=0.02)

    def test_restart_penalty_from_checkpoint_size(self):
        # a 10 GB checkpoint over a 2 GB/s store reads in 5 s
        assert het.restart_penalty_s(10e9) == pytest.approx(5.0)
        with pytest.raises(ValueError):
            het.restart_penalty_s(-1.0)


class TestSyncKAxis:
    def test_normalize(self):
        assert normalize_sync_k(None) == 0
        assert normalize_sync_k("none") == 0
        assert normalize_sync_k(0) == 0
        assert normalize_sync_k(3) == 3

    def test_validate(self):
        validate_sync_k(None)
        validate_sync_k(4)
        with pytest.raises(ValueError):
            validate_sync_k(-1)
        with pytest.raises(ValueError):
            validate_sync_k("three")

    def test_scenario_label_and_grid_roundtrip(self):
        g = dataclasses.replace(
            default_grid(), workloads=("alexnet",), worker_counts=(8,),
            policies=("tensorflow",), sync_ks=(None, 6),
            faults=(None, "fail:0.01@restart2x8"))
        assert len(g) == len(g.expand())
        for i, s in enumerate(g.expand()):
            assert g.scenario_at(i) == s
            s.validate()
        labels = {s.label() for s in g.expand()}
        assert any("/k6" in l for l in labels)
        assert any("fail:0.01@restart2x8" in l for l in labels)

    def test_bad_axis_values_rejected(self):
        with pytest.raises(ValueError):
            dataclasses.replace(default_grid(),
                                sync_ks=(-2,)).validate_axes()
        with pytest.raises(ValueError):
            dataclasses.replace(default_grid(),
                                faults=("fail:2",)).validate_axes()


class TestKthOrderStatistic:
    @settings(max_examples=30, deadline=None)
    @given(worker_rates(), sync_ks())
    def test_matches_sort_on_random_vectors(self, rates, k):
        n = len(rates)
        keff = int(analytical.effective_sync_k(
            normalize_sync_k(k), n))
        got = analytical.kth_order_statistic(
            rates[None, :], np.array(n), np.array(keff))
        assert got[0] == np.sort(rates)[keff - 1]

    def test_padded_rows_ignore_pads(self):
        # zero-padded worker table rows: pads must never win
        vals = np.array([[3.0, 1.0, 2.0, 0.0, 0.0],
                         [5.0, 4.0, 0.0, 0.0, 0.0]])
        n = np.array([3, 2])
        k = np.array([2, 1])
        got = analytical.kth_order_statistic(vals, n, k)
        assert got.tolist() == [2.0, 4.0]

    def test_jitted_jax_top_k_agrees_with_numpy(self):
        import jax
        import jax.numpy as jnp
        from jax.experimental import enable_x64

        rng = np.random.default_rng(0)
        vals = rng.uniform(0.1, 2.0, size=(32, 7))
        n = rng.integers(1, 8, size=32)
        vals *= np.arange(7) < n[:, None]          # zero-pad dead slots
        k = np.minimum(rng.integers(1, 8, size=32), n)
        want = analytical.kth_order_statistic(vals, n, k)
        with enable_x64():
            got = jax.jit(analytical.kth_order_statistic)(
                jnp.asarray(vals), jnp.asarray(n), jnp.asarray(k))
        np.testing.assert_allclose(np.asarray(got), want, rtol=1e-12)

    def test_effective_sync_k_clamps(self):
        n = np.array([4, 4, 4, 4])
        k = np.array([0, 1, 4, 99])
        assert analytical.effective_sync_k(k, n).tolist() == [4, 1, 4, 4]

    def test_worker_bottleneck_k_full_sync_is_max(self):
        inv = np.array([[1.0, 2.0, 0.5]])
        bw = np.array([[1.0, 0.5, 1.0]])
        lat = np.array([[1.0, 2.0, 1.0]])
        t, b, l = analytical.worker_bottleneck_k(
            inv, bw, lat, np.array([3]), np.array([0]))
        t0, b0, l0 = analytical.worker_bottleneck(inv, bw, lat)
        assert (t[0], b[0], l[0]) == (t0[0], b0[0], l0[0]) == (2.0, 0.5, 2.0)
        # K=2 takes the 2nd smallest compute multiplier, links unchanged
        t2, b2, l2 = analytical.worker_bottleneck_k(
            inv, bw, lat, np.array([3]), np.array([2]))
        assert (t2[0], b2[0], l2[0]) == (1.0, 0.5, 2.0)


def _grid(**axes) -> ScenarioGrid:
    base = dict(workloads=("alexnet",), clusters=("v100-nvlink-ib",),
                worker_counts=(8,), policies=("tensorflow",),
                collectives=("ring",))
    base.update(axes)
    return ScenarioGrid(**base)


class TestKofNSemantics:
    def test_k_equals_n_bit_identical_to_full_sync(self):
        axes = dict(worker_counts=(8,),
                    policies=("tensorflow", "caffe-mpi", "bucketed-4mb"),
                    het_profiles=(None, "het:1x0.5+3x1.0"),
                    stragglers=(None, "lognormal:0.25x64"),
                    faults=(None, "fail:0.1@restart1x64"))
        full = sweep(_grid(sync_ks=(None,), **axes), seed=5)
        k_n = sweep(_grid(sync_ks=(8,), **axes), seed=5)
        over = sweep(_grid(sync_ks=(99,), **axes), seed=5)
        for c in ("iteration_time_s", "t_mean_s", "t_p95_s", "t_p99_s",
                  "samples_per_sec", "speedup"):
            assert np.array_equal(full.columns[c], k_n.columns[c]), c
            assert np.array_equal(full.columns[c], over.columns[c]), c

    def test_monotone_non_increasing_in_k(self):
        g = _grid(het_profiles=("het:2x0.4+2x0.8+4x1.2",),
                  sync_ks=tuple(range(1, 9)))
        t = sweep(g).columns["iteration_time_s"]
        assert np.all(np.diff(t) >= -1e-12)
        assert t[0] < t[-1]          # the het spread makes K matter

    def test_k1_waits_for_fastest_worker_only(self):
        prof = "het:1x0.5+7x1.0"     # one half-speed worker in 8
        inv, _, _ = het.worker_vectors(het.parse_het_profile(prof), 8)
        r1 = sweep(_grid(het_profiles=(prof,), sync_ks=(1,))).rows[0]
        # fastest worker: multiplier min(inv) — evaluate the equivalent
        # homogeneous scenario scaled to it via a uniform profile
        fast = sweep(_grid(het_profiles=(f"het:8x{1 / inv.min():g}",),
                           sync_ks=(None,))).rows[0]
        assert r1["iteration_time_s"] == pytest.approx(
            fast["iteration_time_s"], rel=1e-12)

    def test_homogeneous_sync_k_is_noop(self):
        full = sweep(_grid(sync_ks=(None,)))
        k3 = sweep(_grid(sync_ks=(3,)))
        assert np.array_equal(full.columns["iteration_time_s"],
                              k3.columns["iteration_time_s"])

    def test_fault_tails_shift_with_restart(self):
        base = sweep(_grid(faults=("fail:0.2@restart1x400",)), seed=1)
        dbl = sweep(_grid(faults=("fail:0.2@restart2x400",)), seed=1)
        r0, r1 = base.rows[0], dbl.rows[0]
        assert r1["t_mean_s"] > r0["t_mean_s"] > r0["iteration_time_s"]
        assert r1["iteration_time_s"] == r0["iteration_time_s"]

    def test_deterministic_fault_specs_keep_point_mass(self):
        for spec in ("fail:0@restart5x64", "fail:0.5@restart0x64"):
            r = sweep(_grid(faults=(spec,))).rows[0]
            assert r["t_mean_s"] == r["t_p99_s"] == r["iteration_time_s"]


class TestOracleAgreement:
    """Closed form vs the event-driven DAG simulator, <= 1e-6."""

    COLS = ("iteration_time_s", "t_mean_s", "t_p95_s", "t_p99_s")

    def assert_sim_agrees(self, grid, seed=0, rel=1e-6):
        fast = sweep(grid, seed=seed)
        sim = sweep(grid, force_simulator=True, seed=seed)
        for c in self.COLS:
            np.testing.assert_allclose(
                fast.columns[c], sim.columns[c], rtol=rel, err_msg=c)

    def test_builtin_grid_with_failure_axes(self):
        g = dataclasses.replace(
            default_grid(), workloads=("alexnet",),
            worker_counts=(4, 16), collectives=("ring",),
            interconnects=(None,),
            het_profiles=(None, "het:1x0.5+3x1.0"),
            stragglers=(None, "lognormal:0.25x16"),
            sync_ks=(None, 3), faults=(None, "fail:0.2@restart1.5x16"))
        self.assert_sim_agrees(g, seed=11)

    @settings(max_examples=5, deadline=None)
    @given(scenario_grids(with_het=True, with_failures=True))
    def test_random_grids_numpy_vs_simulator(self, grid):
        # keep the oracle affordable: simulator-eligible closed forms,
        # one workload/cluster slice of the drawn grid
        grid = dataclasses.replace(
            grid, workloads=grid.workloads[:1], clusters=grid.clusters[:1],
            policies=("tensorflow", "caffe-mpi"),
            worker_counts=grid.worker_counts[:2],
            interconnects=grid.interconnects[:1],
            stragglers=tuple(s for s in grid.stragglers
                             if s is None or "x8" in s or "x16" in s)
            or (None,),
            faults=tuple(f for f in grid.faults
                         if f is None or "x8" in f or "x16" in f)
            or (None,))
        self.assert_sim_agrees(grid, seed=3)

    @settings(max_examples=8, deadline=None)
    @given(scenario_grids(with_het=True, with_failures=True))
    def test_random_grids_numpy_vs_jax_draw_for_draw(self, grid):
        r = sweep(grid, seed=9)
        rj = sweep(grid, backend="jax", seed=9)
        for c in self.COLS + ("samples_per_sec", "speedup"):
            np.testing.assert_allclose(
                r.columns[c], rj.columns[c], rtol=1e-6, err_msg=c)
        for c in ("sync_k", "faults"):
            assert np.array_equal(r.columns[c], rj.columns[c]), c

    def test_single_scenario_oracle_with_crashes(self):
        s = Scenario("alexnet", "v100-nvlink-ib", 8, "tensorflow",
                     het="het:1x0.5+7x1.0", sync_k=6,
                     faults="fail:0.3@restart2x32")
        fast = evaluate_scenario(s, seed=2)
        sim = evaluate_scenario(s, method="simulator", seed=2)
        for c in self.COLS:
            assert fast[c] == pytest.approx(sim[c], rel=1e-6), c


class TestFailureColumnsAndCli:
    def test_result_filter_normalizes_failure_axes(self):
        g = _grid(sync_ks=(None, 4), faults=(None, "fail:0.1x8"))
        r = sweep(g)
        assert r.filter(sync_k=None) == r.filter(sync_k=0)
        assert r.filter(faults=None) == r.filter(faults="none")
        assert len(r.filter(sync_k=4, faults="fail:0.1x8")) == 1

    def test_format_table_shows_failure_columns(self):
        g = _grid(sync_ks=(4,), faults=("fail:0.1@restart1x8",))
        text = sweep(g).format_table()
        assert "faults" in text and "fail:0.1@restart1x8" in text

    def test_cli_flags(self, capsys, tmp_path):
        import json

        from repro.launch.sweep import main

        path = tmp_path / "cli.json"
        assert main(["--workloads", "alexnet", "--workers", "8",
                     "--policies", "tensorflow", "--sync-k", "none,6",
                     "--faults", "none,fail:0.05@restart1x8",
                     "--json", str(path)]) == 0
        out = capsys.readouterr().out
        assert "2 sync-k" in out and "2 faults" in out
        rows = json.loads(path.read_text())["rows"]
        assert {r["sync_k"] for r in rows} == {0, 6}
        assert {r["faults"] for r in rows} == {"none",
                                               "fail:0.05@restart1x8"}

    def test_cli_rejects_bad_fault_spec(self, capsys):
        from repro.launch.sweep import main

        assert main(["--faults", "fail:2"]) == 2
        assert "error" in capsys.readouterr().err
