"""Scenario-sweep engine: grid expansion, analytical-vs-simulator
agreement, collective-algorithm cost models, and bandwidth
monotonicity (ISSUE 1 acceptance criteria)."""
import dataclasses
import random

import pytest

from repro.core import analytical as A
from repro.core import hardware as HW
from repro.core.costmodel import make_iteration_costs, CNN_WORKLOADS
from repro.core.policies import ALL_POLICIES, get_policy
from repro.core.scenarios import (Scenario, ScenarioGrid, default_grid,
                                  resolve_cluster)
from repro.core.sweep import evaluate_scenario, has_fast_path, sweep

EXACT_POLICIES = ("naive", "cntk", "mxnet", "tensorflow", "caffe-mpi")


class TestGridExpansion:
    def test_cross_product_size(self):
        g = ScenarioGrid(workloads=("alexnet",), clusters=("v100-nvlink-ib",),
                         worker_counts=(1, 4), policies=("naive", "cntk"),
                         collectives=("ring", "tree"),
                         interconnects=(None, "ib-200g"))
        scenarios = g.expand()
        assert len(scenarios) == len(g) == 1 * 1 * 2 * 2 * 2 * 2
        assert len(set(scenarios)) == len(scenarios)      # all distinct

    def test_default_grid_meets_acceptance_size(self):
        assert len(default_grid()) >= 500

    @pytest.mark.parametrize("field,value", [
        ("workload", "vgg16"), ("cluster", "dgx-h100"),
        ("policy", "horovod"), ("collective", "butterfly"),
        ("interconnect", "carrier-pigeon"), ("n_workers", 0),
        ("batch_per_gpu", 0), ("batch_per_gpu", -4)])
    def test_invalid_axis_value_rejected(self, field, value):
        kw = dict(workload="alexnet", cluster="v100-nvlink-ib",
                  n_workers=4, policy="naive")
        kw[field] = value
        with pytest.raises(ValueError):
            Scenario(**kw).validate()

    def test_replace_rejects_unknown_axis(self):
        with pytest.raises(TypeError):
            dataclasses.replace(default_grid(), worker_count=(1,))

    def test_resolve_cluster_sizes_nodes(self):
        s = Scenario("alexnet", "v100-nvlink-ib", 32, "naive")
        c = resolve_cluster(s)
        assert c.gpus_per_node == 4 and c.n_nodes == 8
        s1 = Scenario("alexnet", "v100-nvlink-ib", 3, "naive")
        assert resolve_cluster(s1).n_nodes == 1

    def test_resolve_cluster_applies_preset(self):
        s = Scenario("alexnet", "k80-pcie-10gbe", 16, "naive",
                     interconnect="ib-100g")
        assert resolve_cluster(s).inter.name == "ib-100g"


class TestArrayValuedClosedForms:
    def test_closed_form_accepts_numpy_costs(self):
        """The fast path feeds ndarray-valued IterationCosts into the
        scalar closed forms; they must agree with list-based costs."""
        import numpy as np

        from repro.core.dag import IterationCosts

        rng = random.Random(7)
        for _ in range(50):
            L = rng.randint(1, 12)
            t_f = [rng.uniform(0.01, 10.0) for _ in range(L)]
            t_b = [rng.uniform(0.01, 10.0) for _ in range(L)]
            t_c = [rng.uniform(0.0, 10.0) if rng.random() > 0.3 else 0.0
                   for _ in range(L)]
            lists = IterationCosts(t_f=t_f, t_b=t_b, t_c=t_c,
                                   t_io=1.0, t_h2d=0.5, t_u=0.2)
            arrays = IterationCosts(t_f=np.asarray(t_f),
                                    t_b=np.asarray(t_b),
                                    t_c=np.asarray(t_c),
                                    t_io=1.0, t_h2d=0.5, t_u=0.2)
            for name in EXACT_POLICIES:
                pol = get_policy(name)
                assert float(A.closed_form(arrays, pol)) == pytest.approx(
                    A.closed_form(lists, pol), abs=1e-12)


class TestAnalyticalSimulatorAgreement:
    """ISSUE-1 acceptance: the fast path matches the event-driven
    simulator within 1e-6 on no-overlap policies (and, in fact, on
    every policy with an exact closed form)."""

    @pytest.mark.parametrize("policy", ["naive", "cntk"])
    def test_no_overlap_policies_within_1e6(self, policy):
        grid = ScenarioGrid(worker_counts=(1, 2, 16), policies=(policy,),
                            collectives=HW.COLLECTIVE_ALGORITHMS)
        for s in grid.expand():
            fast = evaluate_scenario(s, method="analytical")
            slow = evaluate_scenario(s, method="simulator")
            assert fast["iteration_time_s"] == pytest.approx(
                slow["iteration_time_s"], rel=1e-6), s.label()

    @pytest.mark.parametrize("policy", ["mxnet", "caffe-mpi"])
    def test_overlap_policies_also_exact(self, policy):
        grid = ScenarioGrid(workloads=("alexnet", "resnet50"),
                            worker_counts=(4, 16), policies=(policy,))
        for s in grid.expand():
            fast = evaluate_scenario(s, method="analytical")
            slow = evaluate_scenario(s, method="simulator")
            assert fast["iteration_time_s"] == pytest.approx(
                slow["iteration_time_s"], rel=1e-6), s.label()

    def test_fast_path_covers_exact_policies_only(self):
        for name, pol in ALL_POLICIES.items():
            expected = name in EXACT_POLICIES
            assert has_fast_path(pol) == expected, name

    def test_bucketed_routes_through_timeline_path(self):
        g = ScenarioGrid(workloads=("alexnet",), clusters=("v100-nvlink-ib",),
                         worker_counts=(4,),
                         policies=("caffe-mpi", "bucketed-25mb"))
        r = sweep(g)
        assert r.n_analytical == 1 and r.n_timeline == 1 \
            and r.n_simulated == 0
        methods = {row["policy"]: row["method"] for row in r.rows}
        assert methods == {"caffe-mpi": "analytical",
                           "bucketed-25mb": "timeline"}

    def test_force_simulator_still_pins_event_driven_path(self):
        g = ScenarioGrid(workloads=("alexnet",), clusters=("v100-nvlink-ib",),
                         worker_counts=(4,),
                         policies=("caffe-mpi", "bucketed-25mb"))
        r = sweep(g, force_simulator=True)
        assert r.n_analytical == 0 and r.n_timeline == 0 \
            and r.n_simulated == 2
        assert {row["method"] for row in r.rows} == {"simulated"}
        # and the oracle agrees with the batched rows
        fast = sweep(g)
        for a, b in zip(fast.rows, r.rows):
            assert a["iteration_time_s"] == pytest.approx(
                b["iteration_time_s"], rel=1e-6)


class TestCollectiveAlgorithms:
    def test_tree_beats_ring_for_small_messages_large_n(self):
        # 4 KB gradient over 64 workers: latency-dominated
        link = HW.Interconnect("x", 10 * HW.GB, 10 * HW.US)
        ring = HW.ring_allreduce_time(4096, 64, link.effective_bandwidth,
                                      link.latency)
        tree = HW.tree_allreduce_time(4096, 64, link.effective_bandwidth,
                                      link.latency)
        assert tree < ring

    def test_ring_beats_tree_for_large_messages(self):
        # 1 GB over 8 workers: bandwidth-dominated; ring moves
        # 2(n-1)/n < 2 payloads per rank
        link = HW.Interconnect("x", 10 * HW.GB, 10 * HW.US)
        ring = HW.ring_allreduce_time(1e9, 8, link.effective_bandwidth,
                                      link.latency)
        tree = HW.tree_allreduce_time(1e9, 8, link.effective_bandwidth,
                                      link.latency)
        assert ring < tree

    def test_hierarchical_equals_ring_on_single_node(self):
        c = HW.V100_CLUSTER
        n = c.gpus_per_node                    # fits one node
        for nbytes in (1e4, 1e6, 1e8):
            assert c.allreduce_time(nbytes, n, "hierarchical") == \
                pytest.approx(c.allreduce_time(nbytes, n, "ring"))

    def test_hierarchical_beats_flat_ring_across_nodes(self):
        # 16 GPUs over 4 nodes: the flat ring pays 2*15 inter-node
        # alphas; hierarchical pays 2*3 intra + 2*3 inter on 1/4 the
        # payload
        c = HW.V100_CLUSTER
        assert c.allreduce_time(25e6, 16, "hierarchical") < \
            c.allreduce_time(25e6, 16, "ring")

    def test_unknown_algorithm_rejected(self):
        with pytest.raises(ValueError, match="unknown collective"):
            HW.V100_CLUSTER.allreduce_time(1e6, 8, "butterfly")

    def test_single_worker_free(self):
        for alg in HW.COLLECTIVE_ALGORITHMS:
            assert HW.V100_CLUSTER.allreduce_time(1e9, 1, alg) == 0.0


class TestBandwidthMonotonicity:
    """More bandwidth never increases predicted iteration time."""

    @pytest.mark.parametrize("policy", EXACT_POLICIES)
    @pytest.mark.parametrize("collective", HW.COLLECTIVE_ALGORITHMS)
    def test_closed_forms_monotone_in_link_bandwidth(self, policy, collective):
        base = HW.V100_CLUSTER
        boosted = dataclasses.replace(
            base, intra=base.intra.scaled(2.0), inter=base.inter.scaled(2.0))
        builder, batch, bps = CNN_WORKLOADS["resnet50"]
        layers = builder()
        pol = get_policy(policy)
        for n in (2, 4, 16):
            t_base = A.closed_form(
                make_iteration_costs(layers, base, batch, n,
                                     bytes_per_sample=bps,
                                     collective=collective), pol)
            t_boost = A.closed_form(
                make_iteration_costs(layers, boosted, batch, n,
                                     bytes_per_sample=bps,
                                     collective=collective), pol)
            assert t_boost <= t_base + 1e-12

    def test_sweep_monotone_across_interconnect_presets(self):
        # ib-100g strictly dominates 10gbe (higher effective bandwidth,
        # lower latency), so no scenario may get slower under it
        kw = dict(workloads=("alexnet", "resnet50"),
                  clusters=("k80-pcie-10gbe",), worker_counts=(8, 16),
                  policies=EXACT_POLICIES,
                  collectives=HW.COLLECTIVE_ALGORITHMS)
        slow_net = sweep(ScenarioGrid(interconnects=("10gbe",), **kw))
        fast_net = sweep(ScenarioGrid(interconnects=("ib-100g",), **kw))
        assert len(slow_net) == len(fast_net)
        for a, b in zip(slow_net.rows, fast_net.rows):
            assert b["iteration_time_s"] <= a["iteration_time_s"] + 1e-12


class TestSweepEngine:
    def test_default_grid_fast_and_under_budget(self):
        r = sweep(default_grid())
        assert len(r) >= 500
        assert r.n_simulated == 0
        assert r.elapsed_s < 30.0          # acceptance gate (actual: ~0.1 s)

    def test_row_schema_and_sanity(self):
        from repro.core.sweep import COLUMNS

        r = sweep(ScenarioGrid(workloads=("googlenet",),
                               worker_counts=(1, 4), policies=("caffe-mpi",)))
        for row in r.rows:
            assert set(row) == set(COLUMNS)
            assert row["iteration_time_s"] > 0
            assert row["samples_per_sec"] > 0
            assert 0 < row["speedup"] <= row["n_workers"] + 1e-9

    def test_speedup_baseline_is_single_worker(self):
        r = sweep(ScenarioGrid(workloads=("alexnet",),
                               clusters=("k80-pcie-10gbe",),
                               worker_counts=(1,), policies=("caffe-mpi",)))
        [row] = r.rows
        assert row["speedup"] == pytest.approx(1.0)

    def test_to_csv_roundtrip(self, tmp_path):
        import csv

        r = sweep(ScenarioGrid(workloads=("alexnet",), worker_counts=(2,),
                               policies=("naive",)))
        path = tmp_path / "sweep.csv"
        r.to_csv(path)
        with open(path) as f:
            rows = list(csv.DictReader(f))
        assert len(rows) == len(r)
        assert float(rows[0]["iteration_time_s"]) == pytest.approx(
            r.rows[0]["iteration_time_s"])

    def test_filter_and_sort(self):
        r = sweep(ScenarioGrid(workloads=("alexnet",),
                               worker_counts=(2, 4), policies=("naive",)))
        sub = r.filter(n_workers=4)
        assert {x["n_workers"] for x in sub} == {4}
        top = r.sorted_by("samples_per_sec")
        assert top[0]["samples_per_sec"] >= top[-1]["samples_per_sec"]


class TestSweepCLI:
    def test_main_smoke(self, capsys, tmp_path):
        from repro.launch.sweep import main

        out_csv = tmp_path / "out.csv"
        rc = main(["--workloads", "alexnet", "--clusters", "v100-nvlink-ib",
                   "--workers", "2,4", "--policies", "naive,caffe-mpi",
                   "--collectives", "ring,tree", "--top", "3",
                   "--csv", str(out_csv)])
        assert rc == 0
        captured = capsys.readouterr().out
        assert "8 scenarios" in captured
        assert "8 analytical" in captured
        assert out_csv.exists()

    def test_main_default_grid_meets_acceptance(self, capsys):
        from repro.launch.sweep import main

        rc = main(["--top", "1"])
        assert rc == 0
        captured = capsys.readouterr().out
        assert "540 scenarios" in captured

    @pytest.mark.parametrize("argv", [
        ["--policies", "horovod"],
        ["--collectives", "butterfly"],
        ["--batch-per-gpu", "0"],
        ["--sort", "iter_ms"],
    ])
    def test_main_invalid_input_fails_cleanly(self, argv, capsys):
        from repro.launch.sweep import main

        rc = main(argv + ["--workers", "2", "--workloads", "alexnet"])
        assert rc == 2
        assert "error:" in capsys.readouterr().err
