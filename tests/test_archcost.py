"""Analytic architecture cost model sanity checks."""
import pytest

from repro.configs import SHAPES, get_config
from repro.core.archcost import param_counts, step_cost


def test_dense_model_flops_is_6nd():
    cfg = get_config("qwen1.5-4b")
    n, na = param_counts(cfg)
    assert n == na
    c = step_cost(cfg, SHAPES["train_4k"])
    D = 256 * 4096
    assert c.model_flops == pytest.approx(6 * na * D)
    assert c.flops > c.model_flops          # + attention terms


def test_moe_active_less_than_total():
    cfg = get_config("qwen2-moe-a2.7b")
    n, na = param_counts(cfg)
    assert na < 0.5 * n                     # top-4 of 60 + shared
    c = step_cost(cfg, SHAPES["train_4k"])
    assert c.model_flops == pytest.approx(6 * na * 256 * 4096)


def test_grok_scale():
    n, na = param_counts(get_config("grok-1-314b"))
    assert 250e9 < n < 340e9
    assert 70e9 < na < 100e9                # top-2 of 8 experts


def test_decode_flops_dominated_by_params():
    cfg = get_config("internlm2-20b")
    c = step_cost(cfg, SHAPES["decode_32k"])
    # one token/seq: 2*N*B plus attention over the 32k cache
    assert c.flops >= c.model_flops
    assert c.hbm_bytes > c.param_bytes      # params + kv cache traffic


def test_window_reduces_decode_cache():
    g = get_config("gemma3-1b")
    c = step_cost(g, SHAPES["long_500k"])
    # 22 local layers cache only 512 tokens; 4 global layers carry 524k
    full_equiv = 26 * 2 * 1 * 524_288 * 1 * 256 * 2
    assert c.hbm_bytes - c.param_bytes < full_equiv * 0.3


def test_ssm_long_decode_constant_state():
    cfg = get_config("rwkv6-1.6b")
    c500 = step_cost(cfg, SHAPES["long_500k"])
    # state is seq-length independent; hbm ~ params + small state
    assert c500.hbm_bytes < 1.2 * c500.param_bytes
