"""Data pipeline, optimizers, checkpointing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import restore_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.pipeline import PrefetchLoader, SyntheticLMDataset
from repro.models import transformer as T
from repro.optim.sgd import adamw, global_norm, sgd


class TestPipeline:
    def test_shapes_and_determinism(self):
        ds1 = iter(SyntheticLMDataset(100, 8, 4, seed=7))
        ds2 = iter(SyntheticLMDataset(100, 8, 4, seed=7))
        b1, b2 = next(ds1), next(ds2)
        assert b1["tokens"].shape == (4, 8)
        np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
        # labels are next-token shifted
        np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])

    def test_prefetch_overlaps_io(self):
        """With depth=2 the consumer should not pay the injected fetch
        latency every step (the paper's I/O-overlap optimization)."""
        import time
        delay = 0.05
        loader = PrefetchLoader(SyntheticLMDataset(50, 8, 2,
                                                   simulate_io_seconds=delay),
                                depth=2)
        next(loader)            # warm
        time.sleep(3 * delay)   # let the producer fill the queue
        t0 = time.perf_counter()
        for _ in range(2):
            next(loader)
        elapsed = time.perf_counter() - t0
        loader.close()
        assert elapsed < 2 * delay   # prefetched, not serial (2*delay each)

    def test_depth0_blocks(self):
        loader = PrefetchLoader(SyntheticLMDataset(50, 8, 2), depth=0)
        b = next(loader)
        assert b["tokens"].shape == (2, 8)
        assert loader.mean_t_io() >= 0.0


class TestOptim:
    def _quad(self):
        params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
        loss = lambda p: jnp.sum(p["w"] ** 2) + p["b"] ** 2
        return params, loss

    @pytest.mark.parametrize("maker", [lambda: sgd(0.1, momentum=0.9),
                                       lambda: sgd(0.1, momentum=0.0),
                                       lambda: adamw(0.05, weight_decay=0.0)])
    def test_converges_on_quadratic(self, maker):
        opt = maker()
        params, loss = self._quad()
        state = opt.init(params)
        for _ in range(120):
            grads = jax.grad(loss)(params)
            params, state = opt.update(grads, state, params)
        assert float(loss(params)) < 1e-2

    def test_sgd_momentum_state_dtype(self):
        opt = sgd(0.1, momentum=0.9)
        params = {"w": jnp.zeros((3,), jnp.bfloat16)}
        st = opt.init(params)
        assert st["mom"]["w"].dtype == jnp.float32
        newp, _ = opt.update({"w": jnp.ones((3,), jnp.bfloat16)}, st, params)
        assert newp["w"].dtype == jnp.bfloat16

    def test_global_norm(self):
        assert float(global_norm({"a": jnp.array([3.0]),
                                  "b": jnp.array([4.0])})) == pytest.approx(5.0)


class TestCheckpoint:
    def test_roundtrip_with_opt_state(self, tmp_path):
        cfg = get_config("gemma3-1b").reduced()
        params = T.init_lm(cfg, jax.random.PRNGKey(0))
        opt = adamw(1e-3)
        st = opt.init(params)
        p = tmp_path / "ck.npz"
        save_checkpoint(p, params, st, step=42, extra={"arch": cfg.name})
        p2, st2, meta = restore_checkpoint(p, params, st)
        assert meta["step"] == 42 and meta["arch"] == cfg.name
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)):
            assert bool(jnp.all(a == b))
        for a, b in zip(jax.tree_util.tree_leaves(st),
                        jax.tree_util.tree_leaves(st2)):
            assert bool(jnp.all(a == b))

    def test_shape_mismatch_raises(self, tmp_path):
        p = tmp_path / "ck.npz"
        save_checkpoint(p, {"w": jnp.zeros((2, 2))})
        with pytest.raises(ValueError, match="shape mismatch"):
            restore_checkpoint(p, {"w": jnp.zeros((3, 3))})
