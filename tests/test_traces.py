"""Trace dataset: paper format round-trip, bundled Table VI, DAG
predictions from traces, trace generation from instrumented models."""
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.hardware import K80_CLUSTER
from repro.core.policies import CAFFE_MPI, CNTK
from repro.core.predictor import predict
from repro.traces.bundled import ALEXNET_K80, TOTAL_GRAD_BYTES
from repro.traces.format import LayerRecord, Trace, make_trace, read_trace, \
    write_trace
from repro.traces.generate import TimedLayer, generate_trace


class TestBundledTableVI:
    def test_dimensions(self):
        assert ALEXNET_K80.network == "alexnet"
        assert ALEXNET_K80.num_layers == 22     # incl. data + loss layers

    def test_total_gradient_bytes_match_alexnet(self):
        # ~61M f32 parameters = ~244 MB, the paper's "~60 millions"
        assert TOTAL_GRAD_BYTES == pytest.approx(243_860_896)

    def test_fc6_row_verbatim(self):
        rec = ALEXNET_K80.iterations[0][14]
        assert rec.name == "fc6"
        assert rec.size_bytes == 151_011_328
        assert rec.comm_us == pytest.approx(311_170)

    def test_to_iteration_costs_maps_data_layer_to_io(self):
        costs = ALEXNET_K80.to_iteration_costs()
        assert costs.t_io == pytest.approx(1.2)          # 1.2e6 us
        assert costs.num_layers == 21
        assert sum(costs.t_c) == pytest.approx(2.649091456, rel=1e-6)

    def test_dag_prediction_from_trace(self):
        """WFBP (Caffe-MPI) must beat comm-at-end (CNTK) on the real
        AlexNet trace, and hide some of the 2.65 s of comm."""
        costs = ALEXNET_K80.to_iteration_costs()
        p_wfbp = predict(costs, 2, CAFFE_MPI, batch_per_gpu=1024,
                         cluster=K80_CLUSTER)
        p_cntk = predict(costs, 2, CNTK, batch_per_gpu=1024)
        assert p_wfbp.iteration_time < p_cntk.iteration_time
        # full comm is 2.65 s; overlap must hide most of it behind the
        # 3.36 s backward pass
        assert (p_cntk.iteration_time - p_wfbp.iteration_time) > 1.0


class TestFormat:
    def test_roundtrip(self, tmp_path):
        p = tmp_path / "t.trace"
        write_trace(ALEXNET_K80, p)
        t2 = read_trace(p)
        assert t2.network == "alexnet"
        assert t2.num_layers == 22
        for a, b in zip(ALEXNET_K80.iterations[0], t2.iterations[0]):
            assert a == b

    def test_multi_iteration_mean(self):
        rows1 = [(0, "l0", 10, 20, 5, 100)]
        rows2 = [(0, "l0", 30, 40, 15, 100)]
        t = make_trace("x", "c", rows1)
        t2 = type(t)(t.network, t.cluster,
                     (t.iterations[0], make_trace("x", "c", rows2).iterations[0]))
        mean = t2.mean_iteration()
        assert mean[0].forward_us == pytest.approx(20)
        assert mean[0].comm_us == pytest.approx(10)

    def test_read_empty_raises(self, tmp_path):
        p = tmp_path / "e.trace"
        p.write_text("# network: x\n")
        with pytest.raises(ValueError):
            read_trace(p)

    def test_batch_metadata_roundtrip(self, tmp_path):
        p = tmp_path / "b.trace"
        write_trace(ALEXNET_K80, p)
        assert read_trace(p).batch_per_gpu == 1024

    def test_ragged_iterations_rejected_at_construction(self):
        it1 = make_trace("x", "c", [(0, "a", 1, 1, 0, 0),
                                    (1, "b", 1, 1, 0, 0)]).iterations[0]
        it2 = make_trace("x", "c", [(0, "a", 1, 1, 0, 0)]).iterations[0]
        with pytest.raises(ValueError, match="ragged"):
            Trace("x", "c", (it1, it2))

    def test_empty_iterations_rejected(self):
        with pytest.raises(ValueError):
            Trace("x", "c", ())
        with pytest.raises(ValueError):
            Trace("x", "c", ((),))

    def test_read_ragged_file_names_the_file(self, tmp_path):
        p = tmp_path / "ragged.trace"
        p.write_text("0\ta\t1\t2\t0\t0\n"
                     "1\tb\t1\t2\t0\t0\n"
                     "# iteration 1\n"
                     "0\ta\t1\t2\t0\t0\n")
        with pytest.raises(ValueError, match="ragged.trace"):
            read_trace(p)


_times = st.floats(min_value=0.0, max_value=1e7)


@st.composite
def traces(draw):
    """Random multi-iteration traces with well-formed layer records."""
    n_layers = draw(st.integers(min_value=1, max_value=8))
    n_iters = draw(st.integers(min_value=1, max_value=4))
    batch = draw(st.integers(min_value=0, max_value=4096))
    its = []
    for _ in range(n_iters):
        its.append(tuple(
            LayerRecord(i, f"layer{i}", draw(_times), draw(_times),
                        draw(_times), float(draw(st.integers(
                            min_value=0, max_value=10**9))))
            for i in range(n_layers)))
    return Trace("net", "clu", tuple(its), batch_per_gpu=batch)


class TestRoundTripProperty:
    @settings(max_examples=30)
    @given(traces())
    def test_write_read_identity(self, trace):
        """write_trace -> read_trace is the identity (%.17g preserves
        every float64 exactly)."""
        import pathlib
        import tempfile

        with tempfile.TemporaryDirectory() as d:
            p = pathlib.Path(d) / "t.trace"
            write_trace(trace, p)
            back = read_trace(p)
        assert back == trace


class TestGenerator:
    def test_generate_matches_structure(self):
        key = jax.random.PRNGKey(0)
        W1 = jax.random.normal(key, (16, 32))
        layers = [TimedLayer("fc1", lambda p, x: jnp.tanh(x @ p), W1),
                  TimedLayer("act", lambda p, x: jax.nn.relu(x), {})]
        tr = generate_trace(layers, jnp.ones((4, 16)), "tiny",
                            n_iterations=2, repeats=2)
        mean = tr.mean_iteration()
        assert [r.name for r in mean] == ["fc1", "act"]
        assert mean[0].size_bytes == 16 * 32 * 4
        assert mean[1].size_bytes == 0          # non-learnable
        assert all(r.forward_us > 0 for r in mean)

    def test_comm_time_fn(self):
        key = jax.random.PRNGKey(0)
        layers = [TimedLayer("fc", lambda p, x: x @ p,
                             jax.random.normal(key, (8, 8)))]
        tr = generate_trace(layers, jnp.ones((2, 8)), "tiny",
                            n_iterations=1, repeats=1,
                            comm_time_fn=lambda b: b * 1e-6)
        rec = tr.mean_iteration()[0]
        assert rec.comm_us == pytest.approx(rec.size_bytes)
