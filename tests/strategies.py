"""Shared `hypothesis` strategies for the batched-engine test suite.

The random generators that used to live inline in ``test_batched.py``
(the WFBP-residual rng loop) and ``test_bucketsim.py`` (``_rand_costs``)
now live here as composite strategies so every property test draws from
one vocabulary: random per-layer cost vectors, random gradient-payload
rows, and random batched-eligible scenario grids (the NumPy ≡ JAX
differential surface of ``test_batched_jax.py``).

Works under both the real ``hypothesis`` package (CI installs it) and
the deterministic mini-shim ``conftest.py`` substitutes locally — stick
to the shim's API subset: ``integers`` / ``floats`` / ``booleans`` /
``lists`` / ``sampled_from`` / ``composite`` with positional bounds.
"""
from __future__ import annotations

import numpy as np
from hypothesis import strategies as st

from repro.core.dag import IterationCosts

#: Bucket-size knobs the DAG builder and the timeline kernel must agree
#: on: per-layer (None), degenerate-small, paper defaults, giant-fused.
BUCKET_BYTES_CHOICES = (None, 1.0, 1e6, 25e6, 1e9)

# Axis vocabularies for random scenario grids — every workload provider
# (cnn:/trace:/llm:) and every built-in batched-eligible policy family.
GRID_WORKLOADS = ("alexnet", "googlenet", "resnet50",
                  "trace:alexnet-k80", "llm:gemma3-1b")
GRID_CLUSTERS = ("k80-pcie-10gbe", "v100-nvlink-ib", "tpu-v5e-pod")
GRID_WORKERS = (1, 2, 4, 8, 16, 32)
GRID_POLICIES = ("naive", "cntk", "mxnet", "tensorflow", "caffe-mpi",
                 "bucketed-1mb", "bucketed-4mb", "bucketed-25mb",
                 "bucketed-100mb", "priority")
GRID_COLLECTIVES = ("ring", "tree", "hierarchical")
GRID_INTERCONNECTS = (None, "ib-100g", "10gbe@bw2@lat0.25",
                      "nvlink@bw0.5@lat4")


@st.composite
def grad_bytes_row(draw, n_layers: int):
    """Per-layer gradient payloads: ~half the layers carry a gradient
    (the rest are parameterless, payload 0), at least one layer does."""
    row = [draw(st.floats(1e5, 8e7)) if draw(st.booleans()) else 0.0
           for _ in range(n_layers)]
    if not any(row):
        row[0] = 1e6
    return row


@st.composite
def iteration_costs(draw, max_layers: int = 12, with_comm: bool = False):
    """Random :class:`~repro.core.dag.IterationCosts` — the generator
    behind the simulator-agreement and bucket-structure properties
    (formerly ``test_bucketsim._rand_costs``).  ``with_comm`` fills
    ``t_c`` on exactly the ``grad_bytes > 0`` layers, matching the
    ``iteration_costs`` contract the DAG builder relies on."""
    L = draw(st.integers(1, max_layers))
    gb = draw(grad_bytes_row(L))
    t_c = [draw(st.floats(0.01, 5.0)) if b > 0 else 0.0 for b in gb] \
        if with_comm else [0.0] * L
    return IterationCosts(
        t_f=[draw(st.floats(1e-3, 5.0)) for _ in range(L)],
        t_b=[draw(st.floats(1e-3, 5.0)) for _ in range(L)],
        t_c=t_c, t_io=draw(st.floats(0.0, 8.0)),
        t_h2d=draw(st.floats(0.0, 3.0)), t_u=draw(st.floats(0.0, 2.0)),
        grad_bytes=gb)


@st.composite
def wfbp_layer_times(draw, max_layers: int = 13):
    """``(t_b, t_c)`` per-layer rows for the WFBP residual property:
    ~60% of layers communicate, the rest have ``t_c = 0`` (formerly the
    inline rng loop of ``test_batched.TestVectorizedWfbpResidual``)."""
    L = draw(st.integers(1, max_layers))
    t_b = np.array([draw(st.floats(0.0, 5.0)) for _ in range(L)])
    t_c = np.array([draw(st.floats(0.0, 5.0))
                    if draw(st.integers(0, 9)) < 6 else 0.0
                    for _ in range(L)])
    return t_b, t_c


def _axis(draw, choices, max_size):
    """A sorted, de-duplicated random axis tuple (order-stable so grid
    cache keys — and therefore drawn examples — are deterministic)."""
    picked = draw(st.lists(st.sampled_from(choices),
                           min_size=1, max_size=max_size))
    return tuple(sorted(set(picked), key=lambda v: str(v)))


@st.composite
def scenario_grids(draw, max_per_axis: int = 2):
    """Random batched-eligible :class:`~repro.core.scenarios.ScenarioGrid`
    spanning every provider, policy family, collective and interconnect
    preset — the NumPy ≡ JAX differential property's input space."""
    from repro.core.scenarios import ScenarioGrid

    return ScenarioGrid(
        workloads=_axis(draw, GRID_WORKLOADS, max_per_axis),
        clusters=_axis(draw, GRID_CLUSTERS, max_per_axis),
        worker_counts=_axis(draw, GRID_WORKERS, max_per_axis),
        policies=_axis(draw, GRID_POLICIES, max_per_axis),
        collectives=_axis(draw, GRID_COLLECTIVES, max_per_axis),
        interconnects=_axis(draw, GRID_INTERCONNECTS, max_per_axis))
