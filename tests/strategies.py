"""Shared `hypothesis` strategies for the batched-engine test suite.

The random generators that used to live inline in ``test_batched.py``
(the WFBP-residual rng loop) and ``test_bucketsim.py`` (``_rand_costs``)
now live here as composite strategies so every property test draws from
one vocabulary: random per-layer cost vectors, random gradient-payload
rows, and random batched-eligible scenario grids (the NumPy ≡ JAX
differential surface of ``test_batched_jax.py``).

Works under both the real ``hypothesis`` package (CI installs it) and
the deterministic mini-shim ``conftest.py`` substitutes locally — stick
to the shim's API subset: ``integers`` / ``floats`` / ``booleans`` /
``lists`` / ``sampled_from`` / ``composite`` with positional bounds.
"""
from __future__ import annotations

import numpy as np
from hypothesis import strategies as st

from repro.core.dag import IterationCosts

#: Bucket-size knobs the DAG builder and the timeline kernel must agree
#: on: per-layer (None), degenerate-small, paper defaults, giant-fused.
BUCKET_BYTES_CHOICES = (None, 1.0, 1e6, 25e6, 1e9)

# Axis vocabularies for random scenario grids — every workload provider
# (cnn:/trace:/llm:) and every built-in batched-eligible policy family.
GRID_WORKLOADS = ("alexnet", "googlenet", "resnet50",
                  "trace:alexnet-k80", "llm:gemma3-1b")
GRID_CLUSTERS = ("k80-pcie-10gbe", "v100-nvlink-ib", "tpu-v5e-pod")
GRID_WORKERS = (1, 2, 4, 8, 16, 32)
GRID_POLICIES = ("naive", "cntk", "mxnet", "tensorflow", "caffe-mpi",
                 "bucketed-1mb", "bucketed-4mb", "bucketed-25mb",
                 "bucketed-100mb", "priority")
GRID_COLLECTIVES = ("ring", "tree", "hierarchical")
GRID_INTERCONNECTS = (None, "ib-100g", "10gbe@bw2@lat0.25",
                      "nvlink@bw0.5@lat4")
GRID_HET_PROFILES = (None, "het:1x0.5+3x1.0", "het:2x1.0@bw0.5",
                     "het:1x0.7@lat2.0+1x1.3", "het:1x1.0")
#: Straggler specs keep draw counts small — property tests run many
#: examples, and the MC cost is (unique points) x draws.
GRID_STRAGGLERS = (None, "lognormal:0.25x32", "exp:0.5x16",
                   "lognormal:0x8")
#: K-of-N partial-sync thresholds (0/None = full sync; K is clamped to
#: the worker count at evaluation, so over-large values are valid).
GRID_SYNC_KS = (None, 0, 1, 2, 3, 7)
#: Fault specs, small draw counts for the same reason as stragglers;
#: ``fail:0`` and ``@restart0`` are the deterministic degenerates.
GRID_FAULTS = (None, "fail:0.1@restart1.5x16", "fail:0.5@restart0.25x8",
               "fail:0x8", "fail:0.3@restart0x8")


@st.composite
def grad_bytes_row(draw, n_layers: int):
    """Per-layer gradient payloads: ~half the layers carry a gradient
    (the rest are parameterless, payload 0), at least one layer does."""
    row = [draw(st.floats(1e5, 8e7)) if draw(st.booleans()) else 0.0
           for _ in range(n_layers)]
    if not any(row):
        row[0] = 1e6
    return row


@st.composite
def iteration_costs(draw, max_layers: int = 12, with_comm: bool = False):
    """Random :class:`~repro.core.dag.IterationCosts` — the generator
    behind the simulator-agreement and bucket-structure properties
    (formerly ``test_bucketsim._rand_costs``).  ``with_comm`` fills
    ``t_c`` on exactly the ``grad_bytes > 0`` layers, matching the
    ``iteration_costs`` contract the DAG builder relies on."""
    L = draw(st.integers(1, max_layers))
    gb = draw(grad_bytes_row(L))
    t_c = [draw(st.floats(0.01, 5.0)) if b > 0 else 0.0 for b in gb] \
        if with_comm else [0.0] * L
    return IterationCosts(
        t_f=[draw(st.floats(1e-3, 5.0)) for _ in range(L)],
        t_b=[draw(st.floats(1e-3, 5.0)) for _ in range(L)],
        t_c=t_c, t_io=draw(st.floats(0.0, 8.0)),
        t_h2d=draw(st.floats(0.0, 3.0)), t_u=draw(st.floats(0.0, 2.0)),
        grad_bytes=gb)


@st.composite
def wfbp_layer_times(draw, max_layers: int = 13):
    """``(t_b, t_c)`` per-layer rows for the WFBP residual property:
    ~60% of layers communicate, the rest have ``t_c = 0`` (formerly the
    inline rng loop of ``test_batched.TestVectorizedWfbpResidual``)."""
    L = draw(st.integers(1, max_layers))
    t_b = np.array([draw(st.floats(0.0, 5.0)) for _ in range(L)])
    t_c = np.array([draw(st.floats(0.0, 5.0))
                    if draw(st.integers(0, 9)) < 6 else 0.0
                    for _ in range(L)])
    return t_b, t_c


def _axis(draw, choices, max_size):
    """A sorted, de-duplicated random axis tuple (order-stable so grid
    cache keys — and therefore drawn examples — are deterministic)."""
    picked = draw(st.lists(st.sampled_from(choices),
                           min_size=1, max_size=max_size))
    return tuple(sorted(set(picked), key=lambda v: str(v)))


@st.composite
def worker_rates(draw, max_workers: int = 8):
    """A per-worker relative-speed vector (each in ``(0, 2]``, at least
    one worker) — raw material for per-worker oracle properties."""
    n = draw(st.integers(1, max_workers))
    return np.array([draw(st.floats(0.1, 2.0)) for _ in range(n)])


@st.composite
def het_profiles(draw, max_slots: int = 3):
    """A random ``het:`` profile string: 1–3 slots with random counts,
    relative speeds, and optional per-slot bandwidth/latency skew."""
    slots = []
    for _ in range(draw(st.integers(1, max_slots))):
        s = f"{draw(st.integers(1, 4))}x{draw(st.floats(0.25, 2.0)):g}"
        if draw(st.booleans()):
            s += f"@bw{draw(st.floats(0.25, 2.0)):g}"
        if draw(st.booleans()):
            s += f"@lat{draw(st.floats(0.5, 4.0)):g}"
        slots.append(s)
    return "het:" + "+".join(slots)


@st.composite
def straggler_specs(draw, max_draws: int = 32):
    """A random parsed-valid straggler spec string; scale 0 (the
    deterministic degenerate) is drawn deliberately often."""
    dist = draw(st.sampled_from(("lognormal", "exp")))
    scale = draw(st.sampled_from((0.0, 0.1, 0.25, 0.5)))
    return f"{dist}:{scale:g}x{draw(st.integers(4, max_draws))}"


@st.composite
def sync_ks(draw, max_k: int = 8):
    """A random K-of-N threshold: ``None``/``0`` (full sync) or a
    positive K — deliberately allowed to exceed the worker count, since
    the engine clamps (``K >= n`` must be bit-identical to full
    sync)."""
    if draw(st.booleans()):
        return draw(st.sampled_from((None, 0)))
    return draw(st.integers(1, max_k))


@st.composite
def fault_specs(draw, max_draws: int = 32):
    """A random parsed-valid ``fail:`` spec string; ``p = 0`` and
    ``restart = 0`` (the deterministic degenerates) are drawn
    deliberately often."""
    p = draw(st.sampled_from((0.0, 0.05, 0.2, 0.5)))
    restart = draw(st.sampled_from((0.0, 0.5, 2.5)))
    return (f"fail:{p:g}@restart{restart:g}"
            f"x{draw(st.integers(4, max_draws))}")


@st.composite
def scenario_grids(draw, max_per_axis: int = 2, with_het: bool = False,
                   with_failures: bool = False):
    """Random batched-eligible :class:`~repro.core.scenarios.ScenarioGrid`
    spanning every provider, policy family, collective and interconnect
    preset — the NumPy ≡ JAX differential property's input space.
    ``with_het=True`` adds the heterogeneity axes (het profiles and
    small-draw straggler specs); ``with_failures=True`` the failure
    axes (K-of-N sync thresholds and fault specs)."""
    from repro.core.scenarios import ScenarioGrid

    extra_axes = {}
    if with_het:
        extra_axes = {
            "het_profiles": _axis(draw, GRID_HET_PROFILES, max_per_axis),
            "stragglers": _axis(draw, GRID_STRAGGLERS, max_per_axis)}
    if with_failures:
        extra_axes["sync_ks"] = _axis(draw, GRID_SYNC_KS, max_per_axis)
        extra_axes["faults"] = _axis(draw, GRID_FAULTS, max_per_axis)
    return ScenarioGrid(
        workloads=_axis(draw, GRID_WORKLOADS, max_per_axis),
        clusters=_axis(draw, GRID_CLUSTERS, max_per_axis),
        worker_counts=_axis(draw, GRID_WORKERS, max_per_axis),
        policies=_axis(draw, GRID_POLICIES, max_per_axis),
        collectives=_axis(draw, GRID_COLLECTIVES, max_per_axis),
        interconnects=_axis(draw, GRID_INTERCONNECTS, max_per_axis),
        **extra_axes)
