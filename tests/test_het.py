"""Heterogeneity & straggler engine (ISSUE 8): the ``het:`` grammar
and straggler specs, the padded worker tables and slowest-worker
reduction, the batched (S,W,L) kernels against the *per-worker*
event-driven oracle, bit-exact scalar degeneration on both backends,
Monte Carlo tail statistics (seeded reproducibility, monotonicity,
zero-jitter degeneration, NumPy = JAX draw-for-draw), and the widened
result-table surface."""
import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings
from strategies import het_profiles, scenario_grids, worker_rates

from repro.core import het
from repro.core.analytical import worker_bottleneck
from repro.core.batched import eval_scenarios, grid_evaluator
from repro.core.batched_jax import eval_scenarios_jax, jax_grid_evaluator
from repro.core.scenarios import Scenario, ScenarioGrid
from repro.core.sweep import COLUMNS, _sim_eval, sweep

HET_PROFILES = ("het:1x0.5+3x1.0", "het:2x1.0@bw0.5",
                "het:1x0.7@lat2.0+1x1.3")


def _scn(**kw):
    base = dict(workload="alexnet", cluster="v100-nvlink-ib",
                n_workers=4, policy="tensorflow", collective="ring")
    base.update(kw)
    return Scenario(**base)


class TestGrammar:
    def test_parse_slots_and_modifiers(self):
        p = het.parse_het_profile("het:1x0.5@bw0.25@lat2+3x1.0")
        assert p.n_slots == 4
        assert p.slots[0] == het.HetSlot(1, 0.5, bw_mult=0.25, lat_mult=2.0)
        assert p.slots[1] == het.HetSlot(3, 1.0)

    def test_none_spellings(self):
        assert het.parse_het_profile(None) is None
        assert het.parse_het_profile("none") is None
        assert het.normalize_het(None) == "none"
        assert het.normalize_het("het:1x0.5") == "het:1x0.5"

    @pytest.mark.parametrize("bad", [
        "het:", "het:3", "het:0x1.0", "het:2x0", "het:2x-1",
        "het:2x1.0@", "het:2x1.0@bw", "het:2x1.0@speed2",
        "het:2x1.0@bw0", "nonsense", "1x0.5"])
    def test_malformed_profiles_raise(self, bad):
        with pytest.raises(ValueError):
            het.parse_het_profile(bad)

    def test_parse_straggler(self):
        s = het.parse_straggler("lognormal:0.2x50")
        assert (s.dist, s.scale, s.draws) == ("lognormal", 0.2, 50)
        assert het.parse_straggler("exp:0.5").draws == het.DEFAULT_DRAWS
        assert het.parse_straggler(None) is None
        assert het.parse_straggler("none") is None
        assert het.parse_straggler("lognormal:0x4").is_deterministic

    @pytest.mark.parametrize("bad", [
        "gauss:0.2", "lognormal", "lognormal:-0.1", "lognormal:0.2x0",
        "lognormal:0.2xmany", f"exp:0.1x{het.MAX_DRAWS + 1}"])
    def test_malformed_stragglers_raise(self, bad):
        with pytest.raises(ValueError):
            het.parse_straggler(bad)

    def test_scenario_axis_validation(self):
        with pytest.raises(ValueError):
            _scn(het="het:0x1").validate()
        with pytest.raises(ValueError):
            _scn(straggler="weibull:0.2").validate()
        g = ScenarioGrid(workloads=("alexnet",),
                         clusters=("v100-nvlink-ib",), worker_counts=(2,),
                         policies=("tensorflow",), collectives=("ring",),
                         het_profiles=("het:bogus",))
        with pytest.raises(ValueError):
            g.validate_axes()


class TestWorkerTables:
    def test_proportional_slot_rule(self):
        p = het.parse_het_profile("het:1x0.5+3x1.0")
        inv, bw, lat = het.worker_vectors(p, 8)
        # the slow quarter stays the slow quarter at any cluster size
        np.testing.assert_array_equal(inv, [2, 2, 1, 1, 1, 1, 1, 1])
        np.testing.assert_array_equal(bw, np.ones(8))
        inv4, _, _ = het.worker_vectors(p, 4)
        np.testing.assert_array_equal(inv4, [2, 1, 1, 1])

    def test_homogeneous_is_all_ones(self):
        inv, bw, lat = het.worker_vectors(None, 3)
        for v in (inv, bw, lat):
            np.testing.assert_array_equal(v, np.ones(3))

    def test_padding_is_neutral_for_bottleneck(self):
        p = het.parse_het_profile("het:1x0.5@bw0.5@lat2.0+1x1.0")
        tab = het.worker_table_rows([(p, 2), (None, 6)])
        assert tab["inv_speed"].shape == (2, 6)
        tm, bm, lm = worker_bottleneck(tab["inv_speed"], tab["bw_mult"],
                                       tab["lat_mult"])
        # row 0: live prefix [2.0, 1.0] / [0.5, 1.0] / [2.0, 1.0]
        np.testing.assert_array_equal(tm, [2.0, 1.0])
        np.testing.assert_array_equal(bm, [0.5, 1.0])
        np.testing.assert_array_equal(lm, [2.0, 1.0])

    @settings(max_examples=20, deadline=None)
    @given(worker_rates())
    def test_bottleneck_reduces_constant_vector_bit_exactly(self, rates):
        inv = 1.0 / rates
        const = np.full_like(inv, inv[0])
        tm, bm, lm = worker_bottleneck(const, const, const)
        assert tm == inv[0] and bm == inv[0] and lm == inv[0]
        tm2, _, _ = worker_bottleneck(inv, np.ones_like(inv),
                                      np.ones_like(inv))
        assert tm2 == inv.max()


class TestPerWorkerOracle:
    """ISSUE-8 acceptance: the batched slowest-worker kernels agree
    <= 1e-6 with the event-driven simulator fed the *unreduced*
    per-worker rate vector — the theorem is validated, not assumed."""

    @pytest.mark.parametrize("profile", HET_PROFILES)
    @pytest.mark.parametrize("policy,collective", [
        ("tensorflow", "ring"), ("caffe-mpi", "tree"),
        ("bucketed-4mb", "ring"), ("priority", "hierarchical")])
    def test_het_matches_per_worker_simulator(self, profile, policy,
                                              collective):
        for n in (2, 8):
            s = _scn(n_workers=n, policy=policy, collective=collective,
                     het=profile)
            fast = eval_scenarios([s])[0]
            sim = _sim_eval(s)
            assert fast["iteration_time_s"] == pytest.approx(
                sim["iteration_time_s"], rel=1e-6)

    @settings(max_examples=8, deadline=None)
    @given(het_profiles())
    def test_random_profiles_match_oracle(self, profile):
        s = _scn(n_workers=6, policy="mxnet", collective="ring",
                 het=profile)
        fast = eval_scenarios([s])[0]
        sim = _sim_eval(s)
        assert fast["iteration_time_s"] == pytest.approx(
            sim["iteration_time_s"], rel=1e-6)

    def test_het_never_faster_than_homogeneous(self):
        rows_het = eval_scenarios(
            [_scn(het="het:1x0.5+3x1.0", n_workers=n) for n in (2, 4, 8)])
        rows_hom = eval_scenarios(
            [_scn(n_workers=n) for n in (2, 4, 8)])
        for rh, r0 in zip(rows_het, rows_hom):
            assert rh["iteration_time_s"] >= r0["iteration_time_s"]


class TestScalarDegeneration:
    """Constant-vector profiles must reproduce the scalar path
    *bit-exactly* — max/min of a constant vector never rounds, and
    multiplying by 1.0 is the identity."""

    def _grids(self):
        base = ScenarioGrid(
            workloads=("alexnet", "resnet50"),
            clusters=("v100-nvlink-ib", "k80-pcie-10gbe"),
            worker_counts=(2, 8), policies=("tensorflow", "bucketed-4mb"),
            collectives=("ring", "hierarchical"))
        return base, dataclasses.replace(base,
                                         het_profiles=("het:1x1.0",))

    @pytest.mark.parametrize("backend", ("numpy", "jax"))
    def test_all_ones_profile_bit_identical(self, backend):
        base, hetg = self._grids()
        r0 = sweep(base, backend=backend)
        r1 = sweep(hetg, backend=backend)
        for k in ("iteration_time_s", "samples_per_sec", "speedup",
                  "t_comm_s", "t_comp_s", "t_mean_s", "t_p95_s",
                  "t_p99_s"):
            np.testing.assert_array_equal(r0.columns[k], r1.columns[k],
                                          err_msg=k)
        assert list(r1.columns["het"]) == ["het:1x1.0"] * len(r1)

    @settings(max_examples=6, deadline=None)
    @given(scenario_grids())
    def test_property_constant_vector_both_backends(self, grid):
        hetg = dataclasses.replace(grid, het_profiles=("het:2x1.0",))
        r0 = sweep(grid, seed=3)
        r1 = sweep(hetg, seed=3)
        np.testing.assert_array_equal(r0.columns["iteration_time_s"],
                                      r1.columns["iteration_time_s"])
        if r0.n_simulated == 0:       # jax rejects simulator-only rows
            j0 = sweep(grid, backend="jax", seed=3)
            j1 = sweep(hetg, backend="jax", seed=3)
            np.testing.assert_array_equal(
                j0.columns["iteration_time_s"],
                j1.columns["iteration_time_s"])


class TestStragglerMonteCarlo:
    def test_fixed_seed_reproducible_and_seed_sensitive(self):
        g = ScenarioGrid(workloads=("alexnet",),
                         clusters=("v100-nvlink-ib",), worker_counts=(4, 8),
                         policies=("tensorflow", "bucketed-4mb"),
                         collectives=("ring",),
                         stragglers=("lognormal:0.3x64",))
        a = sweep(g, seed=7)
        b = sweep(g, seed=7)
        c = sweep(g, seed=8)
        for k in ("t_mean_s", "t_p95_s", "t_p99_s"):
            np.testing.assert_array_equal(a.columns[k], b.columns[k])
        assert not np.array_equal(a.columns["t_p99_s"],
                                  c.columns["t_p99_s"])
        # deterministic columns are untouched by the seed
        np.testing.assert_array_equal(a.columns["iteration_time_s"],
                                      c.columns["iteration_time_s"])

    def test_draws_keyed_by_spec_not_chunk(self):
        spec = het.parse_straggler("lognormal:0.4x32")
        np.testing.assert_array_equal(spec.draw_matrix(4, seed=5),
                                      spec.draw_matrix(4, seed=5))
        assert not np.array_equal(spec.draw_matrix(4, seed=5),
                                  spec.draw_matrix(4, seed=6))

    def test_tails_monotone_in_jitter_scale(self):
        rows = [eval_scenarios(
            [_scn(straggler=f"lognormal:{sc}x128")], seed=11)[0]
            for sc in (0.05, 0.2, 0.6)]
        p95 = [r["t_p95_s"] for r in rows]
        p99 = [r["t_p99_s"] for r in rows]
        assert p95[0] < p95[1] < p95[2]
        assert p99[0] < p99[1] < p99[2]
        for r in rows:
            assert r["t_p99_s"] >= r["t_p95_s"]

    def test_exp_jitter_only_slows(self):
        r = eval_scenarios([_scn(straggler="exp:0.3x64")], seed=2)[0]
        assert r["t_mean_s"] > r["iteration_time_s"]

    @pytest.mark.parametrize("spec", ("lognormal:0x16", "exp:0x16"))
    def test_zero_jitter_is_bit_exact_deterministic(self, spec):
        det = eval_scenarios([_scn()])[0]
        mc = eval_scenarios([_scn(straggler=spec)], seed=9)[0]
        for k in ("iteration_time_s", "t_mean_s", "t_p95_s", "t_p99_s"):
            assert mc[k] == det["iteration_time_s"], k

    def test_numpy_jax_draw_for_draw(self):
        g = ScenarioGrid(workloads=("alexnet",),
                         clusters=("v100-nvlink-ib",), worker_counts=(2, 8),
                         policies=("tensorflow", "bucketed-4mb"),
                         collectives=("ring", "tree"),
                         het_profiles=(None, "het:1x0.5+1x1.0"),
                         stragglers=("lognormal:0.25x48", "exp:0.4x16"))
        rn = sweep(g, backend="numpy", seed=13)
        rj = sweep(g, backend="jax", seed=13)
        for k in ("t_mean_s", "t_p95_s", "t_p99_s"):
            np.testing.assert_allclose(rj.columns[k], rn.columns[k],
                                       rtol=1e-6, atol=1e-12, err_msg=k)

    def test_stochastic_simulator_path_matches_batched(self):
        # per-draw re-simulation with the unreduced jitter vector must
        # agree with the batched per-draw closed form (same draws)
        s = _scn(n_workers=4, policy="priority", collective="ring",
                 het="het:1x0.5+3x1.0", straggler="lognormal:0.3x16")
        fast = eval_scenarios([s], seed=4)[0]
        sim = _sim_eval(s, seed=4)
        for k in ("t_mean_s", "t_p95_s", "t_p99_s"):
            assert fast[k] == pytest.approx(sim[k], rel=1e-6), k

    def test_sharded_sweep_bit_identical(self):
        from repro.core.parallel import parallel_tables
        from repro.core.resulttable import concat_tables
        g = ScenarioGrid(workloads=("alexnet",),
                         clusters=("v100-nvlink-ib",), worker_counts=(2, 4),
                         policies=("tensorflow", "bucketed-4mb"),
                         collectives=("ring",),
                         het_profiles=(None, "het:1x0.5+1x1.0"),
                         stragglers=("lognormal:0.2x32",))
        serial = sweep(g, seed=21)
        sharded = concat_tables(list(parallel_tables(
            g, jobs=2, chunk=2, pool="thread", seed=21)))
        for k in ("iteration_time_s", "t_mean_s", "t_p95_s", "t_p99_s"):
            np.testing.assert_array_equal(serial.columns[k], sharded[k],
                                          err_msg=k)


class TestResultSurface:
    def _result(self):
        g = ScenarioGrid(workloads=("alexnet",),
                         clusters=("v100-nvlink-ib",), worker_counts=(2,),
                         policies=("tensorflow",), collectives=("ring",),
                         het_profiles=(None, "het:1x0.5+1x1.0"),
                         stragglers=(None, "lognormal:0.2x16"))
        return sweep(g, seed=1)

    def test_columns_schema(self):
        r = self._result()
        for k in ("het", "straggler", "t_mean_s", "t_p95_s", "t_p99_s"):
            assert k in COLUMNS and k in r.columns
        assert set(r.rows[0]) == set(COLUMNS)

    def test_filter_and_sort_new_columns(self):
        r = self._result()
        het_rows = r.filter(het="het:1x0.5+1x1.0")
        assert len(het_rows) == 2
        # None normalizes to the "none" label on both axes
        assert len(r.filter(het=None, straggler=None)) == 1
        ordered = r.sorted_by("t_p99_s")
        p99 = [row["t_p99_s"] for row in ordered]
        assert p99 == sorted(p99, reverse=True)

    def test_unknown_column_errors_name_valid_ones(self):
        r = self._result()
        with pytest.raises(KeyError, match="t_p95_s"):
            r.sorted_by("t_p95")
        with pytest.raises(KeyError, match="unknown column"):
            r.filter(bogus=1)

    def test_json_and_eval_scenarios_jax_carry_tails(self, tmp_path):
        r = self._result()
        path = tmp_path / "r.json"
        r.to_json(str(path))
        rows = json.loads(path.read_text())["rows"]
        assert rows[0].keys() >= {"het", "straggler", "t_mean_s",
                                  "t_p95_s", "t_p99_s"}
        jrows = eval_scenarios_jax(
            [_scn(het="het:1x0.5+1x1.0", straggler="lognormal:0.2x16")],
            seed=1)
        assert jrows[0]["t_p99_s"] > 0

    def test_cli_seed_flag(self, tmp_path, capsys):
        from repro.launch.sweep import main
        args = ["--workloads", "alexnet", "--clusters", "v100-nvlink-ib",
                "--workers", "4", "--policies", "tensorflow",
                "--collectives", "ring",
                "--stragglers", "lognormal:0.3x32", "--top", "0"]
        out = {}
        for name, seed in (("a", "7"), ("b", "7"), ("c", "8")):
            path = tmp_path / f"{name}.json"
            assert main(args + ["--seed", seed, "--json", str(path)]) == 0
            out[name] = json.loads(path.read_text())["rows"]
        capsys.readouterr()
        assert out["a"] == out["b"]
        assert out["a"][0]["t_p99_s"] != out["c"][0]["t_p99_s"]
