"""Sweep-as-a-service (the persistent what-if server).

Pins the service contracts:

* **Bit-identity** — a query served through the coalescer (alone or
  fused with concurrent heterogeneous queries) returns *exactly* the
  column arrays a direct :func:`repro.core.sweep.sweep` of its grid
  produces (``np.array_equal`` per column), on both backends, and the
  identity survives the HTTP NDJSON round trip (floats serialize via
  ``repr`` shortest round-trip).
* **Coalescing** — same-signature queries submitted within one batch
  window share **one** kernel call (asserted via the service's kernel
  counter); different seeds (and different padded layer depths) split
  into separate calls.
* **Cache accounting** — the first query against a fresh workload is
  a recorded miss, the repeat a hit, without the probe perturbing the
  caches it measures.
* **Robustness** — malformed queries produce structured
  :class:`repro.core.service.QueryError` / HTTP 400 documents (the
  same rejections the CLI exits 2 on, never a traceback, no
  ``scenarios_per_sec`` division by zero), and a client disconnecting
  mid-stream leaves the server serving.
* **Trailer parity** — the streamed trailer carries exactly the
  :data:`repro.core.sweep.RESULT_META_KEYS` metadata (plus ``qos``),
  key-for-key with :meth:`SweepResult.to_json`.
"""
from __future__ import annotations

import json
import socket
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.core.resulttable import COLUMNS, table_from_rows, table_len
from repro.core.scenarios import grid_from_spec
from repro.core.service import QueryError, SweepService, parse_query
from repro.core.sweep import RESULT_META_KEYS, sweep


def assert_tables_equal(got: dict, want: dict) -> None:
    """Bit-exact column equality (object label columns compare by
    value; float columns must match bit for bit)."""
    assert table_len(got) == table_len(want) > 0
    for k in COLUMNS:
        assert np.array_equal(got[k], want[k]), k


def reference(spec: dict, backend: str = "numpy"):
    grid = grid_from_spec({k: v for k, v in spec.items()
                           if k not in ("backend", "seed")})
    return sweep(grid, backend=backend, seed=spec.get("seed", 0))


# Heterogeneous same-workload queries: same padded layer depth, so all
# four share one kernel signature (seed 7).
COALESCE_SPECS = [
    {"workloads": ["resnet50"], "workers": [4, 8], "seed": 7},
    {"grid": "mixed", "workloads": ["resnet50"], "workers": [8],
     "seed": 7},
    {"workloads": ["resnet50"], "workers": [4],
     "het": ["het:1x0.5+3x1.0"], "seed": 7},
    {"workloads": ["resnet50"], "workers": [16],
     "sync_k": ["none", "3"], "seed": 7},
]


# ----------------------------------------------------------------------
# parse_query: the structured rejection surface
# ----------------------------------------------------------------------
class TestParseQuery:
    @pytest.mark.parametrize("doc,fragment", [
        ({"grid": "nope"}, "grid"),
        ({"bogus": 1}, "unknown query keys"),
        ({"backend": "tpu"}, "backend"),
        ({"seed": "x"}, "seed"),
        ({"seed": True}, "seed"),
        ({"workloads": []}, "workloads"),
        ({"workloads": ["no-such-net"]}, "workload"),
        ({"sync_k": ["-3"]}, "sync_k"),
        ({"policies": ["no-such-policy"]}, "policy"),
    ])
    def test_rejections_are_structured(self, doc, fragment):
        with pytest.raises(QueryError) as ei:
            parse_query(doc)
        assert fragment in str(ei.value)
        assert ei.value.code in ("bad-query", "empty-grid")

    def test_non_dict_rejected(self):
        with pytest.raises(QueryError):
            parse_query(["not", "a", "dict"])

    def test_defaults(self):
        q = parse_query({"workloads": ["resnet50"], "workers": [4]})
        assert (q.backend, q.seed, q.coalescable) == ("numpy", 0, True)
        assert len(q.grid) > 0

    def test_signature_carries_padded_depth(self):
        qa = parse_query({"workloads": ["resnet50"], "workers": [4]})
        qb = parse_query({"workloads": ["alexnet"], "workers": [4]})
        assert qa.signature != qb.signature
        assert qa.signature[:2] == qb.signature[:2]


# ----------------------------------------------------------------------
# SweepService: coalescing + bit-identity + QoS
# ----------------------------------------------------------------------
class TestServiceCoalescing:
    def test_singleton_bit_identity(self):
        with SweepService(window_s=0.0) as svc:
            spec = {"workloads": ["resnet50"], "workers": [4, 8],
                    "seed": 7}
            res = svc.query(dict(spec), timeout=120)
            assert_tables_equal(res.table, reference(spec).columns)

    def test_coalesced_group_bit_identity_one_kernel_call(self):
        # a long window so all four queries land in one batch
        with SweepService(window_s=0.5, max_coalesce=8) as svc:
            tickets = [svc.submit(dict(s)) for s in COALESCE_SPECS]
            results = [t.wait(timeout=120) for t in tickets]
            snap = svc.stats_snapshot()
        for spec, res in zip(COALESCE_SPECS, results):
            assert_tables_equal(res.table, reference(spec).columns)
            assert res.meta["qos"]["coalesced_queries"] == 4
        assert snap["kernel_calls"] == 1
        assert snap["coalesce_factor"] == 4.0
        assert snap["n_queries"] == 4

    def test_different_seeds_split_kernel_calls(self):
        with SweepService(window_s=0.5) as svc:
            a = svc.submit({"workloads": ["resnet50"], "workers": [4],
                            "seed": 1})
            b = svc.submit({"workloads": ["resnet50"], "workers": [4],
                            "seed": 2})
            a.wait(timeout=120), b.wait(timeout=120)
            assert svc.stats_snapshot()["kernel_calls"] == 2

    def test_mixed_depth_split_stays_bit_identical(self):
        # different padded layer depths must not share a kernel call
        # (the layer-sum reduction tree depends on the padding), and
        # each split group must still match its direct sweep exactly
        specs = [
            {"workloads": ["googlenet"], "workers": [8], "seed": 7},
            {"workloads": ["alexnet"], "workers": [2, 4], "seed": 7},
            {"workloads": ["googlenet"], "workers": [2],
             "stragglers": ["lognormal:0.2"], "seed": 7},
        ]
        with SweepService(window_s=0.5, max_coalesce=8) as svc:
            tickets = [svc.submit(dict(s)) for s in specs]
            results = [t.wait(timeout=120) for t in tickets]
            snap = svc.stats_snapshot()
        for spec, res in zip(specs, results):
            assert_tables_equal(res.table, reference(spec).columns)
        assert snap["kernel_calls"] == 2     # googlenet pair + alexnet

    def test_jax_coalesced_bit_identity(self):
        specs = COALESCE_SPECS[:2]
        with SweepService(window_s=0.5) as svc:
            tickets = [svc.submit({**s, "backend": "jax"})
                       for s in specs]
            results = [t.wait(timeout=300) for t in tickets]
            snap = svc.stats_snapshot()
        for spec, res in zip(specs, results):
            assert_tables_equal(res.table,
                                reference(spec, backend="jax").columns)
        assert snap["kernel_calls"] == 1

    def test_concurrent_submitters_all_bit_identical(self):
        refs = [reference(s).columns for s in COALESCE_SPECS]
        with SweepService(window_s=0.05) as svc:
            out = [None] * len(COALESCE_SPECS)

            def run(i, spec):
                out[i] = svc.query(dict(spec), timeout=120)

            threads = [threading.Thread(target=run, args=(i, s))
                       for i, s in enumerate(COALESCE_SPECS)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        for res, ref in zip(out, refs):
            assert_tables_equal(res.table, ref)


class TestServiceAccounting:
    def test_cache_miss_then_hit(self, monkeypatch):
        # a private table memo so process history can't pre-warm it
        monkeypatch.setattr("repro.core.workloads._TABLES", {})
        monkeypatch.setattr("repro.core.batched._EVALUATOR_MEMO", {})
        spec = {"workloads": ["alexnet"], "workers": [2]}
        with SweepService(window_s=0.0) as svc:
            first = svc.query(dict(spec), timeout=120)
            second = svc.query(dict(spec), timeout=120)
            snap = svc.stats_snapshot()
        assert first.meta["qos"]["cache"]["workload_tables"] == "miss"
        assert second.meta["qos"]["cache"]["workload_tables"] == "hit"
        assert first.meta["qos"]["cache"]["grid_structure"] == "miss"
        assert second.meta["qos"]["cache"]["grid_structure"] == "hit"
        for name in ("workload_tables", "grid_structure"):
            assert snap["cache"][name] == {"hits": 1, "misses": 1,
                                           "hit_rate": 0.5}

    def test_trailer_meta_matches_to_json_keys(self):
        spec = {"workloads": ["resnet50"], "workers": [4], "seed": 7}
        with SweepService(window_s=0.0) as svc:
            res = svc.query(dict(spec), timeout=120)
        assert set(res.meta) == set(RESULT_META_KEYS) | {"qos"}
        doc = json.loads(reference(spec).to_json())
        assert set(doc) - {"columns", "rows"} == set(RESULT_META_KEYS)
        for k in ("n_scenarios", "n_analytical", "n_timeline",
                  "n_simulated", "backend"):
            assert res.meta[k] == doc[k], k

    def test_stats_snapshot_shape(self):
        with SweepService(window_s=0.0) as svc:
            svc.query({"workloads": ["resnet50"], "workers": [4]},
                      timeout=120)
            snap = svc.stats_snapshot()
        assert snap["n_queries"] == 1 and snap["n_errors"] == 0
        assert snap["kernel_calls"] == 1
        assert snap["sustained_scenarios_per_sec"] > 0
        assert snap["latency"]["p95_ms"] >= snap["latency"]["p50_ms"]
        assert snap["queue_depth"] == 0

    def test_zero_scenarios_never_divides(self):
        # the empty grid is rejected before evaluation — no div-by-zero
        # path exists for scenarios_per_sec
        with SweepService(window_s=0.0) as svc:
            with pytest.raises(QueryError) as ei:
                svc.submit({"workloads": []})
            assert ei.value.code == "bad-query"

    def test_close_resolves_pending(self):
        svc = SweepService(window_s=0.0)
        svc.close()
        with pytest.raises(RuntimeError):
            svc.submit({"workloads": ["resnet50"], "workers": [4]})


# ----------------------------------------------------------------------
# HTTP launcher
# ----------------------------------------------------------------------
@pytest.fixture(scope="class")
def server():
    from repro.launch.serve_sweep import make_server

    srv = make_server(port=0, window_s=0.02)
    thread = threading.Thread(target=srv.serve_forever, daemon=True)
    thread.start()
    yield srv
    srv.shutdown()
    srv.server_close()
    srv.service.close()


def http_query(srv, doc: dict) -> list[dict]:
    port = srv.server_address[1]
    req = urllib.request.Request(f"http://127.0.0.1:{port}/query",
                                 data=json.dumps(doc).encode(),
                                 method="POST")
    with urllib.request.urlopen(req) as resp:
        return [json.loads(line) for line in resp]


class TestHTTPServer:
    def test_round_trip_bit_identity(self, server):
        from repro.launch.serve_sweep import table_from_wire

        spec = {"workloads": ["resnet50"], "workers": [4, 8], "seed": 7}
        lines = http_query(server, spec)
        assert lines[0]["type"] == "header"
        assert lines[0]["columns"] == list(COLUMNS)
        assert lines[0]["format"] == "columns"
        assert lines[-1]["type"] == "trailer"
        assert_tables_equal(table_from_wire(lines),
                            reference(spec).columns)

    def test_rows_format_round_trip(self, server):
        from repro.launch.serve_sweep import table_from_wire

        spec = {"workloads": ["resnet50"], "workers": [4], "seed": 7,
                "format": "rows"}
        lines = http_query(server, spec)
        assert lines[0]["format"] == "rows"
        rows = [r for ln in lines if ln["type"] == "rows"
                for r in ln["rows"]]
        want = reference({k: v for k, v in spec.items()
                          if k != "format"}).columns
        assert_tables_equal(table_from_rows(rows), want)
        assert_tables_equal(table_from_wire(lines), want)

    def test_trailer_keys(self, server):
        lines = http_query(server, {"workloads": ["resnet50"],
                                    "workers": [4]})
        trailer = lines[-1]
        assert set(trailer) == {"type", "qos"} | set(RESULT_META_KEYS)
        assert set(trailer["qos"]) >= {"queue_wait_s", "latency_s",
                                       "coalesced_queries", "cache"}

    @pytest.mark.parametrize("body,code", [
        (b"{not json", "bad-json"),
        (json.dumps({"workloads": []}).encode(), "bad-query"),
        (json.dumps({"grid": "nope"}).encode(), "bad-query"),
        (json.dumps({"sync_k": ["-1"]}).encode(), "bad-query"),
        (json.dumps({"format": "xml"}).encode(), "bad-query"),
    ])
    def test_malformed_gets_structured_400(self, server, body, code):
        port = server.server_address[1]
        req = urllib.request.Request(f"http://127.0.0.1:{port}/query",
                                     data=body, method="POST")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req)
        assert ei.value.code == 400
        doc = json.loads(ei.value.read())
        assert doc["type"] == "error" and doc["code"] == code
        assert "Traceback" not in doc["error"]

    def test_unknown_endpoint_404(self, server):
        port = server.server_address[1]
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"http://127.0.0.1:{port}/nope")
        assert ei.value.code == 404

    def test_client_disconnect_mid_stream_keeps_serving(self, server):
        port = server.server_address[1]
        body = json.dumps({"grid": "frontier",
                           "workloads": ["resnet50"],
                           "workers": [8], "seed": 7}).encode()
        sock = socket.create_connection(("127.0.0.1", port))
        sock.sendall(b"POST /query HTTP/1.0\r\n"
                     b"Content-Length: %d\r\n\r\n%s"
                     % (len(body), body))
        sock.recv(512)          # read a little, then hang up
        sock.close()
        # the server must still answer the next query, bit-identically
        from repro.launch.serve_sweep import table_from_wire

        spec = {"workloads": ["resnet50"], "workers": [4], "seed": 7}
        lines = http_query(server, spec)
        assert_tables_equal(table_from_wire(lines),
                            reference(spec).columns)

    def test_concurrent_clients_bit_identity(self, server):
        from repro.launch.serve_sweep import table_from_wire

        refs = [reference(s).columns for s in COALESCE_SPECS]
        out = [None] * len(COALESCE_SPECS)

        def run(i, spec):
            out[i] = table_from_wire(http_query(server, spec))

        threads = [threading.Thread(target=run, args=(i, s))
                   for i, s in enumerate(COALESCE_SPECS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        for got, ref in zip(out, refs):
            assert_tables_equal(got, ref)

    def test_stats_and_healthz(self, server):
        port = server.server_address[1]
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/healthz") as r:
            assert json.loads(r.read()) == {"ok": True}
        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/stats") as r:
            stats = json.loads(r.read())
        for key in ("n_queries", "kernel_calls", "coalesce_factor",
                    "latency", "queue_wait", "cache", "queue_depth",
                    "sustained_scenarios_per_sec", "uptime_s"):
            assert key in stats, key


# ----------------------------------------------------------------------
# satellites: spec parity + warmed pools
# ----------------------------------------------------------------------
class TestGridSpecParity:
    def test_grid_from_spec_matches_cli_parsing(self):
        from repro.launch.sweep import build_parser, grid_from_args

        parser = build_parser()
        args = parser.parse_args(
            ["--grid", "mixed", "--workloads", "resnet50,alexnet",
             "--workers", "4,8", "--sync-k", "none,3"])
        from_cli = grid_from_args(args)
        from_spec = grid_from_spec(
            {"grid": "mixed", "workloads": "resnet50,alexnet",
             "workers": "4,8", "sync_k": "none,3"})
        assert from_cli == from_spec


class TestWarmPool:
    def test_warm_pool_then_parallel_sweep_bit_identical(self):
        from repro.core import parallel
        from repro.core.scenarios import default_grid

        parallel.warm_pool("process", jobs=2)
        grid = default_grid()
        ref = sweep(grid, seed=3)
        par = sweep(grid, jobs=2, seed=3)
        assert_tables_equal(par.columns, ref.columns)

    def test_warm_pool_serial_noop(self):
        from repro.core import parallel
        parallel.warm_pool("process", jobs=1)   # must not build a pool
