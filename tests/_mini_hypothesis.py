"""Minimal stand-in for the slice of the `hypothesis` API this test
suite uses, loaded by ``conftest.py`` only when the real library is not
installed (the build image forbids adding dependencies).

It runs each ``@given`` test ``max_examples`` times with values drawn
from a deterministically seeded PRNG (seed = CRC32 of the test's
qualified name), so failures are reproducible run-to-run.  It does NOT
shrink counterexamples or track coverage — when the real ``hypothesis``
package is available it is always preferred.
"""
from __future__ import annotations

import functools
import inspect
import random
import zlib
from typing import Any, Callable, Sequence


class Strategy:
    """A value source: ``do_draw(rng)`` yields one example."""

    def __init__(self, draw_fn: Callable[[random.Random], Any]):
        self._draw = draw_fn

    def do_draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "Strategy":
        return Strategy(lambda r: fn(self.do_draw(r)))


def integers(min_value: int, max_value: int) -> Strategy:
    return Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value: float, max_value: float) -> Strategy:
    return Strategy(lambda r: r.uniform(min_value, max_value))


def booleans() -> Strategy:
    return Strategy(lambda r: bool(r.getrandbits(1)))


def lists(elements: Strategy, min_size: int = 0, max_size: int = 10) -> Strategy:
    return Strategy(
        lambda r: [elements.do_draw(r) for _ in range(r.randint(min_size, max_size))])


def sampled_from(seq: Sequence[Any]) -> Strategy:
    items = list(seq)
    return Strategy(lambda r: items[r.randrange(len(items))])


class DataObject:
    """Interactive draw handle for ``@given(st.data())`` tests."""

    def __init__(self, rng: random.Random):
        self._rng = rng

    def draw(self, strategy: Strategy, label: str | None = None) -> Any:
        return strategy.do_draw(self._rng)


def data() -> Strategy:
    return Strategy(lambda r: DataObject(r))


def composite(fn: Callable) -> Callable[..., Strategy]:
    """``@composite`` strategies: ``fn(draw, *args)`` -> value."""

    @functools.wraps(fn)
    def make(*args: Any, **kwargs: Any) -> Strategy:
        return Strategy(lambda r: fn(lambda s: s.do_draw(r), *args, **kwargs))

    return make


class settings:
    """Decorator recording ``max_examples``; other knobs are ignored."""

    def __init__(self, max_examples: int = 20, deadline: Any = None, **_: Any):
        self.max_examples = max_examples

    def __call__(self, fn: Callable) -> Callable:
        fn._mini_hyp_settings = self  # read by the @given wrapper
        return fn


def given(*strategies: Strategy) -> Callable[[Callable], Callable]:
    def deco(fn: Callable) -> Callable:
        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any):
            cfg = getattr(wrapper, "_mini_hyp_settings", None)
            n = cfg.max_examples if cfg is not None else 20
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = [s.do_draw(rng) for s in strategies]
                fn(*args, *drawn, **kwargs)

        # Hide the drawn parameters from pytest's fixture resolution:
        # the wrapper's visible signature keeps only the leading params
        # (e.g. ``self``) that the strategies do not supply.
        params = list(inspect.signature(fn).parameters.values())
        keep = params[:len(params) - len(strategies)]
        wrapper.__signature__ = inspect.Signature(keep)
        if hasattr(wrapper, "__wrapped__"):
            del wrapper.__wrapped__
        return wrapper

    return deco
