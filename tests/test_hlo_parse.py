"""HLO collective-byte parser: crafted-module unit tests."""
import pytest

from repro.launch import hlo

MODULE = """\
HloModule test

%wbody.1 (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]) parameter(0)
  %ar = f32[64,128]{1,0} all-reduce(%x), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[64,128]) tuple(%i, %ar)
}

%wcond.1 (p: (s32[], f32[64,128])) -> pred[] {
  %p = (s32[], f32[64,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

ENTRY %main (x: f32[64,128]) -> f32[64,128] {
  %x = f32[64,128]{1,0} parameter(0)
  %ag = bf16[32,256]{1,0} all-gather(%y), dimensions={0}
  %w = (s32[], f32[64,128]) while(%init), condition=%wcond.1, body=%wbody.1
  ROOT %r = f32[64,128]{1,0} get-tuple-element(%w), index=1
}
"""


class TestShapeBytes:
    def test_f32(self):
        assert hlo._shape_bytes("f32[64,128]{1,0}") == 64 * 128 * 4

    def test_bf16_and_multiple(self):
        assert hlo._shape_bytes("(bf16[8,2]{1,0}, f32[4])") == 8 * 2 * 2 + 16

    def test_scalar(self):
        assert hlo._shape_bytes("s32[]") == 4


class TestCollectiveStats:
    def test_loop_scaling_from_parsed_trip_count(self):
        stats = hlo.collective_stats(MODULE)
        # all-gather in ENTRY once; all-reduce in the x12 while body
        assert stats.count_by_op["all-gather"] == 1
        assert stats.count_by_op["all-reduce"] == 12
        assert stats.bytes_by_op["all-reduce"] == 12 * 64 * 128 * 4
        assert stats.bytes_by_op["all-gather"] == 32 * 256 * 2

    def test_multipliers(self):
        mults = hlo.computation_multipliers(MODULE)
        assert mults["ENTRY"] == 1
        assert mults["wbody.1"] == 12

    def test_total(self):
        stats = hlo.collective_stats(MODULE)
        assert stats.total_bytes == 12 * 64 * 128 * 4 + 32 * 256 * 2
        assert stats.total_count == 13
