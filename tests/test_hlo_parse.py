"""HLO collective-byte parser: crafted-module unit tests."""
import pytest

from repro.launch import hlo

MODULE = """\
HloModule test

%wbody.1 (p: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %p = (s32[], f32[64,128]) parameter(0)
  %ar = f32[64,128]{1,0} all-reduce(%x), replica_groups={}, to_apply=%sum
  ROOT %t = (s32[], f32[64,128]) tuple(%i, %ar)
}

%wcond.1 (p: (s32[], f32[64,128])) -> pred[] {
  %p = (s32[], f32[64,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%i, %c), direction=LT
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %add = f32[] add(%a, %b)
}

ENTRY %main (x: f32[64,128]) -> f32[64,128] {
  %x = f32[64,128]{1,0} parameter(0)
  %ag = bf16[32,256]{1,0} all-gather(%y), dimensions={0}
  %w = (s32[], f32[64,128]) while(%init), condition=%wcond.1, body=%wbody.1
  ROOT %r = f32[64,128]{1,0} get-tuple-element(%w), index=1
}
"""


class TestShapeBytes:
    def test_f32(self):
        assert hlo._shape_bytes("f32[64,128]{1,0}") == 64 * 128 * 4

    def test_bf16_and_multiple(self):
        assert hlo._shape_bytes("(bf16[8,2]{1,0}, f32[4])") == 8 * 2 * 2 + 16

    def test_scalar(self):
        assert hlo._shape_bytes("s32[]") == 4

    def test_fp8_one_byte_each(self):
        # fp8 buffers must not silently drop out of collective_bytes
        for dt in ("f8e4m3fn", "f8e5m2", "f8e4m3fnuz", "f8e5m2fnuz",
                   "f8e4m3b11fnuz", "f8e4m3", "f8e3m4"):
            assert hlo._shape_bytes(f"{dt}[16,32]{{1,0}}") == 16 * 32, dt


FP8_MODULE = """\
HloModule fp8

ENTRY %main (x: f8e4m3fn[64,128]) -> f8e4m3fn[64,128] {
  %x = f8e4m3fn[64,128]{1,0} parameter(0)
  %ag = f8e5m2[32,256]{1,0} all-gather(%y), dimensions={0}
  ROOT %ar = f8e4m3fn[64,128]{1,0} all-reduce(%x), to_apply=%sum
}
"""


# Optimized HLO prints the while operand with its full tuple type
# (parens inside the operand!) and annotates the authoritative trip
# count in backend_config — both must parse, and the countdown
# condition's constant(0) must never be taken as a trip count.
TYPED_WHILE_MODULE = """\
HloModule typed

%down_cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %z = s32[] constant(0)
  ROOT %gt = pred[] compare(%i, %z), direction=GT
}

%down_body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %ar = f32[8]{0} all-reduce(%g), to_apply=%sum
  ROOT %t = (s32[], f32[8]) tuple(%i, %ar)
}

ENTRY %main (x: f32[8]) -> f32[8] {
  %x = f32[8]{0} parameter(0)
  %w = (s32[], f32[8]) while((s32[], f32[8]) %tuple.1), condition=%down_cond, body=%down_body, metadata={op_name="scan"}, backend_config={"known_trip_count":{"n":"5"}}
  ROOT %r = f32[8]{0} get-tuple-element(%w), index=1
}
"""


class TestFp8Collectives:
    def test_fp8_collective_bytes_counted(self):
        stats = hlo.collective_stats(FP8_MODULE)
        assert stats.bytes_by_op["all-reduce"] == 64 * 128
        assert stats.bytes_by_op["all-gather"] == 32 * 256
        assert stats.total_count == 2


class TestTypedOperandWhile:
    def test_known_trip_count_scales_typed_operand_while(self):
        mults = hlo.computation_multipliers(TYPED_WHILE_MODULE)
        assert mults["down_body"] == 5
        stats = hlo.collective_stats(TYPED_WHILE_MODULE)
        assert stats.count_by_op["all-reduce"] == 5
        assert stats.bytes_by_op["all-reduce"] == 5 * 8 * 4

    def test_countdown_constant_falls_back_to_default(self):
        # strip the backend_config: the cond's constant(0) must not be
        # taken as the trip count; the caller default applies
        module = TYPED_WHILE_MODULE.replace(
            ', backend_config={"known_trip_count":{"n":"5"}}', "")
        stats = hlo.collective_stats(module, loop_trip_count=7)
        assert stats.count_by_op["all-reduce"] == 7


class TestCollectiveStats:
    def test_loop_scaling_from_parsed_trip_count(self):
        stats = hlo.collective_stats(MODULE)
        # all-gather in ENTRY once; all-reduce in the x12 while body
        assert stats.count_by_op["all-gather"] == 1
        assert stats.count_by_op["all-reduce"] == 12
        assert stats.bytes_by_op["all-reduce"] == 12 * 64 * 128 * 4
        assert stats.bytes_by_op["all-gather"] == 32 * 256 * 2

    def test_multipliers(self):
        mults = hlo.computation_multipliers(MODULE)
        assert mults["ENTRY"] == 1
        assert mults["wbody.1"] == 12

    def test_total(self):
        stats = hlo.collective_stats(MODULE)
        assert stats.total_bytes == 12 * 64 * 128 * 4 + 32 * 256 * 2
        assert stats.total_count == 13
