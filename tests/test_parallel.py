"""Columnar sweep pipeline + sharded execution.

Pins the PR-7 contracts:

* The columnar :class:`~repro.core.sweep.SweepResult` is
  row-for-row equivalent to the per-scenario evaluation path on random
  grids, on both backends (the "did the refactor change any number"
  property).
* ``jobs>1`` sharded execution is **bit-identical** to serial, in the
  same order — chunk boundaries are invisible in the output.
* The vectorized ``filter`` / ``sorted_by`` and the columnar
  ``to_csv`` / ``to_json`` / ``format_table`` match their documented
  per-row semantics exactly (including ``sorted`` tie stability).
* The streamed JSON trailer round-trips the new throughput metadata
  (``elapsed_s`` / ``scenarios_per_sec``) with the same key set as the
  buffered document.

And the PR-9 crash-tolerance contracts:

* ``stream()`` is atomic — a failure mid-sweep leaves pre-existing
  output files byte-identical and no ``.tmp`` debris.
* A cached pool whose worker was SIGKILLed is evicted and rebuilt by
  ``_get_pool`` instead of poisoning later sweeps.
* A sweep that loses a worker process mid-flight (chaos SIGKILL)
  finishes with output **bit-identical** to serial; a poison span is
  rescued in-parent and named by flat index; a caller-supplied
  executor is never rebuilt behind the caller's back.
"""
from __future__ import annotations

import csv
import json

import numpy as np
import pytest
from hypothesis import given, settings

from strategies import scenario_grids
from repro.core.parallel import (parallel_tables, resolve_jobs, span_plan)
from repro.core.resulttable import COLUMNS, concat_tables, table_from_rows
from repro.core.scenarios import Scenario, ScenarioGrid, default_grid
from repro.core.sweep import (DEFAULT_CHUNK, SweepResult, evaluate_scenario,
                              iter_tables, stream, sweep)

NUMERIC = ("iteration_time_s", "samples_per_sec", "speedup",
           "t_comm_s", "t_comp_s", "t_mean_s", "t_p95_s", "t_p99_s")
LABELS = tuple(k for k in COLUMNS if k not in NUMERIC)


def assert_tables_identical(a: dict, b: dict):
    for k in COLUMNS:
        assert np.array_equal(a[k], b[k]), k


def assert_rows_agree(got, want, rel=1e-9):
    assert len(got) == len(want)
    for g, w in zip(got, want):
        for k in LABELS:
            assert g[k] == w[k], k
        for k in NUMERIC:
            assert g[k] == pytest.approx(w[k], rel=rel, abs=1e-15), k


def small_grid() -> ScenarioGrid:
    return ScenarioGrid(workloads=("alexnet", "resnet50"),
                        clusters=("v100-nvlink-ib",),
                        worker_counts=(1, 4),
                        policies=("tensorflow", "bucketed-4mb", "priority"),
                        collectives=("ring", "hierarchical"))


class TestColumnarEquivalence:
    @settings(max_examples=10, deadline=None)
    @given(scenario_grids())
    def test_columnar_rows_match_per_scenario_path_on_random_grids(
            self, grid):
        r = sweep(grid)
        assert_rows_agree(r.rows, [evaluate_scenario(s)
                                   for s in grid.expand()])
        rj = sweep(grid, backend="jax")
        assert_rows_agree(rj.rows, r.rows, rel=1e-6)

    def test_rows_view_is_cached_and_list_of_dicts(self):
        r = sweep(small_grid())
        rows = r.rows
        assert isinstance(rows, list) and isinstance(rows[0], dict)
        assert set(rows[0]) == set(COLUMNS)
        assert r.rows is rows
        # plain Python scalars — json-serializable without converters
        json.dumps(rows[0])

    def test_iter_tables_chunking_invisible(self):
        grid = small_grid()
        whole = concat_tables(list(iter_tables(grid)))
        chunked = concat_tables(list(iter_tables(grid, chunk=5)))
        assert_tables_identical(whole, chunked)


class TestShardedExecution:
    def test_jobs2_process_pool_bit_identical(self):
        grid = default_grid()
        serial = sweep(grid)
        parallel = sweep(grid, jobs=2)
        assert_tables_identical(serial.columns, parallel.columns)
        assert (parallel.n_analytical, parallel.n_timeline,
                parallel.n_simulated) == \
            (serial.n_analytical, serial.n_timeline, serial.n_simulated)

    def test_thread_pool_tiny_spans_preserve_order(self):
        grid = small_grid()
        serial = sweep(grid)
        sharded = concat_tables(list(parallel_tables(
            grid, jobs=3, chunk=1, pool="thread")))
        assert_tables_identical(serial.columns, sharded)

    def test_simulator_fallback_rows_filled_in_shards(self):
        from repro.core import policies as P
        from repro.core.policies import Policy
        P.ALL_POLICIES["_unstudied"] = Policy("_unstudied",
                                              overlap_comm=True)
        try:
            grid = ScenarioGrid(workloads=("alexnet",),
                                clusters=("v100-nvlink-ib",),
                                worker_counts=(2, 4),
                                policies=("caffe-mpi", "_unstudied"))
            serial = sweep(grid)
            assert serial.n_simulated == 2
            # thread pool: shares the (test-local) policy registry
            sharded = concat_tables(list(parallel_tables(
                grid, jobs=2, chunk=1, pool="thread")))
            assert_tables_identical(serial.columns, sharded)
        finally:
            del P.ALL_POLICIES["_unstudied"]

    def test_span_plan_covers_exactly(self):
        assert span_plan(0, 4, 10) == []
        for n, jobs, chunk in ((1, 2, 10), (100, 4, 8), (51840, 2, 8192)):
            spans = span_plan(n, jobs, chunk)
            assert spans[0][0] == 0 and spans[-1][1] == n
            assert all(a[1] == b[0] for a, b in zip(spans, spans[1:]))
            assert all(hi - lo >= min(chunk, n) for lo, hi in spans[:-1])
            assert len(spans) <= 4 * jobs

    def test_resolve_jobs(self):
        import os
        assert resolve_jobs(None) == resolve_jobs(0) == resolve_jobs(1) == 1
        assert resolve_jobs(3) == 3
        assert resolve_jobs(-1) == (os.cpu_count() or 1)

    def test_unknown_pool_kind_rejected(self):
        with pytest.raises(ValueError, match="pool"):
            list(parallel_tables(default_grid(), jobs=2, chunk=1,
                                 pool="fiber"))


class TestColumnarResultMethods:
    @pytest.fixture(scope="class")
    def result(self):
        return sweep(small_grid())

    def test_filter_matches_per_row_scan(self, result):
        got = result.filter(policy="bucketed-4mb", n_workers=4)
        want = [r for r in result.rows
                if r["policy"] == "bucketed-4mb" and r["n_workers"] == 4]
        assert got == want and len(got) == 4
        assert result.filter(workload="nope") == []

    def test_filter_normalizes_interconnect(self, result):
        assert result.filter(interconnect=None) == \
            result.filter(interconnect="default") == result.rows

    def test_sorted_by_matches_python_sorted_with_tie_stability(
            self, result):
        for col in ("speedup", "workload", "n_workers"):
            for rev in (True, False):
                assert result.sorted_by(col, reverse=rev) == \
                    sorted(result.rows, key=lambda r: r[col], reverse=rev)

    def test_to_csv_round_trips(self, result, tmp_path):
        path = tmp_path / "r.csv"
        result.to_csv(path)
        with open(path) as f:
            rows = list(csv.DictReader(f))
        assert len(rows) == len(result)
        for got, want in zip(rows, result.rows):
            assert set(got) == set(COLUMNS)
            assert got["workload"] == want["workload"]
            assert int(got["n_workers"]) == want["n_workers"]
            assert float(got["iteration_time_s"]) == \
                want["iteration_time_s"]

    def test_to_json_document(self, result):
        doc = json.loads(result.to_json())
        assert doc["rows"] == result.rows
        assert doc["n_scenarios"] == len(result)
        assert doc["scenarios_per_sec"] == pytest.approx(
            len(result) / doc["elapsed_s"])

    def test_format_table_limit(self, result):
        text = result.format_table(limit=3)
        assert len(text.splitlines()) == 5           # header + rule + 3
        assert result.format_table() == \
            result.format_table(result.rows)

    def test_empty_result(self):
        r = sweep(ScenarioGrid(workloads=()))
        assert len(r) == 0 and r.rows == []
        assert r.filter(policy="naive") == []
        assert r.sorted_by("speedup") == []
        assert json.loads(r.to_json())["rows"] == []


class TestStreamMetadata:
    def test_stream_trailer_round_trips_throughput(self, tmp_path):
        grid = small_grid()
        path = tmp_path / "s.json"
        summary = stream(grid, json_path=path)
        doc = json.loads(path.read_text())
        buffered = json.loads(sweep(grid).to_json())
        assert set(doc) == set(buffered)
        for key in ("n_scenarios", "elapsed_s", "scenarios_per_sec",
                    "n_analytical", "n_timeline", "n_simulated", "backend"):
            assert doc[key] == summary[key]
        assert summary["scenarios_per_sec"] == pytest.approx(
            summary["n_scenarios"] / summary["elapsed_s"])
        assert doc["rows"] == buffered["rows"]

    def test_stream_jobs_matches_serial_output(self, tmp_path):
        grid = default_grid()
        a, b = tmp_path / "serial.csv", tmp_path / "jobs.csv"
        stream(grid, csv_path=a)
        stream(grid, csv_path=b, jobs=2)
        assert a.read_text() == b.read_text()


class TestSweepCli:
    def test_jobs_flag(self, capsys, tmp_path):
        from repro.launch.sweep import main
        path = tmp_path / "cli.json"
        assert main(["--workloads", "alexnet", "--workers", "2,4",
                     "--policies", "tensorflow", "--jobs", "2",
                     "--json", str(path)]) == 0
        out = capsys.readouterr().out
        assert "/s;" in out                      # throughput in the summary
        doc = json.loads(path.read_text())
        import dataclasses
        ref = sweep(dataclasses.replace(          # CLI base is default_grid
            default_grid(), workloads=("alexnet",), worker_counts=(2, 4),
            policies=("tensorflow",)))
        assert doc["rows"] == ref.rows

    def test_jobs_flag_streaming(self, capsys, tmp_path):
        from repro.launch.sweep import main
        path = tmp_path / "cli_stream.json"
        assert main(["--workloads", "alexnet", "--workers", "2",
                     "--policies", "tensorflow,bucketed-4mb",
                     "--jobs", "2", "--chunk", "3",
                     "--stream", "--json", str(path)]) == 0
        doc = json.loads(path.read_text())
        assert doc["n_scenarios"] == 12 == len(doc["rows"])
        assert doc["scenarios_per_sec"] > 0

    def test_chunk_flag_buffered(self, capsys):
        from repro.launch.sweep import main
        assert main(["--workloads", "alexnet", "--workers", "2",
                     "--policies", "tensorflow", "--chunk", "2",
                     "--top", "3"]) == 0
        assert "evaluated in" in capsys.readouterr().out


class TestStreamAtomicity:
    def test_failure_leaves_preexisting_outputs_untouched(
            self, tmp_path, monkeypatch):
        import repro.core.sweep as sweep_mod

        grid = small_grid()
        csv_p, json_p = tmp_path / "out.csv", tmp_path / "out.json"
        csv_p.write_text("sentinel-csv")
        json_p.write_text("sentinel-json")
        real = sweep_mod.iter_tables

        def dies_at_chunk_2(*args, **kw):
            it = real(*args, **kw)
            yield next(it)
            raise RuntimeError("worker killed at chunk 2")

        monkeypatch.setattr(sweep_mod, "iter_tables", dies_at_chunk_2)
        with pytest.raises(RuntimeError, match="chunk 2"):
            stream(grid, csv_path=csv_p, json_path=json_p, chunk=5)
        # the half-written pass must not be visible: old bytes intact,
        # no temp debris
        assert csv_p.read_text() == "sentinel-csv"
        assert json_p.read_text() == "sentinel-json"
        assert sorted(p.name for p in tmp_path.iterdir()) == \
            ["out.csv", "out.json"]

    def test_success_replaces_stale_output_atomically(self, tmp_path):
        grid = small_grid()
        path = tmp_path / "out.json"
        path.write_text("stale")
        stream(grid, json_path=path, chunk=5)
        assert json.loads(path.read_text())["n_scenarios"] == len(grid)
        assert not (tmp_path / "out.json.tmp").exists()


class TestCrashTolerance:
    def test_broken_process_pool_evicted_and_rebuilt(self):
        import os
        import signal

        from concurrent.futures import BrokenExecutor
        from repro.core import parallel as par

        ex = par._get_pool("process", 2)
        assert ex.submit(os.getpid).result() > 0     # spin workers up
        for proc in list(ex._processes.values()):
            os.kill(proc.pid, signal.SIGKILL)
        with pytest.raises(BrokenExecutor):
            ex.submit(os.getpid).result()
        fresh = par._get_pool("process", 2)
        assert fresh is not ex
        assert ("process", 2) in par._POOLS
        assert fresh.submit(os.getpid).result() > 0

    def test_chaos_sigkill_worker_mid_sweep_bit_identical(self):
        import os
        import signal

        from repro.core import parallel as par

        grid = small_grid()
        serial = sweep(grid)
        gen = parallel_tables(grid, jobs=2, chunk=1, pool="process")
        tables = [next(gen)]                         # sweep is in flight
        victim = next(iter(
            par._POOLS[("process", 2)]._processes.values()))
        os.kill(victim.pid, signal.SIGKILL)
        tables.extend(gen)
        assert_tables_identical(serial.columns, concat_tables(tables))

    def test_span_retried_on_fresh_pool_is_bit_identical(
            self, monkeypatch):
        from concurrent.futures import BrokenExecutor
        from repro.core import parallel as par

        calls = {"n": 0}
        real = par._eval_span

        def flaky(grid, lo, hi, warm, seed=0):
            calls["n"] += 1
            if calls["n"] == 1:
                raise BrokenExecutor("worker died")
            return real(grid, lo, hi, warm, seed)

        monkeypatch.setattr(par, "_eval_span", flaky)
        monkeypatch.setattr(par, "RETRY_BACKOFF_S", 0.0)
        grid = small_grid()
        serial = sweep(grid)
        got = concat_tables(list(parallel_tables(
            grid, jobs=2, chunk=1, pool="thread")))
        assert_tables_identical(serial.columns, got)
        assert calls["n"] > len(span_plan(len(grid), 2, 1))  # retried

    def test_rescue_span_names_poison_flat_index(self, monkeypatch):
        from repro.core import parallel as par

        grid = small_grid()
        real = par._eval_span

        def bomb(grid, lo, hi, warm, seed=0):
            if lo <= 5 < hi:
                raise ValueError("boom")
            return real(grid, lo, hi, warm, seed)

        monkeypatch.setattr(par, "_eval_span", bomb)
        with pytest.raises(RuntimeError,
                           match=r"flat index 5 of poison span \[0, 8\)"):
            par._rescue_span(grid, 0, 8, 6, 0)
        # a poison-free span rescues whole, bit-identical to direct eval
        monkeypatch.setattr(par, "_eval_span", real)
        assert_tables_identical(par._rescue_span(grid, 0, 8, 6, 0),
                                real(grid, 0, 8, 6, 0))

    def test_external_executor_is_never_rebuilt(self, monkeypatch):
        from concurrent.futures import BrokenExecutor, ThreadPoolExecutor
        from repro.core import parallel as par

        def always_broken(*args, **kw):
            raise BrokenExecutor("worker died")

        monkeypatch.setattr(par, "_eval_span", always_broken)
        with ThreadPoolExecutor(max_workers=2) as ex:
            with pytest.raises(BrokenExecutor):
                list(parallel_tables(small_grid(), jobs=2, chunk=1,
                                     pool=ex))


class TestSweepResultConstruction:
    def test_from_table_from_rows(self):
        rows = [evaluate_scenario(Scenario("alexnet", "v100-nvlink-ib", 4,
                                           "caffe-mpi"))]
        r = SweepResult(columns=table_from_rows(rows), elapsed_s=0.5,
                        n_analytical=1, n_simulated=0)
        assert r.rows == rows
        assert r.scenarios_per_sec == pytest.approx(2.0)
        assert len(r) == 1

    def test_default_chunk_exported(self):
        assert DEFAULT_CHUNK >= 1
