"""Gradient-sync policies on a real multi-device (host) mesh.

Heavy checks run in a subprocess with 8 forced host devices so the
main pytest process keeps its single-device view (per the dry-run
contract)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")

SCRIPT = textwrap.dedent("""
    import os, json
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.comm.ddp import make_ddp_train_step, lower_ddp_step
    from repro.launch.mesh import make_dp_mesh
    from repro.optim.sgd import sgd

    mesh = make_dp_mesh(8)
    cfg = get_config("qwen1.5-4b").reduced(num_layers=4)
    key = jax.random.PRNGKey(0)
    params = T.init_lm(cfg, key)
    opt = sgd(lr=0.1, momentum=0.9)
    batch = {"tokens": jax.random.randint(key, (16, 32), 0, cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(1), (16, 32),
                                          0, cfg.vocab_size)}
    out = {}
    results = {}
    for pol in ("at_end", "wfbp", "bucketed"):
        p = jax.tree_util.tree_map(lambda x: x.copy(), params)
        st = opt.init(p)
        step = make_ddp_train_step(cfg, opt, mesh, sync_policy=pol)
        p2, st2, m = step(p, st, batch)
        results[pol] = p2
        out[f"loss_{pol}"] = float(m["loss"])
    ref = results["at_end"]
    for pol in ("wfbp", "bucketed"):
        diffs = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)))),
            ref, results[pol])
        out[f"maxdiff_{pol}"] = max(jax.tree_util.tree_leaves(diffs))
    # HLO collective placement
    import re
    for pol in ("at_end", "wfbp"):
        txt = lower_ddp_step(cfg, opt, mesh, pol, 16, 32).compile().as_text()
        comps = {}
        from repro.launch.hlo import split_computations, while_bodies
        cs = split_computations(txt)
        bodies = while_bodies(txt)
        in_loop = sum(c.count("all-reduce(") for n, c in cs.items()
                      if n in bodies)
        entry = cs.get("ENTRY", "").count("all-reduce(")
        out[f"ar_inloop_{pol}"] = in_loop
        out[f"ar_entry_{pol}"] = entry
    print("RESULT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def subproc_out():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    return json.loads(line[len("RESULT "):])


def test_losses_identical_across_policies(subproc_out):
    o = subproc_out
    assert o["loss_at_end"] == pytest.approx(o["loss_wfbp"], abs=1e-5)
    assert o["loss_at_end"] == pytest.approx(o["loss_bucketed"], abs=1e-5)


def test_parameters_identical_across_policies(subproc_out):
    assert subproc_out["maxdiff_wfbp"] < 1e-5
    assert subproc_out["maxdiff_bucketed"] < 1e-6


def test_wfbp_places_allreduce_inside_backward_loop(subproc_out):
    """The paper's WFBP: layer-wise collectives overlap with backward.
    In HLO that is an all-reduce inside the scan's while body; CNTK-
    style at_end keeps every all-reduce in ENTRY after the loops."""
    assert subproc_out["ar_inloop_wfbp"] >= 1
    assert subproc_out["ar_inloop_at_end"] == 0
    assert subproc_out["ar_entry_at_end"] >= 1
