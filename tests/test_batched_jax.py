"""JAX-native batched sweep kernels (ISSUE 6): differential agreement
with the NumPy oracle on every built-in grid and on random grids,
degenerate-scenario identities, gradient correctness against central
finite differences, sharded-mesh equivalence, and the explicit backend
routing errors."""
import dataclasses
import json

import numpy as np
import pytest
from hypothesis import given, settings
from strategies import scenario_grids

from repro.core import batched_jax as BJ
from repro.core.batched import eval_scenarios, grid_evaluator
from repro.core.policies import Policy
from repro.core.scenarios import (Scenario, ScenarioGrid, default_grid,
                                  frontier_grid, mixed_grid, resolve_cluster)
from repro.core.sweep import BACKENDS, iter_rows, stream, sweep
from repro.core.workloads import resolve_workload

NUMERIC = ("iteration_time_s", "samples_per_sec", "speedup",
           "t_comm_s", "t_comp_s")
LABELS = ("workload", "cluster", "n_workers", "policy", "collective",
          "interconnect", "batch_per_gpu", "method")

TIMELINE_POLICIES = ("bucketed-1mb", "bucketed-4mb", "bucketed-25mb",
                     "bucketed-100mb", "priority")


def assert_rows_agree(jax_rows, np_rows, rel=1e-6):
    """Vectorized column-wise agreement: exact labels, <= rel numerics."""
    assert len(jax_rows) == len(np_rows) > 0
    for key in LABELS:
        assert [r[key] for r in jax_rows] == [r[key] for r in np_rows], key
    for key in NUMERIC:
        a = np.array([r[key] for r in jax_rows], dtype=np.float64)
        b = np.array([r[key] for r in np_rows], dtype=np.float64)
        np.testing.assert_allclose(a, b, rtol=rel, atol=1e-12, err_msg=key)


def assert_grid_agrees(grid, rel=1e-6):
    rj = sweep(grid, backend="jax")
    rn = sweep(grid, backend="numpy")
    assert rj.backend == "jax" and rj.n_simulated == 0
    assert rj.n_analytical == rn.n_analytical
    assert rj.n_timeline == rn.n_timeline
    assert_rows_agree(rj.rows, rn.rows, rel=rel)


class TestBuiltinGridAgreement:
    """ISSUE-6 acceptance: the jit/vmap kernels agree with the NumPy
    oracle to <= 1e-6 relative on every built-in grid (plus the
    timeline-policy variants of default/mixed)."""

    def test_default_grid(self):
        assert_grid_agrees(default_grid())

    def test_mixed_grid_spans_all_providers(self):
        g = mixed_grid()
        assert any(w.startswith("trace:") for w in g.workloads)
        assert any(w.startswith("llm:") for w in g.workloads)
        assert_grid_agrees(g)

    def test_frontier_grid(self):
        assert_grid_agrees(frontier_grid())

    def test_default_grid_bucketed_priority(self):
        assert_grid_agrees(dataclasses.replace(
            default_grid(), policies=TIMELINE_POLICIES))

    def test_eval_scenarios_jax_matches_numpy(self):
        scenarios = [
            Scenario("resnet50", "v100-nvlink-ib", 16, "caffe-mpi",
                     collective=c, interconnect=ic)
            for c in ("ring", "tree", "hierarchical")
            for ic in (None, "ib-100g@bw2@lat0.25")
        ] + [
            Scenario("trace:alexnet-k80", "k80-pcie-10gbe", 8, p)
            for p in ("naive", "bucketed-25mb", "priority")
        ] + [
            Scenario("llm:gemma3-1b", "tpu-v5e-pod", 4, "tensorflow",
                     batch_per_gpu=8),
        ]
        assert_rows_agree(BJ.eval_scenarios_jax(scenarios),
                          eval_scenarios(scenarios))


class TestRandomGridProperty:
    @settings(max_examples=10, deadline=None)
    @given(scenario_grids())
    def test_numpy_equals_jax_on_random_grids(self, grid):
        assert_grid_agrees(grid)


class TestDegenerateScenarios:
    def test_single_worker_zero_comm(self):
        """n_workers=1: no collective traffic on any backend/policy."""
        grid = ScenarioGrid(workloads=("alexnet",),
                            clusters=("k80-pcie-10gbe",), worker_counts=(1,),
                            policies=TIMELINE_POLICIES + ("caffe-mpi",))
        r = sweep(grid, backend="jax")
        for row in r.rows:
            assert row["t_comm_s"] == 0.0
            assert row["speedup"] == pytest.approx(1.0)
        times = {row["policy"]: row["iteration_time_s"] for row in r.rows}
        for name in TIMELINE_POLICIES:
            assert times[name] == pytest.approx(times["caffe-mpi"],
                                                rel=1e-12)

    def test_one_giant_bucket_equals_fused_comm_at_end(self):
        """googlenet (~28 MB of gradients) under bucketed-100mb: one
        bucket released by layer-1's backward, so the jax row must be
        max(io+h2d, comp + fused_allreduce + t_u) exactly."""
        s = Scenario("googlenet", "v100-nvlink-ib", 16, "bucketed-100mb")
        tab = resolve_workload(s.workload)
        assert float(tab.grad_bytes.sum()) < 100e6
        cluster = resolve_cluster(s)
        costs = tab.iteration_costs(cluster, tab.batch_default, 16)
        dur = cluster.allreduce_time(float(tab.grad_bytes.sum()), 16)
        want = max(costs.t_io + costs.t_h2d,
                   float(np.sum(costs.t_f) + np.sum(costs.t_b))
                   + dur + costs.t_u)
        [row] = BJ.eval_scenarios_jax([s])
        assert row["method"] == "timeline"
        assert row["iteration_time_s"] == pytest.approx(want, rel=1e-9)

    def test_one_byte_buckets_equal_per_layer_wfbp(self):
        """bucket_bytes below every layer payload ≡ caffe-mpi's exact
        per-layer closed form, on the jax backend too."""
        from repro.core import policies as P
        P.ALL_POLICIES["_bucket1b"] = Policy(
            "_bucket1b", overlap_io=True, h2d_early=True, overlap_comm=True,
            bucket_bytes=1.0)
        try:
            grid = ScenarioGrid(workloads=("alexnet", "resnet50"),
                                clusters=("v100-nvlink-ib",),
                                worker_counts=(4, 16),
                                policies=("_bucket1b", "caffe-mpi"))
            r = sweep(grid, backend="jax")
            b1 = r.filter(policy="_bucket1b")
            cm = r.filter(policy="caffe-mpi")
            assert len(b1) == len(cm) > 0
            for a, b in zip(b1, cm):
                assert a["method"] == "timeline" and b["method"] == "analytical"
                assert a["iteration_time_s"] == pytest.approx(
                    b["iteration_time_s"], rel=1e-9)
        finally:
            del P.ALL_POLICIES["_bucket1b"]


class TestGradientCorrectness:
    """jax.grad through the full kernel vs central finite differences
    on the NumPy oracle (which rebuilds bucket partitions per call)."""

    @staticmethod
    def _fd_grad(grid, p0, key, rel_eps=1e-5):
        g = np.zeros_like(p0[key])
        for i in range(g.size):
            eps = abs(float(p0[key].ravel()[i])) * rel_eps or 1e-9
            hi = {k: v.copy() for k, v in p0.items()}
            lo = {k: v.copy() for k, v in p0.items()}
            hi[key].ravel()[i] += eps
            lo[key].ravel()[i] -= eps
            g.ravel()[i] = (BJ.numpy_iteration_times(grid, hi).sum()
                            - BJ.numpy_iteration_times(grid, lo).sum()) \
                / (2 * eps)
        return g

    def _check_family(self, policies):
        grid = ScenarioGrid(workloads=("resnet50",),
                            clusters=("v100-nvlink-ib",), worker_counts=(16,),
                            policies=policies,
                            collectives=("ring", "hierarchical"))
        p0 = BJ.default_params(grid)
        got = BJ.grad_iteration_time(grid)
        # sanity: the jax path itself matches the oracle at p0
        np.testing.assert_allclose(
            np.asarray(BJ.jax_grid_evaluator(grid)
                       .columns()["iteration_time_s"]),
            BJ.numpy_iteration_times(grid), rtol=1e-9)
        for key in ("intra_bw", "intra_lat", "inter_bw", "inter_lat"):
            want = self._fd_grad(grid, p0, key)
            np.testing.assert_allclose(got[key], want, rtol=1e-3,
                                       atol=1e-12, err_msg=key)
        # at least one link parameter must actually matter
        assert any(np.abs(got[k]).max() > 0
                   for k in ("intra_bw", "inter_bw"))
        return grid, p0, got

    def test_closed_form_family(self):
        self._check_family(("caffe-mpi", "mxnet", "naive"))

    def test_timeline_family_and_flat_bucket_axis(self):
        grid, p0, got = self._check_family(
            ("bucketed-4mb", "bucketed-25mb", "priority"))
        # iteration time is piecewise constant in bucket_bytes: the
        # exact gradient is 0 a.e., and the FD twin (which *rebuilds*
        # the partition) recovers the same 0 inside a partition cell
        assert p0["bucket_bytes"].size > 0
        want = self._fd_grad(grid, p0, "bucket_bytes")
        np.testing.assert_allclose(got["bucket_bytes"], 0.0, atol=1e-12)
        np.testing.assert_allclose(want, 0.0, atol=1e-12)

    def test_unknown_param_key_rejected(self):
        f, p0 = BJ.iteration_time_fn(default_grid())
        with pytest.raises(ValueError, match="unknown param keys"):
            f({**p0, "warp_drive": np.ones(3)})


class TestShardedMesh:
    def test_explicit_mesh_matches_unsharded(self):
        import jax
        from repro.launch.mesh import make_dp_mesh

        grid = dataclasses.replace(default_grid(),
                                   worker_counts=(2, 7, 16))  # odd S: pads
        mesh = make_dp_mesh(len(jax.devices()))
        sharded = BJ.JaxGridEvaluator(grid, mesh=mesh)
        plain = BJ.JaxGridEvaluator(grid, mesh=None)
        assert sharded.mesh is mesh and plain.mesh is None
        a, b = sharded.columns(), plain.columns()
        assert set(a) == set(b)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k], err_msg=k)


class TestBackendRouting:
    """Satellite 4: invalid backend combinations raise loudly — the jax
    backend never falls back to a NumPy path silently."""

    def test_unknown_backend(self):
        for fn in (lambda: sweep(default_grid(), backend="torch"),
                   lambda: list(iter_rows(default_grid(), backend="torch"))):
            with pytest.raises(ValueError, match="unknown backend"):
                fn()
        assert "jax" in BACKENDS and "numpy" in BACKENDS

    def test_jax_rejects_batched_false(self):
        with pytest.raises(ValueError, match="batched=False"):
            sweep(default_grid(), backend="jax", batched=False)
        with pytest.raises(ValueError, match="batched=False"):
            list(iter_rows(default_grid(), backend="jax", batched=False))

    def test_jax_rejects_force_simulator(self):
        with pytest.raises(ValueError, match="force_simulator"):
            sweep(default_grid(), backend="jax", force_simulator=True)
        with pytest.raises(ValueError, match="force_simulator"):
            stream(default_grid(), json_path="/dev/null", backend="jax",
                   force_simulator=True)

    def test_jax_rejects_simulator_only_policies(self):
        from repro.core import policies as P
        # unstudied flag combination: neither closed nor timeline form
        P.ALL_POLICIES["_simonly"] = Policy(
            "_simonly", overlap_io=False, overlap_comm=True,
            bucket_bytes=25e6)
        try:
            grid = ScenarioGrid(workloads=("alexnet",),
                                clusters=("v100-nvlink-ib",),
                                worker_counts=(2,),
                                policies=("caffe-mpi", "_simonly"))
            with pytest.raises(ValueError, match="_simonly"):
                sweep(grid, backend="jax")
            with pytest.raises(ValueError, match="_simonly"):
                BJ.eval_scenarios_jax(grid.expand())
            # the NumPy backend happily interleaves the simulator
            r = sweep(grid, backend="numpy")
            assert r.n_simulated == 1 and r.backend == "numpy"
        finally:
            del P.ALL_POLICIES["_simonly"]

    def test_stream_json_carries_backend(self, tmp_path):
        path = tmp_path / "s.json"
        summary = stream(ScenarioGrid(workloads=("alexnet",),
                                      worker_counts=(2,)),
                         json_path=str(path), backend="jax")
        assert summary["backend"] == "jax"
        doc = json.loads(path.read_text())
        assert doc["backend"] == "jax"
        assert doc["n_simulated"] == 0

    def test_sweep_result_json_carries_backend(self, tmp_path):
        r = sweep(ScenarioGrid(workloads=("alexnet",), worker_counts=(2,)),
                  backend="jax")
        path = tmp_path / "r.json"
        r.to_json(str(path))
        assert json.loads(path.read_text())["backend"] == "jax"


class TestKernelSurface:
    def test_columns_slice_matches_numpy_gridrun(self):
        """The kernel-only surfaces the benchmark times are comparable:
        jax JaxGridRun.columns_slice vs NumPy GridRun.columns_slice."""
        grid = default_grid()
        jr = BJ.jax_grid_evaluator(grid).run()
        nr = grid_evaluator(grid).run()
        a = jr.columns_slice(7, 203)
        b = nr.columns_slice(7, 203)
        for k in NUMERIC:
            np.testing.assert_allclose(a[k], b[k], rtol=1e-6, err_msg=k)
        assert a["method"] == ["analytical"] * (203 - 7)

    def test_empty_grid_columns(self):
        grid = dataclasses.replace(default_grid(), worker_counts=())
        jev = BJ.JaxGridEvaluator(grid)
        cols = jev.columns()
        assert all(v.size == 0 for v in cols.values())
