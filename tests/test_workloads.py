"""Workload registry: provider resolution, memoization, LLM block
slicing consistency with archcost, cross-provider sweep integration,
and the trace-workload analytical/simulator agreement (ISSUE 2
acceptance criteria)."""
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import TRAIN_4K
from repro.core import workloads as W
from repro.core.archcost import block_cost_table, param_counts, step_cost
from repro.core.costmodel import CNN_WORKLOADS, make_iteration_costs
from repro.core.hardware import COLLECTIVE_ALGORITHMS, V100_CLUSTER
from repro.core.scenarios import Scenario, ScenarioGrid, mixed_grid
from repro.core.simulator import simulate_steady
from repro.core.sweep import evaluate_scenario, sweep
from repro.traces.bundled import ALEXNET_K80
from repro.traces.format import write_trace

EXACT_POLICIES = ("naive", "cntk", "mxnet", "tensorflow", "caffe-mpi")


class TestRegistry:
    def test_bare_name_is_cnn_scheme(self):
        assert W.resolve_workload("alexnet") is W.resolve_workload("cnn:alexnet")

    def test_tables_memoized_at_module_scope(self):
        for name in ("cnn:resnet50", "trace:alexnet-k80", "llm:gemma3-1b"):
            assert W.resolve_workload(name) is W.resolve_workload(name)

    def test_known_workloads_spans_all_schemes(self):
        names = W.known_workloads()
        schemes = {n.split(":", 1)[0] for n in names}
        # jax: names appear only once something has been measured into
        # the measurement directory (enumerable, not guaranteed)
        assert {"cnn", "trace", "llm"} <= schemes <= {"cnn", "trace",
                                                      "llm", "jax"}
        assert len([n for n in names if n.startswith("llm:")]) == len(ARCH_IDS)

    @pytest.mark.parametrize("bad", [
        "vgg16", "cnn:vgg16", "trace:nope", "llm:gpt-5",
        "dataset:imagenet", "trace:/no/such/file.trace"])
    def test_unknown_names_raise_value_error(self, bad):
        with pytest.raises(ValueError, match="unknown"):
            W.resolve_workload(bad)

    def test_scenario_validate_accepts_all_providers(self):
        for wl in ("alexnet", "cnn:googlenet", "trace:alexnet-k80",
                   "llm:rwkv6-1.6b"):
            Scenario(wl, "v100-nvlink-ib", 4, "caffe-mpi").validate()

    def test_scenario_validate_rejects_unknown_workload(self):
        with pytest.raises(ValueError, match="unknown workload"):
            Scenario("llm:nope", "v100-nvlink-ib", 4, "naive").validate()

    def test_cnn_table_matches_costmodel(self):
        tab = W.resolve_workload("cnn:resnet50")
        builder, batch, _ = CNN_WORKLOADS["resnet50"]
        layers = builder()
        assert tab.batch_default == batch
        assert tab.num_layers == len(layers)
        np.testing.assert_allclose(tab.grad_bytes,
                                   [l.grad_bytes for l in layers])

    def test_trace_from_file_path(self, tmp_path):
        p = tmp_path / "alexnet.trace"
        write_trace(ALEXNET_K80, p)
        tab = W.resolve_workload(f"trace:{p}")
        bundled = W.resolve_workload("trace:alexnet-k80")
        assert tab.batch_default == bundled.batch_default == 1024
        np.testing.assert_allclose(tab.t_f, bundled.t_f)
        np.testing.assert_allclose(tab.grad_bytes, bundled.grad_bytes)

    def test_trace_table_maps_data_layer_to_io(self):
        tab = W.resolve_workload("trace:alexnet-k80")
        assert tab.is_measured
        assert tab.num_layers == 21                  # data layer stripped
        assert tab.t_io_measured == pytest.approx(1.2)
        assert tab.param_bytes == pytest.approx(243_860_896)


class TestLLMProvider:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_block_table_consistent_with_archcost(self, arch):
        cfg = get_config(arch)
        blocks = block_cost_table(cfg, TRAIN_4K.seq_len)
        total, active = param_counts(cfg)
        assert sum(b.params for b in blocks) == pytest.approx(total)
        assert sum(b.active_params for b in blocks) == pytest.approx(active)
        # train flops = 3x forward (fwd + 2x-fwd backward), B sequences
        sc = step_cost(cfg, TRAIN_4K)
        fwd = sum(b.flops_fwd for b in blocks)
        assert 3.0 * TRAIN_4K.global_batch * fwd == pytest.approx(
            sc.flops, rel=1e-9)

    def test_grad_payload_is_bf16_total_params(self):
        cfg = get_config("qwen2-moe-a2.7b")
        tab = W.resolve_workload("llm:qwen2-moe-a2.7b")
        total, active = param_counts(cfg)
        assert tab.grad_bytes.sum() == pytest.approx(2.0 * total)
        # MoE: gradients cover all experts, compute only routed-active
        assert tab.param_bytes > 2.0 * active

    def test_pattern_aware_blocks(self):
        # gemma3: 5 local : 1 global pattern -> heterogeneous flops
        tab = W.resolve_workload("llm:gemma3-1b")
        cfg = get_config("gemma3-1b")
        assert cfg.tie_embeddings
        assert tab.num_layers == cfg.num_layers + 1   # embed (tied head)
        block_flops = tab.flops_fwd[1:]               # the L/G blocks
        assert len(set(block_flops.tolist())) > 1

    def test_untied_head_is_its_own_layer(self):
        tab = W.resolve_workload("llm:qwen1.5-4b")
        cfg = get_config("qwen1.5-4b")
        assert not cfg.tie_embeddings
        assert tab.num_layers == cfg.num_layers + 2   # embed + lm_head
        emb_bytes = 2.0 * cfg.vocab_size * cfg.d_model
        assert tab.grad_bytes[0] == pytest.approx(emb_bytes)
        assert tab.grad_bytes[-1] == pytest.approx(emb_bytes)


class TestAgreement:
    """ISSUE-2 acceptance: trace: workloads evaluated analytically match
    the event-driven simulator to <= 1e-6 on every exact policy."""

    @pytest.mark.parametrize("policy", EXACT_POLICIES)
    def test_trace_workload_fast_path_exact(self, policy):
        grid = ScenarioGrid(workloads=("trace:alexnet-k80",),
                            clusters=("k80-pcie-10gbe", "v100-nvlink-ib"),
                            worker_counts=(1, 2, 16), policies=(policy,),
                            collectives=COLLECTIVE_ALGORITHMS)
        for s in grid.expand():
            fast = evaluate_scenario(s, method="analytical")
            slow = evaluate_scenario(s, method="simulator")
            assert fast["iteration_time_s"] == pytest.approx(
                slow["iteration_time_s"], rel=1e-6), s.label()

    @pytest.mark.parametrize("policy", ("naive", "caffe-mpi"))
    def test_llm_workload_fast_path_exact(self, policy):
        grid = ScenarioGrid(workloads=("llm:gemma3-1b", "llm:qwen1.5-32b"),
                            clusters=("tpu-v5e-pod",),
                            worker_counts=(4, 64), policies=(policy,))
        for s in grid.expand():
            fast = evaluate_scenario(s, method="analytical")
            slow = evaluate_scenario(s, method="simulator")
            assert fast["iteration_time_s"] == pytest.approx(
                slow["iteration_time_s"], rel=1e-6), s.label()


class TestMixedSweep:
    def test_mixed_grid_spans_providers_on_fast_path(self):
        g = mixed_grid()
        schemes = {wl.split(":", 1)[0] for wl in g.workloads}
        assert schemes == {"cnn", "trace", "llm"}
        assert len([w for w in g.workloads if w.startswith("llm:")]) >= 3
        r = sweep(g)
        assert len(r) == len(g) >= 1000
        assert r.n_simulated == 0
        assert all(row["iteration_time_s"] > 0 for row in r.rows)

    def test_trace_workload_sweeps_other_scales(self):
        # the 2-GPU Table VI trace, predicted at 4 and 16 workers:
        # more workers => more comm => no faster per iteration
        r = sweep(ScenarioGrid(workloads=("trace:alexnet-k80",),
                               clusters=("k80-pcie-10gbe",),
                               worker_counts=(2, 4, 16),
                               policies=("caffe-mpi",)))
        times = [row["iteration_time_s"] for row in r.rows]
        assert times == sorted(times)

    def test_make_iteration_costs_accepts_registry_names(self):
        by_name = make_iteration_costs("trace:alexnet-k80", V100_CLUSTER,
                                       1024, 4)
        tab = W.resolve_workload("trace:alexnet-k80")
        direct = tab.iteration_costs(V100_CLUSTER, 1024, 4)
        np.testing.assert_allclose(by_name.t_f, direct.t_f)
        assert by_name.t_io == pytest.approx(direct.t_io)

    def test_registry_name_honors_legacy_analytic_kwargs(self):
        # the pre-registry make_iteration_costs/predict_cnn kwargs
        # still work through the table path
        base = make_iteration_costs("alexnet", V100_CLUSTER, 32, 4)
        decoded = make_iteration_costs("alexnet", V100_CLUSTER, 32, 4,
                                       decode_seconds_per_byte=1e-9)
        assert decoded.t_io > base.t_io
        halved = make_iteration_costs("alexnet", V100_CLUSTER, 32, 4,
                                      bytes_per_sample=55e3)
        assert halved.t_h2d < base.t_h2d
        ratio3 = make_iteration_costs("alexnet", V100_CLUSTER, 32, 4,
                                      bwd_fwd_ratio=3.0)
        np.testing.assert_allclose(ratio3.t_b, 1.5 * np.asarray(base.t_b))

    def test_measured_workload_rejects_decode_override(self):
        tab = W.resolve_workload("trace:alexnet-k80")
        with pytest.raises(ValueError, match="already includes the decode"):
            tab.iteration_costs(V100_CLUSTER, 1024, 4,
                                decode_seconds_per_byte=1e-9)

    def test_measured_workload_rejects_bwd_fwd_ratio_override(self):
        tab = W.resolve_workload("trace:alexnet-k80")
        with pytest.raises(ValueError, match="own backward times"):
            tab.iteration_costs(V100_CLUSTER, 1024, 4, bwd_fwd_ratio=3.0)
        # the plain default path stays fine (sweep/make_iteration_costs)
        make_iteration_costs("trace:alexnet-k80", V100_CLUSTER, 1024, 4)

    def test_rewritten_trace_file_is_not_served_stale(self, tmp_path):
        import os

        p = tmp_path / "evolving.trace"
        p.write_text("# batch: 8\n0\tconv\t100\t200\t10\t4096\n")
        first = W.resolve_workload(f"trace:{p}")
        p.write_text("# batch: 8\n0\tconv\t999\t200\t10\t4096\n")
        os.utime(p, ns=(os.stat(p).st_mtime_ns + 10**9,) * 2)
        second = W.resolve_workload(f"trace:{p}")
        assert second is not first
        assert second.t_f[0] == pytest.approx(999e-6)

    def test_trace_without_batch_header_locks_batch(self, tmp_path):
        p = tmp_path / "nobatch.trace"
        p.write_text("# network: x\n"
                     "0\tconv\t100\t200\t10\t4096\n")
        tab = W.resolve_workload(f"trace:{p}")
        assert tab.batch_locked and tab.batch_default == 1
        tab.iteration_costs(V100_CLUSTER, 1, 4)       # default batch fine
        with pytest.raises(ValueError, match="no recorded batch"):
            tab.iteration_costs(V100_CLUSTER, 64, 4)

    def test_malformed_batch_header_names_the_file(self, tmp_path):
        from repro.traces.format import read_trace

        p = tmp_path / "badbatch.trace"
        p.write_text("# batch: 1k\n0\tconv\t1\t2\t0\t0\n")
        with pytest.raises(ValueError, match="badbatch.trace"):
            read_trace(p)

    def test_timeline_path_uses_registry_tables(self):
        s = Scenario("llm:gemma3-1b", "tpu-v5e-pod", 8, "bucketed-25mb")
        row = evaluate_scenario(s)
        assert row["method"] == "timeline"
        assert row["iteration_time_s"] > 0
        # the event-driven oracle builds from the same registry table
        # and agrees
        sim = evaluate_scenario(s, method="simulator")
        assert sim["method"] == "simulated"
        assert row["iteration_time_s"] == pytest.approx(
            sim["iteration_time_s"], rel=1e-6)


class TestJSON:
    def test_sweep_result_to_json_roundtrip(self, tmp_path):
        import json

        r = sweep(ScenarioGrid(workloads=("trace:alexnet-k80",),
                               worker_counts=(2,), policies=("naive",)))
        path = tmp_path / "sweep.json"
        text = r.to_json(path)
        doc = json.loads(path.read_text())
        assert json.loads(text) == doc
        assert doc["n_scenarios"] == len(r)
        assert doc["rows"][0]["iteration_time_s"] == pytest.approx(
            r.rows[0]["iteration_time_s"])

    def test_cli_json_flag(self, tmp_path, capsys):
        from repro.launch.sweep import main

        out = tmp_path / "out.json"
        rc = main(["--workloads", "cnn:alexnet,trace:alexnet-k80",
                   "--clusters", "k80-pcie-10gbe", "--workers", "2",
                   "--policies", "caffe-mpi", "--collectives", "ring",
                   "--top", "2", "--json", str(out)])
        assert rc == 0
        assert out.exists()
        import json

        doc = json.loads(out.read_text())
        assert doc["n_scenarios"] == 2

    def test_cli_mixed_grid(self, capsys):
        from repro.launch.sweep import main

        rc = main(["--grid", "mixed", "--workers", "4", "--top", "0"])
        assert rc == 0
        captured = capsys.readouterr().out
        assert "270 scenarios" in captured
        assert "270 analytical" in captured
        assert "llm:" in captured and "trace:" in captured

    def test_cli_list_workloads(self, capsys):
        from repro.launch.sweep import main

        rc = main(["--list-workloads"])
        assert rc == 0
        out = capsys.readouterr().out
        for expect in ("cnn:alexnet", "trace:alexnet-k80", "llm:gemma3-1b"):
            assert expect in out


class TestThroughputBenchmark:
    def test_smoke_mode_writes_json(self, tmp_path):
        from benchmarks.bench_sweep_throughput import run

        path = tmp_path / "BENCH_sweep.json"
        report = run(smoke=True, json_path=str(path))
        assert path.exists()
        for key in ("default_grid", "mixed_grid", "frontier_grid",
                    "bucketed_priority_grid"):
            assert report[key]["batched"]["scenarios_per_sec"] > 0
            assert report[key]["batched"]["n_simulated"] == 0
        # both paths timed (and the speedup ratio recorded) on the
        # default, mixed and bucketed/priority grids even in smoke mode
        for key in ("default_grid", "mixed_grid",
                    "bucketed_priority_grid"):
            assert report[key]["per_scenario"]["scenarios_per_sec"] > 0
            assert report[key]["speedup"] > 1.0
        # the bucketed/priority grid is where the simulated-path
        # trajectory finally records non-zero rows: every scenario is
        # schedule-dependent, so the batched side is all-timeline and
        # the per-scenario side is all-simulator
        tl = report["bucketed_priority_grid"]
        assert tl["batched"]["n_timeline"] == tl["n_scenarios"]
        assert tl["per_scenario"]["n_simulated"] == tl["n_scenarios"]
        assert tl["speedup"] > 10.0
