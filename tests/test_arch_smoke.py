"""Per-architecture smoke tests (deliverable f): a REDUCED variant of
each assigned config (2 layers, d_model<=512, <=4 experts) runs one
forward and one train step on CPU with shape + finiteness asserts."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import encdec as ED
from repro.models import transformer as T
from repro.optim.sgd import sgd

B, S = 2, 16


def _inputs(cfg, key):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extra = {}
    if cfg.arch_type == "audio":
        extra["frames"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), cfg.dtype)
    if cfg.arch_type == "vlm":
        extra["images"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model), cfg.dtype)
    return tokens, labels, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finiteness(arch):
    cfg = get_config(arch).reduced()
    assert cfg.d_model <= 512 and cfg.num_layers <= 2
    assert cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    tokens, _, extra = _inputs(cfg, key)
    if cfg.arch_type == "audio":
        params = ED.init_encdec(cfg, key)
        logits, aux = ED.forward(cfg, params, extra["frames"], tokens)
    else:
        params = T.init_lm(cfg, key)
        logits, aux = T.forward(cfg, params, tokens,
                                encoder_out=extra.get("images"))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    tokens, labels, extra = _inputs(cfg, key)
    opt = sgd(lr=1e-2, momentum=0.9)
    if cfg.arch_type == "audio":
        params = ED.init_encdec(cfg, key)
        loss = lambda p: ED.loss_fn(cfg, p, extra["frames"], tokens, labels)[0]
    else:
        params = T.init_lm(cfg, key)
        loss = lambda p: T.loss_fn(cfg, p, tokens, labels,
                                   encoder_out=extra.get("images"))[0]
    l0, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(l0))
    gleaves = jax.tree_util.tree_leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in gleaves)
    state = opt.init(params)
    new_params, _ = opt.update(grads, state, params)
    l1 = loss(new_params)
    assert bool(jnp.isfinite(l1))
    # a gradient step on the same batch should not increase loss much
    assert float(l1) < float(l0) + 0.5


@pytest.mark.parametrize("arch", ["gemma3-1b", "rwkv6-1.6b",
                                  "recurrentgemma-2b", "qwen2-moe-a2.7b",
                                  "llama-3.2-vision-90b", "whisper-tiny"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    tokens, _, extra = _inputs(cfg, key)
    if cfg.arch_type == "audio":
        params = ED.init_encdec(cfg, key)
        enc = ED.encode(cfg, params["encoder"], extra["frames"])
        fwd, _ = T.forward(cfg, params["decoder"], tokens, encoder_out=enc)
        dec, _ = T.prefill_via_decode(cfg, params["decoder"], tokens, S,
                                      encoder_out=enc)
    else:
        params = T.init_lm(cfg, key)
        enc = extra.get("images")
        fwd, _ = T.forward(cfg, params, tokens, encoder_out=enc)
        dec, _ = T.prefill_via_decode(cfg, params, tokens, S, encoder_out=enc)
    scale = float(jnp.max(jnp.abs(fwd))) + 1e-6
    assert float(jnp.max(jnp.abs(fwd - dec))) / scale < 5e-4


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned dims."""
    expect = {
        "whisper-tiny": (4, 384, 6, 6, 1536, 51865),
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "gemma3-1b": (26, 1152, 4, 1, 6912, 262144),
        "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
        "rwkv6-1.6b": (24, 2048, 32, 32, 7168, 65536),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
    }
    for arch, (L, d, H, K, ff, V) in expect.items():
        cfg = get_config(arch)
        assert cfg.num_layers == L and cfg.d_model == d, arch
        assert cfg.num_heads == H and cfg.kv_heads == K, arch
        assert cfg.d_ff == ff and cfg.vocab_size == V, arch
        assert cfg.source, f"{arch} missing citation"


def test_moe_counts():
    moe = get_config("qwen2-moe-a2.7b")
    assert moe.num_experts == 60 and moe.experts_per_token == 4
    assert moe.shared_expert_d_ff == 4 * 1408
    grok = get_config("grok-1-314b")
    assert grok.num_experts == 8 and grok.experts_per_token == 2


def test_param_scale_sanity():
    """Full-size parameter counts are in the right ballpark (analytic)."""
    from repro.core.archcost import param_counts
    approx = {
        "internlm2-20b": 20e9, "qwen1.5-4b": 4e9, "gemma3-1b": 1.3e9,
        "grok-1-314b": 314e9, "rwkv6-1.6b": 1.6e9,
        "recurrentgemma-2b": 2.7e9, "llama-3.2-vision-90b": 90e9,
        "qwen1.5-32b": 32e9, "qwen2-moe-a2.7b": 14e9,
    }
    for arch, want in approx.items():
        n, _ = param_counts(get_config(arch))
        assert 0.5 * want < n < 1.8 * want, (arch, n, want)
