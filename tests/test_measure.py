"""The measurement loop: segmentation math, calibration fits, the
``jax:`` workload provider, the hostdev flag helper, and (slow, in a
subprocess with forced host devices) the end-to-end instrumented run
with its bytes cross-check — lowered ``wfbp`` HLO collective bytes
must equal the matching workload table's ``sum(grad_bytes)``, tying
``comm/sync.py``, ``launch/hlo.py`` and ``core/workloads.py``
together."""
import math
import os
from pathlib import Path

import numpy as np
import pytest

from repro.core.dag import IterationCosts
from repro.core.predictor import (SYNC_POLICY_MODELS, predict_sync_policy,
                                  predict_workload)
from repro.core.policies import CAFFE_MPI, get_policy
from repro.core.scenarios import ScenarioGrid
from repro.core.sweep import sweep
from repro.core.workloads import (clear_workload_cache, known_workloads,
                                  resolve_workload)
from repro.launch.hostdev import (HOST_DEVICE_FLAG, child_env,
                                  force_host_device_count,
                                  host_device_flags)
from repro.measure.calibrate import (METRIC_COLLECTIVE_BYTES, fit_alpha_beta,
                                     comm_scale_from_fit)
from repro.measure.harness import segment_from_depths
from repro.traces.format import make_trace, read_trace, write_trace

SRC = str(Path(__file__).resolve().parents[1] / "src")


# ----------------------------------------------------------------------
# hostdev: the shared XLA_FLAGS helper (the dry-run clobber fix)
# ----------------------------------------------------------------------
class TestHostdev:
    def test_fresh_env(self):
        assert host_device_flags(8) == f"{HOST_DEVICE_FLAG}=8"

    def test_preserves_user_flags(self):
        out = host_device_flags(8, "--xla_cpu_enable_fast_math=false")
        assert "--xla_cpu_enable_fast_math=false" in out
        assert out.endswith(f"{HOST_DEVICE_FLAG}=8")

    def test_replaces_existing_count_idempotently(self):
        once = host_device_flags(8, f"--foo=1 {HOST_DEVICE_FLAG}=2")
        again = host_device_flags(8, once)
        assert once == again == f"--foo=1 {HOST_DEVICE_FLAG}=8"

    def test_force_applies_to_env(self):
        env = {"XLA_FLAGS": "--bar=2"}
        value = force_host_device_count(4, env)
        assert env["XLA_FLAGS"] == value
        assert "--bar=2" in value and f"{HOST_DEVICE_FLAG}=4" in value

    def test_child_env_copies(self):
        env = child_env(4, {"PYTHONPATH": "x"})
        assert env["PYTHONPATH"] == "x"
        assert f"{HOST_DEVICE_FLAG}=4" in env["XLA_FLAGS"]

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            host_device_flags(0)


# ----------------------------------------------------------------------
# Scan-structure segmentation (pure math)
# ----------------------------------------------------------------------
class TestSegmentation:
    def test_exact_recovery_from_linear_data(self):
        # fwd = 0.5 + 0.2*u ; full = 0.8 + 0.7*u  (=> bwd 0.3 + 0.5*u)
        units = [2, 4, 8]
        fwd = [0.5 + 0.2 * u for u in units]
        full = [0.8 + 0.7 * u for u in units]
        seg = segment_from_depths(units, fwd, full)
        assert seg.unit_fwd_s == pytest.approx(0.2)
        assert seg.unit_bwd_s == pytest.approx(0.5)
        assert seg.rest_fwd_s == pytest.approx(0.5)
        assert seg.rest_bwd_s == pytest.approx(0.3)

    def test_noise_clamps_to_zero(self):
        # full < fwd (impossible physically, pure noise): bwd clamps to 0
        seg = segment_from_depths([1, 2], [1.0, 2.0], [0.9, 1.8])
        assert seg.unit_bwd_s == 0.0
        assert seg.rest_bwd_s == pytest.approx(0.0, abs=1e-12)

    def test_requires_two_distinct_depths(self):
        with pytest.raises(ValueError):
            segment_from_depths([3], [1.0], [2.0])
        with pytest.raises(ValueError):
            segment_from_depths([3, 3], [1.0, 1.0], [2.0, 2.0])


# ----------------------------------------------------------------------
# Alpha-beta calibration fit
# ----------------------------------------------------------------------
class TestAlphaBetaFit:
    def test_exact_two_point_fit(self):
        alpha, bw = 2e-4, 5e9
        samples = [(1e6, alpha + 1e6 / bw), (1e8, alpha + 1e8 / bw)]
        lat, fit_bw = fit_alpha_beta(samples)
        assert lat == pytest.approx(alpha, rel=1e-9)
        assert fit_bw == pytest.approx(bw, rel=1e-9)

    def test_no_samples_means_no_comm(self):
        lat, bw = fit_alpha_beta([])
        assert lat == 0.0 and math.isinf(bw)
        assert comm_scale_from_fit(lat, bw)(1e9, 0.0) == 0.0

    def test_single_sample_pins_latency_to_zero(self):
        lat, bw = fit_alpha_beta([(1e6, 1e-3)])
        assert lat == 0.0
        assert bw == pytest.approx(1e9)

    def test_repeated_payloads_collapse_to_their_minimum(self):
        # noisy repeats of one payload: an outlier-first ordering must
        # not decide the fit — the minimum observation does
        lat, bw = fit_alpha_beta([(1e6, 9e-3), (1e6, 1e-3), (1e6, 2e-3)])
        assert lat == 0.0
        assert bw == pytest.approx(1e9)
        alpha, beta = 2e-4, 5e9
        samples = [(1e6, alpha + 1e6 / beta + 5e-3),     # outlier
                   (1e6, alpha + 1e6 / beta),
                   (1e8, alpha + 1e8 / beta)]
        lat, bw = fit_alpha_beta(samples)
        assert lat == pytest.approx(alpha, rel=1e-9)
        assert bw == pytest.approx(beta, rel=1e-9)

    def test_negative_slope_degrades_to_infinite_bandwidth(self):
        lat, bw = fit_alpha_beta([(1e6, 2e-3), (2e6, 1e-3)])
        assert math.isinf(bw)

    def test_comm_scale_zero_payload(self):
        scale = comm_scale_from_fit(1e-4, 1e9)
        assert scale(0.0, 123.0) == 0.0
        assert scale(1e9, 0.0) == pytest.approx(1e-4 + 1.0)


# ----------------------------------------------------------------------
# Payload accounting across mixed parameter dtypes
# ----------------------------------------------------------------------
class TestExpectedCollectiveBytes:
    def test_per_leaf_accounting_with_mixed_dtypes(self):
        """bf16 configs keep f32 leaves (norms): the bucketed (f32
        upcast) expectation must count 4 bytes per *element*, and the
        at_end/wfbp one each leaf's own dtype — rescaling a
        dtype-weighted total would miscount the mix."""
        import jax
        import jax.numpy as jnp

        from repro.configs import get_config
        from repro.measure.calibrate import expected_collective_bytes
        from repro.models import transformer as T

        cfg = get_config("qwen1.5-4b").reduced(
            num_layers=2, d_model=64, num_heads=4, d_ff=128,
            vocab_size=256, dtype=jnp.bfloat16)
        leaves = jax.tree_util.tree_leaves(
            jax.eval_shape(lambda k: T.init_lm(cfg, k),
                           jax.random.PRNGKey(0)))
        n_elems = sum(l.size for l in leaves)
        dtype_bytes = sum(l.size * jnp.dtype(l.dtype).itemsize
                          for l in leaves)
        assert expected_collective_bytes(cfg, "bucketed") \
            == 4.0 * n_elems + METRIC_COLLECTIVE_BYTES
        assert expected_collective_bytes(cfg, "wfbp") \
            == dtype_bytes + METRIC_COLLECTIVE_BYTES
        assert expected_collective_bytes(cfg, "at_end") \
            == dtype_bytes + METRIC_COLLECTIVE_BYTES


# ----------------------------------------------------------------------
# Runner geometry flags
# ----------------------------------------------------------------------
class TestRunnerGeometry:
    def test_smoke_preset_applies_when_flags_untouched(self):
        from repro.measure.run import (SMOKE_GEOMETRY, _geometry_from_args,
                                       build_parser)

        args = build_parser().parse_args(["--arch", "gemma3-1b", "--smoke"])
        assert _geometry_from_args(args) == SMOKE_GEOMETRY

    def test_explicit_flag_wins_even_when_equal_to_full_default(self):
        from repro.measure.run import (Geometry, SMOKE_GEOMETRY,
                                       _geometry_from_args, build_parser)

        full = Geometry()
        args = build_parser().parse_args(
            ["--arch", "gemma3-1b", "--smoke",
             "--seq-len", str(full.seq_len)])
        g = _geometry_from_args(args)
        assert g.seq_len == full.seq_len          # explicit value kept
        assert g.num_layers == SMOKE_GEOMETRY.num_layers  # preset rest

    def test_every_geometry_field_has_a_parser_flag(self):
        import dataclasses

        from repro.measure.run import Geometry, _geometry_flag, build_parser

        parser = build_parser()
        argv = ["--arch", "gemma3-1b"]
        for i, f in enumerate(dataclasses.fields(Geometry)):
            argv += [_geometry_flag(f.name), str(100 + i)]
        args = parser.parse_args(argv)
        for i, f in enumerate(dataclasses.fields(Geometry)):
            assert getattr(args, f.name) == 100 + i


# ----------------------------------------------------------------------
# Sync-policy prediction mapping
# ----------------------------------------------------------------------
class TestPredictSyncPolicy:
    costs = IterationCosts(
        t_f=[0.01, 0.02, 0.03], t_b=[0.02, 0.04, 0.06],
        t_c=[0.005, 0.01, 0.015], t_io=0.0, t_h2d=0.0, t_u=0.007,
        grad_bytes=[1e6, 2e6, 3e6])

    def test_at_end_is_one_fused_collective_after_backward(self):
        scale = comm_scale_from_fit(1e-3, 1e9)
        t = predict_sync_policy(self.costs, 4, "at_end", comm_scale=scale)
        serial = sum(self.costs.t_f) + sum(self.costs.t_b)
        expected = serial + scale(6e6, 0.0) + self.costs.t_u
        assert t == pytest.approx(expected, rel=1e-9)

    def test_wfbp_matches_caffe_mpi_policy(self):
        from repro.core.simulator import simulate_steady

        t = predict_sync_policy(self.costs, 4, "wfbp")
        assert t == pytest.approx(
            simulate_steady(self.costs, 4, CAFFE_MPI, n_iterations=8),
            rel=1e-9)

    def test_bucketed_threshold_override(self):
        scale = comm_scale_from_fit(1e-3, 1e9)
        # tiny threshold -> per-layer buckets; giant -> one fused bucket
        t_small = predict_sync_policy(self.costs, 4, "bucketed",
                                      comm_scale=scale, bucket_bytes=1.0)
        t_fused = predict_sync_policy(self.costs, 4, "bucketed",
                                      comm_scale=scale, bucket_bytes=1e12)
        t_at_end = predict_sync_policy(self.costs, 4, "at_end",
                                       comm_scale=scale)
        assert t_fused == pytest.approx(t_at_end, rel=1e-9)
        assert t_small != pytest.approx(t_fused, rel=1e-6)

    def test_unknown_policy_raises(self):
        with pytest.raises(ValueError, match="unknown sync policy"):
            predict_sync_policy(self.costs, 4, "gossip")

    def test_model_table_is_exhaustive_over_sync_policies(self):
        from repro.comm.sync import SYNC_POLICIES

        assert set(SYNC_POLICY_MODELS) == set(SYNC_POLICIES) - {"none"}


# ----------------------------------------------------------------------
# Trace-format bytes-per-sample header
# ----------------------------------------------------------------------
class TestBytesPerSampleHeader:
    def test_round_trip(self, tmp_path):
        tr = make_trace("net", "clu",
                        [(0, "embed", 10.0, 20.0, 0.0, 4096.0),
                         (1, "unit0", 5.0, 9.0, 0.0, 2048.0)],
                        batch_per_gpu=4, bytes_per_sample=256.0)
        p = tmp_path / "t.trace"
        write_trace(tr, p)
        assert "# bytes-per-sample: 256" in p.read_text()
        back = read_trace(p)
        assert back == tr

    def test_absent_header_means_zero(self, tmp_path):
        tr = make_trace("net", "clu", [(0, "l", 1.0, 2.0, 0.0, 8.0)])
        p = tmp_path / "t.trace"
        write_trace(tr, p)
        assert "bytes-per-sample" not in p.read_text()
        assert read_trace(p).bytes_per_sample == 0.0

    def test_malformed_header_raises(self, tmp_path):
        p = tmp_path / "t.trace"
        p.write_text("# bytes-per-sample: lots\n0\tl\t1\t2\t0\t8\n")
        with pytest.raises(ValueError, match="bytes-per-sample"):
            read_trace(p)


# ----------------------------------------------------------------------
# jax: workload provider
# ----------------------------------------------------------------------
@pytest.fixture
def measured_dir(tmp_path, monkeypatch):
    """A measurement directory with one synthetic measured trace, wired
    in as $REPRO_MEASURE_DIR."""
    tr = make_trace("tiny-lm", "jax-host-cpu-x2",
                    [(0, "embed_head", 120.0, 260.0, 0.0, 524800.0),
                     (1, "unit0", 900.0, 1800.0, 0.0, 657920.0),
                     (2, "unit1", 900.0, 1800.0, 0.0, 657920.0)],
                    batch_per_gpu=2, bytes_per_sample=256.0)
    write_trace(tr, tmp_path / "tiny-lm.trace")
    monkeypatch.setenv("REPRO_MEASURE_DIR", str(tmp_path))
    clear_workload_cache()
    yield tmp_path
    clear_workload_cache()


class TestJaxProvider:
    def test_listed_in_known_workloads(self, measured_dir):
        assert "jax:tiny-lm" in known_workloads()

    def test_resolves_to_measured_table(self, measured_dir):
        tab = resolve_workload("jax:tiny-lm")
        assert tab.is_measured
        assert tab.name == "jax:tiny-lm"
        assert tab.num_layers == 3
        assert tab.bytes_per_sample == 256.0
        assert tab.batch_default == 2
        np.testing.assert_allclose(
            tab.grad_bytes, [524800.0, 657920.0, 657920.0])

    def test_resolves_explicit_path(self, measured_dir):
        path = str(measured_dir / "tiny-lm.trace")
        tab = resolve_workload(f"jax:{path}")
        assert tab.is_measured and tab.num_layers == 3

    def test_unknown_spec_mentions_the_measure_cli(self, measured_dir):
        with pytest.raises(ValueError, match="repro.measure"):
            resolve_workload("jax:never-measured")

    def test_predict_workload(self, measured_dir):
        from repro.core.hardware import CLUSTERS

        p = predict_workload("jax:tiny-lm", CLUSTERS["v100-nvlink-ib"],
                             8, CAFFE_MPI)
        assert p.iteration_time > 0
        assert 0 < p.speedup <= 8.0

    def test_sweeps_through_batched_engine_both_paths(self, measured_dir):
        """Closed-form AND bucket-timeline batched paths serve jax:
        workloads, and both agree with the event-driven oracle."""
        grid = ScenarioGrid(
            workloads=("jax:tiny-lm",),
            clusters=("k80-pcie-10gbe", "v100-nvlink-ib"),
            worker_counts=(2, 8),
            policies=("cntk", "caffe-mpi", "bucketed-25mb", "priority"),
            collectives=("ring",))
        fast = sweep(grid)
        assert fast.n_analytical == 8 and fast.n_timeline == 8 \
            and fast.n_simulated == 0
        oracle = sweep(grid, force_simulator=True)
        for rf, ro in zip(fast.rows, oracle.rows):
            assert rf["iteration_time_s"] == pytest.approx(
                ro["iteration_time_s"], rel=1e-6), rf

    def test_stale_cache_busted_on_rewrite(self, measured_dir):
        t1 = resolve_workload("jax:tiny-lm")
        tr = make_trace("tiny-lm", "jax-host-cpu-x2",
                        [(0, "embed_head", 50.0, 90.0, 0.0, 1000.0)],
                        batch_per_gpu=2)
        path = measured_dir / "tiny-lm.trace"
        write_trace(tr, path)
        os.utime(path, ns=(1, 1))   # force a distinct mtime
        t2 = resolve_workload("jax:tiny-lm")
        assert t2.num_layers == 1 and t1.num_layers == 3


# ----------------------------------------------------------------------
# End to end, in a forced-host-device subprocess (slow): measure a tiny
# model, then cross-check HLO collective bytes against the jax: table.
# ----------------------------------------------------------------------
@pytest.fixture(scope="module")
def measured_run(tmp_path_factory):
    from repro.measure.run import Geometry, measure_in_subprocess

    out = tmp_path_factory.mktemp("measure")
    # repeats=5 + seq_len=32 keep the segmentation slope (min-of-
    # repeats at a 2x depth spread) robustly above wall-clock noise
    # even on a loaded 2-core box; compile time dominates the cost
    geometry = Geometry(num_layers=2, d_model=64, num_heads=4, d_ff=128,
                        vocab_size=256, seq_len=32, batch_per_gpu=2,
                        n_devices=2, repeats=5, step_iters=3)
    doc = measure_in_subprocess("qwen1.5-4b", out_dir=out,
                                geometry=geometry, timeout=560)
    return out, doc


class TestMeasuredRunEndToEnd:
    def test_artifacts_and_sanity(self, measured_run):
        out, doc = measured_run
        assert (out / "qwen1.5-4b.trace").exists()
        for pol in ("at_end", "wfbp", "bucketed"):
            assert doc["policy_times_s"][pol] > 0
        assert doc["t_update_s"] > 0
        assert doc["allreduce_fit"]["bandwidth_bytes_per_s"] > 0
        assert len(doc["allreduce_samples"]) >= 2

    def test_wfbp_hlo_bytes_equal_table_grad_bytes(self, measured_run,
                                                   monkeypatch):
        """The satellite cross-check: the lowered wfbp step's
        while-loop-scaled HLO collective bytes equal the matching
        workload table's sum(grad_bytes) (plus the two scalar metric
        pmeans) — drift in comm/sync.py, launch/hlo.py or the table
        construction breaks this equality."""
        out, doc = measured_run
        monkeypatch.setenv("REPRO_MEASURE_DIR", str(out))
        clear_workload_cache()
        tab = resolve_workload("jax:qwen1.5-4b")
        table_bytes = float(np.sum(tab.grad_bytes))
        hlo_bytes = doc["collective_stats"]["wfbp"]["total_bytes"]
        assert hlo_bytes == pytest.approx(
            table_bytes + METRIC_COLLECTIVE_BYTES, rel=1e-9)
        # and the harness's own cross-check agreed, for every policy
        for pol, chk in doc["bytes_crosscheck"].items():
            assert chk["rel_err"] < 1e-6, (pol, chk)
        clear_workload_cache()

    def test_trace_segments_are_positive(self, measured_run):
        out, _ = measured_run
        trace = read_trace(out / "qwen1.5-4b.trace")
        recs = trace.iterations[0]
        assert [r.name for r in recs][:2] == ["embed_head", "unit0"]
        assert all(r.size_bytes > 0 for r in recs)
        assert all(r.forward_us >= 0 and r.backward_us >= 0 for r in recs)
        # unit compute must be non-degenerate (the scan slope)
        assert recs[1].forward_us > 0 and recs[1].backward_us > 0

    def test_predictions_are_finite_and_close(self, measured_run):
        """The Fig.-4 loop on the measured doc: model predictions for
        every policy are finite, positive and within a (generous,
        CPU-noise-proof) factor of the measurement."""
        from benchmarks.bench_model_vs_measured import predict_policies

        out, doc = measured_run
        preds = predict_policies(doc, str(out / "qwen1.5-4b.trace"))
        for pol, t_pred in preds.items():
            t_meas = doc["policy_times_s"][pol]
            assert math.isfinite(t_pred) and t_pred > 0
            assert t_pred / t_meas < 10 and t_meas / t_pred < 10, \
                (pol, t_pred, t_meas)
